//! End-to-end driver (DESIGN.md §7): the full AlexNet conv+pool stack on
//! a synthetic 227×227×3 image, **full cycle simulation**, activations
//! threaded layer to layer, conv1 golden-checked bit-exactly against the
//! AOT JAX/Pallas artifact through PJRT, and the paper's headline
//! metrics printed next to Table II.
//!
//!     make artifacts && cargo run --release --example alexnet_e2e

use convaix::coordinator::metrics::NetworkResult;
use convaix::coordinator::EngineConfig;
use convaix::energy::power;
use convaix::model::{alexnet_conv, alexnet_pools};
use convaix::runtime::{Manifest, PjrtRunner};
use convaix::util::table::Table;
use convaix::util::XorShift;

fn main() -> anyhow::Result<()> {
    let t_start = std::time::Instant::now();
    let convs = alexnet_conv();
    let pools = alexnet_pools();
    let mut rng = XorShift::new(2024);

    // synthetic input image (deterministic)
    let mut act = rng.i16_vec(3 * 227 * 227, -4000, 4000);
    // per-layer weights, kept for the golden check
    let weights: Vec<(Vec<i16>, Vec<i32>)> = convs
        .iter()
        .map(|l| {
            (
                rng.i16_vec(l.oc * (l.ic / l.groups) * l.fh * l.fw, -200, 200),
                rng.i32_vec(l.oc, -2000, 2000),
            )
        })
        .collect();

    // FullCycle is the EngineConfig default
    let mut engine = EngineConfig::new().ext_capacity(1 << 26).build();
    let mut net = NetworkResult { name: "AlexNet".into(), ..Default::default() };

    println!("running full-cycle simulation of AlexNet (conv+pool)...");
    for (i, l) in convs.iter().enumerate() {
        let (w, b) = &weights[i];
        let t0 = std::time::Instant::now();
        let r = engine.run_conv_layer(l, &act, w, b).map_err(|e| anyhow::anyhow!("{e}"))?;
        println!(
            "  {:6}: {:9} cycles, util {:.3}, host {:?}",
            l.name, r.cycles, r.utilization(), t0.elapsed()
        );
        act = r.out.clone();
        net.layers.push(r);
        // pooling after conv1, conv2, conv5
        let pool = match l.name {
            "conv1" => Some(&pools[0]),
            "conv2" => Some(&pools[1]),
            "conv5" => Some(&pools[2]),
            _ => None,
        };
        if let Some(p) = pool {
            let r = engine.run_pool_layer(p, &act).map_err(|e| anyhow::anyhow!("{e}"))?;
            println!("  {:6}: {:9} cycles (SFU)", p.name, r.cycles);
            act = r.out.clone();
            net.layers.push(r);
        }
    }

    // ---- golden check: conv1 against the AOT JAX/Pallas artifact ------
    let manifest = Manifest::load("artifacts")?;
    let art = manifest
        .conv("conv_alexnet_l1")
        .ok_or_else(|| anyhow::anyhow!("conv_alexnet_l1 artifact missing"))?;
    let runner = PjrtRunner::new()?;
    // re-generate the same input/weights used above
    let mut rng2 = XorShift::new(2024);
    let x0 = rng2.i16_vec(3 * 227 * 227, -4000, 4000);
    let (w0, b0) = (&weights[0].0, &weights[0].1);
    println!("golden-checking conv1 against JAX/Pallas via PJRT...");
    let golden = runner.run_conv(&manifest, art, &x0, w0, b0)?;
    let sim_out = {
        let mut engine2 = EngineConfig::new().ext_capacity(1 << 26).build();
        engine2
            .run_conv_layer(&convs[0], &x0, w0, b0)
            .map_err(|e| anyhow::anyhow!("{e}"))?
            .out
    };
    let mism = sim_out.iter().zip(&golden).filter(|(a, b)| a != b).count();
    assert_eq!(mism, 0, "conv1 golden mismatch: {mism} elements");
    println!("  conv1 golden: bit-exact OK ({} elements)", golden.len());

    // ---- headline metrics vs Table II ----------------------------------
    let secs = net.time_ms() / 1e3;
    let pwr = power::network_power(&net.stats(), secs);
    let conv_cycles: u64 = net.layers.iter().filter(|l| l.macs > 0).map(|l| l.cycles).sum();
    let conv_ms = conv_cycles as f64 / convaix::CLOCK_HZ as f64 * 1e3;
    let mut t = Table::new(
        "AlexNet end-to-end (full cycle sim) vs paper Table II",
        &["Metric", "Measured", "Paper"],
    );
    t.row(&["Conv processing time [ms]".into(), format!("{:.2}", conv_ms), "12.60".into()]);
    t.row(&["MAC utilization".into(), format!("{:.3}", net.utilization()), "0.69".into()]);
    t.row(&["Off-chip I/O [MByte]".into(), format!("{:.2}", net.io_mbytes()), "10.79 (8b)".into()]);
    t.row(&["Power [mW] (16b)".into(), format!("{:.1}", pwr.total_mw()), "228.8 (8b gated)".into()]);
    t.row(&[
        "Effective throughput [GOP/s]".into(),
        format!("{:.1}", net.gops()),
        format!("{:.1}", 2.0 * net.macs() as f64 / 0.0126 / 1e9),
    ]);
    t.print();
    println!("total wall time: {:?}", t_start.elapsed());
    Ok(())
}
