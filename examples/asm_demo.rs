//! Hand-written VLIW assembly on the simulator: a dot-product kernel in
//! `.cvx` syntax — the "C-programmable" story at the ISA level. Shows
//! the assembler, PM capacity accounting, disassembler round-trip, and
//! cycle/stall statistics.
//!
//!     cargo run --release --example asm_demo

use convaix::core::Cpu;
use convaix::isa::{asm, disasm};
use convaix::mem::pm::ProgramMem;
use convaix::util::XorShift;

const KERNEL: &str = r#"
; 16-wide dot products: for each of 8 steps, accumulate
; VRl[0..4) += bcast(input pixels) * filter vector from the FIFO.
; r1 = filter base, r2 = input base, r3 = output address
    csrwi frac_shift, 4
    csrwi lb_stride, 1
    ldvf [r1]!32                   ; prime the filter FIFO
    ldvf [r1]!32
    lbld 0, r2, 16                 ; line buffer <- 16 input pixels
    nop | vclra | vclra | vclra
    loopi 8, 1
    ldvf [r1]!32 | vmac lb:0, ff | vmac lb:4, ff | vmac lb:8, ff
    nop | vqmov v4, 1 | vqmov v8, 1 | vqmov v12, 1
    nop  | vmul lb:0, ff | vnop | vnop      ; drain the 2 primed entries
    nop  | vmul lb:0, ff | vnop | vnop      ; (into now-dead accumulators)
    stv v4, [r3]!32
    stv v8, [r3]!32
    stv v12, [r3]!32
    halt
"#;

fn main() -> anyhow::Result<()> {
    let prog = asm::assemble(KERNEL).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "assembled {} bundles -> {} bytes of the {} byte PM",
        prog.len(),
        prog.encoded_size(),
        convaix::mem::PM_BYTES
    );
    println!("--- disassembly ---\n{}", disasm::program(&prog));

    // round-trip check: asm(disasm(p)) == p
    let back = asm::assemble(&disasm::program(&prog)).map_err(|e| anyhow::anyhow!("{e}"))?;
    assert_eq!(prog.bundles, back.bundles);
    println!("asm <-> disasm round-trip: OK");

    let pm = ProgramMem::load(&prog)?;
    let mut cpu = Cpu::new(1 << 16);
    let mut rng = XorShift::new(1);
    // stage: 10 filter vectors (8 used + 2 overfetch) at 0x100, pixels at 0x400
    let filters = rng.i16_vec(16 * 10, -50, 50);
    let pixels = rng.i16_vec(16, -50, 50);
    cpu.mem.dm.poke_i16_slice(0x100, &filters);
    cpu.mem.dm.poke_i16_slice(0x400, &pixels);
    cpu.regs.set_r(convaix::isa::SReg(1), 0x100);
    cpu.regs.set_r(convaix::isa::SReg(2), 0x400);
    cpu.regs.set_r(convaix::isa::SReg(3), 0x800);

    let stats = cpu.run(&pm)?;
    println!(
        "ran in {} cycles: {} bundles, {} MAC ops, {} hazard stalls, {} lb stalls",
        stats.cycles, stats.bundles, stats.mac_ops, stats.hazard_stalls, stats.lb_stalls
    );

    // verify: stored vector i (slot i+1, slice j=1) lane l =
    //   requant( sum_k pix[4i+1] * filters[k][l] )
    let shift = 4;
    for (i, base) in [0x800usize, 0x820, 0x840].iter().enumerate() {
        let px = pixels[4 * i + 1] as i32;
        for l in 0..16 {
            let mut acc: i32 = 0;
            for k in 0..8 {
                acc = acc.wrapping_add(px * filters[k * 16 + l] as i32);
            }
            let expect = convaix::fixed::requantize(
                acc,
                shift,
                convaix::fixed::RoundMode::HalfUp,
                false,
            );
            let got = cpu.mem.dm.peek_i16(base + 2 * l);
            assert_eq!(got, expect, "vector {i} lane {l}");
        }
    }
    println!("dot-product results verified against host arithmetic: OK");
    Ok(())
}
