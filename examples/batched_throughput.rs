//! Batched throughput demo: serve a stream of frames on a multi-core
//! ConvAix pool — the production-serving scenario the paper's batch-1,
//! single-core setup cannot express.
//!
//! AlexNet and VGG-16 conv stacks, 8 frames, 1 → 4 cores, tile-analytic
//! mode at the paper's 8-bit gated operating point; each core count is
//! priced under both the partitioned and the shared external bus.
//!
//!     cargo run --release --example batched_throughput

use convaix::coordinator::{BusModel, EngineConfig, ExecMode, NetLayer};
use convaix::model::{alexnet_conv, conv_stack, vgg16_conv};
use convaix::util::table::Table;
use convaix::util::XorShift;

fn main() -> anyhow::Result<()> {
    const BATCH: usize = 8;
    for (name, conv) in [("AlexNet", alexnet_conv()), ("VGG-16", vgg16_conv())] {
        let (ic, ih, iw) = (conv[0].ic, conv[0].ih, conv[0].iw);
        let layers: Vec<NetLayer> = conv_stack(conv);
        let mut rng = XorShift::new(0xF00D);
        let inputs: Vec<Vec<i16>> =
            (0..BATCH).map(|_| rng.i16_vec(ic * ih * iw, -2000, 2000)).collect();

        let mut t = Table::new(
            &format!("{name}: {BATCH} frames fanned out over the core pool"),
            &["Cores", "Bus", "Batch latency [ms]", "Throughput [f/s]", "Speedup", "Useful frac"],
        );
        for cores in [1usize, 2, 4] {
            for bus in [BusModel::Partitioned, BusModel::Shared] {
                let mut engine = EngineConfig::new()
                    .mode(ExecMode::TileAnalytic)
                    .gate_bits(8)
                    .cores(cores)
                    .batch(BATCH)
                    .bus(bus)
                    .build();
                let br = engine
                    .run_batched(name, &layers, &inputs)
                    .map_err(|e| anyhow::anyhow!("{e}"))?;
                let busy = br
                    .core_utilization()
                    .iter()
                    .map(|u| format!("{u:.2}"))
                    .collect::<Vec<_>>()
                    .join(" ");
                t.row(&[
                    cores.to_string(),
                    format!("{bus:?}"),
                    format!(
                        "{:.2}",
                        br.makespan_cycles() as f64 / convaix::CLOCK_HZ as f64 * 1e3
                    ),
                    format!("{:.1}", br.throughput_fps()),
                    format!("{:.2}x", br.speedup()),
                    busy,
                ]);
            }
        }
        t.print();
        println!();
    }
    Ok(())
}
