//! Design-space exploration — the flexibility story of the paper
//! (Section I: "tiling-factors and loop-order can be flexibly adjusted
//! in software"; Section IV knobs). Sweeps:
//!
//! 1. lane mapping variant A vs B per layer (what the planner chooses),
//! 2. precision gating 16 vs 8 bit (energy, Fig. 3c effect),
//! 3. vector geometry (lanes/slices/slots) through the area model —
//!    the design-time unrolling trade-off.
//!
//!     cargo run --release --example design_space

use convaix::codegen::layout::{self, Variant};
use convaix::coordinator::{EngineConfig, ExecMode};
use convaix::energy::{area, power};
use convaix::model::{alexnet_conv, vgg16_conv, ConvLayer};
use convaix::util::table::Table;
use convaix::util::XorShift;

fn run_one(l: &ConvLayer, gate: u8) -> anyhow::Result<convaix::coordinator::LayerResult> {
    let mut engine = EngineConfig::new()
        .mode(ExecMode::TileAnalytic)
        .gate_bits(gate)
        .build();
    let mut rng = XorShift::new(9);
    let x = vec![0i16; l.ic * l.ih * l.iw];
    let w = rng.i16_vec(l.oc * (l.ic / l.groups) * l.fh * l.fw, -128, 128);
    let b = rng.i32_vec(l.oc, -500, 500);
    engine.run_conv_layer(l, &x, &w, &b).map_err(|e| anyhow::anyhow!("{e}"))
}

fn main() -> anyhow::Result<()> {
    // --- 1. variant choice per layer ------------------------------------
    let mut t = Table::new(
        "Lane-mapping variants (A: lanes=OCh, B: lanes=pixels) — estimated utilization",
        &["Layer", "est A", "est B", "planner picks", "why"],
    );
    for l in alexnet_conv().iter().chain(vgg16_conv().iter()) {
        let d = l.per_group();
        let ea = layout::plan_variant(&d, Variant::A).map(|p| p.util_estimate());
        let eb = layout::plan_variant(&d, Variant::B).map(|p| p.util_estimate());
        let pick = layout::plan(&d)?;
        t.row(&[
            l.name.into(),
            ea.as_ref().map(|u| format!("{u:.3}")).unwrap_or("infeasible".into()),
            eb.as_ref().map(|u| format!("{u:.3}")).unwrap_or("infeasible".into()),
            format!("{:?}", pick.variant),
            match pick.variant {
                Variant::A => "wide rows / 16-ch tiles",
                Variant::B => "narrow rows / many channels",
            }
            .into(),
        ]);
    }
    t.print();

    // --- 2. precision gating --------------------------------------------
    let mut t = Table::new(
        "Precision gating (AlexNet conv3): energy scales, cycles don't",
        &["gate bits", "cycles", "vALU mW", "total mW"],
    );
    let l = alexnet_conv().into_iter().nth(2).unwrap();
    for gate in [16u8, 8] {
        let r = run_one(&l, gate)?;
        let p = power::network_power(&r.stats, r.cycles as f64 / convaix::CLOCK_HZ as f64);
        t.row(&[
            gate.to_string(),
            r.cycles.to_string(),
            format!("{:.1}", p.valu_mw),
            format!("{:.1}", p.total_mw()),
        ]);
    }
    t.print();

    // --- 3. vector geometry (design-time unrolling factors) --------------
    let mut t = Table::new(
        "Design-time geometry sweep (area model): peak throughput vs logic area",
        &["slots x slices x lanes", "MACs/cycle", "peak GOP/s", "logic kGE", "GOP/s/MGE (peak)"],
    );
    for (slots, slices, lanes) in
        [(3usize, 4usize, 16usize), (2, 4, 16), (3, 4, 8), (3, 2, 16), (4, 4, 16), (3, 4, 32)]
    {
        let kge = area::logic_kge(slots, slices, lanes);
        let gops = area::peak_gops(slots, slices, lanes, 400.0);
        t.row(&[
            format!("{slots} x {slices} x {lanes}"),
            (slots * slices * lanes).to_string(),
            format!("{gops:.1}"),
            format!("{kge:.0}"),
            format!("{:.1}", gops / (kge / 1e3)),
        ]);
    }
    t.print();
    println!("reference design (3 x 4 x 16) matches Table I: 192 MACs, 153.6 GOP/s, 1293 kGE");
    Ok(())
}
