//! Quickstart: compile one conv layer to a ConvAix VLIW kernel, run it on
//! the cycle-accurate simulator, verify the output bit-exactly against
//! the host reference (and the JAX/Pallas golden artifact if present),
//! and print the metrics the paper reports.
//!
//!     cargo run --release --example quickstart

use convaix::codegen::{layout, refconv};
use convaix::coordinator::EngineConfig;
use convaix::fixed::RoundMode;
use convaix::model::ConvLayer;
use convaix::runtime::{golden_conv_check, Manifest, PjrtRunner};
use convaix::util::XorShift;

fn main() -> anyhow::Result<()> {
    // A VGG-style 3x3 conv layer.
    let layer = ConvLayer::new("quickstart", 16, 32, 32, 32, 3, 3, 1, 1, 1);
    println!(
        "layer: {}x{}x{} -> {}x{}x{}, {:.1} MMACs",
        layer.ic, layer.ih, layer.iw, layer.oc, layer.oh(), layer.ow(),
        layer.macs() as f64 / 1e6
    );

    // what the planner decided (Fig. 2 slicing)
    let plan = layout::plan(&layer)?;
    println!(
        "plan: variant {:?}, {} input slice(s), {} band(s) of {} rows, {} oc tiles, window {} px{}",
        plan.variant, plan.m, plan.n_bands, plan.band_rows, plan.n_tiles, plan.win,
        if plan.fused_rows { " (2-D fused line-buffer loads)" } else { "" },
    );

    // synthetic tensors
    let mut rng = XorShift::new(7);
    let x = rng.i16_vec(layer.ic * layer.ih * layer.iw, -2000, 2000);
    let w = rng.i16_vec(layer.oc * layer.ic * 9, -256, 256);
    let b = rng.i32_vec(layer.oc, -1000, 1000);

    // run on the cycle simulator through the engine front door
    let mut engine = EngineConfig::new().ext_capacity(1 << 22).build();
    let r = engine
        .run_conv_layer(&layer, &x, &w, &b)
        .map_err(|e| anyhow::anyhow!("{e}"))?;

    // verify against the host reference (same Q-format contract)
    let expect = refconv::conv2d(&x, &w, &b, &layer, RoundMode::HalfUp, 16);
    assert_eq!(r.out, expect, "simulator output mismatch");
    println!("bit-exact vs host reference: OK ({} elements)", expect.len());

    println!(
        "cycles {}  time {:.3} ms @400MHz  utilization {:.3}  {:.1} GOP/s  off-chip {:.1} KB",
        r.cycles,
        r.time_ms(),
        r.utilization(),
        r.gops(),
        r.io_total() as f64 / 1e3
    );

    // golden check against the AOT JAX/Pallas artifact (optional)
    match Manifest::load("artifacts") {
        Ok(manifest) => {
            let runner = PjrtRunner::new()?;
            if let Some(art) = manifest.conv("conv_vgg_s") {
                let g = golden_conv_check(&runner, &manifest, art, 7)?;
                println!(
                    "golden vs JAX/Pallas ({}): {}",
                    art.name,
                    if g.ok() { "bit-exact OK" } else { "MISMATCH" }
                );
                assert!(g.ok());
            }
        }
        Err(_) => println!("(artifacts/ not built — run `make artifacts` for the PJRT golden check)"),
    }
    Ok(())
}
