//! Layer-pipelined streaming demo: cut a conv stack into contiguous
//! stages (one ConvAix core per stage, balanced by the predicted-
//! makespan cost model) and stream frames through them — frame t on
//! stage i while frame t−1 occupies stage i+1. The steady-state
//! regime of Shen et al.'s resource partitioning (arXiv:1607.00064),
//! next to the frame fan-out mode the same pool offers.
//!
//! AlexNet and VGG-16 — both the conv stacks and the full end-to-end
//! nets with their fc6/fc7/fc8 tails (the weight-DMA-bound FC tail
//! lands in its own stage; watch the per-stage table) — a 5-frame
//! stream (deliberately not a multiple of the core count), 1 → 4
//! cores, tile-analytic mode at the paper's 8-bit gated operating
//! point, shared external bus.
//!
//!     cargo run --release --example streaming_pipeline

use convaix::cli::report;
use convaix::coordinator::{BusModel, EngineConfig, ExecMode, NetLayer, PoolMode};
use convaix::model::{alexnet_conv, alexnet_full, conv_stack, vgg16_conv, vgg16_full};
use convaix::util::table::Table;
use convaix::util::XorShift;

fn main() -> anyhow::Result<()> {
    const STREAM: usize = 5;
    let nets: [(&str, Vec<NetLayer>); 4] = [
        ("AlexNet", conv_stack(alexnet_conv())),
        ("VGG-16", conv_stack(vgg16_conv())),
        ("AlexNet-full", alexnet_full()),
        ("VGG-16-full", vgg16_full()),
    ];
    for (name, layers) in nets {
        let in_elems = layers[0].op().in_elems();
        let mut rng = XorShift::new(0x57AE);
        let inputs: Vec<Vec<i16>> =
            (0..STREAM).map(|_| rng.i16_vec(in_elems, -2000, 2000)).collect();

        let mut t = Table::new(
            &format!("{name}: {STREAM}-frame stream, pipeline vs frame fan-out"),
            &[
                "Cores",
                "Pipe steady [f/s]",
                "Pipe fill [ms]",
                "Pipe stream [f/s]",
                "Fan-out [f/s]",
            ],
        );
        for cores in [1usize, 2, 4] {
            let cfg = EngineConfig::new()
                .mode(ExecMode::TileAnalytic)
                .gate_bits(8)
                .cores(cores)
                .batch(STREAM)
                .bus(BusModel::Shared);

            let pr = cfg
                .clone()
                .pool_mode(PoolMode::Pipelined)
                .build()
                .run_streaming(name, &layers, &inputs)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            let fo = cfg
                .build()
                .run_batched(name, &layers, &inputs)
                .map_err(|e| anyhow::anyhow!("{e}"))?;

            t.row(&[
                cores.to_string(),
                format!("{:.1}", pr.steady_state_fps()),
                format!("{:.2}", pr.fill_cycles as f64 / convaix::CLOCK_HZ as f64 * 1e3),
                format!("{:.1}", pr.throughput_fps()),
                format!("{:.1}", fo.throughput_fps()),
            ]);
        }
        t.print();

        // the per-stage breakdown at 4 cores, through the CLI renderer
        let cfg4 = EngineConfig::new()
            .mode(ExecMode::TileAnalytic)
            .gate_bits(8)
            .cores(4)
            .batch(STREAM)
            .pool_mode(PoolMode::Pipelined)
            .bus(BusModel::Shared);
        let pr = cfg4
            .clone()
            .build()
            .run_streaming(name, &layers, &inputs)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        print!("{}", report::streaming_report(&pr, &layers, &cfg4));
        println!();
    }
    Ok(())
}
