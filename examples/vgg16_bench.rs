//! VGG-16 benchmark: the paper's second workload, per-layer breakdown in
//! tile-analytic mode (cycle-simulated row kernels composed analytically;
//! pass --full for the complete cycle simulation, ~minutes).
//!
//!     cargo run --release --example vgg16_bench [-- --full]

use convaix::cli::report;
use convaix::coordinator::{EngineConfig, ExecMode};
use convaix::energy::power;
use convaix::model::{conv_stack, vgg16_conv};
use convaix::util::table::Table;

fn main() -> anyhow::Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let cfg = EngineConfig::new()
        .mode(if full { ExecMode::FullCycle } else { ExecMode::TileAnalytic })
        .gate_bits(8);
    let t0 = std::time::Instant::now();
    let net = report::bench_network("VGG-16", &conv_stack(vgg16_conv()), &cfg)?;

    let mut t = Table::new(
        "VGG-16 conv layers on ConvAix",
        &["Layer", "MACs [M]", "Cycles", "Util", "Time [ms]", "GOP/s", "I/O [MB]"],
    );
    for l in &net.layers {
        t.row(&[
            l.name.to_string(),
            format!("{:.1}", l.macs as f64 / 1e6),
            l.cycles.to_string(),
            format!("{:.3}", l.utilization()),
            format!("{:.2}", l.time_ms()),
            format!("{:.1}", l.gops()),
            format!("{:.2}", l.io_total() as f64 / 1e6),
        ]);
    }
    t.print();

    let secs = net.time_ms() / 1e3;
    let p = power::network_power(&net.stats(), secs);
    println!(
        "total: {:.1} ms (paper 263.0), util {:.3} (paper 0.76), {:.1} MB I/O (paper 208.14), \
         {:.1} mW (paper 223.9), {:.0} GOP/s/W (paper 497)",
        net.time_ms(),
        net.utilization(),
        net.io_mbytes(),
        p.total_mw(),
        power::energy_eff_gops_per_w(net.macs(), secs, p.total_mw()),
    );
    println!("(mode: {}, wall {:?})", if full { "full-cycle" } else { "tile-analytic" }, t0.elapsed());
    Ok(())
}
