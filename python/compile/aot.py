"""AOT lowering: JAX/Pallas model -> HLO *text* artifacts for the rust runtime.

Interchange format is HLO text, NOT `.serialize()`: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids, which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`). The text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/gen_hlo.py).

Emits one `<name>.hlo.txt` per artifact plus `manifest.json` describing the
shapes/parameters, which the rust golden tests parse to drive bit-exact
comparisons (simulator OFMap == JAX/Pallas OFMap).

Python runs ONLY here (build time); the rust binary is self-contained
afterwards.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.conv16 import conv2d_pallas, maxpool2d_pallas
from .model import ConvCfg


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# --- artifact set ---------------------------------------------------------
# Conv artifacts: one per microarchitecturally-distinct configuration the
# simulator supports (unit filters, strided, padded, large first-layer).
# OC is a multiple of 16 here; the rust side compares against the same
# padded shapes (model-level OC padding is exercised in python tests).

CONV_ARTIFACTS = [
    ConvCfg("conv_small",      ic=8,  ih=16,  iw=16,  oc=16, fh=3,  fw=3,  pad=1),
    ConvCfg("conv_stride2",    ic=4,  ih=16,  iw=16,  oc=32, fh=5,  fw=5,  stride=2, pad=2),
    ConvCfg("conv_1x1",        ic=16, ih=12,  iw=12,  oc=16, fh=1,  fw=1, relu=False),
    ConvCfg("conv_vgg_s",      ic=16, ih=32,  iw=32,  oc=16, fh=3,  fw=3,  pad=1),
    ConvCfg("conv_alexnet_l1", ic=3,  ih=227, iw=227, oc=96, fh=11, fw=11, stride=4),
]

POOL_ARTIFACTS = [
    # (name, ic, ih, iw, size, stride)
    ("pool_3s2", 16, 13, 13, 3, 2),
    ("pool_2s2", 8, 16, 16, 2, 2),
]


def lower_conv(cfg: ConvCfg):
    def fn(x, w, b):
        return (conv2d_pallas(x, w, b, stride=cfg.stride, pad=cfg.pad,
                              frac_shift=cfg.frac_shift, relu=cfg.relu),)

    xs = jax.ShapeDtypeStruct((cfg.ic, cfg.ih, cfg.iw), jnp.int16)
    ws = jax.ShapeDtypeStruct((cfg.oc, cfg.ic, cfg.fh, cfg.fw), jnp.int16)
    bs = jax.ShapeDtypeStruct((cfg.oc,), jnp.int32)
    return jax.jit(fn).lower(xs, ws, bs)


def lower_pool(ic, ih, iw, size, stride):
    def fn(x):
        return (maxpool2d_pallas(x, size=size, stride=stride),)

    xs = jax.ShapeDtypeStruct((ic, ih, iw), jnp.int16)
    return jax.jit(fn).lower(xs)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"convs": [], "pools": []}
    for cfg in CONV_ARTIFACTS:
        text = to_hlo_text(lower_conv(cfg))
        path = os.path.join(args.out, f"{cfg.name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["convs"].append({
            "name": cfg.name, "ic": cfg.ic, "ih": cfg.ih, "iw": cfg.iw,
            "oc": cfg.oc, "fh": cfg.fh, "fw": cfg.fw, "stride": cfg.stride,
            "pad": cfg.pad, "frac_shift": cfg.frac_shift,
            "relu": int(cfg.relu), "oh": cfg.oh, "ow": cfg.ow,
        })
        print(f"wrote {path} ({len(text)} chars)")

    for (name, ic, ih, iw, size, stride) in POOL_ARTIFACTS:
        text = to_hlo_text(lower_pool(ic, ih, iw, size, stride))
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        oh = (ih - size) // stride + 1
        ow = (iw - size) // stride + 1
        manifest["pools"].append({
            "name": name, "ic": ic, "ih": ih, "iw": iw, "size": size,
            "stride": stride, "oh": oh, "ow": ow,
        })
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {args.out}/manifest.json")


if __name__ == "__main__":
    main()
