"""Layer 1 — the ConvAix compute hot-spot as a Pallas kernel.

Fixed-point int16 2-D convolution with int32 accumulation, mirroring the
vALU mapping of the paper (DESIGN.md §4):

  * grid = (OC/16, OH): one program instance produces one OFMap row of one
    16-output-channel tile — the 16 vector *lanes* are output channels, the
    row dimension is what the 4 slices × 3 slots sweep on the ASIP.
  * inner reduction over k = (ic, fy, fx): one filter vector (16 OCh for a
    fixed k) is multiplied with a strided selection of input pixels from
    one IFMap row — exactly the line-buffer feed + broadcast operand
    prepare of the vALUs.
  * accumulation in int32 (the 512-bit VRl register file), requantization
    with fractional shift + round-half-up + saturation (the vALU's
    configurable rounding stage), optional fused ReLU (slot-1 SFU).

Hardware adaptation (DESIGN.md §3): the output block (16 × OW) stays
resident in VMEM across the whole reduction (≈ VRl + DM scratchpad), the
input is consumed row-wise (≈ line buffer), the filter tile is the second
resident operand (≈ pre-loaded filters of Fig. 2).

MUST be lowered with interpret=True: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .quant import requantize, mac_init

LANES = 16  # vector lanes per slice == OCh tile width


def _conv_row_kernel(x_ref, w_ref, b_ref, o_ref, *, ic, fh, fw, stride, ow,
                     frac_shift, relu):
    """One (16-OCh, OW) output row.

    x_ref: (IC, IHp, IWp) int16   padded input, fully resident
    w_ref: (16, IC*FH*FW) int16   filter tile for this OCh block
    b_ref: (16,) int32            bias tile
    o_ref: (16, 1, OW) int16      output row
    """
    oh_idx = pl.program_id(1)
    span = stride * (ow - 1) + 1  # input pixels touched per row per fx

    acc0 = jnp.broadcast_to(
        mac_init(b_ref[...], frac_shift)[:, None], (LANES, ow)
    ).astype(jnp.int32)

    def body(k, acc):
        # unravel k -> (ic, fy, fx); reduction order matches codegen/ref.
        c = k // (fh * fw)
        r = k % (fh * fw)
        fy = r // fw
        fx = r % fw
        # line-buffer read: one IFMap row, strided pixel select
        row = x_ref[c, oh_idx * stride + fy, :]          # (IWp,) int16
        window = jax.lax.dynamic_slice(row, (fx,), (span,))
        pix = window[::stride]                           # (OW,) int16
        wv = w_ref[:, k]                                 # (16,) int16
        # 16 lanes x OW positions of int16*int16 -> wrapping int32 MACs
        return acc + wv[:, None].astype(jnp.int32) * pix[None, :].astype(jnp.int32)

    acc = jax.lax.fori_loop(0, ic * fh * fw, body, acc0)
    o_ref[...] = requantize(acc, frac_shift, relu)[:, None, :]


def conv2d_pallas(x, w, b, *, stride=1, pad=0, frac_shift=8, relu=False,
                  interpret=True):
    """Pallas fixed-point conv. Shapes/semantics identical to ref.conv2d_ref.

    OC must be a multiple of 16 (the model layer pads; see model.py).
    """
    x = jnp.asarray(x, jnp.int16)
    w = jnp.asarray(w, jnp.int16)
    b = jnp.asarray(b, jnp.int32)
    ic, ih, iw = x.shape
    oc, ic2, fh, fw = w.shape
    assert ic == ic2
    assert oc % LANES == 0, f"OC={oc} must be a multiple of {LANES}"
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    ihp, iwp = ih + 2 * pad, iw + 2 * pad
    oh = (ihp - fh) // stride + 1
    ow = (iwp - fw) // stride + 1

    wmat = w.reshape(oc, ic * fh * fw)

    kernel = functools.partial(
        _conv_row_kernel, ic=ic, fh=fh, fw=fw, stride=stride, ow=ow,
        frac_shift=frac_shift, relu=relu,
    )
    out = pl.pallas_call(
        kernel,
        grid=(oc // LANES, oh),
        in_specs=[
            # full padded input resident (the ASIP streams rows; in Pallas
            # the whole map is the block, rows are read in the fori_loop)
            pl.BlockSpec((ic, ihp, iwp), lambda o, y: (0, 0, 0)),
            pl.BlockSpec((LANES, ic * fh * fw), lambda o, y: (o, 0)),
            pl.BlockSpec((LANES,), lambda o, y: (o,)),
        ],
        out_specs=pl.BlockSpec((LANES, 1, ow), lambda o, y: (o, y, 0)),
        out_shape=jax.ShapeDtypeStruct((oc, oh, ow), jnp.int16),
        interpret=interpret,
    )(xp, wmat, b)
    return out


def maxpool2d_pallas(x, *, size=2, stride=2, interpret=True):
    """int16 max-pool as a Pallas kernel (the slot-1 SFU path).

    Grid over output rows; each instance max-reduces a (IC, size, IW) strip.
    """
    x = jnp.asarray(x, jnp.int16)
    ic, ih, iw = x.shape
    oh = (ih - size) // stride + 1
    ow = (iw - size) // stride + 1

    def kernel(x_ref, o_ref):
        # pooling windows overlap when stride < size, which BlockSpec block
        # indexing cannot express — keep the input resident and slice rows
        # in-kernel (the SFU reads from the DM scratchpad the same way).
        y = pl.program_id(0)
        span = stride * (ow - 1) + 1
        acc = jnp.full((ic, ow), -32768, jnp.int16)
        for fy in range(size):
            strip = jax.lax.dynamic_slice(
                x_ref[...], (0, y * stride + fy, 0), (ic, 1, iw))[:, 0, :]
            for fx in range(size):
                vals = jax.lax.dynamic_slice(
                    strip, (0, fx), (ic, span))[:, ::stride]
                acc = jnp.maximum(acc, vals)
        o_ref[...] = acc[:, None, :]

    return pl.pallas_call(
        kernel,
        grid=(oh,),
        in_specs=[pl.BlockSpec((ic, ih, iw), lambda y: (0, 0, 0))],
        out_specs=pl.BlockSpec((ic, 1, ow), lambda y: (0, y, 0)),
        out_shape=jax.ShapeDtypeStruct((ic, oh, ow), jnp.int16),
        interpret=interpret,
    )(x)
