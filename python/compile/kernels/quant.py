"""Shared fixed-point (Q-format) arithmetic semantics for ConvAix.

These functions define the *bit-exact contract* between the three layers:

  * the Pallas kernel (`conv16.py`),
  * the pure-jnp oracle (`ref.py`),
  * the rust cycle simulator (`rust/src/fixed/`).

Semantics (mirrors the paper's vALU datapath, Section IV):

  * activations / weights : int16 (Q-format, fractional position implied)
  * MAC accumulation      : int32, two's-complement **wrapping** (VRl is a
    32-bit-per-lane register file; hardware wraps, so do we — jnp int32
    arithmetic wraps, rust uses `wrapping_*`)
  * requantization        : arithmetic-shift-right by the runtime-configured
    fractional shift with round-half-up (the ASIP's default rounding mode;
    the rust simulator also implements truncate and round-to-nearest-even,
    but the AOT artifacts are generated with round-half-up), then saturate
    to int16
  * optional ReLU fused after requantization (the slot-1 SFU)
  * precision gating of g < 16 bits zeroes the 16-g LSBs of *operands*
    (energy-saving technique from Moons et al.; numerics change, energy
    model scales MAC energy by the gated width)
"""

import jax.numpy as jnp

INT16_MIN = -32768
INT16_MAX = 32767


def requantize(acc_i32, frac_shift: int, relu: bool):
    """int32 accumulator -> int16 output. Round-half-up, saturate, opt. ReLU.

    `frac_shift` is static (a layer constant baked into the program, set at
    runtime on the ASIP via its config registers).
    """
    acc = acc_i32
    if frac_shift > 0:
        # round half-up: add 2^(s-1) (wrapping, as the 32-bit adder would),
        # then arithmetic shift right.
        acc = acc + jnp.int32(1 << (frac_shift - 1))
        acc = acc >> frac_shift
    acc = jnp.clip(acc, INT16_MIN, INT16_MAX)
    if relu:
        acc = jnp.maximum(acc, 0)
    return acc.astype(jnp.int16)


def gate_precision(x_i16, bits: int):
    """Zero the (16-bits) LSBs of an int16 operand (precision gating)."""
    if bits >= 16:
        return x_i16
    mask = jnp.int16(-(1 << (16 - bits)))  # e.g. bits=8 -> 0xFF00
    return x_i16 & mask


def mac_init(bias_i32, frac_shift: int):
    """Accumulator initial value: bias pre-shifted so that after the final
    fractional shift the bias lands at unit weight (acc = conv + bias<<s)."""
    return bias_i32 << frac_shift if frac_shift > 0 else bias_i32
