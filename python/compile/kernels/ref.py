"""Pure-jnp oracle for the ConvAix fixed-point conv / pool kernels.

This is the correctness reference the Pallas kernel (and transitively the
rust cycle simulator, via the AOT artifacts) is checked against. It is
written for clarity, not speed: im2col + int32 matmul.
"""

import jax.numpy as jnp
import numpy as np

from .quant import requantize, mac_init, gate_precision


def conv2d_ref(x, w, b, *, stride=1, pad=0, frac_shift=8, relu=False,
               gate_bits=16):
    """Fixed-point 2-D convolution, NCHW-without-N.

    x: int16 (IC, IH, IW)   activations
    w: int16 (OC, IC, FH, FW) weights
    b: int32 (OC,)          bias (added at accumulator scale << frac_shift)
    returns int16 (OC, OH, OW)
    """
    x = jnp.asarray(x, jnp.int16)
    w = jnp.asarray(w, jnp.int16)
    b = jnp.asarray(b, jnp.int32)
    if gate_bits < 16:
        x = gate_precision(x, gate_bits)
        w = gate_precision(w, gate_bits)
    ic, ih, iw = x.shape
    oc, ic2, fh, fw = w.shape
    assert ic == ic2, f"IC mismatch {ic} vs {ic2}"
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    ihp, iwp = ih + 2 * pad, iw + 2 * pad
    oh = (ihp - fh) // stride + 1
    ow = (iwp - fw) // stride + 1

    # im2col: (IC*FH*FW, OH*OW) — reduction order (ic, fy, fx) matches the
    # kernel; irrelevant for wrapping-int32 sums (assoc.+comm.), kept for
    # clarity.
    cols = []
    for fy in range(fh):
        for fx in range(fw):
            patch = xp[:, fy:fy + stride * (oh - 1) + 1:stride,
                       fx:fx + stride * (ow - 1) + 1:stride]
            cols.append(patch.reshape(ic, oh * ow))
    col = jnp.stack(cols, axis=1).reshape(ic * fh * fw, oh * ow)
    wmat = w.reshape(oc, ic * fh * fw)
    acc = jnp.matmul(wmat.astype(jnp.int32), col.astype(jnp.int32))
    acc = acc + mac_init(b, frac_shift)[:, None]
    out = requantize(acc, frac_shift, relu)
    return out.reshape(oc, oh, ow)


def maxpool2d_ref(x, *, size=2, stride=2):
    """int16 max pooling (IC, IH, IW) -> (IC, OH, OW). No padding."""
    x = jnp.asarray(x, jnp.int16)
    ic, ih, iw = x.shape
    oh = (ih - size) // stride + 1
    ow = (iw - size) // stride + 1
    views = []
    for fy in range(size):
        for fx in range(size):
            views.append(x[:, fy:fy + stride * (oh - 1) + 1:stride,
                           fx:fx + stride * (ow - 1) + 1:stride])
    return jnp.max(jnp.stack(views), axis=0)


def relu_ref(x):
    return jnp.maximum(jnp.asarray(x, jnp.int16), 0)


def conv2d_numpy(x, w, b, *, stride=1, pad=0, frac_shift=8, relu=False):
    """Second, independent oracle in plain numpy with explicit loops.

    Used by the test suite to cross-check `conv2d_ref` itself (triple
    modular redundancy: numpy loops vs jnp im2col vs pallas).
    """
    x = np.asarray(x, np.int64)
    w = np.asarray(w, np.int64)
    b = np.asarray(b, np.int64)
    ic, ih, iw = x.shape
    oc, _, fh, fw = w.shape
    xp = np.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    oh = (ih + 2 * pad - fh) // stride + 1
    ow = (iw + 2 * pad - fw) // stride + 1

    def wrap32(v):
        return ((v + 2**31) % 2**32) - 2**31

    out = np.zeros((oc, oh, ow), np.int16)
    for o in range(oc):
        for y in range(oh):
            for xo in range(ow):
                acc = wrap32(int(b[o]) << frac_shift)
                for c in range(ic):
                    for fy in range(fh):
                        for fx in range(fw):
                            acc = wrap32(acc + int(xp[c, y * stride + fy,
                                                      xo * stride + fx])
                                         * int(w[o, c, fy, fx]))
                if frac_shift > 0:
                    acc = wrap32(acc + (1 << (frac_shift - 1))) >> frac_shift
                acc = max(-32768, min(32767, acc))
                if relu:
                    acc = max(acc, 0)
                out[o, y, xo] = acc
    return out
