"""Layer 2 — the JAX model: quantized CNN layers composing the Pallas kernel.

These functions are the build-time definition of what ConvAix executes; they
are AOT-lowered by `aot.py` into HLO-text artifacts that the rust runtime
loads as the *golden model* for the cycle simulator (bit-exact comparison).

Only the network-shape tables needed for artifact generation live here; the
full AlexNet / VGG-16 workload tables used by the benchmarks are in
`rust/src/model/` (they must exist without python at runtime).
"""

from dataclasses import dataclass

import jax.numpy as jnp

from .kernels.conv16 import conv2d_pallas, maxpool2d_pallas, LANES
from .kernels.ref import conv2d_ref


@dataclass(frozen=True)
class ConvCfg:
    """One convolutional layer (batch-1, NCHW without N, as in the paper)."""
    name: str
    ic: int
    ih: int
    iw: int
    oc: int
    fh: int
    fw: int
    stride: int = 1
    pad: int = 0
    groups: int = 1
    frac_shift: int = 8
    relu: bool = True

    @property
    def oh(self):
        return (self.ih + 2 * self.pad - self.fh) // self.stride + 1

    @property
    def ow(self):
        return (self.iw + 2 * self.pad - self.fw) // self.stride + 1

    @property
    def macs(self):
        """MAC count (grouped convolution aware)."""
        return (self.oc * (self.ic // self.groups) * self.fh * self.fw
                * self.oh * self.ow)


def _pad_oc(oc):
    return (oc + LANES - 1) // LANES * LANES


def conv_layer(x, w, b, cfg: ConvCfg, *, use_pallas=True):
    """Quantized conv layer. Handles OC padding to the 16-lane width and
    grouped convolution (AlexNet conv2/4/5) by per-group kernel calls."""
    g = cfg.groups
    icg = cfg.ic // g
    ocg = cfg.oc // g
    outs = []
    fn = conv2d_pallas if use_pallas else conv2d_ref
    for gi in range(g):
        xg = x[gi * icg:(gi + 1) * icg]
        wg = w[gi * ocg:(gi + 1) * ocg]
        bg = b[gi * ocg:(gi + 1) * ocg]
        ocp = _pad_oc(ocg)
        if ocp != ocg and use_pallas:
            wg = jnp.pad(wg, ((0, ocp - ocg), (0, 0), (0, 0), (0, 0)))
            bg = jnp.pad(bg, (0, ocp - ocg))
        out = fn(xg, wg, bg, stride=cfg.stride, pad=cfg.pad,
                 frac_shift=cfg.frac_shift, relu=cfg.relu)
        outs.append(out[:ocg])
    return jnp.concatenate(outs, axis=0) if g > 1 else outs[0]


def maxpool_layer(x, *, size, stride, use_pallas=True):
    if use_pallas:
        return maxpool2d_pallas(x, size=size, stride=stride)
    from .kernels.ref import maxpool2d_ref
    return maxpool2d_ref(x, size=size, stride=stride)


# --- network tables used for artifact generation -------------------------
# (full tables incl. MAC/io accounting are mirrored in rust/src/model/)

ALEXNET_CONV = [
    ConvCfg("conv1", ic=3,   ih=227, iw=227, oc=96,  fh=11, fw=11, stride=4),
    ConvCfg("conv2", ic=96,  ih=27,  iw=27,  oc=256, fh=5,  fw=5,  pad=2, groups=2),
    ConvCfg("conv3", ic=256, ih=13,  iw=13,  oc=384, fh=3,  fw=3,  pad=1),
    ConvCfg("conv4", ic=384, ih=13,  iw=13,  oc=384, fh=3,  fw=3,  pad=1, groups=2),
    ConvCfg("conv5", ic=384, ih=13,  iw=13,  oc=256, fh=3,  fw=3,  pad=1, groups=2),
]

VGG16_CONV = [
    ConvCfg("conv1_1", ic=3,   ih=224, iw=224, oc=64,  fh=3, fw=3, pad=1),
    ConvCfg("conv1_2", ic=64,  ih=224, iw=224, oc=64,  fh=3, fw=3, pad=1),
    ConvCfg("conv2_1", ic=64,  ih=112, iw=112, oc=128, fh=3, fw=3, pad=1),
    ConvCfg("conv2_2", ic=128, ih=112, iw=112, oc=128, fh=3, fw=3, pad=1),
    ConvCfg("conv3_1", ic=128, ih=56,  iw=56,  oc=256, fh=3, fw=3, pad=1),
    ConvCfg("conv3_2", ic=256, ih=56,  iw=56,  oc=256, fh=3, fw=3, pad=1),
    ConvCfg("conv3_3", ic=256, ih=56,  iw=56,  oc=256, fh=3, fw=3, pad=1),
    ConvCfg("conv4_1", ic=256, ih=28,  iw=28,  oc=512, fh=3, fw=3, pad=1),
    ConvCfg("conv4_2", ic=512, ih=28,  iw=28,  oc=512, fh=3, fw=3, pad=1),
    ConvCfg("conv4_3", ic=512, ih=28,  iw=28,  oc=512, fh=3, fw=3, pad=1),
    ConvCfg("conv5_1", ic=512, ih=14,  iw=14,  oc=512, fh=3, fw=3, pad=1),
    ConvCfg("conv5_2", ic=512, ih=14,  iw=14,  oc=512, fh=3, fw=3, pad=1),
    ConvCfg("conv5_3", ic=512, ih=14,  iw=14,  oc=512, fh=3, fw=3, pad=1),
]

# sanity targets from the literature (checked by python/tests/test_model.py)
ALEXNET_CONV_MACS = 665_784_864     # grouped AlexNet conv stack
VGG16_CONV_MACS = 15_346_630_656    # VGG-16 conv stack
