"""AOT path tests: every artifact lowers to parseable HLO text and the
lowered computation, when *executed in python*, matches the oracle.

(The rust side re-checks execution through PJRT; this guards the lowering
itself so `make artifacts` failures are caught at pytest time.)
"""

import numpy as np
import pytest

from compile.aot import (CONV_ARTIFACTS, POOL_ARTIFACTS, lower_conv,
                         lower_pool, to_hlo_text)
from compile.kernels.ref import conv2d_numpy, maxpool2d_ref

RNG = np.random.RandomState(99)


@pytest.mark.parametrize("cfg", CONV_ARTIFACTS, ids=lambda c: c.name)
def test_conv_artifact_lowers_to_hlo(cfg):
    text = to_hlo_text(lower_conv(cfg))
    assert text.startswith("HloModule"), text[:80]
    assert "s16" in text  # int16 tensors present
    # the pallas fori_loop must lower to a single while loop (perf target,
    # DESIGN.md §9) — interpret-mode emits while for the grid as well, so
    # require at least one.
    assert "while" in text


@pytest.mark.parametrize("cfg", [c for c in CONV_ARTIFACTS
                                 if c.ih * c.iw <= 1200],
                         ids=lambda c: c.name)
def test_conv_artifact_executes_correctly(cfg):
    """Compile the lowered module in-process and compare vs numpy oracle."""
    lowered = lower_conv(cfg)
    compiled = lowered.compile()
    x = RNG.randint(-2000, 2000, (cfg.ic, cfg.ih, cfg.iw)).astype(np.int16)
    w = RNG.randint(-300, 300, (cfg.oc, cfg.ic, cfg.fh, cfg.fw)).astype(np.int16)
    b = RNG.randint(-500, 500, (cfg.oc,)).astype(np.int32)
    (got,) = compiled(x, w, b)
    ref = conv2d_numpy(x, w, b, stride=cfg.stride, pad=cfg.pad,
                       frac_shift=cfg.frac_shift, relu=cfg.relu)
    np.testing.assert_array_equal(np.asarray(got), ref)


@pytest.mark.parametrize("spec", POOL_ARTIFACTS, ids=lambda s: s[0])
def test_pool_artifact(spec):
    name, ic, ih, iw, size, stride = spec
    lowered = lower_pool(ic, ih, iw, size, stride)
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule")
    compiled = lowered.compile()
    x = RNG.randint(-32768, 32767, (ic, ih, iw)).astype(np.int16)
    (got,) = compiled(x)
    ref = np.asarray(maxpool2d_ref(x, size=size, stride=stride))
    np.testing.assert_array_equal(np.asarray(got), ref)


def test_artifact_names_unique():
    names = [c.name for c in CONV_ARTIFACTS] + [p[0] for p in POOL_ARTIFACTS]
    assert len(names) == len(set(names))
