"""Pallas kernel vs pure-jnp oracle vs numpy loop oracle — bit-exact.

This is the core L1 correctness signal: the AOT artifacts loaded by the
rust runtime embed the Pallas kernel, and the rust cycle simulator is
checked against those artifacts, so exactness here anchors the whole
golden-model chain.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import (conv2d_ref, conv2d_numpy, maxpool2d_ref,
                                 relu_ref)
from compile.kernels.conv16 import conv2d_pallas, maxpool2d_pallas

RNG = np.random.RandomState(1234)


def rand_case(ic, oc, ih, iw, fh, fw, lo=-4000, hi=4000, wlo=-500, whi=500):
    x = RNG.randint(lo, hi, (ic, ih, iw)).astype(np.int16)
    w = RNG.randint(wlo, whi, (oc, ic, fh, fw)).astype(np.int16)
    b = RNG.randint(-(1 << 12), 1 << 12, (oc,)).astype(np.int32)
    return x, w, b


CASES = [
    # (ic, oc, ih, iw, fh, fw, stride, pad, shift, relu)
    (3, 16, 12, 12, 3, 3, 1, 1, 8, True),
    (3, 16, 12, 12, 3, 3, 1, 1, 8, False),
    (4, 32, 9, 9, 3, 3, 2, 1, 8, True),
    (2, 16, 11, 11, 5, 5, 2, 0, 6, True),
    (1, 16, 8, 8, 1, 1, 1, 0, 0, False),
    (8, 16, 7, 7, 3, 3, 1, 1, 10, True),
    (5, 48, 10, 10, 3, 3, 1, 0, 8, True),
    (3, 16, 23, 23, 11, 11, 4, 0, 8, True),  # AlexNet-L1-like
    (6, 16, 9, 13, 3, 5, 1, 2, 8, False),    # non-square filters/maps
    (2, 16, 6, 6, 2, 2, 2, 0, 4, True),
]


@pytest.mark.parametrize("ic,oc,ih,iw,fh,fw,s,p,shift,relu", CASES)
def test_pallas_vs_refs(ic, oc, ih, iw, fh, fw, s, p, shift, relu):
    x, w, b = rand_case(ic, oc, ih, iw, fh, fw)
    r_jnp = np.asarray(conv2d_ref(x, w, b, stride=s, pad=p,
                                  frac_shift=shift, relu=relu))
    r_np = conv2d_numpy(x, w, b, stride=s, pad=p, frac_shift=shift, relu=relu)
    r_pl = np.asarray(conv2d_pallas(x, w, b, stride=s, pad=p,
                                    frac_shift=shift, relu=relu))
    np.testing.assert_array_equal(r_jnp, r_np)
    np.testing.assert_array_equal(r_jnp, r_pl)


def test_saturation_positive():
    """Accumulator larger than int16 range must clip to 32767."""
    x = np.full((1, 3, 3), 32767, np.int16)
    w = np.full((16, 1, 3, 3), 32767, np.int16)
    b = np.zeros(16, np.int32)
    out = np.asarray(conv2d_pallas(x, w, b, frac_shift=2, relu=False))
    ref = conv2d_numpy(x, w, b, frac_shift=2, relu=False)
    np.testing.assert_array_equal(out, ref)


def test_saturation_negative():
    x = np.full((1, 3, 3), -32768, np.int16)
    w = np.full((16, 1, 3, 3), 32767, np.int16)
    b = np.zeros(16, np.int32)
    out = np.asarray(conv2d_pallas(x, w, b, frac_shift=2, relu=False))
    ref = conv2d_numpy(x, w, b, frac_shift=2, relu=False)
    np.testing.assert_array_equal(out, ref)
    assert (out == -32768).all()


def test_wrapping_accumulator():
    """Many large products wrap the int32 accumulator — both sides must
    wrap identically (VRl is a 32-bit register; hardware wraps)."""
    ic, n = 64, 5
    x = np.full((ic, n, n), 30000, np.int16)
    w = np.full((16, ic, n, n), 30000, np.int16)
    b = np.zeros(16, np.int32)
    out = np.asarray(conv2d_pallas(x, w, b, frac_shift=0, relu=False))
    ref = conv2d_numpy(x, w, b, frac_shift=0, relu=False)
    np.testing.assert_array_equal(out, ref)


def test_rounding_half_up():
    """acc = 2 with shift 1 -> 1; acc = 1 with shift 1 -> 1 (half rounds up);
    acc = -1 with shift 1 -> 0 (arithmetic shift of -1+1=0)."""
    x = np.ones((1, 1, 1), np.int16)
    w = np.array([[[[1]]], [[[2]]], [[[-1]]], [[[3]]]] * 4, np.int16)  # 16 oc
    b = np.zeros(16, np.int32)
    out = np.asarray(conv2d_pallas(x, w, b, stride=1, pad=0, frac_shift=1,
                                   relu=False))
    np.testing.assert_array_equal(out[:4, 0, 0], [1, 1, 0, 2])


def test_bias_scaling():
    """Bias is applied at accumulator scale: out = conv + bias after shift."""
    x = np.zeros((1, 4, 4), np.int16)
    w = np.zeros((16, 1, 3, 3), np.int16)
    b = np.arange(16, dtype=np.int32) - 8
    out = np.asarray(conv2d_pallas(x, w, b, pad=1, frac_shift=8, relu=False))
    for o in range(16):
        assert (out[o] == b[o]).all()


def test_relu_fused():
    x, w, b = rand_case(3, 16, 8, 8, 3, 3)
    no = np.asarray(conv2d_ref(x, w, b, pad=1, relu=False))
    yes = np.asarray(conv2d_ref(x, w, b, pad=1, relu=True))
    np.testing.assert_array_equal(yes, np.maximum(no, 0))


@settings(max_examples=40, deadline=None)
@given(
    ic=st.integers(1, 6),
    octile=st.integers(1, 2),
    ih=st.integers(5, 14),
    iw=st.integers(5, 14),
    fh=st.integers(1, 5),
    fw=st.integers(1, 5),
    stride=st.integers(1, 3),
    pad=st.integers(0, 2),
    shift=st.integers(0, 12),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_sweep(ic, octile, ih, iw, fh, fw, stride, pad, shift,
                          relu, seed):
    """Property: pallas == numpy-loop oracle on arbitrary valid shapes."""
    if ih + 2 * pad < fh or iw + 2 * pad < fw:
        return  # invalid geometry
    oc = 16 * octile
    r = np.random.RandomState(seed)
    x = r.randint(-32768, 32768, (ic, ih, iw)).astype(np.int16)
    w = r.randint(-2048, 2048, (oc, ic, fh, fw)).astype(np.int16)
    b = r.randint(-(1 << 16), 1 << 16, (oc,)).astype(np.int32)
    got = np.asarray(conv2d_pallas(x, w, b, stride=stride, pad=pad,
                                   frac_shift=shift, relu=relu))
    ref = conv2d_numpy(x, w, b, stride=stride, pad=pad, frac_shift=shift,
                       relu=relu)
    np.testing.assert_array_equal(got, ref)


@settings(max_examples=25, deadline=None)
@given(
    ic=st.integers(1, 8),
    ih=st.integers(4, 20),
    iw=st.integers(4, 20),
    size=st.integers(2, 3),
    stride=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_maxpool(ic, ih, iw, size, stride, seed):
    if ih < size or iw < size:
        return
    r = np.random.RandomState(seed)
    x = r.randint(-32768, 32768, (ic, ih, iw)).astype(np.int16)
    got = np.asarray(maxpool2d_pallas(x, size=size, stride=stride))
    ref = np.asarray(maxpool2d_ref(x, size=size, stride=stride))
    np.testing.assert_array_equal(got, ref)


def test_relu_ref_identity():
    x = np.array([-5, 0, 7, -32768, 32767], np.int16)
    np.testing.assert_array_equal(np.asarray(relu_ref(x)), [0, 0, 7, 0, 32767])
