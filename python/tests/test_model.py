"""L2 model tests: layer composition, grouped conv, OC padding, MAC tables."""

import numpy as np
import pytest

from compile.model import (ALEXNET_CONV, VGG16_CONV, ALEXNET_CONV_MACS,
                           VGG16_CONV_MACS, ConvCfg, conv_layer,
                           maxpool_layer, _pad_oc)
from compile.kernels.ref import conv2d_ref

RNG = np.random.RandomState(7)


def test_alexnet_macs_match_literature():
    assert sum(c.macs for c in ALEXNET_CONV) == ALEXNET_CONV_MACS


def test_vgg16_macs_match_literature():
    assert sum(c.macs for c in VGG16_CONV) == VGG16_CONV_MACS


def test_alexnet_shapes_chain():
    """conv1 -> pool -> conv2 -> pool -> conv3/4/5 shapes are consistent."""
    c1, c2, c3, c4, c5 = ALEXNET_CONV
    assert (c1.oh, c1.ow) == (55, 55)
    # 3x3/s2 pool: 55 -> 27
    assert (55 - 3) // 2 + 1 == c2.ih
    assert (c2.oh, c2.ow) == (27, 27)
    assert (27 - 3) // 2 + 1 == c3.ih
    assert c3.ic == c2.oc and c4.ic == c3.oc and c5.ic == c4.oc


def test_vgg_shapes_chain():
    for prev, nxt in zip(VGG16_CONV, VGG16_CONV[1:]):
        assert nxt.ic == prev.oc
        assert nxt.ih in (prev.oh, prev.oh // 2)  # same block or after pool


@pytest.mark.parametrize("oc", [8, 16, 17, 40, 96])
def test_pad_oc(oc):
    p = _pad_oc(oc)
    assert p % 16 == 0 and p >= oc and p - oc < 16


def test_conv_layer_oc_padding_matches_ref():
    """OC not a multiple of 16: pallas path pads, result equals ref."""
    cfg = ConvCfg("t", ic=3, ih=8, iw=8, oc=24, fh=3, fw=3, pad=1)
    x = RNG.randint(-1000, 1000, (3, 8, 8)).astype(np.int16)
    w = RNG.randint(-200, 200, (24, 3, 3, 3)).astype(np.int16)
    b = RNG.randint(-100, 100, (24,)).astype(np.int32)
    got = np.asarray(conv_layer(x, w, b, cfg, use_pallas=True))
    ref = np.asarray(conv2d_ref(x, w, b, stride=1, pad=1, frac_shift=8,
                                relu=True))
    np.testing.assert_array_equal(got, ref)
    assert got.shape == (24, 8, 8)


def test_grouped_conv_matches_blockwise_ref():
    """groups=2 (AlexNet conv2/4/5 style): each half independent."""
    cfg = ConvCfg("t", ic=8, ih=6, iw=6, oc=32, fh=3, fw=3, pad=1, groups=2)
    x = RNG.randint(-1000, 1000, (8, 6, 6)).astype(np.int16)
    w = RNG.randint(-200, 200, (32, 4, 3, 3)).astype(np.int16)
    b = RNG.randint(-100, 100, (32,)).astype(np.int32)
    got = np.asarray(conv_layer(x, w, b, cfg, use_pallas=True))
    for g in range(2):
        ref = np.asarray(conv2d_ref(x[g * 4:(g + 1) * 4],
                                    w[g * 16:(g + 1) * 16],
                                    b[g * 16:(g + 1) * 16],
                                    stride=1, pad=1, frac_shift=8, relu=True))
        np.testing.assert_array_equal(got[g * 16:(g + 1) * 16], ref)


def test_grouped_macs_half_of_dense():
    dense = ConvCfg("d", ic=8, ih=6, iw=6, oc=32, fh=3, fw=3, pad=1)
    grouped = ConvCfg("g", ic=8, ih=6, iw=6, oc=32, fh=3, fw=3, pad=1, groups=2)
    assert grouped.macs * 2 == dense.macs


def test_maxpool_layer_pallas_vs_ref():
    from compile.kernels.ref import maxpool2d_ref
    x = RNG.randint(-32768, 32767, (6, 13, 13)).astype(np.int16)
    got = np.asarray(maxpool_layer(x, size=3, stride=2, use_pallas=True))
    ref = np.asarray(maxpool2d_ref(x, size=3, stride=2))
    np.testing.assert_array_equal(got, ref)


def test_alexnet_first_layer_small_slice():
    """Run AlexNet conv1 geometry on a cropped input (full run is covered
    by the rust e2e example via the AOT artifact)."""
    cfg = ALEXNET_CONV[0]
    crop = ConvCfg("c1s", ic=3, ih=39, iw=39, oc=96, fh=11, fw=11, stride=4)
    x = RNG.randint(-4000, 4000, (3, 39, 39)).astype(np.int16)
    w = RNG.randint(-300, 300, (96, 3, 11, 11)).astype(np.int16)
    b = RNG.randint(-100, 100, (96,)).astype(np.int32)
    got = np.asarray(conv_layer(x, w, b, crop, use_pallas=True))
    ref = np.asarray(conv2d_ref(x, w, b, stride=4, pad=0, frac_shift=8,
                                relu=True))
    np.testing.assert_array_equal(got, ref)
    assert got.shape == (96, 8, 8)
    assert cfg.fh == crop.fh and cfg.stride == crop.stride
