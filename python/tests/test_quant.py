"""Unit tests for the shared Q-format quantization semantics."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.quant import (requantize, gate_precision, mac_init,
                                   INT16_MIN, INT16_MAX)


def rq(v, shift, relu=False):
    return int(np.asarray(requantize(jnp.int32(v), shift, relu)))


def test_requantize_shift0_saturates():
    assert rq(40000, 0) == INT16_MAX
    assert rq(-40000, 0) == INT16_MIN
    assert rq(123, 0) == 123


def test_requantize_round_half_up():
    # 3/2 -> 2 (half up), 1/2 -> 1, -1/2 -> 0, -3/2 -> -1
    assert rq(3, 1) == 2
    assert rq(1, 1) == 1
    assert rq(-1, 1) == 0
    assert rq(-3, 1) == -1


def test_requantize_relu():
    assert rq(-100, 0, relu=True) == 0
    assert rq(100, 0, relu=True) == 100


def test_requantize_wrapping_round_addend():
    """Adding the rounding constant near INT32_MAX wraps (hardware adder)."""
    v = np.int32(2**31 - 1)
    out = rq(v, 8)
    # (INT32_MAX + 128) wraps negative -> arithmetic shift -> saturate low
    assert out == INT16_MIN


@settings(max_examples=200, deadline=None)
@given(v=st.integers(-(2**31), 2**31 - 1), shift=st.integers(0, 15))
def test_requantize_in_range(v, shift):
    out = rq(np.int32(v), shift)
    assert INT16_MIN <= out <= INT16_MAX


@settings(max_examples=100, deadline=None)
@given(v=st.integers(-(2**20), 2**20), shift=st.integers(1, 10))
def test_requantize_matches_python_model(v, shift):
    """Cross-check against an independent python big-int model."""
    acc = ((v + (1 << (shift - 1)) + 2**31) % 2**32) - 2**31
    expect = max(INT16_MIN, min(INT16_MAX, acc >> shift))
    assert rq(np.int32(v), shift) == expect


def test_gate_precision_masks_lsbs():
    x = jnp.int16(0x1234)
    assert int(gate_precision(x, 8)) == 0x1200
    assert int(gate_precision(x, 16)) == 0x1234
    assert int(gate_precision(x, 4)) == 0x1000


def test_gate_precision_sign_preserved():
    x = jnp.int16(-1)  # 0xFFFF
    assert int(gate_precision(x, 8)) == -256  # 0xFF00


@settings(max_examples=100, deadline=None)
@given(v=st.integers(-32768, 32767), bits=st.sampled_from([1, 2, 4, 8, 12, 16]))
def test_gate_precision_idempotent(v, bits):
    x = jnp.int16(v)
    g1 = gate_precision(x, bits)
    g2 = gate_precision(g1, bits)
    assert int(g1) == int(g2)


def test_mac_init_scales_bias():
    assert int(np.asarray(mac_init(jnp.int32(3), 8))) == 3 << 8
    assert int(np.asarray(mac_init(jnp.int32(-3), 0))) == -3
