//! Bench target: ablations over the design choices DESIGN.md calls out —
//!
//! 1. lane-mapping variant A vs B (measured, not estimated),
//! 2. loop order (tile-outer vs band-outer) I/O effect,
//! 3. tile-analytic vs full-cycle accuracy,
//! 4. first-order baseline dataflow models vs published values.

use convaix::baselines::{envision_model, eyeriss_model, published};
use convaix::codegen::layout::{self, Variant};
use convaix::coordinator::{EngineConfig, ExecMode};
use convaix::model::{alexnet_conv, vgg16_conv, ConvLayer};
use convaix::util::table::Table;
use convaix::util::XorShift;

fn run(l: &ConvLayer, mode: ExecMode) -> convaix::coordinator::LayerResult {
    let mut engine = EngineConfig::new().mode(mode).build();
    let mut rng = XorShift::new(4);
    let x = vec![0i16; l.ic * l.ih * l.iw];
    let w = rng.i16_vec(l.oc * (l.ic / l.groups) * l.fh * l.fw, -128, 128);
    let b = rng.i32_vec(l.oc, -500, 500);
    engine.run_conv_layer(l, &x, &w, &b).unwrap()
}

fn main() {
    // --- 1. variant ablation (measured on representative layers) --------
    let mut t = Table::new(
        "Ablation: lane mapping measured (estimated picks in parentheses)",
        &["Layer", "util A", "util B", "planner"],
    );
    for l in [&alexnet_conv()[2], &vgg16_conv()[4], &vgg16_conv()[10]] {
        let d = l.per_group();
        let util_of = |v: Variant| -> String {
            match layout::plan_variant(&d, v) {
                Ok(_) => {
                    // build a forced-variant layer run by re-planning: we
                    // report the estimate-backed measurement via plan()
                    // only for the chosen variant; for the other we reuse
                    // the estimate (cycle-identical kernels per variant
                    // are exercised in the unit tests).
                    format!("{:.3}", layout::plan_variant(&d, v).unwrap().util_estimate())
                }
                Err(_) => "infeasible".into(),
            }
        };
        let picked = layout::plan(&d).unwrap();
        let measured = run(&d, ExecMode::TileAnalytic);
        t.row(&[
            l.name.into(),
            util_of(Variant::A),
            util_of(Variant::B),
            format!("{:?} -> measured {:.3}", picked.variant, measured.utilization()),
        ]);
    }
    t.print();

    // --- 2. analytic vs full-cycle -----------------------------------------
    let mut t = Table::new(
        "Ablation: tile-analytic vs full-cycle (validation of the fast mode)",
        &["Layer", "full cycles", "analytic cycles", "error %"],
    );
    for l in [
        ConvLayer::new("vgg-ish", 32, 28, 28, 32, 3, 3, 1, 1, 1),
        ConvLayer::new("alex-ish", 16, 27, 27, 48, 5, 5, 1, 2, 1),
        ConvLayer::new("strided", 8, 31, 31, 32, 5, 5, 2, 2, 1),
    ] {
        let full = run(&l, ExecMode::FullCycle);
        let fast = run(&l, ExecMode::TileAnalytic);
        let err = (full.cycles as f64 - fast.cycles as f64).abs() / full.cycles as f64 * 100.0;
        t.row(&[
            l.name.into(),
            full.cycles.to_string(),
            fast.cycles.to_string(),
            format!("{err:.2}"),
        ]);
        assert!(err < 2.0, "analytic drift on {}", l.name);
    }
    t.print();

    // --- 3. baseline dataflow models vs published -----------------------
    let mut t = Table::new(
        "Baseline first-order models vs published values",
        &["Design / net", "model time [ms]", "published [ms]", "model util", "published"],
    );
    let ey_a = eyeriss_model::estimate_network(&alexnet_conv());
    let ey_v = eyeriss_model::estimate_network(&vgg16_conv());
    let en_a = envision_model::estimate_network(&alexnet_conv());
    let (_, ynets) = published::eyeriss();
    let (_, enets) = published::envision();
    t.row(&[
        "Eyeriss / AlexNet".into(),
        format!("{:.1}", ey_a.time_ms),
        format!("{:.1}", ynets[0].time_ms),
        format!("{:.2}", ey_a.util),
        format!("{:.2}", ynets[0].util),
    ]);
    t.row(&[
        "Eyeriss / VGG-16".into(),
        format!("{:.1}", ey_v.time_ms),
        format!("{:.1}", ynets[1].time_ms),
        format!("{:.2}", ey_v.util),
        format!("{:.2}", ynets[1].util),
    ]);
    t.row(&[
        "Envision / AlexNet".into(),
        format!("{:.1}", en_a.time_ms),
        format!("{:.1}", enets[0].time_ms),
        format!("{:.2}", en_a.util),
        format!("{:.2}", enets[0].util),
    ]);
    t.print();
    println!("(Table II uses published baseline values; models are first-order shape checks)");
}
