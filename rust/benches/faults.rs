//! Bench target: fault-injection overhead sweep — the AlexNet conv
//! stack fanned out as 4 frames over 2 cores, with a seeded transient
//! campaign at increasing site rates. Reports what robustness *costs*:
//! the always-on checksum pricing at rate 0, the retry/recovery cycle
//! overhead as the rate climbs, and the bit-identity guarantee (every
//! detected campaign's outputs equal the fault-free run's).
//!
//! Emits `BENCH_faults.json` (per-rate retries, recovery cycles,
//! makespans, overhead fractions) so the robustness-cost trajectory is
//! tracked machine-readably across PRs. `MULTICORE_NO_ASSERT=1` skips
//! the hard targets without skipping the report.
//!
//!     cargo bench --bench faults

use std::collections::BTreeMap;

use convaix::coordinator::{EngineConfig, ExecMode, FaultPlan, NetLayer};
use convaix::model::{alexnet_conv, conv_stack};
use convaix::util::json::Json;
use convaix::util::table::Table;
use convaix::util::XorShift;

/// Campaign seed: at 0.05 it fires once over this bench's 4-frame ×
/// 5-layer × 2-core site grid, at 0.25 five times, at 0.50 eleven —
/// the site draw is pure in `(seed, frame, layer, core)`, so the ramp
/// is a fixed property of the seed, not sampling luck.
const SEED: u64 = 0xFA0175;
const BATCH: usize = 4;
const CORES: usize = 2;

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn main() {
    let no_assert = std::env::var_os("MULTICORE_NO_ASSERT").is_some();
    let layers: Vec<NetLayer> = conv_stack(alexnet_conv());
    let in_elems = 3 * 227 * 227;
    let mut rng = XorShift::new(0xBA7C4);
    let inputs: Vec<Vec<i16>> =
        (0..BATCH).map(|_| rng.i16_vec(in_elems, -2000, 2000)).collect();
    let cfg = EngineConfig::new().mode(ExecMode::TileAnalytic).cores(CORES).batch(BATCH);

    let mut clean_eng = cfg.clone().build();
    let clean = clean_eng.run_batched("alexnet", &layers, &inputs).expect("clean fan-out");
    let clean_makespan = clean.makespan_cycles();

    let mut t = Table::new(
        &format!(
            "AlexNet conv stack, batch {BATCH} over {CORES} cores: detected-fault \
             campaign (seed {SEED:#x}) vs injection rate"
        ),
        &["Rate", "Retries", "Recovery cyc", "Makespan cyc", "Overhead", "Outputs"],
    );
    let mut rows = Vec::new();
    // (rate %, retries, recovery cycles, bit-identical) per sweep point
    let mut sweep: Vec<(u32, u64, u64, bool)> = Vec::new();
    for rate in [0.0, 0.01, 0.05, 0.10, 0.25, 0.50] {
        let mut eng = cfg.clone().faults(FaultPlan::new(SEED, rate)).build();
        let br = eng.run_batched("alexnet", &layers, &inputs).expect("injected fan-out");
        let identical = clean
            .frames
            .iter()
            .zip(&br.frames)
            .all(|(a, b)| a.layers.iter().zip(&b.layers).all(|(x, y)| x.out == y.out));
        let overhead =
            br.makespan_cycles() as f64 / clean_makespan.max(1) as f64 - 1.0;
        t.row(&[
            format!("{rate:.2}"),
            br.faults.retries.to_string(),
            br.faults.recovery_cycles.to_string(),
            br.makespan_cycles().to_string(),
            format!("{:.2} %", overhead * 100.0),
            if identical { "bit-identical".to_string() } else { "DIVERGED".to_string() },
        ]);
        rows.push(obj(vec![
            ("rate", num(rate)),
            ("retries", num(br.faults.retries as f64)),
            ("recovery_cycles", num(br.faults.recovery_cycles as f64)),
            ("makespan_cycles", num(br.makespan_cycles() as f64)),
            ("overhead_frac", num(overhead)),
            ("bit_identical", Json::Num(if identical { 1.0 } else { 0.0 })),
        ]));
        sweep.push((
            (rate * 100.0) as u32,
            br.faults.retries,
            br.faults.recovery_cycles,
            identical,
        ));
    }
    t.print();

    let mut dump: BTreeMap<String, Json> = BTreeMap::new();
    dump.insert("seed".into(), num(SEED as f64));
    dump.insert("batch".into(), num(BATCH as f64));
    dump.insert("cores".into(), num(CORES as f64));
    dump.insert("clean_makespan_cycles".into(), num(clean_makespan as f64));
    dump.insert("rate_sweep".into(), Json::Arr(rows));

    // Machine-readable trajectory dump, written BEFORE the hard
    // asserts below: a failing run is exactly the one whose numbers
    // must not be lost.
    let json = Json::Obj(dump).to_string();
    std::fs::write("BENCH_faults.json", &json).expect("write BENCH_faults.json");
    println!("wrote BENCH_faults.json ({} bytes)", json.len());

    if !no_assert {
        let mut prev = (0u64, 0u64);
        for &(pct, retries, recovery, identical) in &sweep {
            assert!(
                identical,
                "rate {pct}%: detected campaign outputs diverged from the fault-free run \
                 (set MULTICORE_NO_ASSERT=1 to report without asserting)"
            );
            if pct == 0 {
                assert_eq!(
                    retries, 0,
                    "rate 0%: no sites may fire \
                     (set MULTICORE_NO_ASSERT=1 to report without asserting)"
                );
            }
            if pct >= 5 {
                assert!(
                    retries > 0,
                    "rate {pct}%: seed {SEED:#x} must fire at this rate \
                     (set MULTICORE_NO_ASSERT=1 to report without asserting)"
                );
            }
            // the rate threshold gates one fixed rng draw per site, so
            // a higher rate fires a strict superset of sites
            assert!(
                retries >= prev.0 && recovery >= prev.1,
                "rate {pct}%: overhead not monotone in rate \
                 (set MULTICORE_NO_ASSERT=1 to report without asserting)"
            );
            prev = (retries, recovery);
        }
    }
    println!("\nfaults bench done (asserts skipped = {no_assert})");
}
