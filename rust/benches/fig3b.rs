//! Bench target: regenerate Fig. 3b (logic-area breakdown).

use convaix::cli::report;

fn main() {
    print!("{}", report::fig3b());
}
