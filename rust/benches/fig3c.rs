//! Bench target: regenerate Fig. 3c (power distribution, AlexNet conv3,
//! 8-bit gated precision) from simulated switching activity.

use convaix::cli::report;
use convaix::util::bench::Bench;

fn main() {
    print!("{}", report::fig3c().expect("fig3c"));
    let b = Bench::quick();
    b.run("fig3c (conv3 analytic + power model)", || {
        report::fig3c().unwrap().len()
    });
}
