//! Bench target: multi-core scaling sweep — VGG-16 conv stack in
//! tile-analytic mode, layers sharded across 1 / 2 / 4 ConvAix cores
//! (cycle-level makespan) with the simulation itself on host threads
//! (wall-clock). Also sweeps the batched frame fan-out mode.
//!
//!     cargo bench --bench multicore

use std::time::Instant;

use convaix::cli::report;
use convaix::coordinator::executor::{ExecMode, ExecOptions, NetLayer};
use convaix::coordinator::scheduler::{run_batched, CorePool};
use convaix::model::vgg16_conv;
use convaix::util::table::Table;

fn main() {
    let host_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("host threads available: {host_threads}\n");

    // --- layer-sharded sweep -------------------------------------------------
    let mut t = Table::new(
        "VGG-16 conv stack, tile-analytic, layer-sharded across N cores",
        &["Cores", "Model cycles", "Cycle speedup", "Wall [s]", "Wall speedup"],
    );
    let mut wall1 = 0.0f64;
    let mut cycles1 = 0u64;
    let mut wall_speedup_at_4 = 0.0f64;
    for cores in [1usize, 2, 4] {
        let opts = ExecOptions {
            mode: ExecMode::TileAnalytic,
            gate_bits: 8,
            cores,
            batch: 1,
        };
        let t0 = Instant::now();
        let net = report::bench_network_mc("VGG-16", &vgg16_conv(), opts).expect("vgg16 mc");
        let wall = t0.elapsed().as_secs_f64();
        if cores == 1 {
            wall1 = wall;
            cycles1 = net.cycles();
        }
        let wall_speedup = wall1 / wall.max(1e-9);
        if cores == 4 {
            wall_speedup_at_4 = wall_speedup;
        }
        t.row(&[
            cores.to_string(),
            net.cycles().to_string(),
            format!("{:.2}x", cycles1 as f64 / net.cycles().max(1) as f64),
            format!("{wall:.2}"),
            format!("{wall_speedup:.2}x"),
        ]);
    }
    t.print();

    // --- batched frame fan-out sweep ----------------------------------------
    let conv: Vec<NetLayer> = vgg16_conv().into_iter().map(NetLayer::Conv).collect();
    let frame = vec![0i16; 3 * 224 * 224];
    let inputs: Vec<Vec<i16>> = (0..4).map(|_| frame.clone()).collect();
    let mut t = Table::new(
        "VGG-16, batch 4, frame fan-out over N cores",
        &["Cores", "Makespan cycles", "Throughput [f/s]", "Cycle speedup"],
    );
    for cores in [1usize, 2, 4] {
        let opts = ExecOptions {
            mode: ExecMode::TileAnalytic,
            gate_bits: 8,
            cores,
            batch: inputs.len(),
        };
        let mut pool = CorePool::new(cores, 1 << 24);
        let br = run_batched(&mut pool, "VGG-16", &conv, &inputs, opts, 0xC0FFEE).expect("batch");
        t.row(&[
            cores.to_string(),
            br.makespan_cycles().to_string(),
            format!("{:.1}", br.throughput_fps()),
            format!("{:.2}x", br.speedup()),
        ]);
    }
    t.print();

    // Wall-clock scaling depends on real host parallelism; skip the hard
    // target on undersized hosts, and allow MULTICORE_NO_ASSERT=1 as an
    // escape hatch for loaded / SMT-limited machines.
    let no_assert = std::env::var_os("MULTICORE_NO_ASSERT").is_some();
    if host_threads >= 4 && !no_assert {
        println!("wall-clock speedup at 4 cores: {wall_speedup_at_4:.2}x (target >= 1.7x)");
        assert!(
            wall_speedup_at_4 >= 1.7,
            "4-core wall-clock speedup {wall_speedup_at_4:.2}x below the 1.7x target \
             (set MULTICORE_NO_ASSERT=1 to report without asserting)"
        );
    } else {
        println!(
            "wall-clock speedup at 4 cores: {wall_speedup_at_4:.2}x \
             (1.7x target not enforced: host threads = {host_threads}, \
             MULTICORE_NO_ASSERT = {no_assert})"
        );
    }
}
