//! Bench target: multi-core scaling sweep — VGG-16 conv stack in
//! tile-analytic mode, layers sharded across 1 / 2 / 4 ConvAix cores
//! (cycle-level makespan) with the simulation itself on host threads
//! (wall-clock). Also duels the shard policies on the early VGG layers,
//! sweeps the batched frame fan-out mode under both bus models, and
//! duels layer-pipelined streaming against frame fan-out on a 5-frame
//! stream (the batch-misaligned serving case) — on the conv stacks and
//! on the full nets with their DMA-bound FC tails.
//!
//! Emits `BENCH_multicore.json` (steady f/s, makespans, per-stage
//! utilization per config) so the performance trajectory is tracked
//! machine-readably across PRs. `MULTICORE_NO_ASSERT=1` skips the hard
//! targets without skipping the report.
//!
//!     cargo bench --bench multicore

use std::collections::BTreeMap;
use std::time::Instant;

use convaix::cli::report;
use convaix::coordinator::{
    BusModel, EngineConfig, ExecMode, NetLayer, PoolMode, ShardPolicy, StageCores,
};
use convaix::model::{alexnet_conv, alexnet_full, conv_stack, vgg16_conv, vgg16_full};
use convaix::util::json::Json;
use convaix::util::table::Table;
use convaix::util::XorShift;

fn cfg_base() -> EngineConfig {
    EngineConfig::new().mode(ExecMode::TileAnalytic).gate_bits(8)
}

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn main() {
    let host_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let no_assert = std::env::var_os("MULTICORE_NO_ASSERT").is_some();
    println!("host threads available: {host_threads}\n");
    let mut dump: BTreeMap<String, Json> = BTreeMap::new();
    dump.insert("host_threads".into(), num(host_threads as f64));

    // --- layer-sharded sweep -------------------------------------------------
    let mut t = Table::new(
        "VGG-16 conv stack, tile-analytic, layer-sharded across N cores (oc-tile)",
        &["Cores", "Model cycles", "Cycle speedup", "Wall [s]", "Wall speedup"],
    );
    let vgg_conv_stack: Vec<NetLayer> = conv_stack(vgg16_conv());
    let mut wall1 = 0.0f64;
    let mut cycles1 = 0u64;
    let mut wall_speedup_at_4 = 0.0f64;
    let mut sharded_rows = Vec::new();
    for cores in [1usize, 2, 4] {
        let cfg = cfg_base().cores(cores);
        let t0 = Instant::now();
        let net = report::bench_network("VGG-16", &vgg_conv_stack, &cfg).expect("vgg16 mc");
        let wall = t0.elapsed().as_secs_f64();
        if cores == 1 {
            wall1 = wall;
            cycles1 = net.cycles();
        }
        let wall_speedup = wall1 / wall.max(1e-9);
        if cores == 4 {
            wall_speedup_at_4 = wall_speedup;
        }
        let cycle_speedup = cycles1 as f64 / net.cycles().max(1) as f64;
        t.row(&[
            cores.to_string(),
            net.cycles().to_string(),
            format!("{cycle_speedup:.2}x"),
            format!("{wall:.2}"),
            format!("{wall_speedup:.2}x"),
        ]);
        sharded_rows.push(obj(vec![
            ("cores", num(cores as f64)),
            ("model_cycles", num(net.cycles() as f64)),
            ("cycle_speedup", num(cycle_speedup)),
            ("wall_s", num(wall)),
            ("wall_speedup", num(wall_speedup)),
        ]));
    }
    t.print();
    dump.insert("sharded_vgg_conv".into(), Json::Arr(sharded_rows));

    // --- shard-policy duel on the early VGG layers ---------------------------
    // Early layers have few output channels and huge inputs: oc-tile
    // replicates the full IFMap per core and goes DMA-bound, row bands
    // divide it. Run at full 16-bit I/O (no gating) — the DMA-bound
    // regime the second shard axis exists for. The acceptance target:
    // row-band beats oc-tile makespan on conv1_1 at 4 cores.
    let mut t = Table::new(
        "Early VGG-16 layers at 4 cores, 16b I/O: shard-policy makespan duel",
        &["Layer", "oc-tile cyc", "row-band cyc", "auto cyc", "rb gain"],
    );
    let mut conv11_oc = 0u64;
    let mut conv11_rb = 0u64;
    let mut duel_rows = Vec::new();
    for l in &vgg16_conv()[..2] {
        let mut rng = XorShift::new(0xD0E1);
        let x = vec![0i16; l.ic * l.ih * l.iw];
        let w = rng.i16_vec(l.oc * (l.ic / l.groups) * l.fh * l.fw, -128, 128);
        let b = rng.i32_vec(l.oc, -1000, 1000);
        let run = |policy: ShardPolicy| {
            let mut engine = cfg_base().gate_bits(16).cores(4).shard(policy).build();
            engine.run_conv_layer(l, &x, &w, &b).expect("sharded layer").cycles
        };
        let (oc, rb, auto) =
            (run(ShardPolicy::OcTile), run(ShardPolicy::RowBand), run(ShardPolicy::Auto));
        if l.name == "conv1_1" {
            conv11_oc = oc;
            conv11_rb = rb;
        }
        t.row(&[
            l.name.into(),
            oc.to_string(),
            rb.to_string(),
            auto.to_string(),
            format!("{:.2}x", oc as f64 / rb.max(1) as f64),
        ]);
        duel_rows.push(obj(vec![
            ("layer", Json::Str(l.name.into())),
            ("oc_tile_cycles", num(oc as f64)),
            ("row_band_cycles", num(rb as f64)),
            ("auto_cycles", num(auto as f64)),
        ]));
    }
    t.print();
    dump.insert("policy_duel_4c".into(), Json::Arr(duel_rows));
    println!(
        "conv1_1 @ 4 cores: row-band {conv11_rb} vs oc-tile {conv11_oc} cycles \
         ({:.2}x)\n",
        conv11_oc as f64 / conv11_rb.max(1) as f64
    );

    // --- batched frame fan-out sweep, shared vs partitioned bus --------------
    let frame = vec![0i16; 3 * 224 * 224];
    let inputs: Vec<Vec<i16>> = (0..4).map(|_| frame.clone()).collect();
    let mut t = Table::new(
        "VGG-16, batch 4, frame fan-out over N cores",
        &[
            "Cores",
            "Part. makespan",
            "Shared makespan",
            "Part. speedup",
            "Shared speedup",
            "Shared f/s",
        ],
    );
    let mut batched_rows = Vec::new();
    for cores in [1usize, 2, 4] {
        let run = |bus: BusModel| {
            let mut engine = cfg_base().cores(cores).batch(inputs.len()).bus(bus).build();
            engine.run_batched("VGG-16", &vgg_conv_stack, &inputs).expect("batch")
        };
        let part = run(BusModel::Partitioned);
        let shared = run(BusModel::Shared);
        assert!(
            shared.makespan_cycles() >= part.makespan_cycles(),
            "shared bus cannot beat partitioned"
        );
        t.row(&[
            cores.to_string(),
            part.makespan_cycles().to_string(),
            shared.makespan_cycles().to_string(),
            format!("{:.2}x", part.speedup()),
            format!("{:.2}x", shared.speedup()),
            format!("{:.1}", shared.throughput_fps()),
        ]);
        batched_rows.push(obj(vec![
            ("cores", num(cores as f64)),
            ("partitioned_makespan", num(part.makespan_cycles() as f64)),
            ("shared_makespan", num(shared.makespan_cycles() as f64)),
            ("partitioned_speedup", num(part.speedup())),
            ("shared_speedup", num(shared.speedup())),
            ("shared_fps", num(shared.throughput_fps())),
            (
                "shared_core_util",
                Json::Arr(shared.core_utilization().into_iter().map(num).collect()),
            ),
        ]));
    }
    t.print();
    dump.insert("batched_vgg_conv".into(), Json::Arr(batched_rows));

    // --- pipeline vs frame fan-out duel ---------------------------------
    // Streaming serving: 5 frames (deliberately NOT a multiple of the
    // core count — the steady-state streaming case) on 4 cores, shared
    // bus, 8-bit gating. Frame fan-out quantizes the stream into
    // core-count waves (ceil(5/4) = 2 serial frames on core 0), while
    // the pipeline keeps emitting one frame per bottleneck-stage
    // interval once full. Acceptance target: pipelined steady-state
    // throughput >= the fan-out batch throughput on the VGG-16 conv
    // stack at 4 cores. The full nets (…-full) ride along so the
    // trajectory of the DMA-bound FC tails is tracked too.
    const STREAM: usize = 5;
    let mut t = Table::new(
        "Streaming duel: 5 frames on 4 cores, shared bus — fan-out vs pipeline",
        &["Net", "Fan-out f/s", "Pipe steady f/s", "Pipe stream f/s", "Fill [ms]", "Drain [ms]"],
    );
    let mut vgg_fanout_fps = 0.0f64;
    let mut vgg_steady_fps = 0.0f64;
    let mut stream_rows = Vec::new();
    let nets: [(&str, Vec<NetLayer>); 4] = [
        ("AlexNet", conv_stack(alexnet_conv())),
        ("VGG-16", vgg_conv_stack.clone()),
        ("AlexNet-full", alexnet_full()),
        ("VGG-16-full", vgg16_full()),
    ];
    for (name, layers) in nets {
        let frame = vec![0i16; layers[0].op().in_elems()];
        let inputs: Vec<Vec<i16>> = (0..STREAM).map(|_| frame.clone()).collect();

        let mut fan = cfg_base().cores(4).batch(STREAM).bus(BusModel::Shared).build();
        let fo = fan.run_batched(name, &layers, &inputs).expect("fan-out");

        let mut pipe = cfg_base()
            .cores(4)
            .batch(STREAM)
            .pool_mode(PoolMode::Pipelined)
            .bus(BusModel::Shared)
            .build();
        let pr = pipe.run_streaming(name, &layers, &inputs).expect("pipeline");

        // the pipeline must not change what is computed
        assert_eq!(
            pr.frames.iter().map(|f| f.macs()).sum::<u64>(),
            fo.frames.iter().map(|f| f.macs()).sum::<u64>(),
            "{name}: pipelining changed the modeled work"
        );
        if name == "VGG-16" {
            vgg_fanout_fps = fo.throughput_fps();
            vgg_steady_fps = pr.steady_state_fps();
        }
        t.row(&[
            name.into(),
            format!("{:.1}", fo.throughput_fps()),
            format!("{:.1}", pr.steady_state_fps()),
            format!("{:.1}", pr.throughput_fps()),
            format!("{:.2}", pr.fill_cycles as f64 / convaix::CLOCK_HZ as f64 * 1e3),
            format!("{:.2}", pr.drain_cycles as f64 / convaix::CLOCK_HZ as f64 * 1e3),
        ]);
        stream_rows.push(obj(vec![
            ("net", Json::Str(name.into())),
            ("fanout_fps", num(fo.throughput_fps())),
            ("steady_fps", num(pr.steady_state_fps())),
            ("stream_fps", num(pr.throughput_fps())),
            ("fill_cycles", num(pr.fill_cycles as f64)),
            ("drain_cycles", num(pr.drain_cycles as f64)),
            ("makespan_cycles", num(pr.makespan_cycles as f64)),
            ("stage_util", Json::Arr(pr.stage_utilization().into_iter().map(num).collect())),
            (
                "stages",
                Json::Arr(
                    pr.stages
                        .iter()
                        .map(|&(l0, l1)| {
                            Json::Str(format!(
                                "{}..{}",
                                layers[l0].name(),
                                layers[l1 - 1].name()
                            ))
                        })
                        .collect(),
                ),
            ),
        ]));
    }
    t.print();
    dump.insert("streaming_duel_4c".into(), Json::Arr(stream_rows));
    println!(
        "VGG-16 stream of {STREAM} @ 4 cores: pipeline steady {vgg_steady_fps:.1} f/s vs \
         fan-out {vgg_fanout_fps:.1} f/s ({:.2}x)\n",
        vgg_steady_fps / vgg_fanout_fps.max(1e-9)
    );

    // --- partition duel: unequal core groups vs 1-per-stage vs fan-out -------
    // VGG-16-full on 4 cores, shared bus: the partition-DP (auto) is
    // free to give a fat conv stage a multi-core group and leave the
    // weight-DMA-bound FC tail on one core; per-stage is the legacy
    // one-core-per-stage cut; frame fan-out is the non-pipelined
    // baseline. Outputs must be bit-identical across the schedules;
    // the acceptance target is auto's steady interval <= per-stage's.
    let full_net = vgg16_full();
    let frame = vec![0i16; full_net[0].op().in_elems()];
    let inputs: Vec<Vec<i16>> = (0..STREAM).map(|_| frame.clone()).collect();
    let pipe_with = |sc: StageCores| {
        let mut engine = cfg_base()
            .cores(4)
            .batch(STREAM)
            .pool_mode(PoolMode::Pipelined)
            .bus(BusModel::Shared)
            .stage_cores(sc)
            .build();
        engine.run_streaming("VGG-16-full", &full_net, &inputs).expect("partition duel")
    };
    let auto = pipe_with(StageCores::Auto);
    let per_stage = pipe_with(StageCores::PerStage);
    let mut fan = cfg_base().cores(4).batch(STREAM).bus(BusModel::Shared).build();
    let fo = fan.run_batched("VGG-16-full", &full_net, &inputs).expect("fan-out");
    assert_eq!(
        auto.outputs, per_stage.outputs,
        "partition-DP changed the computed outputs"
    );

    let mut t = Table::new(
        "VGG-16-full, 5 frames on 4 cores, shared bus: partition duel",
        &["Schedule", "Stage plan", "Steady f/s", "Steady interval", "Makespan cyc"],
    );
    let plan_of = |sc: &[usize]| sc.iter().map(ToString::to_string).collect::<Vec<_>>().join("+");
    let mut duel_rows = Vec::new();
    for (label, pr) in [("auto (partition-DP)", &auto), ("per-stage (legacy)", &per_stage)] {
        t.row(&[
            label.into(),
            plan_of(&pr.stage_cores),
            format!("{:.1}", pr.steady_state_fps()),
            pr.steady_interval_cycles.to_string(),
            pr.makespan_cycles.to_string(),
        ]);
        duel_rows.push(obj(vec![
            ("schedule", Json::Str(label.into())),
            ("stage_plan", Json::Arr(pr.stage_cores.iter().map(|&k| num(k as f64)).collect())),
            ("steady_fps", num(pr.steady_state_fps())),
            ("steady_interval_cycles", num(pr.steady_interval_cycles as f64)),
            ("makespan_cycles", num(pr.makespan_cycles as f64)),
        ]));
    }
    t.row(&[
        "frame fan-out".into(),
        "-".into(),
        format!("{:.1}", fo.throughput_fps()),
        "-".into(),
        fo.makespan_cycles().to_string(),
    ]);
    duel_rows.push(obj(vec![
        ("schedule", Json::Str("frame fan-out".into())),
        ("fanout_fps", num(fo.throughput_fps())),
        ("makespan_cycles", num(fo.makespan_cycles() as f64)),
    ]));
    t.print();
    dump.insert("partition_duel_vgg_full_4c".into(), Json::Arr(duel_rows));
    println!(
        "VGG-16-full stream of {STREAM} @ 4 cores: auto partition {} steady interval \
         {} vs per-stage {} ({:.2}x)\n",
        plan_of(&auto.stage_cores),
        auto.steady_interval_cycles,
        per_stage.steady_interval_cycles,
        per_stage.steady_interval_cycles as f64 / auto.steady_interval_cycles.max(1) as f64
    );

    // Machine-readable trajectory dump for cross-PR tracking. Written
    // BEFORE the hard perf asserts below: a regression run is exactly
    // the one whose numbers must not be lost (nor masked by a stale
    // file from a previous green run).
    let json = Json::Obj(dump).to_string();
    std::fs::write("BENCH_multicore.json", &json).expect("write BENCH_multicore.json");
    println!("wrote BENCH_multicore.json ({} bytes)", json.len());

    if !no_assert {
        assert!(
            conv11_rb < conv11_oc,
            "row-band ({conv11_rb}) must beat oc-tile ({conv11_oc}) on conv1_1 at 4 cores \
             (set MULTICORE_NO_ASSERT=1 to report without asserting)"
        );
        assert!(
            vgg_steady_fps >= vgg_fanout_fps,
            "pipelined steady state ({vgg_steady_fps:.1} f/s) must match or beat frame \
             fan-out ({vgg_fanout_fps:.1} f/s) on the VGG-16 stream of {STREAM} at 4 cores \
             (set MULTICORE_NO_ASSERT=1 to report without asserting)"
        );
        assert!(
            auto.steady_interval_cycles <= per_stage.steady_interval_cycles,
            "partition-DP steady interval ({}) must not lose to the 1-core-per-stage cut \
             ({}) on VGG-16-full at 4 cores \
             (set MULTICORE_NO_ASSERT=1 to report without asserting)",
            auto.steady_interval_cycles,
            per_stage.steady_interval_cycles,
        );
    }

    // Wall-clock scaling depends on real host parallelism; skip the hard
    // target on undersized hosts, and allow MULTICORE_NO_ASSERT=1 as an
    // escape hatch for loaded / SMT-limited machines.
    if host_threads >= 4 && !no_assert {
        println!("wall-clock speedup at 4 cores: {wall_speedup_at_4:.2}x (target >= 1.7x)");
        assert!(
            wall_speedup_at_4 >= 1.7,
            "4-core wall-clock speedup {wall_speedup_at_4:.2}x below the 1.7x target \
             (set MULTICORE_NO_ASSERT=1 to report without asserting)"
        );
    } else {
        println!(
            "wall-clock speedup at 4 cores: {wall_speedup_at_4:.2}x \
             (1.7x target not enforced: host threads = {host_threads}, \
             MULTICORE_NO_ASSERT = {no_assert})"
        );
    }
}
