//! Bench target: simulator performance (the L3 hot path of the perf
//! pass, EXPERIMENTS.md §Perf). Reports bundles/second on the MAC-dense
//! steady state and on a full conv layer.

use convaix::coordinator::EngineConfig;
use convaix::core::Cpu;
use convaix::isa::asm::assemble;
use convaix::mem::pm::ProgramMem;
use convaix::model::ConvLayer;
use convaix::util::bench::Bench;
use convaix::util::XorShift;

fn main() {
    // 1. dense vmac loop: the dominant bundle shape in conv kernels
    let mut src = String::from(
        "csrwi lb_stride, 1\nli r1, 0\nldvf [r1]!32\nldvf [r1]!32\nlbld 0, r1, 16\n",
    );
    src.push_str("loopi 60000, 1\n");
    // no post-increment: the speed benchmark re-reads one address so the
    // 60k-bundle stream never leaves DM
    src.push_str("ldvf [r1] | vmac lb:0, ff | vmac lb:4, ff | vmac lb:8, ff\n");
    src.push_str("nop | vmul lb:0, ff | vnop | vnop\nnop | vmul lb:0, ff | vnop | vnop\nhalt\n");
    let pm = ProgramMem::load(&assemble(&src).unwrap()).unwrap();

    let b = Bench::default();
    let mut cpu = Cpu::new(1 << 16);
    let r = b.run("steady-state vmac loop (60k bundles)", || {
        cpu.run(&pm).unwrap().cycles
    });
    let bundles_per_sec = 60_000.0 / (r.median_ns as f64 / 1e9);
    println!("  -> {:.1} M bundles/s (MAC-dense)", bundles_per_sec / 1e6);

    // 2. a realistic conv layer, full cycle
    let l = ConvLayer::new("bench", 32, 28, 28, 64, 3, 3, 1, 1, 1);
    let mut rng = XorShift::new(5);
    let x = rng.i16_vec(l.ic * l.ih * l.iw, -500, 500);
    let w = rng.i16_vec(l.oc * l.ic * 9, -100, 100);
    let bias = rng.i32_vec(l.oc, -100, 100);
    let mut engine = EngineConfig::new().build();
    let mut cycles = 0;
    let r = b.run("conv 32x28x28 -> 64 full-cycle", || {
        let res = engine.run_conv_layer(&l, &x, &w, &bias).unwrap();
        cycles = res.compute_cycles;
        cycles
    });
    let cps = cycles as f64 / (r.median_ns as f64 / 1e9);
    println!("  -> {:.1} M simulated cycles/s on a full conv layer", cps / 1e6);
}
