//! Bench target: simulator performance — host-side speed of the
//! serving paths, and the compile-once layer cache's effect on them.
//!
//! Sections:
//!  1. raw interpreter speed (bundles/s on the MAC-dense steady state),
//!  2. a full-cycle conv layer (simulated cycles/s + host MAC/s),
//!  3. cached vs uncached **batched** VGG-16 conv stack (tile-analytic,
//!     the serving configuration): `--no-cache`-equivalent engine vs a
//!     warm engine, wall-clock,
//!  4. the same duel on the **streaming** (pipelined) path, full net.
//!
//! Emits `BENCH_simspeed.json` BEFORE any perf assert, so regression
//! runs keep their trajectory record. Hard target (hosts with >= 4
//! threads, `MULTICORE_NO_ASSERT=1` to skip): warm cache >= 1.5x over
//! uncached wall-clock on the batched VGG-16 conv stack.
//!
//!     cargo bench --bench simspeed

use std::collections::BTreeMap;
use std::time::Instant;

use convaix::coordinator::{BusModel, EngineConfig, ExecMode, NetLayer, PoolMode};
use convaix::core::Cpu;
use convaix::isa::asm::assemble;
use convaix::mem::pm::ProgramMem;
use convaix::model::{conv_stack, vgg16_conv, vgg16_full, ConvLayer};
use convaix::util::bench::Bench;
use convaix::util::json::Json;
use convaix::util::XorShift;

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn main() {
    let host_threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let no_assert = std::env::var_os("MULTICORE_NO_ASSERT").is_some();
    println!("host threads available: {host_threads}\n");
    let mut dump: BTreeMap<String, Json> = BTreeMap::new();
    dump.insert("host_threads".into(), num(host_threads as f64));

    // --- 1. dense vmac loop: the dominant bundle shape in conv kernels ---
    let mut src = String::from(
        "csrwi lb_stride, 1\nli r1, 0\nldvf [r1]!32\nldvf [r1]!32\nlbld 0, r1, 16\n",
    );
    src.push_str("loopi 60000, 1\n");
    // no post-increment: the speed benchmark re-reads one address so the
    // 60k-bundle stream never leaves DM
    src.push_str("ldvf [r1] | vmac lb:0, ff | vmac lb:4, ff | vmac lb:8, ff\n");
    src.push_str("nop | vmul lb:0, ff | vnop | vnop\nnop | vmul lb:0, ff | vnop | vnop\nhalt\n");
    let pm = ProgramMem::load(&assemble(&src).unwrap()).unwrap();

    let b = Bench::default();
    let mut cpu = Cpu::new(1 << 16);
    let r = b.run("steady-state vmac loop (60k bundles)", || {
        cpu.run(&pm).unwrap().cycles
    });
    let bundles_per_sec = 60_000.0 / (r.median_ns as f64 / 1e9);
    println!("  -> {:.1} M bundles/s (MAC-dense)\n", bundles_per_sec / 1e6);
    dump.insert("bundles_per_s".into(), num(bundles_per_sec));

    // --- 2. a realistic conv layer, full cycle ---------------------------
    let l = ConvLayer::new("bench", 32, 28, 28, 64, 3, 3, 1, 1, 1);
    let mut rng = XorShift::new(5);
    let x = rng.i16_vec(l.ic * l.ih * l.iw, -500, 500);
    let w = rng.i16_vec(l.oc * l.ic * 9, -100, 100);
    let bias = rng.i32_vec(l.oc, -100, 100);
    let mut engine = EngineConfig::new().build();
    let mut cycles = 0;
    let r = b.run("conv 32x28x28 -> 64 full-cycle", || {
        let res = engine.run_conv_layer(&l, &x, &w, &bias).unwrap();
        cycles = res.compute_cycles;
        cycles
    });
    let secs = r.median_ns as f64 / 1e9;
    let cps = cycles as f64 / secs;
    let host_macs = l.macs() as f64 / secs;
    println!(
        "  -> {:.1} M simulated cycles/s, {:.1} M MAC/s host throughput\n",
        cps / 1e6,
        host_macs / 1e6
    );
    dump.insert("fullcycle_sim_cycles_per_s".into(), num(cps));
    dump.insert("fullcycle_host_macs_per_s".into(), num(host_macs));

    // --- 3. cached vs uncached batched VGG-16 conv stack -----------------
    // The serving configuration: tile-analytic, 8-bit gated, frames
    // fanned over min(4, host) cores. "Uncached" compiles every plan /
    // program / analytic profile fresh per call (the pre-0.5 executor);
    // "warm" reuses the engine's PlanCache — the steady state of a
    // long-running server.
    let cores = host_threads.min(4).max(1);
    const FRAMES: usize = 8;
    let vgg: Vec<NetLayer> = conv_stack(vgg16_conv());
    let frame = vec![0i16; 3 * 224 * 224];
    let inputs: Vec<Vec<i16>> = (0..FRAMES).map(|_| frame.clone()).collect();
    let cfg = || {
        EngineConfig::new()
            .mode(ExecMode::TileAnalytic)
            .gate_bits(8)
            .cores(cores)
            .batch(FRAMES)
            .bus(BusModel::Shared)
    };
    let batch_macs: u64 = vgg.iter().map(|l| l.op().macs()).sum::<u64>() * FRAMES as u64;

    let mut uncached_engine = cfg().plan_cache(false).build();
    let t0 = Instant::now();
    let bu = uncached_engine.run_batched("VGG-16", &vgg, &inputs).expect("uncached batch");
    let uncached_wall = t0.elapsed().as_secs_f64();

    let mut cached_engine = cfg().build();
    let t0 = Instant::now();
    let bc = cached_engine.run_batched("VGG-16", &vgg, &inputs).expect("cold batch");
    let cold_wall = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let bw = cached_engine.run_batched("VGG-16", &vgg, &inputs).expect("warm batch");
    let warm_wall = t0.elapsed().as_secs_f64();

    // the cache must never change the model's answers
    assert_eq!(bu.core_cycles, bc.core_cycles, "uncached vs cold modeled cycles");
    assert_eq!(bc.core_cycles, bw.core_cycles, "cold vs warm modeled cycles");

    let batched_speedup = uncached_wall / warm_wall.max(1e-9);
    let cs = cached_engine.cache_stats();
    println!(
        "batched VGG-16 conv stack, {FRAMES} frames on {cores} core(s), tile-analytic:\n\
         \x20 uncached {uncached_wall:.3} s | cold {cold_wall:.3} s | warm {warm_wall:.3} s \
         -> {batched_speedup:.2}x warm-vs-uncached\n\
         \x20 host throughput warm: {:.1} M MAC/s; cache: {} hits / {} misses\n",
        batch_macs as f64 / warm_wall.max(1e-9) / 1e6,
        cs.hits,
        cs.misses,
    );
    dump.insert(
        "batched_vgg_conv".into(),
        obj(vec![
            ("cores", num(cores as f64)),
            ("frames", num(FRAMES as f64)),
            ("uncached_wall_s", num(uncached_wall)),
            ("cold_wall_s", num(cold_wall)),
            ("warm_wall_s", num(warm_wall)),
            ("speedup_warm_vs_uncached", num(batched_speedup)),
            ("host_macs_per_s_warm", num(batch_macs as f64 / warm_wall.max(1e-9))),
            ("host_macs_per_s_uncached", num(batch_macs as f64 / uncached_wall.max(1e-9))),
            ("cache_hits", num(cs.hits as f64)),
            ("cache_misses", num(cs.misses as f64)),
        ]),
    );

    // --- 4. the streaming path, full net ---------------------------------
    // Layer-pipelined VGG-16-full (conv + pools + the DMA-bound FC
    // tail): same duel on the other serving entry point.
    let full_net = vgg16_full();
    let sframe = vec![0i16; full_net[0].op().in_elems()];
    let sinputs: Vec<Vec<i16>> = (0..FRAMES).map(|_| sframe.clone()).collect();
    let scfg = || cfg().pool_mode(PoolMode::Pipelined);

    let mut uncached_engine = scfg().plan_cache(false).build();
    let t0 = Instant::now();
    let su = uncached_engine.run_streaming("VGG-16-full", &full_net, &sinputs).expect("uncached");
    let s_uncached = t0.elapsed().as_secs_f64();

    let mut cached_engine = scfg().build();
    cached_engine.run_streaming("VGG-16-full", &full_net, &sinputs).expect("cold stream");
    let t0 = Instant::now();
    let sw = cached_engine.run_streaming("VGG-16-full", &full_net, &sinputs).expect("warm");
    let s_warm = t0.elapsed().as_secs_f64();

    assert_eq!(su.stage_cycles, sw.stage_cycles, "cache changed streamed stage cycles");
    let stream_speedup = s_uncached / s_warm.max(1e-9);
    println!(
        "streaming VGG-16-full, {FRAMES} frames through {} stage(s):\n\
         \x20 uncached {s_uncached:.3} s | warm {s_warm:.3} s -> {stream_speedup:.2}x\n",
        sw.stages.len(),
    );
    dump.insert(
        "streaming_vgg_full".into(),
        obj(vec![
            ("stages", num(sw.stages.len() as f64)),
            ("frames", num(FRAMES as f64)),
            ("uncached_wall_s", num(s_uncached)),
            ("warm_wall_s", num(s_warm)),
            ("speedup_warm_vs_uncached", num(stream_speedup)),
        ]),
    );

    // Trajectory dump FIRST: a regression run is exactly the one whose
    // numbers must not be lost behind a failed assert.
    let json = Json::Obj(dump).to_string();
    std::fs::write("BENCH_simspeed.json", &json).expect("write BENCH_simspeed.json");
    println!("wrote BENCH_simspeed.json ({} bytes)", json.len());

    // Hard target: the compile-once cache must be worth >= 1.5x host
    // wall-clock on the batched conv-stack serving path. Wall-clock
    // needs real host parallelism; undersized hosts report only.
    if host_threads >= 4 && !no_assert {
        println!("cached-vs-uncached speedup: {batched_speedup:.2}x (target >= 1.5x)");
        assert!(
            batched_speedup >= 1.5,
            "warm plan cache {batched_speedup:.2}x below the 1.5x target on the batched \
             VGG-16 conv stack (set MULTICORE_NO_ASSERT=1 to report without asserting)"
        );
    } else {
        println!(
            "cached-vs-uncached speedup: {batched_speedup:.2}x \
             (1.5x target not enforced: host threads = {host_threads}, \
             MULTICORE_NO_ASSERT = {no_assert})"
        );
    }
}
