//! Bench target: regenerate Table I (processor specification) plus a
//! peak-throughput sanity run — a dense MAC microkernel must hit the
//! 192 MAC/cycle steady state.

use convaix::cli::report;
use convaix::core::Cpu;
use convaix::isa::asm::assemble;
use convaix::mem::pm::ProgramMem;
use convaix::util::bench::Bench;

fn main() {
    print!("{}", report::table1());

    // peak-throughput microkernel: 3 vmacs per bundle for 200 bundles
    let mut src = String::from(
        "csrwi lb_stride, 1\nli r1, 0\nldvf [r1]!32\nldvf [r1]!32\nlbld 0, r1, 16\n",
    );
    src.push_str("loopi 200, 1\n");
    src.push_str("ldvf [r1]!32 | vmac lb:0, ff | vmac lb:4, ff | vmac lb:8, ff\n");
    src.push_str("nop | vmul lb:0, ff | vnop | vnop\nnop | vmul lb:0, ff | vnop | vnop\nhalt\n");
    let pm = ProgramMem::load(&assemble(&src).unwrap()).unwrap();

    let mut cpu = Cpu::new(1 << 16);
    let stats = cpu.run(&pm).unwrap();
    let macs_per_cycle = stats.mac_ops as f64 / stats.cycles as f64;
    println!(
        "peak sanity: {} MACs in {} cycles = {:.1} MAC/cycle (spec: 192)",
        stats.mac_ops, stats.cycles, macs_per_cycle
    );
    assert!(macs_per_cycle > 180.0, "steady state below spec");

    // how fast does the simulator itself generate this table?
    let b = Bench::default();
    b.run("table1 generation", report::table1);
}
