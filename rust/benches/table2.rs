//! Bench target: regenerate Table II (the paper's headline comparison).
//! Runs both benchmark networks in tile-analytic mode at 8-bit gated
//! precision (the paper's operating point) and prints the full table
//! with the paper's values side by side.

use convaix::cli::report;
use convaix::coordinator::{EngineConfig, ExecMode};
use convaix::util::bench::Bench;

fn main() {
    let cfg = EngineConfig::new().mode(ExecMode::TileAnalytic).gate_bits(8);
    print!("{}", report::table2(&cfg).expect("table2"));
    let b = Bench::quick();
    b.run("table2 (AlexNet+VGG16, tile-analytic)", || {
        report::table2(&cfg).unwrap().len()
    });
}
