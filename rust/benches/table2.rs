//! Bench target: regenerate Table II (the paper's headline comparison).
//! Runs both benchmark networks in tile-analytic mode at 8-bit gated
//! precision (the paper's operating point) and prints the full table
//! with the paper's values side by side.

use convaix::cli::report;
use convaix::coordinator::executor::{ExecMode, ExecOptions};
use convaix::util::bench::Bench;

fn main() {
    let opts = ExecOptions { mode: ExecMode::TileAnalytic, gate_bits: 8, ..Default::default() };
    print!("{}", report::table2(opts).expect("table2"));
    let b = Bench::quick();
    b.run("table2 (AlexNet+VGG16, tile-analytic)", || {
        report::table2(opts).unwrap().len()
    });
}
