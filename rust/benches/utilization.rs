//! Bench target: the per-layer utilization table behind the abstract's
//! "average ALU utilization of 72.5 %" claim (AlexNet + VGG-16 conv
//! layers, 16-bit vector instructions).

use convaix::cli::report;
use convaix::coordinator::executor::{ExecMode, ExecOptions};

fn main() {
    let opts = ExecOptions { mode: ExecMode::TileAnalytic, ..Default::default() };
    print!("{}", report::util_table(opts).expect("util"));
}
