//! Bench target: the per-layer utilization table behind the abstract's
//! "average ALU utilization of 72.5 %" claim (AlexNet + VGG-16 conv
//! layers, 16-bit vector instructions), swept over both precision gates
//! now that DMA streams are priced by the feasibility-gated
//! fill/steady rotation timeline.
//!
//! Emits `BENCH_utilization.json` (per-layer util / fill / serialized
//! DMA rows for AlexNet and VGG-16 at gates 8 and 16, plus the
//! MAC-weighted 16-bit conv aggregate) so the utilization trajectory is
//! tracked machine-readably across PRs. The JSON is written BEFORE the
//! hard asserts; `MULTICORE_NO_ASSERT=1` skips the asserts without
//! skipping the report.
//!
//!     cargo bench --bench utilization

use std::collections::BTreeMap;

use convaix::cli::report;
use convaix::coordinator::{EngineConfig, ExecMode, NetLayer};
use convaix::model::{alexnet_conv, conv_stack, vgg16_conv};
use convaix::util::json::Json;
use convaix::util::table::Table;

/// The abstract's claimed average conv ALU utilization at 16 bit.
const PAPER_CONV_UTIL: f64 = 0.725;
/// Absolute tolerance on the model's 16-bit conv aggregate vs the
/// paper (same spirit as `OPERATING_POINT_TOL`: the model prices the
/// DMA timeline analytically, not from silicon traces).
const CONV_UTIL_TOL: f64 = 0.15;

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn main() {
    let no_assert = std::env::var_os("MULTICORE_NO_ASSERT").is_some();
    let mut dump: BTreeMap<String, Json> = BTreeMap::new();

    // MAC-weighted 16-bit conv aggregate across BOTH nets — the
    // utilization definition of Table II fn. e restricted to conv.
    let mut agg_macs_16 = 0u64;
    let mut agg_busy_16 = 0u64;

    let nets: [(&str, Vec<NetLayer>); 2] =
        [("AlexNet", conv_stack(alexnet_conv())), ("VGG-16", conv_stack(vgg16_conv()))];
    for gate in [8u8, 16] {
        let mut t = Table::new(
            &format!(
                "Per-layer ALU utilization, {gate}-bit gate \
                 (paper: 72.5 % average across AlexNet+VGG-16 16-bit conv layers)"
            ),
            &["Net", "Layer", "Util %", "Fill cyc", "Serial cyc", "Time [ms]"],
        );
        for (net, layers) in &nets {
            let cfg = EngineConfig::new().mode(ExecMode::TileAnalytic).gate_bits(gate);
            let r = report::bench_network(net, layers, &cfg).expect("utilization net");
            let mut rows = Vec::new();
            for l in &r.layers {
                if l.macs == 0 {
                    continue;
                }
                t.row(&[
                    (*net).into(),
                    l.name.to_string(),
                    format!("{:.1}", l.utilization() * 100.0),
                    l.dma_fill_cycles.to_string(),
                    l.dma_serial_cycles.to_string(),
                    format!("{:.3}", l.time_ms()),
                ]);
                rows.push(obj(vec![
                    ("layer", Json::Str(l.name.to_string())),
                    ("util", num(l.utilization())),
                    ("cycles", num(l.cycles as f64)),
                    ("macs", num(l.macs as f64)),
                    ("dma_fill_cycles", num(l.dma_fill_cycles as f64)),
                    ("dma_serial_cycles", num(l.dma_serial_cycles as f64)),
                ]));
            }
            if let Some(conv) = r.kind_totals(layers).iter().find(|kt| kt.kind == "conv") {
                if gate == 16 {
                    agg_macs_16 += conv.macs;
                    agg_busy_16 += conv.busy_core_cycles;
                }
                dump.insert(
                    format!("{}_gate{gate}_conv_util", net.to_lowercase()),
                    num(conv.utilization()),
                );
            }
            dump.insert(format!("{}_gate{gate}_layers", net.to_lowercase()), Json::Arr(rows));
        }
        t.print();
    }

    let conv_avg_16 = if agg_busy_16 == 0 {
        0.0
    } else {
        (agg_macs_16 as f64 / convaix::PEAK_MACS_PER_CYCLE as f64) / agg_busy_16 as f64
    };
    dump.insert("conv_util_16b_avg".into(), num(conv_avg_16));
    dump.insert("paper_conv_util".into(), num(PAPER_CONV_UTIL));
    println!(
        "16-bit conv ALU utilization, MAC-weighted AlexNet+VGG-16 aggregate: {:.1} % \
         (paper: {:.1} %)\n",
        conv_avg_16 * 100.0,
        PAPER_CONV_UTIL * 100.0
    );

    // Written BEFORE the hard assert: a regression run is exactly the
    // one whose numbers must not be lost (nor masked by a stale file
    // from a previous green run).
    let json = Json::Obj(dump).to_string();
    std::fs::write("BENCH_utilization.json", &json).expect("write BENCH_utilization.json");
    println!("wrote BENCH_utilization.json ({} bytes)", json.len());

    if !no_assert {
        assert!(
            (conv_avg_16 - PAPER_CONV_UTIL).abs() <= CONV_UTIL_TOL,
            "16-bit conv utilization {:.3} strayed more than {CONV_UTIL_TOL} from the \
             paper's {PAPER_CONV_UTIL} \
             (set MULTICORE_NO_ASSERT=1 to report without asserting)",
            conv_avg_16,
        );
    }
}
