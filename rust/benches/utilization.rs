//! Bench target: the per-layer utilization table behind the abstract's
//! "average ALU utilization of 72.5 %" claim (AlexNet + VGG-16 conv
//! layers, 16-bit vector instructions).

use convaix::cli::report;
use convaix::coordinator::{EngineConfig, ExecMode};

fn main() {
    let cfg = EngineConfig::new().mode(ExecMode::TileAnalytic);
    print!("{}", report::util_table(&cfg).expect("util"));
}
