//! Baseline accelerators for the Table II comparison: Envision [7] and
//! Eyeriss [6].
//!
//! Two levels of fidelity:
//!
//! * [`published`] — the literature values the paper itself tabulates
//!   (we cannot re-measure other groups' silicon; the paper doesn't
//!   either). These feed the Table II regenerator verbatim, with the
//!   derived columns (effective GOP/s, area efficiency, scaled energy
//!   efficiency) recomputed by our code — a genuine consistency check on
//!   the paper's arithmetic.
//! * [`eyeriss_model`] / [`envision_model`] — first-order dataflow-shape
//!   models (row-stationary 12×14 array; 16×16 MAC array) giving
//!   order-of-magnitude time/utilization estimates from array geometry
//!   and per-pass ramp costs. They capture spatial mapping losses but
//!   not psum-depth or batching effects (the full driver of Eyeriss's
//!   published VGG-16 collapse to 0.36) — Table II therefore uses the
//!   `published` values for the comparison columns, and these models
//!   feed the ablation bench only.

pub mod published {
    /// Static spec of a comparison design (Table II upper rows).
    #[derive(Debug, Clone)]
    pub struct BaselineSpec {
        pub name: &'static str,
        pub tech_nm: f64,
        pub voltage: f64,
        pub kge: f64,
        pub sram_kb: f64,
        pub freq_mhz: f64,
        pub n_macs: u32,
        pub peak_gops: f64,
        pub arch: &'static str,
        pub precision: &'static str,
    }

    /// Published per-network measurement (Table II lower rows).
    #[derive(Debug, Clone)]
    pub struct BaselineNet {
        pub net: &'static str,
        pub time_ms: f64,
        pub power_mw: f64,
        pub io_mbytes: f64,
        pub util: f64,
        /// Energy efficiency as printed (GOP/s/W, unscaled).
        pub eff_printed: f64,
        /// Scaled efficiency as printed (28 nm / 1 V).
        pub eff_scaled_printed: f64,
        /// Network GOPs (2·MACs), conv stack.
        pub gop: f64,
    }

    pub fn envision() -> (BaselineSpec, Vec<BaselineNet>) {
        (
            BaselineSpec {
                name: "Envision [7]",
                tech_nm: 40.0,
                voltage: 0.905, // mid of the published 0.85–0.92 range
                kge: 1600.0,
                sram_kb: 148.0,
                freq_mhz: 204.0,
                n_macs: 256,
                peak_gops: 104.5,
                arch: "RISC + MAC array",
                precision: "1-16b scalable",
            },
            vec![BaselineNet {
                net: "AlexNet",
                time_ms: 21.07,
                power_mw: 70.1,
                io_mbytes: 9.97,
                util: 0.61,
                eff_printed: 815.0,
                eff_scaled_printed: 955.0,
                gop: 1.3316,
            }],
        )
    }

    pub fn eyeriss() -> (BaselineSpec, Vec<BaselineNet>) {
        (
            BaselineSpec {
                name: "Eyeriss [6]",
                tech_nm: 65.0,
                voltage: 1.0,
                kge: 1176.0,
                sram_kb: 181.5,
                freq_mhz: 200.0,
                n_macs: 168,
                peak_gops: 67.2,
                arch: "ASIC (row stationary)",
                precision: "16b fixed",
            },
            vec![
                BaselineNet {
                    net: "AlexNet",
                    time_ms: 25.88,
                    power_mw: 116.8,
                    io_mbytes: 7.19,
                    util: 0.77,
                    eff_printed: 187.0,
                    eff_scaled_printed: 434.0,
                    gop: 1.3316,
                },
                BaselineNet {
                    net: "VGG-16",
                    time_ms: 1251.63,
                    power_mw: 104.8,
                    io_mbytes: 125.8,
                    util: 0.36,
                    eff_printed: 104.0,
                    eff_scaled_printed: 242.0,
                    gop: 30.693,
                },
            ],
        )
    }

    impl BaselineNet {
        /// Effective throughput (GOP/s) from published time.
        pub fn eff_gops(&self) -> f64 {
            self.gop / (self.time_ms / 1e3)
        }
        /// Area efficiency (GOP/s/MGE) — effective throughput per mega
        /// gate, the Table II definition.
        pub fn area_eff(&self, spec: &BaselineSpec) -> f64 {
            self.eff_gops() / (spec.kge / 1e3)
        }
        /// Energy efficiency scaled to 28 nm / 1 V with the paper's
        /// formula, from the printed unscaled value.
        pub fn eff_scaled(&self, spec: &BaselineSpec) -> f64 {
            crate::energy::scale_energy_eff(self.eff_printed, spec.tech_nm, spec.voltage, 28.0, 1.0)
        }
    }
}

/// First-order row-stationary (Eyeriss) utilization/time model.
///
/// A 12×14 PE array; each PE runs a 1-D convolution of one filter row.
/// A *pass* maps `FH` filter rows × up to 14 output-row strips, and is
/// replicated `floor(12/FH)` times vertically. Between passes the array
/// is re-configured and filter/psum state is ramped through the NoC —
/// `RAMP_CYCLES` per pass. Deep layers (VGG: 512 channels, 3×3 filters)
/// need many short passes, collapsing utilization — the effect the paper
/// quotes (0.36 for VGG vs 0.77 for AlexNet).
pub mod eyeriss_model {
    use crate::model::ConvLayer;

    pub const ROWS: usize = 12;
    pub const COLS: usize = 14;
    pub const FREQ_MHZ: f64 = 200.0;
    /// Reconfiguration + fill/drain cost per pass (calibrated to the
    /// published utilization gap).
    pub const RAMP_CYCLES: f64 = 600.0;

    pub struct EyerissEstimate {
        pub util: f64,
        pub time_ms: f64,
    }

    pub fn estimate_layer(l: &ConvLayer) -> EyerissEstimate {
        let lg = l.per_group();
        let repl = (ROWS / lg.fh).max(1);
        let active_rows = (repl * lg.fh).min(ROWS);
        let spatial = active_rows as f64 / ROWS as f64
            * (lg.ow().min(COLS) as f64 / COLS as f64);
        // one pass: `repl` filters × 1 input channel × 14-wide strip
        let strips = lg.ow().div_ceil(COLS);
        let passes = (lg.oc.div_ceil(repl) * lg.ic * strips) as f64 / 8.0; // psum depth reuse across passes
        let active_pes = (active_rows * COLS.min(lg.ow())) as f64;
        let compute_cycles = l.macs() as f64 / (active_pes * spatial.max(1e-9));
        let cycles = compute_cycles + passes * RAMP_CYCLES;
        let ideal = l.macs() as f64 / (ROWS * COLS) as f64;
        EyerissEstimate {
            util: ideal / cycles,
            time_ms: cycles / (FREQ_MHZ * 1e6) * 1e3,
        }
    }

    pub fn estimate_network(layers: &[ConvLayer]) -> EyerissEstimate {
        let mut cycles = 0.0;
        let mut macs = 0u64;
        for l in layers {
            let e = estimate_layer(l);
            cycles += e.time_ms / 1e3 * FREQ_MHZ * 1e6;
            macs += l.macs();
        }
        let ideal = macs as f64 / (ROWS * COLS) as f64;
        EyerissEstimate { util: ideal / cycles, time_ms: cycles / (FREQ_MHZ * 1e6) * 1e3 }
    }
}

/// First-order Envision model: 16×16 MAC array fed by a RISC core;
/// parallelism over (16 output channels × 16 pixels); per-tile setup by
/// the scalar core costs `SETUP_CYCLES`.
pub mod envision_model {
    use crate::model::ConvLayer;

    pub const ARRAY: usize = 16;
    pub const FREQ_MHZ: f64 = 204.0;
    pub const SETUP_CYCLES: f64 = 160.0;

    pub struct EnvisionEstimate {
        pub util: f64,
        pub time_ms: f64,
    }

    pub fn estimate_layer(l: &ConvLayer) -> EnvisionEstimate {
        let lg = l.per_group();
        let oc_eff = lg.oc.min(ARRAY) as f64 / ARRAY as f64;
        let px_eff = (lg.ow() * lg.oh()).min(ARRAY) as f64 / ARRAY as f64;
        let spatial = oc_eff * px_eff.max(1.0_f64.min(px_eff));
        let tiles = lg.oc.div_ceil(ARRAY) * (lg.ow() * lg.oh()).div_ceil(ARRAY) * lg.ic;
        let compute = l.macs() as f64 / ((ARRAY * ARRAY) as f64 * spatial);
        let cycles = compute + tiles as f64 * SETUP_CYCLES / (lg.fh * lg.fw) as f64;
        let ideal = l.macs() as f64 / (ARRAY * ARRAY) as f64;
        EnvisionEstimate { util: ideal / cycles, time_ms: cycles / (FREQ_MHZ * 1e6) * 1e3 }
    }

    pub fn estimate_network(layers: &[ConvLayer]) -> EnvisionEstimate {
        let mut cycles = 0.0;
        let mut macs = 0u64;
        for l in layers {
            let e = estimate_layer(l);
            cycles += e.time_ms / 1e3 * FREQ_MHZ * 1e6;
            macs += l.macs();
        }
        let ideal = macs as f64 / (ARRAY * ARRAY) as f64;
        EnvisionEstimate { util: ideal / cycles, time_ms: cycles / (FREQ_MHZ * 1e6) * 1e3 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{alexnet_conv, vgg16_conv};

    #[test]
    fn published_area_eff_matches_paper() {
        let (spec, nets) = published::eyeriss();
        // paper: 44.01 (AlexNet), 20.85 (VGG)
        assert!((nets[0].area_eff(&spec) - 44.01).abs() < 0.8, "{}", nets[0].area_eff(&spec));
        assert!((nets[1].area_eff(&spec) - 20.85).abs() < 0.5, "{}", nets[1].area_eff(&spec));
        let (espec, enets) = published::envision();
        // paper: 39.73
        assert!((enets[0].area_eff(&espec) - 39.73).abs() < 0.8, "{}", enets[0].area_eff(&espec));
    }

    #[test]
    fn published_eff_scaling_matches_paper() {
        let (spec, nets) = published::eyeriss();
        assert!((nets[0].eff_scaled(&spec) - nets[0].eff_scaled_printed).abs() < 5.0);
        assert!((nets[1].eff_scaled(&spec) - nets[1].eff_scaled_printed).abs() < 5.0);
        let (espec, enets) = published::envision();
        assert!((enets[0].eff_scaled(&espec) - enets[0].eff_scaled_printed).abs() < 15.0);
    }

    #[test]
    fn eyeriss_model_plausible_magnitudes() {
        // First-order model: right order of magnitude for time/util.
        // (The published VGG collapse to 0.36 needs psum-depth and batch
        // effects the first-order model does not capture — Table II uses
        // the `published` values; see module docs.)
        let alex = eyeriss_model::estimate_network(&alexnet_conv());
        let vgg = eyeriss_model::estimate_network(&vgg16_conv());
        assert!(alex.util > 0.15 && alex.util <= 1.0, "alex {}", alex.util);
        assert!(vgg.util > 0.15 && vgg.util <= 1.0, "vgg {}", vgg.util);
        // both designs are slower than ConvAix's published times
        assert!(alex.time_ms > 12.6);
        assert!(vgg.time_ms > 263.0);
    }

    #[test]
    fn envision_model_plausible() {
        let alex = envision_model::estimate_network(&alexnet_conv());
        assert!(alex.util > 0.35 && alex.util <= 1.0, "{}", alex.util);
    }
}
