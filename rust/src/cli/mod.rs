//! Command-line interface of the `convaix` binary and the table/figure
//! regenerators shared with the `cargo bench` targets.

pub mod report;

use anyhow::Result;

use crate::coordinator::{BusModel, EngineConfig, FaultPlan, PoolMode, ShardPolicy, StageCores};

const USAGE: &str = "\
convaix — ConvAix ASIP reproduction (ISCAS'19)

USAGE: convaix <command> [options]

COMMANDS:
  table1             Table I   — processor specification
  fig3b              Fig. 3b   — logic area breakdown
  fig3c              Fig. 3c   — power breakdown (AlexNet conv3, 8-bit gated)
  table2             Table II  — comparison vs Envision / Eyeriss
  util               per-layer MAC utilization (the 72.5 % claim)
  run <net>          run a network and report metrics:
                       alexnet | vgg16           conv stacks (Table II)
                       alexnet-full | vgg16-full end-to-end nets with the
                       pools and fc6/fc7/fc8 tails (per-kind report rows)
  run-multi <t>...   multi-tenant serving: each positional is one tenant
                     as net[:cores[:gate]] (e.g. vgg16-full:3:8
                     alexnet-full:1:16); the tenants stream
                     concurrently, compete for ONE shared external bus,
                     and share the compile-once plan cache. --batch sets
                     the frames per tenant; --stage-cores applies to
                     every tenant
  golden             bit-exact check: simulator vs JAX/Pallas PJRT artifacts
  lint <net>         compile every task program of a net (solo + sharded
                     sub-shapes, gates 8 and 16) and run the static
                     verifier, the symbolic memory-access verifier and
                     the cycle analyzer over each; nonzero exit if any
                     program has findings; --json emits one machine-
                     readable object per finding
  asm <file.cvx>     assemble a .cvx file, report size, disassemble back

OPTIONS:
  --full             full cycle simulation (default: tile-analytic)
  --gate <bits>      precision gating (default 8, i.e. the paper's setup)
  --artifacts <dir>  artifact directory (default: artifacts)
  --cores <n>        shard layers across n ConvAix cores (default 1);
                     `run` reports per-core utilization and speedup
  --batch <n>        batched throughput mode: fan n frames out over the
                     core pool (default 1 = latency mode)
  --pipeline         layer-pipelined streaming instead of frame fan-out:
                     cut the network into --cores contiguous stages and
                     stream the --batch frames through them (reports
                     steady-state f/s and fill/drain latency)
  --pool-mode <m>    long form of the same switch:
                     fan-out (default) | pipelined
  --shard <policy>   intra-layer shard axis for --cores > 1:
                     oc-tile (default) | row-band | auto
  --bus <model>      external bandwidth model for --cores > 1:
                     partitioned (default) | shared
  --stage-cores <p>  stage-to-core mapping for --pipeline:
                     per-stage (default, one core per stage) | auto
                     (partition-DP: stages may own unequal core groups
                     and shard internally) | an explicit plan like 1,2,1
  --json             machine-readable lint output: a JSON document with
                     one {net, layer, shard, pass, kind, location}
                     object per finding (lint only)
  --verify-programs  run the static verifier on every plan-cache insert
                     (always on in debug builds; this flag sets ANALYZE=1
                     so release runs verify too)
  --no-cache         disable the compile-once layer cache (plans, task
                     programs and analytic profiles are then re-derived
                     on every call — the pre-0.5 behavior; results are
                     bit-identical, only the host wall-clock changes)
  --no-rotation      forbid DM double buffering: every layer's DMA
                     stream is priced serialized against compute
                     (compute + dma per iteration) instead of the
                     fill/steady rotated timeline — outputs are
                     bit-identical, only cycles change
  --inject <spec>    seeded fault-injection campaign, seed[:rate[:kinds]]
                     (seed decimal or 0x… hex; rate a fraction in [0,1],
                     default 0.05; kinds a comma list of bitflip |
                     dma-corrupt | dma-drop | hang | fail | all | silent
                     — default: every transient kind, detection on).
                     With detection on, faults are detected, priced and
                     retried: outputs stay bit-identical to the
                     fault-free run (the run verifies this) and the
                     report shows retry/recovery counts; `silent`
                     disables detection so faults corrupt outputs —
                     the unprotected baseline. `fail` exhausts core
                     retry budgets: with spare --cores the run degrades
                     onto the survivors instead of crashing
";

/// Tiny argv parser (clap is not in the offline vendor set).
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    pub full: bool,
    pub gate_bits: u8,
    pub artifacts: String,
    pub cores: usize,
    pub batch: usize,
    pub pipeline: bool,
    pub shard: ShardPolicy,
    pub bus: BusModel,
    pub stage_cores: StageCores,
    pub no_cache: bool,
    pub no_rotation: bool,
    pub verify_programs: bool,
    pub json: bool,
    pub inject: Option<FaultPlan>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut a = Args {
            command: String::new(),
            positional: vec![],
            full: false,
            gate_bits: 8,
            artifacts: "artifacts".into(),
            cores: 1,
            batch: 1,
            pipeline: false,
            shard: ShardPolicy::OcTile,
            bus: BusModel::Partitioned,
            stage_cores: StageCores::PerStage,
            no_cache: false,
            no_rotation: false,
            verify_programs: false,
            json: false,
            inject: None,
        };
        let mut it = argv.iter().skip(1).peekable();
        while let Some(arg) = it.next() {
            match arg.as_str() {
                "--full" => a.full = true,
                "--gate" => {
                    a.gate_bits = it
                        .next()
                        .ok_or_else(|| anyhow::anyhow!("--gate needs a value"))?
                        .parse()?;
                }
                "--artifacts" => {
                    a.artifacts = it
                        .next()
                        .ok_or_else(|| anyhow::anyhow!("--artifacts needs a value"))?
                        .clone();
                }
                "--cores" => {
                    a.cores = it
                        .next()
                        .ok_or_else(|| anyhow::anyhow!("--cores needs a value"))?
                        .parse()?;
                    if a.cores == 0 {
                        anyhow::bail!("--cores must be >= 1");
                    }
                }
                "--batch" => {
                    a.batch = it
                        .next()
                        .ok_or_else(|| anyhow::anyhow!("--batch needs a value"))?
                        .parse()?;
                    if a.batch == 0 {
                        anyhow::bail!("--batch must be >= 1");
                    }
                }
                "--pipeline" => a.pipeline = true,
                "--json" => a.json = true,
                "--no-cache" => a.no_cache = true,
                "--no-rotation" => a.no_rotation = true,
                "--verify-programs" => a.verify_programs = true,
                "--pool-mode" => {
                    let m: PoolMode = it
                        .next()
                        .ok_or_else(|| anyhow::anyhow!("--pool-mode needs a value"))?
                        .parse()
                        .map_err(|e: String| anyhow::anyhow!("{e}"))?;
                    a.pipeline = m == PoolMode::Pipelined;
                }
                "--shard" => {
                    a.shard = it
                        .next()
                        .ok_or_else(|| anyhow::anyhow!("--shard needs a value"))?
                        .parse()
                        .map_err(|e: String| anyhow::anyhow!("{e}"))?;
                }
                "--bus" => {
                    a.bus = it
                        .next()
                        .ok_or_else(|| anyhow::anyhow!("--bus needs a value"))?
                        .parse()
                        .map_err(|e: String| anyhow::anyhow!("{e}"))?;
                }
                "--stage-cores" => {
                    a.stage_cores = it
                        .next()
                        .ok_or_else(|| anyhow::anyhow!("--stage-cores needs a value"))?
                        .parse()
                        .map_err(|e: String| anyhow::anyhow!("{e}"))?;
                }
                "--inject" => {
                    let plan: FaultPlan = it
                        .next()
                        .ok_or_else(|| {
                            anyhow::anyhow!("--inject needs a seed[:rate[:kinds]] spec")
                        })?
                        .parse()
                        .map_err(|e: String| anyhow::anyhow!("{e}"))?;
                    a.inject = Some(plan);
                }
                "-h" | "--help" => {
                    a.command = "help".into();
                    return Ok(a);
                }
                other if a.command.is_empty() => a.command = other.to_string(),
                other => a.positional.push(other.to_string()),
            }
        }
        if a.command.is_empty() {
            a.command = "help".into();
        }
        Ok(a)
    }

    /// Map parsed flags onto an engine configuration.
    pub fn engine_config(&self) -> EngineConfig {
        let mode = if self.full {
            crate::coordinator::ExecMode::FullCycle
        } else {
            crate::coordinator::ExecMode::TileAnalytic
        };
        let cfg = EngineConfig::new()
            .mode(mode)
            .gate_bits(self.gate_bits)
            .cores(self.cores)
            .batch(self.batch)
            .pool_mode(if self.pipeline { PoolMode::Pipelined } else { PoolMode::FanOut })
            .shard(self.shard)
            .bus(self.bus)
            .stage_cores(self.stage_cores.clone())
            .plan_cache(!self.no_cache)
            .dma_rotation(!self.no_rotation);
        match self.inject {
            Some(plan) => cfg.faults(plan),
            None => cfg,
        }
    }
}

pub fn main_with(argv: &[String]) -> Result<i32> {
    let args = Args::parse(argv)?;
    if args.verify_programs {
        // opt release builds into verify-on-insert (debug builds always
        // verify); see `isa::analysis::enabled`
        std::env::set_var("ANALYZE", "1");
    }
    let cfg = args.engine_config();
    match args.command.as_str() {
        "help" => {
            print!("{USAGE}");
            Ok(0)
        }
        "table1" => {
            print!("{}", report::table1());
            Ok(0)
        }
        "fig3b" => {
            print!("{}", report::fig3b());
            Ok(0)
        }
        "fig3c" => {
            print!("{}", report::fig3c()?);
            Ok(0)
        }
        "table2" => {
            print!("{}", report::table2(&cfg)?);
            Ok(0)
        }
        "util" => {
            print!("{}", report::util_table(&cfg)?);
            Ok(0)
        }
        "run" => {
            let net = args
                .positional
                .first()
                .map(String::as_str)
                .unwrap_or("alexnet");
            if args.pipeline {
                print!("{}", report::streaming(net, &cfg)?);
            } else if args.batch > 1 {
                print!("{}", report::throughput(net, &cfg)?);
            } else if args.cores > 1 {
                print!("{}", report::run_net_mc(net, &cfg)?);
            } else {
                print!("{}", report::run_net(net, &cfg)?);
            }
            Ok(0)
        }
        "run-multi" => {
            // default episode: the two full nets contending for one bus
            let tenants: Vec<String> = if args.positional.is_empty() {
                vec!["vgg16-full:2".into(), "alexnet-full:1".into()]
            } else {
                args.positional.clone()
            };
            print!("{}", report::run_multi(&tenants, &args)?);
            Ok(0)
        }
        "golden" => {
            let (text, ok) = report::golden(&args.artifacts)?;
            print!("{text}");
            Ok(if ok { 0 } else { 1 })
        }
        "lint" => {
            let net = args
                .positional
                .first()
                .map(String::as_str)
                .unwrap_or("alexnet-full");
            let (text, ok) = report::lint(net, args.json)?;
            print!("{text}");
            Ok(if ok { 0 } else { 1 })
        }
        "asm" => {
            let path = args
                .positional
                .first()
                .ok_or_else(|| anyhow::anyhow!("asm needs a file"))?;
            let src = std::fs::read_to_string(path)?;
            let prog = crate::isa::asm::assemble(&src)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            println!(
                "{} bundles, {} bytes encoded ({} byte PM)",
                prog.len(),
                prog.encoded_size(),
                crate::mem::PM_BYTES
            );
            print!("{}", crate::isa::disasm::program(&prog));
            Ok(0)
        }
        other => {
            eprintln!("unknown command `{other}`\n{USAGE}");
            Ok(2)
        }
    }
}
