//! Table/figure regenerators (Table I, Table II, Fig. 3b, Fig. 3c,
//! per-layer utilization) — used by the CLI and the bench targets.
//! Everything executes through [`Engine`]; the run shape (cores, batch,
//! shard policy, bus model, mode) comes in as an [`EngineConfig`].

use std::sync::Arc;

use anyhow::Result;

use crate::baselines::published;
use crate::coordinator::{
    run_multi_streaming, BatchedResult, BusModel, Engine, EngineConfig, FaultPlan, FaultReport,
    NetLayer, NetworkResult, PipelineResult, PlanCache, PoolMode, TenantRun,
};
use crate::energy::{area, power};
use crate::model::{alexnet_conv, alexnet_full, conv_stack, vgg16_conv, vgg16_full};
use crate::util::table::{bar_chart, Table};
use crate::util::XorShift;

/// Build an engine for `cfg` (one per report run: the pool is fresh,
/// the weight stream is the config's seed).
fn engine_for(cfg: &EngineConfig) -> Engine {
    cfg.clone().build()
}

/// Run a layer list (conv stack or full net) with synthetic weights;
/// returns per-layer results. The engine's deterministic per-layer
/// xorshift draws make MAC totals identical across core counts and
/// shard policies.
pub fn bench_network(
    name: &str,
    layers: &[NetLayer],
    cfg: &EngineConfig,
) -> Result<NetworkResult> {
    let Some(first) = layers.first() else {
        return Ok(NetworkResult { name: name.into(), ..Default::default() });
    };
    let input = vec![0i16; first.op().in_elems()];
    engine_for(cfg)
        .run_network(name, layers, &input)
        .map_err(|e| anyhow::anyhow!("{e}"))
}

/// `convaix run <net> --cores N` — per-layer multi-core breakdown with
/// kind labels and per-core utilization and speedup columns.
pub fn run_net_mc(net: &str, cfg: &EngineConfig) -> Result<String> {
    let layers = net_layers(net)?;
    // the serial baseline always runs fault-free: its cycle column is
    // the undisturbed cost, and (outputs being core-count-invariant by
    // design) it doubles as the clean reference for an injected run
    let mut serial_cfg = cfg.clone().cores(1).batch(1);
    serial_cfg.faults = None;
    let serial = bench_network(net, &layers, &serial_cfg)?;
    let mut engine = engine_for(cfg);
    let input = vec![0i16; layers[0].op().in_elems()];
    let sharded = engine
        .run_network(net, &layers, &input)
        .map_err(|e| anyhow::anyhow!("{e}"))?;

    let mut t = Table::new(
        &format!(
            "{net} sharded across {} ConvAix cores ({:?} shards, {:?} bus)",
            cfg.cores, cfg.shard, cfg.bus
        ),
        &["Layer", "Kind", "1-core cyc", "Makespan cyc", "Speedup", "Par eff", "Util/core"],
    );
    for ((d, l1), lm) in layers.iter().zip(&serial.layers).zip(&sharded.layers) {
        let speedup = l1.cycles as f64 / lm.cycles.max(1) as f64;
        t.row(&[
            lm.name.to_string(),
            d.kind().into(),
            l1.cycles.to_string(),
            lm.cycles.to_string(),
            format!("{:.2}x", speedup),
            format!("{:.2}", lm.parallel_efficiency()),
            // LayerResult::utilization is per core (divides by the
            // shard's core count), so this column stays <= 1.0
            format!("{:.3}", lm.utilization()),
        ]);
    }
    let total_speedup = serial.cycles() as f64 / sharded.cycles().max(1) as f64;
    let mut s = t.render();
    s.push_str(&format!(
        "{net}: {:.2} ms on {} cores vs {:.2} ms on 1 core — {:.2}x cycle-level speedup\n",
        sharded.time_ms(),
        cfg.cores,
        serial.time_ms(),
        total_speedup,
    ));
    if let Some(plan) = cfg.faults {
        // layer-level degrade waste is already folded into the layers'
        // fault_recovery_cycles by the engine, so the report sums those
        s.push_str(&fault_lines(&FaultReport {
            retries: sharded.fault_retries(),
            recovery_cycles: sharded.fault_recovery_cycles(),
            blacklisted_cores: engine.blacklisted_cores().to_vec(),
            degrade_waste_cycles: 0,
        }));
        s.push_str(&verify_against_clean(
            &plan,
            net,
            std::slice::from_ref(&sharded),
            std::slice::from_ref(&serial),
        )?);
    }
    Ok(s)
}

/// `convaix run <net> --batch B [--cores N]` — batched throughput mode:
/// B frames fanned out over the core pool.
pub fn throughput(net: &str, cfg: &EngineConfig) -> Result<String> {
    let layers = net_layers(net)?;
    let in_elems = layers[0].op().in_elems();
    let mut rng = XorShift::new(0xBA7C4);
    let inputs: Vec<Vec<i16>> =
        (0..cfg.batch).map(|_| rng.i16_vec(in_elems, -2000, 2000)).collect();
    let mut engine = engine_for(cfg);
    let br = engine
        .run_batched(net, &layers, &inputs)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let mut s = throughput_report(&br, cfg);
    s.push_str(&cache_line(&engine));
    if let Some(plan) = cfg.faults {
        let mut clean_cfg = cfg.clone();
        clean_cfg.faults = None;
        let clean = engine_for(&clean_cfg)
            .run_batched(net, &layers, &inputs)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        s.push_str(&verify_against_clean(&plan, net, &br.frames, &clean.frames)?);
    }
    Ok(s)
}

/// One-line plan-cache summary for the serving reports: how much of
/// the run's layer setup was compile-once reuse.
fn cache_line(engine: &Engine) -> String {
    let cs = engine.cache_stats();
    format!(
        "plan cache: {} hits / {} misses ({} conv + {} pool entries{})\n",
        cs.hits,
        cs.misses,
        cs.conv_entries,
        cs.pool_entries,
        if engine.plan_cache().is_enabled() { "" } else { "; cache disabled" },
    )
}

/// Fault-campaign summary lines shared by every serving report: what
/// the detection machinery caught, what the recovery priced in, and —
/// when cores exhausted their budgets — the degraded topology.
fn fault_lines(fr: &FaultReport) -> String {
    let mut s = format!(
        "faults: {} detected-and-retried transfer(s), {} recovery cycle(s) \
         ({:.3} ms) priced into the run\n",
        fr.retries,
        fr.recovery_cycles,
        fr.recovery_cycles as f64 / crate::CLOCK_HZ as f64 * 1e3,
    );
    if fr.degraded() {
        s.push_str(&format!(
            "degraded onto survivors: core(s) {:?} blacklisted",
            fr.blacklisted_cores,
        ));
        if fr.degrade_waste_cycles > 0 {
            s.push_str(&format!(
                ", {} discarded re-execution cycle(s) absorbed into the makespan",
                fr.degrade_waste_cycles,
            ));
        }
        s.push('\n');
    }
    s
}

/// Clean-reference bit-identity check. `frames` came from an injected
/// run; `clean` is the same run with the fault plan stripped. With
/// detection on, any divergence means recovery failed to mask an
/// injected fault — that's a bug, so the report errors out (nonzero
/// CLI exit; the CI fault smoke step leans on this). With detection
/// off (`silent`), corruption is the expected observation, so the
/// divergence is reported rather than fatal.
fn verify_against_clean(
    plan: &FaultPlan,
    what: &str,
    frames: &[NetworkResult],
    clean: &[NetworkResult],
) -> Result<String> {
    let identical = frames.len() == clean.len()
        && frames.iter().zip(clean).all(|(a, b)| {
            a.layers.len() == b.layers.len()
                && a.layers
                    .iter()
                    .zip(&b.layers)
                    .all(|(x, y)| x.out == y.out && x.macs == y.macs)
        });
    if plan.detect {
        if !identical {
            anyhow::bail!(
                "fault campaign seed {:#x}: {what} outputs DIVERGED from the fault-free \
                 reference despite detection — recovery failed to mask an injected fault",
                plan.seed,
            );
        }
        Ok(format!(
            "fault campaign seed {:#x}: outputs verified bit-identical to the \
             fault-free run\n",
            plan.seed,
        ))
    } else {
        Ok(format!(
            "silent campaign seed {:#x} (detection off): outputs {} the fault-free run\n",
            plan.seed,
            if identical { "match" } else { "DIVERGED from" },
        ))
    }
}

/// Render a [`BatchedResult`] as the throughput table + summary lines.
/// `Useful frac` is private-bandwidth busy work over the makespan, so a
/// shared-bus run reports how much of the window was *work* rather than
/// bus wait (never above 1.0).
pub fn throughput_report(br: &BatchedResult, cfg: &EngineConfig) -> String {
    let mut t = Table::new(
        &format!(
            "{}: batch {} over {} core(s), {:?} bus — frame fan-out",
            br.name,
            br.frames.len(),
            cfg.cores,
            br.bus,
        ),
        &["Core", "Occupied cycles", "Useful frac", "Frames"],
    );
    let util = br.core_utilization();
    let mut frames_per_core = vec![0usize; br.core_cycles.len()];
    for &c in &br.frame_core {
        frames_per_core[c] += 1;
    }
    for (c, &busy) in br.core_cycles.iter().enumerate() {
        t.row(&[
            c.to_string(),
            busy.to_string(),
            format!("{:.3}", util[c]),
            frames_per_core[c].to_string(),
        ]);
    }
    let mut s = t.render();
    s.push_str(&format!(
        "batch latency {:.2} ms, throughput {:.1} frames/s, speedup {:.2}x over 1 core \
         (serial {:.2} ms)\n",
        br.makespan_cycles() as f64 / crate::CLOCK_HZ as f64 * 1e3,
        br.throughput_fps(),
        br.speedup(),
        br.serial_cycles() as f64 / crate::CLOCK_HZ as f64 * 1e3,
    ));
    if br.faults.any() {
        s.push_str(&fault_lines(&br.faults));
    }
    s
}

/// `convaix run <net> --pipeline [--cores N --batch B]` — layer-
/// pipelined streaming: the network cut into N contiguous stages, B
/// frames streamed through them. On the full nets the DMA-bound FC
/// tail lands in its own stage(s) — see the stage table.
pub fn streaming(net: &str, cfg: &EngineConfig) -> Result<String> {
    let layers = net_layers(net)?;
    let in_elems = layers[0].op().in_elems();
    let mut rng = XorShift::new(0xBA7C4);
    let inputs: Vec<Vec<i16>> =
        (0..cfg.batch).map(|_| rng.i16_vec(in_elems, -2000, 2000)).collect();
    let mut engine = engine_for(cfg);
    let pr = engine
        .run_streaming(net, &layers, &inputs)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let mut s = streaming_report(&pr, &layers, cfg);
    s.push_str(&cache_line(&engine));
    if let Some(plan) = cfg.faults {
        let mut clean_cfg = cfg.clone();
        clean_cfg.faults = None;
        let clean = engine_for(&clean_cfg)
            .run_streaming(net, &layers, &inputs)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        s.push_str(&verify_against_clean(&plan, net, &pr.frames, &clean.frames)?);
    }
    Ok(s)
}

/// Render a [`PipelineResult`] as the per-stage table + summary lines.
/// `Useful frac` is private-bandwidth stage time over the stream
/// makespan — the occupied-vs-useful split, never above 1.0. The
/// `Cores` column shows each stage's core group: `1` for legacy
/// one-core stages, `k (policy)` when the stage shards its layers
/// across a k-core group.
pub fn streaming_report(pr: &PipelineResult, layers: &[NetLayer], cfg: &EngineConfig) -> String {
    let mut t = Table::new(
        &format!(
            "{}: {} frame(s) streamed through {} pipeline stage(s), {:?} bus",
            pr.name,
            pr.frames.len(),
            pr.stages.len(),
            pr.bus,
        ),
        &["Stage", "Layers", "Cores", "Occupied cycles", "Useful frac"],
    );
    let util = pr.stage_utilization();
    for (s, &(l0, l1)) in pr.stages.iter().enumerate() {
        let span = if l1 - l0 == 1 {
            layers[l0].name().to_string()
        } else {
            format!("{}..{}", layers[l0].name(), layers[l1 - 1].name())
        };
        let k = pr.stage_cores.get(s).copied().unwrap_or(1);
        let group = if k == 1 {
            "1".to_string()
        } else {
            format!("{k} ({:?})", cfg.shard)
        };
        t.row(&[
            s.to_string(),
            span,
            group,
            pr.stage_cycles[s].to_string(),
            format!("{:.3}", util[s]),
        ]);
    }
    let mut s = t.render();
    s.push_str(&format!(
        "steady state {:.1} frames/s (one frame per {} cycles once full); \
         fill {:.2} ms, drain {:.2} ms\n\
         stream of {}: {:.2} ms end to end, {:.1} frames/s, {:.2}x over 1 core \
         ({} core(s) configured)\n",
        pr.steady_state_fps(),
        pr.steady_interval_cycles,
        pr.fill_cycles as f64 / crate::CLOCK_HZ as f64 * 1e3,
        pr.drain_cycles as f64 / crate::CLOCK_HZ as f64 * 1e3,
        pr.frames.len(),
        pr.makespan_cycles as f64 / crate::CLOCK_HZ as f64 * 1e3,
        pr.throughput_fps(),
        pr.speedup(),
        cfg.cores,
    ));
    if pr.faults.any() {
        s.push_str(&fault_lines(&pr.faults));
    }
    s
}

/// `convaix run-multi <net[:cores[:gate]]>...` — multi-tenant serving.
/// Every tenant pipelines its own network over its own engine's cores
/// (partitioned per `--stage-cores`), all tenants contend for ONE
/// Shared external bus, and all engines reuse one compile-once plan
/// cache. Per-tenant rows are priced under the combined bus divisor,
/// so a tenant's makespan here is >= its isolated `run --pipeline`
/// makespan.
pub fn run_multi(tenants: &[String], args: &super::Args) -> Result<String> {
    struct Spec {
        name: String,
        layers: Vec<NetLayer>,
        inputs: Vec<Vec<i16>>,
        cores: usize,
        gate: u8,
    }
    let mut specs = Vec::new();
    for (i, spec) in tenants.iter().enumerate() {
        let mut parts = spec.split(':');
        let net = parts.next().unwrap_or_default();
        let layers = net_layers(net)?;
        let cores = match parts.next() {
            Some(c) => c
                .parse::<usize>()
                .map_err(|_| anyhow::anyhow!("tenant `{spec}`: bad core count `{c}`"))?,
            None => 1,
        };
        if cores == 0 {
            anyhow::bail!("tenant `{spec}`: core count must be >= 1");
        }
        let gate = match parts.next() {
            Some(g) => g
                .parse::<u8>()
                .map_err(|_| anyhow::anyhow!("tenant `{spec}`: bad gate bits `{g}`"))?,
            None => args.gate_bits,
        };
        if let Some(junk) = parts.next() {
            anyhow::bail!("tenant `{spec}`: trailing `:{junk}` (want net[:cores[:gate]])");
        }
        let in_elems = layers[0].op().in_elems();
        let mut rng = XorShift::new(0xBA7C4 + i as u64);
        let inputs: Vec<Vec<i16>> =
            (0..args.batch).map(|_| rng.i16_vec(in_elems, -2000, 2000)).collect();
        specs.push(Spec { name: net.to_string(), layers, inputs, cores, gate });
    }

    // one compile-once cache for the whole zoo: tenants serving the
    // same shapes reuse each other's compiled layers
    let cache = Arc::new(if args.no_cache { PlanCache::disabled() } else { PlanCache::new() });
    let mode = if args.full {
        crate::coordinator::ExecMode::FullCycle
    } else {
        crate::coordinator::ExecMode::TileAnalytic
    };
    let build_engines = |inject: Option<FaultPlan>| -> Vec<Engine> {
        specs
            .iter()
            .enumerate()
            .map(|(i, sp)| {
                let cfg = EngineConfig::new()
                    .mode(mode)
                    .gate_bits(sp.gate)
                    .cores(sp.cores)
                    .batch(args.batch)
                    .pool_mode(PoolMode::Pipelined)
                    .shard(args.shard)
                    // run-multi IS the shared-bus story; --bus is ignored
                    .bus(BusModel::Shared)
                    .stage_cores(args.stage_cores.clone())
                    .dma_rotation(!args.no_rotation)
                    .seed(0xC0DE + i as u64);
                let cfg = match inject {
                    Some(plan) => cfg.faults(plan),
                    None => cfg,
                };
                Engine::new_with_cache(cfg, cache.clone())
            })
            .collect()
    };
    let mut engines = build_engines(args.inject);
    let mut runs: Vec<TenantRun<'_>> = engines
        .iter_mut()
        .zip(&specs)
        .map(|(engine, sp)| TenantRun {
            engine,
            name: &sp.name,
            layers: &sp.layers,
            inputs: &sp.inputs,
        })
        .collect();
    let mt = run_multi_streaming(&mut runs).map_err(|e| anyhow::anyhow!("{e}"))?;
    drop(runs);

    let mut t = Table::new(
        &format!(
            "multi-tenant serving: {} tenant(s) over {} core(s), one Shared bus \
             (combined divisor {}, {} DMA-bound core(s) at the fixed point)",
            mt.tenants.len(),
            mt.total_cores(),
            mt.divisor,
            mt.contenders,
        ),
        &["Tenant", "Net", "Cores", "Stage plan", "Gate", "Steady f/s", "Makespan ms", "Bus share"],
    );
    let shares = mt.bus_shares();
    for (i, pr) in mt.tenants.iter().enumerate() {
        let plan =
            pr.stage_cores.iter().map(ToString::to_string).collect::<Vec<_>>().join("+");
        t.row(&[
            i.to_string(),
            pr.name.clone(),
            mt.tenant_cores[i].to_string(),
            plan,
            specs[i].gate.to_string(),
            format!("{:.1}", pr.steady_state_fps()),
            format!("{:.2}", pr.makespan_cycles as f64 / crate::CLOCK_HZ as f64 * 1e3),
            format!("{:.1} %", shares[i] * 100.0),
        ]);
    }
    let mut s = t.render();
    s.push_str(&format!(
        "aggregate {:.1} frames/s steady across tenants; episode ends at {:.2} ms \
         (slowest tenant's stream of {} frame(s))\n",
        mt.aggregate_steady_fps(),
        mt.makespan_cycles() as f64 / crate::CLOCK_HZ as f64 * 1e3,
        args.batch,
    ));
    s.push_str(&cache_line(&engines[0]));
    if mt.faults.any() {
        s.push_str(&fault_lines(&mt.faults));
    }
    if let Some(plan) = args.inject {
        let mut clean_engines = build_engines(None);
        let mut clean_runs: Vec<TenantRun<'_>> = clean_engines
            .iter_mut()
            .zip(&specs)
            .map(|(engine, sp)| TenantRun {
                engine,
                name: &sp.name,
                layers: &sp.layers,
                inputs: &sp.inputs,
            })
            .collect();
        let clean = run_multi_streaming(&mut clean_runs).map_err(|e| anyhow::anyhow!("{e}"))?;
        drop(clean_runs);
        for (pr, cp) in mt.tenants.iter().zip(&clean.tenants) {
            s.push_str(&verify_against_clean(&plan, &pr.name, &pr.frames, &cp.frames)?);
        }
    }
    Ok(s)
}

/// One structured `lint` finding — the unit of `--json` output.
struct LintFinding {
    layer: String,
    shard: String,
    pass: &'static str,
    kind: String,
    location: String,
}

/// Minimal JSON string escaping (no serde in the offline vendor set).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// `convaix lint <net> [--json]` — walk every layer of the net, compile
/// every task program it can execute (the solo per-layer shapes plus
/// every sub-layer shape each shard policy would produce on a 4-core
/// pool, at gate bits 8 and 16), run the static verifier
/// (`isa::analysis` passes 1–3), the symbolic memory-access verifier
/// (pass 5, at the extremal in-band row ABIs with the plan-derived
/// region map — in BOTH rotation phases when the plan double-buffers,
/// so a compute access into the in-flight prefetch shadow is flagged
/// as a DMA race) and the static cycle analyzer over each program, and
/// report per-program verdicts. Returns `(report, all_clean)`.
///
/// With `json` the report is one JSON document: `{net, programs,
/// clean, findings}` where `findings` holds one object per finding —
/// `{net, layer, shard, pass, kind, location}`.
///
/// Identical shapes reached through different policies/gates dedup via
/// the plan cache (same `Arc` = one row). In debug builds the cache
/// itself verifies on insert and a dirty program aborts compilation;
/// in release builds `lint` is the explicit check.
pub fn lint(net: &str, json: bool) -> Result<(String, bool)> {
    use std::collections::BTreeSet;

    use crate::codegen::{conv, pool, TaskFlavor};
    use crate::coordinator::ShardPolicy;
    use crate::isa::analysis::{self, memory, AbiSpec, FindingKind};

    let layers = net_layers(net)?;
    let cache = PlanCache::new();
    let mut t = Table::new(
        &format!("{net}: static verification of all task programs"),
        &["Layer", "Kind", "Gate", "Task", "Bundles", "Static cycles", "Verdict"],
    );
    let mut findings = String::new();
    let mut structured: Vec<LintFinding> = Vec::new();
    let mut n_programs = 0usize;
    let mut seen: BTreeSet<usize> = BTreeSet::new();

    // "conv1/OcTile0" → ("conv1", "OcTile0"); solo layers have no shard
    let split = |label: &str| -> (String, String) {
        match label.split_once('/') {
            Some((l, s)) => (l.to_string(), s.to_string()),
            None => (label.to_string(), "-".into()),
        }
    };

    let mut lint_one = |label: &str, layer: &NetLayer, gate: u8| -> Result<()> {
        let (lname, shard) = split(label);
        let dense = match layer {
            NetLayer::Conv(l) => Some(l.per_group()),
            NetLayer::Fc(l) => Some(l.as_conv()),
            NetLayer::Pool(_) => None,
        };
        if let Some(dense) = dense {
            let cc =
                cache.conv(&dense, gate, true).map_err(|e| anyhow::anyhow!("{label}: {e}"))?;
            if !seen.insert(Arc::as_ptr(&cc) as usize) {
                return Ok(());
            }
            let timings = cc.analyzer_timing();
            let mut progs: Vec<_> = cc.programs().collect();
            progs.sort_by_key(|(k, _)| format!("{k:?}"));
            for (key, pm) in progs {
                n_programs += 1;
                let mut rep = analysis::verify(pm.program(), &AbiSpec::conv());
                // pass 5: memory — extremal rows suffice (accesses are
                // affine in r2, see `codegen::compiled`), checked in
                // BOTH rotation phases when the plan carries a shadow:
                // phase A runs at the primary ABI with the shadow as
                // the no-access prefetch target, phase B at the
                // shadow-slot ABI with the primary pair inactive.
                let flavor = TaskFlavor { first_slice: key.1, last_slice: key.2 };
                let spec = conv::mem_spec(&cc.plan, flavor);
                let spec_b = conv::mem_spec_phase_b(&cc.plan, flavor);
                let mut mem_seen: BTreeSet<(FindingKind, usize)> = BTreeSet::new();
                let last_row = cc.plan.band_rows.saturating_sub(1);
                let rows = if last_row == 0 { vec![0] } else { vec![0, last_row] };
                for &oh_local in &rows {
                    let mut phases = vec![(cc.abi_env_for_row(oh_local), &spec)];
                    if let (Some(env_b), Some(sb)) =
                        (cc.abi_env_for_row_rot(oh_local), spec_b.as_ref())
                    {
                        phases.push((env_b, sb));
                    }
                    for (env, phase_spec) in phases {
                        match memory::check(pm.program(), &env, phase_spec) {
                            Ok(mrep) => {
                                for f in mrep.findings {
                                    if mem_seen.insert((f.kind, f.pc)) {
                                        rep.findings.push(f);
                                    }
                                }
                            }
                            Err(e) => {
                                findings.push_str(&format!(
                                    "{label} {key:?}: memory walk failed: {e}\n"
                                ));
                                structured.push(LintFinding {
                                    layer: lname.clone(),
                                    shard: shard.clone(),
                                    pass: "memory",
                                    kind: "walk-error".into(),
                                    location: format!("task {key:?}"),
                                });
                            }
                        }
                    }
                }
                rep.findings.sort_by(|a, b| (a.pc, a.kind).cmp(&(b.pc, b.kind)));
                let cycles = match &timings[key] {
                    Ok(st) => st.cycles.to_string(),
                    Err(e) => {
                        findings
                            .push_str(&format!("{label} {key:?}: static prediction failed: {e}\n"));
                        structured.push(LintFinding {
                            layer: lname.clone(),
                            shard: shard.clone(),
                            pass: "predict",
                            kind: "predict-error".into(),
                            location: format!("task {key:?}"),
                        });
                        "-".into()
                    }
                };
                for f in &rep.findings {
                    structured.push(LintFinding {
                        layer: lname.clone(),
                        shard: shard.clone(),
                        pass: f.kind.pass(),
                        kind: f.kind.to_string(),
                        location: format!("task {key:?} bundle {}", f.pc),
                    });
                }
                let verdict = if rep.is_clean() {
                    "clean".to_string()
                } else {
                    findings.push_str(&format!("-- {label} task {key:?} --\n{rep}\n"));
                    format!("{} finding(s)", rep.findings.len())
                };
                t.row(&[
                    label.to_string(),
                    layer.kind().into(),
                    gate.to_string(),
                    format!("{key:?}"),
                    pm.program().len().to_string(),
                    cycles,
                    verdict,
                ]);
            }
        } else if let NetLayer::Pool(l) = layer {
            let cp = cache.pool(l, true).map_err(|e| anyhow::anyhow!("{label}: {e}"))?;
            if !seen.insert(Arc::as_ptr(&cp) as usize) {
                return Ok(());
            }
            n_programs += 1;
            let mut rep = analysis::verify(cp.pm.program(), &AbiSpec::pool());
            let mut phases = vec![(cp.abi_env(), pool::mem_spec(&cp.plan))];
            if let (Some(env_b), Some(spec_b)) =
                (cp.abi_env_rot(), pool::mem_spec_phase_b(&cp.plan))
            {
                phases.push((env_b, spec_b));
            }
            for (env, spec) in &phases {
                match memory::check(cp.pm.program(), env, spec) {
                    Ok(mrep) => rep.findings.extend(mrep.findings),
                    Err(e) => {
                        findings.push_str(&format!("{label}: memory walk failed: {e}\n"));
                        structured.push(LintFinding {
                            layer: lname.clone(),
                            shard: shard.clone(),
                            pass: "memory",
                            kind: "walk-error".into(),
                            location: "task row".into(),
                        });
                    }
                }
            }
            rep.findings.sort_by(|a, b| (a.pc, a.kind).cmp(&(b.pc, b.kind)));
            let cycles = match cp.analyzer_timing() {
                Ok(st) => st.cycles.to_string(),
                Err(e) => {
                    findings.push_str(&format!("{label}: static prediction failed: {e}\n"));
                    structured.push(LintFinding {
                        layer: lname.clone(),
                        shard: shard.clone(),
                        pass: "predict",
                        kind: "predict-error".into(),
                        location: "task row".into(),
                    });
                    "-".into()
                }
            };
            for f in &rep.findings {
                structured.push(LintFinding {
                    layer: lname.clone(),
                    shard: shard.clone(),
                    pass: f.kind.pass(),
                    kind: f.kind.to_string(),
                    location: format!("task row bundle {}", f.pc),
                });
            }
            let verdict = if rep.is_clean() {
                "clean".to_string()
            } else {
                findings.push_str(&format!("-- {label} --\n{rep}\n"));
                format!("{} finding(s)", rep.findings.len())
            };
            t.row(&[
                label.to_string(),
                layer.kind().into(),
                gate.to_string(),
                "row".into(),
                cp.pm.program().len().to_string(),
                cycles,
                verdict,
            ]);
        }
        Ok(())
    };

    for gate in [8u8, 16] {
        for layer in &layers {
            let name = layer.name();
            lint_one(name, layer, gate)?;
            // every sub-layer shape a sharded run could compile
            let x = vec![0i16; layer.op().in_elems()];
            for policy in [ShardPolicy::OcTile, ShardPolicy::RowBand, ShardPolicy::Auto] {
                for (i, sh) in layer.op().shard(&x, policy, 4).iter().enumerate() {
                    lint_one(&format!("{name}/{policy:?}{i}"), &sh.sub, gate)?;
                }
            }
        }
    }

    let n_findings = structured.len();
    let ok = n_findings == 0;
    let s = if json {
        let mut s = String::from("{\n");
        s.push_str(&format!("  \"net\": {},\n", json_str(net)));
        s.push_str(&format!("  \"programs\": {n_programs},\n"));
        s.push_str(&format!("  \"clean\": {ok},\n"));
        s.push_str("  \"findings\": [");
        for (i, f) in structured.iter().enumerate() {
            s.push_str(if i == 0 { "\n" } else { ",\n" });
            s.push_str(&format!(
                "    {{\"net\": {}, \"layer\": {}, \"shard\": {}, \"pass\": {}, \
                 \"kind\": {}, \"location\": {}}}",
                json_str(net),
                json_str(&f.layer),
                json_str(&f.shard),
                json_str(f.pass),
                json_str(&f.kind),
                json_str(&f.location),
            ));
        }
        if !structured.is_empty() {
            s.push_str("\n  ");
        }
        s.push_str("]\n}\n");
        s
    } else {
        let mut s = t.render();
        s.push_str(&findings);
        s.push_str(&format!(
            "{net}: {n_programs} program(s) verified (structural/dataflow/resource/memory + \
             cycle prediction) across gates {{8, 16}} and all shard policies — {}\n",
            if ok { "all clean".to_string() } else { format!("{n_findings} finding(s)") },
        ));
        s
    };
    Ok((s, ok))
}

fn net_layers(net: &str) -> Result<Vec<NetLayer>> {
    match net {
        "alexnet" => Ok(conv_stack(alexnet_conv())),
        "vgg16" | "vgg" => Ok(conv_stack(vgg16_conv())),
        "alexnet-full" => Ok(alexnet_full()),
        "vgg16-full" | "vgg-full" => Ok(vgg16_full()),
        other => anyhow::bail!(
            "unknown network `{other}` (alexnet | vgg16 | alexnet-full | vgg16-full)"
        ),
    }
}

/// Table I — processor specification.
pub fn table1() -> String {
    let mut t = Table::new(
        "TABLE I: PROCESSOR SPECIFICATION (measured model vs paper)",
        &["Parameter", "This model", "Paper"],
    );
    let rows: Vec<(&str, String, &str)> = vec![
        ("Technology", "28nm model (calibrated)".into(), "TSMC 28nm SVT 1P8M"),
        ("Core voltage", "1.0 V".into(), "1.0 V"),
        ("Clock frequency", format!("{} MHz", crate::CLOCK_HZ / 1_000_000), "400 MHz"),
        ("Gate count (logic)", format!("{:.0} kGE", area::LOGIC_KGE_TOTAL), "1293 kGE"),
        ("On-chip SRAM", format!("{} KByte (128 data + 16 instr)", area::SRAM_KBYTES), "128+16 KByte"),
        ("# MAC units", format!("{} (3 x 4 x 16)", crate::PEAK_MACS_PER_CYCLE), "192 (3 x 4 x 16)"),
        ("Registers & pipe regs", format!("{} Byte", area::REGISTER_BYTES), "3648 Byte"),
        ("Peak throughput", format!("{:.1} GOP/s", crate::PEAK_GOPS), "153.6 GOP/s"),
        ("Arithmetic precision", "16b fixed (+gating)".into(), "16b fixed (+gating)"),
    ];
    for (p, m, pa) in rows {
        t.row(&[p.to_string(), m, pa.to_string()]);
    }
    t.render()
}

/// Fig. 3b — logic area breakdown.
pub fn fig3b() -> String {
    let items: Vec<(String, f64)> = area::area_breakdown()
        .iter()
        .map(|i| (format!("{} ({:.0} kGE)", i.name, i.kge), i.kge))
        .collect();
    let mut s = bar_chart("Fig. 3b: processor area breakdown (w/o SRAMs)", &items, 40);
    s.push_str(&format!(
        "total logic: {:.0} kGE (paper: 1293); SRAM macros: {:.0} % of chip area (paper: 63 %)\n",
        area::LOGIC_KGE_TOTAL,
        area::SRAM_AREA_FRACTION * 100.0
    ));
    s
}

/// Fig. 3c — power distribution for AlexNet conv3 at 8-bit gating.
pub fn fig3c() -> Result<String> {
    let l = alexnet_conv().into_iter().nth(2).expect("conv3");
    let mut rng = XorShift::new(3);
    let x = vec![0i16; l.ic * l.ih * l.iw];
    let w = rng.i16_vec(l.oc * (l.ic / l.groups) * l.fh * l.fw, -128, 128);
    let b = rng.i32_vec(l.oc, -1000, 1000);
    let cfg = EngineConfig::new()
        .mode(crate::coordinator::ExecMode::TileAnalytic)
        .gate_bits(8);
    let r = engine_for(&cfg)
        .run_conv_layer(&l, &x, &w, &b)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let p = power::network_power(&r.stats, r.cycles as f64 / crate::CLOCK_HZ as f64);
    let (va, me, ct) = p.fractions();
    let items = vec![
        (format!("vector ALUs ({:.1} mW)", p.valu_mw), p.valu_mw),
        (format!("DM + RF + line buffer ({:.1} mW)", p.mem_mw), p.mem_mw),
        (format!("control + fetch + scalar ({:.1} mW)", p.ctrl_mw), p.ctrl_mw),
    ];
    let mut s = bar_chart(
        "Fig. 3c: power distribution, AlexNet conv3, 8-bit gated precision",
        &items,
        40,
    );
    s.push_str(&format!(
        "total {:.1} mW — paper: vALUs 44 %, memories+RF+LB 44.1 % (measured {:.1} / {:.1} / {:.1} %)\n",
        p.total_mw(),
        va * 100.0,
        me * 100.0,
        ct * 100.0
    ));
    Ok(s)
}

/// Everything Table II needs about a ConvAix run.
pub struct ConvAixRow {
    pub net: String,
    pub time_ms: f64,
    pub power_mw: f64,
    pub io_mb: f64,
    pub util: f64,
    pub area_eff: f64,
    pub energy_eff: f64,
}

pub fn convaix_row(name: &str, layers: &[NetLayer], cfg: &EngineConfig) -> Result<ConvAixRow> {
    let net = bench_network(name, layers, cfg)?;
    let secs = net.time_ms() / 1e3;
    let p = power::network_power(&net.stats(), secs);
    let gops = net.gops();
    Ok(ConvAixRow {
        net: name.into(),
        time_ms: net.time_ms(),
        power_mw: p.total_mw(),
        io_mb: net.io_mbytes(),
        util: net.utilization(),
        area_eff: gops / (area::LOGIC_KGE_TOTAL / 1e3),
        energy_eff: power::energy_eff_gops_per_w(net.macs(), secs, p.total_mw()),
    })
}

/// Table II — comparison with state-of-the-art accelerators. Always a
/// **single-core** run regardless of `--cores`: the paper's baselines
/// and the power model are calibrated for one ConvAix core, so sharding
/// here would compare a 4-core makespan against single-core silicon.
pub fn table2(cfg: &EngineConfig) -> Result<String> {
    let cfg = &cfg.clone().cores(1).batch(1);
    let alex = convaix_row("AlexNet", &conv_stack(alexnet_conv()), cfg)?;
    let vgg = convaix_row("VGG-16", &conv_stack(vgg16_conv()), cfg)?;
    let (espec, enets) = published::envision();
    let (yspec, ynets) = published::eyeriss();

    let mut t = Table::new(
        "TABLE II: COMPARISON WITH STATE-OF-THE-ART ACCELERATORS",
        &["Metric", "Envision [7]", "Eyeriss [6] A/V", "ConvAix (this model) A/V", "ConvAix paper A/V"],
    );
    let e = &enets[0];
    let (ya, yv) = (&ynets[0], &ynets[1]);
    t.row(&["Technology".into(), "40nm LP".into(), "65nm LP".into(), "28nm (model)".into(), "28nm LP (P&R)".into()]);
    t.row(&[
        "Gate count [kGE]".into(),
        format!("{:.0}", espec.kge),
        format!("{:.0}", yspec.kge),
        format!("{:.0}", area::LOGIC_KGE_TOTAL),
        "1293".into(),
    ]);
    t.row(&[
        "Clock [MHz]".into(),
        format!("{:.0}", espec.freq_mhz),
        format!("{:.0}", yspec.freq_mhz),
        "400".into(),
        "400".into(),
    ]);
    t.row(&[
        "Peak perf [GOP/s]".into(),
        format!("{:.1}", espec.peak_gops),
        format!("{:.1}", yspec.peak_gops),
        format!("{:.1}", crate::PEAK_GOPS),
        "153.6".into(),
    ]);
    t.row(&[
        "Processing time [ms]".into(),
        format!("{:.2}", e.time_ms),
        format!("{:.2} / {:.2}", ya.time_ms, yv.time_ms),
        format!("{:.2} / {:.2}", alex.time_ms, vgg.time_ms),
        "12.60 / 263.0".into(),
    ]);
    t.row(&[
        "Power [mW]".into(),
        format!("{:.1}", e.power_mw),
        format!("{:.1} / {:.1}", ya.power_mw, yv.power_mw),
        format!("{:.1} / {:.1}", alex.power_mw, vgg.power_mw),
        "228.8 / 223.9".into(),
    ]);
    t.row(&[
        "Off-chip I/O [MByte]".into(),
        format!("{:.2}", e.io_mbytes),
        format!("{:.2} / {:.2}", ya.io_mbytes, yv.io_mbytes),
        format!("{:.2} / {:.2}", alex.io_mb, vgg.io_mb),
        "10.79 / 208.14".into(),
    ]);
    t.row(&[
        "MAC utilization".into(),
        format!("{:.2}", e.util),
        format!("{:.2} / {:.2}", ya.util, yv.util),
        format!("{:.2} / {:.2}", alex.util, vgg.util),
        "0.69 / 0.76".into(),
    ]);
    t.row(&[
        "Area eff [GOP/s/MGE]".into(),
        format!("{:.2}", e.area_eff(&espec)),
        format!("{:.2} / {:.2}", ya.area_eff(&yspec), yv.area_eff(&yspec)),
        format!("{:.2} / {:.2}", alex.area_eff, vgg.area_eff),
        "82.23 / 90.26".into(),
    ]);
    t.row(&[
        "Energy eff @28nm/1V [GOP/s/W]".into(),
        format!("{:.0}", e.eff_scaled(&espec)),
        format!("{:.0} / {:.0}", ya.eff_scaled(&yspec), yv.eff_scaled(&yspec)),
        format!("{:.0} / {:.0}", alex.energy_eff, vgg.energy_eff),
        "459 / 497".into(),
    ]);
    let mut s = t.render();
    s.push_str(&format!(
        "speedup vs Eyeriss: {:.1}x (AlexNet, paper 2.1x), {:.1}x (VGG-16, paper 4.8x)\n\
         area-eff vs best baseline: {:.1}x (AlexNet, paper 1.9x), {:.1}x (VGG-16, paper 4.3x)\n",
        ya.time_ms / alex.time_ms,
        yv.time_ms / vgg.time_ms,
        alex.area_eff / ya.area_eff(&yspec).max(e.area_eff(&espec)),
        vgg.area_eff / yv.area_eff(&yspec),
    ));
    Ok(s)
}

/// Per-layer utilization table (the abstract's 72.5 % average claim).
/// Always single-core — the claim it reproduces is a single-core one;
/// use `run <net> --cores N` for the multi-core per-layer breakdown.
pub fn util_table(cfg: &EngineConfig) -> Result<String> {
    let cfg = &cfg.clone().cores(1).batch(1);
    let mut t = Table::new(
        "Per-layer MAC utilization (paper: 72.5 % average across AlexNet+VGG-16 conv layers)",
        &["Net", "Layer", "Util", "Time [ms]", "GOP/s", "I/O [MB]"],
    );
    let mut utils = Vec::new();
    for (net, layers) in
        [("AlexNet", conv_stack(alexnet_conv())), ("VGG-16", conv_stack(vgg16_conv()))]
    {
        let r = bench_network(net, &layers, cfg)?;
        for l in &r.layers {
            utils.push(l.utilization());
            t.row(&[
                net.into(),
                l.name.to_string(),
                format!("{:.3}", l.utilization()),
                format!("{:.2}", l.time_ms()),
                format!("{:.1}", l.gops()),
                format!("{:.2}", l.io_total() as f64 / 1e6),
            ]);
        }
        t.row(&[
            net.into(),
            "== net ==".into(),
            format!("{:.3}", r.utilization()),
            format!("{:.2}", r.time_ms()),
            format!("{:.1}", r.gops()),
            format!("{:.2}", r.io_mbytes()),
        ]);
    }
    let avg = utils.iter().sum::<f64>() / utils.len() as f64;
    let mut s = t.render();
    s.push_str(&format!(
        "average ALU utilization across all conv layers: {:.1} % (paper: 72.5 %)\n",
        avg * 100.0
    ));
    Ok(s)
}

/// `convaix run <net>` — per-layer breakdown with kind labels,
/// per-kind rollup rows (conv vs pool vs fc — on the full nets the fc
/// rows show the weight-DMA-bound tail), and the metrics summary.
pub fn run_net(net: &str, cfg: &EngineConfig) -> Result<String> {
    let layers = net_layers(net)?;
    let r = bench_network(net, &layers, cfg)?;

    let mut t = Table::new(
        &format!("{net}: per-layer breakdown"),
        &["Layer", "Kind", "Time [ms]", "Util %", "GOP/s", "I/O [MB]"],
    );
    for (d, l) in layers.iter().zip(&r.layers) {
        t.row(&[
            l.name.to_string(),
            d.kind().into(),
            format!("{:.3}", l.time_ms()),
            format!("{:.1}", l.utilization() * 100.0),
            format!("{:.1}", l.gops()),
            format!("{:.2}", l.io_total() as f64 / 1e6),
        ]);
    }
    // per-kind rollups: one row per layer kind present in the net; the
    // Util % cell is the kind's aggregate ALU utilization (ideal MAC
    // cycles over busy core cycles — see `KindTotal::utilization`)
    for kt in r.kind_totals(&layers) {
        let gops = if kt.cycles == 0 {
            0.0
        } else {
            2.0 * kt.macs as f64 / (kt.cycles as f64 / crate::CLOCK_HZ as f64) / 1e9
        };
        t.row(&[
            format!("== {} x{} ==", kt.kind, kt.layers),
            kt.kind.into(),
            format!("{:.3}", kt.time_ms()),
            if kt.macs > 0 { format!("{:.1}", kt.utilization() * 100.0) } else { "-".into() },
            format!("{gops:.1}"),
            format!("{:.2}", kt.io_bytes as f64 / 1e6),
        ]);
    }

    let secs = r.time_ms() / 1e3;
    let p = power::network_power(&r.stats(), secs);
    let mut s = t.render();
    s.push_str(&format!(
        "{net}: {:.2} ms, util {:.3}, {:.1} GOP/s, {:.2} MB off-chip I/O, {:.1} mW, {:.0} GOP/s/W\n",
        r.time_ms(),
        r.utilization(),
        r.gops(),
        r.io_mbytes(),
        p.total_mw(),
        power::energy_eff_gops_per_w(r.macs(), secs, p.total_mw()),
    ));
    if let Some(conv) = r.kind_totals(&layers).iter().find(|kt| kt.kind == "conv") {
        s.push_str(&format!(
            "conv ALU utilization: {:.1} % (paper: 72.5 % average across AlexNet+VGG-16 \
             16-bit conv layers)\n",
            conv.utilization() * 100.0,
        ));
    }
    if let Some(plan) = cfg.faults {
        s.push_str(&fault_lines(&FaultReport {
            retries: r.fault_retries(),
            recovery_cycles: r.fault_recovery_cycles(),
            ..Default::default()
        }));
        let mut clean_cfg = cfg.clone();
        clean_cfg.faults = None;
        let clean = bench_network(net, &layers, &clean_cfg)?;
        s.push_str(&verify_against_clean(
            &plan,
            net,
            std::slice::from_ref(&r),
            std::slice::from_ref(&clean),
        )?);
    }
    Ok(s)
}

/// `convaix golden` — bit-exact verification against the AOT artifacts.
pub fn golden(dir: &str) -> Result<(String, bool)> {
    use crate::runtime::{golden_conv_check, golden_pool_check, Manifest, PjrtRunner};
    let manifest = Manifest::load(dir)?;
    let runner = PjrtRunner::new()?;
    let mut t = Table::new(
        "Golden check: cycle simulator vs JAX/Pallas (PJRT) vs host reference",
        &["Artifact", "Elements", "sim==pjrt", "sim==host", "Cycles", "Util"],
    );
    let mut all_ok = true;
    for (i, art) in manifest.convs.iter().enumerate() {
        let r = golden_conv_check(&runner, &manifest, art, 100 + i as u64)?;
        all_ok &= r.ok();
        t.row(&[
            r.name.clone(),
            r.elements.to_string(),
            if r.sim_vs_pjrt_mismatches == 0 { "OK".into() } else { format!("{} MISMATCH", r.sim_vs_pjrt_mismatches) },
            if r.sim_vs_host_mismatches == 0 { "OK".into() } else { format!("{} MISMATCH", r.sim_vs_host_mismatches) },
            r.sim_cycles.to_string(),
            format!("{:.3}", r.sim_util),
        ]);
    }
    for (i, art) in manifest.pools.iter().enumerate() {
        let r = golden_pool_check(&runner, &manifest, art, 200 + i as u64)?;
        all_ok &= r.ok();
        t.row(&[
            r.name.clone(),
            r.elements.to_string(),
            if r.sim_vs_pjrt_mismatches == 0 { "OK".into() } else { format!("{} MISMATCH", r.sim_vs_pjrt_mismatches) },
            if r.sim_vs_host_mismatches == 0 { "OK".into() } else { format!("{} MISMATCH", r.sim_vs_host_mismatches) },
            r.sim_cycles.to_string(),
            "-".into(),
        ]);
    }
    let mut s = t.render();
    s.push_str(if all_ok { "ALL GOLDEN CHECKS PASSED (bit-exact)\n" } else { "GOLDEN MISMATCHES FOUND\n" });
    Ok((s, all_ok))
}
