//! Compile-once layer cache: memoized plans + task programs + analytic
//! profiles, plus the per-core staging arenas.
//!
//! Everything `run_dense`/`pool_layer` derive from a layer *shape* —
//! the [`ConvPlan`], the assembled task [`ProgramMem`]s, and (in
//! tile-analytic mode) the sampled row timings — depends only on
//! (shape, gate bits), never on frame data. Re-deriving it per call is
//! exactly the per-layer control-setup redundancy the paper's ASIP
//! avoids by specializing control per layer, and that Shen et al.
//! (ISCA'17) amortize by compiling per-layer configurations once. The
//! [`PlanCache`] makes the simulator do the same: the first execution
//! of a shape compiles a [`CompiledConv`]/[`CompiledPool`]; every later
//! frame, shard and pipeline stage reuses it, so the steady-state loop
//! of `run_batched`/`run_streaming` performs zero codegen.
//!
//! Cache keys are **shape + gate bits, never names**: two layers with
//! identical geometry share one entry (VGG's conv3_2/conv3_3, every
//! group of a grouped conv, every frame of a batch), while the same
//! shape at a different gating must miss — the analytic profile's
//! `mac_ops_gated8` counter depends on the CSR gate setting.
//!
//! Why replaying a cached analytic profile is bit-exact: a task
//! program's cycle count and activity counters are functions of the
//! program structure, the DM/LB *addresses* it touches and the CSR
//! state — never of the tensor *values* (gating changes values and the
//! `mac_ops_gated8` counter, but that counter switches on the CSR gate
//! bits, which are part of the cache key). The sampled rows are the
//! same rows, at the same staged addresses, in the same deterministic
//! schedule order on every run of the shape, so storing the raw per-row
//! samples of one cold pass and replaying them reproduces the cold
//! pass's `LayerResult` to the last counter. The bit-identity is locked
//! by `tests/plan_cache.rs`.
//!
//! The [`Scratch`] arena is the allocation half of the same argument:
//! padded-input, staged-band, filter-stream and row-readback buffers
//! are per-core and shape-bounded, so each core reuses one set across
//! layers and frames instead of reallocating per call.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::core::CoreStats;
use crate::isa::analysis::memory::{self, MemSpec};
use crate::isa::analysis::predict::{predict, AbiEnv, StaticTiming};
use crate::isa::analysis::{self, AbiSpec};
use crate::isa::Program;
use crate::mem::pm::ProgramMem;
use crate::model::{ConvLayer, PoolLayer};

use super::conv::{build_conv_task, TaskFlavor};
use super::layout::{self, ConvPlan};
use super::pool::{build_pool_task, plan_pool_with, PoolPlan};
use super::CodegenError;

/// Verify-on-insert: every program entering the plan cache passes the
/// static verifier (`isa::analysis`) when analysis is enabled — always
/// in debug builds and under `cargo test`, opt-in via `ANALYZE=1` /
/// `--verify-programs` in release. A finding is a codegen bug, not a
/// user error, so it surfaces as [`CodegenError::Verify`].
fn verify_on_insert(prog: &Program, abi: &AbiSpec, what: &str) -> Result<(), CodegenError> {
    if !analysis::enabled() {
        return Ok(());
    }
    let report = analysis::verify(prog, abi);
    if report.is_clean() {
        Ok(())
    } else {
        Err(CodegenError::Verify(format!("{what}: {report}")))
    }
}

/// The memory pass (`analysis::memory`, pass 5) on insert: enumerate
/// every access the program performs under each given ABI environment
/// and check region bounds, `DmMap` aliasing and DMA hazards. Callers
/// pass the *extremal* row environments — every access address is
/// affine in r2 with unit coefficient and the access-site set does not
/// depend on r2 (control flow branches only on counters), so region
/// containment at the interval endpoints implies it for every row in
/// between.
fn verify_memory_on_insert(
    prog: &Program,
    envs: &[AbiEnv],
    spec: &MemSpec,
    what: &str,
) -> Result<(), CodegenError> {
    if !analysis::enabled() {
        return Ok(());
    }
    for env in envs {
        let report = memory::check(prog, env, spec)
            .map_err(|e| CodegenError::Verify(format!("{what}: memory walk failed: {e}")))?;
        if !report.is_clean() {
            return Err(CodegenError::Verify(format!("{what}: {report}")));
        }
    }
    Ok(())
}

/// Program selector within one conv plan: (slice input channels,
/// first-slice?, last-slice?) — the same key `run_dense` dispatched on
/// since the seed.
pub(crate) type TaskKey = (usize, bool, bool);

/// Which slice of the Fig. 2 depth slicing task `mi` of `m` executes.
pub(crate) fn flavor_of(mi: usize, m: usize) -> TaskFlavor {
    TaskFlavor { first_slice: mi == 0, last_slice: mi + 1 == m }
}

/// Conv cache key: the dense (per-group) layer's geometry and datapath
/// knobs plus the run's gate bits and rotation knob. Deliberately
/// excludes the name. `rot` is the *requested* knob, not the plan's
/// feasibility outcome — a shape planned with and without rotation may
/// produce different `DmMap`s, so the two must not share an entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct ConvKey {
    ic: usize,
    ih: usize,
    iw: usize,
    oc: usize,
    fh: usize,
    fw: usize,
    stride: usize,
    pad: usize,
    frac_shift: u8,
    relu: bool,
    gate_bits: u8,
    rot: bool,
}

impl ConvKey {
    fn of(l: &ConvLayer, gate_bits: u8, rot: bool) -> Self {
        debug_assert_eq!(l.groups, 1, "conv cache keys are per-group dense views");
        Self {
            ic: l.ic,
            ih: l.ih,
            iw: l.iw,
            oc: l.oc,
            fh: l.fh,
            fw: l.fw,
            stride: l.stride,
            pad: l.pad,
            frac_shift: l.frac_shift,
            relu: l.relu,
            gate_bits,
            rot,
        }
    }
}

/// Pool cache key: everything the one-row pool plan and its task
/// program depend on. `ic`/`ih` are executor-side loop bounds, not
/// plan inputs, so they stay out of the key (the cached plan's
/// `n_tiles` is NOT meaningful across layers — the executor recomputes
/// it from the layer).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct PoolKey {
    iw: usize,
    size: usize,
    stride: usize,
    rot: bool,
}

/// One raw sampled row of a cold tile-analytic pass: the `(cycles,
/// stats)` the cycle simulator returned, tagged with the in-band row
/// index it ran at. The tag makes every sample independently checkable
/// against the static analyzer: `predict` at the matching per-row ABI
/// (`CompiledConv::abi_env_for_row`) must reproduce it field-for-field.
pub(crate) struct RowSample {
    /// Row index within the band (`oh_local`); determines the r2 ABI
    /// value `dm.input + oh_local · stride · row_bytes`.
    pub oh_local: usize,
    pub cycles: u64,
    pub stats: CoreStats,
}

/// The raw sampled rows of one task program from a cold tile-analytic
/// pass.
pub(crate) struct SampleSet {
    /// Raw per-row samples, in the schedule order the cold pass ran
    /// them (at most `ANALYTIC_SAMPLES`; fewer when the layer has fewer
    /// rows of this task).
    pub rows: Vec<RowSample>,
    /// Sum of the sampled cycles (the cold pass's accumulator value).
    pub total_cycles: u64,
    /// Field-wise sum of the sampled stats.
    pub total_stats: CoreStats,
}

impl SampleSet {
    pub fn n(&self) -> u64 {
        self.rows.len() as u64
    }
}

/// The sampled-row record of one cold tile-analytic pass over a shape —
/// enough to replay every later pass without touching the core.
pub(crate) struct AnalyticProfile {
    pub samples: HashMap<TaskKey, SampleSet>,
}

/// A conv layer shape, compiled once: the layout plan plus the
/// assembled task program per distinct [`TaskKey`], plus the lazily
/// published tile-analytic profile.
pub struct CompiledConv {
    pub(crate) plan: ConvPlan,
    programs: HashMap<TaskKey, ProgramMem>,
    /// Published by the first successful tile-analytic pass; replayed
    /// bit-exactly by every later one (see the module docs for why
    /// that is sound). Racing first passes compute identical profiles,
    /// so whichever `set` wins is canonical.
    pub(crate) analytic: OnceLock<AnalyticProfile>,
    /// Static cycle predictions per task program (`analysis::predict`),
    /// computed lazily against the row-0 task ABI and cached for the
    /// shape's lifetime. `Err` records why a program was not statically
    /// predictable (no generated conv task is — asserted by tests).
    analyzer: OnceLock<HashMap<TaskKey, Result<StaticTiming, String>>>,
}

impl CompiledConv {
    pub(crate) fn compile(layer: &ConvLayer) -> Result<Self, CodegenError> {
        Self::compile_with(layer, true)
    }

    pub(crate) fn compile_with(layer: &ConvLayer, rotate: bool) -> Result<Self, CodegenError> {
        let plan = layout::plan_with(layer, rotate)?;
        let mut programs: HashMap<TaskKey, ProgramMem> = HashMap::new();
        for mi in 0..plan.m {
            let f = flavor_of(mi, plan.m);
            let key = (plan.slice_ics(mi), f.first_slice, f.last_slice);
            if !programs.contains_key(&key) {
                let pm = build_conv_task(&plan, key.0, f)?;
                let what = format!("conv task {key:?} of layer {}", layer.name);
                verify_on_insert(pm.program(), &AbiSpec::conv(), &what)?;
                // Memory pass at the extremal in-band rows (r2 is the
                // only row-dependent ABI register and every access is
                // affine in it — see `verify_memory_on_insert`).
                let envs = [
                    Self::row_env(&plan, 0),
                    Self::row_env(&plan, plan.band_rows.saturating_sub(1)),
                ];
                verify_memory_on_insert(
                    pm.program(),
                    &envs,
                    &super::conv::mem_spec(&plan, f),
                    &what,
                )?;
                // Phase B of a rotated plan: the same program runs with
                // r2/r6 re-based into the shadow slots while the primary
                // pair is the in-flight prefetch target (no-access). The
                // DmaRace discipline for host-staged transfers is
                // checked as region containment in both phases.
                if plan.rot.is_some() {
                    let spec_b = super::conv::mem_spec_phase_b(&plan, f)
                        .expect("rotated plan has a phase-B spec");
                    let envs_b = [
                        Self::row_env_rot(&plan, 0),
                        Self::row_env_rot(&plan, plan.band_rows.saturating_sub(1)),
                    ];
                    verify_memory_on_insert(
                        pm.program(),
                        &envs_b,
                        &spec_b,
                        &format!("{what} (rotation phase B)"),
                    )?;
                }
                programs.insert(key, pm);
            }
        }
        Ok(Self { plan, programs, analytic: OnceLock::new(), analyzer: OnceLock::new() })
    }

    pub(crate) fn task_key(&self, mi: usize) -> TaskKey {
        let f = flavor_of(mi, self.plan.m);
        (self.plan.slice_ics(mi), f.first_slice, f.last_slice)
    }

    pub(crate) fn program(&self, key: &TaskKey) -> &ProgramMem {
        &self.programs[key]
    }

    /// All task programs of this shape (for the `lint` CLI walk).
    pub(crate) fn programs(&self) -> impl Iterator<Item = (&TaskKey, &ProgramMem)> {
        self.programs.iter()
    }

    fn row_env(plan: &ConvPlan, oh_local: usize) -> AbiEnv {
        AbiEnv::new(&[
            (2, (plan.dm.input + oh_local * plan.layer.stride * plan.row_bytes) as i32),
            (4, plan.dm.out as i32),
            (5, plan.dm.psum as i32),
            (6, plan.dm.filt as i32),
        ])
    }

    /// Rotation phase B's ABI for the in-band row `oh_local`: input and
    /// filter bases point into the shadow slots, out/psum stay primary
    /// (the row buffer and PSum spill are not doubled — only the
    /// DMA-staged streams rotate). Callers must hold `plan.rot.is_some()`.
    fn row_env_rot(plan: &ConvPlan, oh_local: usize) -> AbiEnv {
        let r = plan.rot.as_ref().expect("phase-B env of an un-rotated plan");
        AbiEnv::new(&[
            (2, (r.input + oh_local * plan.layer.stride * plan.row_bytes) as i32),
            (4, plan.dm.out as i32),
            (5, plan.dm.psum as i32),
            (6, r.filt as i32),
        ])
    }

    /// The ABI environment `run_dense` establishes for the in-band row
    /// `oh_local`: r2 = staged input base + `oh_local · stride ·
    /// row_bytes`, r4/r5/r6 = output / psum / filter stream bases. Only
    /// r2 varies per row; DM bank interleaving makes the row's LB-fill
    /// conflicts depend on that address, which is why per-row timing is
    /// predicted per-row rather than extrapolated from row 0.
    pub(crate) fn abi_env_for_row(&self, oh_local: usize) -> AbiEnv {
        Self::row_env(&self.plan, oh_local)
    }

    /// Phase-B (shadow-slot) ABI for the in-band row `oh_local`, when
    /// the plan rotates (for the `lint` walk's phase-B memory checks).
    pub(crate) fn abi_env_for_row_rot(&self, oh_local: usize) -> Option<AbiEnv> {
        self.plan.rot.as_ref()?;
        Some(Self::row_env_rot(&self.plan, oh_local))
    }

    /// The row-0 ABI environment (the `lint` walk prices row 0).
    pub(crate) fn abi_env(&self) -> AbiEnv {
        self.abi_env_for_row(0)
    }

    /// Static cycle prediction of one task program at one in-band row's
    /// ABI — exact (bit-for-bit against the simulator) per row,
    /// including the row-address-dependent DM bank conflicts of LB
    /// fills. Uncached: callers wanting the cached row-0 map use
    /// [`Self::analyzer_timing`].
    pub(crate) fn predict_row(
        &self,
        key: &TaskKey,
        oh_local: usize,
    ) -> Result<StaticTiming, String> {
        predict(self.programs[key].program(), &self.abi_env_for_row(oh_local))
            .map_err(|e| e.to_string())
    }

    /// Static cycle predictions per task program, lazily computed and
    /// cached on the compiled shape.
    pub(crate) fn analyzer_timing(&self) -> &HashMap<TaskKey, Result<StaticTiming, String>> {
        self.analyzer.get_or_init(|| {
            let env = self.abi_env();
            self.programs
                .iter()
                .map(|(k, pm)| (*k, predict(pm.program(), &env).map_err(|e| e.to_string())))
                .collect()
        })
    }
}

/// A pool layer shape, compiled once: the one-row plan, its task
/// program, and the single sampled-row analytic record (pool rows are
/// cycle-identical, so the seed executor already reused one sample per
/// call — the cache extends that across calls).
pub struct CompiledPool {
    pub(crate) plan: PoolPlan,
    pub(crate) pm: ProgramMem,
    pub(crate) analytic: OnceLock<(u64, CoreStats)>,
    /// Static cycle prediction for the one-row task program.
    analyzer: OnceLock<Result<StaticTiming, String>>,
}

impl CompiledPool {
    pub(crate) fn compile(layer: &PoolLayer) -> Result<Self, CodegenError> {
        Self::compile_with(layer, true)
    }

    pub(crate) fn compile_with(layer: &PoolLayer, rotate: bool) -> Result<Self, CodegenError> {
        let one_row = PoolLayer { ih: layer.size, ..layer.clone() };
        let plan = plan_pool_with(&one_row, rotate)?;
        let pm = build_pool_task(&plan)?;
        let what = format!("pool task of layer {}", layer.name);
        verify_on_insert(pm.program(), &AbiSpec::pool(), &what)?;
        let env = AbiEnv::new(&[(2, plan.dm_input as i32), (4, plan.dm_out as i32)]);
        verify_memory_on_insert(pm.program(), &[env], &super::pool::mem_spec(&plan), &what)?;
        // Phase B of a rotated plan: shadow input/output live, primary
        // pair is the inactive prefetch target (no-access).
        if let (Some(ri), Some(ro)) = (plan.rot_input(), plan.rot_out()) {
            let spec_b =
                super::pool::mem_spec_phase_b(&plan).expect("rotated plan has a phase-B spec");
            let env_b = AbiEnv::new(&[(2, ri as i32), (4, ro as i32)]);
            verify_memory_on_insert(
                pm.program(),
                &[env_b],
                &spec_b,
                &format!("{what} (rotation phase B)"),
            )?;
        }
        Ok(Self { plan, pm, analytic: OnceLock::new(), analyzer: OnceLock::new() })
    }

    /// The ABI environment `run_pool` establishes: r2 = staged input
    /// base, r4 = output base.
    pub(crate) fn abi_env(&self) -> AbiEnv {
        AbiEnv::new(&[(2, self.plan.dm_input as i32), (4, self.plan.dm_out as i32)])
    }

    /// Phase-B (shadow-slot) ABI when the plan rotates (for the `lint`
    /// walk's phase-B memory checks).
    pub(crate) fn abi_env_rot(&self) -> Option<AbiEnv> {
        let (ri, ro) = (self.plan.rot_input()?, self.plan.rot_out()?);
        Some(AbiEnv::new(&[(2, ri as i32), (4, ro as i32)]))
    }

    /// Static cycle prediction, lazily computed and cached.
    pub(crate) fn analyzer_timing(&self) -> &Result<StaticTiming, String> {
        self.analyzer.get_or_init(|| {
            predict(self.pm.program(), &self.abi_env()).map_err(|e| e.to_string())
        })
    }
}

/// Hit/miss counters and entry counts of a [`PlanCache`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub conv_entries: usize,
    pub pool_entries: usize,
}

/// The compile-once cache: shape-keyed compiled layers, shared (behind
/// an `Arc` on the engine) by every core thread, frame, shard and
/// pipeline stage. Disabled it still compiles — fresh on every call —
/// which is exactly the pre-cache behavior the `simspeed` bench uses
/// as its uncached baseline.
pub struct PlanCache {
    enabled: bool,
    conv: Mutex<HashMap<ConvKey, Arc<CompiledConv>>>,
    pool: Mutex<HashMap<PoolKey, Arc<CompiledPool>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl PlanCache {
    pub fn new() -> Self {
        Self {
            enabled: true,
            conv: Mutex::new(HashMap::new()),
            pool: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// A cache that never retains anything: every lookup compiles
    /// fresh (and counts as a miss). The analytic profile is likewise
    /// per-call, so execution behaves exactly like the pre-cache code.
    pub fn disabled() -> Self {
        Self { enabled: false, ..Self::new() }
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Compiled artifact for a dense (per-group) conv layer shape.
    pub(crate) fn conv(
        &self,
        layer: &ConvLayer,
        gate_bits: u8,
        rotate: bool,
    ) -> Result<Arc<CompiledConv>, CodegenError> {
        if !self.enabled {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return CompiledConv::compile_with(layer, rotate).map(Arc::new);
        }
        let key = ConvKey::of(layer, gate_bits, rotate);
        // Compiling under the lock serializes racing first compiles of
        // one shape — cheaper than letting every core compile it.
        let mut map = self.conv.lock().expect("plan cache poisoned");
        if let Some(cc) = map.get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(cc.clone());
        }
        let cc = Arc::new(CompiledConv::compile_with(layer, rotate)?);
        map.insert(key, cc.clone());
        self.misses.fetch_add(1, Ordering::Relaxed);
        Ok(cc)
    }

    /// Compiled artifact for a pool layer shape.
    pub(crate) fn pool(
        &self,
        layer: &PoolLayer,
        rotate: bool,
    ) -> Result<Arc<CompiledPool>, CodegenError> {
        if !self.enabled {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return CompiledPool::compile_with(layer, rotate).map(Arc::new);
        }
        let key = PoolKey { iw: layer.iw, size: layer.size, stride: layer.stride, rot: rotate };
        let mut map = self.pool.lock().expect("plan cache poisoned");
        if let Some(cp) = map.get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(cp.clone());
        }
        let cp = Arc::new(CompiledPool::compile_with(layer, rotate)?);
        map.insert(key, cp.clone());
        self.misses.fetch_add(1, Ordering::Relaxed);
        Ok(cp)
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            conv_entries: self.conv.lock().expect("plan cache poisoned").len(),
            pool_entries: self.pool.lock().expect("plan cache poisoned").len(),
        }
    }
}

impl Default for PlanCache {
    fn default() -> Self {
        Self::new()
    }
}

/// Per-core staging arena: the host-side buffers a layer run stages
/// tensors through, reused across layers and frames. Every buffer is
/// reset (zero-filled to the exact length) before use, so a reused
/// arena is indistinguishable from fresh allocations — only the
/// allocator traffic disappears.
#[derive(Default)]
pub struct Scratch {
    /// Zero-padded input tensor (`stage::pad_input_into`).
    pub(crate) xp: Vec<i16>,
    /// Staged input band for one (slice, band) (`stage::input_band_into`).
    pub(crate) band: Vec<i16>,
    /// Filter stream for one (tile, slice) (`stage::filter_stream_into`).
    pub(crate) filt: Vec<i16>,
    /// One output row read back from the row buffer.
    pub(crate) row: Vec<i16>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> ConvLayer {
        ConvLayer::new("s", 4, 8, 8, 16, 3, 3, 1, 1, 1)
    }

    #[test]
    fn conv_keys_ignore_names_but_not_gate_bits() {
        let cache = PlanCache::new();
        let a = ConvLayer { name: "a", ..small() };
        let b = ConvLayer { name: "b", ..small() };
        let c1 = cache.conv(&a, 16, true).unwrap();
        let c2 = cache.conv(&b, 16, true).unwrap();
        assert!(Arc::ptr_eq(&c1, &c2), "same shape, different name must hit");
        let c3 = cache.conv(&a, 8, true).unwrap();
        assert!(!Arc::ptr_eq(&c1, &c3), "same shape, different gate bits must miss");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.conv_entries), (1, 2, 2));
    }

    #[test]
    fn rotation_knob_is_part_of_both_cache_keys() {
        let cache = PlanCache::new();
        let l = small();
        let on = cache.conv(&l, 16, true).unwrap();
        let off = cache.conv(&l, 16, false).unwrap();
        assert!(!Arc::ptr_eq(&on, &off), "rotation knob must miss");
        assert!(on.plan.rot.is_some() && off.plan.rot.is_none());
        let p = PoolLayer { name: "p", ic: 16, ih: 8, iw: 8, size: 2, stride: 2 };
        let pon = cache.pool(&p, true).unwrap();
        let poff = cache.pool(&p, false).unwrap();
        assert!(!Arc::ptr_eq(&pon, &poff), "rotation knob must miss");
        assert!(pon.plan.rot.is_some() && poff.plan.rot.is_none());
    }

    #[test]
    fn disabled_cache_compiles_fresh_every_call() {
        let cache = PlanCache::disabled();
        let l = small();
        let c1 = cache.conv(&l, 16, true).unwrap();
        let c2 = cache.conv(&l, 16, true).unwrap();
        assert!(!Arc::ptr_eq(&c1, &c2));
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.conv_entries), (0, 2, 0));
    }

    #[test]
    fn compiled_conv_carries_every_task_program() {
        // a multi-slice shape needs first/middle/last flavors
        let l = ConvLayer::new("ms", 768, 6, 6, 16, 3, 3, 1, 1, 1);
        let cc = CompiledConv::compile(&l).unwrap();
        assert!(cc.plan.m > 1);
        for mi in 0..cc.plan.m {
            let key = cc.task_key(mi);
            assert!(cc.program(&key).bundle_count() > 0, "missing program for {key:?}");
        }
    }

    #[test]
    fn pool_keys_ignore_channel_count() {
        let cache = PlanCache::new();
        let p1 = PoolLayer { name: "p1", ic: 16, ih: 8, iw: 8, size: 2, stride: 2 };
        let p2 = PoolLayer { name: "p2", ic: 48, ih: 12, iw: 8, size: 2, stride: 2 };
        let c1 = cache.pool(&p1, true).unwrap();
        let c2 = cache.pool(&p2, true).unwrap();
        assert!(Arc::ptr_eq(&c1, &c2), "pool plans depend on (iw, size, stride) only");
        let p3 = PoolLayer { name: "p3", ic: 16, ih: 8, iw: 13, size: 2, stride: 2 };
        assert!(!Arc::ptr_eq(&c1, &cache.pool(&p3, true).unwrap()));
    }

    // ---- static cycle analyzer vs. cycle simulator ---------------------
    //
    // The analyzer (`isa::analysis::predict`) must reproduce the
    // simulated cycle count and every stall counter *exactly*, for every
    // task program of every shape in the matrix below — at **every**
    // in-band row's ABI, not just row 0. Rows differ only in r2, but DM
    // bank interleaving makes each row's LB-fill conflicts depend on
    // that address; the analyzer prices them per row via
    // `abi_env_for_row`, so the comparison sweeps the extremal and a
    // middle row of the band.

    use crate::core::Cpu;
    use crate::isa::SReg;
    use crate::model::FcLayer;

    /// In-band rows a per-row comparison sweeps: first, middle, last.
    fn sweep_rows(band_rows: usize) -> Vec<usize> {
        let mut rows = vec![0, band_rows / 2, band_rows.saturating_sub(1)];
        rows.dedup();
        rows
    }

    /// Shapes excluded from exact static prediction. Every entry needs a
    /// documented reason; `predict_exclusion_list_does_not_grow` pins
    /// the list empty — the analyzer covers every generated conv, pool
    /// and FC task program.
    const PREDICT_EXCLUSIONS: &[&str] = &[];

    fn conv_matrix() -> Vec<ConvLayer> {
        vec![
            // variant A (lanes = channels)
            ConvLayer::new("va", 4, 24, 24, 16, 3, 3, 1, 1, 1),
            // variant B (lanes = pixels)
            ConvLayer::new("vb", 8, 13, 13, 48, 3, 3, 1, 1, 1),
            // strided + padded (AlexNet conv2-like geometry)
            ConvLayer::new("s2", 3, 23, 23, 16, 5, 5, 2, 2, 1),
            // big window, stride 4, no pad (AlexNet conv1 geometry)
            ConvLayer::new("c1", 3, 43, 43, 16, 11, 11, 4, 0, 1),
            // grouped conv, dense per-group view
            ConvLayer::new("grp", 8, 13, 13, 32, 3, 3, 1, 1, 2).per_group(),
            // multi-slice (m > 1): first / middle / last task flavors
            ConvLayer::new("ms", 768, 6, 6, 16, 3, 3, 1, 1, 1),
            // odd channel count (partial last slice)
            ConvLayer::new("odd", 5, 10, 10, 16, 3, 3, 1, 1, 1),
            // partial output-channel tile
            ConvLayer::new("ocp", 4, 10, 10, 24, 3, 3, 1, 0, 1),
            // no fused ReLU (logits-style epilogue)
            ConvLayer { relu: false, ..ConvLayer::new("nr", 4, 10, 10, 16, 3, 3, 1, 1, 1) },
        ]
    }

    fn assert_conv_prediction_exact(l: &ConvLayer) {
        assert!(!PREDICT_EXCLUSIONS.contains(&l.name), "{} is excluded", l.name);
        let cc = CompiledConv::compile(l).unwrap();
        let timings = cc.analyzer_timing();
        for (key, pm) in cc.programs() {
            // the cached row-0 map must agree with the per-row path
            assert_eq!(timings[key], cc.predict_row(key, 0), "{} {key:?}", l.name);
            for oh_local in sweep_rows(cc.plan.band_rows) {
                let got = match cc.predict_row(key, oh_local) {
                    Ok(t) => t,
                    Err(e) => {
                        panic!("{} {key:?} row {oh_local}: static prediction failed: {e}", l.name)
                    }
                };
                let mut cpu = Cpu::new(1 << 10);
                let r2 = cc.plan.dm.input + oh_local * l.stride * cc.plan.row_bytes;
                cpu.regs.set_r(SReg(2), r2 as i32);
                cpu.regs.set_r(SReg(4), cc.plan.dm.out as i32);
                cpu.regs.set_r(SReg(5), cc.plan.dm.psum as i32);
                cpu.regs.set_r(SReg(6), cc.plan.dm.filt as i32);
                let sim = cpu.run(pm).unwrap();
                assert_eq!(
                    (got.cycles, got.bundles, got.hazard_stalls, got.lb_stalls),
                    (sim.cycles, sim.bundles, sim.hazard_stalls, sim.lb_stalls),
                    "{} {key:?} row {oh_local}",
                    l.name
                );
                assert_eq!(
                    (got.branch_stalls, got.dma_wait_stalls, got.wide_ls_stalls),
                    (sim.branch_stalls, sim.dma_wait_stalls, sim.wide_ls_stalls),
                    "{} {key:?} row {oh_local}",
                    l.name
                );
            }
        }
    }

    #[test]
    fn analyzer_cycles_match_simulator_on_conv_matrix() {
        for l in conv_matrix() {
            assert_conv_prediction_exact(&l);
        }
    }

    #[test]
    fn analyzer_cycles_match_simulator_on_fc_shapes() {
        for (inf, outf) in [(64usize, 48usize), (37, 20), (128, 10), (2560, 16)] {
            let fc = FcLayer::new("fc", inf, outf);
            assert_conv_prediction_exact(&fc.as_conv());
            let logits = FcLayer { relu: false, ..fc };
            assert_conv_prediction_exact(&logits.as_conv());
        }
    }

    #[test]
    fn analyzer_cycles_match_simulator_on_pool_shapes() {
        for (size, stride, iw, ic) in [(2usize, 2usize, 8usize, 16usize), (3, 2, 13, 16)] {
            let l = PoolLayer { name: "p", ic, ih: size, iw, size, stride };
            let cp = CompiledPool::compile(&l).unwrap();
            let got = match cp.analyzer_timing() {
                Ok(t) => *t,
                Err(e) => panic!("pool {size}x{size}/{stride}: static prediction failed: {e}"),
            };
            let mut cpu = Cpu::new(1 << 10);
            cpu.regs.set_r(SReg(2), cp.plan.dm_input as i32);
            cpu.regs.set_r(SReg(4), cp.plan.dm_out as i32);
            let sim = cpu.run(&cp.pm).unwrap();
            assert_eq!(
                (got.cycles, got.bundles, got.hazard_stalls, got.branch_stalls),
                (sim.cycles, sim.bundles, sim.hazard_stalls, sim.branch_stalls),
                "pool {size}x{size}/{stride}"
            );
        }
    }

    #[test]
    fn predict_exclusion_list_does_not_grow() {
        assert!(
            PREDICT_EXCLUSIONS.is_empty(),
            "static prediction exclusions must not grow: {PREDICT_EXCLUSIONS:?}"
        );
    }

    /// The memory pass's symbolic walk resolves *every* access of every
    /// generated conv task to a concrete (address, length, bank set) at
    /// every swept row ABI — no unknown-address skips. (A clean `check`
    /// with unknowns would be vacuous; this pins the walk as total.)
    #[test]
    fn memory_pass_resolves_every_access_on_the_matrix() {
        for l in conv_matrix() {
            let cc = CompiledConv::compile(&l).unwrap();
            for (key, pm) in cc.programs() {
                for oh_local in sweep_rows(cc.plan.band_rows) {
                    let tr = memory::trace(pm.program(), &cc.abi_env_for_row(oh_local)).unwrap();
                    assert_eq!(tr.unknown, 0, "{} {key:?} row {oh_local}", l.name);
                    assert!(!tr.accesses.is_empty(), "{} {key:?} row {oh_local}", l.name);
                }
            }
        }
    }

    #[test]
    fn analyzer_timing_is_cached_per_shape() {
        let cc = CompiledConv::compile(&small()).unwrap();
        let a = cc.analyzer_timing() as *const _;
        let b = cc.analyzer_timing() as *const _;
        assert_eq!(a, b, "OnceLock must hand back the same map");
    }
}
