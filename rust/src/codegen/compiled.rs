//! Compile-once layer cache: memoized plans + task programs + analytic
//! profiles, plus the per-core staging arenas.
//!
//! Everything `run_dense`/`pool_layer` derive from a layer *shape* —
//! the [`ConvPlan`], the assembled task [`ProgramMem`]s, and (in
//! tile-analytic mode) the sampled row timings — depends only on
//! (shape, gate bits), never on frame data. Re-deriving it per call is
//! exactly the per-layer control-setup redundancy the paper's ASIP
//! avoids by specializing control per layer, and that Shen et al.
//! (ISCA'17) amortize by compiling per-layer configurations once. The
//! [`PlanCache`] makes the simulator do the same: the first execution
//! of a shape compiles a [`CompiledConv`]/[`CompiledPool`]; every later
//! frame, shard and pipeline stage reuses it, so the steady-state loop
//! of `run_batched`/`run_streaming` performs zero codegen.
//!
//! Cache keys are **shape + gate bits, never names**: two layers with
//! identical geometry share one entry (VGG's conv3_2/conv3_3, every
//! group of a grouped conv, every frame of a batch), while the same
//! shape at a different gating must miss — the analytic profile's
//! `mac_ops_gated8` counter depends on the CSR gate setting.
//!
//! Why replaying a cached analytic profile is bit-exact: a task
//! program's cycle count and activity counters are functions of the
//! program structure, the DM/LB *addresses* it touches and the CSR
//! state — never of the tensor *values* (gating changes values and the
//! `mac_ops_gated8` counter, but that counter switches on the CSR gate
//! bits, which are part of the cache key). The sampled rows are the
//! same rows, at the same staged addresses, in the same deterministic
//! schedule order on every run of the shape, so storing the raw per-row
//! samples of one cold pass and replaying them reproduces the cold
//! pass's `LayerResult` to the last counter. The bit-identity is locked
//! by `tests/plan_cache.rs`.
//!
//! The [`Scratch`] arena is the allocation half of the same argument:
//! padded-input, staged-band, filter-stream and row-readback buffers
//! are per-core and shape-bounded, so each core reuses one set across
//! layers and frames instead of reallocating per call.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::core::CoreStats;
use crate::mem::pm::ProgramMem;
use crate::model::{ConvLayer, PoolLayer};

use super::conv::{build_conv_task, TaskFlavor};
use super::layout::{self, ConvPlan};
use super::pool::{build_pool_task, plan_pool, PoolPlan};
use super::CodegenError;

/// Program selector within one conv plan: (slice input channels,
/// first-slice?, last-slice?) — the same key `run_dense` dispatched on
/// since the seed.
pub(crate) type TaskKey = (usize, bool, bool);

/// Which slice of the Fig. 2 depth slicing task `mi` of `m` executes.
pub(crate) fn flavor_of(mi: usize, m: usize) -> TaskFlavor {
    TaskFlavor { first_slice: mi == 0, last_slice: mi + 1 == m }
}

/// Conv cache key: the dense (per-group) layer's geometry and datapath
/// knobs plus the run's gate bits. Deliberately excludes the name.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct ConvKey {
    ic: usize,
    ih: usize,
    iw: usize,
    oc: usize,
    fh: usize,
    fw: usize,
    stride: usize,
    pad: usize,
    frac_shift: u8,
    relu: bool,
    gate_bits: u8,
}

impl ConvKey {
    fn of(l: &ConvLayer, gate_bits: u8) -> Self {
        debug_assert_eq!(l.groups, 1, "conv cache keys are per-group dense views");
        Self {
            ic: l.ic,
            ih: l.ih,
            iw: l.iw,
            oc: l.oc,
            fh: l.fh,
            fw: l.fw,
            stride: l.stride,
            pad: l.pad,
            frac_shift: l.frac_shift,
            relu: l.relu,
            gate_bits,
        }
    }
}

/// Pool cache key: everything the one-row pool plan and its task
/// program depend on. `ic`/`ih` are executor-side loop bounds, not
/// plan inputs, so they stay out of the key (the cached plan's
/// `n_tiles` is NOT meaningful across layers — the executor recomputes
/// it from the layer).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct PoolKey {
    iw: usize,
    size: usize,
    stride: usize,
}

/// One raw sampled row of a cold tile-analytic pass: the per-run
/// `(cycles, stats)` the cycle simulator returned.
pub(crate) struct SampleSet {
    /// Raw per-row samples, in the schedule order the cold pass ran
    /// them (at most `ANALYTIC_SAMPLES`; fewer when the layer has fewer
    /// rows of this task).
    pub rows: Vec<(u64, CoreStats)>,
    /// Sum of the sampled cycles (the cold pass's accumulator value).
    pub total_cycles: u64,
    /// Field-wise sum of the sampled stats.
    pub total_stats: CoreStats,
}

impl SampleSet {
    pub fn n(&self) -> u64 {
        self.rows.len() as u64
    }
}

/// The sampled-row record of one cold tile-analytic pass over a shape —
/// enough to replay every later pass without touching the core.
pub(crate) struct AnalyticProfile {
    pub samples: HashMap<TaskKey, SampleSet>,
}

/// A conv layer shape, compiled once: the layout plan plus the
/// assembled task program per distinct [`TaskKey`], plus the lazily
/// published tile-analytic profile.
pub struct CompiledConv {
    pub(crate) plan: ConvPlan,
    programs: HashMap<TaskKey, ProgramMem>,
    /// Published by the first successful tile-analytic pass; replayed
    /// bit-exactly by every later one (see the module docs for why
    /// that is sound). Racing first passes compute identical profiles,
    /// so whichever `set` wins is canonical.
    pub(crate) analytic: OnceLock<AnalyticProfile>,
}

impl CompiledConv {
    pub(crate) fn compile(layer: &ConvLayer) -> Result<Self, CodegenError> {
        let plan = layout::plan(layer)?;
        let mut programs = HashMap::new();
        for mi in 0..plan.m {
            let f = flavor_of(mi, plan.m);
            let key = (plan.slice_ics(mi), f.first_slice, f.last_slice);
            if !programs.contains_key(&key) {
                programs.insert(key, build_conv_task(&plan, key.0, f)?);
            }
        }
        Ok(Self { plan, programs, analytic: OnceLock::new() })
    }

    pub(crate) fn task_key(&self, mi: usize) -> TaskKey {
        let f = flavor_of(mi, self.plan.m);
        (self.plan.slice_ics(mi), f.first_slice, f.last_slice)
    }

    pub(crate) fn program(&self, key: &TaskKey) -> &ProgramMem {
        &self.programs[key]
    }
}

/// A pool layer shape, compiled once: the one-row plan, its task
/// program, and the single sampled-row analytic record (pool rows are
/// cycle-identical, so the seed executor already reused one sample per
/// call — the cache extends that across calls).
pub struct CompiledPool {
    pub(crate) plan: PoolPlan,
    pub(crate) pm: ProgramMem,
    pub(crate) analytic: OnceLock<(u64, CoreStats)>,
}

impl CompiledPool {
    pub(crate) fn compile(layer: &PoolLayer) -> Result<Self, CodegenError> {
        let one_row = PoolLayer { ih: layer.size, ..layer.clone() };
        let plan = plan_pool(&one_row)?;
        let pm = build_pool_task(&plan)?;
        Ok(Self { plan, pm, analytic: OnceLock::new() })
    }
}

/// Hit/miss counters and entry counts of a [`PlanCache`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub conv_entries: usize,
    pub pool_entries: usize,
}

/// The compile-once cache: shape-keyed compiled layers, shared (behind
/// an `Arc` on the engine) by every core thread, frame, shard and
/// pipeline stage. Disabled it still compiles — fresh on every call —
/// which is exactly the pre-cache behavior the `simspeed` bench uses
/// as its uncached baseline.
pub struct PlanCache {
    enabled: bool,
    conv: Mutex<HashMap<ConvKey, Arc<CompiledConv>>>,
    pool: Mutex<HashMap<PoolKey, Arc<CompiledPool>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl PlanCache {
    pub fn new() -> Self {
        Self {
            enabled: true,
            conv: Mutex::new(HashMap::new()),
            pool: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// A cache that never retains anything: every lookup compiles
    /// fresh (and counts as a miss). The analytic profile is likewise
    /// per-call, so execution behaves exactly like the pre-cache code.
    pub fn disabled() -> Self {
        Self { enabled: false, ..Self::new() }
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Compiled artifact for a dense (per-group) conv layer shape.
    pub(crate) fn conv(
        &self,
        layer: &ConvLayer,
        gate_bits: u8,
    ) -> Result<Arc<CompiledConv>, CodegenError> {
        if !self.enabled {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return CompiledConv::compile(layer).map(Arc::new);
        }
        let key = ConvKey::of(layer, gate_bits);
        // Compiling under the lock serializes racing first compiles of
        // one shape — cheaper than letting every core compile it.
        let mut map = self.conv.lock().expect("plan cache poisoned");
        if let Some(cc) = map.get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(cc.clone());
        }
        let cc = Arc::new(CompiledConv::compile(layer)?);
        map.insert(key, cc.clone());
        self.misses.fetch_add(1, Ordering::Relaxed);
        Ok(cc)
    }

    /// Compiled artifact for a pool layer shape.
    pub(crate) fn pool(&self, layer: &PoolLayer) -> Result<Arc<CompiledPool>, CodegenError> {
        if !self.enabled {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return CompiledPool::compile(layer).map(Arc::new);
        }
        let key = PoolKey { iw: layer.iw, size: layer.size, stride: layer.stride };
        let mut map = self.pool.lock().expect("plan cache poisoned");
        if let Some(cp) = map.get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(cp.clone());
        }
        let cp = Arc::new(CompiledPool::compile(layer)?);
        map.insert(key, cp.clone());
        self.misses.fetch_add(1, Ordering::Relaxed);
        Ok(cp)
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            conv_entries: self.conv.lock().expect("plan cache poisoned").len(),
            pool_entries: self.pool.lock().expect("plan cache poisoned").len(),
        }
    }
}

impl Default for PlanCache {
    fn default() -> Self {
        Self::new()
    }
}

/// Per-core staging arena: the host-side buffers a layer run stages
/// tensors through, reused across layers and frames. Every buffer is
/// reset (zero-filled to the exact length) before use, so a reused
/// arena is indistinguishable from fresh allocations — only the
/// allocator traffic disappears.
#[derive(Default)]
pub struct Scratch {
    /// Zero-padded input tensor (`stage::pad_input_into`).
    pub(crate) xp: Vec<i16>,
    /// Staged input band for one (slice, band) (`stage::input_band_into`).
    pub(crate) band: Vec<i16>,
    /// Filter stream for one (tile, slice) (`stage::filter_stream_into`).
    pub(crate) filt: Vec<i16>,
    /// One output row read back from the row buffer.
    pub(crate) row: Vec<i16>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> ConvLayer {
        ConvLayer::new("s", 4, 8, 8, 16, 3, 3, 1, 1, 1)
    }

    #[test]
    fn conv_keys_ignore_names_but_not_gate_bits() {
        let cache = PlanCache::new();
        let a = ConvLayer { name: "a", ..small() };
        let b = ConvLayer { name: "b", ..small() };
        let c1 = cache.conv(&a, 16).unwrap();
        let c2 = cache.conv(&b, 16).unwrap();
        assert!(Arc::ptr_eq(&c1, &c2), "same shape, different name must hit");
        let c3 = cache.conv(&a, 8).unwrap();
        assert!(!Arc::ptr_eq(&c1, &c3), "same shape, different gate bits must miss");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.conv_entries), (1, 2, 2));
    }

    #[test]
    fn disabled_cache_compiles_fresh_every_call() {
        let cache = PlanCache::disabled();
        let l = small();
        let c1 = cache.conv(&l, 16).unwrap();
        let c2 = cache.conv(&l, 16).unwrap();
        assert!(!Arc::ptr_eq(&c1, &c2));
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.conv_entries), (0, 2, 0));
    }

    #[test]
    fn compiled_conv_carries_every_task_program() {
        // a multi-slice shape needs first/middle/last flavors
        let l = ConvLayer::new("ms", 768, 6, 6, 16, 3, 3, 1, 1, 1);
        let cc = CompiledConv::compile(&l).unwrap();
        assert!(cc.plan.m > 1);
        for mi in 0..cc.plan.m {
            let key = cc.task_key(mi);
            assert!(cc.program(&key).bundle_count() > 0, "missing program for {key:?}");
        }
    }

    #[test]
    fn pool_keys_ignore_channel_count() {
        let cache = PlanCache::new();
        let p1 = PoolLayer { name: "p1", ic: 16, ih: 8, iw: 8, size: 2, stride: 2 };
        let p2 = PoolLayer { name: "p2", ic: 48, ih: 12, iw: 8, size: 2, stride: 2 };
        let c1 = cache.pool(&p1).unwrap();
        let c2 = cache.pool(&p2).unwrap();
        assert!(Arc::ptr_eq(&c1, &c2), "pool plans depend on (iw, size, stride) only");
        let p3 = PoolLayer { name: "p3", ic: 16, ih: 8, iw: 13, size: 2, stride: 2 };
        assert!(!Arc::ptr_eq(&c1, &cache.pool(&p3).unwrap()));
    }
}
