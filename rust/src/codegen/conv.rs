//! Conv task program builder.
//!
//! One *task* computes one output row of one output-channel tile for one
//! input-depth slice. The same program is reused for every row/tile of a
//! band (and across bands); per-task parameters arrive in registers set
//! by the coordinator (the "ABI" below). Software structure:
//!
//! ```text
//! CSR setup, bias load
//! for g in 0..G:                      (software loop, branch)
//!     rewind filter ptr; prime filter FIFO (2)
//!     LbLoad window(ic=0)             (2-D window when FH·win fits a slot)
//!     InitA(bias)            — first slice
//!     LdA ×12                — continuing slice (PSums, Fig. 2)
//!     loopi ics/2:                    (hardware loop, 2 ics per body for
//!                                      static line-buffer double-buffering)
//!         prefetch LbLoad(next) ∥ FH·FW × { ldvf ∥ 3×vmac }
//!     [tail ic if ics odd]
//!     QMov ×4 ∥ ×3 slots     — last slice (requant + ReLU)
//!     flush filter FIFO (2)
//!     StV ×12                — last slice   (OFMap row buffer)
//!     StA ×12                — other slices (PSum row buffer)
//!     advance group pointers, branch
//! halt
//! ```
//!
//! ABI (set by the coordinator before `Cpu::run`):
//!
//! | reg | meaning                                         |
//! |-----|--------------------------------------------------|
//! | r2  | input base for this row (= dm.input + oh_local·S·row_bytes) |
//! | r4  | output row buffer base (= dm.out)               |
//! | r5  | psum row buffer base (= dm.psum)                |
//! | r6  | filter stream base (= dm.filt)                  |
//!
//! r0/r1/r3/r7..r10 are clobbered by the program.
//!
//! **Verified invariants.** Every program this builder emits is checked
//! by the static verifier (`isa::analysis`, on plan-cache insert in
//! debug builds and via the `lint` CLI) against [`AbiSpec::conv`]
//! (r2/r4/r5/r6 predefined, `RoundMode`/`GateBits` host-owned). The
//! load-bearing invariants the passes rely on:
//!
//! * `LbStride` and `FracShift` are written in the prologue, before any
//!   line-buffer read or `InitA`/`QMov` — the dataflow pass treats them
//!   as *undefined* at entry.
//! * filter-FIFO pushes (`LdVF`) and FIFO-sourced MACs balance exactly
//!   on every path: primed by 2 before the ic loop, drained by 2 after,
//!   equal depth at every join, zero at `Halt`.
//! * DMA is not used by task programs (staging is the coordinator's
//!   job), so the DMA-protocol lints are trivially clean here.
//! * every `LbLoad` extent covers the widest subsequent `Lb`/`LbVec`
//!   read of that row under the programmed stride.
//!
//! [`AbiSpec::conv`]: crate::isa::analysis::AbiSpec::conv

use crate::isa::analysis::memory::{MemSpec, Region};
use crate::isa::*;
use crate::mem::pm::ProgramMem;

use super::layout::{ConvPlan, Variant};
use super::CodegenError;

/// Which slice of the Fig. 2 depth slicing this task executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TaskFlavor {
    /// First input slice: accumulators start from the bias.
    pub first_slice: bool,
    /// Last input slice: requantize + store OFMap (else spill PSums).
    pub last_slice: bool,
}

impl TaskFlavor {
    pub fn single() -> Self {
        Self { first_slice: true, last_slice: true }
    }
}

/// The memory contract a conv task of this flavor is checked against by
/// the `isa::analysis::memory` pass: the plan's `DmMap` regions with
/// per-flavor permissions. Filter reads include the 64 B FIFO over-read
/// slack (part of the `filt` region by construction); staged-input reads
/// include the prefetch slack band (`input..end`); the PSum buffer is
/// readable only on continuing slices and writable only on non-final
/// ones, so a single-slice program touching it at all is a finding.
/// When the plan rotates ([`ConvPlan::rot`]), the inactive shadow
/// buffers are a no-access region: the host prefetches the next
/// (tile, slice, band) stream into them while this task runs, so a
/// compute access landing there is a DMA race and the pass flags it
/// ([`mem_spec_phase_b`] is the other rotation phase, with the
/// active/inactive roles swapped).
pub fn mem_spec(plan: &ConvPlan, flavor: TaskFlavor) -> MemSpec {
    let dm = &plan.dm;
    let mut regions = vec![
        Region::new("bias", dm.bias, dm.filt, true, false),
        Region::new("filt", dm.filt, dm.out, true, false),
        Region::new("out", dm.out, dm.psum, false, flavor.last_slice),
        Region::new("psum", dm.psum, dm.input, !flavor.first_slice, !flavor.last_slice),
        Region::new("input", dm.input, dm.end, true, false),
    ];
    if let Some(r) = &plan.rot {
        regions.push(Region::new("rot_filt", r.bias, r.input, false, false));
        regions.push(Region::new("rot_input", r.input, r.end, false, false));
    }
    MemSpec::with_regions(regions)
}

/// Phase-B memory contract of a rotated plan: the shadow bias/filter/
/// input slots are the live ones (the task ABI re-bases r2/r6 onto
/// them) and the PRIMARY bias/filter/input regions are the inactive
/// prefetch target (no access). The out/psum row buffers are shared
/// between phases — rows commit from the same buffers either way.
/// `None` when the plan does not rotate.
pub fn mem_spec_phase_b(plan: &ConvPlan, flavor: TaskFlavor) -> Option<MemSpec> {
    let dm = &plan.dm;
    let r = plan.rot.as_ref()?;
    Some(MemSpec::with_regions(vec![
        Region::new("inactive_filt", dm.bias, dm.out, false, false),
        Region::new("out", dm.out, dm.psum, false, flavor.last_slice),
        Region::new("psum", dm.psum, dm.input, !flavor.first_slice, !flavor.last_slice),
        Region::new("inactive_input", dm.input, dm.end, false, false),
        Region::new("bias", r.bias, r.filt, true, false),
        Region::new("filt", r.filt, r.input, true, false),
        Region::new("input", r.input, r.end, true, false),
    ]))
}

const R0: SReg = SReg(0); // zero
const RF: SReg = SReg(1); // filter walk
const RIN: SReg = SReg(2); // ABI: row input base
const RIC: SReg = SReg(3); // ic walker
const ROUT: SReg = SReg(4); // ABI: out row buffer
const RPS: SReg = SReg(5); // ABI: psum row buffer
const RFB: SReg = SReg(6); // ABI: filter base
const RG: SReg = SReg(7); // group counter
const RGIN: SReg = SReg(8); // group input base
const RGOUT: SReg = SReg(9); // group out ptr
const RGPS: SReg = SReg(10); // group psum ptr

/// Build the task program for `plan` with `slice_ics` input channels
/// (the last slice may be smaller than `plan.ics`).
///
/// Pure function of `(plan, slice_ics, flavor)` — `codegen::compiled`
/// memoizes the result per layer shape, so any new input (a CSR knob,
/// a mode flag) must flow through the plan or the cache key rather
/// than ambient state.
pub fn build_conv_task(
    plan: &ConvPlan,
    slice_ics: usize,
    flavor: TaskFlavor,
) -> Result<ProgramMem, CodegenError> {
    let l = &plan.layer;
    let s = l.stride;
    let win = plan.win as u16;
    let row_bytes = plan.row_bytes as u16;
    let ic_stride = plan.ic_stride;
    assert!(2 * ic_stride <= u16::MAX as usize);
    let (nrows, rstride) = if plan.fused_rows {
        (l.fh as u8, row_bytes)
    } else {
        (1u8, 0u16)
    };

    let mut p = Program::default();
    let b = &mut p.bundles;

    // ---- prologue -------------------------------------------------------
    b.push(Bundle::s0(SlotOp::Csrwi { csr: Csr::LbStride, imm: s as u32 }));
    b.push(Bundle::s0(SlotOp::Csrwi { csr: Csr::FracShift, imm: l.frac_shift as u32 }));
    b.push(Bundle::s0(SlotOp::Li { rd: R0, imm: 0 }));
    b.push(Bundle::s0(SlotOp::Li { rd: RG, imm: plan.g as i32 }));
    // bias vector lives 32 B below the filter stream
    b.push(Bundle::s0(SlotOp::LdV { vd: VReg(0), addr: Addr::offs(RFB, -32) }));
    b.push(Bundle::s0(mv(RGIN, RIN)));
    b.push(Bundle::s0(mv(RGOUT, ROUT)));
    b.push(Bundle::s0(mv(RGPS, RPS)));

    // ---- group loop ------------------------------------------------------
    let group_top = b.len() as u32;
    b.push(Bundle::s0(mv(RF, RFB)));
    b.push(Bundle::s0(mv(RIC, RGIN)));
    b.push(Bundle::s0(SlotOp::LdVF { addr: Addr::post(RF, 32) })); // prime 0
    b.push(Bundle::s0(SlotOp::LdVF { addr: Addr::post(RF, 32) })); // prime 1
    // stage the first input window (ic 0)
    b.push(Bundle::s0(SlotOp::LbLoad {
        row: 0,
        dm: RIC,
        off: 0,
        win: win as u8,
        nrows,
        rstride,
    }));

    // accumulator init
    if flavor.first_slice {
        b.push(Bundle {
            slot0: SlotOp::Nop,
            v: std::array::from_fn(|i| init_op(plan.variant, i as u8 + 1)),
        });
    } else {
        for k in 0..12u8 {
            b.push(Bundle::s0(SlotOp::LdA {
                ad: VAcc(k),
                addr: Addr::offs(RGPS, k as i32 * 64),
            }));
        }
    }

    // ---- hardware loop over ic pairs -------------------------------------
    let half = slice_ics / 2;
    let tail = slice_ics % 2 == 1;
    if half > 0 {
        let body = body_bundles(plan, l.fh, l.fw);
        b.push(Bundle::s0(SlotOp::LoopI { n: half as u32, body: body as u16 }));
        emit_ic_pair(b, plan, s, win, nrows, rstride, row_bytes, ic_stride);
    }
    if tail {
        emit_tail_ic(b, plan, s, win, row_bytes);
    }

    // ---- epilogue ---------------------------------------------------------
    if flavor.last_slice {
        // requantize: bundle j does QMov(j) on all three slots
        for j in 0..4u8 {
            b.push(Bundle {
                slot0: SlotOp::Nop,
                v: std::array::from_fn(|i| {
                    let slot = i as u8 + 1;
                    VecOp::QMov { vd: VReg(slot * 4 + j), j, relu: l.relu }
                }),
            });
        }
        // flush the 2 primed-ahead FIFO entries (accs are dead now)
        for _ in 0..2 {
            b.push(flush_bundle(plan.variant));
        }
        // store the 12 output vectors
        for pidx in 0..12u8 {
            let slot = pidx / 4 + 1;
            let j = pidx % 4;
            let offset = match plan.variant {
                Variant::A => pidx as i32 * 32,
                Variant::B => pidx as i32 * (plan.g * 16 * 2) as i32,
            };
            b.push(Bundle::s0(SlotOp::StV {
                vs: VReg(slot * 4 + j),
                addr: Addr::offs(RGOUT, offset),
            }));
        }
    } else {
        // spill PSums, then flush
        for k in 0..12u8 {
            b.push(Bundle::s0(SlotOp::StA {
                as_: VAcc(k),
                addr: Addr::offs(RGPS, k as i32 * 64),
            }));
        }
        for _ in 0..2 {
            b.push(flush_bundle(plan.variant));
        }
    }

    // ---- advance & loop ----------------------------------------------------
    let pix = plan.variant.pix();
    b.push(Bundle::s0(addi(RGIN, (pix * s * 2) as i32)));
    let out_adv = match plan.variant {
        Variant::A => (pix * 32) as i32,
        Variant::B => 32,
    };
    b.push(Bundle::s0(addi(RGOUT, out_adv)));
    if !(flavor.first_slice && flavor.last_slice) {
        b.push(Bundle::s0(addi(RGPS, 768)));
    }
    b.push(Bundle::s0(addi(RG, -1)));
    b.push(Bundle::s0(SlotOp::Br { c: Cond::Ne, ra: RG, rb: R0, target: group_top }));
    b.push(Bundle::s0(SlotOp::Halt));

    Ok(ProgramMem::load(&p)?)
}

/// Bundles in one hardware-loop body (2 input channels).
fn body_bundles(plan: &ConvPlan, fh: usize, fw: usize) -> usize {
    if plan.fused_rows {
        2 + 2 * fh * fw + 1
    } else {
        2 * fh + 2 * fh * fw + 1
    }
}

fn mv(rd: SReg, rs: SReg) -> SlotOp {
    SlotOp::Alu { f: AluFn::Add, w: Width::W32, rd, ra: rs, rb: R0 }
}

fn addi(rd: SReg, imm: i32) -> SlotOp {
    SlotOp::AluI { f: AluFn::Add, w: Width::W32, rd, ra: rd, imm }
}

fn init_op(v: Variant, slot: u8) -> VecOp {
    match v {
        Variant::A => VecOp::InitA { vr: VReg(0) },
        Variant::B => VecOp::InitALane { vr: VReg(0), base: (slot - 1) * 4 },
    }
}

/// The MAC for (slot, fy, fx) reading LB slot `buf`.
fn mac_op(plan: &ConvPlan, slot: u8, buf: u8, fy: usize, fx: usize) -> VecOp {
    let s = plan.layer.stride;
    let base = if plan.fused_rows { fy * plan.win } else { 0 };
    match plan.variant {
        Variant::A => VecOp::Mac {
            a: ASrc::Lb {
                row: buf,
                off: (base + fx + (slot as usize - 1) * 4 * s) as u16,
            },
            b: BSrc::Fifo,
        },
        Variant::B => VecOp::Mac {
            a: ASrc::LbVec { row: buf, off: (base + fx) as u16 },
            b: BSrc::FifoLaneQuad { base: (slot - 1) * 4 },
        },
    }
}

fn mac_bundle(plan: &ConvPlan, buf: u8, fy: usize, fx: usize, ldvf: bool) -> Bundle {
    Bundle {
        slot0: if ldvf {
            SlotOp::LdVF { addr: Addr::post(RF, 32) }
        } else {
            SlotOp::Nop
        },
        v: std::array::from_fn(|i| mac_op(plan, i as u8 + 1, buf, fy, fx)),
    }
}

/// A FIFO-draining bundle: one dead MUL into slot 1's accumulators.
fn flush_bundle(v: Variant) -> Bundle {
    let a = match v {
        Variant::A => ASrc::Lb { row: 0, off: 0 },
        Variant::B => ASrc::LbVec { row: 0, off: 0 },
    };
    let bsrc = match v {
        Variant::A => BSrc::Fifo,
        Variant::B => BSrc::FifoLaneQuad { base: 0 },
    };
    Bundle {
        slot0: SlotOp::Nop,
        v: [VecOp::Mul { a, b: bsrc }, VecOp::Nop, VecOp::Nop],
    }
}

/// Emit the hardware-loop body processing input channels (e, e+1):
/// prefetch e+1 into buf 1, MACs on buf 0, prefetch e+2 into buf 0,
/// MACs on buf 1, advance the ic walker.
#[allow(clippy::too_many_arguments)]
fn emit_ic_pair(
    b: &mut Vec<Bundle>,
    plan: &ConvPlan,
    _s: usize,
    win: u16,
    nrows: u8,
    rstride: u16,
    row_bytes: u16,
    ic_stride: usize,
) {
    let l = &plan.layer;
    if plan.fused_rows {
        // prefetch odd ic window
        b.push(Bundle::s0(SlotOp::LbLoad {
            row: 1,
            dm: RIC,
            off: ic_stride as u16,
            win: win as u8,
            nrows,
            rstride,
        }));
        for fy in 0..l.fh {
            for fx in 0..l.fw {
                b.push(mac_bundle(plan, 0, fy, fx, true));
            }
        }
        // prefetch even ic of the NEXT pair
        b.push(Bundle::s0(SlotOp::LbLoad {
            row: 0,
            dm: RIC,
            off: (2 * ic_stride) as u16,
            win: win as u8,
            nrows,
            rstride,
        }));
        for fy in 0..l.fh {
            for fx in 0..l.fw {
                b.push(mac_bundle(plan, 1, fy, fx, true));
            }
        }
    } else {
        // per-(ic,fy) single-row windows; global row index g = icpar*FH+fy,
        // buffer parity g&1, prefetch one row ahead.
        let off_of = |g: usize| -> u16 {
            let icn = g / l.fh;
            let fyn = g % l.fh;
            (icn * ic_stride + fyn * row_bytes as usize) as u16
        };
        for icpar in 0..2usize {
            for fy in 0..l.fh {
                let g = icpar * l.fh + fy;
                b.push(Bundle::s0(SlotOp::LbLoad {
                    row: ((g + 1) & 1) as u8,
                    dm: RIC,
                    off: off_of(g + 1),
                    win: win as u8,
                    nrows: 1,
                    rstride: 0,
                }));
                for fx in 0..l.fw {
                    b.push(mac_bundle(plan, (g & 1) as u8, fy, fx, true));
                }
            }
        }
    }
    b.push(Bundle::s0(addi(RIC, (2 * ic_stride) as i32)));
}

/// Tail input channel (odd slice size). Its data sits in buf 0: either
/// prefetched by the last loop iteration, or (half == 0) by the prologue
/// LbLoad. Non-fused mode loads rows fy>0 inline.
fn emit_tail_ic(b: &mut Vec<Bundle>, plan: &ConvPlan, _s: usize, win: u16, row_bytes: u16) {
    let l = &plan.layer;
    if plan.fused_rows {
        for fy in 0..l.fh {
            for fx in 0..l.fw {
                b.push(mac_bundle(plan, 0, fy, fx, true));
            }
        }
    } else {
        for fy in 0..l.fh {
            if fy + 1 < l.fh {
                b.push(Bundle::s0(SlotOp::LbLoad {
                    row: ((fy + 1) & 1) as u8,
                    dm: RIC,
                    off: (fy as u16 + 1) * row_bytes,
                    win: win as u8,
                    nrows: 1,
                    rstride: 0,
                }));
            }
            for fx in 0..l.fw {
                b.push(mac_bundle(plan, (fy & 1) as u8, fy, fx, true));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::layout::plan;
    use super::*;
    use crate::model::{alexnet_conv, vgg16_conv, ConvLayer};

    #[test]
    fn all_benchmark_tasks_fit_pm() {
        for l in alexnet_conv().iter().chain(vgg16_conv().iter()) {
            let d = l.per_group();
            let p = plan(&d).unwrap();
            for (first, last) in [(true, true), (true, false), (false, false), (false, true)] {
                let pm = build_conv_task(
                    &p,
                    p.slice_ics(0),
                    TaskFlavor { first_slice: first, last_slice: last },
                )
                .unwrap_or_else(|e| panic!("{} ({first},{last}): {e}", l.name));
                assert!(pm.bundle_count() <= 512, "{}", l.name);
            }
        }
    }

    #[test]
    fn fifo_balance_is_exact() {
        // pushes (2 primes + ldvf per mac bundle) == pops (mac bundles +
        // 2 flushes) per group — statically checkable on the program.
        for l in alexnet_conv().iter().chain(vgg16_conv().iter()) {
            let d = l.per_group();
            let p = plan(&d).unwrap();
            let pm = build_conv_task(&p, p.slice_ics(0), TaskFlavor::single()).unwrap();
            let prog = pm.program();
            let mut pushes = 0i64;
            let mut pops = 0i64;
            for bd in &prog.bundles {
                if matches!(bd.slot0, SlotOp::LdVF { .. }) {
                    pushes += 1;
                }
                if bd.v.iter().any(|op| {
                    matches!(
                        op,
                        VecOp::Mac { b: BSrc::Fifo | BSrc::FifoLaneQuad { .. }, .. }
                            | VecOp::Mul { b: BSrc::Fifo | BSrc::FifoLaneQuad { .. }, .. }
                    )
                }) {
                    pops += 1;
                }
            }
            // hardware loop multiplies body counts; account for it
            let half = (p.slice_ics(0) / 2) as i64;
            let body_push = (2 * d.fh * d.fw) as i64;
            let body_pop = body_push;
            let static_extra = (half - 1).max(0);
            let total_push = pushes + static_extra * body_push;
            let total_pop = pops + static_extra * body_pop;
            assert_eq!(total_push, total_pop, "{}", l.name);
        }
    }

    #[test]
    fn peak_bundle_present() {
        let l = ConvLayer::new("t", 8, 16, 16, 16, 3, 3, 1, 1, 1);
        let p = plan(&l).unwrap();
        let pm = build_conv_task(&p, 8, TaskFlavor::single()).unwrap();
        let has_full_mac = pm
            .program()
            .bundles
            .iter()
            .any(|b| b.mac_count() == crate::PEAK_MACS_PER_CYCLE);
        assert!(has_full_mac);
    }
}
