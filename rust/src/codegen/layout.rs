//! Layout planner: the Fig. 2 slicing decisions.
//!
//! For one (dense, per-group) conv layer it chooses
//!
//! * the lane-mapping **variant** (A: lanes = OCh; B: lanes = pixels) by
//!   estimated utilization under LB-capacity feasibility,
//! * the input-depth slice size `ics` (→ `M = ceil(ic/ics)` slices; when
//!   `M > 1` partial sums spill per the paper),
//! * the output-row **band** size (how many output rows' worth of input
//!   is staged in DM at once — the coarse-grained form of the paper's
//!   row-wise streaming),
//! * the DM memory map for one task,
//! * the tile/band loop order minimizing off-chip I/O.
//!
//! All hardware limits are enforced here: 128 KB DM, 64-pixel LB row
//! slots, u16 LbLoad offsets, 512-bundle PM (estimated, re-checked on
//! build).

use crate::mem::linebuf::LB_ROW_PIXELS;
use crate::mem::DM_BYTES;
use crate::model::ConvLayer;

use super::CodegenError;

/// Lane mapping of the vector MACs (DESIGN.md §4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// 16 lanes = output channels; 3 slots × 4 slices = 12 output pixels.
    A,
    /// 16 lanes = output pixels; 3 slots × 4 slices = 12 output channels.
    B,
}

impl Variant {
    /// Output pixels covered per group.
    pub fn pix(self) -> usize {
        match self {
            Variant::A => 12,
            Variant::B => 16,
        }
    }
    /// Output channels covered per tile.
    pub fn ocs(self) -> usize {
        match self {
            Variant::A => 16,
            Variant::B => 12,
        }
    }
}

/// Loop order of the outer coordinator loops (I/O trade-off):
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoopOrder {
    /// `for tile { for band { stream input } }` — filters loaded once,
    /// input re-streamed per tile.
    TileOuter,
    /// `for band { for tile { load filters } }` — input streamed once,
    /// filters re-loaded per band.
    BandOuter,
}

/// DM region addresses for one task (bytes).
#[derive(Debug, Clone)]
pub struct DmMap {
    /// Bias vector (32 B), placed directly below the filters.
    pub bias: usize,
    /// Filter stream (K·32 B + 64 B over-read slack).
    pub filt: usize,
    /// Output row buffer (G·384 B).
    pub out: usize,
    /// PSum row buffer (G·768 B) — used when `m > 1`.
    pub psum: usize,
    /// Staged input band (ics · ic_stride + prefetch slack).
    pub input: usize,
    /// Total bytes used (including slack).
    pub end: usize,
}

/// Double-buffer rotation shadow appended past [`DmMap::end`] when it
/// fits: a second bias+filter slot and a second staged-input band, so
/// the coordinator can prefetch the NEXT (tile, slice, band) stream
/// while the current one computes. Shadow regions mirror the primary
/// layout byte-for-byte ([`DmRot::bias`] is 32-aligned so vector
/// accesses keep the primary phase's DM alignment); the out/psum row
/// buffers are NOT doubled — rows commit from the same buffers in both
/// phases. The memory verifier checks both phases: in each phase the
/// inactive buffer pair is a no-access region, so any compute access
/// into the in-flight prefetch target is flagged (the DmaRace
/// discipline for the host-staged transfers).
#[derive(Debug, Clone)]
pub struct DmRot {
    /// Shadow bias vector (32 B).
    pub bias: usize,
    /// Shadow filter stream (same size as `[dm.filt, dm.out)`).
    pub filt: usize,
    /// Shadow staged input band (same size as `[dm.input, dm.end)`).
    pub input: usize,
    /// First byte past the rotation shadow (`<= DM_BYTES`).
    pub end: usize,
}

/// Complete plan for one dense conv layer.
#[derive(Debug, Clone)]
pub struct ConvPlan {
    pub layer: ConvLayer,
    pub variant: Variant,
    /// Input channels per slice (uniform; last slice may be smaller).
    pub ics: usize,
    /// Number of input-depth slices.
    pub m: usize,
    /// Output rows per band.
    pub band_rows: usize,
    pub n_bands: usize,
    /// Output-channel tiles.
    pub n_tiles: usize,
    /// Pixel groups per output row.
    pub g: usize,
    /// LB window pixels per source row.
    pub win: usize,
    /// One 2-D LbLoad per input channel (FH rows at once)?
    pub fused_rows: bool,
    /// Staged row width in pixels (padded for window overrun).
    pub iwp_stage: usize,
    pub row_bytes: usize,
    /// Input rows staged per band.
    pub in_rows_band: usize,
    /// Bytes between consecutive input channels in the staged band.
    pub ic_stride: usize,
    pub dm: DmMap,
    /// Double-buffer rotation shadow — `Some` when DM holds a second
    /// filter-block + input-band slot beside the working map, `None`
    /// when the stream must serialize against compute. Derived purely
    /// from the layer *shape* (plus the planner's `rotate` knob), so
    /// the compiled-plan cache key stays shape-only.
    pub rot: Option<DmRot>,
    pub loop_order: LoopOrder,
    /// Planner cost estimate in cycles: `max(compute, dma)` when the
    /// plan rotates, `compute + dma` when it serializes.
    pub est_cost: f64,
}

impl ConvPlan {
    /// Estimated steady-state utilization ceiling (used to pick the
    /// variant; the true number comes from cycle simulation).
    pub fn util_estimate(&self) -> f64 {
        let l = &self.layer;
        let k2 = 2 * l.fh * l.fw; // mac bundles per 2-ic body
        let body = if self.fused_rows { 2 + k2 + 1 } else { 2 * l.fh + k2 + 1 };
        let pix_eff = l.ow() as f64 / (self.g * self.variant.pix()) as f64;
        let oc_eff = l.oc as f64 / (self.n_tiles * self.variant.ocs()) as f64;
        (k2 as f64 / body as f64) * pix_eff * oc_eff
    }

    /// Bytes of filters for one (tile, slice): K vectors of 32 B.
    pub fn filter_bytes(&self, slice_ics: usize) -> usize {
        slice_ics * self.layer.fh * self.layer.fw * 32
    }

    /// Off-chip bytes the executor charges for one (tile, slice)
    /// filter+bias stream: the filter vectors, the 2 FIFO over-read
    /// slack vectors, and the 32 B bias. THE single definition — the
    /// executor's I/O accounting and the FC weight-residency model
    /// must subtract exactly what was charged.
    pub fn filter_stream_bytes(&self, mi: usize) -> u64 {
        ((self.slice_ics(mi) * self.layer.fh * self.layer.fw + 2) * 32 + 32) as u64
    }

    /// Output row-buffer bytes (identical for both variants: G·384).
    pub fn out_row_bytes(&self) -> usize {
        self.g * self.variant.pix() * self.variant.ocs() * 2
    }

    /// PSum row-buffer bytes (i32 accumulators: G·768).
    pub fn psum_row_bytes(&self) -> usize {
        2 * self.out_row_bytes()
    }

    /// Input channels in slice `mi`.
    pub fn slice_ics(&self, mi: usize) -> usize {
        let l = &self.layer;
        if mi + 1 == self.m {
            l.ic - mi * self.ics
        } else {
            self.ics
        }
    }

    /// Output rows in band `bi`.
    pub fn band_rows_of(&self, bi: usize) -> usize {
        let oh = self.layer.oh();
        if bi + 1 == self.n_bands {
            oh - bi * self.band_rows
        } else {
            self.band_rows
        }
    }
}

/// Plan a dense (per-group) conv layer. `layer.groups` must be 1.
///
/// Deterministic in the layer's *shape*: two layers differing only in
/// `name` plan identically. `codegen::compiled` relies on this — its
/// cache key ([`crate::codegen::compiled`]) mirrors every
/// plan-relevant `ConvLayer` field except the name, so a new field
/// that influences planning or codegen MUST also be added to the
/// cache key, or same-key layers would share a stale plan.
pub fn plan(layer: &ConvLayer) -> Result<ConvPlan, CodegenError> {
    plan_with(layer, true)
}

/// [`plan`] with an explicit rotation knob: `rotate = false` forbids
/// the double-buffer shadow, so every candidate is priced with its
/// stream serialized (`compute + dma`) — the honest no-double-buffering
/// baseline the rotation-identity tests and `--no-rotation` use.
pub fn plan_with(layer: &ConvLayer, rotate: bool) -> Result<ConvPlan, CodegenError> {
    assert_eq!(layer.groups, 1, "plan() takes per-group dense views");
    let a = plan_variant_with(layer, Variant::A, rotate);
    let b = plan_variant_with(layer, Variant::B, rotate);
    match (a, b) {
        (Ok(pa), Ok(pb)) => Ok(if pa.est_cost <= pb.est_cost { pa } else { pb }),
        (Ok(pa), Err(_)) => Ok(pa),
        (Err(_), Ok(pb)) => Ok(pb),
        (Err(e), Err(_)) => Err(e),
    }
}

/// Plan a specific variant with rotation allowed (public for the
/// ablation bench).
pub fn plan_variant(layer: &ConvLayer, variant: Variant) -> Result<ConvPlan, CodegenError> {
    plan_variant_with(layer, variant, true)
}

/// Plan a specific variant: joint search over (ics, band_rows,
/// rotation, loop order) minimizing the estimated layer time. A
/// candidate whose DM also fits the [`DmRot`] shadow prices at
/// `max(compute, dma)` (steady-state double-buffered overlap); one
/// that does not prices at `compute + dma` (the stream serializes
/// against compute) — so the search trades staged-band size against
/// overlap instead of assuming overlap for free.
pub fn plan_variant_with(
    layer: &ConvLayer,
    variant: Variant,
    rotate: bool,
) -> Result<ConvPlan, CodegenError> {
    let l = layer;
    let s = l.stride;
    let pix = variant.pix();
    let ocs = variant.ocs();
    let win = (pix - 1) * s + l.fw;
    if win > LB_ROW_PIXELS {
        return Err(CodegenError::Infeasible(format!(
            "{}: window {win}px exceeds LB row ({LB_ROW_PIXELS}px), variant {variant:?}",
            l.name
        )));
    }
    let fused_rows = l.fh * win <= LB_ROW_PIXELS;
    let g = l.ow().div_ceil(pix);
    let n_tiles = l.oc.div_ceil(ocs);
    // staged row must cover the last group's window
    let iwp_stage = (g - 1) * pix * s + win;
    let row_bytes = iwp_stage * 2;
    let out_row = g * pix * ocs * 2;
    let psum_row = 2 * out_row;

    let mut best: Option<(f64, ConvPlan)> = None;
    let mut ics = l.ic;
    while ics >= 1 {
        let m = l.ic.div_ceil(ics);
        // PM estimate: per-2-ic body + fixed overhead (re-checked on build)
        let body = if fused_rows { 2 + 2 * l.fh * l.fw + 1 } else { 2 * l.fh + 2 * l.fh * l.fw + 1 };
        let tail = if ics % 2 == 1 { body / 2 + 1 } else { 0 };
        if body + tail + 64 > 500 {
            ics /= 2;
            continue;
        }
        // max feasible band_rows for this ics, once per rotation mode:
        // a rotated candidate must also fit the DmRot shadow (second
        // filter block + second input band), so it may settle on a
        // smaller band than the serialized one.
        let filt = ics * l.fh * l.fw * 32 + 64;
        for rotated in [false, true] {
            if rotated && !rotate {
                continue;
            }
            let mut band_rows = l.oh();
            let found = loop {
                if band_rows == 0 {
                    break None;
                }
                let in_rows = (band_rows - 1) * s + l.fh;
                let ic_stride = in_rows * row_bytes;
                // u16 LbLoad offset limit: prefetch offsets go up to 2·ic_stride
                if 2 * ic_stride <= u16::MAX as usize {
                    let input = ics * ic_stride;
                    let slack = 2 * ic_stride + win * 2; // prefetch over-read
                    let total = 32 + filt + out_row + psum_row + input + slack;
                    // shadow = 32-aligned base + bias + filt + input band
                    let footprint = if rotated {
                        total.div_ceil(32) * 32 + 32 + filt + input + slack
                    } else {
                        total
                    };
                    if footprint <= DM_BYTES {
                        break Some((band_rows, in_rows, ic_stride, total));
                    }
                }
                band_rows = if band_rows > 8 { band_rows / 2 } else { band_rows - 1 };
            };
            let Some((band_rows, in_rows, ic_stride, total)) = found else {
                continue;
            };
            let n_bands = l.oh().div_ceil(band_rows);
            // I/O estimate (ring accounting: band overlap rows are not
            // re-fetched within one streaming pass)
            let input_once = (l.ic * l.ihp().max(in_rows) * row_bytes) as f64;
            let filt_once =
                (n_tiles * (l.ic * l.fh * l.fw + 2 * m) * 32 + 32 * n_tiles * m) as f64;
            let psum_io = if m > 1 {
                (2 * (m - 1) * l.oh() * psum_row * n_tiles) as f64
            } else {
                0.0
            };
            let out_io = (l.oh() * n_tiles) as f64
                * match variant {
                    Variant::A => (l.ow() * 32) as f64,
                    Variant::B => (l.ow() * 2 * ocs) as f64,
                };
            // compute estimate from the bundle model
            let rows_cycles = {
                let per2ic = body as f64;
                let groups = g as f64;
                let per_row = groups * (per2ic * (ics as f64 / 2.0) + 36.0);
                per_row * (l.oh() * n_tiles * m) as f64
            };
            let input_sz = ics * ic_stride;
            let slack = 2 * ic_stride + win * 2;
            let rot = rotated.then(|| {
                let base = total.div_ceil(32) * 32;
                DmRot {
                    bias: base,
                    filt: base + 32,
                    input: base + 32 + filt,
                    end: base + 32 + filt + input_sz + slack,
                }
            });
            for order in [LoopOrder::TileOuter, LoopOrder::BandOuter] {
                let (input_io, filt_io) = match order {
                    LoopOrder::TileOuter => (input_once * n_tiles as f64, filt_once),
                    LoopOrder::BandOuter => (input_once, filt_once * n_bands as f64),
                };
                let io = input_io + filt_io + psum_io + out_io;
                let dma_est = io / crate::mem::EXT_BYTES_PER_CYCLE as f64;
                // rotated: steady-state overlap; serialized: honest sum
                let cost =
                    if rotated { rows_cycles.max(dma_est) } else { rows_cycles + dma_est };
                if best.as_ref().is_none_or(|(c, _)| cost < *c) {
                    let dm = DmMap {
                        bias: 0,
                        filt: 32,
                        out: 32 + filt,
                        psum: 32 + filt + out_row,
                        input: 32 + filt + out_row + psum_row,
                        end: total,
                    };
                    best = Some((
                        cost,
                        ConvPlan {
                            layer: l.clone(),
                            variant,
                            ics,
                            m,
                            band_rows,
                            n_bands,
                            n_tiles,
                            g,
                            win,
                            fused_rows,
                            iwp_stage,
                            row_bytes,
                            in_rows_band: in_rows,
                            ic_stride,
                            dm,
                            rot: rot.clone(),
                            loop_order: order,
                            est_cost: cost,
                        },
                    ));
                }
            }
        }
        ics /= 2;
    }
    best.map(|(_, p)| p)
        .ok_or_else(|| CodegenError::Infeasible(l.name.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{alexnet_conv, vgg16_conv};

    #[test]
    fn all_benchmark_layers_plan() {
        for l in alexnet_conv().iter().chain(vgg16_conv().iter()) {
            let d = l.per_group();
            let p = plan(&d).unwrap_or_else(|e| panic!("{}: {e}", l.name));
            assert!(p.dm.end <= DM_BYTES, "{} overflows DM", l.name);
            if let Some(r) = &p.rot {
                assert!(r.end <= DM_BYTES, "{} rotation shadow overflows DM", l.name);
            }
            assert!(p.util_estimate() > 0.3, "{}: est {}", l.name, p.util_estimate());
        }
    }

    /// Every AlexNet/VGG-16 conv layer fits a rotation shadow at SOME
    /// (ics, band_rows) point, and the shadow mirrors the primary
    /// filter/input regions byte-for-byte at a 32-aligned base past
    /// `dm.end` — the pairwise-disjointness the memory verifier
    /// machine-checks holds by construction.
    #[test]
    fn benchmark_layers_rotate_with_shadow_in_bounds() {
        for l in alexnet_conv().iter().chain(vgg16_conv().iter()) {
            let p = plan(&l.per_group()).unwrap();
            let r = p.rot.as_ref().unwrap_or_else(|| panic!("{} should rotate", l.name));
            assert!(r.end <= DM_BYTES, "{}: shadow end {}", l.name, r.end);
            assert!(r.bias >= p.dm.end, "{}: shadow under dm.end", l.name);
            assert_eq!(r.bias % 32, 0, "{}: shadow base unaligned", l.name);
            assert_eq!(r.filt - r.bias, p.dm.filt - p.dm.bias, "{}: bias slot", l.name);
            assert_eq!(r.input - r.filt, p.dm.out - p.dm.filt, "{}: filter slot", l.name);
            assert_eq!(r.end - r.input, p.dm.end - p.dm.input, "{}: input slot", l.name);
        }
    }

    /// A layer whose base map fills DM past the point where a shadow
    /// could ever fit (single input channel, so `ics` cannot shrink;
    /// one output row, so `band_rows` cannot shrink) must plan WITHOUT
    /// rotation — the executor prices its stream serialized.
    #[test]
    fn tall_filter_wide_row_layer_cannot_rotate() {
        let l = ConvLayer::new("tall", 1, 31, 350, 16, 31, 1, 1, 0, 1);
        let p = plan(&l.per_group()).unwrap();
        assert!(p.rot.is_none(), "unexpected rotation: {:?}", p.rot);
        assert!(p.dm.end <= DM_BYTES);
    }

    /// `plan_with(_, false)` never allocates a shadow, even for layers
    /// that could rotate — the `--no-rotation` baseline.
    #[test]
    fn rotation_knob_disables_the_shadow() {
        for l in alexnet_conv().iter().chain(vgg16_conv().iter()) {
            let p = plan_with(&l.per_group(), false).unwrap();
            assert!(p.rot.is_none(), "{}", l.name);
        }
    }

    #[test]
    fn alexnet_conv1_uses_variant_a() {
        // stride-4 11x11: variant B window (15*4+11=71) exceeds the LB row
        let l = alexnet_conv()[0].per_group();
        let p = plan(&l).unwrap();
        assert_eq!(p.variant, Variant::A);
        assert!(!p.fused_rows); // 11 rows * 55 px >> 64
    }

    #[test]
    fn small_ow_layers_prefer_variant_b() {
        // AlexNet conv3: ow=13 — A wastes 11/24 pixels, B only 3/16
        let l = alexnet_conv()[2].per_group();
        let p = plan(&l).unwrap();
        assert_eq!(p.variant, Variant::B);
        assert!(p.fused_rows);
    }

    #[test]
    fn vgg_mid_layers_use_fused_rows() {
        let l = vgg16_conv()[4].per_group(); // conv3_1 3x3 s1
        let p = plan(&l).unwrap();
        assert!(p.fused_rows);
        assert!(p.util_estimate() > 0.7, "est {}", p.util_estimate());
    }

    #[test]
    fn slices_and_bands_cover_layer() {
        for l in alexnet_conv().iter().chain(vgg16_conv().iter()) {
            let d = l.per_group();
            let p = plan(&d).unwrap();
            let ic_sum: usize = (0..p.m).map(|i| p.slice_ics(i)).sum();
            assert_eq!(ic_sum, d.ic, "{}", l.name);
            let row_sum: usize = (0..p.n_bands).map(|i| p.band_rows_of(i)).sum();
            assert_eq!(row_sum, d.oh(), "{}", l.name);
        }
    }

    #[test]
    fn offsets_fit_u16() {
        for l in alexnet_conv().iter().chain(vgg16_conv().iter()) {
            let p = plan(&l.per_group()).unwrap();
            assert!(2 * p.ic_stride <= u16::MAX as usize, "{}", l.name);
        }
    }

    #[test]
    fn window_fits_lb() {
        for l in alexnet_conv().iter().chain(vgg16_conv().iter()) {
            let p = plan(&l.per_group()).unwrap();
            let total = if p.fused_rows { p.layer.fh * p.win } else { p.win };
            assert!(total <= LB_ROW_PIXELS, "{}", l.name);
        }
    }
}
