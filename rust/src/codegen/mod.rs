//! The "compiler": generates ConvAix VLIW kernels for CNN layers.
//!
//! On the real ASIP this role is played by the auto-generated C/C++
//! compiler plus a hand-tuned kernel library (Section I); here the
//! kernels are emitted directly as [`crate::isa::Program`]s implementing
//! the Fig. 2 dataflow:
//!
//! * IFMaps/OFMaps depth-sliced (`M` input slices × output-channel
//!   tiles), output rows processed row-wise with line-buffer reuse,
//! * filters pre-loaded per slice and streamed through the filter FIFO
//!   ("at least one new filter vector ... loaded each cycle"),
//! * partial sums kept in VRl, spilled via `StA`/`LdA` only when the
//!   input depth is sliced (`M > 1`),
//! * two lane mappings, chosen per layer by the planner:
//!   **variant A** — 16 lanes = output channels, 12 slices = pixels;
//!   **variant B** — 16 lanes = pixels, 12 slices = output channels.

pub mod compiled;
pub mod conv;
pub mod layout;
pub mod pool;
pub mod refconv;
pub mod reffc;
pub mod stage;

pub use compiled::{CacheStats, PlanCache, Scratch};
pub use conv::{build_conv_task, TaskFlavor};
pub use layout::{ConvPlan, Variant};

#[derive(Debug)]
pub enum CodegenError {
    Infeasible(String),
    Pm(crate::mem::pm::PmError),
    Internal(String),
    /// The static verifier (`isa::analysis`) rejected a generated
    /// program on plan-cache insert — always a codegen bug, surfaced in
    /// debug builds / tests and under `ANALYZE=1`.
    Verify(String),
}

impl std::fmt::Display for CodegenError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CodegenError::Infeasible(l) => {
                write!(f, "layer {l}: no feasible layout (DM/PM/LB constraints)")
            }
            CodegenError::Pm(e) => write!(f, "program does not fit PM: {e}"),
            CodegenError::Internal(what) => write!(f, "internal: {what}"),
            CodegenError::Verify(what) => write!(f, "program verification failed: {what}"),
        }
    }
}

impl std::error::Error for CodegenError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CodegenError::Pm(e) => Some(e),
            _ => None,
        }
    }
}

impl From<crate::mem::pm::PmError> for CodegenError {
    fn from(e: crate::mem::pm::PmError) -> Self {
        CodegenError::Pm(e)
    }
}
