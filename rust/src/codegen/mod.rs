//! The "compiler": generates ConvAix VLIW kernels for CNN layers.
//!
//! On the real ASIP this role is played by the auto-generated C/C++
//! compiler plus a hand-tuned kernel library (Section I); here the
//! kernels are emitted directly as [`crate::isa::Program`]s implementing
//! the Fig. 2 dataflow:
//!
//! * IFMaps/OFMaps depth-sliced (`M` input slices × output-channel
//!   tiles), output rows processed row-wise with line-buffer reuse,
//! * filters pre-loaded per slice and streamed through the filter FIFO
//!   ("at least one new filter vector ... loaded each cycle"),
//! * partial sums kept in VRl, spilled via `StA`/`LdA` only when the
//!   input depth is sliced (`M > 1`),
//! * two lane mappings, chosen per layer by the planner:
//!   **variant A** — 16 lanes = output channels, 12 slices = pixels;
//!   **variant B** — 16 lanes = pixels, 12 slices = output channels.

pub mod conv;
pub mod layout;
pub mod pool;
pub mod refconv;
pub mod stage;

pub use conv::{build_conv_task, TaskFlavor};
pub use layout::{ConvPlan, Variant};

#[derive(Debug, thiserror::Error)]
pub enum CodegenError {
    #[error("layer {0}: no feasible layout (DM/PM/LB constraints)")]
    Infeasible(String),
    #[error("program does not fit PM: {0}")]
    Pm(#[from] crate::mem::pm::PmError),
    #[error("internal: {0}")]
    Internal(String),
}
