//! Max-pooling kernels for the slot-1 SFU.
//!
//! Data layout: channel-tile vectors, pixel-major (exactly what the
//! variant-A conv epilogue produces) — one 16-channel vector per pixel.
//! A task computes one output row of one 16-channel tile: a software
//! loop over output pixels; per pixel the `size²` window vectors are
//! loaded round-robin into VR while the SFU folds them with `PoolMax`
//! into an accumulator vector.
//!
//! ABI: r2 = staged input base (`size` rows, pixel-major vectors),
//! r4 = output row base. r0/r3/r7/r9 clobbered.

use crate::isa::analysis::memory::{MemSpec, Region};
use crate::isa::*;
use crate::mem::pm::ProgramMem;
use crate::mem::DM_BYTES;
use crate::model::PoolLayer;

use super::CodegenError;

/// Plan for a pooling layer.
#[derive(Debug, Clone)]
pub struct PoolPlan {
    pub layer: PoolLayer,
    /// 16-channel tiles.
    pub n_tiles: usize,
    /// Input row bytes (iw pixel-vectors).
    pub in_row_bytes: usize,
    /// DM address of the staged input rows.
    pub dm_input: usize,
    /// DM address of the output row buffer.
    pub dm_out: usize,
    /// Double-buffer rotation shadow: DM address of a second
    /// input-rows + output-row slot (same sizes, 32-aligned base by
    /// construction) when one fits beside the primary pair, `None`
    /// when the per-row stream must serialize against compute.
    pub rot: Option<usize>,
}

impl PoolPlan {
    /// Shadow input base (phase B's `r2`). Only when `rot` is `Some`.
    pub fn rot_input(&self) -> Option<usize> {
        self.rot
    }
    /// Shadow output base (phase B's `r4`). Only when `rot` is `Some`.
    pub fn rot_out(&self) -> Option<usize> {
        self.rot.map(|r| r + (self.dm_out - self.dm_input))
    }
    /// First byte past the rotation shadow.
    pub fn rot_end(&self) -> Option<usize> {
        self.rot.map(|r| r + self.dm_out + self.layer.ow() * 32)
    }
}

/// Plan a pooling layer (callers pass a one-row view, `ih == size`).
///
/// The plan's DM map and the task program depend only on
/// `(iw, size, stride)` plus the rotation knob — exactly the
/// `codegen::compiled` pool cache key. `n_tiles` is derived from `ic`
/// and is NOT part of that key: the executor recomputes it per layer,
/// so a cached plan's `n_tiles` must never be read across layers. A
/// new `ic`/`ih`-dependent plan field would have to widen the cache
/// key.
pub fn plan_pool(layer: &PoolLayer) -> Result<PoolPlan, CodegenError> {
    plan_pool_with(layer, true)
}

/// [`plan_pool`] with an explicit rotation knob (`false` = the honest
/// no-double-buffering baseline).
pub fn plan_pool_with(layer: &PoolLayer, rotate: bool) -> Result<PoolPlan, CodegenError> {
    let in_row_bytes = layer.iw * 32;
    let input_bytes = layer.size * in_row_bytes;
    let out_bytes = layer.ow() * 32;
    if input_bytes + out_bytes > DM_BYTES {
        return Err(CodegenError::Infeasible(format!("pool {}", layer.name)));
    }
    // both slots are whole 32 B vectors, so the shadow base is aligned
    let rot = (rotate && 2 * (input_bytes + out_bytes) <= DM_BYTES)
        .then_some(input_bytes + out_bytes);
    Ok(PoolPlan {
        layer: layer.clone(),
        n_tiles: layer.ic.div_ceil(16),
        in_row_bytes,
        dm_input: 0,
        dm_out: input_bytes,
        rot,
    })
}

/// The memory contract of a pool task for the `isa::analysis::memory`
/// pass: staged input rows are read-only, the output row buffer is
/// write-only, nothing else in DM may be touched. The window walk ends
/// exactly at `dm_out` ((ow−1)·stride + size ≤ iw), which the pass
/// verifies per compiled plan. When the plan rotates, the inactive
/// shadow slot is a no-access region — a compute access landing in the
/// in-flight prefetch target is flagged (the DmaRace discipline for
/// host-staged transfers); [`mem_spec_phase_b`] is the same contract
/// with the active/inactive roles swapped.
pub fn mem_spec(plan: &PoolPlan) -> MemSpec {
    let mut regions = vec![
        Region::new("in", plan.dm_input, plan.dm_out, true, false),
        Region::new("out", plan.dm_out, plan.dm_out + plan.layer.ow() * 32, false, true),
    ];
    if let (Some(ri), Some(re)) = (plan.rot_input(), plan.rot_end()) {
        regions.push(Region::new("rot", ri, re, false, false));
    }
    MemSpec::with_regions(regions)
}

/// Phase-B memory contract of a rotated pool plan: the shadow slots
/// are live (input readable, output writable) and the primary pair is
/// the inactive no-access prefetch target. `None` when the plan does
/// not rotate.
pub fn mem_spec_phase_b(plan: &PoolPlan) -> Option<MemSpec> {
    let (ri, ro, re) = (plan.rot_input()?, plan.rot_out()?, plan.rot_end()?);
    Some(MemSpec::with_regions(vec![
        Region::new("primary", plan.dm_input, plan.dm_out + plan.layer.ow() * 32, false, false),
        Region::new("in", ri, ro, true, false),
        Region::new("out", ro, re, false, true),
    ]))
}

const R0: SReg = SReg(0);
const RIN: SReg = SReg(2);
const RWIN: SReg = SReg(3);
const ROUT: SReg = SReg(4);
const RCNT: SReg = SReg(7);

/// Build the per-(tile, output-row) pooling task.
pub fn build_pool_task(plan: &PoolPlan) -> Result<ProgramMem, CodegenError> {
    let l = &plan.layer;
    let mut p = Program::default();
    let b = &mut p.bundles;

    b.push(Bundle::s0(SlotOp::Li { rd: R0, imm: 0 }));
    b.push(Bundle::s0(SlotOp::Li { rd: RCNT, imm: l.ow() as i32 }));
    b.push(Bundle::s0(SlotOp::Alu { f: AluFn::Add, w: Width::W32, rd: RWIN, ra: RIN, rb: R0 }));

    let top = b.len() as u32;
    // window offsets in load order: (fy, fx) row-major; first into the
    // accumulator v4, the rest round-robin v0..v3 folded 2 bundles later.
    let mut offs = Vec::new();
    for fy in 0..l.size {
        for fx in 0..l.size {
            offs.push((fy * plan.in_row_bytes + fx * 32) as i32);
        }
    }
    let n = offs.len();
    // bundle i (i<n): load offs[i] into v4 (i==0) or v0..v3; also fold
    // loaded vector i-2 (for i>=2).
    let dest = |i: usize| -> VReg {
        if i == 0 {
            VReg(4)
        } else {
            VReg(((i - 1) % 4) as u8)
        }
    };
    for i in 0..n + 2 {
        let slot0 = if i < n {
            SlotOp::LdV { vd: dest(i), addr: Addr::offs(RWIN, offs[i]) }
        } else if i == n {
            SlotOp::AluI {
                f: AluFn::Add,
                w: Width::W32,
                rd: RWIN,
                ra: RWIN,
                imm: (l.stride * 32) as i32,
            }
        } else {
            SlotOp::AluI { f: AluFn::Add, w: Width::W32, rd: RCNT, ra: RCNT, imm: -1 }
        };
        let v1 = if (3..n + 2).contains(&i) {
            // fold vector loaded at bundle i-2 (skip i-2==0: that IS v4)
            VecOp::PoolMax { vd: VReg(4), va: VReg(4), vb: dest(i - 2) }
        } else {
            VecOp::Nop
        };
        b.push(Bundle { slot0, v: [v1, VecOp::Nop, VecOp::Nop] });
    }
    // store the finished pixel vector and loop
    b.push(Bundle::s0(SlotOp::StV { vs: VReg(4), addr: Addr::post(ROUT, 32) }));
    b.push(Bundle::s0(SlotOp::Br { c: Cond::Ne, ra: RCNT, rb: R0, target: top }));
    b.push(Bundle::s0(SlotOp::Halt));
    Ok(ProgramMem::load(&p)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::refconv::maxpool2d;
    use crate::core::Cpu;
    use crate::util::XorShift;

    /// Stage `size` input rows (pixel-major channel vectors) and run one
    /// output row; compare against the host reference.
    #[test]
    fn pool_task_matches_reference() {
        for (size, stride, iw, ic) in [(2usize, 2usize, 8usize, 16usize), (3, 2, 13, 16)] {
            let ih = size; // one output row's worth
            let l = PoolLayer { name: "p", ic, ih, iw, size, stride };
            let plan = plan_pool(&l).unwrap();
            let pm = build_pool_task(&plan).unwrap();
            let mut rng = XorShift::new(42);
            let x = rng.i16_vec(ic * ih * iw, -30000, 30000);
            let expect = maxpool2d(&x, ic, ih, iw, size, stride);
            let ow = l.ow();

            let mut cpu = Cpu::new(1 << 16);
            // stage: [row][pixel][16ch] vectors
            for r in 0..size {
                for px in 0..iw {
                    let v: Vec<i16> = (0..16).map(|c| x[(c * ih + r) * iw + px]).collect();
                    cpu.mem
                        .dm
                        .poke_i16_slice(plan.dm_input + r * plan.in_row_bytes + px * 32, &v);
                }
            }
            cpu.regs.set_r(RIN, plan.dm_input as i32);
            cpu.regs.set_r(ROUT, plan.dm_out as i32);
            let stats = cpu.run(&pm).unwrap();
            assert!(stats.sfu_ops > 0);
            for px in 0..ow {
                let v = cpu.mem.dm.peek_i16_slice(plan.dm_out + px * 32, 16);
                for c in 0..16 {
                    assert_eq!(
                        v[c],
                        expect[(c * 1 + 0) * ow + px],
                        "size={size} px={px} c={c}"
                    );
                }
            }
        }
    }

    #[test]
    fn pool_task_fits_pm() {
        for l in crate::model::alexnet_pools().iter().chain(crate::model::vgg16_pools().iter()) {
            let one_row = PoolLayer { ih: l.size, ..l.clone() };
            let plan = plan_pool(&one_row).unwrap();
            let pm = build_pool_task(&plan).unwrap();
            assert!(pm.bundle_count() < 100, "{}", l.name);
        }
    }

    /// Every benchmark pool fits the rotation shadow (two row-window +
    /// output-row pairs are tiny next to DM), both phases' region maps
    /// are disjoint and in bounds, and the knob disables the shadow.
    #[test]
    fn pool_plans_rotate_with_disjoint_phase_specs() {
        for l in crate::model::alexnet_pools().iter().chain(crate::model::vgg16_pools().iter()) {
            let one_row = PoolLayer { ih: l.size, ..l.clone() };
            let p = plan_pool(&one_row).unwrap();
            assert!(p.rot.is_some(), "{} should rotate", l.name);
            assert!(p.rot_end().unwrap() <= DM_BYTES, "{}", l.name);
            assert!(mem_spec(&p).region_violations().is_empty(), "{}", l.name);
            let pb = mem_spec_phase_b(&p).expect("rotated plan has a phase B");
            assert!(pb.region_violations().is_empty(), "{}", l.name);
            let np = plan_pool_with(&one_row, false).unwrap();
            assert!(np.rot.is_none(), "{}", l.name);
            assert!(mem_spec_phase_b(&np).is_none(), "{}", l.name);
        }
    }
}
