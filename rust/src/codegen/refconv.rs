//! Host-side reference convolution / pooling with the exact ConvAix
//! fixed-point semantics (`crate::fixed`). Used by codegen tests, the
//! coordinator's self-checks, and as the CPU-side expectation in golden
//! tests (the authoritative oracle is the JAX/Pallas HLO artifact — this
//! mirrors it bit-for-bit).

use crate::fixed::{gate, mac, mac_init, requantize, RoundMode};
use crate::model::ConvLayer;

/// Dense (single-group) fixed-point conv, NCHW-without-N.
/// x: (ic, ih, iw) i16; w: (oc, ic, fh, fw) i16; b: (oc,) i32.
/// Returns (oc, oh, ow) i16.
#[allow(clippy::too_many_arguments)]
pub fn conv2d(
    x: &[i16],
    w: &[i16],
    b: &[i32],
    l: &ConvLayer,
    mode: RoundMode,
    gate_bits: u8,
) -> Vec<i16> {
    assert_eq!(l.groups, 1, "use per_group() views for grouped layers");
    assert_eq!(x.len(), l.ic * l.ih * l.iw);
    assert_eq!(w.len(), l.oc * l.ic * l.fh * l.fw);
    assert_eq!(b.len(), l.oc);
    let (oh, ow) = (l.oh(), l.ow());
    let (ihp, iwp) = (l.ihp(), l.iwp());
    // stage padded input
    let mut xp = vec![0i16; l.ic * ihp * iwp];
    for c in 0..l.ic {
        for y in 0..l.ih {
            for xx in 0..l.iw {
                xp[(c * ihp + y + l.pad) * iwp + xx + l.pad] = x[(c * l.ih + y) * l.iw + xx];
            }
        }
    }
    let mut out = vec![0i16; l.oc * oh * ow];
    for o in 0..l.oc {
        for y in 0..oh {
            for xx in 0..ow {
                let mut acc = mac_init(b[o], l.frac_shift);
                for c in 0..l.ic {
                    for fy in 0..l.fh {
                        for fx in 0..l.fw {
                            let px = xp[(c * ihp + y * l.stride + fy) * iwp
                                + xx * l.stride
                                + fx];
                            let wt = w[((o * l.ic + c) * l.fh + fy) * l.fw + fx];
                            acc = mac(acc, gate(px, gate_bits), gate(wt, gate_bits));
                        }
                    }
                }
                out[(o * oh + y) * ow + xx] = requantize(acc, l.frac_shift, mode, l.relu);
            }
        }
    }
    out
}

/// Grouped conv by per-group dense runs (matches the executor).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_grouped(
    x: &[i16],
    w: &[i16],
    b: &[i32],
    l: &ConvLayer,
    mode: RoundMode,
    gate_bits: u8,
) -> Vec<i16> {
    if l.groups == 1 {
        return conv2d(x, w, b, l, mode, gate_bits);
    }
    let g = l.groups;
    let lg = l.per_group();
    let (icg, ocg) = (lg.ic, lg.oc);
    let mut out = vec![0i16; l.oc * l.oh() * l.ow()];
    let ohw = l.oh() * l.ow();
    for gi in 0..g {
        let xg = &x[gi * icg * l.ih * l.iw..(gi + 1) * icg * l.ih * l.iw];
        let wg = &w[gi * ocg * icg * l.fh * l.fw..(gi + 1) * ocg * icg * l.fh * l.fw];
        let bg = &b[gi * ocg..(gi + 1) * ocg];
        let og = conv2d(xg, wg, bg, &lg, mode, gate_bits);
        out[gi * ocg * ohw..(gi + 1) * ocg * ohw].copy_from_slice(&og);
    }
    out
}

/// Max pooling (ic, ih, iw) -> (ic, oh, ow), no padding.
pub fn maxpool2d(x: &[i16], ic: usize, ih: usize, iw: usize, size: usize, stride: usize) -> Vec<i16> {
    let oh = (ih - size) / stride + 1;
    let ow = (iw - size) / stride + 1;
    let mut out = vec![0i16; ic * oh * ow];
    for c in 0..ic {
        for y in 0..oh {
            for xx in 0..ow {
                let mut m = i16::MIN;
                for fy in 0..size {
                    for fx in 0..size {
                        m = m.max(x[(c * ih + y * stride + fy) * iw + xx * stride + fx]);
                    }
                }
                out[(c * oh + y) * ow + xx] = m;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift;

    fn tiny_layer() -> ConvLayer {
        ConvLayer::new("t", 2, 5, 5, 4, 3, 3, 1, 1, 1)
    }

    #[test]
    fn identity_filter_passthrough() {
        // 1x1 filter of 1<<shift acts as identity (with relu off)
        let mut l = ConvLayer::new("id", 1, 4, 4, 1, 1, 1, 1, 0, 1);
        l.relu = false;
        let x: Vec<i16> = (0..16).map(|i| i as i16 - 8).collect();
        let w = vec![1i16 << l.frac_shift];
        let b = vec![0i32];
        let out = conv2d(&x, &w, &b, &l, RoundMode::HalfUp, 16);
        assert_eq!(out, x);
    }

    #[test]
    fn bias_only() {
        let mut l = tiny_layer();
        l.relu = false;
        let x = vec![0i16; 2 * 5 * 5];
        let w = vec![0i16; 4 * 2 * 9];
        let b = vec![-3, 0, 7, 100];
        let out = conv2d(&x, &w, &b, &l, RoundMode::HalfUp, 16);
        for o in 0..4 {
            assert!(out[o * 25..(o + 1) * 25].iter().all(|&v| v as i32 == b[o]));
        }
    }

    #[test]
    fn relu_clamps() {
        let l = tiny_layer(); // relu on
        let x = vec![0i16; 2 * 5 * 5];
        let w = vec![0i16; 4 * 2 * 9];
        let b = vec![-3, 5, -1, 2];
        let out = conv2d(&x, &w, &b, &l, RoundMode::HalfUp, 16);
        assert!(out[0..25].iter().all(|&v| v == 0));
        assert!(out[25..50].iter().all(|&v| v == 5));
    }

    #[test]
    fn grouped_equals_manual_split() {
        let mut rng = XorShift::new(5);
        let l = ConvLayer::new("g", 4, 6, 6, 8, 3, 3, 1, 1, 2);
        let x = rng.i16_vec(4 * 36, -500, 500);
        let w = rng.i16_vec(8 * 2 * 9, -100, 100);
        let b = rng.i32_vec(8, -50, 50);
        let out = conv2d_grouped(&x, &w, &b, &l, RoundMode::HalfUp, 16);
        assert_eq!(out.len(), 8 * 36);
        // group 1 output must not depend on group 0 input
        let mut x2 = x.clone();
        for v in &mut x2[0..2 * 36] {
            *v = v.wrapping_add(17);
        }
        let out2 = conv2d_grouped(&x2, &w, &b, &l, RoundMode::HalfUp, 16);
        assert_eq!(out[4 * 36..], out2[4 * 36..]);
        assert_ne!(out[..4 * 36], out2[..4 * 36]);
    }

    #[test]
    fn maxpool_basic() {
        let x: Vec<i16> = (0..16).collect();
        let out = maxpool2d(&x, 1, 4, 4, 2, 2);
        assert_eq!(out, vec![5, 7, 13, 15]);
        // overlapping 3x3 s2 on 5x5
        let x2: Vec<i16> = (0..25).collect();
        let out2 = maxpool2d(&x2, 1, 5, 5, 3, 2);
        assert_eq!(out2, vec![12, 14, 22, 24]);
    }
}
