//! Host-side reference fully-connected layer with the exact ConvAix
//! fixed-point semantics (`crate::fixed`) — the FC analogue of
//! [`refconv`](super::refconv). Used by executor/engine tests as the
//! bit-exact oracle for the 1×1-conv lowering ([`FcLayer::as_conv`]):
//! the weight layout `(out, in)` coincides with the conv's
//! `(oc, ic, 1, 1)`, so `fc_forward` and `refconv::conv2d` on the
//! lowered layer must agree bit-for-bit (locked by a test here).

use crate::fixed::{gate, mac, mac_init, requantize, RoundMode};
use crate::model::FcLayer;

/// Fixed-point fully connected forward pass.
/// `x`: (in_features,) i16; `w`: (out_features, in_features) i16,
/// row-major; `b`: (out_features,) i32. Returns (out_features,) i16.
pub fn fc_forward(
    x: &[i16],
    w: &[i16],
    b: &[i32],
    l: &FcLayer,
    mode: RoundMode,
    gate_bits: u8,
) -> Vec<i16> {
    assert_eq!(x.len(), l.in_features);
    assert_eq!(w.len(), l.in_features * l.out_features);
    assert_eq!(b.len(), l.out_features);
    let mut out = vec![0i16; l.out_features];
    for (o, y) in out.iter_mut().enumerate() {
        let mut acc = mac_init(b[o], l.frac_shift);
        for (i, &px) in x.iter().enumerate() {
            let wt = w[o * l.in_features + i];
            acc = mac(acc, gate(px, gate_bits), gate(wt, gate_bits));
        }
        *y = requantize(acc, l.frac_shift, mode, l.relu);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::refconv;
    use super::*;
    use crate::util::XorShift;

    #[test]
    fn identity_row_passthrough() {
        // a one-hot row of 1<<shift copies that input feature (relu off)
        let mut l = FcLayer::new("id", 4, 4);
        l.relu = false;
        let x: Vec<i16> = vec![-7, 3, 0, 12];
        let mut w = vec![0i16; 16];
        for o in 0..4 {
            w[o * 4 + o] = 1i16 << l.frac_shift;
        }
        let b = vec![0i32; 4];
        assert_eq!(fc_forward(&x, &w, &b, &l, RoundMode::HalfUp, 16), x);
    }

    #[test]
    fn bias_and_relu() {
        let l = FcLayer::new("br", 3, 4); // relu on
        let x = vec![0i16; 3];
        let w = vec![0i16; 12];
        let b = vec![-3, 0, 7, 100];
        let out = fc_forward(&x, &w, &b, &l, RoundMode::HalfUp, 16);
        assert_eq!(out, vec![0, 0, 7, 100]);
    }

    #[test]
    fn matches_refconv_on_the_1x1_lowering() {
        // the FC oracle and the conv oracle must coincide on the
        // lowering the executor uses — weight layouts are identical
        let mut rng = XorShift::new(42);
        for (inf, outf, relu) in [(24usize, 16usize, true), (33, 10, false)] {
            let mut l = FcLayer::new("low", inf, outf);
            l.relu = relu;
            let x = rng.i16_vec(inf, -2000, 2000);
            let w = rng.i16_vec(inf * outf, -256, 256);
            let b = rng.i32_vec(outf, -1000, 1000);
            for gate_bits in [16u8, 8] {
                let fc = fc_forward(&x, &w, &b, &l, RoundMode::HalfUp, gate_bits);
                let cv = refconv::conv2d(&x, &w, &b, &l.as_conv(), RoundMode::HalfUp, gate_bits);
                assert_eq!(fc, cv, "in {inf} out {outf} gate {gate_bits}");
            }
        }
    }
}
