//! Data staging: host tensors ⇄ the DM layouts the generated kernels
//! expect. Used by the coordinator (and tests) around each task run.
//!
//! Layouts (see `conv.rs`):
//!
//! * **filter stream**: one 16-lane vector per (ic_local, fy, fx) in
//!   consumption order; lanes = output channels of the tile (variant A:
//!   16, variant B: 12 + 4 zero lanes). 2 slack vectors at the end
//!   absorb the FIFO prefetch over-read.
//! * **bias vector**: 32 B directly below the filter stream.
//! * **input band**: `[ic_local][row_local][iwp_stage pixels]`, rows
//!   pre-padded (zero padding baked in), `ic_stride` fixed to the plan.
//! * **output row buffer**: variant A — pixel-major 16-OCh vectors;
//!   variant B — OCh-major 16-pixel row chunks.
//! * **psum row buffer**: per group, 12 accumulator entries of 64 B
//!   (lanes-low 32 B then lanes-high 32 B, as `StA` writes them).
//!
//! **Invariant (checked by `isa::analysis`):** staging happens strictly
//! *before* `Cpu::run` and the task programs never issue DMA, so no
//! port-0 access in a task can race an in-flight transfer — the
//! verifier's DMA-overlap lint would flag exactly that. The 2 slack
//! vectors at the end of the filter stream are load-bearing: the FIFO
//! prefetch reads 2 vectors past the last consumed one, and dropping
//! them would make the generated programs read unstaged DM (caught at
//! the FIFO-balance level, since prime/drain counts would then change).

use crate::isa::LANES;
use crate::mem::dm::DataMem;
use crate::model::ConvLayer;

use super::layout::{ConvPlan, Variant};

/// Reset a reusable staging buffer to exactly `n` zeroed elements.
/// `clear` + `resize` reuses the allocation while writing every element
/// — a reused buffer is bit-identical to a fresh `vec![0; n]`.
fn reset(buf: &mut Vec<i16>, n: usize) {
    buf.clear();
    buf.resize(n, 0);
}

/// Zero-pad a dense input tensor (ic, ih, iw) -> (ic, ihp, iwp) into a
/// reusable buffer (see [`crate::codegen::Scratch`]).
pub fn pad_input_into(l: &ConvLayer, x: &[i16], xp: &mut Vec<i16>) {
    assert_eq!(x.len(), l.ic * l.ih * l.iw);
    let (ihp, iwp) = (l.ihp(), l.iwp());
    reset(xp, l.ic * ihp * iwp);
    for c in 0..l.ic {
        for y in 0..l.ih {
            let src = (c * l.ih + y) * l.iw;
            let dst = (c * ihp + y + l.pad) * iwp + l.pad;
            xp[dst..dst + l.iw].copy_from_slice(&x[src..src + l.iw]);
        }
    }
}

/// Zero-pad a dense input tensor (ic, ih, iw) -> (ic, ihp, iwp).
pub fn pad_input(l: &ConvLayer, x: &[i16]) -> Vec<i16> {
    let mut xp = Vec::new();
    pad_input_into(l, x, &mut xp);
    xp
}

/// Build the filter stream for (tile, slice mi) into a reusable buffer:
/// lane-major i16 words, `(slice_ics*fh*fw + 2) * 16` of them.
pub fn filter_stream_into(plan: &ConvPlan, w: &[i16], tile: usize, mi: usize, out: &mut Vec<i16>) {
    let l = &plan.layer;
    let ocs = plan.variant.ocs();
    let slice_ics = plan.slice_ics(mi);
    let ic0 = mi * plan.ics;
    out.clear();
    out.reserve((slice_ics * l.fh * l.fw + 2) * LANES);
    for icl in 0..slice_ics {
        let ic = ic0 + icl;
        for fy in 0..l.fh {
            for fx in 0..l.fw {
                for lane in 0..LANES {
                    let oc = tile * ocs + lane;
                    let v = if lane < ocs && oc < l.oc {
                        w[((oc * l.ic + ic) * l.fh + fy) * l.fw + fx]
                    } else {
                        0
                    };
                    out.push(v);
                }
            }
        }
    }
    // FIFO over-read slack
    out.extend(std::iter::repeat(0).take(2 * LANES));
}

/// Build the filter stream for (tile, slice mi): returns lane-major i16
/// words, `(slice_ics*fh*fw + 2) * 16` of them.
pub fn filter_stream(plan: &ConvPlan, w: &[i16], tile: usize, mi: usize) -> Vec<i16> {
    let mut out = Vec::new();
    filter_stream_into(plan, w, tile, mi, &mut out);
    out
}

/// Bias vector for a tile (biases must fit i16 — the InitA datapath
/// shifts a 16-bit lane; asserted here).
pub fn bias_vector(plan: &ConvPlan, b: &[i32], tile: usize) -> [i16; LANES] {
    let l = &plan.layer;
    let ocs = plan.variant.ocs();
    std::array::from_fn(|lane| {
        let oc = tile * ocs + lane;
        if lane < ocs && oc < l.oc {
            let v = b[oc];
            assert!(
                (i16::MIN as i32..=i16::MAX as i32).contains(&v),
                "bias {v} exceeds the 16-bit InitA datapath"
            );
            v as i16
        } else {
            0
        }
    })
}

/// Stage the input band for slice `mi`, band starting at output row
/// `oh0`, into a reusable buffer: `[ic_local][row_local][iwp_stage]`
/// pixels, using the plan's fixed `ic_stride` (zero-filled outside the
/// padded map).
pub fn input_band_into(plan: &ConvPlan, xp: &[i16], mi: usize, oh0: usize, out: &mut Vec<i16>) {
    let l = &plan.layer;
    let (ihp, iwp) = (l.ihp(), l.iwp());
    let slice_ics = plan.slice_ics(mi);
    let ic0 = mi * plan.ics;
    let y0 = oh0 * l.stride;
    reset(out, slice_ics * plan.in_rows_band * plan.iwp_stage);
    for icl in 0..slice_ics {
        for r in 0..plan.in_rows_band {
            let y = y0 + r;
            if y >= ihp {
                continue;
            }
            let src = ((ic0 + icl) * ihp + y) * iwp;
            let dst = (icl * plan.in_rows_band + r) * plan.iwp_stage;
            let n = iwp.min(plan.iwp_stage);
            out[dst..dst + n].copy_from_slice(&xp[src..src + n]);
        }
    }
}

/// Stage the input band for slice `mi`, band starting at output row
/// `oh0`. Returns `[ic_local][row_local][iwp_stage]` pixels.
pub fn input_band(plan: &ConvPlan, xp: &[i16], mi: usize, oh0: usize) -> Vec<i16> {
    let mut out = Vec::new();
    input_band_into(plan, xp, mi, oh0, &mut out);
    out
}

/// Write staged words into DM at `base` (untimed; DMA timing is modeled
/// analytically by the coordinator).
pub fn poke(dm: &mut DataMem, base: usize, words: &[i16]) {
    dm.poke_i16_slice(base, words);
}

/// Read one output row back from the row buffer into a reusable
/// buffer: logical `[oc_local][ow]`.
pub fn read_out_row_into(plan: &ConvPlan, dm: &DataMem, ow: usize, out: &mut Vec<i16>) {
    let ocs = plan.variant.ocs();
    let base = plan.dm.out;
    reset(out, ocs * ow);
    match plan.variant {
        Variant::A => {
            // pixel-major vectors of 16 OCh
            for p in 0..ow {
                let v = dm.peek_i16_slice(base + p * 32, LANES);
                for (oc, val) in v.iter().enumerate().take(ocs) {
                    out[oc * ow + p] = *val;
                }
            }
        }
        Variant::B => {
            let owp = plan.g * 16;
            for oc in 0..ocs {
                let row = dm.peek_i16_slice(base + oc * owp * 2, ow);
                out[oc * ow..(oc + 1) * ow].copy_from_slice(&row);
            }
        }
    }
}

/// Read one output row back from the row buffer: logical `[oc_local][ow]`.
pub fn read_out_row(plan: &ConvPlan, dm: &DataMem, ow: usize) -> Vec<i16> {
    let mut out = Vec::new();
    read_out_row_into(plan, dm, ow, &mut out);
    out
}

/// Read the psum row buffer (raw accumulators) — `[group][entry12][lane16]`
/// as i32, in the exact `StA` image (lo/hi split resolved).
pub fn read_psum_row(plan: &ConvPlan, dm: &DataMem) -> Vec<i32> {
    let base = plan.dm.psum;
    let n = plan.g * 12;
    let mut out = vec![0i32; n * LANES];
    for e in 0..n {
        for lane in 0..LANES {
            let lo = dm.peek_i16(base + e * 64 + 2 * lane) as u16 as i32;
            let hi = dm.peek_i16(base + e * 64 + 32 + 2 * lane) as i32;
            out[e * LANES + lane] = lo | (hi << 16);
        }
    }
    out
}

/// Write a psum row buffer back (the `LdA` image).
pub fn write_psum_row(plan: &ConvPlan, dm: &mut DataMem, psums: &[i32]) {
    let base = plan.dm.psum;
    let n = plan.g * 12;
    assert_eq!(psums.len(), n * LANES);
    for e in 0..n {
        for lane in 0..LANES {
            let v = psums[e * LANES + lane];
            dm.poke_i16(base + e * 64 + 2 * lane, v as i16);
            dm.poke_i16(base + e * 64 + 32 + 2 * lane, (v >> 16) as i16);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::layout::plan;
    use super::*;
    use crate::model::ConvLayer;
    use crate::util::XorShift;

    fn small() -> ConvLayer {
        ConvLayer::new("s", 4, 8, 8, 16, 3, 3, 1, 1, 1)
    }

    #[test]
    fn pad_input_centers() {
        let l = small();
        let x: Vec<i16> = (0..l.ic * 64).map(|i| i as i16).collect();
        let xp = pad_input(&l, &x);
        assert_eq!(xp.len(), 4 * 10 * 10);
        assert_eq!(xp[0], 0); // corner pad
        assert_eq!(xp[(0 * 10 + 1) * 10 + 1], x[0]);
    }

    #[test]
    fn filter_stream_order_and_padding() {
        let l = small();
        let p = plan(&l).unwrap();
        let mut rng = XorShift::new(1);
        let w = rng.i16_vec(16 * 4 * 9, -100, 100);
        let fs = filter_stream(&p, &w, 0, 0);
        assert_eq!(fs.len(), (4 * 9 + 2) * 16);
        // first vector = (ic0, fy0, fx0) over oc lanes
        let ocs = p.variant.ocs();
        for lane in 0..ocs.min(16) {
            assert_eq!(fs[lane], w[lane * 4 * 9]);
        }
        // slack vectors are zero
        assert!(fs[fs.len() - 32..].iter().all(|&v| v == 0));
    }

    #[test]
    fn psum_roundtrip() {
        let l = small();
        let p = plan(&l).unwrap();
        let mut dm = DataMem::new();
        let mut rng = XorShift::new(2);
        let vals = rng.i32_vec(p.g * 12 * LANES, -1_000_000, 1_000_000);
        write_psum_row(&p, &mut dm, &vals);
        assert_eq!(read_psum_row(&p, &dm), vals);
    }

    #[test]
    #[should_panic(expected = "bias")]
    fn oversize_bias_rejected() {
        let l = small();
        let p = plan(&l).unwrap();
        let b = vec![1 << 20; 16];
        bias_vector(&p, &b, 0);
    }
}
