//! External-bus contention model for the multi-core pool.
//!
//! The seed scheduler assumed fully **partitioned** external bandwidth:
//! every core owns a private `EXT_BYTES_PER_CYCLE`-wide port, so its DMA
//! time never depends on what the other cores do. Real multi-array
//! deployments usually hang all cores off one DRAM channel — the exact
//! resource-partitioning trade-off of Shen et al. (FPGA'17): dividing
//! the array *helps* compute but the memory system stays shared.
//!
//! [`BusModel::Shared`] models that channel analytically: the bus
//! bandwidth is divided evenly across the cores that are *concurrently
//! DMA-bound* (cores whose aggregate DMA time exceeds their aggregate
//! compute time — compute-bound cores hide their transfers in compute
//! slack and do not occupy the channel steadily). The divisor is the
//! fixed point of "how many cores are DMA-bound once the bandwidth is
//! divided that many ways": slowing the bus down can tip previously
//! compute-bound cores over, so the count is grown until it stabilizes
//! (it is monotone, so at most `cores` iterations).
//!
//! Only the **transfer** term of the DMA model scales — per-request DRAM
//! latency is pipelined per bank and stays constant. With a divisor of 1
//! (one DMA-bound core, or a partitioned bus) the accounting is
//! bit-identical to the seed model.

use crate::mem::EXT_BYTES_PER_CYCLE;

use super::metrics::LayerResult;

/// How the pool's cores reach external memory.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BusModel {
    /// Every core has a private full-width external port (the seed
    /// model; upper bound on scaling).
    #[default]
    Partitioned,
    /// All cores share one `EXT_BYTES_PER_CYCLE`-wide DRAM channel;
    /// bandwidth is divided across concurrently DMA-bound cores.
    Shared,
}

impl std::str::FromStr for BusModel {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "partitioned" | "private" => Ok(Self::Partitioned),
            "shared" => Ok(Self::Shared),
            other => Err(format!("unknown bus model `{other}` (partitioned | shared)")),
        }
    }
}

/// One schedulable unit of a core's timeline (a shard or a layer):
/// its compute time and the decomposed DMA terms needed to re-price the
/// transfer under contention. The DMA stream splits into three
/// portions per the executor's fill/steady/serialized timeline:
/// `fill_*` is the serialized first-iteration stream of a rotated
/// (double-buffered) plan, `serial_*` is the whole stream of a plan
/// that cannot rotate, and `bytes`/`lat` hold the **steady** remainder
/// — the only portion compute can hide.
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct Segment {
    /// Pure compute cycles of the unit.
    pub compute: u64,
    /// Steady (overlappable) off-chip payload bytes of the unit.
    pub bytes: u64,
    /// Per-request DRAM latency cycles of the steady portion (the
    /// bandwidth-independent term).
    pub lat: u64,
    /// Serialized first-iteration fill of a rotated plan.
    pub fill_bytes: u64,
    pub fill_lat: u64,
    /// Never-overlapped stream of an un-rotatable plan.
    pub serial_bytes: u64,
    pub serial_lat: u64,
    /// Cycles of the unit at full private bandwidth: the executor's
    /// fill/steady timeline result, including per-iteration rounding
    /// the aggregate terms cannot reconstruct.
    pub part: u64,
}

/// Transfer-plus-latency cycles of one DMA portion when `d` cores
/// contend (the latency term is pipelined per bank and does not scale).
fn portion(bytes: u64, lat: u64, d: u64) -> u64 {
    lat + (bytes * d).div_ceil(EXT_BYTES_PER_CYCLE as u64)
}

impl Segment {
    /// Decompose an executed layer/shard result into bus-model terms.
    /// Each portion's latency term is recovered as its cycles minus its
    /// full-bandwidth transfer time, so `fill(1)`/`serial(1)`/
    /// `steady(1)` reproduce the executor's portion cycles exactly and
    /// `busy(1) == part` — the 1-contender boundary stays bit-identical
    /// to private-bus pricing.
    pub fn of_layer(r: &LayerResult) -> Self {
        let e = EXT_BYTES_PER_CYCLE as u64;
        let fill_lat = r.dma_fill_cycles.saturating_sub(r.dma_fill_bytes.div_ceil(e));
        let serial_lat = r.dma_serial_cycles.saturating_sub(r.dma_serial_bytes.div_ceil(e));
        let bytes = (r.io_in + r.io_out).saturating_sub(r.dma_fill_bytes + r.dma_serial_bytes);
        let steady_cycles =
            r.dma_cycles.saturating_sub(r.dma_fill_cycles + r.dma_serial_cycles);
        Self {
            compute: r.compute_cycles,
            bytes,
            lat: steady_cycles.saturating_sub(bytes.div_ceil(e)),
            fill_bytes: r.dma_fill_bytes,
            fill_lat,
            serial_bytes: r.dma_serial_bytes,
            serial_lat,
            part: r.cycles,
        }
    }

    /// Contended fill cycles (serialized ahead of the unit's compute).
    fn fill(&self, d: u64) -> u64 {
        portion(self.fill_bytes, self.fill_lat, d)
    }

    /// Contended serialized-stream cycles (never overlapped).
    fn serial(&self, d: u64) -> u64 {
        portion(self.serial_bytes, self.serial_lat, d)
    }

    /// Contended steady-stream cycles (overlappable with compute).
    fn steady(&self, d: u64) -> u64 {
        portion(self.bytes, self.lat, d)
    }

    /// Transfer-plus-latency DMA cycles when `d` cores contend.
    fn dma(&self, d: u64) -> u64 {
        self.fill(d) + self.steady(d) + self.serial(d)
    }

    /// Occupied cycles when `d` cores contend for the bus: the
    /// serialized portions always pay their contended price, and the
    /// overlapped remainder of the private result is extended only if
    /// the contended steady stream outgrows it. At `d = 1` this is
    /// exactly `part`; with no fill/serial portions it degenerates to
    /// `part.max(dma(d))`.
    fn busy(&self, d: u64) -> u64 {
        let overlapped = self.part.saturating_sub(self.fill(1) + self.serial(1));
        self.fill(d) + self.serial(d) + overlapped.max(self.steady(d))
    }
}

/// Steady-state initiation interval of one pipeline-stage frame when
/// `d` cores contend for the bus.
///
/// A pipeline stage repeats the *same* layer schedule every frame, and
/// its next frame's inputs already exist (the upstream stage produced
/// them during the current interval), so the double-buffered DMA stream
/// never drains at layer boundaries: filters and input bands for the
/// next (layer, frame) prefetch under the current compute. The stage
/// interval is therefore `Σ serial + max(Σ compute, Σ fill+steady)`
/// across the whole stage: fill portions overlap across frames in
/// steady state (the next frame's first stream prefetches under the
/// current frame's tail compute, though its bytes still press the
/// bus), while a `serial` portion — a stream whose DM cannot hold a
/// rotation shadow — drains the pipeline every frame by construction
/// and never hides under any compute. Unlike a frame fan-out core,
/// whose next layer's *input* is the output it is still computing (a
/// true dependency), a stage is not pinned to the per-layer overlap
/// sum.
pub(crate) fn stage_interval(segs: &[Segment], d: u64) -> u64 {
    let compute: u64 = segs.iter().map(|s| s.compute).sum();
    let overlappable: u64 = segs.iter().map(|s| s.fill(d) + s.steady(d)).sum();
    let serial: u64 = segs.iter().map(|s| s.serial(d)).sum();
    serial + compute.max(overlappable)
}

/// A stage's *first* pass over a frame when `d` cores contend: the
/// cross-layer overlap of [`stage_interval`] needs a repeating
/// schedule to prefetch against, which the first frame through a stage
/// does not have — each layer's input is a true dependency on the
/// previous layer's output, so the layers chain at their individual
/// `max(compute, dma)` times. This prices the pipeline's fill phase.
pub(crate) fn stage_first_pass(segs: &[Segment], d: u64) -> u64 {
    segs.iter().map(|s| s.busy(d)).sum()
}

/// Steady-state initiation interval of a **k-core** pipeline-stage
/// frame when `d` cores contend for the bus. `layers` is the stage's
/// per-layer shard list: each shard is `(group-relative core slot,
/// Segment)`. Every core in the group repeats *its own* shard schedule
/// each frame, so the cross-layer overlap of [`stage_interval`]
/// applies per core to that core's shard timeline; the group's
/// interval is its slowest core's. With one core (every shard on slot
/// 0) this is exactly `stage_interval` over the stage's segments — the
/// all-groups-of-1 partition prices bit-identically to the legacy
/// one-core-per-stage pipeline.
pub(crate) fn group_interval(layers: &[Vec<(usize, Segment)>], k: usize, d: u64) -> u64 {
    (0..k.max(1))
        .map(|c| {
            let segs: Vec<Segment> = layers
                .iter()
                .flatten()
                .filter(|(slot, _)| *slot == c)
                .map(|(_, s)| *s)
                .collect();
            stage_interval(&segs, d)
        })
        .max()
        .unwrap_or(0)
}

/// First pass of a **k-core** stage frame when `d` cores contend: the
/// group's cores run each layer's shards concurrently and re-join at
/// the layer boundary (the merge the sharded runner performs — the
/// next layer's input is the merged output, a true dependency), so
/// layers chain at the slowest core's busy time. With one core this is
/// exactly [`stage_first_pass`]. Like the first pass of a 1-core
/// stage, there is no repeating schedule to prefetch against yet.
pub(crate) fn group_first_pass(layers: &[Vec<(usize, Segment)>], k: usize, d: u64) -> u64 {
    layers
        .iter()
        .map(|shards| {
            (0..k.max(1))
                .map(|c| {
                    shards
                        .iter()
                        .filter(|(slot, _)| *slot == c)
                        .map(|(_, s)| s.busy(d))
                        .sum::<u64>()
                })
                .max()
                .unwrap_or(0)
        })
        .sum()
}

/// Per-core cycle accounting under a bus model.
pub(crate) struct BusAccount {
    /// Occupied cycles per core (includes shared-bus wait).
    pub busy: Vec<u64>,
    /// Busy cycles per core at full private bandwidth — the useful-work
    /// view. Equals `busy` for [`BusModel::Partitioned`].
    pub useful: Vec<u64>,
    /// Cores counted as concurrently DMA-bound (the bandwidth divisor);
    /// 0 when the bus is partitioned or nobody is DMA-bound.
    pub contenders: usize,
}

/// Is this core's timeline dominated by DMA when `d` cores contend?
pub(crate) fn dma_bound(segs: &[Segment], d: u64) -> bool {
    if segs.is_empty() {
        return false;
    }
    let compute: u64 = segs.iter().map(|s| s.compute).sum();
    let dma: u64 = segs.iter().map(|s| s.dma(d)).sum();
    dma > compute
}

/// The shared-bus bandwidth divisor: the grown-until-stable count of
/// concurrently DMA-bound cores. Slowing the bus can tip previously
/// compute-bound cores over, so the count is iterated to its fixed
/// point (monotone, at most `cores` steps). Exactly 1 when at most one
/// core is DMA-bound — the 1-contender boundary where shared pricing
/// must be bit-identical to the partitioned model.
pub(crate) fn shared_divisor(per_core: &[Vec<Segment>]) -> u64 {
    let count = |d: u64| per_core.iter().filter(|segs| dma_bound(segs, d)).count() as u64;
    let mut d = 1u64;
    loop {
        let bound = count(d);
        if bound.max(1) <= d {
            return d;
        }
        d = bound;
    }
}

/// Price each core's segment list under `bus`. Deterministic; the
/// shared-bus divisor is the grown-until-stable count of DMA-bound
/// cores ([`shared_divisor`]).
pub(crate) fn core_busy(per_core: &[Vec<Segment>], bus: BusModel) -> BusAccount {
    let useful: Vec<u64> = per_core
        .iter()
        .map(|segs| segs.iter().map(|s| s.part).sum())
        .collect();
    match bus {
        BusModel::Partitioned => BusAccount { busy: useful.clone(), useful, contenders: 0 },
        BusModel::Shared => {
            let d = shared_divisor(per_core);
            let busy = per_core
                .iter()
                .map(|segs| segs.iter().map(|s| s.busy(d)).sum())
                .collect();
            let contenders = per_core.iter().filter(|segs| dma_bound(segs, d)).count();
            BusAccount { busy, useful, contenders }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const E: u64 = EXT_BYTES_PER_CYCLE as u64;

    /// A latency-free, fully-steady segment (no fill or serial
    /// portion): `part` is the executor's overlap max.
    fn seg(compute: u64, bytes: u64) -> Segment {
        Segment {
            compute,
            bytes,
            lat: 0,
            part: compute.max(bytes.div_ceil(E)),
            ..Default::default()
        }
    }

    #[test]
    fn partitioned_is_the_private_sum() {
        let cores = vec![vec![seg(100, 80 * E), seg(500, 8)], vec![seg(900, 8)]];
        let acct = core_busy(&cores, BusModel::Partitioned);
        assert_eq!(acct.busy, vec![80 + 500, 900]);
        assert_eq!(acct.useful, acct.busy);
        assert_eq!(acct.contenders, 0);
    }

    #[test]
    fn one_dma_bound_core_keeps_the_full_bus() {
        // one DMA-bound core among compute-bound peers: divisor 1, the
        // shared channel prices exactly like the partitioned one
        let cores = vec![
            vec![seg(100, 1000 * E)], // dma 1000 > compute 100
            vec![seg(5000, 10 * E)],  // compute-bound
            vec![seg(5000, 10 * E)],
        ];
        let part = core_busy(&cores, BusModel::Partitioned);
        let shared = core_busy(&cores, BusModel::Shared);
        assert_eq!(shared.busy, part.busy);
        assert_eq!(shared.contenders, 1);
    }

    #[test]
    fn single_contender_divisor_is_exactly_one() {
        // The 1-contender boundary: with only one DMA-bound core (idle
        // and compute-bound peers don't count) the divisor must be
        // exactly 1, so the shared-bus accounting is bit-identical to
        // the partitioned model and busy == useful on every core —
        // per-core utilization derived from this split can never
        // exceed 1.0.
        let cores = vec![
            vec![seg(100, 1000 * E)], // the lone DMA-bound core
            vec![seg(5000, 10 * E)],  // compute-bound
            vec![],                   // idle
        ];
        assert_eq!(shared_divisor(&cores), 1);
        let acct = core_busy(&cores, BusModel::Shared);
        let part = core_busy(&cores, BusModel::Partitioned);
        assert_eq!(acct.busy, part.busy, "divisor 1 must price like the partitioned bus");
        assert_eq!(acct.busy, acct.useful, "no contention wait at the 1-contender boundary");
        // and with zero DMA-bound cores the divisor stays pinned at 1
        let quiet = vec![vec![seg(5000, 10 * E)], vec![seg(4000, 8 * E)]];
        assert_eq!(shared_divisor(&quiet), 1);
    }

    #[test]
    fn occupied_never_below_useful_under_contention() {
        // busy >= useful for every core at every contender count: the
        // shared bus only ever *adds* wait cycles, so utilization
        // (useful over occupied makespan) stays <= 1.0.
        for n in 1..6usize {
            let cores: Vec<Vec<Segment>> =
                (0..n).map(|i| vec![seg(100 + i as u64, 500 * E)]).collect();
            let acct = core_busy(&cores, BusModel::Shared);
            for (b, u) in acct.busy.iter().zip(&acct.useful) {
                assert!(b >= u, "{n} cores: occupied {b} < useful {u}");
            }
        }
    }

    #[test]
    fn two_dma_bound_cores_halve_the_bandwidth() {
        let cores = vec![
            vec![seg(100, 1000 * E)],
            vec![seg(100, 1000 * E)],
            vec![seg(5000, 10 * E)], // stays compute-bound even at d=2
        ];
        let acct = core_busy(&cores, BusModel::Shared);
        // transfer term doubles for the two contenders
        assert_eq!(acct.busy[0], 2000);
        assert_eq!(acct.busy[1], 2000);
        // the compute-bound core absorbs its (doubled) transfer in slack
        assert_eq!(acct.busy[2], 5000);
        assert_eq!(acct.useful, vec![1000, 1000, 5000]);
        assert_eq!(acct.contenders, 2);
    }

    #[test]
    fn n_dma_bound_cores_divide_by_n() {
        for n in [2usize, 3, 4, 8] {
            let cores: Vec<Vec<Segment>> =
                (0..n).map(|_| vec![seg(100, 1000 * E)]).collect();
            let acct = core_busy(&cores, BusModel::Shared);
            assert_eq!(acct.contenders, n);
            for c in 0..n {
                assert_eq!(acct.busy[c], 1000 * n as u64, "{n} cores");
                assert_eq!(acct.useful[c], 1000);
            }
        }
    }

    #[test]
    fn contention_cascade_tips_borderline_cores() {
        // C is compute-bound at full bandwidth (1500 > 1000) but flips
        // once A and B halve the bus (2000 > 1500): divisor grows 2 -> 3.
        let cores = vec![
            vec![seg(100, 1000 * E)],
            vec![seg(100, 1000 * E)],
            vec![seg(1500, 1000 * E)],
        ];
        let acct = core_busy(&cores, BusModel::Shared);
        assert_eq!(acct.contenders, 3);
        assert_eq!(acct.busy, vec![3000, 3000, 3000]);
    }

    #[test]
    fn latency_term_does_not_scale() {
        let s = Segment { compute: 0, bytes: 10 * E, lat: 400, part: 410, ..Default::default() };
        let cores = vec![vec![s], vec![s]];
        let acct = core_busy(&cores, BusModel::Shared);
        // transfer doubles (10 -> 20); the 400-cycle latency term doesn't
        assert_eq!(acct.busy, vec![420, 420]);
    }

    #[test]
    fn stage_interval_overlaps_compute_and_dma_across_layers() {
        // two layers, one compute-bound and one DMA-bound: the repeating
        // stage schedule hides each stream under the other, so the
        // interval is the max of the sums, not the sum of the maxes
        let segs = vec![seg(1000, 10 * E), seg(50, 600 * E)];
        assert_eq!(stage_interval(&segs, 1), 1050.max(610));
        // contention scales only the transfer term
        assert_eq!(stage_interval(&segs, 4), (4 * 610).max(1050));
        // empty stages are free
        assert_eq!(stage_interval(&[], 3), 0);
        // the first pass has no repeating schedule to prefetch against:
        // layers chain at their individual max(compute, dma) times, so
        // it can never undercut the steady-state interval
        assert_eq!(stage_first_pass(&segs, 1), 1000 + 600);
        assert_eq!(stage_first_pass(&segs, 4), 1000 + 2400);
        assert!(stage_first_pass(&segs, 1) >= stage_interval(&segs, 1));
    }

    #[test]
    fn fc_shaped_stages_price_as_weight_dma() {
        // An FC tail segment: tiny compute, a huge weight stream (every
        // weight crosses the bus once per frame). The steady-state
        // interval of a stage holding it is pinned by the DMA sum, and
        // an upstream compute-bound conv segment in the same stage
        // hides its own transfer under the FC stream — the overlap the
        // pipeline DP exploits when it isolates the FC tail.
        let fc = seg(500, 20_000 * E); // dma 20000 >> compute 500
        let conv = seg(8_000, 100 * E); // compute-bound
        assert_eq!(stage_interval(&[fc], 1), 20_000);
        assert_eq!(stage_interval(&[conv, fc], 1), 20_100.max(8_500));
        // fill pricing chains the layers instead
        assert_eq!(stage_first_pass(&[conv, fc], 1), 8_000 + 20_000);
        // under contention only the transfer terms scale
        assert_eq!(stage_interval(&[fc], 3), 60_000);
        // an FC stage next to a conv stage: the FC stage is the lone
        // DMA-bound contender, so it keeps the full bus (divisor 1)
        let cores = vec![vec![fc], vec![conv]];
        assert_eq!(shared_divisor(&cores), 1);
        let acct = core_busy(&cores, BusModel::Shared);
        assert_eq!(acct.busy, vec![20_000, 8_000]);
        assert_eq!(acct.contenders, 1);
    }

    #[test]
    fn weight_residency_drops_steady_bytes_but_not_fill() {
        // The engine models FC weight residency by shrinking a steady
        // segment's payload while pricing the fill pass on the
        // original segment: the interval falls with the bytes, the
        // first pass doesn't, and a DMA-bound segment stays strictly
        // ordered (fill > steady) as long as anything was resident.
        let fc_full = seg(500, 20_000 * E); // fill view: full weight stream
        let fc_resident = seg(500, 2_000 * E); // steady view: weights stay in DM
        assert_eq!(stage_first_pass(&[fc_full], 1), 20_000);
        assert_eq!(stage_interval(&[fc_resident], 1), 2_000);
        assert!(stage_interval(&[fc_resident], 1) < stage_first_pass(&[fc_full], 1));
        // residency never lifts a segment below its compute floor
        let all_resident = seg(500, 0);
        assert_eq!(stage_interval(&[all_resident], 1), 500);
    }

    #[test]
    fn group_pricing_degenerates_to_single_core_stage() {
        // one core slot: group pricing must equal the legacy stage
        // pricing exactly, segment for segment
        let a = seg(1000, 10 * E);
        let b = seg(50, 600 * E);
        let layers = vec![vec![(0usize, a)], vec![(0usize, b)]];
        for d in [1u64, 3] {
            assert_eq!(group_interval(&layers, 1, d), stage_interval(&[a, b], d));
            assert_eq!(group_first_pass(&layers, 1, d), stage_first_pass(&[a, b], d));
        }
    }

    #[test]
    fn group_pricing_takes_the_slowest_core() {
        // one layer split into two shards on two cores: the layer
        // barrier means the stage runs at the slower shard's pace,
        // and the interval view is per-core (each core repeats only
        // its own shard schedule)
        let fast = seg(100, 10 * E);
        let slow = seg(400, 10 * E);
        let layers = vec![vec![(0usize, fast), (1usize, slow)]];
        assert_eq!(group_first_pass(&layers, 2, 1), 400);
        assert_eq!(group_interval(&layers, 2, 1), 400);
        // two layers, shards alternating cores: per-core overlap sums
        // each core's own compute/dma streams
        let layers2 = vec![
            vec![(0usize, seg(1000, 10 * E)), (1usize, seg(900, 10 * E))],
            vec![(0usize, seg(50, 600 * E)), (1usize, seg(60, 500 * E))],
        ];
        // core 0: max(1050, 610) = 1050; core 1: max(960, 510) = 960
        assert_eq!(group_interval(&layers2, 2, 1), 1050);
        // layer barriers: max(1000, 900) + max(600, 510) = 1600
        assert_eq!(group_first_pass(&layers2, 2, 1), 1000 + 600);
        // a core with no shards in the stage contributes nothing
        assert_eq!(group_interval(&layers2, 3, 1), 1050);
        // contention scales only the transfer terms
        assert!(group_interval(&layers2, 2, 4) > group_interval(&layers2, 2, 1));
        assert!(group_first_pass(&layers2, 2, 1) >= group_interval(&layers2, 2, 1));
    }

    #[test]
    fn idle_cores_never_contend() {
        let cores = vec![vec![seg(10, 1000 * E)], vec![]];
        let acct = core_busy(&cores, BusModel::Shared);
        assert_eq!(acct.contenders, 1);
        assert_eq!(acct.busy[1], 0);
    }

    #[test]
    fn of_layer_decomposes_fill_steady_and_serial_portions() {
        // rotated layer: 1000E bytes over 5 requests, a 100E/1-request
        // fill carved out, the steady remainder hidden under compute
        let rot = LayerResult {
            compute_cycles: 5000,
            io_in: 900 * E,
            io_out: 100 * E,
            dma_fill_bytes: 100 * E,
            dma_fill_cycles: 100 + 40,
            dma_cycles: 1000 + 5 * 40,
            cycles: 5140, // fill + compute (steady stream fully hidden)
            ..Default::default()
        };
        let s = Segment::of_layer(&rot);
        assert_eq!((s.fill_bytes, s.fill_lat), (100 * E, 40));
        assert_eq!((s.bytes, s.lat), (900 * E, 160));
        assert_eq!((s.serial_bytes, s.serial_lat), (0, 0));
        assert_eq!(s.busy(1), 5140, "d=1 must reproduce the private result exactly");
        assert_eq!(s.dma(1), 1200);
        // contention scales the fill's transfer term too (its bytes
        // press the bus) but the overlapped compute absorbs the
        // doubled steady stream
        assert_eq!(s.busy(2), (40 + 200) + 5000);
        assert_eq!(stage_interval(&[s], 1), 5000 + 0);

        // un-rotatable layer: the whole 2000E/3-request stream serial
        let ser = LayerResult {
            compute_cycles: 500,
            io_in: 2000 * E,
            dma_serial_bytes: 2000 * E,
            dma_serial_cycles: 2000 + 120,
            dma_cycles: 2000 + 120,
            cycles: 500 + 2120,
            ..Default::default()
        };
        let t = Segment::of_layer(&ser);
        assert_eq!((t.serial_bytes, t.serial_lat), (2000 * E, 120));
        assert_eq!((t.bytes, t.lat, t.fill_bytes), (0, 0, 0));
        assert_eq!(t.busy(1), 2620);
        // the serialized stream never hides under stage compute: the
        // interval strictly exceeds the old max-of-sums overlap
        assert_eq!(stage_interval(&[t], 1), 2120 + 500);
        assert!(stage_interval(&[t], 1) > 500u64.max(2120));
        assert_eq!(t.busy(2), (120 + 4000) + 500);
        // mixed stage: serial portions add, the rest overlaps
        assert_eq!(stage_interval(&[s, t], 1), 2120 + 5500u64.max(1200));
    }

    #[test]
    fn segment_of_layer_roundtrips_the_dma_model() {
        // dma_cycles = ceil(bytes / E) + reqs * lat, as the executor
        // computes it; of_layer must recover the latency term exactly
        let r = LayerResult {
            compute_cycles: 50,
            dma_cycles: (1000 * E).div_ceil(E) + 3 * 40,
            io_in: 600 * E,
            io_out: 400 * E,
            cycles: 1120,
            ..Default::default()
        };
        let s = Segment::of_layer(&r);
        assert_eq!(s.bytes, 1000 * E);
        assert_eq!(s.lat, 120);
        assert_eq!(s.busy(1), 1120);
        assert_eq!(s.busy(2), 2120);
    }
}
