//! The `Engine`: the one front door for running layers, networks and
//! batches on one or many ConvAix cores.
//!
//! The paper separates *what* a layer computes from *how* it is
//! scheduled onto the vector lanes (Fig. 2); this module gives the
//! coordinator the same separation at the chip level. An [`Engine`] is
//! built from an [`EngineConfig`] (cores, batch, shard policy, pool
//! mode, bus model, execution mode, seed) and exposes the entry points
//! [`Engine::run_layer`], [`Engine::run_network`],
//! [`Engine::run_batched`] and [`Engine::run_streaming`]. (The 0.2
//! free-function API and its `#[deprecated]` 0.3 shims are gone;
//! `tools/check-deprecated.sh` keeps them from coming back.)
//!
//! Internally there is exactly **one** network walk
//! (`walk_network`), parameterized by a `LayerRunner`: the
//! single-core runner and the sharded pool runner are two
//! implementations of the same trait, so the deterministic xorshift
//! weight draws stay bit-identical across core counts by construction
//! (the multicore determinism tests lock that contract). Everything
//! layer-kind-specific — shapes, draws, execution, shard building,
//! merging, the first-order cost model — lives behind the
//! [`LayerOp`](super::ops::LayerOp) trait (conv, pool and FC layers);
//! the engine never matches on the layer kind.
//!
//! Two intra-layer shard axes are offered ([`ShardPolicy`]); FC layers
//! always shard as *neuron tiles* (oc tiles of their 1×1 lowering):
//!
//! * **`OcTile`** — output channels split into tile-aligned contiguous
//!   ranges (the seed policy). Every core re-reads the full input but
//!   only its filter slice; best when `oc` is deep.
//! * **`RowBand`** — contiguous output-row bands, each core running the
//!   *full* `oc` over a slice of rows (with the halo rows its windows
//!   need). Divides the input traffic instead of the filter traffic;
//!   best for early layers where `oc < cores × ocs` or the input
//!   dominates DMA. Outputs are still bit-identical: each output
//!   element is produced by exactly the arithmetic the single-core
//!   schedule would run.
//! * **`Auto`** — per layer, picks whichever policy predicts the lower
//!   makespan under a first-order cost model (MACs for compute, tensor
//!   footprints over the bus width for DMA).
//!
//! External bandwidth is priced by a [`BusModel`]: `Partitioned` keeps
//! the seed assumption of a private full-width port per core; `Shared`
//! divides `EXT_BYTES_PER_CYCLE` across concurrently DMA-bound cores
//! (see [`super::bus`]).
//!
//! Multi-frame streams have two pool layouts ([`PoolMode`]):
//!
//! * **`FanOut`** ([`Engine::run_batched`]) — whole frames round-robin
//!   over the cores, every core running the full network. Best bulk
//!   throughput when the batch divides evenly by the core count.
//! * **`Pipelined`** ([`Engine::run_streaming`]) — the network is cut
//!   into contiguous layer *stages* balanced by the predicted-makespan
//!   cost model, each stage owning a core *group* per [`StageCores`]
//!   (one core per stage by default; `Auto` runs a partition-DP that
//!   may hand a fat conv stage several cores and shard its layers
//!   across them); frame `t` runs on stage `i` while
//!   frame `t−1` occupies stage `i+1` (the resource-partitioning
//!   regime of Shen et al., arXiv:1607.00064). Stage-boundary
//!   activations cross the external bus inside the existing per-layer
//!   DMA accounting (the producer's OFMap write, the consumer's IFMap
//!   read), so [`BusModel::Shared`] contention applies across
//!   concurrently streaming stages. Layer outputs stay bit-identical
//!   to single-core runs: both walks share one layer-step helper and
//!   one weight-draw stream.

use std::sync::Arc;
use std::thread;

use crate::codegen::compiled::{CacheStats, PlanCache, Scratch};
use crate::core::Cpu;
use crate::model::{ConvLayer, FcLayer, NetLayer, PoolLayer};

use super::bus::{
    core_busy, dma_bound, group_first_pass, group_interval, shared_divisor, BusModel, Segment,
};
use super::executor::{ExecCtx, ExecError, ExecMode, ExecOptions};
use super::faults::{apply_layer_faults, layer_key, FaultPlan, FaultReport};
use super::metrics::{add_stats, LayerResult, MultiTenantResult, NetworkResult, PipelineResult};
use super::ops::Shard;

/// How a layer is split across the pool's cores.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ShardPolicy {
    /// Shard output channels into tile-aligned contiguous ranges
    /// (pool layers: 16-channel slabs).
    #[default]
    OcTile,
    /// Shard contiguous output-row bands at full output depth.
    RowBand,
    /// Pick per layer by predicted makespan.
    Auto,
}

impl std::str::FromStr for ShardPolicy {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "oc-tile" | "oc" => Ok(Self::OcTile),
            "row-band" | "row" => Ok(Self::RowBand),
            "auto" => Ok(Self::Auto),
            other => Err(format!("unknown shard policy `{other}` (oc-tile | row-band | auto)")),
        }
    }
}

/// How a multi-frame stream is laid onto the pool's cores.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PoolMode {
    /// Fan whole frames out across the cores: every core runs the full
    /// network on its share of the frames ([`Engine::run_batched`]).
    #[default]
    FanOut,
    /// Partition the network into contiguous layer stages, one core per
    /// stage, and stream frames through them
    /// ([`Engine::run_streaming`]).
    Pipelined,
}

impl std::str::FromStr for PoolMode {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "fan-out" | "batch" => Ok(Self::FanOut),
            "pipelined" | "pipeline" => Ok(Self::Pipelined),
            other => Err(format!("unknown pool mode `{other}` (fan-out | pipelined)")),
        }
    }
}

/// How [`PoolMode::Pipelined`] streaming maps pipeline stages onto the
/// pool's cores.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub enum StageCores {
    /// One core per stage (the legacy pipeline, and the default): the
    /// network is cut into `min(cores, layers)` contiguous stages by
    /// the bottleneck DP.
    #[default]
    PerStage,
    /// Partition-DP over (stage cut, core count) pairs: stages may own
    /// **unequal core groups** (a fat conv stage takes 2–3 cores and
    /// shards its layers across them per the run's
    /// [`ShardPolicy`]; a weight-DMA-bound FC tail keeps 1), chosen to
    /// minimize the predicted bottleneck interval over every feasible
    /// (cut, core-count) assignment — the resource-partitioning regime
    /// of Shen et al. (arXiv:1607.00064) applied to the layer
    /// pipeline. An all-groups-of-1 outcome is bit-identical to
    /// [`StageCores::PerStage`].
    Auto,
    /// Explicit per-stage core counts, e.g. `vec![1, 2, 1]` = three
    /// stages, the middle one sharding across two cores. The layer
    /// cuts are still chosen by the bottleneck DP *given* the group
    /// sizes; the counts must sum to at most the engine's cores.
    Fixed(Vec<usize>),
}

impl std::str::FromStr for StageCores {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "auto" => Ok(Self::Auto),
            "per-stage" | "one" => Ok(Self::PerStage),
            list => {
                let plan: Result<Vec<usize>, _> =
                    list.split(',').map(|p| p.trim().parse::<usize>()).collect();
                match plan {
                    Ok(p) if !p.is_empty() && p.iter().all(|&k| k >= 1) => Ok(Self::Fixed(p)),
                    _ => Err(format!(
                        "unknown stage-cores plan `{list}` (auto | per-stage | k1,k2,… with every k >= 1)"
                    )),
                }
            }
        }
    }
}

/// Builder for an [`Engine`]. Every knob has the seed-compatible
/// default, so `EngineConfig::new().build()` is the paper's single-core
/// full-cycle setup.
///
/// ```no_run
/// use convaix::coordinator::{BusModel, EngineConfig, NetLayer, ShardPolicy};
/// use convaix::model::ConvLayer;
///
/// let mut engine = EngineConfig::new()
///     .cores(4)
///     .shard(ShardPolicy::Auto)
///     .bus(BusModel::Shared)
///     .build();
/// let layers = vec![NetLayer::Conv(ConvLayer::new("c1", 4, 8, 8, 16, 3, 3, 1, 1, 1))];
/// let input = vec![0i16; 4 * 8 * 8];
/// let net = engine.run_network("demo", &layers, &input).unwrap();
/// assert_eq!(net.layers.len(), 1);
/// ```
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// ConvAix cores in the pool (1 = the paper's setup).
    pub cores: usize,
    /// Nominal frames per [`Engine::run_batched`] call. Advisory: the
    /// CLI/report tooling uses it to size synthetic input batches; the
    /// engine itself batches exactly the `inputs` it is handed.
    pub batch: usize,
    /// Intra-layer shard axis for multi-core single-frame runs.
    pub shard: ShardPolicy,
    /// Pool layout for multi-frame streams: frame fan-out or layer
    /// pipelining. Advisory for the `run_*` entry points (each has a
    /// fixed layout); the CLI and report tooling dispatch on it.
    pub pool_mode: PoolMode,
    /// Stage-to-core mapping for [`Engine::run_streaming`]: the legacy
    /// one-core-per-stage pipeline (default), the partition-DP over
    /// unequal core groups, or an explicit per-stage plan.
    pub stage_cores: StageCores,
    /// External-bandwidth model for multi-core runs.
    pub bus: BusModel,
    /// Cycle simulation fidelity.
    pub mode: ExecMode,
    /// Precision gating (16 = off, 8 = the paper's gated operating point).
    pub gate_bits: u8,
    /// Seed of the deterministic per-layer xorshift weight draws.
    pub seed: u64,
    /// External DRAM model capacity per core, bytes.
    pub ext_capacity: usize,
    /// Compile-once layer cache (default on): memoize layout plans,
    /// task programs and tile-analytic profiles per layer shape across
    /// frames, shards and pipeline stages. `false` compiles fresh on
    /// every call — the pre-0.5 behavior, kept as the honest baseline
    /// for `benches/simspeed` (CLI: `--no-cache`). Outputs, cycle
    /// counts and stats are bit-identical either way (locked by
    /// `tests/plan_cache.rs`).
    pub plan_cache: bool,
    /// DMA double buffering (default on): plans allocate a DM rotation
    /// shadow where capacity permits, so steady-state iterations
    /// overlap compute with the next iteration's stream. `false` is
    /// the honest no-overlap baseline (CLI: `--no-rotation`). Outputs
    /// are bit-identical either way — only cycles move (locked by
    /// `tests/rotation_identity.rs`).
    pub dma_rotation: bool,
    /// Seeded fault-injection campaign (`None` = the perfect substrate
    /// every pre-0.10 run assumed). With detection on, injected faults
    /// are recovered transparently — outputs stay bit-identical to the
    /// fault-free run, only cycles move; with detection off they
    /// silently corrupt outputs (see [`super::faults`]). CLI:
    /// `--inject seed[:rate[:kinds]]`.
    pub faults: Option<FaultPlan>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            cores: 1,
            batch: 1,
            shard: ShardPolicy::OcTile,
            pool_mode: PoolMode::FanOut,
            stage_cores: StageCores::PerStage,
            bus: BusModel::Partitioned,
            mode: ExecMode::FullCycle,
            gate_bits: 16,
            seed: 0xC0FFEE,
            ext_capacity: 1 << 24,
            plan_cache: true,
            dma_rotation: true,
            faults: None,
        }
    }
}

impl EngineConfig {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn cores(mut self, n: usize) -> Self {
        self.cores = n.max(1);
        self
    }

    pub fn batch(mut self, n: usize) -> Self {
        self.batch = n.max(1);
        self
    }

    pub fn shard(mut self, p: ShardPolicy) -> Self {
        self.shard = p;
        self
    }

    pub fn pool_mode(mut self, m: PoolMode) -> Self {
        self.pool_mode = m;
        self
    }

    /// Stage-to-core mapping for pipelined streaming (see
    /// [`StageCores`]).
    pub fn stage_cores(mut self, sc: StageCores) -> Self {
        self.stage_cores = sc;
        self
    }

    pub fn bus(mut self, b: BusModel) -> Self {
        self.bus = b;
        self
    }

    pub fn mode(mut self, m: ExecMode) -> Self {
        self.mode = m;
        self
    }

    pub fn gate_bits(mut self, bits: u8) -> Self {
        self.gate_bits = bits;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    pub fn ext_capacity(mut self, bytes: usize) -> Self {
        self.ext_capacity = bytes;
        self
    }

    /// Enable/disable the compile-once layer cache (see the field doc).
    pub fn plan_cache(mut self, on: bool) -> Self {
        self.plan_cache = on;
        self
    }

    /// Enable/disable DMA double buffering (see the field doc).
    pub fn dma_rotation(mut self, on: bool) -> Self {
        self.dma_rotation = on;
        self
    }

    /// Arm a seeded fault-injection campaign (see the field doc).
    pub fn faults(mut self, plan: FaultPlan) -> Self {
        self.faults = Some(plan);
        self
    }

    /// Finish the builder: allocate the core pool and return the engine.
    pub fn build(self) -> Engine {
        Engine::new(self)
    }

    pub(crate) fn run_spec(&self) -> RunSpec {
        RunSpec {
            opts: ExecOptions {
                mode: self.mode,
                gate_bits: self.gate_bits,
                cores: self.cores,
                batch: self.batch,
                rotation: self.dma_rotation,
            },
            shard: self.shard,
            bus: self.bus,
            seed: self.seed,
            faults: self.faults,
        }
    }
}

/// Everything a single run needs besides the pool — bundles the legacy
/// [`ExecOptions`] with the engine-level policies.
#[derive(Debug, Clone, Copy)]
pub(crate) struct RunSpec {
    pub opts: ExecOptions,
    pub shard: ShardPolicy,
    pub bus: BusModel,
    pub seed: u64,
    pub faults: Option<FaultPlan>,
}

/// The execution engine: an [`EngineConfig`] plus its pool of
/// cycle-accurate cores and the shared compile-once [`PlanCache`].
/// All public entry points run on this.
pub struct Engine {
    cfg: EngineConfig,
    pool: CorePool,
    /// Compile-once layer cache, shared by every core thread (and, via
    /// [`Engine::new_with_cache`], across engines). Compiled layers
    /// persist across `run_*` calls, so the steady-state loop of
    /// batched / streaming serving performs zero codegen after the
    /// first frame of each shape.
    cache: Arc<PlanCache>,
}

impl Engine {
    pub fn new(cfg: EngineConfig) -> Self {
        let cache =
            Arc::new(if cfg.plan_cache { PlanCache::new() } else { PlanCache::disabled() });
        Self::new_with_cache(cfg, cache)
    }

    /// Build an engine over an existing (possibly shared) plan cache —
    /// several engines serving the same model zoo can reuse one
    /// compiled-layer set.
    pub fn new_with_cache(cfg: EngineConfig, cache: Arc<PlanCache>) -> Self {
        let pool = CorePool::new(cfg.cores, cfg.ext_capacity);
        Self { cfg, pool, cache }
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    pub fn cores(&self) -> usize {
        self.pool.cores()
    }

    /// The engine's compile-once layer cache.
    pub fn plan_cache(&self) -> &Arc<PlanCache> {
        &self.cache
    }

    /// Hit/miss counters and entry counts of the plan cache.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Cores blacklisted by fault degrade so far (blacklist order).
    /// Blacklists persist across `run_*` calls — a benched core stays
    /// benched for the engine's lifetime, as a fused-off core would.
    pub fn blacklisted_cores(&self) -> &[usize] {
        self.pool.blacklisted()
    }

    /// Run one network layer (any [`LayerOp`](super::ops::LayerOp)
    /// kind) with caller-provided tensors, sharded per the config.
    /// `w`/`b` are empty for weightless layers (pools).
    pub fn run_layer(
        &mut self,
        layer: &NetLayer,
        x: &[i16],
        w: &[i16],
        b: &[i32],
    ) -> Result<LayerResult, ExecError> {
        let spec = self.cfg.run_spec();
        run_layer_sharded(&mut self.pool, &self.cache, layer, x, w, b, spec)
    }

    /// Run a (possibly grouped) conv layer. `x`: (ic, ih, iw), `w`:
    /// (oc, ic/groups, fh, fw), `b`: (oc,). Outputs and MAC counts are
    /// bit-identical across core counts and shard policies.
    pub fn run_conv_layer(
        &mut self,
        layer: &ConvLayer,
        x: &[i16],
        w: &[i16],
        b: &[i32],
    ) -> Result<LayerResult, ExecError> {
        self.run_layer(&NetLayer::Conv(layer.clone()), x, w, b)
    }

    /// Run a max-pool layer. `x`: (ic, ih, iw).
    pub fn run_pool_layer(
        &mut self,
        layer: &PoolLayer,
        x: &[i16],
    ) -> Result<LayerResult, ExecError> {
        self.run_layer(&NetLayer::Pool(layer.clone()), x, &[], &[])
    }

    /// Run a fully connected layer. `x`: (in_features,), `w`:
    /// (out_features, in_features), `b`: (out_features,). Sharded as
    /// neuron tiles; outputs are bit-identical across core counts.
    pub fn run_fc_layer(
        &mut self,
        layer: &FcLayer,
        x: &[i16],
        w: &[i16],
        b: &[i32],
    ) -> Result<LayerResult, ExecError> {
        self.run_layer(&NetLayer::Fc(layer.clone()), x, w, b)
    }

    /// Run a layer sequence, threading activations; weights/biases are
    /// drawn deterministically (xorshift, `cfg.seed`) per layer. In
    /// analytic mode activations are not threaded (zeros).
    pub fn run_network(
        &mut self,
        name: &str,
        layers: &[NetLayer],
        input: &[i16],
    ) -> Result<NetworkResult, ExecError> {
        let spec = self.cfg.run_spec();
        run_network_on(&mut self.pool, &self.cache, name, layers, input, spec)
    }

    /// Batched inference: fan `inputs` (one tensor per frame)
    /// round-robin over the cores, each core running whole networks
    /// back to back — no intra-layer synchronization. A single-frame
    /// batch is bit-identical to [`Engine::run_network`].
    pub fn run_batched(
        &mut self,
        name: &str,
        layers: &[NetLayer],
        inputs: &[Vec<i16>],
    ) -> Result<BatchedResult, ExecError> {
        let spec = self.cfg.run_spec();
        run_batched_on(&mut self.pool, &self.cache, name, layers, inputs, spec)
    }

    /// Layer-pipelined streaming ([`PoolMode::Pipelined`]): cut the
    /// network into contiguous stages balanced by the predicted-
    /// makespan cost model, give each stage a core *group* per the
    /// config's [`StageCores`] (one core per stage by default; the
    /// partition-DP may assign unequal groups, inside which layers
    /// shard per the run's [`ShardPolicy`]), and stream `inputs`
    /// through them — frame `t` on stage `i` while frame `t−1`
    /// occupies stage `i+1`. Layer outputs are bit-identical to
    /// [`Engine::run_network`] per frame for every partition; the
    /// result reports steady-state throughput, fill/drain latency and
    /// the per-stage occupied-vs-useful cycle split.
    pub fn run_streaming(
        &mut self,
        name: &str,
        layers: &[NetLayer],
        inputs: &[Vec<i16>],
    ) -> Result<PipelineResult, ExecError> {
        let spec = self.cfg.run_spec();
        let sc = self.cfg.stage_cores.clone();
        run_streaming_on(&mut self.pool, &self.cache, name, layers, inputs, spec, &sc)
    }
}

/// One tenant of a multi-tenant run ([`run_multi_streaming`]): an
/// engine (its own cores, gate bits, seed, stage plan — and possibly a
/// plan cache shared across tenants via [`Engine::new_with_cache`])
/// plus the network and frame stream it serves.
pub struct TenantRun<'a> {
    pub engine: &'a mut Engine,
    pub name: &'a str,
    pub layers: &'a [NetLayer],
    pub inputs: &'a [Vec<i16>],
}

/// Run several tenants concurrently on one shared external bus: each
/// tenant pipelines its own network over its own engine's cores
/// (partitioned per that engine's [`StageCores`]), and the shared-bus
/// bandwidth divisor is the fixed point over **every** tenant's core
/// timelines — tenant A's weight-DMA-bound FC tail slows tenant B's
/// DMA-bound stages exactly as co-located accelerators on one DRAM
/// channel would. Outputs stay bit-identical to each tenant's solo
/// run (contention only adds wait cycles); per-tenant
/// [`PipelineResult`]s come back priced under the combined divisor,
/// plus the combined contention account. Engines' own `bus` configs
/// are overridden by the episode's shared channel.
pub fn run_multi_streaming(
    tenants: &mut [TenantRun<'_>],
) -> Result<MultiTenantResult, ExecError> {
    // exec phase: each tenant walks its frames on its own cores (the
    // bit-identical half — segments are collected, nothing priced yet)
    let mut execs = Vec::with_capacity(tenants.len());
    let mut tenant_cores = Vec::with_capacity(tenants.len());
    for t in tenants.iter_mut() {
        let eng = &mut *t.engine;
        let spec = eng.cfg.run_spec();
        let sc = eng.cfg.stage_cores.clone();
        tenant_cores.push(eng.pool.cores());
        let (ex, waste) = stream_exec_degrading(
            &mut eng.pool,
            &eng.cache,
            t.name,
            t.layers,
            t.inputs,
            spec,
            &sc,
        )?;
        execs.push((ex, waste, eng.pool.blacklisted().to_vec()));
    }
    // hierarchical pricing: the fixed-point divisor over ALL tenants'
    // per-core aggregate DMA timelines (stages feed their core groups'
    // timelines up into one pool-wide contention account)
    let all: Vec<Vec<Segment>> = execs.iter().flat_map(|(ex, _, _)| core_timelines(ex)).collect();
    let d = shared_divisor(&all);
    let contenders = all.iter().filter(|segs| dma_bound(segs, d)).count();
    let mut res = MultiTenantResult {
        tenant_cores,
        divisor: d,
        contenders,
        ..Default::default()
    };
    for (ex, waste, dead) in execs {
        let mut pr = price_stream(ex, BusModel::Shared, d);
        pr.faults = FaultReport {
            retries: pr.frames.iter().map(|f| f.fault_retries()).sum(),
            recovery_cycles: pr.frames.iter().map(|f| f.fault_recovery_cycles()).sum::<u64>()
                + waste,
            blacklisted_cores: dead,
            degrade_waste_cycles: waste,
        };
        pr.makespan_cycles += waste;
        res.faults.absorb(&pr.faults);
        res.tenants.push(pr);
    }
    Ok(res)
}

/// A pool of independent ConvAix cores (one cycle simulator each),
/// each paired with its own [`Scratch`] staging arena so core threads
/// reuse buffers without sharing them.
pub struct CorePool {
    cpus: Vec<Cpu>,
    scratch: Vec<Scratch>,
    /// Liveness mask: a core that exhausted its fault retry budget is
    /// blacklisted (`false`) and the degrade paths re-distribute work
    /// over the survivors. All-true on a fault-free pool.
    alive: Vec<bool>,
    /// Blacklisted core ids in blacklist order — the degraded-topology
    /// report surfaced through [`FaultReport::blacklisted_cores`].
    dead: Vec<usize>,
}

impl CorePool {
    /// Build a pool of `cores` cores (min 1), each with its own
    /// external-memory model of `ext_capacity` bytes.
    pub fn new(cores: usize, ext_capacity: usize) -> Self {
        let cores = cores.max(1);
        Self {
            cpus: (0..cores).map(|_| Cpu::new(ext_capacity)).collect(),
            scratch: (0..cores).map(|_| Scratch::default()).collect(),
            alive: vec![true; cores],
            dead: Vec::new(),
        }
    }

    pub fn cores(&self) -> usize {
        self.cpus.len()
    }

    /// Surviving (non-blacklisted) cores.
    pub fn alive_cores(&self) -> usize {
        self.alive.iter().filter(|&&a| a).count()
    }

    /// Ids of the surviving cores, ascending — the logical-slot →
    /// physical-core map every degraded distribution indexes through.
    pub(crate) fn alive_ids(&self) -> Vec<usize> {
        (0..self.cpus.len()).filter(|&i| self.alive[i]).collect()
    }

    /// Blacklist core `i` (idempotent). The caller must keep at least
    /// one core alive — the degrade loops check `alive_cores()` first.
    pub(crate) fn blacklist(&mut self, i: usize) {
        if self.alive[i] {
            self.alive[i] = false;
            self.dead.push(i);
        }
    }

    /// Blacklisted core ids, in blacklist order.
    pub fn blacklisted(&self) -> &[usize] {
        &self.dead
    }

    /// Core 0 — the single-core fallback path.
    pub fn cpu0(&mut self) -> &mut Cpu {
        &mut self.cpus[0]
    }

    /// Core `i` with its scratch arena.
    pub(crate) fn core(&mut self, i: usize) -> (&mut Cpu, &mut Scratch) {
        (&mut self.cpus[i], &mut self.scratch[i])
    }
}

/// The layer-granular half of the ONE network walk: how a single layer
/// is executed. Implemented by the single-core runner and the sharded
/// pool runner; [`walk_network`] is generic over it so the RNG stream
/// and activation threading cannot diverge between the two worlds.
/// Layer-kind dispatch happens behind [`NetLayer::op`] — the runners
/// are kind-agnostic.
pub(crate) trait LayerRunner {
    fn run(
        &mut self,
        layer: &NetLayer,
        x: &[i16],
        w: &[i16],
        b: &[i32],
    ) -> Result<LayerResult, ExecError>;
}

/// Runs every layer on one core, through that core's scratch arena and
/// the engine's shared plan cache.
pub(crate) struct SoloRunner<'a> {
    pub cpu: &'a mut Cpu,
    pub scratch: &'a mut Scratch,
    pub cache: &'a PlanCache,
    pub opts: ExecOptions,
    /// Armed fault campaign, applied per layer at site
    /// `(frame, layer, core)`.
    pub faults: Option<FaultPlan>,
    /// Frame index of the walk this runner executes (fault-site key).
    pub frame: u64,
    /// Physical pool core this runner occupies (fault-site key).
    pub core: usize,
}

impl LayerRunner for SoloRunner<'_> {
    fn run(
        &mut self,
        layer: &NetLayer,
        x: &[i16],
        w: &[i16],
        b: &[i32],
    ) -> Result<LayerResult, ExecError> {
        let mut ctx = ExecCtx::new(self.cache, self.scratch);
        let mut r = layer.op().run_solo(self.cpu, x, w, b, self.opts, &mut ctx)?;
        if let Some(plan) = &self.faults {
            apply_layer_faults(plan, self.frame, layer_key(layer.name()), self.core, &mut r)?;
        }
        Ok(r)
    }
}

/// Shards every layer across the pool per the spec's policy/bus.
pub(crate) struct ShardedRunner<'a> {
    pub pool: &'a mut CorePool,
    pub cache: &'a PlanCache,
    pub spec: RunSpec,
}

impl LayerRunner for ShardedRunner<'_> {
    fn run(
        &mut self,
        layer: &NetLayer,
        x: &[i16],
        w: &[i16],
        b: &[i32],
    ) -> Result<LayerResult, ExecError> {
        run_layer_sharded(self.pool, self.cache, layer, x, w, b, self.spec)
    }
}

/// One step of THE network walk: run `layer` on `runner` against the
/// threaded activation, which is advanced in place when the layer
/// produces an output (FullCycle mode; analytic runs leave it alone).
/// A shape mismatch (analytic mode, or a caller-staged input of the
/// wrong size) substitutes zeros, exactly as the 0.2 walker did. The
/// conv→FC boundary is the implicit flatten: NCHW-contiguous
/// activations already are the feature vector, so the element-count
/// check is the whole boundary.
pub(crate) fn step_layer<R: LayerRunner>(
    runner: &mut R,
    layer: &NetLayer,
    tensors: &Option<(Vec<i16>, Vec<i32>)>,
    act: &mut Vec<i16>,
) -> Result<LayerResult, ExecError> {
    let n_in = layer.op().in_elems();
    let x = if act.len() == n_in { act.clone() } else { vec![0i16; n_in] };
    debug_assert_eq!(
        tensors.is_some(),
        layer.op().param_elems().0 > 0,
        "layer {}: drawn tensors must match its parameter surface",
        layer.name()
    );
    let (w, b): (&[i16], &[i32]) = match tensors {
        Some((w, b)) => (w.as_slice(), b.as_slice()),
        None => (&[], &[]),
    };
    let r = runner.run(layer, &x, w, b)?;
    if !r.out.is_empty() {
        *act = r.out.clone();
    }
    Ok(r)
}

/// THE network walk: threads activations through the layer list and
/// draws per-layer weights/biases lazily from one xorshift stream
/// ([`LayerOp::draw`](super::ops::LayerOp::draw) + [`step_layer`] —
/// one layer's tensors resident at a time). Every public path (single
/// core, sharded, each batched frame, the pipelined stage walk)
/// funnels through these helpers, so the draws are bit-identical
/// everywhere by construction.
pub(crate) fn walk_network<R: LayerRunner>(
    runner: &mut R,
    name: &str,
    layers: &[NetLayer],
    input: &[i16],
    seed: u64,
) -> Result<NetworkResult, ExecError> {
    let mut rng = crate::util::XorShift::new(seed);
    let mut act = input.to_vec();
    let mut net = NetworkResult { name: name.into(), ..Default::default() };
    for layer in layers {
        let t = layer.op().draw(&mut rng);
        net.layers.push(step_layer(runner, layer, &t, &mut act)?);
    }
    Ok(net)
}

/// Single-frame network run on `pool`, single-core or sharded per the
/// spec. The implementation behind [`Engine::run_network`].
pub(crate) fn run_network_on(
    pool: &mut CorePool,
    cache: &PlanCache,
    name: &str,
    layers: &[NetLayer],
    input: &[i16],
    spec: RunSpec,
) -> Result<NetworkResult, ExecError> {
    // One path for any core count: run_layer_sharded degenerates to the
    // single-core executor at n = 1 and owns the fault degrade loop.
    let mut runner = ShardedRunner { pool, cache, spec };
    walk_network(&mut runner, name, layers, input, spec.seed)
}

/// Run per-core worklists on the pool's cores (one host thread per
/// busy core) and return the shard results in shard-index order. Each
/// thread gets its core's scratch arena; the plan cache is shared by
/// reference inside `work`, which also receives the physical core id
/// and the item's global index (the fault-site key halves). A worker
/// thread that panics surfaces as [`ExecError::CoreFailure`] for that
/// core instead of poisoning the whole process — the degrade paths
/// treat it exactly like an exhausted retry budget.
fn run_on_pool<W, R>(
    pool: &mut CorePool,
    assignments: Vec<Vec<(usize, W)>>,
    n_shards: usize,
    work: impl Fn(&mut Cpu, &mut Scratch, usize, usize, &W) -> Result<R, ExecError> + Sync,
) -> Result<Vec<R>, ExecError>
where
    W: Send,
    R: Send,
{
    let work = &work;
    let mut slots: Vec<Option<R>> = (0..n_shards).map(|_| None).collect();
    thread::scope(|s| -> Result<(), ExecError> {
        let mut handles = Vec::new();
        for (core, ((cpu, scratch), list)) in
            pool.cpus.iter_mut().zip(pool.scratch.iter_mut()).zip(assignments).enumerate()
        {
            if list.is_empty() {
                continue;
            }
            handles.push((
                core,
                s.spawn(move || -> Result<Vec<(usize, R)>, ExecError> {
                    let mut done = Vec::with_capacity(list.len());
                    for (idx, w) in &list {
                        done.push((*idx, work(cpu, scratch, core, *idx, w)?));
                    }
                    Ok(done)
                }),
            ));
        }
        for (core, h) in handles {
            let joined = h.join().map_err(|_| ExecError::CoreFailure {
                core,
                layer: "<worker thread panicked>".into(),
            })?;
            for (idx, r) in joined? {
                slots[idx] = Some(r);
            }
        }
        Ok(())
    })?;
    // invariant: every shard index 0..n_shards appears in exactly one
    // per-core list, so a clean join fills every slot.
    Ok(slots.into_iter().map(|r| r.expect("shard not executed")).collect())
}

/// Run any layer sharded across the pool, kind-agnostic: the layer's
/// [`LayerOp`](super::ops::LayerOp) builds the shards, each shard's
/// sub-layer re-enters `run_solo` on its core, and the op's `merge`
/// scatters the outputs and prices the makespan. With one core this is
/// exactly the single-core executor.
pub(crate) fn run_layer_sharded(
    pool: &mut CorePool,
    cache: &PlanCache,
    layer: &NetLayer,
    x: &[i16],
    w: &[i16],
    b: &[i32],
    spec: RunSpec,
) -> Result<LayerResult, ExecError> {
    let mut waste = 0u64;
    loop {
        match layer_sharded_attempt(pool, cache, layer, x, w, b, spec) {
            Err(ExecError::CoreFailure { core, .. }) if pool.alive_cores() > 1 => {
                // Blacklist the exhausted core, charge its watchdog-
                // bounded wasted attempts, and re-run the layer over
                // the survivors (slot-compacted re-distribution).
                waste += degrade_waste(&spec.faults, layer.op().layer_cost());
                pool.blacklist(core);
            }
            Ok(mut r) => {
                r.fault_recovery_cycles += waste;
                r.cycles += waste;
                return Ok(r);
            }
            err => return err,
        }
    }
}

/// Cycles a run wastes per blacklist event before it can re-partition:
/// the failed unit's watchdog-bounded attempts (`FaultPlan::fail_waste`
/// when a campaign is armed; one watchdog interval for a bare worker
/// panic).
fn degrade_waste(faults: &Option<FaultPlan>, static_cycles: u64) -> u64 {
    match faults {
        Some(plan) => plan.fail_waste(static_cycles),
        None => super::faults::watchdog_bound(static_cycles),
    }
}

/// One attempt of [`run_layer_sharded`]: distribute shards over the
/// currently-alive cores (logical slot `i % n` → physical core
/// `alive[i % n]`) and merge. Fault sites key on the physical core id,
/// so a re-run after a blacklist draws fresh sites on the survivors.
fn layer_sharded_attempt(
    pool: &mut CorePool,
    cache: &PlanCache,
    layer: &NetLayer,
    x: &[i16],
    w: &[i16],
    b: &[i32],
    spec: RunSpec,
) -> Result<LayerResult, ExecError> {
    let op = layer.op();
    let alive = pool.alive_ids();
    let n = spec.opts.cores.min(alive.len()).max(1);
    let lkey = layer_key(layer.name());
    if n == 1 {
        let core = alive[0];
        let (cpu, scratch) = pool.core(core);
        let mut r = op.run_solo(cpu, x, w, b, spec.opts, &mut ExecCtx::new(cache, scratch))?;
        if let Some(plan) = &spec.faults {
            apply_layer_faults(plan, 0, lkey, core, &mut r)?;
        }
        return Ok(r);
    }
    let inner = ExecOptions { cores: 1, batch: 1, ..spec.opts };
    let shards = op.shard(x, spec.shard, n);
    let n_shards = shards.len();
    let placements: Vec<Vec<(usize, usize)>> =
        shards.iter().map(|s| s.placement.clone()).collect();
    let core_of: Vec<usize> = (0..n_shards).map(|i| i % n).collect();
    let mut assignments: Vec<Vec<(usize, Shard)>> =
        (0..pool.cores()).map(|_| Vec::new()).collect();
    for (i, sh) in shards.into_iter().enumerate() {
        assignments[alive[i % n]].push((i, sh));
    }
    let results =
        run_on_pool(pool, assignments, n_shards, |cpu, scratch, core, _idx, sh: &Shard| {
            let mut r = sh.sub.op().run_solo(
                cpu,
                sh.input.resolve(x),
                &w[sh.w.0..sh.w.1],
                &b[sh.b.0..sh.b.1],
                inner,
                &mut ExecCtx::new(cache, scratch),
            )?;
            if let Some(plan) = &spec.faults {
                apply_layer_faults(plan, 0, lkey, core, &mut r)?;
            }
            Ok(r)
        })?;
    op.merge(results, &placements, &core_of, n, spec.opts.mode, spec.bus, spec.faults.as_ref())
}

/// Result of a batched multi-core run.
#[derive(Debug, Clone, Default)]
pub struct BatchedResult {
    pub name: String,
    /// Per-frame network results, in input order.
    pub frames: Vec<NetworkResult>,
    /// Final activation per frame (empty vectors in analytic mode).
    pub outputs: Vec<Vec<i16>>,
    /// Occupied cycles per core under the run's bus model (includes
    /// shared-bus wait cycles).
    pub core_cycles: Vec<u64>,
    /// Busy cycles per core at full private bandwidth — the useful-work
    /// view. Equals `core_cycles` under a partitioned bus.
    pub core_useful_cycles: Vec<u64>,
    /// Which core slot ran each frame (parallel to `frames`). Slots are
    /// logical: on a degraded pool slot `i` is the `i`-th *surviving*
    /// core.
    pub frame_core: Vec<usize>,
    /// External-bus model the batch was priced under.
    pub bus: BusModel,
    /// Fault/recovery account of the batch (empty when no campaign is
    /// armed and nothing failed).
    pub faults: FaultReport,
}

impl BatchedResult {
    /// Batch latency: the slowest core's occupied cycles.
    pub fn makespan_cycles(&self) -> u64 {
        self.core_cycles.iter().copied().max().unwrap_or(0)
    }

    /// What the batch would cost on one core (which owns the full bus,
    /// so this is the same under either bus model).
    pub fn serial_cycles(&self) -> u64 {
        self.frames.iter().map(|f| f.cycles()).sum()
    }

    /// Cycle-level speedup of the fan-out over a single core. Under a
    /// shared bus the makespan includes contention wait, so this
    /// degrades honestly instead of assuming partitioned bandwidth.
    pub fn speedup(&self) -> f64 {
        let mk = self.makespan_cycles();
        if mk == 0 {
            return 1.0;
        }
        self.serial_cycles() as f64 / mk as f64
    }

    /// Frames per second at the modeled clock.
    pub fn throughput_fps(&self) -> f64 {
        let mk = self.makespan_cycles();
        if mk == 0 {
            return 0.0;
        }
        self.frames.len() as f64 / (mk as f64 / crate::CLOCK_HZ as f64)
    }

    /// Per-core *useful* fraction of the makespan: private-bandwidth
    /// busy cycles over the batch makespan. Shared-bus wait cycles are
    /// not useful work, so DMA-bound shared runs report < 1.0 — never
    /// above it.
    pub fn core_utilization(&self) -> Vec<f64> {
        let mk = self.makespan_cycles().max(1) as f64;
        self.core_useful_cycles.iter().map(|&c| (c as f64 / mk).min(1.0)).collect()
    }

    /// Aggregate core activity over all frames (for the energy model).
    pub fn stats(&self) -> crate::core::CoreStats {
        let mut acc = crate::core::CoreStats::default();
        for f in &self.frames {
            acc = add_stats(&acc, &f.stats());
        }
        acc
    }
}

/// Batched fan-out on `pool`. The implementation behind
/// [`Engine::run_batched`].
pub(crate) fn run_batched_on(
    pool: &mut CorePool,
    cache: &PlanCache,
    name: &str,
    layers: &[NetLayer],
    inputs: &[Vec<i16>],
    spec: RunSpec,
) -> Result<BatchedResult, ExecError> {
    // Degrade loop: a core that exhausts its retry budget (or panics)
    // is blacklisted and the whole batch re-fans over the survivors —
    // the run completes slower instead of crashing. Deterministic
    // draws make the re-run's surviving frames bit-identical.
    let mut waste = 0u64;
    let (results, n, core_of) = loop {
        match batched_attempt(pool, cache, name, layers, inputs, spec) {
            Err(ExecError::CoreFailure { core, layer }) if pool.alive_cores() > 1 => {
                waste += degrade_waste(&spec.faults, static_layer_cost(layers, &layer));
                pool.blacklist(core);
            }
            Ok(t) => break t,
            Err(e) => return Err(e),
        }
    };

    let mut segs: Vec<Vec<Segment>> = (0..n).map(|_| Vec::new()).collect();
    let mut br = BatchedResult {
        name: name.into(),
        frame_core: core_of,
        bus: spec.bus,
        ..Default::default()
    };
    for (idx, f) in results.into_iter().enumerate() {
        for l in &f.layers {
            segs[br.frame_core[idx]].push(Segment::of_layer(l));
        }
        br.outputs.push(f.layers.last().map(|l| l.out.clone()).unwrap_or_default());
        br.frames.push(f);
    }
    let acct = core_busy(&segs, spec.bus);
    br.core_cycles = acct.busy;
    br.core_useful_cycles = acct.useful;
    // Degrade waste stalls the whole pool (the re-fan starts only after
    // the watchdog writes the core off), so every slot's occupied — but
    // not useful — cycles carry it; makespan and utilization degrade
    // honestly.
    for c in &mut br.core_cycles {
        *c += waste;
    }
    br.faults = FaultReport {
        retries: br.frames.iter().map(|f| f.fault_retries()).sum(),
        recovery_cycles: br.frames.iter().map(|f| f.fault_recovery_cycles()).sum::<u64>()
            + waste,
        blacklisted_cores: pool.blacklisted().to_vec(),
        degrade_waste_cycles: waste,
    };
    Ok(br)
}

/// One attempt of [`run_batched_on`]'s fan-out over the currently-alive
/// cores. Returns the per-frame results plus the slot count and the
/// frame → slot map.
#[allow(clippy::type_complexity)]
fn batched_attempt(
    pool: &mut CorePool,
    cache: &PlanCache,
    name: &str,
    layers: &[NetLayer],
    inputs: &[Vec<i16>],
    spec: RunSpec,
) -> Result<(Vec<NetworkResult>, usize, Vec<usize>), ExecError> {
    let alive = pool.alive_ids();
    let n = spec.opts.cores.min(alive.len()).max(1);
    let inner = ExecOptions { cores: 1, batch: 1, ..spec.opts };
    let n_frames = inputs.len();
    let core_of: Vec<usize> = (0..n_frames).map(|i| i % n).collect();
    let mut assignments: Vec<Vec<(usize, &Vec<i16>)>> =
        (0..pool.cores()).map(|_| Vec::new()).collect();
    for (i, x) in inputs.iter().enumerate() {
        assignments[alive[i % n]].push((i, x));
    }
    let results =
        run_on_pool(pool, assignments, n_frames, |cpu, scratch, core, idx, x: &&Vec<i16>| {
            let mut runner = SoloRunner {
                cpu,
                scratch,
                cache,
                opts: inner,
                faults: spec.faults,
                frame: idx as u64,
                core,
            };
            walk_network(&mut runner, name, layers, x.as_slice(), spec.seed)
        })?;
    Ok((results, n, core_of))
}

/// Static cost of the layer a [`ExecError::CoreFailure`] names, for
/// degrade-waste pricing. Falls back to the costliest layer when the
/// name is not in the net (e.g. a worker-thread panic marker).
fn static_layer_cost(layers: &[NetLayer], name: &str) -> u64 {
    layers
        .iter()
        .find(|l| l.name() == name)
        .map(|l| l.op().layer_cost())
        .unwrap_or_else(|| layers.iter().map(|l| l.op().layer_cost()).max().unwrap_or(0))
}

/// Cut `layers` into at most `want` contiguous stages minimizing the
/// bottleneck stage's predicted cost
/// ([`LayerOp::layer_cost`](super::ops::LayerOp::layer_cost) — the
/// same first-order model the `Auto` shard policy uses): half-open
/// `(l0, l1)` layer ranges. Deterministic in its inputs; O(n·len²) on
/// the handful of layers a CNN has. FC layers are heavily DMA-bound
/// (weights dominate), so the DP isolates an FC tail onto its own
/// stage(s) instead of serializing it behind the conv stack's
/// bottleneck core.
fn pipeline_stages(layers: &[NetLayer], want: usize) -> Vec<(usize, usize)> {
    let len = layers.len();
    if len == 0 {
        return Vec::new();
    }
    let n = want.max(1).min(len);
    let costs: Vec<u64> = layers.iter().map(|l| l.op().layer_cost()).collect();
    let mut pre = vec![0u64; len + 1];
    for (i, c) in costs.iter().enumerate() {
        pre[i + 1] = pre[i] + c;
    }
    // best[k][i]: minimal bottleneck splitting layers[i..] into exactly
    // k non-empty contiguous stages; cut[k][i]: where stage 1 of that
    // optimum ends. Ties break toward the earliest cut, keeping the
    // partition deterministic.
    let mut best = vec![vec![u64::MAX; len + 1]; n + 1];
    let mut cut = vec![vec![0usize; len + 1]; n + 1];
    for i in 0..=len {
        best[1][i] = pre[len] - pre[i];
        cut[1][i] = len;
    }
    for k in 2..=n {
        // stage 1 must leave at least k-1 layers for the remaining stages
        for i in 0..=(len - k) {
            for j in (i + 1)..=(len - (k - 1)) {
                let v = (pre[j] - pre[i]).max(best[k - 1][j]);
                if v < best[k][i] {
                    best[k][i] = v;
                    cut[k][i] = j;
                }
            }
        }
    }
    let mut stages = Vec::with_capacity(n);
    let (mut i, mut k) = (0usize, n);
    while k > 0 {
        let j = cut[k][i];
        stages.push((i, j));
        i = j;
        k -= 1;
    }
    stages
}

/// Partition-DP over (stage cut, core count) pairs: cut `layers` into
/// contiguous stages AND give each stage a core group, spending exactly
/// `cores` cores total, minimizing the bottleneck stage's predicted
/// steady interval under
/// [`LayerOp::layer_cost_on`](super::ops::LayerOp::layer_cost_on) (the
/// k-core view of the same ~2/3-utilization estimate the `Auto` shard
/// policy and the legacy one-core-per-stage DP consume). Returns
/// `(l0, l1, k)` triples in layer order.
///
/// The per-layer DMA floor (a shard reads its full input, so the IFMap
/// bytes do not shrink with k) is what makes heterogeneous partitions
/// win: a stage of DMA-floored layers wastes every core past its
/// bandwidth knee, so the DP parks those layers on thin groups and
/// spends the freed cores where compute still scales — e.g.
/// VGG-16-full's weight-streaming FC tail keeps 1 core while a fat
/// mid-net conv stage takes several. Exact-`cores` usage is never
/// wasteful because `layer_cost_on` is non-increasing in k.
///
/// `best[c][i]`: minimal bottleneck covering `layers[i..]` with exactly
/// `c` cores; the first stage takes `layers[i..j)` on `k` cores and
/// `choice[c][i]` records that `(k, j)`. Ties break toward the
/// smallest k, then the earliest cut — deterministic, and it prefers
/// deeper pipelines (more stages) over fatter groups when the
/// estimate cannot tell them apart. O(cores²·len²) on CNN-sized nets.
fn partition_auto(layers: &[NetLayer], cores: usize) -> Vec<(usize, usize, usize)> {
    let len = layers.len();
    if len == 0 {
        return Vec::new();
    }
    let n = cores.max(1);
    // pre[k][i]: prefix sums of layer_cost_on(k) (row 0 unused)
    let pre: Vec<Vec<u64>> = (0..=n)
        .map(|k| {
            let mut p = vec![0u64; len + 1];
            if k >= 1 {
                for (i, l) in layers.iter().enumerate() {
                    p[i + 1] = p[i] + l.op().layer_cost_on(k);
                }
            }
            p
        })
        .collect();
    let seg = |k: usize, i: usize, j: usize| pre[k][j] - pre[k][i];
    let mut best = vec![vec![u64::MAX; len + 1]; n + 1];
    let mut choice = vec![vec![(0usize, 0usize); len + 1]; n + 1];
    best[0][len] = 0;
    for c in 1..=n {
        for i in 0..len {
            for k in 1..=c {
                if c - k == 0 {
                    // last stage: must cover the remaining suffix
                    let v = seg(k, i, len);
                    if v < best[c][i] {
                        best[c][i] = v;
                        choice[c][i] = (k, len);
                    }
                } else {
                    for j in (i + 1)..len {
                        if best[c - k][j] == u64::MAX {
                            continue;
                        }
                        let v = seg(k, i, j).max(best[c - k][j]);
                        if v < best[c][i] {
                            best[c][i] = v;
                            choice[c][i] = (k, j);
                        }
                    }
                }
            }
        }
    }
    let mut out = Vec::new();
    let (mut c, mut i) = (n, 0usize);
    while i < len {
        let (k, j) = choice[c][i];
        out.push((i, j, k));
        c -= k;
        i = j;
    }
    out
}

/// Stage-cut DP for an explicit per-stage core plan (`--stage-cores
/// 1,2,1`): the stage count and each stage's core group are fixed by
/// `plan`; only the cut points are optimized, minimizing the bottleneck
/// under the same [`layer_cost_on`](super::ops::LayerOp::layer_cost_on)
/// estimate. Structurally the legacy [`pipeline_stages`] DP with
/// per-stage cost rows — for an all-ones plan the ranges, tie-breaks
/// and reconstruction are identical, so the cuts are too.
fn partition_for_plan(layers: &[NetLayer], plan: &[usize]) -> Vec<(usize, usize, usize)> {
    let len = layers.len();
    if len == 0 || plan.is_empty() {
        return Vec::new();
    }
    let ns = plan.len().min(len);
    let plan: Vec<usize> = plan[..ns].iter().map(|&k| k.max(1)).collect();
    // per-stage prefix sums of layer_cost_on(plan[s])
    let pre: Vec<Vec<u64>> = plan
        .iter()
        .map(|&k| {
            let mut p = vec![0u64; len + 1];
            for (i, l) in layers.iter().enumerate() {
                p[i + 1] = p[i] + l.op().layer_cost_on(k);
            }
            p
        })
        .collect();
    // best[s][i]: minimal bottleneck running layers[i..] on stages s..;
    // cut[s][i]: where stage s ends. Ties break toward the earliest cut.
    let mut best = vec![vec![u64::MAX; len + 1]; ns];
    let mut cut = vec![vec![0usize; len + 1]; ns];
    for i in 0..=len {
        best[ns - 1][i] = pre[ns - 1][len] - pre[ns - 1][i];
        cut[ns - 1][i] = len;
    }
    for s in (0..ns.saturating_sub(1)).rev() {
        let left = ns - s; // stages s.. still to place
        for i in 0..=(len - left) {
            for j in (i + 1)..=(len - (left - 1)) {
                let v = (pre[s][j] - pre[s][i]).max(best[s + 1][j]);
                if v < best[s][i] {
                    best[s][i] = v;
                    cut[s][i] = j;
                }
            }
        }
    }
    let mut out = Vec::with_capacity(ns);
    let mut i = 0usize;
    for (s, &k) in plan.iter().enumerate() {
        let j = cut[s][i];
        out.push((i, j, k));
        i = j;
    }
    out
}

/// Predicted bottleneck of a `(l0, l1, k)` partition under the
/// first-order estimate — what [`partition_auto`] minimizes. Used by
/// the DP monotonicity test and the bench duel.
#[cfg_attr(not(test), allow(dead_code))]
fn partition_bottleneck(layers: &[NetLayer], stages: &[(usize, usize, usize)]) -> u64 {
    stages
        .iter()
        .map(|&(l0, l1, k)| layers[l0..l1].iter().map(|l| l.op().layer_cost_on(k)).sum::<u64>())
        .max()
        .unwrap_or(0)
}

/// Resolve the config's [`StageCores`] into concrete `(l0, l1, k)`
/// stages for this run. `PerStage` reproduces the legacy
/// one-core-per-stage DP exactly (all k = 1); `Auto` runs the
/// partition-DP; `Fixed` keeps the caller's per-stage core counts and
/// optimizes only the cuts. A fixed plan longer than the layer list is
/// truncated (a stage cannot be empty); one asking for more cores than
/// the pool has is a config error, not a silent clamp.
fn resolve_stage_partition(
    layers: &[NetLayer],
    pool_cores: usize,
    spec: RunSpec,
    stage_cores: &StageCores,
) -> Result<Vec<(usize, usize, usize)>, ExecError> {
    let cores = spec.opts.cores.min(pool_cores).max(1);
    match stage_cores {
        StageCores::PerStage => {
            Ok(pipeline_stages(layers, cores).into_iter().map(|(l0, l1)| (l0, l1, 1)).collect())
        }
        StageCores::Auto => Ok(partition_auto(layers, cores)),
        StageCores::Fixed(plan) => {
            if plan.is_empty() {
                return Err(ExecError::Config("empty --stage-cores plan".into()));
            }
            let stages = partition_for_plan(layers, plan);
            let used: usize = stages.iter().map(|&(_, _, k)| k).sum();
            if used > cores {
                return Err(ExecError::Config(format!(
                    "stage-cores plan wants {used} cores but the run has {cores}"
                )));
            }
            Ok(stages)
        }
    }
}

/// Shards one layer across a pipeline stage's core GROUP: cores
/// `offset..offset+k` of the pool. The sharding, placement and merge
/// are exactly [`run_layer_sharded`]'s — the group is a k-core pool
/// starting at a core offset — so a single stage owning the whole pool
/// is bit-and-cycle-identical to the flat fan-out. After each `run`
/// the per-shard `(group slot, Segment)` pairs are left in `shards`
/// for the caller's timeline bookkeeping.
struct GroupRunner<'a> {
    pool: &'a mut CorePool,
    cache: &'a PlanCache,
    spec: RunSpec,
    /// First *logical slot* of this stage's group: slots index the
    /// pool's alive-core list, so a degraded re-partition re-maps the
    /// same slot ranges onto the survivors.
    offset: usize,
    /// Cores in the group.
    k: usize,
    /// Frame index this runner is executing (fault-site key).
    frame: u64,
    /// Per-shard (group slot, segment) of the most recent layer.
    shards: Vec<(usize, Segment)>,
}

impl LayerRunner for GroupRunner<'_> {
    fn run(
        &mut self,
        layer: &NetLayer,
        x: &[i16],
        w: &[i16],
        b: &[i32],
    ) -> Result<LayerResult, ExecError> {
        let op = layer.op();
        let (k, offset, cache) = (self.k, self.offset, self.cache);
        let spec = self.spec;
        let frame = self.frame;
        let lkey = layer_key(layer.name());
        let alive = self.pool.alive_ids();
        let inner = ExecOptions { cores: 1, batch: 1, ..spec.opts };
        let shards = op.shard(x, spec.shard, k);
        let n_shards = shards.len();
        let placements: Vec<Vec<(usize, usize)>> =
            shards.iter().map(|s| s.placement.clone()).collect();
        let core_of: Vec<usize> = (0..n_shards).map(|i| i % k).collect();
        let mut assignments: Vec<Vec<(usize, Shard)>> =
            (0..self.pool.cores()).map(|_| Vec::new()).collect();
        for (i, sh) in shards.into_iter().enumerate() {
            assignments[alive[offset + i % k]].push((i, sh));
        }
        let results = run_on_pool(
            &mut *self.pool,
            assignments,
            n_shards,
            |cpu, scratch, core, _idx, sh: &Shard| {
                let mut r = sh.sub.op().run_solo(
                    cpu,
                    sh.input.resolve(x),
                    &w[sh.w.0..sh.w.1],
                    &b[sh.b.0..sh.b.1],
                    inner,
                    &mut ExecCtx::new(cache, scratch),
                )?;
                if let Some(plan) = &spec.faults {
                    apply_layer_faults(plan, frame, lkey, core, &mut r)?;
                }
                Ok(r)
            },
        )?;
        self.shards =
            results.iter().enumerate().map(|(i, r)| (i % k, Segment::of_layer(r))).collect();
        op.merge(results, &placements, &core_of, k, spec.opts.mode, spec.bus, spec.faults.as_ref())
    }
}

/// The executed-but-unpriced half of a streaming run: every frame
/// walked through every stage (outputs final), plus the per-cell shard
/// segments bus pricing needs. Splitting execution from pricing lets
/// [`run_multi_streaming`] run several tenants first and then price
/// them all under ONE combined shared-bus divisor.
pub(crate) struct StreamExec {
    name: String,
    /// `(l0, l1, k)` stages: half-open layer range on a k-core group.
    stages: Vec<(usize, usize, usize)>,
    frames: Vec<NetworkResult>,
    outputs: Vec<Vec<i16>>,
    /// `cells[s][f][l]`: stage s, frame f, in-stage layer l — that
    /// layer's shard segments as (group slot, segment) pairs (a single
    /// `(0, seg)` for 1-core stages).
    cells: Vec<Vec<Vec<Vec<(usize, Segment)>>>>,
}

/// Execute a streaming run on `pool` without pricing it: resolve the
/// stage partition, walk every frame through every stage, and collect
/// the per-cell shard segments.
///
/// Functionally each frame is the single network walk split at the
/// stage boundaries — same weight draws, same activation threading —
/// so outputs are bit-identical to [`Engine::run_network`] for EVERY
/// partition: 1-core stages run the layer solo on the stage's core,
/// k-core stages re-enter the `run_layer_sharded` machinery via
/// [`GroupRunner`] (whose merge is bit-identical to solo by the
/// sharding invariant). The walk is stage-major: each stage draws only
/// ITS layers' tensors (stages are contiguous layer ranges, so the
/// lazy draws consume the one xorshift stream in exactly the global
/// layer order) and runs every frame through them before the next
/// stage starts — peak weight memory is one stage's tensors, not the
/// whole net's (the FC tails alone would be ~250 MB on vgg16-full).
/// Host execution is deliberately serial: the modeled cycles are
/// identical either way, and wavefront host-threading would only speed
/// up the simulation wall-clock at the cost of determinism plumbing.
pub(crate) fn stream_exec(
    pool: &mut CorePool,
    cache: &PlanCache,
    name: &str,
    layers: &[NetLayer],
    inputs: &[Vec<i16>],
    spec: RunSpec,
    stage_cores: &StageCores,
) -> Result<StreamExec, ExecError> {
    // Partition over the *surviving* cores: after a blacklist the
    // degrade loop re-enters here and the DP re-runs on the smaller
    // pool.
    let alive = pool.alive_ids();
    let stages = resolve_stage_partition(layers, alive.len(), spec, stage_cores)?;
    let n_stages = stages.len();
    let mut ex = StreamExec {
        name: name.into(),
        stages: stages.clone(),
        frames: Vec::new(),
        outputs: Vec::new(),
        cells: (0..n_stages).map(|_| Vec::with_capacity(inputs.len())).collect(),
    };
    if n_stages == 0 || inputs.is_empty() {
        return Ok(ex);
    }
    let inner = ExecOptions { cores: 1, batch: 1, ..spec.opts };

    let mut rng = crate::util::XorShift::new(spec.seed);
    let mut acts: Vec<Vec<i16>> = inputs.to_vec();
    let mut nets: Vec<NetworkResult> = (0..inputs.len())
        .map(|_| NetworkResult { name: name.into(), ..Default::default() })
        .collect();
    let mut offset = 0usize; // first logical slot of the current group
    for (s, &(l0, l1, k)) in stages.iter().enumerate() {
        let tensors: Vec<Option<(Vec<i16>, Vec<i32>)>> =
            layers[l0..l1].iter().map(|l| l.op().draw(&mut rng)).collect();
        for (f, act) in acts.iter_mut().enumerate() {
            let mut layer_cells = Vec::with_capacity(l1 - l0);
            for (t, li) in (l0..l1).enumerate() {
                if k == 1 {
                    let core = alive[offset];
                    let (cpu, scratch) = pool.core(core);
                    let mut runner = SoloRunner {
                        cpu,
                        scratch,
                        cache,
                        opts: inner,
                        faults: spec.faults,
                        frame: f as u64,
                        core,
                    };
                    let r = step_layer(&mut runner, &layers[li], &tensors[t], act)?;
                    layer_cells.push(vec![(0usize, Segment::of_layer(&r))]);
                    nets[f].layers.push(r);
                } else {
                    let mut runner = GroupRunner {
                        pool: &mut *pool,
                        cache,
                        spec,
                        offset,
                        k,
                        frame: f as u64,
                        shards: Vec::new(),
                    };
                    let r = step_layer(&mut runner, &layers[li], &tensors[t], act)?;
                    layer_cells.push(std::mem::take(&mut runner.shards));
                    nets[f].layers.push(r);
                }
            }
            ex.cells[s].push(layer_cells);
        }
        offset += k;
    }
    for net in nets {
        ex.outputs.push(net.layers.last().map(|l| l.out.clone()).unwrap_or_default());
        ex.frames.push(net);
    }

    // FC weight residency (LayerOp::resident_param_stream): a stage's
    // repeating schedule keeps parameter tiles resident in DM across
    // frames when they fit, so frames after the first drop those
    // transfers — payload bytes AND the elided descriptors' DRAM
    // latency — from their steady-state DMA. The fill pass (f == 0)
    // keeps the full stream (the tiles must arrive once); the gated-
    // I/O halving mirrors the executor's packed-transfer accounting.
    // Residency is only credited when the layer OWNS its stage on ONE
    // core: every layer's DM map packs from the same base addresses,
    // so a co-staged layer would overwrite the resident tiles each
    // frame, and a sharded layer re-slices its parameter tiles per
    // shard — the conservative model keeps multi-core groups streaming.
    let n_frames = inputs.len();
    for (s, &(l0, l1, k)) in stages.iter().enumerate() {
        if l1 - l0 != 1 || k != 1 {
            continue;
        }
        let (mut bytes, reqs) = layers[l0].op().resident_param_stream();
        if spec.opts.gate_bits <= 8 {
            bytes /= 2;
        }
        if bytes == 0 {
            continue;
        }
        let lat = reqs * crate::mem::EXT_LATENCY_CYCLES;
        for f in 1..n_frames {
            let seg = &mut ex.cells[s][f][0][0].1;
            seg.bytes = seg.bytes.saturating_sub(bytes);
            seg.lat = seg.lat.saturating_sub(lat);
        }
    }
    Ok(ex)
}

/// Flatten an executed stream into per-core aggregate DMA timelines for
/// the shared-bus fixed point: one timeline per (stage, group slot),
/// each the flat list of that core's segments across frames and layers.
/// For all-1-core partitions this is exactly the legacy per-stage
/// flattening; fatter groups contribute one timeline per member core,
/// so a 3-core conv group presses on the bus three times (each shard
/// re-reads its full input) — the divisor sees through the group
/// hierarchy to physical cores.
pub(crate) fn core_timelines(ex: &StreamExec) -> Vec<Vec<Segment>> {
    let mut out = Vec::new();
    for (s, &(_, _, k)) in ex.stages.iter().enumerate() {
        for c in 0..k {
            out.push(
                ex.cells
                    .get(s)
                    .into_iter()
                    .flatten()
                    .flatten()
                    .flatten()
                    .filter(|(slot, _)| *slot == c)
                    .map(|&(_, seg)| seg)
                    .collect(),
            );
        }
    }
    out
}

/// Price an executed stream under bus divisor `d`: per-(stage, frame)
/// times via the k-core group views of the stage schedule
/// (`bus::group_first_pass` for the fill frame, `bus::group_interval`
/// for the repeating schedule — both degenerate to the 1-core
/// `stage_first_pass`/`stage_interval` at k = 1), then the classic
/// flow-shop recurrence: a stage starts a frame when both the frame
/// has left the previous stage and the stage has finished the previous
/// frame. The steady-state interval is read off each stage's LAST
/// frame (weight residency makes frame 0 heavier, never lighter).
pub(crate) fn price_stream(ex: StreamExec, bus: BusModel, d: u64) -> PipelineResult {
    let StreamExec { name, stages, frames, outputs, cells } = ex;
    let n_stages = stages.len();
    let mut res = PipelineResult {
        name,
        stages: stages.iter().map(|&(l0, l1, _)| (l0, l1)).collect(),
        stage_cores: stages.iter().map(|&(_, _, k)| k).collect(),
        bus,
        frames,
        outputs,
        ..Default::default()
    };
    let n_frames = res.frames.len();
    if n_stages == 0 || n_frames == 0 {
        res.stage_cycles = vec![0; n_stages];
        res.stage_useful_cycles = vec![0; n_stages];
        return res;
    }

    let priced = |layer_cells: &[Vec<(usize, Segment)>], k: usize, f: usize, div: u64| {
        if f == 0 {
            group_first_pass(layer_cells, k, div)
        } else {
            group_interval(layer_cells, k, div)
        }
    };
    let t: Vec<Vec<u64>> = cells
        .iter()
        .zip(&stages)
        .map(|(fs, &(_, _, k))| {
            fs.iter().enumerate().map(|(f, lc)| priced(lc, k, f, d)).collect()
        })
        .collect();
    res.stage_cycles = t.iter().map(|row| row.iter().sum()).collect();
    res.stage_useful_cycles = cells
        .iter()
        .zip(&stages)
        .map(|(fs, &(_, _, k))| fs.iter().enumerate().map(|(f, lc)| priced(lc, k, f, 1)).sum())
        .collect();
    res.steady_interval_cycles = cells
        .iter()
        .zip(&stages)
        .filter_map(|(fs, &(_, _, k))| fs.last().map(|lc| group_interval(lc, k, d)))
        .max()
        .unwrap_or(0);

    let mut finish = vec![vec![0u64; n_frames]; n_stages];
    let mut last_frame_entry = 0u64;
    for f in 0..n_frames {
        for s in 0..n_stages {
            let prev_stage = if s == 0 { 0 } else { finish[s - 1][f] };
            let prev_frame = if f == 0 { 0 } else { finish[s][f - 1] };
            let start = prev_stage.max(prev_frame);
            if s == 0 && f + 1 == n_frames {
                last_frame_entry = start;
            }
            finish[s][f] = start + t[s][f];
        }
    }
    res.fill_cycles = finish[n_stages - 1][0];
    res.makespan_cycles = finish[n_stages - 1][n_frames - 1];
    res.drain_cycles = res.makespan_cycles - last_frame_entry;
    res
}

/// Layer-pipelined streaming on `pool`. Shared by
/// [`Engine::run_streaming`]; see [`PipelineResult`] for what comes
/// back. Execution ([`stream_exec`]) and pricing ([`price_stream`])
/// are split so multi-tenant runs can price several executed streams
/// under one combined divisor; here the divisor is this run's own
/// fixed point over its per-core timelines (stage groups feed their
/// member cores' aggregate DMA into the hierarchy), or 1 on a
/// partitioned bus. Stage-boundary activations cross the external bus
/// inside the per-layer DMA accounting (producer OFMap write +
/// consumer IFMap read).
pub(crate) fn run_streaming_on(
    pool: &mut CorePool,
    cache: &PlanCache,
    name: &str,
    layers: &[NetLayer],
    inputs: &[Vec<i16>],
    spec: RunSpec,
    stage_cores: &StageCores,
) -> Result<PipelineResult, ExecError> {
    let (ex, waste) = stream_exec_degrading(pool, cache, name, layers, inputs, spec, stage_cores)?;
    let d = match spec.bus {
        BusModel::Partitioned => 1,
        BusModel::Shared => shared_divisor(&core_timelines(&ex)),
    };
    let mut res = price_stream(ex, spec.bus, d);
    res.faults = stream_fault_report(&res.frames, pool, waste);
    res.makespan_cycles += waste;
    Ok(res)
}

/// Execute a stream with the fault degrade loop: on
/// [`ExecError::CoreFailure`] the exhausted core is blacklisted, its
/// watchdog-bounded waste is charged, and the whole stream re-runs over
/// the survivors (the partition-DP re-cuts for the smaller pool; the
/// deterministic draws keep the re-run's outputs bit-identical). Fails
/// only when a lone surviving core fails.
fn stream_exec_degrading(
    pool: &mut CorePool,
    cache: &PlanCache,
    name: &str,
    layers: &[NetLayer],
    inputs: &[Vec<i16>],
    spec: RunSpec,
    stage_cores: &StageCores,
) -> Result<(StreamExec, u64), ExecError> {
    let mut waste = 0u64;
    loop {
        match stream_exec(pool, cache, name, layers, inputs, spec, stage_cores) {
            Err(ExecError::CoreFailure { core, layer }) if pool.alive_cores() > 1 => {
                waste += degrade_waste(&spec.faults, static_layer_cost(layers, &layer));
                pool.blacklist(core);
            }
            Ok(ex) => return Ok((ex, waste)),
            Err(e) => return Err(e),
        }
    }
}

/// Fault/recovery account of a priced stream: per-layer retry sums plus
/// the degrade waste, and the pool's bench list as the topology report.
fn stream_fault_report(frames: &[NetworkResult], pool: &CorePool, waste: u64) -> FaultReport {
    FaultReport {
        retries: frames.iter().map(|f| f.fault_retries()).sum(),
        recovery_cycles: frames.iter().map(|f| f.fault_recovery_cycles()).sum::<u64>() + waste,
        blacklisted_cores: pool.blacklisted().to_vec(),
        degrade_waste_cycles: waste,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::executor::{conv_layer, pool_layer};
    use crate::util::XorShift;

    /// Single-core reference run with a private (fresh) cache/scratch.
    fn solo_conv(cpu: &mut Cpu, l: &ConvLayer, x: &[i16], w: &[i16], b: &[i32]) -> LayerResult {
        let cache = PlanCache::new();
        let mut scratch = Scratch::default();
        conv_layer(cpu, l, x, w, b, ExecOptions::default(), &mut ExecCtx::new(&cache, &mut scratch))
            .unwrap()
    }

    fn solo_pool(cpu: &mut Cpu, l: &PoolLayer, x: &[i16]) -> LayerResult {
        let cache = PlanCache::new();
        let mut scratch = Scratch::default();
        pool_layer(cpu, l, x, ExecOptions::default(), &mut ExecCtx::new(&cache, &mut scratch))
            .unwrap()
    }

    fn tensors(l: &ConvLayer, seed: u64) -> (Vec<i16>, Vec<i16>, Vec<i32>) {
        let mut rng = XorShift::new(seed);
        (
            rng.i16_vec(l.ic * l.ih * l.iw, -2000, 2000),
            rng.i16_vec(l.oc * (l.ic / l.groups) * l.fh * l.fw, -256, 256),
            rng.i32_vec(l.oc, -1000, 1000),
        )
    }

    #[test]
    fn sharded_conv_matches_single_core_bitexact() {
        let l = ConvLayer::new("mc", 8, 16, 16, 64, 3, 3, 1, 1, 1);
        let (x, w, b) = tensors(&l, 3);
        let mut solo = Cpu::new(1 << 22);
        let base = solo_conv(&mut solo, &l, &x, &w, &b);
        for policy in [ShardPolicy::OcTile, ShardPolicy::RowBand, ShardPolicy::Auto] {
            for cores in [2usize, 4] {
                let mut engine =
                    EngineConfig::new().cores(cores).shard(policy).ext_capacity(1 << 22).build();
                let r = engine.run_conv_layer(&l, &x, &w, &b).unwrap();
                assert_eq!(r.out, base.out, "{policy:?} {cores}-core output");
                assert_eq!(r.macs, base.macs, "{policy:?} {cores}-core macs");
                assert_eq!(r.core_cycles.len(), cores);
                assert!(r.cycles > 0);
                assert!(
                    r.parallel_speedup() > 1.5,
                    "{policy:?} {cores}-core speedup {}",
                    r.parallel_speedup()
                );
            }
        }
    }

    #[test]
    fn sharded_grouped_conv_matches() {
        let l = ConvLayer::new("mcg", 8, 13, 13, 32, 3, 3, 1, 1, 2);
        let (x, w, b) = tensors(&l, 5);
        let mut solo = Cpu::new(1 << 22);
        let base = solo_conv(&mut solo, &l, &x, &w, &b);
        for policy in [ShardPolicy::OcTile, ShardPolicy::RowBand] {
            let mut engine =
                EngineConfig::new().cores(4).shard(policy).ext_capacity(1 << 22).build();
            let r = engine.run_conv_layer(&l, &x, &w, &b).unwrap();
            assert_eq!(r.out, base.out, "{policy:?}");
            assert_eq!(r.macs, base.macs, "{policy:?}");
        }
    }

    #[test]
    fn sharded_pool_layer_matches() {
        let l = PoolLayer { name: "mcp", ic: 48, ih: 13, iw: 13, size: 3, stride: 2 };
        let mut rng = XorShift::new(9);
        let x = rng.i16_vec(l.ic * l.ih * l.iw, -30000, 30000);
        let mut solo = Cpu::new(1 << 22);
        let base = solo_pool(&mut solo, &l, &x);
        for policy in [ShardPolicy::OcTile, ShardPolicy::RowBand, ShardPolicy::Auto] {
            let mut engine =
                EngineConfig::new().cores(3).shard(policy).ext_capacity(1 << 22).build();
            let r = engine.run_pool_layer(&l, &x).unwrap();
            assert_eq!(r.out, base.out, "{policy:?}");
        }
    }

    #[test]
    fn sharded_fc_matches_single_core_bitexact() {
        // neuron-tiled FC shards are a pure reshuffling of the solo
        // matvec — outputs, MACs and the host reference all agree
        let fc = FcLayer::new("fcx", 128, 96);
        let mut rng = XorShift::new(17);
        let x = rng.i16_vec(fc.in_features, -2000, 2000);
        let w = rng.i16_vec(fc.in_features * fc.out_features, -256, 256);
        let b = rng.i32_vec(fc.out_features, -1000, 1000);
        let mut solo = EngineConfig::new().ext_capacity(1 << 22).build();
        let base = solo.run_fc_layer(&fc, &x, &w, &b).unwrap();
        assert_eq!(base.macs, fc.macs());
        let expect = crate::codegen::reffc::fc_forward(
            &x,
            &w,
            &b,
            &fc,
            crate::fixed::RoundMode::HalfUp,
            16,
        );
        assert_eq!(base.out, expect, "solo FC vs host reference");
        for policy in [ShardPolicy::OcTile, ShardPolicy::RowBand, ShardPolicy::Auto] {
            for cores in [2usize, 4] {
                let mut engine =
                    EngineConfig::new().cores(cores).shard(policy).ext_capacity(1 << 22).build();
                let r = engine.run_fc_layer(&fc, &x, &w, &b).unwrap();
                assert_eq!(r.out, base.out, "{policy:?} {cores}-core FC output");
                assert_eq!(r.macs, base.macs, "{policy:?} {cores}-core FC macs");
                assert_eq!(r.core_cycles.len(), cores);
            }
        }
    }

    #[test]
    fn shared_bus_never_beats_partitioned() {
        // a DMA-heavy layer: tiny ic, large spatial output
        let l = ConvLayer::new("dma", 2, 48, 48, 32, 3, 3, 1, 1, 1);
        let (x, w, b) = tensors(&l, 7);
        let run = |bus: BusModel| {
            let mut engine = EngineConfig::new()
                .cores(4)
                .bus(bus)
                .mode(ExecMode::TileAnalytic)
                .ext_capacity(1 << 22)
                .build();
            engine.run_conv_layer(&l, &x, &w, &b).unwrap()
        };
        let part = run(BusModel::Partitioned);
        let shared = run(BusModel::Shared);
        assert!(
            shared.cycles >= part.cycles,
            "shared {} < partitioned {}",
            shared.cycles,
            part.cycles
        );
        // contention never changes what was computed
        assert_eq!(shared.macs, part.macs);
        assert_eq!(shared.io_in, part.io_in);
        assert_eq!(shared.io_out, part.io_out);
    }

    #[test]
    fn batched_frames_match_serial_runs() {
        let layers = vec![
            NetLayer::Conv(ConvLayer::new("c1", 4, 12, 12, 16, 3, 3, 1, 1, 1)),
            NetLayer::Pool(PoolLayer { name: "p1", ic: 16, ih: 12, iw: 12, size: 2, stride: 2 }),
            NetLayer::Conv(ConvLayer::new("c2", 16, 6, 6, 16, 3, 3, 1, 1, 1)),
        ];
        let mut rng = XorShift::new(11);
        let inputs: Vec<Vec<i16>> =
            (0..3).map(|_| rng.i16_vec(4 * 12 * 12, -1000, 1000)).collect();
        let mut engine =
            EngineConfig::new().cores(2).batch(3).seed(42).ext_capacity(1 << 22).build();
        let br = engine.run_batched("mini", &layers, &inputs).unwrap();
        assert_eq!(br.frames.len(), 3);
        assert_eq!(br.outputs.len(), 3);
        assert_eq!(br.frame_core, vec![0, 1, 0], "round-robin frame placement");
        // every frame must equal its standalone single-core run
        for (i, input) in inputs.iter().enumerate() {
            let mut solo = EngineConfig::new().seed(42).ext_capacity(1 << 22).build();
            let f = solo.run_network("mini", &layers, input).unwrap();
            assert_eq!(br.outputs[i], f.layers.last().unwrap().out, "frame {i}");
            assert_eq!(br.frames[i].macs(), f.macs(), "frame {i} macs");
        }
        assert!(br.speedup() > 1.0, "two cores must beat one on 3 frames");
        // partitioned bus: occupied == useful
        assert_eq!(br.core_cycles, br.core_useful_cycles);
        for u in br.core_utilization() {
            assert!(u <= 1.0, "util {u}");
        }
    }

    #[test]
    fn shared_bus_batched_reports_sane_utilization() {
        let layers = vec![
            NetLayer::Conv(ConvLayer::new("c1", 2, 24, 24, 16, 3, 3, 1, 1, 1)),
            NetLayer::Conv(ConvLayer::new("c2", 16, 24, 24, 16, 3, 3, 1, 1, 1)),
        ];
        let inputs: Vec<Vec<i16>> = (0..4).map(|_| vec![0i16; 2 * 24 * 24]).collect();
        let run = |bus: BusModel| {
            let mut engine = EngineConfig::new()
                .cores(4)
                .batch(4)
                .bus(bus)
                .mode(ExecMode::TileAnalytic)
                .ext_capacity(1 << 22)
                .build();
            engine.run_batched("duo", &layers, &inputs).unwrap()
        };
        let part = run(BusModel::Partitioned);
        let shared = run(BusModel::Shared);
        assert!(shared.makespan_cycles() >= part.makespan_cycles());
        assert!(shared.speedup() <= part.speedup() + 1e-9);
        for u in shared.core_utilization() {
            assert!(u <= 1.0, "shared-bus per-core utilization {u} > 1");
        }
        // useful work is bus-independent
        assert_eq!(shared.core_useful_cycles, part.core_useful_cycles);
    }

    #[test]
    fn pipeline_stages_partition_and_balance() {
        let layers = vec![
            NetLayer::Conv(ConvLayer::new("c1", 4, 24, 24, 16, 3, 3, 1, 1, 1)),
            NetLayer::Pool(PoolLayer { name: "p1", ic: 16, ih: 24, iw: 24, size: 2, stride: 2 }),
            NetLayer::Conv(ConvLayer::new("c2", 16, 12, 12, 32, 3, 3, 1, 1, 1)),
            NetLayer::Conv(ConvLayer::new("c3", 32, 12, 12, 32, 3, 3, 1, 1, 1)),
            NetLayer::Conv(ConvLayer::new("c4", 32, 12, 12, 48, 3, 3, 1, 1, 1)),
        ];
        for want in 1..=6usize {
            let stages = pipeline_stages(&layers, want);
            assert_eq!(stages.len(), want.min(layers.len()), "want {want}");
            // contiguous, non-empty, covering every layer exactly once
            let mut next = 0usize;
            for &(l0, l1) in &stages {
                assert_eq!(l0, next, "want {want}: stages must be contiguous");
                assert!(l1 > l0, "want {want}: empty stage");
                next = l1;
            }
            assert_eq!(next, layers.len(), "want {want}: uncovered tail");
        }
        // the DP must beat (or match) the naive equal-count split on a
        // skewed cost profile: one heavy layer, several light ones
        let costs: Vec<u64> = layers.iter().map(|l| l.op().layer_cost()).collect();
        let stages = pipeline_stages(&layers, 2);
        let bottleneck = |cuts: &[(usize, usize)]| {
            cuts.iter().map(|&(a, b)| costs[a..b].iter().sum::<u64>()).max().unwrap()
        };
        assert!(bottleneck(&stages) <= bottleneck(&[(0, 3), (3, 5)]));
        assert!(bottleneck(&stages) <= bottleneck(&[(0, 2), (2, 5)]));
        // degenerate inputs
        assert!(pipeline_stages(&[], 4).is_empty());
    }

    #[test]
    fn stage_dp_isolates_a_dma_bound_fc_tail() {
        // a weight-heavy FC dwarfs the tiny convs in predicted cost
        // (its weights stream once per frame), so the 2-stage cut must
        // put the FC tail alone on its own core rather than serialize
        // it behind a conv stage
        let layers = vec![
            NetLayer::Conv(ConvLayer::new("c1", 4, 8, 8, 8, 3, 3, 1, 1, 1)),
            NetLayer::Conv(ConvLayer::new("c2", 8, 8, 8, 8, 3, 3, 1, 1, 1)),
            NetLayer::Fc(FcLayer::new("fc", 4096, 4096)),
        ];
        let fc_cost = layers[2].op().layer_cost();
        let conv_cost: u64 = layers[..2].iter().map(|l| l.op().layer_cost()).sum();
        assert!(fc_cost > 10 * conv_cost, "fc must dominate: {fc_cost} vs {conv_cost}");
        assert_eq!(pipeline_stages(&layers, 2), vec![(0, 2), (2, 3)]);
    }

    #[test]
    fn streaming_matches_single_core_frames_bitexact() {
        let layers = vec![
            NetLayer::Conv(ConvLayer::new("c1", 4, 12, 12, 16, 3, 3, 1, 1, 1)),
            NetLayer::Pool(PoolLayer { name: "p1", ic: 16, ih: 12, iw: 12, size: 2, stride: 2 }),
            NetLayer::Conv(ConvLayer::new("c2", 16, 6, 6, 16, 3, 3, 1, 1, 1)),
        ];
        let mut rng = XorShift::new(31);
        let inputs: Vec<Vec<i16>> =
            (0..3).map(|_| rng.i16_vec(4 * 12 * 12, -1000, 1000)).collect();
        let mut engine = EngineConfig::new()
            .cores(2)
            .pool_mode(PoolMode::Pipelined)
            .seed(42)
            .ext_capacity(1 << 22)
            .build();
        let pr = engine.run_streaming("mini", &layers, &inputs).unwrap();
        assert_eq!(pr.stages.len(), 2);
        assert_eq!(pr.frames.len(), 3);
        for (i, input) in inputs.iter().enumerate() {
            let mut solo = EngineConfig::new().seed(42).ext_capacity(1 << 22).build();
            let f = solo.run_network("mini", &layers, input).unwrap();
            assert_eq!(pr.outputs[i], f.layers.last().unwrap().out, "frame {i}");
            for (lp, ls) in pr.frames[i].layers.iter().zip(&f.layers) {
                assert_eq!(lp.out, ls.out, "frame {i} layer {} output", ls.name);
                assert_eq!(lp.macs, ls.macs, "frame {i} layer {} macs", ls.name);
            }
            if i == 0 {
                // the first frame has no pipeline overlap to exploit:
                // on a partitioned bus its fill latency is exactly the
                // single-core frame latency, split across the stages
                assert_eq!(pr.fill_cycles, f.cycles(), "fill != single-core frame latency");
            }
        }
        // schedule sanity: the pipe fills, streams at the bottleneck
        // interval, and drains
        assert!(pr.steady_interval_cycles > 0);
        assert!(pr.fill_cycles >= pr.steady_interval_cycles);
        assert!(pr.makespan_cycles >= pr.fill_cycles);
        assert!(pr.drain_cycles <= pr.makespan_cycles);
        // every stage runs its frames serially, so no stage can be busy
        // for longer than the whole stream
        let busiest = pr.stage_cycles.iter().copied().max().unwrap();
        assert!(pr.makespan_cycles >= busiest, "makespan below the busiest stage");
        // partitioned bus: occupied == useful; the occupied-vs-useful
        // split is checked in raw cycles (stage_utilization clamps, so
        // a ratio assert could never fail)
        assert_eq!(pr.stage_cycles, pr.stage_useful_cycles);
        for &u in &pr.stage_useful_cycles {
            assert!(u <= pr.makespan_cycles, "useful {u} exceeds makespan");
        }
    }

    #[test]
    fn streaming_shared_bus_only_adds_wait() {
        let layers = vec![
            NetLayer::Conv(ConvLayer::new("c1", 2, 24, 24, 16, 3, 3, 1, 1, 1)),
            NetLayer::Conv(ConvLayer::new("c2", 16, 24, 24, 16, 3, 3, 1, 1, 1)),
        ];
        let inputs: Vec<Vec<i16>> = (0..4).map(|_| vec![0i16; 2 * 24 * 24]).collect();
        let run = |bus: BusModel| {
            let mut engine = EngineConfig::new()
                .cores(2)
                .pool_mode(PoolMode::Pipelined)
                .bus(bus)
                .mode(ExecMode::TileAnalytic)
                .ext_capacity(1 << 22)
                .build();
            engine.run_streaming("duo", &layers, &inputs).unwrap()
        };
        let part = run(BusModel::Partitioned);
        let shared = run(BusModel::Shared);
        assert!(shared.makespan_cycles >= part.makespan_cycles);
        assert!(shared.steady_interval_cycles >= part.steady_interval_cycles);
        // useful work is bus-independent; contention never changes MACs
        assert_eq!(shared.stage_useful_cycles, part.stage_useful_cycles);
        for (fs, fp) in shared.frames.iter().zip(&part.frames) {
            assert_eq!(fs.macs(), fp.macs());
        }
        // raw-cycle check (stage_utilization clamps to 1.0, so a ratio
        // assert would be vacuous): useful work fits inside both the
        // occupied view and the makespan
        for (s, &u) in shared.stage_useful_cycles.iter().enumerate() {
            assert!(u <= shared.stage_cycles[s], "stage {s}: useful above occupied");
            assert!(u <= shared.makespan_cycles, "stage {s}: useful above makespan");
        }
    }

    #[test]
    fn fc_weight_residency_lifts_steady_state_only() {
        // One pipeline stage holding one DM-resident FC head: frame 0
        // (the fill pass) pays the full weight stream; every later
        // frame keeps the tiles resident and runs strictly faster. A
        // conv stage has no resident parameters, so its steady frames
        // price exactly like its first.
        use crate::coordinator::ops::LayerOp;
        let fc = FcLayer::new("head", 256, 10);
        assert!(LayerOp::resident_param_stream(&fc).0 > 0, "head must be DM-resident");
        let fc_net = vec![NetLayer::Fc(fc.clone())];
        let inputs: Vec<Vec<i16>> = (0..2).map(|_| vec![5i16; 256]).collect();
        let cfg = || {
            EngineConfig::new()
                .mode(ExecMode::TileAnalytic)
                .seed(4)
                .ext_capacity(1 << 22)
        };
        let pr = cfg().pool_mode(PoolMode::Pipelined).build()
            .run_streaming("head", &fc_net, &inputs)
            .unwrap();
        assert_eq!(pr.stages.len(), 1);
        // fill-pass timing is unchanged by residency: it equals the
        // single-core frame latency exactly (partitioned bus)
        let solo = cfg().build().run_network("head", &fc_net, &inputs[0]).unwrap();
        assert_eq!(pr.fill_cycles, solo.cycles(), "residency must not touch the fill pass");
        // steady frame strictly cheaper than the fill frame
        let steady = pr.makespan_cycles - pr.fill_cycles;
        assert!(
            steady < pr.fill_cycles,
            "resident FC steady frame {steady} must beat fill {}",
            pr.fill_cycles
        );
        assert_eq!(pr.steady_interval_cycles, steady, "steady interval reads the warm frame");

        // contrast: a conv stage has no resident parameters, so its
        // steady frames can only beat the fill frame through pipeline
        // prefetch — the rotated plan's first-iteration fill hides
        // under the previous frame's tail compute in steady state —
        // never through residency elision (its byte stream repeats in
        // full every frame)
        let conv_net = vec![NetLayer::Conv(ConvLayer::new("c", 4, 12, 12, 16, 3, 3, 1, 1, 1))];
        let conv_inputs: Vec<Vec<i16>> = (0..2).map(|_| vec![0i16; 4 * 12 * 12]).collect();
        let cr = cfg().pool_mode(PoolMode::Pipelined).build()
            .run_streaming("conv", &conv_net, &conv_inputs)
            .unwrap();
        let conv_steady = cr.makespan_cycles - cr.fill_cycles;
        assert!(
            conv_steady <= cr.fill_cycles,
            "a non-resident stage's steady frame {conv_steady} cannot exceed its fill frame {}",
            cr.fill_cycles
        );
        assert_eq!(cr.steady_interval_cycles, conv_steady, "interval reads the warm frame");

        // and a stage the FC does NOT own alone gets no residency: the
        // conv's per-frame staging would overwrite the tiles in DM, so
        // the steady interval must equal the full-stream overlap value
        // reconstructable from the solo per-layer results (512 input
        // features — small enough that the weight tiles fit beside the
        // rotated working map, so residency WOULD apply on a solo stage)
        let shared_fc = FcLayer { in_features: 8 * 8 * 8, ..fc.clone() };
        assert!(
            LayerOp::resident_param_stream(&shared_fc).0 > 0,
            "the shared-stage FC must be resident-sized for this test to bite"
        );
        let shared_net = vec![
            NetLayer::Conv(ConvLayer::new("c", 4, 8, 8, 8, 3, 3, 1, 1, 1)),
            NetLayer::Fc(shared_fc),
        ];
        let shared_inputs: Vec<Vec<i16>> = (0..2).map(|_| vec![3i16; 4 * 8 * 8]).collect();
        let sr = cfg().pool_mode(PoolMode::Pipelined).build()
            .run_streaming("shared", &shared_net, &shared_inputs)
            .unwrap();
        assert_eq!(sr.stages.len(), 1, "one core => conv and fc share the stage");
        let solo2 = cfg().build().run_network("shared", &shared_net, &shared_inputs[0]).unwrap();
        let (compute, dma): (u64, u64) = solo2
            .layers
            .iter()
            .map(|r| (r.compute_cycles, r.dma_cycles))
            .fold((0, 0), |(c, d), (lc, ld)| (c + lc, d + ld));
        assert_eq!(
            sr.steady_interval_cycles,
            compute.max(dma),
            "a shared stage must keep the FULL weight stream in its steady interval"
        );
    }

    /// The first partition-DP endpoint: an all-1-core plan must be
    /// bit-AND-cycle-identical to the legacy one-core-per-stage
    /// pipeline — outputs, cuts, and every timing field — at several
    /// core counts under both bus models.
    #[test]
    fn all_ones_partition_reproduces_legacy_pipeline() {
        let layers = vec![
            NetLayer::Conv(ConvLayer::new("c1", 4, 24, 24, 16, 3, 3, 1, 1, 1)),
            NetLayer::Pool(PoolLayer { name: "p1", ic: 16, ih: 24, iw: 24, size: 2, stride: 2 }),
            NetLayer::Conv(ConvLayer::new("c2", 16, 12, 12, 32, 3, 3, 1, 1, 1)),
            NetLayer::Conv(ConvLayer::new("c3", 32, 12, 12, 32, 3, 3, 1, 1, 1)),
            NetLayer::Fc(FcLayer::new("fc", 32 * 12 * 12, 64)),
        ];
        let mut rng = XorShift::new(9);
        let inputs: Vec<Vec<i16>> =
            (0..3).map(|_| rng.i16_vec(4 * 24 * 24, -800, 800)).collect();
        for cores in [2usize, 3, 4] {
            for bus in [BusModel::Partitioned, BusModel::Shared] {
                let cfg = || {
                    EngineConfig::new()
                        .cores(cores)
                        .pool_mode(PoolMode::Pipelined)
                        .bus(bus)
                        .seed(17)
                        .ext_capacity(1 << 22)
                };
                let legacy =
                    cfg().build().run_streaming("ones", &layers, &inputs).unwrap();
                let ones = cfg()
                    .stage_cores(StageCores::Fixed(vec![1; cores]))
                    .build()
                    .run_streaming("ones", &layers, &inputs)
                    .unwrap();
                let tag = format!("{cores} cores {bus:?}");
                assert_eq!(ones.stages, legacy.stages, "{tag}: cuts");
                assert_eq!(ones.stage_cores, legacy.stage_cores, "{tag}: groups");
                assert!(legacy.stage_cores.iter().all(|&k| k == 1), "{tag}: legacy k");
                assert_eq!(ones.outputs, legacy.outputs, "{tag}: outputs");
                assert_eq!(ones.fill_cycles, legacy.fill_cycles, "{tag}: fill");
                assert_eq!(
                    ones.steady_interval_cycles, legacy.steady_interval_cycles,
                    "{tag}: steady"
                );
                assert_eq!(ones.drain_cycles, legacy.drain_cycles, "{tag}: drain");
                assert_eq!(ones.makespan_cycles, legacy.makespan_cycles, "{tag}: makespan");
                assert_eq!(ones.stage_cycles, legacy.stage_cycles, "{tag}: stage cycles");
                assert_eq!(
                    ones.stage_useful_cycles, legacy.stage_useful_cycles,
                    "{tag}: useful"
                );
            }
        }
    }

    /// The other endpoint: a single stage owning the whole pool IS the
    /// `run_layer_sharded` fan-out — same outputs and (for one frame,
    /// where the streaming divisor sees exactly the merge's segments)
    /// the same priced makespan, under both bus models.
    #[test]
    fn single_stage_all_cores_matches_fanout() {
        let l = ConvLayer::new("solo", 8, 16, 16, 32, 3, 3, 1, 1, 1);
        let layers = vec![NetLayer::Conv(l.clone())];
        let mut rng = XorShift::new(11);
        let input = rng.i16_vec(8 * 16 * 16, -900, 900);
        for bus in [BusModel::Partitioned, BusModel::Shared] {
            let cfg = || {
                EngineConfig::new()
                    .cores(4)
                    .shard(ShardPolicy::OcTile)
                    .bus(bus)
                    .seed(23)
                    .ext_capacity(1 << 22)
            };
            let pr = cfg()
                .pool_mode(PoolMode::Pipelined)
                .stage_cores(StageCores::Fixed(vec![4]))
                .build()
                .run_streaming("solo", &layers, std::slice::from_ref(&input))
                .unwrap();
            assert_eq!(pr.stages, vec![(0, 1)], "{bus:?}: one stage");
            assert_eq!(pr.stage_cores, vec![4], "{bus:?}: all cores");
            // the fan-out reference, fed the same drawn tensors
            let (w, b) = layers[0].op().draw(&mut XorShift::new(23)).unwrap();
            let r = cfg().build().run_layer(&layers[0], &input, &w, &b).unwrap();
            assert_eq!(pr.outputs[0], r.out, "{bus:?}: outputs");
            assert_eq!(pr.fill_cycles, r.cycles, "{bus:?}: makespan");
            assert_eq!(pr.makespan_cycles, r.cycles, "{bus:?}: one-frame stream");
        }
    }

    /// The partition-DP consumes the same first-order estimate as the
    /// Auto shard policy; its optimum must be monotone in the core
    /// budget, and handing the bottleneck stage one more core can never
    /// raise the predicted makespan (layer_cost_on is non-increasing
    /// in k).
    #[test]
    fn partition_dp_monotone_in_cores() {
        let layers = crate::model::nets::vgg16_full();
        let mut prev = u64::MAX;
        for cores in 1..=6usize {
            let stages = partition_auto(&layers, cores);
            // structural sanity: contiguous cover, exact core usage
            let mut next = 0usize;
            for &(l0, l1, k) in &stages {
                assert_eq!(l0, next, "{cores} cores: contiguous");
                assert!(l1 > l0 && k >= 1, "{cores} cores: empty stage/group");
                next = l1;
            }
            assert_eq!(next, layers.len(), "{cores} cores: cover");
            assert_eq!(
                stages.iter().map(|&(_, _, k)| k).sum::<usize>(),
                cores,
                "{cores} cores: exact budget"
            );
            let b = partition_bottleneck(&layers, &stages);
            assert!(b <= prev, "{cores} cores: bottleneck {b} worse than {prev}");
            prev = b;

            // adding a core to the bottleneck stage never hurts
            let (bi, _) = stages
                .iter()
                .enumerate()
                .map(|(i, &(l0, l1, k))| {
                    (i, layers[l0..l1].iter().map(|l| l.op().layer_cost_on(k)).sum::<u64>())
                })
                .max_by_key(|&(_, c)| c)
                .unwrap();
            let mut fatter = stages.clone();
            fatter[bi].2 += 1;
            assert!(
                partition_bottleneck(&layers, &fatter) <= b,
                "{cores} cores: extra core raised the bottleneck"
            );
        }
    }

    /// The headline acceptance shape: on VGG-16-full at 4 cores the
    /// partition-DP picks an UNEQUAL partition — the weight-DMA-bound
    /// FC tail keeps a 1-core stage while a fat conv stage takes ≥ 2
    /// cores — and predicts a makespan no worse than the legacy
    /// one-core-per-stage pipeline's.
    #[test]
    fn partition_dp_picks_unequal_on_vgg16_full() {
        let layers = crate::model::nets::vgg16_full();
        let stages = partition_auto(&layers, 4);
        assert_eq!(stages.iter().map(|&(_, _, k)| k).sum::<usize>(), 4);
        assert!(stages.len() >= 2, "degenerated to one stage: {stages:?}");
        assert!(
            stages.iter().any(|&(_, _, k)| k >= 2),
            "no fat conv stage: {stages:?}"
        );
        // fc6 (the 102M-weight layer) sits at index 18: 13 convs + 5
        // interleaved pools come first
        assert!(matches!(layers[18], NetLayer::Fc(_)), "net shape changed under the test");
        let (_, _, fc_k) =
            *stages.iter().find(|&&(l0, l1, _)| l0 <= 18 && 18 < l1).unwrap();
        assert_eq!(fc_k, 1, "the DMA-floored FC tail must keep a thin group: {stages:?}");
        assert_eq!(stages.last().unwrap().2, 1, "logits stage wants 1 core: {stages:?}");
        // never worse than the legacy all-ones pipeline under the
        // shared estimate
        let legacy: Vec<(usize, usize, usize)> =
            pipeline_stages(&layers, 4).into_iter().map(|(a, b)| (a, b, 1)).collect();
        assert!(
            partition_bottleneck(&layers, &stages) <= partition_bottleneck(&layers, &legacy),
            "partition-DP lost to 1-per-stage: {stages:?} vs {legacy:?}"
        );
    }

    #[test]
    fn fixed_plan_validation_errors() {
        let layers = vec![
            NetLayer::Conv(ConvLayer::new("c1", 4, 12, 12, 8, 3, 3, 1, 1, 1)),
            NetLayer::Conv(ConvLayer::new("c2", 8, 12, 12, 8, 3, 3, 1, 1, 1)),
        ];
        let inputs = vec![vec![0i16; 4 * 12 * 12]];
        // plan wants 4 cores, run has 2
        let err = EngineConfig::new()
            .cores(2)
            .pool_mode(PoolMode::Pipelined)
            .stage_cores(StageCores::Fixed(vec![2, 2]))
            .ext_capacity(1 << 22)
            .build()
            .run_streaming("over", &layers, &inputs)
            .unwrap_err();
        assert!(matches!(err, ExecError::Config(_)), "want Config error, got {err:?}");
        // a longer-than-net plan truncates (stages cannot be empty)
        let pr = EngineConfig::new()
            .cores(3)
            .pool_mode(PoolMode::Pipelined)
            .stage_cores(StageCores::Fixed(vec![1, 1, 1]))
            .ext_capacity(1 << 22)
            .build()
            .run_streaming("trunc", &layers, &inputs)
            .unwrap();
        assert_eq!(pr.stages.len(), 2);
        assert_eq!(pr.stage_cores, vec![1, 1]);
    }

    #[test]
    fn stage_cores_parses() {
        assert_eq!("auto".parse::<StageCores>().unwrap(), StageCores::Auto);
        assert_eq!("per-stage".parse::<StageCores>().unwrap(), StageCores::PerStage);
        assert_eq!("one".parse::<StageCores>().unwrap(), StageCores::PerStage);
        assert_eq!(
            "1,2,1".parse::<StageCores>().unwrap(),
            StageCores::Fixed(vec![1, 2, 1])
        );
        assert!("1,0,1".parse::<StageCores>().is_err());
        assert!("".parse::<StageCores>().is_err());
        assert!("fast".parse::<StageCores>().is_err());
    }

    /// Multi-tenancy: outputs stay bit-identical to each tenant's solo
    /// run (the shared bus only adds wait), a lone tenant prices
    /// exactly like its own shared-bus run, and the combined account
    /// is self-consistent.
    #[test]
    fn multi_tenant_shares_bus_and_matches_solo() {
        let conv_net = vec![
            NetLayer::Conv(ConvLayer::new("c1", 4, 16, 16, 16, 3, 3, 1, 1, 1)),
            NetLayer::Conv(ConvLayer::new("c2", 16, 16, 16, 16, 3, 3, 1, 1, 1)),
        ];
        let fc_net = vec![NetLayer::Fc(FcLayer::new("fc", 256, 64))];
        let mut rng = XorShift::new(5);
        let conv_inputs: Vec<Vec<i16>> =
            (0..3).map(|_| rng.i16_vec(4 * 16 * 16, -700, 700)).collect();
        let fc_inputs: Vec<Vec<i16>> = (0..3).map(|_| rng.i16_vec(256, -700, 700)).collect();
        let conv_cfg = || {
            EngineConfig::new()
                .cores(2)
                .pool_mode(PoolMode::Pipelined)
                .bus(BusModel::Shared)
                .seed(41)
                .ext_capacity(1 << 22)
        };
        let fc_cfg = || {
            EngineConfig::new()
                .pool_mode(PoolMode::Pipelined)
                .bus(BusModel::Shared)
                .seed(43)
                .ext_capacity(1 << 22)
        };
        let solo_conv =
            conv_cfg().build().run_streaming("conv", &conv_net, &conv_inputs).unwrap();
        let solo_fc = fc_cfg().build().run_streaming("fc", &fc_net, &fc_inputs).unwrap();

        let mut ea = conv_cfg().build();
        let mut eb = fc_cfg().build();
        let mut tenants = [
            TenantRun { engine: &mut ea, name: "conv", layers: &conv_net, inputs: &conv_inputs },
            TenantRun { engine: &mut eb, name: "fc", layers: &fc_net, inputs: &fc_inputs },
        ];
        let mt = run_multi_streaming(&mut tenants).unwrap();
        assert_eq!(mt.tenants.len(), 2);
        assert_eq!(mt.tenant_cores, vec![2, 1]);
        assert_eq!(mt.total_cores(), 3);
        assert!(mt.divisor >= 1);
        // outputs are contention-proof
        assert_eq!(mt.tenants[0].outputs, solo_conv.outputs);
        assert_eq!(mt.tenants[1].outputs, solo_fc.outputs);
        // more contenders can only slow a tenant down
        assert!(mt.tenants[0].makespan_cycles >= solo_conv.makespan_cycles);
        assert!(mt.tenants[1].makespan_cycles >= solo_fc.makespan_cycles);
        assert_eq!(
            mt.makespan_cycles(),
            mt.tenants.iter().map(|t| t.makespan_cycles).max().unwrap()
        );
        let shares = mt.bus_shares();
        assert_eq!(shares.len(), 2);
        assert!((shares.iter().sum::<f64>() - 1.0).abs() < 1e-9, "shares {shares:?}");

        // a lone tenant is exactly its own shared-bus streaming run
        let mut solo_engine = conv_cfg().build();
        let mut lone = [TenantRun {
            engine: &mut solo_engine,
            name: "conv",
            layers: &conv_net,
            inputs: &conv_inputs,
        }];
        let one = run_multi_streaming(&mut lone).unwrap();
        let t = &one.tenants[0];
        assert_eq!(t.outputs, solo_conv.outputs);
        assert_eq!(t.fill_cycles, solo_conv.fill_cycles);
        assert_eq!(t.steady_interval_cycles, solo_conv.steady_interval_cycles);
        assert_eq!(t.makespan_cycles, solo_conv.makespan_cycles);
        assert_eq!(t.stage_cycles, solo_conv.stage_cycles);
    }

    #[test]
    fn engine_network_is_deterministic_across_repeats() {
        let layers = vec![
            NetLayer::Conv(ConvLayer::new("c1", 8, 16, 16, 48, 3, 3, 1, 1, 1)),
        ];
        let mut rng = XorShift::new(3);
        let input = rng.i16_vec(8 * 16 * 16, -500, 500);
        let mut engine =
            EngineConfig::new().cores(4).shard(ShardPolicy::RowBand).ext_capacity(1 << 22).build();
        let r1 = engine.run_network("rep", &layers, &input).unwrap();
        let r2 = engine.run_network("rep", &layers, &input).unwrap();
        assert_eq!(r1.layers[0].out, r2.layers[0].out);
        assert_eq!(r1.cycles(), r2.cycles());
        assert_eq!(r1.layers[0].core_cycles, r2.layers[0].core_cycles);
    }
}
