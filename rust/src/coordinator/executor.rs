//! Layer executor: Fig. 2 scheduling of conv / pool / FC layers onto
//! **one** core. The crate-internal `conv_layer` / `pool_layer` /
//! `fc_layer` are the primitives everything funnels into, behind the
//! [`LayerOp`](super::ops::LayerOp) trait — use
//! [`crate::coordinator::Engine`] to run them.
//!
//! Since 0.5.0 the executors are **cache- and scratch-aware**: every
//! call carries an [`ExecCtx`] — the engine's compile-once
//! [`PlanCache`] plus the core's [`Scratch`] staging arena — so the
//! layout plan, the task programs and (in tile-analytic mode) the
//! sampled row profile are derived once per layer *shape* and reused
//! across frames, shards and pipeline stages. A warm tile-analytic
//! pass performs no codegen, no staging and no cycle simulation at
//! all: it replays the cached analytic profile bit-exactly (see
//! `codegen::compiled` for why that is sound) and only walks the
//! accounting. FullCycle passes still execute every task — they
//! produce the outputs — but reuse the compiled programs and the
//! staging arena.

use std::collections::HashMap;

use crate::codegen::compiled::{
    flavor_of, AnalyticProfile, CompiledConv, PlanCache, RowSample, SampleSet, Scratch, TaskKey,
};
use crate::codegen::layout::{LoopOrder, Variant};
use crate::codegen::stage;
use crate::core::{CoreStats, Cpu, SimError};
use crate::isa::SReg;
use crate::mem::{EXT_BYTES_PER_CYCLE, EXT_LATENCY_CYCLES};
use crate::model::{ConvLayer, FcLayer, PoolLayer};

// The layer-descriptor enum moved to the model in 0.4.0; re-exported
// here so `coordinator::NetLayer` keeps working.
pub use crate::model::NetLayer;

use super::metrics::{add_stats, div_stats, scale_stats, LayerResult};

/// Execution mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// Cycle-simulate every task; outputs are produced and exact.
    FullCycle,
    /// Cycle-simulate one task per distinct (flavor, slice size) and
    /// compose analytically (row tasks are cycle-identical by
    /// construction). ~1000× faster; no outputs. Validated against
    /// FullCycle by tests and `benches/ablation`. With a warm
    /// [`PlanCache`] the sampled tasks are replayed from the compiled
    /// layer's profile instead of re-simulated.
    TileAnalytic,
}

#[derive(Debug, Clone, Copy)]
pub struct ExecOptions {
    pub mode: ExecMode,
    /// Precision gating (16 = off, 8 = the paper's gated AlexNet run).
    pub gate_bits: u8,
    /// Number of ConvAix cores a layer may be sharded across (1 = the
    /// paper's single-core latency setup). The single-layer executors
    /// in this module ignore it; it is consumed by
    /// [`crate::coordinator::engine`].
    pub cores: usize,
    /// Frames per batched run (1 = latency mode). Ignored by the
    /// single-layer executors.
    pub batch: usize,
    /// DMA double buffering: when `true` (default) plans allocate a
    /// rotation shadow where DM capacity permits, so steady-state
    /// iterations overlap compute with the next iteration's stream.
    /// `false` is the honest no-overlap baseline (every stream
    /// serializes). Outputs are identical either way — only cycles
    /// move — pinned by `tests/rotation_identity.rs`.
    pub rotation: bool,
}

impl Default for ExecOptions {
    fn default() -> Self {
        Self { mode: ExecMode::FullCycle, gate_bits: 16, cores: 1, batch: 1, rotation: true }
    }
}

/// Everything a single-core layer execution needs besides the core
/// itself: the engine's compile-once [`PlanCache`] and the core's
/// [`Scratch`] staging arena. Built by the engine per core; the
/// executors never allocate either themselves, which is what makes the
/// steady-state loop of `run_batched`/`run_streaming` compile- and
/// (near-)allocation-free after the first frame.
pub struct ExecCtx<'a> {
    pub(crate) cache: &'a PlanCache,
    pub(crate) scratch: &'a mut Scratch,
}

impl<'a> ExecCtx<'a> {
    pub fn new(cache: &'a PlanCache, scratch: &'a mut Scratch) -> Self {
        Self { cache, scratch }
    }
}

#[derive(Debug)]
pub enum ExecError {
    Codegen(crate::codegen::CodegenError),
    Sim(SimError),
    /// A run configuration the scheduler cannot honor (e.g. an explicit
    /// `--stage-cores` plan asking for more cores than the pool has).
    Config(String),
    /// A pool core is gone: it exhausted its fault-retry budget on
    /// `layer` (see [`super::faults`]), or its worker thread panicked.
    /// The engine catches this, blacklists the core and re-runs the
    /// shard assignment / stage partition over the survivors; it only
    /// escapes to the caller when no survivor is left.
    CoreFailure { core: usize, layer: String },
    /// A shard-hand-off checksum cross-check failed at `merge_shards`:
    /// data changed between a shard's (verified) production and its
    /// merge — corruption the bounded retry could not see.
    Corrupted { layer: String },
}

impl std::fmt::Display for ExecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecError::Codegen(e) => write!(f, "codegen: {e}"),
            ExecError::Sim(e) => write!(f, "sim: {e}"),
            ExecError::Config(msg) => write!(f, "config: {msg}"),
            ExecError::CoreFailure { core, layer } => {
                write!(f, "core failure: core {core} failed layer `{layer}` beyond its retry budget")
            }
            ExecError::Corrupted { layer } => {
                write!(f, "detected corruption: shard output checksum mismatch in layer `{layer}`")
            }
        }
    }
}

impl std::error::Error for ExecError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ExecError::Codegen(e) => Some(e),
            ExecError::Sim(e) => Some(e),
            ExecError::Config(_) | ExecError::CoreFailure { .. } | ExecError::Corrupted { .. } => {
                None
            }
        }
    }
}

impl From<crate::codegen::CodegenError> for ExecError {
    fn from(e: crate::codegen::CodegenError) -> Self {
        ExecError::Codegen(e)
    }
}

impl From<SimError> for ExecError {
    fn from(e: SimError) -> Self {
        ExecError::Sim(e)
    }
}

/// Analytic DMA time for moving `bytes` with `requests` descriptors.
///
/// The transfer term rounds **up**: a trailing partial bus beat still
/// occupies a full cycle on the `EXT_BYTES_PER_CYCLE`-wide external bus.
/// (Truncating here undercounted every DMA-bound segment whose size is
/// not a multiple of the bus width, inflating reported GOP/s and
/// utilization.)
pub(crate) fn dma_cycles(bytes: u64, requests: u64) -> u64 {
    bytes.div_ceil(EXT_BYTES_PER_CYCLE as u64) + requests * EXT_LATENCY_CYCLES
}

/// One (tile, slice, band) iteration of a layer's staging schedule:
/// the compute cycles its rows cost and the off-chip stream (bytes,
/// descriptors) that must land in DM before those rows can run.
#[derive(Debug, Clone, Copy)]
pub(crate) struct IterRec {
    pub compute: u64,
    pub bytes: u64,
    pub reqs: u64,
}

/// A layer's DMA timeline under the feasibility-gated overlap model.
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct DmaTimeline {
    /// Total layer cycles (compute + exposed DMA).
    pub cycles: u64,
    /// Total DMA transfer cycles (Σ per-iteration streams).
    pub dma_cycles: u64,
    /// Serialized first-iteration fill (rotated plans only).
    pub fill_bytes: u64,
    pub fill_cycles: u64,
    /// Never-overlapped stream (un-rotatable plans only).
    pub serial_bytes: u64,
    pub serial_cycles: u64,
}

/// Price a layer's iteration schedule. With a rotation shadow
/// (`rotated`), iteration `i`'s compute overlaps iteration `i+1`'s
/// stream into the inactive buffer pair — only the first stream is
/// exposed (`fill`), and every steady iteration costs
/// `max(compute_i, dma_{i+1})`. Without one, every stream serializes
/// against compute: `Σ (compute_i + dma_i)`. Either way
/// `cycles ≥ max(Σ compute, Σ dma)` — overlap can hide latency, never
/// create bandwidth.
pub(crate) fn price_iters(iters: &[IterRec], rotated: bool) -> DmaTimeline {
    let d: Vec<u64> = iters.iter().map(|it| dma_cycles(it.bytes, it.reqs)).collect();
    let dma: u64 = d.iter().sum();
    let compute: u64 = iters.iter().map(|it| it.compute).sum();
    if iters.is_empty() {
        return DmaTimeline::default();
    }
    if rotated {
        let mut cycles = d[0];
        for (i, it) in iters.iter().enumerate() {
            cycles += it.compute.max(d.get(i + 1).copied().unwrap_or(0));
        }
        DmaTimeline {
            cycles,
            dma_cycles: dma,
            fill_bytes: iters[0].bytes,
            fill_cycles: d[0],
            serial_bytes: 0,
            serial_cycles: 0,
        }
    } else {
        DmaTimeline {
            cycles: compute + dma,
            dma_cycles: dma,
            fill_bytes: 0,
            fill_cycles: 0,
            serial_bytes: iters.iter().map(|it| it.bytes).sum(),
            serial_cycles: dma,
        }
    }
}

/// Run a (possibly grouped) conv layer. `x`: (ic, ih, iw), `w`:
/// (oc, ic/groups, fh, fw), `b`: (oc,). Returns metrics and (in
/// FullCycle mode) the output tensor (oc, oh, ow).
pub(crate) fn conv_layer(
    cpu: &mut Cpu,
    layer: &ConvLayer,
    x: &[i16],
    w: &[i16],
    b: &[i32],
    opts: ExecOptions,
    ctx: &mut ExecCtx<'_>,
) -> Result<LayerResult, ExecError> {
    let g = layer.groups;
    if g == 1 {
        let cc = ctx.cache.conv(layer, opts.gate_bits, opts.rotation)?;
        return run_dense(cpu, &cc, layer.name, x, w, b, opts, ctx.scratch);
    }
    let lg = layer.per_group();
    // one compiled artifact serves every group (identical dense shape)
    let cc = ctx.cache.conv(&lg, opts.gate_bits, opts.rotation)?;
    let (icg, ocg) = (lg.ic, lg.oc);
    let ohw = layer.oh() * layer.ow();
    let mut total = LayerResult { name: layer.name, ..Default::default() };
    // the assembled output only exists in FullCycle mode (analytic
    // group runs return no values to scatter)
    let mut out =
        if opts.mode == ExecMode::FullCycle { vec![0i16; layer.oc * ohw] } else { Vec::new() };
    for gi in 0..g {
        let xg = &x[gi * icg * layer.ih * layer.iw..(gi + 1) * icg * layer.ih * layer.iw];
        let wg = &w[gi * ocg * icg * layer.fh * layer.fw..(gi + 1) * ocg * icg * layer.fh * layer.fw];
        let bg = &b[gi * ocg..(gi + 1) * ocg];
        let r = run_dense(cpu, &cc, lg.name, xg, wg, bg, opts, ctx.scratch)?;
        if !r.out.is_empty() {
            out[gi * ocg * ohw..(gi + 1) * ocg * ohw].copy_from_slice(&r.out);
        }
        total.cycles += r.cycles;
        total.compute_cycles += r.compute_cycles;
        total.dma_cycles += r.dma_cycles;
        total.dma_fill_bytes += r.dma_fill_bytes;
        total.dma_fill_cycles += r.dma_fill_cycles;
        total.dma_serial_bytes += r.dma_serial_bytes;
        total.dma_serial_cycles += r.dma_serial_cycles;
        total.macs += r.macs;
        total.io_in += r.io_in;
        total.io_out += r.io_out;
        total.stats = add_stats(&total.stats, &r.stats);
    }
    if opts.mode == ExecMode::FullCycle {
        total.out = out;
    }
    Ok(total)
}

/// Tile-analytic sample budget per task key (rows are cycle-identical
/// modulo DM bank-conflict noise, so a 4-row sample mean is within ~1 %).
const ANALYTIC_SAMPLES: u64 = 4;

#[allow(clippy::too_many_arguments)]
fn run_dense(
    cpu: &mut Cpu,
    cc: &CompiledConv,
    name: &'static str,
    x: &[i16],
    w: &[i16],
    b: &[i32],
    opts: ExecOptions,
    scratch: &mut Scratch,
) -> Result<LayerResult, ExecError> {
    let plan = &cc.plan;
    let l = &plan.layer;
    let (oh, ow) = (l.oh(), l.ow());
    let ocs = plan.variant.ocs();
    let full = opts.mode == ExecMode::FullCycle;

    // gate-bits override: patch the CSR after program setup by setting
    // it in the Cpu directly before each run (the program writes
    // frac_shift/lb_stride; gate_bits persists).
    cpu.csr.gate_bits = opts.gate_bits;

    // Warm analytic profile: a previous tile-analytic pass over this
    // shape published its sampled rows — replay them instead of
    // staging/simulating anything (bit-exact, see codegen::compiled).
    let warm: Option<&AnalyticProfile> = if full { None } else { cc.analytic.get() };

    let mut res = LayerResult { name, macs: l.macs(), ..Default::default() };
    // the output tensor and the PSum shadow only exist in FullCycle
    // mode — analytic passes produce no values
    let mut out = if full { vec![0i16; l.oc * oh * ow] } else { Vec::new() };
    // PSum shadow (host side) per (tile, row) — the off-chip buffer of
    // Fig. 2 step 2 when M > 1.
    let mut psum: Vec<Vec<i32>> =
        if full && plan.m > 1 { vec![Vec::new(); plan.n_tiles * oh] } else { Vec::new() };

    // Padded input, staged lazily into the scratch arena: a warm
    // analytic pass never stages a band, so it never pays the pad.
    let mut xp_ready = false;

    // Cold analytic sampling state — (count, Σcycles, Σstats) per task
    // key, exactly the 0.4 shape — plus the raw per-row record used to
    // publish the profile, plus the warm replay cursors.
    let mut acc: HashMap<TaskKey, (u64, u64, CoreStats)> = HashMap::new();
    let mut raw: HashMap<TaskKey, Vec<RowSample>> = HashMap::new();
    let mut cursor: HashMap<TaskKey, usize> = HashMap::new();

    // I/O accounting per plan.loop_order (DESIGN.md §6 ablation).
    // Bytes are charged for every iteration whether or not the host
    // actually stages data for it — skipping dead staging is a host-
    // side optimization invisible to the model.
    let filt_bytes = |mi: usize| plan.filter_stream_bytes(mi);
    let band_in_bytes = |mi: usize, bi: usize| -> u64 {
        let rows = if bi == 0 {
            plan.in_rows_band
        } else {
            (plan.band_rows_of(bi) * l.stride).min(plan.in_rows_band)
        };
        (plan.slice_ics(mi) * rows * plan.row_bytes) as u64
    };
    let out_row_bytes = match plan.variant {
        Variant::A => (ow * 32) as u64,
        Variant::B => (ow * 2 * ocs) as u64,
    };
    let psum_row_bytes = (plan.g * 12 * 64) as u64;

    let band_outer = plan.loop_order == LoopOrder::BandOuter;

    // Will any row of `key` still run on the core? Staging is only
    // needed while this holds (FullCycle: always).
    let live = |acc: &HashMap<TaskKey, (u64, u64, CoreStats)>, key: &TaskKey| -> bool {
        if full {
            return true;
        }
        if warm.is_some() {
            return false;
        }
        acc.get(key).is_none_or(|e| e.0 < ANALYTIC_SAMPLES)
    };

    // One (tile, slice, band) iteration's rows. Fast paths accumulate
    // whole saturated bands in one step (the same integer sums the
    // per-row loop would produce); the slow path stages/simulates.
    let do_band = |cpu: &mut Cpu,
                   res: &mut LayerResult,
                   acc: &mut HashMap<TaskKey, (u64, u64, CoreStats)>,
                   raw: &mut HashMap<TaskKey, Vec<RowSample>>,
                   cursor: &mut HashMap<TaskKey, usize>,
                   psum: &mut Vec<Vec<i32>>,
                   out: &mut Vec<i16>,
                   row_buf: &mut Vec<i16>,
                   tile: usize,
                   mi: usize,
                   bi: usize|
     -> Result<(), ExecError> {
        let f = flavor_of(mi, plan.m);
        let key = cc.task_key(mi);
        let oh0 = bi * plan.band_rows;
        let rows = plan.band_rows_of(bi);
        let psum_in = plan.m > 1 && !f.first_slice;
        let psum_out = plan.m > 1 && !f.last_slice;

        // bulk I/O accounting for `n` rows (identical to n per-row adds)
        let bulk_io = |res: &mut LayerResult, n: u64| {
            if psum_in {
                res.io_in += n * psum_row_bytes;
            }
            if psum_out {
                res.io_out += n * psum_row_bytes;
            }
            if f.last_slice {
                res.io_out += n * out_row_bytes;
            }
        };

        if !full {
            if let Some(p) = warm {
                // Replay: the leading occurrences of `key` take the raw
                // samples in schedule order (exactly the rows the cold
                // pass simulated), the rest take the truncated mean —
                // the same arithmetic, in the same order, as the cold
                // pass.
                let s: &SampleSet = p
                    .samples
                    .get(&key)
                    .expect("analytic profile missing a task key of its own shape");
                let done = cursor.entry(key).or_insert(0);
                let mut r = 0usize;
                while r < rows && *done < s.rows.len() {
                    let sample = &s.rows[*done];
                    res.compute_cycles += sample.cycles;
                    res.stats = add_stats(&res.stats, &sample.stats);
                    *done += 1;
                    r += 1;
                }
                let rest = (rows - r) as u64;
                if rest > 0 {
                    let n = s.n();
                    res.compute_cycles += rest * (s.total_cycles / n);
                    res.stats =
                        add_stats(&res.stats, &scale_stats(&div_stats(&s.total_stats, n), rest));
                }
                bulk_io(res, rows as u64);
                return Ok(());
            }
            if let Some((n, cyc, st)) = acc.get(&key) {
                if *n >= ANALYTIC_SAMPLES {
                    // whole band saturated: rows × the truncated mean
                    res.compute_cycles += (rows as u64) * (cyc / n);
                    res.stats = add_stats(
                        &res.stats,
                        &scale_stats(&div_stats(st, *n), rows as u64),
                    );
                    bulk_io(res, rows as u64);
                    return Ok(());
                }
            }
        }

        // per-row path: FullCycle, or cold analytic until saturation
        for r in 0..rows {
            let oh_local = r;
            let oh_abs = oh0 + r;
            // psum I/O + staging (values only matter in FullCycle mode)
            if psum_in {
                if full {
                    let pv = &psum[tile * oh + oh_abs];
                    stage::write_psum_row(plan, &mut cpu.mem.dm, pv);
                }
                res.io_in += psum_row_bytes;
            }
            let analytic_hit =
                !full && acc.get(&key).is_some_and(|(n, _, _)| *n >= ANALYTIC_SAMPLES);
            if !analytic_hit {
                // ABI registers
                cpu.regs.set_r(
                    SReg(2),
                    (plan.dm.input + oh_local * l.stride * plan.row_bytes) as i32,
                );
                cpu.regs.set_r(SReg(4), plan.dm.out as i32);
                cpu.regs.set_r(SReg(5), plan.dm.psum as i32);
                cpu.regs.set_r(SReg(6), plan.dm.filt as i32);
                let stats = cpu.run(cc.program(&key))?;
                cpu.csr.gate_bits = opts.gate_bits; // program may not touch it
                res.compute_cycles += stats.cycles;
                if !full {
                    let e = acc.entry(key).or_insert((0, 0, CoreStats::default()));
                    e.0 += 1;
                    e.1 += stats.cycles;
                    e.2 = add_stats(&e.2, &stats);
                    raw.entry(key)
                        .or_default()
                        .push(RowSample { oh_local, cycles: stats.cycles, stats });
                }
                res.stats = add_stats(&res.stats, &stats);
            } else {
                let (n, cyc, stats) = &acc[&key];
                res.compute_cycles += cyc / n;
                res.stats = add_stats(&res.stats, &scale_stats(&div_stats(stats, *n), 1));
            }
            // collect outputs / psums
            if full {
                if f.last_slice {
                    stage::read_out_row_into(plan, &cpu.mem.dm, ow, row_buf);
                    for ocl in 0..ocs {
                        let oc = tile * ocs + ocl;
                        if oc < l.oc {
                            out[(oc * oh + oh_abs) * ow..(oc * oh + oh_abs) * ow + ow]
                                .copy_from_slice(&row_buf[ocl * ow..(ocl + 1) * ow]);
                        }
                    }
                } else {
                    psum[tile * oh + oh_abs] = stage::read_psum_row(plan, &cpu.mem.dm);
                }
            }
            if psum_out {
                res.io_out += psum_row_bytes;
            }
            if f.last_slice {
                res.io_out += out_row_bytes;
            }
        }
        Ok(())
    };

    // Per-iteration timeline record: one entry per (tile, slice, band),
    // in schedule order. Compute and byte charges are captured as
    // running deltas of the accumulators, so staging charges stay
    // exactly where the accounting above puts them (the band charge
    // lands in the tile-0 iteration under BandOuter, the filter charge
    // in the band-0 iteration under TileOuter). Each iteration is one
    // descriptor; the per-tile readback descriptor rides the tile's
    // last (slice, band) iteration — Σ reqs equals the pre-timeline
    // whole-layer request count.
    let mut iters: Vec<IterRec> =
        Vec::with_capacity(plan.n_tiles * plan.m * plan.n_bands);
    let iter_reqs = |mi: usize, bi: usize| -> u64 {
        1 + u64::from(mi + 1 == plan.m && bi + 1 == plan.n_bands)
    };

    if band_outer {
        // input streamed once per slice; filters re-loaded per band
        for mi in 0..plan.m {
            let key = cc.task_key(mi);
            for bi in 0..plan.n_bands {
                let oh0 = bi * plan.band_rows;
                let mut io0 = res.io_in + res.io_out;
                if live(&acc, &key) {
                    if !xp_ready {
                        stage::pad_input_into(l, x, &mut scratch.xp);
                        xp_ready = true;
                    }
                    stage::input_band_into(plan, &scratch.xp, mi, oh0, &mut scratch.band);
                    stage::poke(&mut cpu.mem.dm, plan.dm.input, &scratch.band);
                }
                res.io_in += band_in_bytes(mi, bi);
                for tile in 0..plan.n_tiles {
                    let c0 = res.compute_cycles;
                    if live(&acc, &key) {
                        stage_filters(cpu, cc, w, b, tile, mi, &mut scratch.filt);
                    }
                    res.io_in += filt_bytes(mi);
                    do_band(
                        cpu, &mut res, &mut acc, &mut raw, &mut cursor, &mut psum, &mut out,
                        &mut scratch.row, tile, mi, bi,
                    )?;
                    iters.push(IterRec {
                        compute: res.compute_cycles - c0,
                        bytes: res.io_in + res.io_out - io0,
                        reqs: iter_reqs(mi, bi),
                    });
                    io0 = res.io_in + res.io_out;
                }
            }
        }
    } else {
        // filters loaded once per (tile, slice); input re-streamed per tile
        for tile in 0..plan.n_tiles {
            for mi in 0..plan.m {
                let key = cc.task_key(mi);
                let mut io0 = res.io_in + res.io_out;
                if live(&acc, &key) {
                    stage_filters(cpu, cc, w, b, tile, mi, &mut scratch.filt);
                }
                res.io_in += filt_bytes(mi);
                for bi in 0..plan.n_bands {
                    let oh0 = bi * plan.band_rows;
                    let c0 = res.compute_cycles;
                    if live(&acc, &key) {
                        if !xp_ready {
                            stage::pad_input_into(l, x, &mut scratch.xp);
                            xp_ready = true;
                        }
                        stage::input_band_into(plan, &scratch.xp, mi, oh0, &mut scratch.band);
                        stage::poke(&mut cpu.mem.dm, plan.dm.input, &scratch.band);
                    }
                    res.io_in += band_in_bytes(mi, bi);
                    do_band(
                        cpu, &mut res, &mut acc, &mut raw, &mut cursor, &mut psum, &mut out,
                        &mut scratch.row, tile, mi, bi,
                    )?;
                    iters.push(IterRec {
                        compute: res.compute_cycles - c0,
                        bytes: res.io_in + res.io_out - io0,
                        reqs: iter_reqs(mi, bi),
                    });
                    io0 = res.io_in + res.io_out;
                }
            }
        }
    }

    // Precision-gated off-chip transfers are packed: at <=8 effective
    // bits, tensors move at 1 byte/element (Table II footnote: values
    // are reported "with optimized word width"). Every per-iteration
    // byte charge is a sum of even row quantities, so halving each
    // iteration tiles the halved totals exactly.
    if opts.gate_bits <= 8 {
        res.io_in /= 2;
        res.io_out /= 2;
        for it in &mut iters {
            it.bytes /= 2;
        }
    }
    // DMA timeline: when the plan carries a rotation shadow
    // (`plan.rot`), iteration i's compute overlaps iteration i+1's
    // stream into the inactive buffer pair and only the first stream
    // serializes (fill); without one, DM cannot hold the next stream
    // alongside the live one, so every stream serializes honestly.
    let t = price_iters(&iters, plan.rot.is_some());
    res.dma_cycles = t.dma_cycles;
    res.dma_fill_bytes = t.fill_bytes;
    res.dma_fill_cycles = t.fill_cycles;
    res.dma_serial_bytes = t.serial_bytes;
    res.dma_serial_cycles = t.serial_cycles;
    res.cycles = t.cycles;
    if full {
        res.out = out;
    } else if warm.is_none() {
        // publish the sampled rows so every later analytic pass over
        // this shape replays instead of re-simulating (first publisher
        // wins; racing cold passes compute identical profiles)
        let samples = raw
            .into_iter()
            .map(|(k, rows)| {
                let total_cycles = rows.iter().map(|r| r.cycles).sum();
                let mut total_stats = CoreStats::default();
                for r in &rows {
                    total_stats = add_stats(&total_stats, &r.stats);
                }
                (k, SampleSet { rows, total_cycles, total_stats })
            })
            .collect();
        let _ = cc.analytic.set(AnalyticProfile { samples });
    }
    Ok(res)
}

fn stage_filters(
    cpu: &mut Cpu,
    cc: &CompiledConv,
    w: &[i16],
    b: &[i32],
    tile: usize,
    mi: usize,
    filt_buf: &mut Vec<i16>,
) {
    let plan = &cc.plan;
    let bias = stage::bias_vector(plan, b, tile);
    stage::poke(&mut cpu.mem.dm, plan.dm.bias, &bias);
    stage::filter_stream_into(plan, w, tile, mi, filt_buf);
    stage::poke(&mut cpu.mem.dm, plan.dm.filt, filt_buf);
}

/// Run a max-pool layer. Input `x`: (ic, ih, iw). Output (ic, oh, ow).
pub(crate) fn pool_layer(
    cpu: &mut Cpu,
    layer: &PoolLayer,
    x: &[i16],
    opts: ExecOptions,
    ctx: &mut ExecCtx<'_>,
) -> Result<LayerResult, ExecError> {
    let cp = ctx.cache.pool(layer, opts.rotation)?;
    let plan = &cp.plan;
    let (oh, ow) = (layer.oh(), layer.ow());
    let full = opts.mode == ExecMode::FullCycle;
    let mut res = LayerResult { name: layer.name, ..Default::default() };
    let mut out = if full { vec![0i16; layer.ic * oh * ow] } else { Vec::new() };
    let n_tiles = layer.ic.div_ceil(16);
    // pool rows are cycle-identical: one sample serves the whole layer
    // (and, via the compiled artifact, every later analytic pass)
    let mut analytic: Option<(u64, CoreStats)> =
        if full { None } else { cp.analytic.get().copied() };
    // one iteration = one (tile, output row): its window rows stream
    // in, its output row streams out, one descriptor each way folded
    // into one request (matching the pre-timeline request count)
    let iter_bytes = ((layer.size * layer.iw + ow) * 32) as u64;
    let mut iters: Vec<IterRec> = Vec::with_capacity(n_tiles * oh);

    for tile in 0..n_tiles {
        for oy in 0..oh {
            let c0 = res.compute_cycles;
            let cached = if full { None } else { analytic };
            if let Some((cyc, stats)) = &cached {
                res.compute_cycles += cyc;
                res.stats = add_stats(&res.stats, stats);
            } else {
                // stage `size` input rows as pixel-major 16-ch vectors
                for r in 0..layer.size {
                    let y = oy * layer.stride + r;
                    for px in 0..layer.iw {
                        let mut v = [0i16; 16];
                        for (cl, vv) in v.iter_mut().enumerate() {
                            let c = tile * 16 + cl;
                            if c < layer.ic {
                                *vv = x[(c * layer.ih + y) * layer.iw + px];
                            }
                        }
                        cpu.mem
                            .dm
                            .poke_i16_slice(plan.dm_input + r * plan.in_row_bytes + px * 32, &v);
                    }
                }
                cpu.regs.set_r(SReg(2), plan.dm_input as i32);
                cpu.regs.set_r(SReg(4), plan.dm_out as i32);
                let stats = cpu.run(&cp.pm)?;
                res.compute_cycles += stats.cycles;
                if !full {
                    analytic = Some((stats.cycles, stats));
                    let _ = cp.analytic.set((stats.cycles, stats));
                }
                res.stats = add_stats(&res.stats, &stats);
                if full {
                    for px in 0..ow {
                        let v = cpu.mem.dm.peek_i16_slice(plan.dm_out + px * 32, 16);
                        for cl in 0..16 {
                            let c = tile * 16 + cl;
                            if c < layer.ic {
                                out[(c * oh + oy) * ow + px] = v[cl];
                            }
                        }
                    }
                }
            }
            iters.push(IterRec {
                compute: res.compute_cycles - c0,
                bytes: iter_bytes,
                reqs: 1,
            });
        }
    }
    // I/O: rows in (with window overlap), rows out
    res.io_in = (n_tiles * oh * layer.size * layer.iw * 32) as u64;
    res.io_out = (n_tiles * oh * ow * 32) as u64;
    // DMA timeline: pool windows are tiny, so every benchmark pool
    // rotates — but the feasibility gate is the plan's, not assumed
    let t = price_iters(&iters, plan.rot.is_some());
    res.dma_cycles = t.dma_cycles;
    res.dma_fill_bytes = t.fill_bytes;
    res.dma_fill_cycles = t.fill_cycles;
    res.dma_serial_bytes = t.serial_bytes;
    res.dma_serial_cycles = t.serial_cycles;
    res.cycles = t.cycles;
    if full {
        res.out = out;
    }
    Ok(res)
}

/// Run a fully connected layer: `y = act(W·x + b)` lowered onto the
/// conv dataflow as a 1×1 convolution over a 1×1 map
/// ([`FcLayer::as_conv`]) — input features stream through the filter
/// FIFO as depth slices, output neurons ride the oc-tile machinery.
/// `x`: (in_features,), `w`: (out_features, in_features), `b`:
/// (out_features,). The lowering is bit-exact against the host
/// reference (`codegen::reffc`) because the weight layouts coincide.
/// The plan cache keys on the lowered conv shape, so same-shape FC
/// layers share one compiled artifact with their conv twins.
pub(crate) fn fc_layer(
    cpu: &mut Cpu,
    layer: &FcLayer,
    x: &[i16],
    w: &[i16],
    b: &[i32],
    opts: ExecOptions,
    ctx: &mut ExecCtx<'_>,
) -> Result<LayerResult, ExecError> {
    conv_layer(cpu, &layer.as_conv(), x, w, b, opts, ctx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::layout;
    use crate::codegen::refconv;
    use crate::fixed::RoundMode;
    use crate::util::XorShift;

    fn run_conv(
        cpu: &mut Cpu,
        l: &ConvLayer,
        x: &[i16],
        w: &[i16],
        b: &[i32],
        opts: ExecOptions,
    ) -> LayerResult {
        let cache = PlanCache::new();
        let mut scratch = Scratch::default();
        conv_layer(cpu, l, x, w, b, opts, &mut ExecCtx::new(&cache, &mut scratch)).unwrap()
    }

    fn run_pool(cpu: &mut Cpu, l: &PoolLayer, x: &[i16], opts: ExecOptions) -> LayerResult {
        let cache = PlanCache::new();
        let mut scratch = Scratch::default();
        pool_layer(cpu, l, x, opts, &mut ExecCtx::new(&cache, &mut scratch)).unwrap()
    }

    fn run_fc(
        cpu: &mut Cpu,
        l: &FcLayer,
        x: &[i16],
        w: &[i16],
        b: &[i32],
        opts: ExecOptions,
    ) -> LayerResult {
        let cache = PlanCache::new();
        let mut scratch = Scratch::default();
        fc_layer(cpu, l, x, w, b, opts, &mut ExecCtx::new(&cache, &mut scratch)).unwrap()
    }

    fn check_layer(l: &ConvLayer, seed: u64) {
        let mut rng = XorShift::new(seed);
        let x = rng.i16_vec(l.ic * l.ih * l.iw, -2000, 2000);
        let w = rng.i16_vec(l.oc * (l.ic / l.groups) * l.fh * l.fw, -256, 256);
        let b = rng.i32_vec(l.oc, -2000, 2000);
        let mut cpu = Cpu::new(1 << 20);
        let r = run_conv(&mut cpu, l, &x, &w, &b, ExecOptions::default());
        let expect = refconv::conv2d_grouped(&x, &w, &b, l, RoundMode::HalfUp, 16);
        assert_eq!(r.out.len(), expect.len(), "{}", l.name);
        for (i, (got, want)) in r.out.iter().zip(&expect).enumerate() {
            assert_eq!(got, want, "{}: idx {i}", l.name);
        }
        assert!(r.utilization() > 0.1, "{}: util {}", l.name, r.utilization());
    }

    #[test]
    fn small_conv_variant_a_matches_reference() {
        // ow=24 -> G=2 full groups under variant A
        let l = ConvLayer::new("va", 4, 24, 24, 16, 3, 3, 1, 1, 1);
        let p = layout::plan(&l).unwrap();
        assert_eq!(p.variant, Variant::A);
        check_layer(&l, 1);
    }

    #[test]
    fn small_conv_variant_b_matches_reference() {
        // small ow + many oc -> variant B
        let l = ConvLayer::new("vb", 8, 13, 13, 48, 3, 3, 1, 1, 1);
        let p = layout::plan(&l).unwrap();
        assert_eq!(p.variant, Variant::B);
        check_layer(&l, 2);
    }

    #[test]
    fn strided_conv_matches_reference() {
        let l = ConvLayer::new("s2", 3, 23, 23, 16, 5, 5, 2, 2, 1);
        check_layer(&l, 3);
    }

    #[test]
    fn conv1_like_unfused_rows() {
        // stride 4, 11x11: per-fy line loads
        let l = ConvLayer::new("c1", 3, 43, 43, 16, 11, 11, 4, 0, 1);
        let p = layout::plan(&l).unwrap();
        assert!(!p.fused_rows);
        check_layer(&l, 4);
    }

    #[test]
    fn grouped_conv_matches_reference() {
        let l = ConvLayer::new("grp", 8, 13, 13, 32, 3, 3, 1, 1, 2);
        check_layer(&l, 5);
    }

    #[test]
    fn multi_slice_psum_path_matches_reference() {
        // force M > 1 by exceeding the DM filter budget: ic large
        let l = ConvLayer::new("ms", 768, 6, 6, 16, 3, 3, 1, 1, 1);
        let p = layout::plan(&l).unwrap();
        assert!(p.m > 1, "expected multiple slices, got m={}", p.m);
        check_layer(&l, 6);
    }

    #[test]
    fn odd_ic_tail_matches_reference() {
        let l = ConvLayer::new("odd", 5, 10, 10, 16, 3, 3, 1, 1, 1);
        check_layer(&l, 7);
    }

    #[test]
    fn non_multiple_oc_padding() {
        let l = ConvLayer::new("ocp", 4, 10, 10, 24, 3, 3, 1, 0, 1);
        check_layer(&l, 8);
    }

    #[test]
    fn relu_off_layer() {
        let mut l = ConvLayer::new("nr", 4, 8, 8, 16, 3, 3, 1, 1, 1);
        l.relu = false;
        check_layer(&l, 9);
    }

    #[test]
    fn analytic_matches_full_cycle_time() {
        let l = ConvLayer::new("an", 8, 16, 16, 32, 3, 3, 1, 1, 1);
        let mut rng = XorShift::new(10);
        let x = rng.i16_vec(l.ic * l.ih * l.iw, -500, 500);
        let w = rng.i16_vec(l.oc * l.ic * 9, -100, 100);
        let b = rng.i32_vec(l.oc, -100, 100);
        let mut cpu = Cpu::new(1 << 20);
        let full = run_conv(&mut cpu, &l, &x, &w, &b, ExecOptions::default());
        let mut cpu2 = Cpu::new(1 << 20);
        let fast = run_conv(
            &mut cpu2,
            &l,
            &x,
            &w,
            &b,
            ExecOptions { mode: ExecMode::TileAnalytic, ..Default::default() },
        );
        let err = (full.cycles as f64 - fast.cycles as f64).abs() / full.cycles as f64;
        assert!(err < 0.01, "analytic vs full: {} vs {}", fast.cycles, full.cycles);
        assert_eq!(full.io_total(), fast.io_total());
    }

    #[test]
    fn analytic_samples_are_data_independent() {
        // The compile-once profile replay rests on one property: a task
        // program's cycles and activity counters are functions of the
        // program, the addresses it touches and the CSR state — never
        // of tensor VALUES (mac_ops_gated8 switches on the CSR gate
        // bits, which key the cache). Two cold analytic passes over
        // different data must therefore agree to the last counter.
        let l = ConvLayer::new("di", 8, 16, 16, 32, 3, 3, 1, 1, 1);
        for gate in [16u8, 8] {
            let opts =
                ExecOptions { mode: ExecMode::TileAnalytic, gate_bits: gate, ..Default::default() };
            let run = |seed: u64| {
                let mut rng = XorShift::new(seed);
                let x = rng.i16_vec(l.ic * l.ih * l.iw, -2000, 2000);
                let w = rng.i16_vec(l.oc * l.ic * 9, -256, 256);
                let b = rng.i32_vec(l.oc, -100, 100);
                let mut cpu = Cpu::new(1 << 20);
                run_conv(&mut cpu, &l, &x, &w, &b, opts)
            };
            let a = run(1);
            let c = run(2);
            assert_eq!(a.cycles, c.cycles, "gate {gate}: cycles depend on data");
            assert_eq!(a.compute_cycles, c.compute_cycles, "gate {gate}");
            assert_eq!(a.stats, c.stats, "gate {gate}: stats depend on data");
        }
    }

    #[test]
    fn warm_analytic_replay_is_bit_identical_to_cold() {
        // one shared cache: call 1 is the cold pass (samples + publish),
        // call 2 replays the profile without touching the core — every
        // reported number must match to the last counter
        for l in [
            ConvLayer::new("wa", 8, 16, 16, 32, 3, 3, 1, 1, 1),
            ConvLayer::new("wms", 768, 6, 6, 16, 3, 3, 1, 1, 1), // m > 1
            ConvLayer::new("wg", 8, 13, 13, 32, 3, 3, 1, 1, 2),  // grouped
        ] {
            let mut rng = XorShift::new(77);
            let x = rng.i16_vec(l.ic * l.ih * l.iw, -500, 500);
            let w = rng.i16_vec(l.oc * (l.ic / l.groups) * l.fh * l.fw, -100, 100);
            let b = rng.i32_vec(l.oc, -100, 100);
            let opts = ExecOptions { mode: ExecMode::TileAnalytic, ..Default::default() };
            let cache = PlanCache::new();
            let mut scratch = Scratch::default();
            let mut cpu = Cpu::new(1 << 22);
            let cold = conv_layer(
                &mut cpu, &l, &x, &w, &b, opts, &mut ExecCtx::new(&cache, &mut scratch),
            )
            .unwrap();
            let mut cpu2 = Cpu::new(1 << 22);
            let hot = conv_layer(
                &mut cpu2, &l, &x, &w, &b, opts, &mut ExecCtx::new(&cache, &mut scratch),
            )
            .unwrap();
            assert_eq!(hot.cycles, cold.cycles, "{}", l.name);
            assert_eq!(hot.compute_cycles, cold.compute_cycles, "{}", l.name);
            assert_eq!(hot.dma_cycles, cold.dma_cycles, "{}", l.name);
            assert_eq!(hot.io_in, cold.io_in, "{}", l.name);
            assert_eq!(hot.io_out, cold.io_out, "{}", l.name);
            assert_eq!(hot.stats, cold.stats, "{}: stats drifted on replay", l.name);
        }
    }

    #[test]
    fn sampled_rows_match_static_prediction_per_row() {
        // Every raw row sample a cold tile-analytic pass records must
        // equal the static analyzer's prediction at that row's own ABI
        // (`CompiledConv::predict_row`) — cycles, bundles and all five
        // stall counters. The cold pass samples consecutive in-band
        // rows, so this exercises r2 values beyond row 0, where LB-fill
        // DM bank conflicts are address-dependent.
        for l in [
            ConvLayer::new("pra", 4, 24, 24, 16, 3, 3, 1, 1, 1), // variant A
            ConvLayer::new("prb", 8, 13, 13, 48, 3, 3, 1, 1, 1), // variant B
            ConvLayer::new("prm", 768, 6, 6, 16, 3, 3, 1, 1, 1), // m > 1
        ] {
            let mut rng = XorShift::new(5);
            let x = rng.i16_vec(l.ic * l.ih * l.iw, -500, 500);
            let w = rng.i16_vec(l.oc * l.ic * l.fh * l.fw, -100, 100);
            let b = rng.i32_vec(l.oc, -100, 100);
            let opts = ExecOptions { mode: ExecMode::TileAnalytic, ..Default::default() };
            let cache = PlanCache::new();
            let mut scratch = Scratch::default();
            let mut cpu = Cpu::new(1 << 22);
            conv_layer(&mut cpu, &l, &x, &w, &b, opts, &mut ExecCtx::new(&cache, &mut scratch))
                .unwrap();
            let cc = cache.conv(&l, opts.gate_bits, opts.rotation).unwrap();
            let profile = cc.analytic.get().expect("cold pass must publish a profile");
            let mut checked = 0usize;
            let mut rows_seen = std::collections::HashSet::new();
            for (key, s) in &profile.samples {
                for sample in &s.rows {
                    let got = cc.predict_row(key, sample.oh_local).unwrap_or_else(|e| {
                        panic!("{} {key:?} row {}: {e}", l.name, sample.oh_local)
                    });
                    assert_eq!(
                        (got.cycles, got.bundles, got.hazard_stalls, got.lb_stalls),
                        (
                            sample.cycles,
                            sample.stats.bundles,
                            sample.stats.hazard_stalls,
                            sample.stats.lb_stalls
                        ),
                        "{} {key:?} row {}",
                        l.name,
                        sample.oh_local
                    );
                    assert_eq!(
                        (got.branch_stalls, got.dma_wait_stalls, got.wide_ls_stalls),
                        (
                            sample.stats.branch_stalls,
                            sample.stats.dma_wait_stalls,
                            sample.stats.wide_ls_stalls
                        ),
                        "{} {key:?} row {}",
                        l.name,
                        sample.oh_local
                    );
                    rows_seen.insert(sample.oh_local);
                    checked += 1;
                }
            }
            assert!(checked > 0, "{}: no samples checked", l.name);
            assert!(
                rows_seen.len() > 1 || cc.plan.band_rows == 1,
                "{}: sampling covered only one distinct row",
                l.name
            );
        }
    }

    #[test]
    fn pool_layer_matches_reference() {
        let l = PoolLayer { name: "p", ic: 24, ih: 13, iw: 13, size: 3, stride: 2 };
        let mut rng = XorShift::new(11);
        let x = rng.i16_vec(l.ic * l.ih * l.iw, -30000, 30000);
        let mut cpu = Cpu::new(1 << 20);
        let r = run_pool(&mut cpu, &l, &x, ExecOptions::default());
        let expect = refconv::maxpool2d(&x, l.ic, l.ih, l.iw, l.size, l.stride);
        assert_eq!(r.out, expect);
    }

    #[test]
    fn dma_cycles_rounds_partial_beats_up() {
        let bus = EXT_BYTES_PER_CYCLE as u64;
        let lat = EXT_LATENCY_CYCLES;
        // no payload: only the per-request DRAM latency
        assert_eq!(dma_cycles(0, 1), lat);
        // a single byte still occupies one full bus beat
        assert_eq!(dma_cycles(1, 1), 1 + lat);
        // one beat minus a byte, exactly one beat, one beat plus a byte
        assert_eq!(dma_cycles(bus - 1, 1), 1 + lat);
        assert_eq!(dma_cycles(bus, 1), 1 + lat);
        assert_eq!(dma_cycles(bus + 1, 1), 2 + lat);
        // exact multiples gain nothing from the ceiling
        assert_eq!(dma_cycles(7 * bus, 3), 7 + 3 * lat);
        // the old truncating formula lost a cycle here
        assert_eq!(dma_cycles(7 * bus + 5, 3), 8 + 3 * lat);
        // requests scale the latency term linearly
        assert_eq!(dma_cycles(bus, 10), 1 + 10 * lat);
    }

    #[test]
    fn price_iters_is_exact_in_both_directions() {
        let iters = [
            IterRec { compute: 100, bytes: 80, reqs: 1 },
            IterRec { compute: 50, bytes: 800, reqs: 1 },
            IterRec { compute: 200, bytes: 8, reqs: 2 },
        ];
        let d: Vec<u64> = iters.iter().map(|i| dma_cycles(i.bytes, i.reqs)).collect();
        assert_eq!(d, [50, 140, 81]);
        // un-rotatable: every stream serializes against compute
        let ser = price_iters(&iters, false);
        assert_eq!(ser.cycles, 350 + 271);
        assert_eq!(ser.dma_cycles, 271);
        assert_eq!((ser.serial_bytes, ser.serial_cycles), (888, 271));
        assert_eq!((ser.fill_bytes, ser.fill_cycles), (0, 0));
        // rotated: serialized fill, then max(compute_i, dma_{i+1})
        let rot = price_iters(&iters, true);
        assert_eq!(rot.cycles, 50 + 140 + 81 + 200);
        assert_eq!(rot.dma_cycles, 271);
        assert_eq!((rot.fill_bytes, rot.fill_cycles), (80, 50));
        assert_eq!((rot.serial_bytes, rot.serial_cycles), (0, 0));
        // overlap hides latency, never bandwidth
        assert!(rot.cycles >= 350 && rot.cycles >= 271);
        assert!(rot.cycles <= ser.cycles);
        assert_eq!(price_iters(&[], true).cycles, 0);
        assert_eq!(price_iters(&[], false).cycles, 0);
    }

    #[test]
    fn rotated_conv_pays_a_fill_then_overlaps_steady_state() {
        let l = ConvLayer::new("va", 4, 24, 24, 16, 3, 3, 1, 1, 1);
        assert!(layout::plan(&l).unwrap().rot.is_some());
        let mut rng = XorShift::new(41);
        let x = rng.i16_vec(l.ic * l.ih * l.iw, -2000, 2000);
        let w = rng.i16_vec(l.oc * l.ic * 9, -256, 256);
        let b = rng.i32_vec(l.oc, -500, 500);
        let mut cpu = Cpu::new(1 << 20);
        let r = run_conv(&mut cpu, &l, &x, &w, &b, ExecOptions::default());
        assert!(r.dma_fill_bytes > 0 && r.dma_fill_cycles > 0, "fill must be strictly > 0");
        assert_eq!((r.dma_serial_bytes, r.dma_serial_cycles), (0, 0));
        // the fill is serialized ahead of compute; overlap never hides bandwidth
        assert!(r.cycles >= r.compute_cycles + r.dma_fill_cycles);
        assert!(r.cycles >= r.dma_cycles);
        // knob off: identical outputs, honestly serialized stream
        let mut cpu2 = Cpu::new(1 << 20);
        let off = ExecOptions { rotation: false, ..Default::default() };
        let ro = run_conv(&mut cpu2, &l, &x, &w, &b, off);
        assert_eq!(ro.out, r.out, "rotation may move cycles, never values");
        assert_eq!(ro.cycles, ro.compute_cycles + ro.dma_cycles);
        assert_eq!(ro.dma_serial_cycles, ro.dma_cycles);
        assert_eq!((ro.dma_fill_bytes, ro.dma_fill_cycles), (0, 0));
    }

    #[test]
    fn unrotatable_conv_serializes_its_stream() {
        // ic=1 (the slice cannot shrink) and oh=1 (the band cannot
        // shrink): the base footprint fits DM but no shadow does, so
        // the plan cannot rotate even with the knob on.
        let l = ConvLayer::new("tall", 1, 31, 350, 16, 31, 1, 1, 0, 1);
        assert!(layout::plan(&l).unwrap().rot.is_none());
        let mut rng = XorShift::new(43);
        let x = rng.i16_vec(l.ic * l.ih * l.iw, -500, 500);
        let w = rng.i16_vec(l.oc * l.ic * l.fh * l.fw, -100, 100);
        let b = rng.i32_vec(l.oc, -100, 100);
        let mut cpu = Cpu::new(1 << 22);
        let opts = ExecOptions { mode: ExecMode::TileAnalytic, ..Default::default() };
        let r = run_conv(&mut cpu, &l, &x, &w, &b, opts);
        assert!(r.dma_cycles > 0);
        assert_eq!(r.cycles, r.compute_cycles + r.dma_cycles);
        assert_eq!(r.dma_serial_cycles, r.dma_cycles);
        assert_eq!(r.dma_serial_bytes, r.io_total());
        assert_eq!((r.dma_fill_bytes, r.dma_fill_cycles), (0, 0));
    }

    #[test]
    fn pool_stream_rotates_and_fills() {
        let l = PoolLayer { name: "p", ic: 24, ih: 13, iw: 13, size: 3, stride: 2 };
        let mut rng = XorShift::new(44);
        let x = rng.i16_vec(l.ic * l.ih * l.iw, -30000, 30000);
        let mut cpu = Cpu::new(1 << 20);
        let r = run_pool(&mut cpu, &l, &x, ExecOptions::default());
        assert!(r.dma_fill_cycles > 0);
        assert_eq!((r.dma_serial_bytes, r.dma_serial_cycles), (0, 0));
        assert!(r.cycles >= r.compute_cycles + r.dma_fill_cycles);
        let mut cpu2 = Cpu::new(1 << 20);
        let off = ExecOptions { rotation: false, ..Default::default() };
        let ro = run_pool(&mut cpu2, &l, &x, off);
        assert_eq!(ro.out, r.out);
        assert_eq!(ro.cycles, ro.compute_cycles + ro.dma_cycles);
    }

    #[test]
    fn grouped_conv_slice_bookkeeping() {
        // Grouped layers run one group at a time through `run_dense`;
        // the per-group metrics must tile the full layer exactly: MACs,
        // I/O and compute cycles add up, and each group's output block
        // is bit-identical to running that group as a standalone dense
        // layer on the same slices.
        let l = ConvLayer::new("gbk", 8, 13, 13, 32, 3, 3, 1, 1, 2);
        let mut rng = XorShift::new(21);
        let x = rng.i16_vec(l.ic * l.ih * l.iw, -1500, 1500);
        let w = rng.i16_vec(l.oc * (l.ic / l.groups) * l.fh * l.fw, -200, 200);
        let b = rng.i32_vec(l.oc, -500, 500);

        let mut cpu = Cpu::new(1 << 22);
        let total = run_conv(&mut cpu, &l, &x, &w, &b, ExecOptions::default());
        assert_eq!(total.macs, l.macs(), "grouped MACs must cover the whole layer");
        assert_eq!(total.out.len(), l.oc * l.oh() * l.ow());

        let lg = l.per_group();
        let (icg, ocg) = (lg.ic, lg.oc);
        let ohw = l.oh() * l.ow();
        let mut sum = LayerResult::default();
        for gi in 0..l.groups {
            let xg = &x[gi * icg * l.ih * l.iw..(gi + 1) * icg * l.ih * l.iw];
            let wg = &w[gi * ocg * icg * l.fh * l.fw..(gi + 1) * ocg * icg * l.fh * l.fw];
            let bg = &b[gi * ocg..(gi + 1) * ocg];
            let mut c = Cpu::new(1 << 22);
            let r = run_conv(&mut c, &lg, xg, wg, bg, ExecOptions::default());
            assert_eq!(
                r.out,
                total.out[gi * ocg * ohw..(gi + 1) * ocg * ohw],
                "group {gi} output block"
            );
            sum.macs += r.macs;
            sum.compute_cycles += r.compute_cycles;
            sum.io_in += r.io_in;
            sum.io_out += r.io_out;
        }
        assert_eq!(sum.macs, total.macs);
        assert_eq!(sum.compute_cycles, total.compute_cycles);
        assert_eq!(sum.io_in, total.io_in);
        assert_eq!(sum.io_out, total.io_out);
    }

    #[test]
    fn fc_layer_matches_reference() {
        use crate::codegen::reffc;
        // even / odd feature counts, relu on / off
        for (inf, outf, relu, seed) in
            [(64usize, 48usize, true, 31u64), (37, 20, false, 32), (128, 10, true, 33)]
        {
            let mut fc = crate::model::FcLayer::new("fct", inf, outf);
            fc.relu = relu;
            let mut rng = XorShift::new(seed);
            let x = rng.i16_vec(inf, -2000, 2000);
            let w = rng.i16_vec(inf * outf, -256, 256);
            let b = rng.i32_vec(outf, -1000, 1000);
            let mut cpu = Cpu::new(1 << 20);
            let r = run_fc(&mut cpu, &fc, &x, &w, &b, ExecOptions::default());
            let expect = reffc::fc_forward(&x, &w, &b, &fc, RoundMode::HalfUp, 16);
            assert_eq!(r.out, expect, "in {inf} out {outf} relu {relu}");
            assert_eq!(r.macs, fc.macs());
            // weights dominate the off-chip traffic
            assert!(r.io_in as usize >= 2 * inf * outf, "weight stream must be counted");
        }
    }

    #[test]
    fn fc_multi_slice_psum_path_matches_reference() {
        use crate::codegen::reffc;
        // in_features large enough that the planner slices the input
        // depth (M > 1): exercises the PSum spill/reload path on the
        // 1×1 lowering
        let fc = crate::model::FcLayer::new("fcm", 2560, 16);
        let p = layout::plan(&fc.as_conv()).unwrap();
        assert!(p.m > 1, "expected multiple slices, got m={}", p.m);
        let mut rng = XorShift::new(34);
        let x = rng.i16_vec(fc.in_features, -2000, 2000);
        let w = rng.i16_vec(fc.in_features * fc.out_features, -128, 128);
        let b = rng.i32_vec(fc.out_features, -1000, 1000);
        let mut cpu = Cpu::new(1 << 22);
        let r = run_fc(&mut cpu, &fc, &x, &w, &b, ExecOptions::default());
        let expect = reffc::fc_forward(&x, &w, &b, &fc, RoundMode::HalfUp, 16);
        assert_eq!(r.out, expect);
    }

    #[test]
    fn gated_precision_changes_output() {
        let l = ConvLayer::new("g8", 4, 10, 10, 16, 3, 3, 1, 1, 1);
        let mut rng = XorShift::new(12);
        let x = rng.i16_vec(l.ic * 100, -2000, 2000);
        let w = rng.i16_vec(16 * 4 * 9, -256, 256);
        let b = rng.i32_vec(16, -100, 100);
        let mut cpu = Cpu::new(1 << 20);
        let opts8 = ExecOptions { mode: ExecMode::FullCycle, gate_bits: 8, ..Default::default() };
        let r8 = run_conv(&mut cpu, &l, &x, &w, &b, opts8);
        let expect = refconv::conv2d_grouped(&x, &w, &b, &l, RoundMode::HalfUp, 8);
        assert_eq!(r8.out, expect);
        assert!(r8.stats.mac_ops_gated8 > 0);
    }
}
