//! Layer executor: Fig. 2 scheduling of conv / pool / FC layers onto
//! **one** core. The crate-internal `conv_layer` / `pool_layer` /
//! `fc_layer` are the primitives everything funnels into, behind the
//! [`LayerOp`](super::ops::LayerOp) trait — use
//! [`crate::coordinator::Engine`] to run them. (The 0.2 free-function
//! shims were removed in 0.4.0.)

use std::collections::HashMap;

use crate::codegen::conv::{build_conv_task, TaskFlavor};
use crate::codegen::layout::{self, ConvPlan, LoopOrder, Variant};
use crate::codegen::pool::{build_pool_task, plan_pool};
use crate::codegen::stage;
use crate::core::{CoreStats, Cpu, SimError};
use crate::isa::SReg;
use crate::mem::{EXT_BYTES_PER_CYCLE, EXT_LATENCY_CYCLES};
use crate::model::{ConvLayer, FcLayer, PoolLayer};

// The layer-descriptor enum moved to the model in 0.4.0; re-exported
// here so `coordinator::NetLayer` keeps working.
pub use crate::model::NetLayer;

use super::metrics::{add_stats, div_stats, scale_stats, LayerResult};

/// Execution mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// Cycle-simulate every task; outputs are produced and exact.
    FullCycle,
    /// Cycle-simulate one task per distinct (flavor, slice size) and
    /// compose analytically (row tasks are cycle-identical by
    /// construction). ~1000× faster; no outputs. Validated against
    /// FullCycle by tests and `benches/ablation`.
    TileAnalytic,
}

#[derive(Debug, Clone, Copy)]
pub struct ExecOptions {
    pub mode: ExecMode,
    /// Precision gating (16 = off, 8 = the paper's gated AlexNet run).
    pub gate_bits: u8,
    /// Number of ConvAix cores a layer may be sharded across (1 = the
    /// paper's single-core latency setup). The single-layer executors
    /// in this module ignore it; it is consumed by
    /// [`crate::coordinator::engine`].
    pub cores: usize,
    /// Frames per batched run (1 = latency mode). Ignored by the
    /// single-layer executors.
    pub batch: usize,
}

impl Default for ExecOptions {
    fn default() -> Self {
        Self { mode: ExecMode::FullCycle, gate_bits: 16, cores: 1, batch: 1 }
    }
}

#[derive(Debug)]
pub enum ExecError {
    Codegen(crate::codegen::CodegenError),
    Sim(SimError),
}

impl std::fmt::Display for ExecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecError::Codegen(e) => write!(f, "codegen: {e}"),
            ExecError::Sim(e) => write!(f, "sim: {e}"),
        }
    }
}

impl std::error::Error for ExecError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ExecError::Codegen(e) => Some(e),
            ExecError::Sim(e) => Some(e),
        }
    }
}

impl From<crate::codegen::CodegenError> for ExecError {
    fn from(e: crate::codegen::CodegenError) -> Self {
        ExecError::Codegen(e)
    }
}

impl From<SimError> for ExecError {
    fn from(e: SimError) -> Self {
        ExecError::Sim(e)
    }
}

/// Analytic DMA time for moving `bytes` with `requests` descriptors.
///
/// The transfer term rounds **up**: a trailing partial bus beat still
/// occupies a full cycle on the `EXT_BYTES_PER_CYCLE`-wide external bus.
/// (Truncating here undercounted every DMA-bound segment whose size is
/// not a multiple of the bus width, inflating reported GOP/s and
/// utilization.)
pub(crate) fn dma_cycles(bytes: u64, requests: u64) -> u64 {
    bytes.div_ceil(EXT_BYTES_PER_CYCLE as u64) + requests * EXT_LATENCY_CYCLES
}

/// Run a (possibly grouped) conv layer. `x`: (ic, ih, iw), `w`:
/// (oc, ic/groups, fh, fw), `b`: (oc,). Returns metrics and (in
/// FullCycle mode) the output tensor (oc, oh, ow).
pub(crate) fn conv_layer(
    cpu: &mut Cpu,
    layer: &ConvLayer,
    x: &[i16],
    w: &[i16],
    b: &[i32],
    opts: ExecOptions,
) -> Result<LayerResult, ExecError> {
    let g = layer.groups;
    if g == 1 {
        return run_dense(cpu, layer, x, w, b, opts);
    }
    let lg = layer.per_group();
    let (icg, ocg) = (lg.ic, lg.oc);
    let ohw = layer.oh() * layer.ow();
    let mut total = LayerResult { name: layer.name.to_string(), ..Default::default() };
    let mut out = vec![0i16; layer.oc * ohw];
    for gi in 0..g {
        let xg = &x[gi * icg * layer.ih * layer.iw..(gi + 1) * icg * layer.ih * layer.iw];
        let wg = &w[gi * ocg * icg * layer.fh * layer.fw..(gi + 1) * ocg * icg * layer.fh * layer.fw];
        let bg = &b[gi * ocg..(gi + 1) * ocg];
        let r = run_dense(cpu, &lg, xg, wg, bg, opts)?;
        if !r.out.is_empty() {
            out[gi * ocg * ohw..(gi + 1) * ocg * ohw].copy_from_slice(&r.out);
        }
        total.cycles += r.cycles;
        total.compute_cycles += r.compute_cycles;
        total.dma_cycles += r.dma_cycles;
        total.macs += r.macs;
        total.io_in += r.io_in;
        total.io_out += r.io_out;
        total.stats = add_stats(&total.stats, &r.stats);
    }
    if opts.mode == ExecMode::FullCycle {
        total.out = out;
    }
    Ok(total)
}

fn run_dense(
    cpu: &mut Cpu,
    layer: &ConvLayer,
    x: &[i16],
    w: &[i16],
    b: &[i32],
    opts: ExecOptions,
) -> Result<LayerResult, ExecError> {
    let plan = layout::plan(layer)?;
    let xp = stage::pad_input(layer, x);
    let (oh, ow) = (layer.oh(), layer.ow());
    let ocs = plan.variant.ocs();

    // gate-bits override: patch the CSR after program setup by setting
    // it in the Cpu directly before each run (the program writes
    // frac_shift/lb_stride; gate_bits persists).
    cpu.csr.gate_bits = opts.gate_bits;

    // task programs per (slice_ics, flavor)
    let mut programs: HashMap<(usize, bool, bool), crate::mem::pm::ProgramMem> = HashMap::new();
    for mi in 0..plan.m {
        let f = flavor_of(mi, plan.m);
        let key = (plan.slice_ics(mi), f.first_slice, f.last_slice);
        if !programs.contains_key(&key) {
            programs.insert(key, build_conv_task(&plan, key.0, f)?);
        }
    }

    let mut res = LayerResult {
        name: layer.name.to_string(),
        macs: layer.macs(),
        ..Default::default()
    };
    let mut out = vec![0i16; layer.oc * oh * ow];
    // PSum shadow (host side) per (tile, row) — the off-chip buffer of
    // Fig. 2 step 2 when M > 1.
    let mut psum: Vec<Vec<i32>> = Vec::new();
    if plan.m > 1 {
        psum = vec![Vec::new(); plan.n_tiles * oh];
    }

    // analytic cache: (slice_ics, first, last) -> sampled rows (count,
    // total cycles, accumulated stats). Rows are cycle-identical modulo
    // DM bank-conflict noise, so a 4-row sample mean is within ~1 %.
    let mut analytic: HashMap<(usize, bool, bool), (u64, u64, CoreStats)> = HashMap::new();
    const ANALYTIC_SAMPLES: u64 = 4;

    // I/O accounting per plan.loop_order (DESIGN.md §6 ablation).
    // Ring accounting: within one streaming pass over a slice, band
    // overlap rows stay in the DM ring — only *new* rows are fetched.
    let filt_bytes =
        |mi: usize| ((plan.slice_ics(mi) * layer.fh * layer.fw + 2) * 32 + 32) as u64;
    let band_in_bytes = |mi: usize, bi: usize| -> u64 {
        let rows = if bi == 0 {
            plan.in_rows_band
        } else {
            (plan.band_rows_of(bi) * layer.stride).min(plan.in_rows_band)
        };
        (plan.slice_ics(mi) * rows * plan.row_bytes) as u64
    };
    let out_row_bytes = match plan.variant {
        Variant::A => (ow * 32) as u64,
        Variant::B => (ow * 2 * ocs) as u64,
    };
    let psum_row_bytes = (plan.g * 12 * 64) as u64;

    let band_outer = plan.loop_order == LoopOrder::BandOuter;

    let run_row =
        |cpu: &mut Cpu,
         res: &mut LayerResult,
         analytic: &mut HashMap<(usize, bool, bool), (u64, u64, CoreStats)>,
         psum: &mut Vec<Vec<i32>>,
         out: &mut Vec<i16>,
         tile: usize,
         mi: usize,
         oh_local: usize,
         oh_abs: usize|
         -> Result<(), ExecError> {
            let f = flavor_of(mi, plan.m);
            let key = (plan.slice_ics(mi), f.first_slice, f.last_slice);
            // psum I/O + staging (values only matter in FullCycle mode)
            if plan.m > 1 && !f.first_slice {
                if opts.mode == ExecMode::FullCycle {
                    let pv = &psum[tile * oh + oh_abs];
                    stage::write_psum_row(&plan, &mut cpu.mem.dm, pv);
                }
                res.io_in += psum_row_bytes;
            }
            let analytic_hit = opts.mode == ExecMode::TileAnalytic
                && analytic.get(&key).is_some_and(|(n, _, _)| *n >= ANALYTIC_SAMPLES);
            if !analytic_hit {
                // ABI registers
                cpu.regs.set_r(SReg(2), (plan.dm.input + oh_local * layer.stride * plan.row_bytes) as i32);
                cpu.regs.set_r(SReg(4), plan.dm.out as i32);
                cpu.regs.set_r(SReg(5), plan.dm.psum as i32);
                cpu.regs.set_r(SReg(6), plan.dm.filt as i32);
                let pm = &programs[&key];
                let stats = cpu.run(pm)?;
                cpu.csr.gate_bits = opts.gate_bits; // program may not touch it
                res.compute_cycles += stats.cycles;
                if opts.mode == ExecMode::TileAnalytic {
                    let e = analytic.entry(key).or_insert((0, 0, CoreStats::default()));
                    e.0 += 1;
                    e.1 += stats.cycles;
                    e.2 = add_stats(&e.2, &stats);
                }
                res.stats = add_stats(&res.stats, &stats);
            } else {
                let (n, cyc, stats) = &analytic[&key];
                res.compute_cycles += cyc / n;
                res.stats = add_stats(&res.stats, &scale_stats(&div_stats(stats, *n), 1));
            }
            // collect outputs / psums
            if opts.mode == ExecMode::FullCycle {
                if f.last_slice {
                    let row = stage::read_out_row(&plan, &cpu.mem.dm, ow);
                    for ocl in 0..ocs {
                        let oc = tile * ocs + ocl;
                        if oc < layer.oc {
                            out[(oc * oh + oh_abs) * ow..(oc * oh + oh_abs) * ow + ow]
                                .copy_from_slice(&row[ocl * ow..(ocl + 1) * ow]);
                        }
                    }
                } else {
                    psum[tile * oh + oh_abs] = stage::read_psum_row(&plan, &cpu.mem.dm);
                }
            }
            if plan.m > 1 && !f.last_slice {
                res.io_out += psum_row_bytes;
            }
            if f.last_slice {
                res.io_out += out_row_bytes;
            }
            Ok(())
        };

    if band_outer {
        // input streamed once per slice; filters re-loaded per band
        for mi in 0..plan.m {
            for bi in 0..plan.n_bands {
                let oh0 = bi * plan.band_rows;
                let band = stage::input_band(&plan, &xp, mi, oh0);
                stage::poke(&mut cpu.mem.dm, plan.dm.input, &band);
                res.io_in += band_in_bytes(mi, bi);
                for tile in 0..plan.n_tiles {
                    stage_filters(cpu, &plan, w, b, tile, mi);
                    res.io_in += filt_bytes(mi);
                    for r in 0..plan.band_rows_of(bi) {
                        run_row(cpu, &mut res, &mut analytic, &mut psum, &mut out, tile, mi, r, oh0 + r)?;
                    }
                }
            }
        }
    } else {
        // filters loaded once per (tile, slice); input re-streamed per tile
        for tile in 0..plan.n_tiles {
            for mi in 0..plan.m {
                stage_filters(cpu, &plan, w, b, tile, mi);
                res.io_in += filt_bytes(mi);
                for bi in 0..plan.n_bands {
                    let oh0 = bi * plan.band_rows;
                    let band = stage::input_band(&plan, &xp, mi, oh0);
                    stage::poke(&mut cpu.mem.dm, plan.dm.input, &band);
                    res.io_in += band_in_bytes(mi, bi);
                    for r in 0..plan.band_rows_of(bi) {
                        run_row(cpu, &mut res, &mut analytic, &mut psum, &mut out, tile, mi, r, oh0 + r)?;
                    }
                }
            }
        }
    }

    // Precision-gated off-chip transfers are packed: at <=8 effective
    // bits, tensors move at 1 byte/element (Table II footnote: values
    // are reported "with optimized word width").
    if opts.gate_bits <= 8 {
        res.io_in /= 2;
        res.io_out /= 2;
    }
    // DMA overlap: one double-buffered stream alongside compute.
    let reqs = (plan.n_tiles * plan.m * plan.n_bands) as u64 + plan.n_tiles as u64;
    res.dma_cycles = dma_cycles(res.io_in + res.io_out, reqs);
    res.cycles = res.compute_cycles.max(res.dma_cycles);
    if opts.mode == ExecMode::FullCycle {
        res.out = out;
    }
    Ok(res)
}

fn flavor_of(mi: usize, m: usize) -> TaskFlavor {
    TaskFlavor { first_slice: mi == 0, last_slice: mi + 1 == m }
}

fn stage_filters(cpu: &mut Cpu, plan: &ConvPlan, w: &[i16], b: &[i32], tile: usize, mi: usize) {
    let bias = stage::bias_vector(plan, b, tile);
    stage::poke(&mut cpu.mem.dm, plan.dm.bias, &bias);
    let fs = stage::filter_stream(plan, w, tile, mi);
    stage::poke(&mut cpu.mem.dm, plan.dm.filt, &fs);
}

/// Run a max-pool layer. Input `x`: (ic, ih, iw). Output (ic, oh, ow).
pub(crate) fn pool_layer(
    cpu: &mut Cpu,
    layer: &PoolLayer,
    x: &[i16],
    opts: ExecOptions,
) -> Result<LayerResult, ExecError> {
    let one_row = PoolLayer { ih: layer.size, ..layer.clone() };
    let plan = plan_pool(&one_row)?;
    let pm = build_pool_task(&plan)?;
    let (oh, ow) = (layer.oh(), layer.ow());
    let mut res = LayerResult { name: layer.name.to_string(), ..Default::default() };
    let mut out = vec![0i16; layer.ic * oh * ow];
    let n_tiles = layer.ic.div_ceil(16);
    let mut analytic: Option<(u64, CoreStats)> = None;

    for tile in 0..n_tiles {
        for oy in 0..oh {
            if opts.mode == ExecMode::TileAnalytic {
                if let Some((cyc, stats)) = &analytic {
                    res.compute_cycles += cyc;
                    res.stats = add_stats(&res.stats, stats);
                    continue;
                }
            }
            // stage `size` input rows as pixel-major 16-ch vectors
            for r in 0..layer.size {
                let y = oy * layer.stride + r;
                for px in 0..layer.iw {
                    let v: Vec<i16> = (0..16)
                        .map(|cl| {
                            let c = tile * 16 + cl;
                            if c < layer.ic {
                                x[(c * layer.ih + y) * layer.iw + px]
                            } else {
                                0
                            }
                        })
                        .collect();
                    cpu.mem
                        .dm
                        .poke_i16_slice(plan.dm_input + r * plan.in_row_bytes + px * 32, &v);
                }
            }
            cpu.regs.set_r(SReg(2), plan.dm_input as i32);
            cpu.regs.set_r(SReg(4), plan.dm_out as i32);
            let stats = cpu.run(&pm)?;
            res.compute_cycles += stats.cycles;
            if opts.mode == ExecMode::TileAnalytic {
                analytic = Some((stats.cycles, stats.clone()));
            }
            res.stats = add_stats(&res.stats, &stats);
            if opts.mode == ExecMode::FullCycle {
                for px in 0..ow {
                    let v = cpu.mem.dm.peek_i16_slice(plan.dm_out + px * 32, 16);
                    for cl in 0..16 {
                        let c = tile * 16 + cl;
                        if c < layer.ic {
                            out[(c * oh + oy) * ow + px] = v[cl];
                        }
                    }
                }
            }
        }
    }
    // I/O: rows in (with window overlap), rows out
    res.io_in = (n_tiles * oh * layer.size * layer.iw * 32) as u64;
    res.io_out = (n_tiles * oh * ow * 32) as u64;
    res.dma_cycles = dma_cycles(res.io_in + res.io_out, (n_tiles * oh) as u64);
    res.cycles = res.compute_cycles.max(res.dma_cycles);
    if opts.mode == ExecMode::FullCycle {
        res.out = out;
    }
    Ok(res)
}

/// Run a fully connected layer: `y = act(W·x + b)` lowered onto the
/// conv dataflow as a 1×1 convolution over a 1×1 map
/// ([`FcLayer::as_conv`]) — input features stream through the filter
/// FIFO as depth slices, output neurons ride the oc-tile machinery.
/// `x`: (in_features,), `w`: (out_features, in_features), `b`:
/// (out_features,). The lowering is bit-exact against the host
/// reference (`codegen::reffc`) because the weight layouts coincide.
pub(crate) fn fc_layer(
    cpu: &mut Cpu,
    layer: &FcLayer,
    x: &[i16],
    w: &[i16],
    b: &[i32],
    opts: ExecOptions,
) -> Result<LayerResult, ExecError> {
    conv_layer(cpu, &layer.as_conv(), x, w, b, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::refconv;
    use crate::fixed::RoundMode;
    use crate::util::XorShift;

    fn check_layer(l: &ConvLayer, seed: u64) {
        let mut rng = XorShift::new(seed);
        let x = rng.i16_vec(l.ic * l.ih * l.iw, -2000, 2000);
        let w = rng.i16_vec(l.oc * (l.ic / l.groups) * l.fh * l.fw, -256, 256);
        let b = rng.i32_vec(l.oc, -2000, 2000);
        let mut cpu = Cpu::new(1 << 20);
        let r = conv_layer(&mut cpu, l, &x, &w, &b, ExecOptions::default()).unwrap();
        let expect = refconv::conv2d_grouped(&x, &w, &b, l, RoundMode::HalfUp, 16);
        assert_eq!(r.out.len(), expect.len(), "{}", l.name);
        for (i, (got, want)) in r.out.iter().zip(&expect).enumerate() {
            assert_eq!(got, want, "{}: idx {i}", l.name);
        }
        assert!(r.utilization() > 0.1, "{}: util {}", l.name, r.utilization());
    }

    #[test]
    fn small_conv_variant_a_matches_reference() {
        // ow=24 -> G=2 full groups under variant A
        let l = ConvLayer::new("va", 4, 24, 24, 16, 3, 3, 1, 1, 1);
        let p = layout::plan(&l).unwrap();
        assert_eq!(p.variant, Variant::A);
        check_layer(&l, 1);
    }

    #[test]
    fn small_conv_variant_b_matches_reference() {
        // small ow + many oc -> variant B
        let l = ConvLayer::new("vb", 8, 13, 13, 48, 3, 3, 1, 1, 1);
        let p = layout::plan(&l).unwrap();
        assert_eq!(p.variant, Variant::B);
        check_layer(&l, 2);
    }

    #[test]
    fn strided_conv_matches_reference() {
        let l = ConvLayer::new("s2", 3, 23, 23, 16, 5, 5, 2, 2, 1);
        check_layer(&l, 3);
    }

    #[test]
    fn conv1_like_unfused_rows() {
        // stride 4, 11x11: per-fy line loads
        let l = ConvLayer::new("c1", 3, 43, 43, 16, 11, 11, 4, 0, 1);
        let p = layout::plan(&l).unwrap();
        assert!(!p.fused_rows);
        check_layer(&l, 4);
    }

    #[test]
    fn grouped_conv_matches_reference() {
        let l = ConvLayer::new("grp", 8, 13, 13, 32, 3, 3, 1, 1, 2);
        check_layer(&l, 5);
    }

    #[test]
    fn multi_slice_psum_path_matches_reference() {
        // force M > 1 by exceeding the DM filter budget: ic large
        let l = ConvLayer::new("ms", 768, 6, 6, 16, 3, 3, 1, 1, 1);
        let p = layout::plan(&l).unwrap();
        assert!(p.m > 1, "expected multiple slices, got m={}", p.m);
        check_layer(&l, 6);
    }

    #[test]
    fn odd_ic_tail_matches_reference() {
        let l = ConvLayer::new("odd", 5, 10, 10, 16, 3, 3, 1, 1, 1);
        check_layer(&l, 7);
    }

    #[test]
    fn non_multiple_oc_padding() {
        let l = ConvLayer::new("ocp", 4, 10, 10, 24, 3, 3, 1, 0, 1);
        check_layer(&l, 8);
    }

    #[test]
    fn relu_off_layer() {
        let mut l = ConvLayer::new("nr", 4, 8, 8, 16, 3, 3, 1, 1, 1);
        l.relu = false;
        check_layer(&l, 9);
    }

    #[test]
    fn analytic_matches_full_cycle_time() {
        let l = ConvLayer::new("an", 8, 16, 16, 32, 3, 3, 1, 1, 1);
        let mut rng = XorShift::new(10);
        let x = rng.i16_vec(l.ic * l.ih * l.iw, -500, 500);
        let w = rng.i16_vec(l.oc * l.ic * 9, -100, 100);
        let b = rng.i32_vec(l.oc, -100, 100);
        let mut cpu = Cpu::new(1 << 20);
        let full = conv_layer(&mut cpu, &l, &x, &w, &b, ExecOptions::default()).unwrap();
        let mut cpu2 = Cpu::new(1 << 20);
        let fast = conv_layer(
            &mut cpu2,
            &l,
            &x,
            &w,
            &b,
            ExecOptions { mode: ExecMode::TileAnalytic, ..Default::default() },
        )
        .unwrap();
        let err = (full.cycles as f64 - fast.cycles as f64).abs() / full.cycles as f64;
        assert!(err < 0.01, "analytic vs full: {} vs {}", fast.cycles, full.cycles);
        assert_eq!(full.io_total(), fast.io_total());
    }

    #[test]
    fn pool_layer_matches_reference() {
        let l = PoolLayer { name: "p", ic: 24, ih: 13, iw: 13, size: 3, stride: 2 };
        let mut rng = XorShift::new(11);
        let x = rng.i16_vec(l.ic * l.ih * l.iw, -30000, 30000);
        let mut cpu = Cpu::new(1 << 20);
        let r = pool_layer(&mut cpu, &l, &x, ExecOptions::default()).unwrap();
        let expect = refconv::maxpool2d(&x, l.ic, l.ih, l.iw, l.size, l.stride);
        assert_eq!(r.out, expect);
    }

    #[test]
    fn dma_cycles_rounds_partial_beats_up() {
        let bus = EXT_BYTES_PER_CYCLE as u64;
        let lat = EXT_LATENCY_CYCLES;
        // no payload: only the per-request DRAM latency
        assert_eq!(dma_cycles(0, 1), lat);
        // a single byte still occupies one full bus beat
        assert_eq!(dma_cycles(1, 1), 1 + lat);
        // one beat minus a byte, exactly one beat, one beat plus a byte
        assert_eq!(dma_cycles(bus - 1, 1), 1 + lat);
        assert_eq!(dma_cycles(bus, 1), 1 + lat);
        assert_eq!(dma_cycles(bus + 1, 1), 2 + lat);
        // exact multiples gain nothing from the ceiling
        assert_eq!(dma_cycles(7 * bus, 3), 7 + 3 * lat);
        // the old truncating formula lost a cycle here
        assert_eq!(dma_cycles(7 * bus + 5, 3), 8 + 3 * lat);
        // requests scale the latency term linearly
        assert_eq!(dma_cycles(bus, 10), 1 + 10 * lat);
    }

    #[test]
    fn grouped_conv_slice_bookkeeping() {
        // Grouped layers run one group at a time through `run_dense`;
        // the per-group metrics must tile the full layer exactly: MACs,
        // I/O and compute cycles add up, and each group's output block
        // is bit-identical to running that group as a standalone dense
        // layer on the same slices.
        let l = ConvLayer::new("gbk", 8, 13, 13, 32, 3, 3, 1, 1, 2);
        let mut rng = XorShift::new(21);
        let x = rng.i16_vec(l.ic * l.ih * l.iw, -1500, 1500);
        let w = rng.i16_vec(l.oc * (l.ic / l.groups) * l.fh * l.fw, -200, 200);
        let b = rng.i32_vec(l.oc, -500, 500);

        let mut cpu = Cpu::new(1 << 22);
        let total = conv_layer(&mut cpu, &l, &x, &w, &b, ExecOptions::default()).unwrap();
        assert_eq!(total.macs, l.macs(), "grouped MACs must cover the whole layer");
        assert_eq!(total.out.len(), l.oc * l.oh() * l.ow());

        let lg = l.per_group();
        let (icg, ocg) = (lg.ic, lg.oc);
        let ohw = l.oh() * l.ow();
        let mut sum = LayerResult::default();
        for gi in 0..l.groups {
            let xg = &x[gi * icg * l.ih * l.iw..(gi + 1) * icg * l.ih * l.iw];
            let wg = &w[gi * ocg * icg * l.fh * l.fw..(gi + 1) * ocg * icg * l.fh * l.fw];
            let bg = &b[gi * ocg..(gi + 1) * ocg];
            let mut c = Cpu::new(1 << 22);
            let r = conv_layer(&mut c, &lg, xg, wg, bg, ExecOptions::default()).unwrap();
            assert_eq!(
                r.out,
                total.out[gi * ocg * ohw..(gi + 1) * ocg * ohw],
                "group {gi} output block"
            );
            sum.macs += r.macs;
            sum.compute_cycles += r.compute_cycles;
            sum.io_in += r.io_in;
            sum.io_out += r.io_out;
        }
        assert_eq!(sum.macs, total.macs);
        assert_eq!(sum.compute_cycles, total.compute_cycles);
        assert_eq!(sum.io_in, total.io_in);
        assert_eq!(sum.io_out, total.io_out);
    }

    #[test]
    fn fc_layer_matches_reference() {
        use crate::codegen::reffc;
        // even / odd feature counts, relu on / off
        for (inf, outf, relu, seed) in
            [(64usize, 48usize, true, 31u64), (37, 20, false, 32), (128, 10, true, 33)]
        {
            let mut fc = crate::model::FcLayer::new("fct", inf, outf);
            fc.relu = relu;
            let mut rng = XorShift::new(seed);
            let x = rng.i16_vec(inf, -2000, 2000);
            let w = rng.i16_vec(inf * outf, -256, 256);
            let b = rng.i32_vec(outf, -1000, 1000);
            let mut cpu = Cpu::new(1 << 20);
            let r = fc_layer(&mut cpu, &fc, &x, &w, &b, ExecOptions::default()).unwrap();
            let expect = reffc::fc_forward(&x, &w, &b, &fc, RoundMode::HalfUp, 16);
            assert_eq!(r.out, expect, "in {inf} out {outf} relu {relu}");
            assert_eq!(r.macs, fc.macs());
            // weights dominate the off-chip traffic
            assert!(r.io_in as usize >= 2 * inf * outf, "weight stream must be counted");
        }
    }

    #[test]
    fn fc_multi_slice_psum_path_matches_reference() {
        use crate::codegen::reffc;
        // in_features large enough that the planner slices the input
        // depth (M > 1): exercises the PSum spill/reload path on the
        // 1×1 lowering
        let fc = crate::model::FcLayer::new("fcm", 2560, 16);
        let p = layout::plan(&fc.as_conv()).unwrap();
        assert!(p.m > 1, "expected multiple slices, got m={}", p.m);
        let mut rng = XorShift::new(34);
        let x = rng.i16_vec(fc.in_features, -2000, 2000);
        let w = rng.i16_vec(fc.in_features * fc.out_features, -128, 128);
        let b = rng.i32_vec(fc.out_features, -1000, 1000);
        let mut cpu = Cpu::new(1 << 22);
        let r = fc_layer(&mut cpu, &fc, &x, &w, &b, ExecOptions::default()).unwrap();
        let expect = reffc::fc_forward(&x, &w, &b, &fc, RoundMode::HalfUp, 16);
        assert_eq!(r.out, expect);
    }

    #[test]
    fn gated_precision_changes_output() {
        let l = ConvLayer::new("g8", 4, 10, 10, 16, 3, 3, 1, 1, 1);
        let mut rng = XorShift::new(12);
        let x = rng.i16_vec(l.ic * 100, -2000, 2000);
        let w = rng.i16_vec(16 * 4 * 9, -256, 256);
        let b = rng.i32_vec(16, -100, 100);
        let mut cpu = Cpu::new(1 << 20);
        let opts8 = ExecOptions { mode: ExecMode::FullCycle, gate_bits: 8, ..Default::default() };
        let r8 = conv_layer(&mut cpu, &l, &x, &w, &b, opts8).unwrap();
        let expect = refconv::conv2d_grouped(&x, &w, &b, &l, RoundMode::HalfUp, 8);
        assert_eq!(r8.out, expect);
        assert!(r8.stats.mac_ops_gated8 > 0);
    }
}
