//! Seeded, deterministic fault injection and detect/retry/degrade
//! recovery for the execution stack.
//!
//! The paper's 497 GOP/s/W story assumes a perfect substrate; dense
//! 28 nm SRAM and a single-channel LPDDR interface are not one. This
//! module prices the gap. A [`FaultPlan`] injects the three soft-error
//! classes such a part actually suffers — DM word bit-flips in staged
//! tensors, corrupted/dropped DMA transfers, and core hang/fail events
//! — at sites keyed by `(frame, layer, core)` through one xorshift
//! draw per site, so a campaign replays **bit-identically** for a
//! given seed regardless of host threading or shard policy.
//!
//! Detection is *priced, not free*: every layer attempt pays a
//! checksum pass over its off-chip stream
//! ([`checksum_cycles`] — the verification unit folds
//! [`CHECKSUM_BEATS_PER_CYCLE`](crate::mem::CHECKSUM_BEATS_PER_CYCLE)
//! bus beats per cycle plus one DRAM-latency flush for the
//! compare/ack), shard outputs carry FNV checksums that `merge_shards`
//! cross-checks at the hand-off, and a watchdog bounds a layer's
//! simulated cycles at the static analyzer's exact prediction plus a
//! margin ([`watchdog_bound`] — the tile-analytic cycle count *is*
//! `predict.rs`'s static timing, pinned exact by
//! `tests/static_analysis.rs`, so the bound is honest rather than a
//! tuned constant).
//!
//! Recovery is bounded re-execution: a detected transient fault costs
//! one retry (the wasted attempt plus the re-staged transfer, charged
//! into [`LayerResult::fault_retries`] /
//! [`LayerResult::fault_recovery_cycles`] and the layer's `cycles`,
//! from where it flows through `merge_shards` and the bus segment
//! decomposition unchanged — recovery time rides in the segment's
//! `part` term, i.e. serialized on the affected core, never scaled by
//! the shared-bus divisor). A core whose faults persist past the
//! [`FaultPlan::retry_budget`] raises
//! [`ExecError::CoreFailure`]; the engine blacklists it and re-runs
//! the shard assignment / stage partition-DP over the surviving cores,
//! charging the exhausted attempts' watchdog-bounded waste into the
//! run's [`FaultReport`] and makespan.
//!
//! **Determinism contract**: with detection enabled, every recovered
//! run's outputs are bit-identical to the fault-free run — the faulted
//! attempt is discarded and the retry re-executes the same
//! deterministic computation, so transparency holds *by construction*
//! (and is locked across shard policies × buses × modes by
//! `tests/fault_recovery.rs`). With detection disabled the injector
//! corrupts the real output tensor and charges nothing — the
//! measurably-wrong baseline that proves the injector is live.

use crate::mem::{CHECKSUM_BEATS_PER_CYCLE, EXT_BYTES_PER_CYCLE, EXT_LATENCY_CYCLES};
use crate::util::XorShift;

use super::executor::{dma_cycles, ExecError};
use super::metrics::LayerResult;

/// One injected fault class. `CoreFail` is persistent (the site keeps
/// failing across retries, exhausting the budget); the others are
/// transient (one clean retry recovers).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// A DM word bit-flip in a staged tensor: the attempt completes but
    /// its output is wrong — caught by the output checksum, recovered
    /// by re-staging the input and re-running the layer.
    BitFlip,
    /// A corrupted DMA transfer: caught in flight by the per-transfer
    /// checksum, recovered by re-issuing the stream.
    DmaCorrupt,
    /// A dropped DMA transfer: noticed at the descriptor timeout,
    /// recovered by re-issuing (timeout + retransfer latency).
    DmaDrop,
    /// A hung core: caught when the layer exceeds its watchdog bound,
    /// recovered by resetting and re-running.
    CoreHang,
    /// A persistently failing core: every retry fails; the budget
    /// exhausts and the engine degrades around the core.
    CoreFail,
}

/// Every kind, in the deterministic pick order of [`FaultPlan::draw`].
pub const ALL_KINDS: [FaultKind; 5] = [
    FaultKind::BitFlip,
    FaultKind::DmaCorrupt,
    FaultKind::DmaDrop,
    FaultKind::CoreHang,
    FaultKind::CoreFail,
];

impl FaultKind {
    /// Bit in a [`FaultPlan::kinds`] mask.
    pub fn mask(self) -> u8 {
        match self {
            FaultKind::BitFlip => 0b0_0001,
            FaultKind::DmaCorrupt => 0b0_0010,
            FaultKind::DmaDrop => 0b0_0100,
            FaultKind::CoreHang => 0b0_1000,
            FaultKind::CoreFail => 0b1_0000,
        }
    }
}

/// Default kind mask: every transient kind. `CoreFail` is opt-in —
/// a default campaign should stress recovery, not demand spare cores.
pub const TRANSIENT_KINDS: u8 = 0b0_1111;

/// A seeded fault-injection campaign: which faults hit which
/// `(frame, layer, core)` sites, whether detection/recovery runs, and
/// how many retries a core gets before it is written off.
///
/// `Copy` so it rides inside the engine's `RunSpec` for free.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultPlan {
    /// Campaign seed: the per-site draw is
    /// `XorShift(mix(seed, frame, layer, core))`, so two runs with the
    /// same seed inject the exact same faults at the exact same sites.
    pub seed: u64,
    /// Per-site fault probability in parts per million (0..=1_000_000).
    pub rate_ppm: u32,
    /// Enabled [`FaultKind`] mask (see [`FaultKind::mask`]).
    pub kinds: u8,
    /// Detection + recovery on (the default). When `false`, injected
    /// faults silently corrupt the output tensor and charge nothing —
    /// the honest "no protection" baseline.
    pub detect: bool,
    /// Retries a core gets per layer before [`ExecError::CoreFailure`]
    /// blacklists it. Transient faults need 1; a budget of 0 makes any
    /// detected fault fatal for its core.
    pub retry_budget: u32,
}

impl FaultPlan {
    /// A detection-on campaign over the transient kinds at `rate`
    /// (fraction of sites, clamped to [0, 1]).
    pub fn new(seed: u64, rate: f64) -> Self {
        Self {
            seed,
            rate_ppm: (rate.clamp(0.0, 1.0) * 1e6) as u32,
            kinds: TRANSIENT_KINDS,
            detect: true,
            retry_budget: 3,
        }
    }

    /// Replace the kind mask.
    pub fn kinds(mut self, mask: u8) -> Self {
        self.kinds = mask;
        self
    }

    /// Enable/disable detection + recovery.
    pub fn detect(mut self, on: bool) -> Self {
        self.detect = on;
        self
    }

    /// Replace the per-core retry budget.
    pub fn retry_budget(mut self, n: u32) -> Self {
        self.retry_budget = n;
        self
    }

    /// The deterministic site draw: `None` (no fault) or the kind
    /// injected at `(frame, layer, core)`. Pure in the plan and the
    /// site key — host threading and execution order cannot move it.
    pub fn draw(&self, frame: u64, layer: u64, core: u64) -> Option<FaultKind> {
        self.site_rng(frame, layer, core).1
    }

    /// Site rng + drawn kind; the rng is advanced past the draw so the
    /// corruption path can keep pulling deterministic values from it.
    fn site_rng(&self, frame: u64, layer: u64, core: u64) -> (XorShift, Option<FaultKind>) {
        let mut rng = XorShift::new(mix(self.seed, frame, layer, core));
        if self.rate_ppm == 0 || self.kinds == 0 {
            return (rng, None);
        }
        if rng.next_u64() % 1_000_000 >= u64::from(self.rate_ppm) {
            return (rng, None);
        }
        let enabled: Vec<FaultKind> =
            ALL_KINDS.iter().copied().filter(|k| self.kinds & k.mask() != 0).collect();
        let pick = (rng.next_u64() % enabled.len() as u64) as usize;
        let kind = enabled[pick];
        (rng, Some(kind))
    }

    /// Watchdog-bounded cycles wasted by a core that exhausted its
    /// retry budget on a layer of static cost `static_cycles`: every
    /// attempt ran to (at worst) the watchdog bound before failing.
    pub(crate) fn fail_waste(&self, static_cycles: u64) -> u64 {
        (u64::from(self.retry_budget) + 1) * watchdog_bound(static_cycles)
    }
}

/// `seed[:rate[:kinds]]` — the CLI `--inject` spec. `seed` is decimal
/// or `0x…` hex; `rate` is a fraction in [0, 1] (default 0.05);
/// `kinds` is a comma list of
/// `bitflip | dma-corrupt | dma-drop | hang | fail | all | silent`
/// (default: every transient kind, detection on; `silent` disables
/// detection; `all` enables every kind including `fail`).
impl std::str::FromStr for FaultPlan {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let mut parts = s.splitn(3, ':');
        let seed_s = parts.next().unwrap_or(""); // invariant: splitn yields >= 1 part
        let seed = if let Some(hex) = seed_s.strip_prefix("0x").or_else(|| seed_s.strip_prefix("0X")) {
            u64::from_str_radix(hex, 16)
        } else {
            seed_s.parse::<u64>()
        }
        .map_err(|_| format!("--inject: bad seed `{seed_s}` (decimal or 0x… hex)"))?;
        let mut plan = FaultPlan::new(seed, 0.05);
        if let Some(rate_s) = parts.next() {
            let rate: f64 = rate_s
                .parse()
                .map_err(|_| format!("--inject: bad rate `{rate_s}` (fraction in [0, 1])"))?;
            if !(0.0..=1.0).contains(&rate) {
                return Err(format!("--inject: rate {rate} outside [0, 1]"));
            }
            plan.rate_ppm = (rate * 1e6) as u32;
        }
        if let Some(kinds_s) = parts.next() {
            let mut mask = 0u8;
            for tok in kinds_s.split(',') {
                match tok.trim() {
                    "bitflip" | "bit-flip" => mask |= FaultKind::BitFlip.mask(),
                    "dma-corrupt" | "corrupt" => mask |= FaultKind::DmaCorrupt.mask(),
                    "dma-drop" | "drop" => mask |= FaultKind::DmaDrop.mask(),
                    "hang" => mask |= FaultKind::CoreHang.mask(),
                    "fail" => mask |= FaultKind::CoreFail.mask(),
                    "all" => mask |= ALL_KINDS.iter().map(|k| k.mask()).sum::<u8>(),
                    "silent" | "no-detect" => plan.detect = false,
                    other => {
                        return Err(format!(
                            "--inject: unknown fault kind `{other}` (bitflip | dma-corrupt | \
                             dma-drop | hang | fail | all | silent)"
                        ))
                    }
                }
            }
            if mask != 0 {
                plan.kinds = mask;
            }
        }
        Ok(plan)
    }
}

/// SplitMix64-style site-key mixer: decorrelates the per-site rng
/// streams so neighbouring `(frame, layer, core)` sites draw
/// independently.
fn mix(seed: u64, frame: u64, layer: u64, core: u64) -> u64 {
    let mut h = seed ^ 0x9E37_79B9_7F4A_7C15;
    for v in [frame, layer, core] {
        h ^= v.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        h = h.rotate_left(27).wrapping_mul(0x94D0_49BB_1331_11EB);
    }
    h
}

/// FNV-1a over a layer's static name — the deterministic `layer` half
/// of a fault-site key (model names are unique within a net, and the
/// key survives re-sharding / re-partitioning, which a positional
/// index would not).
pub fn layer_key(name: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// FNV-1a over an output tensor — the shard-level checksum
/// `merge_shards` cross-checks at the shard hand-off.
pub fn checksum_words(words: &[i16]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for w in words {
        h ^= u64::from(*w as u16);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Cycles the verification unit spends checksumming a `bytes`-long
/// off-chip stream: it keeps up with
/// [`CHECKSUM_BEATS_PER_CYCLE`](crate::mem::CHECKSUM_BEATS_PER_CYCLE)
/// bus beats per cycle (a wide XOR/FNV fold), plus one DRAM-latency
/// flush for the compare/ack round trip. Zero-byte streams verify for
/// free.
pub fn checksum_cycles(bytes: u64) -> u64 {
    if bytes == 0 {
        return 0;
    }
    bytes.div_ceil((CHECKSUM_BEATS_PER_CYCLE * EXT_BYTES_PER_CYCLE) as u64) + EXT_LATENCY_CYCLES
}

/// The watchdog's cycle bound for a layer predicted (exactly, by the
/// static analyzer — tile-analytic cycles ARE `predict.rs`'s timing)
/// to take `predicted` cycles: prediction + 12.5 % margin + a 64-cycle
/// floor. A core still running past this is hung by definition.
pub fn watchdog_bound(predicted: u64) -> u64 {
    predicted + predicted / 8 + 64
}

/// Inject, detect and recover at one `(frame, layer, core)` site,
/// against the *clean* result `r` of the layer attempt.
///
/// Timing here is data-independent (the repo's locked invariant), so a
/// discarded faulted attempt costs exactly what the clean attempt's
/// accounting says — recovery is priced from `r`'s own cycle/byte
/// numbers without executing corrupted data:
///
/// * detection on: every attempt pays [`checksum_cycles`] over its
///   off-chip stream; a drawn transient fault adds one retry
///   (`fault_retries`) and its kind-specific recovery cycles
///   (`fault_recovery_cycles`, also added to `cycles` so the cost
///   flows through every makespan/bus account); a drawn `CoreFail`
///   (or any fault with a zero retry budget) raises
///   [`ExecError::CoreFailure`] for the engine's blacklist/degrade
///   path. The output is always the clean output — recovery is
///   semantically transparent.
/// * detection off: a drawn fault deterministically corrupts the real
///   output tensor (FullCycle mode) and charges nothing.
pub(crate) fn apply_layer_faults(
    plan: &FaultPlan,
    frame: u64,
    layer: u64,
    core: usize,
    r: &mut LayerResult,
) -> Result<(), ExecError> {
    let clean_cycles = r.cycles;
    let (mut rng, drawn) = plan.site_rng(frame, layer, core);
    if plan.detect {
        // every attempt verifies its streams, faulted or not
        r.cycles += checksum_cycles(r.io_in + r.io_out);
        r.out_checksum = checksum_words(&r.out);
        let Some(kind) = drawn else { return Ok(()) };
        if kind == FaultKind::CoreFail || plan.retry_budget == 0 {
            return Err(ExecError::CoreFailure { core, layer: r.name.to_string() });
        }
        let recovery = match kind {
            // wrong output caught at the output check: the whole
            // attempt is wasted, the input re-stages, the layer re-runs
            FaultKind::BitFlip => clean_cycles + dma_cycles(r.io_in, 1),
            // caught in flight by the transfer checksum: re-issue only
            FaultKind::DmaCorrupt => dma_cycles(r.io_in.max(r.io_out), 1),
            // descriptor timeout, then re-issue
            FaultKind::DmaDrop => dma_cycles(r.io_in, 2),
            // watchdog fires at the bound; reset and re-run (the clean
            // attempt already counted — this is the hung time)
            FaultKind::CoreHang => watchdog_bound(clean_cycles),
            FaultKind::CoreFail => unreachable!("handled above"),
        } + checksum_cycles(r.io_in + r.io_out); // the retry re-verifies
        r.fault_retries += 1;
        r.fault_recovery_cycles += recovery;
        r.cycles += recovery;
    } else if let Some(kind) = drawn {
        corrupt(&mut rng, kind, &mut r.out);
    }
    Ok(())
}

/// Deterministic output corruption for detection-off campaigns. A
/// bit-flip/corrupt fault XORs one drawn bit of one drawn word (always
/// changes the tensor); a drop zeroes a 16-word run; a hang/fail
/// leaves a poisoned run. No-op on empty (analytic-mode) outputs.
fn corrupt(rng: &mut XorShift, kind: FaultKind, out: &mut [i16]) {
    if out.is_empty() {
        return;
    }
    let i = (rng.next_u64() % out.len() as u64) as usize;
    match kind {
        FaultKind::BitFlip | FaultKind::DmaCorrupt => {
            out[i] ^= 1i16 << (rng.next_u64() % 16);
        }
        FaultKind::DmaDrop => {
            let end = (i + 16).min(out.len());
            out[i..end].fill(0);
        }
        FaultKind::CoreHang | FaultKind::CoreFail => {
            let end = (i + 64).min(out.len());
            out[i..end].fill(-1);
        }
    }
}

/// Fault/recovery account of a whole run (batched, streaming or
/// multi-tenant): the degraded-topology report the results carry.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultReport {
    /// Detected-and-retried faults, including the exhausted attempts of
    /// blacklisted cores.
    pub retries: u64,
    /// Total modeled recovery cycles: the per-layer
    /// `fault_recovery_cycles` sums plus `degrade_waste_cycles`.
    pub recovery_cycles: u64,
    /// Pool cores blacklisted after exhausting their retry budget, in
    /// blacklist order. Non-empty ⇒ the run finished on a degraded
    /// topology (the partition-DP / shard assignment re-ran over the
    /// survivors).
    pub blacklisted_cores: Vec<usize>,
    /// Watchdog-bounded cycles the exhausted cores wasted before each
    /// degrade re-partition — charged on top of the degraded makespan
    /// (the re-run starts only after the watchdog gives up).
    pub degrade_waste_cycles: u64,
}

impl FaultReport {
    /// Did the run lose cores and re-partition?
    pub fn degraded(&self) -> bool {
        !self.blacklisted_cores.is_empty()
    }

    /// Anything to report at all?
    pub fn any(&self) -> bool {
        self.retries > 0 || self.recovery_cycles > 0 || self.degraded()
    }

    /// Fold another report in (multi-tenant aggregation).
    pub fn absorb(&mut self, other: &FaultReport) {
        self.retries += other.retries;
        self.recovery_cycles += other.recovery_cycles;
        self.blacklisted_cores.extend_from_slice(&other.blacklisted_cores);
        self.degrade_waste_cycles += other.degrade_waste_cycles;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn site_draws_replay_bit_identically() {
        let plan = FaultPlan::new(0xBEEF, 0.5);
        for frame in 0..4u64 {
            for layer in [layer_key("conv1"), layer_key("fc6")] {
                for core in 0..4u64 {
                    assert_eq!(
                        plan.draw(frame, layer, core),
                        plan.draw(frame, layer, core),
                        "site draw must be pure in (plan, site)"
                    );
                }
            }
        }
    }

    #[test]
    fn rate_bounds_hold() {
        let never = FaultPlan::new(7, 0.0);
        let always = FaultPlan::new(7, 1.0);
        let mut hits = 0;
        for site in 0..200u64 {
            assert_eq!(never.draw(site, 1, 2), None);
            if always.draw(site, 1, 2).is_some() {
                hits += 1;
            }
        }
        assert_eq!(hits, 200, "rate 1.0 must hit every site");
    }

    #[test]
    fn kind_mask_restricts_draws() {
        let plan = FaultPlan::new(11, 1.0).kinds(FaultKind::DmaDrop.mask());
        for site in 0..50u64 {
            assert_eq!(plan.draw(site, 3, 0), Some(FaultKind::DmaDrop));
        }
    }

    #[test]
    fn parse_full_spec() {
        let p: FaultPlan = "0xBEEF:0.25:bitflip,hang".parse().unwrap();
        assert_eq!(p.seed, 0xBEEF);
        assert_eq!(p.rate_ppm, 250_000);
        assert_eq!(p.kinds, FaultKind::BitFlip.mask() | FaultKind::CoreHang.mask());
        assert!(p.detect);
        let q: FaultPlan = "42".parse().unwrap();
        assert_eq!(q.seed, 42);
        assert_eq!(q.kinds, TRANSIENT_KINDS);
        let s: FaultPlan = "1:1.0:bitflip,silent".parse().unwrap();
        assert!(!s.detect);
        assert_eq!(s.kinds, FaultKind::BitFlip.mask());
        let a: FaultPlan = "1:0.5:all".parse().unwrap();
        assert_eq!(a.kinds, ALL_KINDS.iter().map(|k| k.mask()).sum::<u8>());
    }

    #[test]
    fn parse_errors_name_the_flag() {
        for bad in ["zzz", "1:2.5", "1:-0.1", "1:0.5:gamma-ray", "1:abc"] {
            let err = bad.parse::<FaultPlan>().unwrap_err();
            assert!(err.contains("--inject"), "`{bad}` error must name --inject: {err}");
        }
    }

    #[test]
    fn detection_pricing_is_charged_and_transparent() {
        let mut r = LayerResult {
            name: "t",
            cycles: 10_000,
            io_in: 4096,
            io_out: 1024,
            out: vec![1, 2, 3, 4],
            ..Default::default()
        };
        let clean_out = r.out.clone();
        // no fault drawn: checksum overhead only
        let plan = FaultPlan::new(1, 0.0);
        apply_layer_faults(&plan, 0, 0, 0, &mut r).unwrap();
        assert_eq!(r.cycles, 10_000 + checksum_cycles(5120));
        assert_eq!(r.fault_retries, 0);
        assert_eq!(r.out, clean_out);
        assert_eq!(r.out_checksum, checksum_words(&clean_out));
        // guaranteed fault: recovery charged, output still clean
        let mut r2 = LayerResult {
            name: "t",
            cycles: 10_000,
            io_in: 4096,
            io_out: 1024,
            out: clean_out.clone(),
            ..Default::default()
        };
        let hot = FaultPlan::new(1, 1.0);
        apply_layer_faults(&hot, 0, 0, 0, &mut r2).unwrap();
        assert_eq!(r2.fault_retries, 1);
        assert!(r2.fault_recovery_cycles > 0);
        assert_eq!(r2.cycles, 10_000 + checksum_cycles(5120) + r2.fault_recovery_cycles);
        assert_eq!(r2.out, clean_out, "recovery must be semantically transparent");
    }

    #[test]
    fn silent_faults_corrupt_and_cost_nothing() {
        let mut r = LayerResult {
            name: "t",
            cycles: 10_000,
            io_in: 4096,
            io_out: 1024,
            out: vec![7i16; 256],
            ..Default::default()
        };
        let plan = FaultPlan::new(3, 1.0).kinds(FaultKind::BitFlip.mask()).detect(false);
        apply_layer_faults(&plan, 0, 0, 0, &mut r).unwrap();
        assert_eq!(r.cycles, 10_000, "silent faults charge nothing");
        assert_ne!(r.out, vec![7i16; 256], "silent faults corrupt the output");
        assert_eq!(r.fault_retries, 0);
    }

    #[test]
    fn core_fail_exhausts_into_core_failure() {
        let mut r = LayerResult { name: "conv9", cycles: 5_000, ..Default::default() };
        let plan = FaultPlan::new(5, 1.0).kinds(FaultKind::CoreFail.mask());
        match apply_layer_faults(&plan, 0, 0, 2, &mut r) {
            Err(ExecError::CoreFailure { core, layer }) => {
                assert_eq!(core, 2);
                assert_eq!(layer, "conv9");
            }
            other => panic!("expected CoreFailure, got {other:?}"),
        }
        // zero budget makes transient faults fatal too
        let mut r2 = LayerResult { name: "conv9", cycles: 5_000, ..Default::default() };
        let strict = FaultPlan::new(5, 1.0).kinds(FaultKind::BitFlip.mask()).retry_budget(0);
        assert!(matches!(
            apply_layer_faults(&strict, 0, 0, 0, &mut r2),
            Err(ExecError::CoreFailure { .. })
        ));
    }

    #[test]
    fn watchdog_bound_exceeds_prediction() {
        assert_eq!(watchdog_bound(0), 64);
        assert_eq!(watchdog_bound(8000), 8000 + 1000 + 64);
        let plan = FaultPlan::new(1, 1.0);
        assert_eq!(plan.fail_waste(8000), 4 * watchdog_bound(8000));
    }

    #[test]
    fn checksums_detect_single_word_changes() {
        let a = vec![1i16, -2, 3, 4];
        let mut b = a.clone();
        assert_eq!(checksum_words(&a), checksum_words(&b));
        b[2] ^= 1;
        assert_ne!(checksum_words(&a), checksum_words(&b));
        assert_eq!(checksum_cycles(0), 0);
        assert!(checksum_cycles(1) >= EXT_LATENCY_CYCLES);
    }
}
