//! Metric aggregation: the quantities of Table II, plus the multi-core
//! serving views (batched fan-out, layer-pipelined streaming) and the
//! per-layer-kind rollups the end-to-end-network reports print.

use crate::core::CoreStats;
use crate::model::NetLayer;

use super::bus::BusModel;

/// Result of executing one layer.
#[derive(Debug, Clone, Default)]
pub struct LayerResult {
    /// Layer name (model tables carry static names; borrowing them
    /// keeps the per-call result path allocation-free).
    pub name: &'static str,
    /// Total cycles under the per-iteration fill/steady DMA timeline:
    /// a rotated (double-buffered) plan pays a serialized first-stream
    /// fill then `max(compute, next stream)` per iteration; an
    /// un-rotatable plan pays `compute + stream` per iteration.
    pub cycles: u64,
    /// Pure compute cycles on the core.
    pub compute_cycles: u64,
    /// Analytic DMA transfer cycles (sum over per-iteration streams).
    pub dma_cycles: u64,
    /// Bytes of the serialized first-iteration fill (rotated plans;
    /// 0 when the layer's stream serializes instead).
    pub dma_fill_bytes: u64,
    /// Cycles of the serialized first-iteration fill.
    pub dma_fill_cycles: u64,
    /// Bytes of a stream that cannot be double-buffered and therefore
    /// never overlaps compute (un-rotatable plans; 0 when rotated).
    pub dma_serial_bytes: u64,
    /// Cycles of the serialized (never-overlapped) stream.
    pub dma_serial_cycles: u64,
    /// Useful MACs (the layer's arithmetic, not garbage lanes).
    pub macs: u64,
    /// Off-chip bytes read (weights, IFMaps, PSums back in).
    pub io_in: u64,
    /// Off-chip bytes written (OFMaps, PSum spills).
    pub io_out: u64,
    /// Aggregated core activity (for the energy model).
    pub stats: CoreStats,
    /// Layer output (empty in analytic mode).
    pub out: Vec<i16>,
    /// Busy cycles per core when the layer was sharded by the
    /// multi-core scheduler (empty for single-core runs). `cycles` is
    /// then the makespan — the maximum entry of this vector.
    pub core_cycles: Vec<u64>,
    /// Faults detected and retried on this layer (0 when fault
    /// injection is off — see [`super::faults`]).
    pub fault_retries: u64,
    /// Cycles spent detecting and recovering (wasted attempts,
    /// re-staged transfers, watchdog waits, retry re-verification).
    /// Already included in `cycles`; recorded separately so reports can
    /// split clean time from recovery time.
    pub fault_recovery_cycles: u64,
    /// FNV checksum of `out`, stamped at (priced) verification time
    /// when a fault plan with detection is active; 0 otherwise.
    /// `merge_shards` cross-checks it at the shard hand-off.
    pub out_checksum: u64,
}

impl LayerResult {
    /// MAC utilization per the paper's definition (Table II fn. e):
    /// ideal processing time over actual, **per core**. Sharded layers
    /// spend `cores × makespan` core-cycles, so the denominator scales
    /// with [`LayerResult::parallel_cores`] — a 4-core makespan can
    /// never report above-peak utilization.
    pub fn utilization(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        let ideal = self.macs as f64 / crate::PEAK_MACS_PER_CYCLE as f64;
        ideal / (self.cycles as f64 * self.parallel_cores() as f64)
    }

    pub fn time_ms(&self) -> f64 {
        self.cycles as f64 / crate::CLOCK_HZ as f64 * 1e3
    }

    pub fn gops(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        2.0 * self.macs as f64 / (self.cycles as f64 / crate::CLOCK_HZ as f64) / 1e9
    }

    pub fn io_total(&self) -> u64 {
        self.io_in + self.io_out
    }

    /// Number of cores this layer ran on (1 when not sharded).
    pub fn parallel_cores(&self) -> usize {
        self.core_cycles.len().max(1)
    }

    /// Cycle-level speedup of the sharded run over executing the same
    /// shards serially on one core: `sum(core busy) / makespan`.
    /// 1.0 for single-core runs.
    pub fn parallel_speedup(&self) -> f64 {
        let max = *self.core_cycles.iter().max().unwrap_or(&0);
        if max == 0 {
            return 1.0;
        }
        self.core_cycles.iter().sum::<u64>() as f64 / max as f64
    }

    /// Fraction of the `cores × makespan` cycle budget spent busy.
    /// 1.0 for single-core runs.
    pub fn parallel_efficiency(&self) -> f64 {
        self.parallel_speedup() / self.parallel_cores() as f64
    }
}

/// Whole-network aggregate.
#[derive(Debug, Clone, Default)]
pub struct NetworkResult {
    pub name: String,
    pub layers: Vec<LayerResult>,
}

impl NetworkResult {
    pub fn cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.cycles).sum()
    }
    pub fn macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs).sum()
    }
    pub fn io_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.io_total()).sum()
    }
    pub fn io_mbytes(&self) -> f64 {
        self.io_bytes() as f64 / 1e6
    }
    pub fn time_ms(&self) -> f64 {
        self.cycles() as f64 / crate::CLOCK_HZ as f64 * 1e3
    }
    /// Network MAC utilization (conv layers carry all MACs), per core:
    /// sharded layers charge `cores × makespan` core-cycles.
    pub fn utilization(&self) -> f64 {
        let ideal = self.macs() as f64 / crate::PEAK_MACS_PER_CYCLE as f64;
        let actual: u64 = self
            .layers
            .iter()
            .filter(|l| l.macs > 0)
            .map(|l| l.cycles * l.parallel_cores() as u64)
            .sum();
        if actual == 0 {
            0.0
        } else {
            ideal / actual as f64
        }
    }
    pub fn gops(&self) -> f64 {
        2.0 * self.macs() as f64 / (self.cycles() as f64 / crate::CLOCK_HZ as f64) / 1e9
    }
    /// Faults detected and retried across all layers.
    pub fn fault_retries(&self) -> u64 {
        self.layers.iter().map(|l| l.fault_retries).sum()
    }
    /// Cycles spent on fault recovery across all layers (already
    /// inside [`NetworkResult::cycles`]).
    pub fn fault_recovery_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.fault_recovery_cycles).sum()
    }
    /// Aggregate core stats over all layers.
    pub fn stats(&self) -> CoreStats {
        let mut acc = CoreStats::default();
        for l in &self.layers {
            acc = add_stats(&acc, &l.stats);
        }
        acc
    }

    /// Roll the per-layer results up by layer *kind* (conv / pool / fc
    /// — whatever kinds `layers` carries, in first-appearance order).
    /// `layers` must be the descriptor list this result was produced
    /// from; kind labels come from the `LayerOp` surface, so new layer
    /// kinds show up in reports without report changes.
    pub fn kind_totals(&self, layers: &[NetLayer]) -> Vec<KindTotal> {
        assert_eq!(layers.len(), self.layers.len(), "descriptor/result mismatch");
        let mut out: Vec<KindTotal> = Vec::new();
        for (d, r) in layers.iter().zip(&self.layers) {
            let kind = d.kind();
            let idx = match out.iter().position(|t| t.kind == kind) {
                Some(i) => i,
                None => {
                    out.push(KindTotal { kind, ..Default::default() });
                    out.len() - 1
                }
            };
            let t = &mut out[idx];
            t.layers += 1;
            t.cycles += r.cycles;
            t.macs += r.macs;
            t.io_bytes += r.io_total();
            if r.macs > 0 {
                t.busy_core_cycles += r.cycles * r.parallel_cores() as u64;
            }
        }
        out
    }
}

/// One layer kind's rollup within a network run (see
/// [`NetworkResult::kind_totals`]).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct KindTotal {
    /// Kind label (`"conv"`, `"pool"`, `"fc"`, …).
    pub kind: &'static str,
    /// Number of layers of this kind.
    pub layers: usize,
    /// Summed layer cycles (makespans for sharded layers).
    pub cycles: u64,
    /// Summed useful MACs.
    pub macs: u64,
    /// Summed off-chip bytes.
    pub io_bytes: u64,
    /// Σ cycles × parallel cores over the kind's MAC-carrying layers —
    /// the denominator of the kind's aggregate utilization.
    pub busy_core_cycles: u64,
}

impl KindTotal {
    pub fn time_ms(&self) -> f64 {
        self.cycles as f64 / crate::CLOCK_HZ as f64 * 1e3
    }

    /// Aggregate ALU utilization of the kind's MAC-carrying layers:
    /// ideal cycles over occupied core-cycles (MAC-weighted, same
    /// definition as [`NetworkResult::utilization`] restricted to the
    /// kind). 0.0 for kinds without MACs (pool).
    pub fn utilization(&self) -> f64 {
        if self.busy_core_cycles == 0 {
            return 0.0;
        }
        let ideal = self.macs as f64 / crate::PEAK_MACS_PER_CYCLE as f64;
        ideal / self.busy_core_cycles as f64
    }
}

/// Result of a layer-pipelined streaming run
/// ([`Engine::run_streaming`](super::engine::Engine::run_streaming)):
/// the network is cut into contiguous layer *stages*, each stage owns
/// a **group** of one or more cores (layers shard across the group
/// in-stage), and frames stream through them — frame `t` on stage `i`
/// while frame `t−1` occupies stage `i+1`.
#[derive(Debug, Clone, Default)]
pub struct PipelineResult {
    pub name: String,
    /// Per-frame network results, in input order — layer outputs are
    /// bit-identical to single-core [`NetworkResult`] runs.
    pub frames: Vec<NetworkResult>,
    /// Final activation per frame (empty vectors in analytic mode).
    pub outputs: Vec<Vec<i16>>,
    /// Half-open layer ranges: stage `s` runs `layers[stages[s].0 ..
    /// stages[s].1]` on its core group. Balanced by the
    /// predicted-makespan cost model.
    pub stages: Vec<(usize, usize)>,
    /// Cores owned by each stage (parallel to `stages`; all 1 for the
    /// legacy one-core-per-stage partition). A stage with `k > 1`
    /// shards each of its layers across its group per the run's
    /// [`ShardPolicy`](super::engine::ShardPolicy).
    pub stage_cores: Vec<usize>,
    /// Occupied cycles per stage core over the whole stream, priced
    /// under the run's bus model (includes shared-bus wait).
    pub stage_cycles: Vec<u64>,
    /// Stage cycles at full private bandwidth — the useful-work view.
    /// Equals `stage_cycles` under a partitioned bus.
    pub stage_useful_cycles: Vec<u64>,
    /// Steady-state initiation interval: the bottleneck stage's
    /// per-frame cycles. One frame leaves the pipe every interval once
    /// it is full.
    pub steady_interval_cycles: u64,
    /// Fill latency: cycles until the first frame leaves the last stage.
    pub fill_cycles: u64,
    /// Drain latency: cycles the *last* frame spends in the pipe (from
    /// entering stage 0 to leaving the last stage).
    pub drain_cycles: u64,
    /// End-to-end cycles for the whole stream (flow-shop makespan).
    /// After a mid-stream degrade this includes the blacklisted cores'
    /// watchdog-bounded waste (`faults.degrade_waste_cycles`).
    pub makespan_cycles: u64,
    /// External-bus model the stream was priced under.
    pub bus: BusModel,
    /// Fault/recovery account and degraded-topology report: retries,
    /// recovery cycles, blacklisted cores. `stages`/`stage_cores`
    /// describe the partition the stream *finished* on — after a
    /// degrade that is the re-partition over the surviving cores.
    pub faults: super::faults::FaultReport,
}

impl PipelineResult {
    /// Steady-state throughput at the modeled clock: one frame per
    /// initiation interval once the pipe is full. Excludes fill/drain —
    /// the number a long-running stream converges to.
    pub fn steady_state_fps(&self) -> f64 {
        if self.steady_interval_cycles == 0 {
            return 0.0;
        }
        crate::CLOCK_HZ as f64 / self.steady_interval_cycles as f64
    }

    /// Whole-stream throughput including fill and drain: frames over
    /// the flow-shop makespan.
    pub fn throughput_fps(&self) -> f64 {
        if self.makespan_cycles == 0 {
            return 0.0;
        }
        self.frames.len() as f64 / (self.makespan_cycles as f64 / crate::CLOCK_HZ as f64)
    }

    /// What the stream would cost serially on one core (the sum of the
    /// per-frame single-core cycle counts).
    pub fn serial_cycles(&self) -> u64 {
        self.frames.iter().map(|f| f.cycles()).sum()
    }

    /// Cycle-level speedup of the pipelined stream over one core.
    pub fn speedup(&self) -> f64 {
        if self.makespan_cycles == 0 {
            return 1.0;
        }
        self.serial_cycles() as f64 / self.makespan_cycles as f64
    }

    /// Per-stage useful fraction of the makespan: private-bandwidth
    /// stage cycles over the stream makespan. Shared-bus wait is not
    /// useful work, so contended stages report < 1.0 — never above.
    pub fn stage_utilization(&self) -> Vec<f64> {
        let mk = self.makespan_cycles.max(1) as f64;
        self.stage_useful_cycles.iter().map(|&c| (c as f64 / mk).min(1.0)).collect()
    }

    /// Aggregate core activity over all frames (for the energy model).
    pub fn stats(&self) -> CoreStats {
        let mut acc = CoreStats::default();
        for f in &self.frames {
            acc = add_stats(&acc, &f.stats());
        }
        acc
    }

    /// Total off-chip bytes the stream moved (all frames, all layers)
    /// — a tenant's demand on the shared channel.
    pub fn dma_bytes(&self) -> u64 {
        self.frames.iter().map(|f| f.io_bytes()).sum()
    }
}

/// Result of a multi-tenant run
/// ([`run_multi_streaming`](super::engine::run_multi_streaming)):
/// several engines, each pipelining its own network over its own core
/// group, competing for ONE shared external bus. Per-tenant metrics
/// are full [`PipelineResult`]s (outputs bit-identical to each
/// tenant's solo run — contention only adds wait cycles); the combined
/// account says how the channel was split.
#[derive(Debug, Clone, Default)]
pub struct MultiTenantResult {
    /// Per-tenant pipeline results, in submission order. Priced under
    /// the **combined** shared-bus divisor, so a tenant's cycles here
    /// are ≥ its isolated `run_streaming` cycles.
    pub tenants: Vec<PipelineResult>,
    /// Cores each tenant's engine contributed to the pool.
    pub tenant_cores: Vec<usize>,
    /// Fixed-point bandwidth divisor across ALL tenants' cores.
    pub divisor: u64,
    /// Cores counted as concurrently DMA-bound at the fixed point.
    pub contenders: usize,
    /// Aggregate fault/recovery account over all tenants (each tenant's
    /// own report stays on its [`PipelineResult`]).
    pub faults: super::faults::FaultReport,
}

impl MultiTenantResult {
    /// Total cores across all tenants.
    pub fn total_cores(&self) -> usize {
        self.tenant_cores.iter().sum()
    }

    /// The slowest tenant's stream makespan — when the whole
    /// multi-tenant episode ends.
    pub fn makespan_cycles(&self) -> u64 {
        self.tenants.iter().map(|t| t.makespan_cycles).max().unwrap_or(0)
    }

    /// Each tenant's fraction of the off-chip bytes moved — the
    /// shared-bus occupancy split (sums to 1.0 when anything moved).
    pub fn bus_shares(&self) -> Vec<f64> {
        let total: u64 = self.tenants.iter().map(|t| t.dma_bytes()).sum();
        if total == 0 {
            return vec![0.0; self.tenants.len()];
        }
        self.tenants.iter().map(|t| t.dma_bytes() as f64 / total as f64).collect()
    }

    /// Summed steady-state throughput across tenants (frames/s) — the
    /// pool's aggregate serving rate once every pipe is full.
    pub fn aggregate_steady_fps(&self) -> f64 {
        self.tenants.iter().map(|t| t.steady_state_fps()).sum()
    }
}

/// Field-wise sum of two activity-counter sets — how per-layer,
/// per-frame and per-core stats compose into pool aggregates (and how
/// the energy model's multi-core GOP/s/W is derived; see
/// `tests/energy_validation.rs`).
pub fn add_stats(a: &CoreStats, b: &CoreStats) -> CoreStats {
    macro_rules! s {
        ($($f:ident),* $(,)?) => { CoreStats { $($f: a.$f + b.$f),* } };
    }
    s!(
        cycles, bundles, mac_ops, mac_bundles, vmacs, qmovs, veops, sfu_ops,
        acc_setup, scalar_ops, ctrl_ops, branch_stalls, hazard_stalls,
        lb_stalls, dma_wait_stalls, wide_ls_stalls, vloads, vstores, aloads,
        astores, sloads, sstores, lb_fills, lb_pixel_reads, vr_reads,
        vr_writes, vrl_writes, mac_ops_gated8,
    )
}

pub(crate) fn div_stats(a: &CoreStats, den: u64) -> CoreStats {
    macro_rules! s {
        ($($f:ident),* $(,)?) => { CoreStats { $($f: a.$f / den),* } };
    }
    s!(
        cycles, bundles, mac_ops, mac_bundles, vmacs, qmovs, veops, sfu_ops,
        acc_setup, scalar_ops, ctrl_ops, branch_stalls, hazard_stalls,
        lb_stalls, dma_wait_stalls, wide_ls_stalls, vloads, vstores, aloads,
        astores, sloads, sstores, lb_fills, lb_pixel_reads, vr_reads,
        vr_writes, vrl_writes, mac_ops_gated8,
    )
}

pub(crate) fn scale_stats(a: &CoreStats, num: u64) -> CoreStats {
    macro_rules! s {
        ($($f:ident),* $(,)?) => { CoreStats { $($f: a.$f * num),* } };
    }
    s!(
        cycles, bundles, mac_ops, mac_bundles, vmacs, qmovs, veops, sfu_ops,
        acc_setup, scalar_ops, ctrl_ops, branch_stalls, hazard_stalls,
        lb_stalls, dma_wait_stalls, wide_ls_stalls, vloads, vstores, aloads,
        astores, sloads, sstores, lb_fills, lb_pixel_reads, vr_reads,
        vr_writes, vrl_writes, mac_ops_gated8,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn utilization_formula() {
        let r = LayerResult {
            macs: 192 * 1000,
            cycles: 2000,
            ..Default::default()
        };
        assert!((r.utilization() - 0.5).abs() < 1e-9);
        assert!((r.gops() - crate::PEAK_GOPS * 0.5).abs() < 1e-6);
    }

    #[test]
    fn sharded_utilization_divides_by_cores() {
        // 4 cores × 1000-cycle makespan moving 4000 ideal single-core
        // cycles of MACs → exactly 1.0 per-core utilization, not 4.0
        let r = LayerResult {
            macs: 192 * 4000,
            cycles: 1000,
            core_cycles: vec![1000, 980, 990, 1000],
            ..Default::default()
        };
        assert!((r.utilization() - 1.0).abs() < 1e-9);
        let mut n = NetworkResult { name: "n".into(), ..Default::default() };
        n.layers.push(r);
        assert!((n.utilization() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn pipeline_result_metrics() {
        let pr = PipelineResult {
            frames: vec![NetworkResult::default(); 8],
            stage_cycles: vec![40_000_000, 20_000_000],
            stage_useful_cycles: vec![40_000_000, 10_000_000],
            steady_interval_cycles: 4_000_000, // 100 f/s at 400 MHz
            makespan_cycles: 40_000_000,       // 8 frames in 0.1 s
            ..Default::default()
        };
        assert!((pr.steady_state_fps() - 100.0).abs() < 1e-9);
        assert!((pr.throughput_fps() - 80.0).abs() < 1e-9);
        let u = pr.stage_utilization();
        assert!((u[0] - 1.0).abs() < 1e-9);
        assert!((u[1] - 0.25).abs() < 1e-9);
        // empty pipelines report zeros, not NaNs
        let empty = PipelineResult::default();
        assert_eq!(empty.steady_state_fps(), 0.0);
        assert_eq!(empty.throughput_fps(), 0.0);
        assert_eq!(empty.speedup(), 1.0);
    }

    #[test]
    fn kind_totals_roll_up_by_layer_kind() {
        use crate::model::{ConvLayer, FcLayer, PoolLayer};
        let layers = vec![
            NetLayer::Conv(ConvLayer::new("c1", 4, 8, 8, 16, 3, 3, 1, 1, 1)),
            NetLayer::Pool(PoolLayer { name: "p1", ic: 16, ih: 8, iw: 8, size: 2, stride: 2 }),
            NetLayer::Conv(ConvLayer::new("c2", 16, 4, 4, 16, 3, 3, 1, 1, 1)),
            NetLayer::Fc(FcLayer::new("fc", 256, 10)),
        ];
        let mut n = NetworkResult { name: "k".into(), ..Default::default() };
        for (i, l) in layers.iter().enumerate() {
            n.layers.push(LayerResult {
                name: l.name().into(),
                cycles: 100 * (i as u64 + 1),
                macs: 10 * (i as u64 + 1),
                io_in: i as u64,
                ..Default::default()
            });
        }
        let kt = n.kind_totals(&layers);
        assert_eq!(kt.len(), 3);
        assert_eq!((kt[0].kind, kt[0].layers, kt[0].cycles, kt[0].macs), ("conv", 2, 400, 40));
        assert_eq!((kt[1].kind, kt[1].layers, kt[1].cycles), ("pool", 1, 200));
        assert_eq!((kt[2].kind, kt[2].layers, kt[2].cycles, kt[2].io_bytes), ("fc", 1, 400, 3));
        // totals tile the network aggregates exactly
        assert_eq!(kt.iter().map(|t| t.cycles).sum::<u64>(), n.cycles());
        assert_eq!(kt.iter().map(|t| t.macs).sum::<u64>(), n.macs());
        assert_eq!(kt.iter().map(|t| t.io_bytes).sum::<u64>(), n.io_bytes());
    }

    #[test]
    fn network_aggregates() {
        let mut n = NetworkResult { name: "n".into(), ..Default::default() };
        n.layers.push(LayerResult { cycles: 100, macs: 192 * 100, io_in: 10, ..Default::default() });
        n.layers.push(LayerResult { cycles: 100, macs: 0, io_out: 5, ..Default::default() });
        assert_eq!(n.cycles(), 200);
        assert_eq!(n.io_bytes(), 15);
        // utilization counts only mac-carrying layers' cycles
        assert!((n.utilization() - 1.0).abs() < 1e-9);
    }
}
