//! Metric aggregation: the quantities of Table II.

use crate::core::CoreStats;

/// Result of executing one layer.
#[derive(Debug, Clone, Default)]
pub struct LayerResult {
    pub name: String,
    /// Total cycles including DMA-bound segments (max(compute, dma)).
    pub cycles: u64,
    /// Pure compute cycles on the core.
    pub compute_cycles: u64,
    /// Analytic DMA transfer cycles (overlapped with compute).
    pub dma_cycles: u64,
    /// Useful MACs (the layer's arithmetic, not garbage lanes).
    pub macs: u64,
    /// Off-chip bytes read (weights, IFMaps, PSums back in).
    pub io_in: u64,
    /// Off-chip bytes written (OFMaps, PSum spills).
    pub io_out: u64,
    /// Aggregated core activity (for the energy model).
    pub stats: CoreStats,
    /// Layer output (empty in analytic mode).
    pub out: Vec<i16>,
    /// Busy cycles per core when the layer was sharded by the
    /// multi-core scheduler (empty for single-core runs). `cycles` is
    /// then the makespan — the maximum entry of this vector.
    pub core_cycles: Vec<u64>,
}

impl LayerResult {
    /// MAC utilization per the paper's definition (Table II fn. e):
    /// ideal processing time over actual, **per core**. Sharded layers
    /// spend `cores × makespan` core-cycles, so the denominator scales
    /// with [`LayerResult::parallel_cores`] — a 4-core makespan can
    /// never report above-peak utilization.
    pub fn utilization(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        let ideal = self.macs as f64 / crate::PEAK_MACS_PER_CYCLE as f64;
        ideal / (self.cycles as f64 * self.parallel_cores() as f64)
    }

    pub fn time_ms(&self) -> f64 {
        self.cycles as f64 / crate::CLOCK_HZ as f64 * 1e3
    }

    pub fn gops(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        2.0 * self.macs as f64 / (self.cycles as f64 / crate::CLOCK_HZ as f64) / 1e9
    }

    pub fn io_total(&self) -> u64 {
        self.io_in + self.io_out
    }

    /// Number of cores this layer ran on (1 when not sharded).
    pub fn parallel_cores(&self) -> usize {
        self.core_cycles.len().max(1)
    }

    /// Cycle-level speedup of the sharded run over executing the same
    /// shards serially on one core: `sum(core busy) / makespan`.
    /// 1.0 for single-core runs.
    pub fn parallel_speedup(&self) -> f64 {
        let max = *self.core_cycles.iter().max().unwrap_or(&0);
        if max == 0 {
            return 1.0;
        }
        self.core_cycles.iter().sum::<u64>() as f64 / max as f64
    }

    /// Fraction of the `cores × makespan` cycle budget spent busy.
    /// 1.0 for single-core runs.
    pub fn parallel_efficiency(&self) -> f64 {
        self.parallel_speedup() / self.parallel_cores() as f64
    }
}

/// Whole-network aggregate.
#[derive(Debug, Clone, Default)]
pub struct NetworkResult {
    pub name: String,
    pub layers: Vec<LayerResult>,
}

impl NetworkResult {
    pub fn cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.cycles).sum()
    }
    pub fn macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs).sum()
    }
    pub fn io_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.io_total()).sum()
    }
    pub fn io_mbytes(&self) -> f64 {
        self.io_bytes() as f64 / 1e6
    }
    pub fn time_ms(&self) -> f64 {
        self.cycles() as f64 / crate::CLOCK_HZ as f64 * 1e3
    }
    /// Network MAC utilization (conv layers carry all MACs), per core:
    /// sharded layers charge `cores × makespan` core-cycles.
    pub fn utilization(&self) -> f64 {
        let ideal = self.macs() as f64 / crate::PEAK_MACS_PER_CYCLE as f64;
        let actual: u64 = self
            .layers
            .iter()
            .filter(|l| l.macs > 0)
            .map(|l| l.cycles * l.parallel_cores() as u64)
            .sum();
        if actual == 0 {
            0.0
        } else {
            ideal / actual as f64
        }
    }
    pub fn gops(&self) -> f64 {
        2.0 * self.macs() as f64 / (self.cycles() as f64 / crate::CLOCK_HZ as f64) / 1e9
    }
    /// Aggregate core stats over all layers.
    pub fn stats(&self) -> CoreStats {
        let mut acc = CoreStats::default();
        for l in &self.layers {
            acc = add_stats(&acc, &l.stats);
        }
        acc
    }
}

pub(crate) fn add_stats(a: &CoreStats, b: &CoreStats) -> CoreStats {
    macro_rules! s {
        ($($f:ident),* $(,)?) => { CoreStats { $($f: a.$f + b.$f),* } };
    }
    s!(
        cycles, bundles, mac_ops, mac_bundles, vmacs, qmovs, veops, sfu_ops,
        acc_setup, scalar_ops, ctrl_ops, branch_stalls, hazard_stalls,
        lb_stalls, dma_wait_stalls, wide_ls_stalls, vloads, vstores, aloads,
        astores, sloads, sstores, lb_fills, lb_pixel_reads, vr_reads,
        vr_writes, vrl_writes, mac_ops_gated8,
    )
}

pub(crate) fn div_stats(a: &CoreStats, den: u64) -> CoreStats {
    macro_rules! s {
        ($($f:ident),* $(,)?) => { CoreStats { $($f: a.$f / den),* } };
    }
    s!(
        cycles, bundles, mac_ops, mac_bundles, vmacs, qmovs, veops, sfu_ops,
        acc_setup, scalar_ops, ctrl_ops, branch_stalls, hazard_stalls,
        lb_stalls, dma_wait_stalls, wide_ls_stalls, vloads, vstores, aloads,
        astores, sloads, sstores, lb_fills, lb_pixel_reads, vr_reads,
        vr_writes, vrl_writes, mac_ops_gated8,
    )
}

pub(crate) fn scale_stats(a: &CoreStats, num: u64) -> CoreStats {
    macro_rules! s {
        ($($f:ident),* $(,)?) => { CoreStats { $($f: a.$f * num),* } };
    }
    s!(
        cycles, bundles, mac_ops, mac_bundles, vmacs, qmovs, veops, sfu_ops,
        acc_setup, scalar_ops, ctrl_ops, branch_stalls, hazard_stalls,
        lb_stalls, dma_wait_stalls, wide_ls_stalls, vloads, vstores, aloads,
        astores, sloads, sstores, lb_fills, lb_pixel_reads, vr_reads,
        vr_writes, vrl_writes, mac_ops_gated8,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn utilization_formula() {
        let r = LayerResult {
            macs: 192 * 1000,
            cycles: 2000,
            ..Default::default()
        };
        assert!((r.utilization() - 0.5).abs() < 1e-9);
        assert!((r.gops() - crate::PEAK_GOPS * 0.5).abs() < 1e-6);
    }

    #[test]
    fn sharded_utilization_divides_by_cores() {
        // 4 cores × 1000-cycle makespan moving 4000 ideal single-core
        // cycles of MACs → exactly 1.0 per-core utilization, not 4.0
        let r = LayerResult {
            macs: 192 * 4000,
            cycles: 1000,
            core_cycles: vec![1000, 980, 990, 1000],
            ..Default::default()
        };
        assert!((r.utilization() - 1.0).abs() < 1e-9);
        let mut n = NetworkResult { name: "n".into(), ..Default::default() };
        n.layers.push(r);
        assert!((n.utilization() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn network_aggregates() {
        let mut n = NetworkResult { name: "n".into(), ..Default::default() };
        n.layers.push(LayerResult { cycles: 100, macs: 192 * 100, io_in: 10, ..Default::default() });
        n.layers.push(LayerResult { cycles: 100, macs: 0, io_out: 5, ..Default::default() });
        assert_eq!(n.cycles(), 200);
        assert_eq!(n.io_bytes(), 15);
        // utilization counts only mac-carrying layers' cycles
        assert!((n.utilization() - 1.0).abs() < 1e-9);
    }
}
