//! The coordinator: schedules whole CNN layers onto the core, per the
//! Fig. 2 dataflow — output-channel tiles × input-depth slices × row
//! bands, with PSum spilling and double-buffered DMA streaming.
//!
//! The coordinator is the paper's "software" half: on the silicon ASIP
//! this logic is compiled C code running in slot 0 between kernels; here
//! it is host rust that (a) stages tensors into DM (untimed pokes — the
//! transfer *time* is charged through the analytic DMA overlap model,
//! and the *bytes* through the off-chip I/O counters), (b) presets the
//! task ABI registers, (c) runs the generated kernels on the
//! cycle-accurate core, and (d) aggregates metrics.
//!
//! The public entry point is the [`engine`] module: build an [`Engine`]
//! from an [`EngineConfig`] (cores, batch, [`ShardPolicy`],
//! [`PoolMode`], [`BusModel`], mode, seed) and call `run_layer` /
//! `run_network` / `run_batched` / `run_streaming`. One network walk
//! serves every mode; the multi-core pool shards layers by
//! output-channel tiles or output-row bands, fans batched frames out,
//! or pipelines contiguous layer stages across the cores, and prices
//! external bandwidth per the [`bus`] contention model. The 0.2 free
//! functions in [`executor`] / [`scheduler`] are deprecated shims.

pub mod bus;
pub mod engine;
pub mod executor;
pub mod metrics;
pub mod scheduler;

pub use bus::BusModel;
pub use engine::{BatchedResult, CorePool, Engine, EngineConfig, PoolMode, ShardPolicy};
pub use executor::{ExecMode, ExecOptions, NetLayer};
pub use metrics::{LayerResult, NetworkResult, PipelineResult};

// 0.2 compatibility re-exports (deprecated shims, kept one release).
#[allow(deprecated)]
pub use executor::{run_conv_layer, run_network, run_pool_layer};
#[allow(deprecated)]
pub use scheduler::{run_batched, run_conv_layer_mc, run_network_mc, run_pool_layer_mc};
