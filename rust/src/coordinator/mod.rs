//! The coordinator: schedules whole CNN layers onto the core, per the
//! Fig. 2 dataflow — output-channel tiles × input-depth slices × row
//! bands, with PSum spilling and double-buffered DMA streaming.
//!
//! The coordinator is the paper's "software" half: on the silicon ASIP
//! this logic is compiled C code running in slot 0 between kernels; here
//! it is host rust that (a) stages tensors into DM (untimed pokes — the
//! transfer *time* is charged through the analytic DMA overlap model,
//! and the *bytes* through the off-chip I/O counters), (b) presets the
//! task ABI registers, (c) runs the generated kernels on the
//! cycle-accurate core, and (d) aggregates metrics.

//! The multi-core extension lives in [`scheduler`]: a [`CorePool`] of
//! cycle simulators, output-channel tile sharding within a layer, and
//! frame-level batching — the throughput-serving mode the paper's
//! batch-1 setup cannot express.

pub mod executor;
pub mod metrics;
pub mod scheduler;

pub use executor::{run_conv_layer, run_network, run_pool_layer, ExecMode, ExecOptions, NetLayer};
pub use metrics::{LayerResult, NetworkResult};
pub use scheduler::{
    run_batched, run_conv_layer_mc, run_network_mc, run_pool_layer_mc, BatchedResult, CorePool,
};
