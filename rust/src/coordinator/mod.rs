//! The coordinator: schedules whole CNN layers onto the core, per the
//! Fig. 2 dataflow — output-channel tiles × input-depth slices × row
//! bands, with PSum spilling and feasibility-gated DMA double
//! buffering.
//!
//! The coordinator is the paper's "software" half: on the silicon ASIP
//! this logic is compiled C code running in slot 0 between kernels; here
//! it is host rust that (a) stages tensors into DM (untimed pokes — the
//! transfer *time* is charged through the per-iteration DMA timeline,
//! and the *bytes* through the off-chip I/O counters), (b) presets the
//! task ABI registers, (c) runs the generated kernels on the
//! cycle-accurate core, and (d) aggregates metrics.
//!
//! DMA double buffering is **feasibility-gated**, not assumed: the
//! layout planner ([`crate::codegen::layout`]) only allocates a
//! rotation region (`ConvPlan::rot` / `PoolPlan::rot`) when a second
//! filter-block + input-band slot actually fits in the 128 KiB DM
//! beside the working set. When it fits, the executor prices the layer
//! as a serialized **fill** for iteration 0 followed by a **steady**
//! state of `Σ_iter max(compute_iter, dma_next_iter)`; when it does not
//! fit, the stream is honestly serialized as
//! `Σ_iter (compute_iter + dma_iter)`. The fill/steady/serial split is
//! carried through [`metrics::LayerResult`], the [`bus`] contention
//! segments, and [`ops::LayerOp::layer_cost_on`] (which keeps both
//! regimes monotone in cores for the pipeline partition-DP).
//! `EngineConfig::dma_rotation(false)` (CLI `--no-rotation`) forbids
//! rotation globally — outputs are bit-identical, only cycles change.
//!
//! The public entry point is the [`engine`] module: build an [`Engine`]
//! from an [`EngineConfig`] (cores, batch, [`ShardPolicy`],
//! [`PoolMode`], [`BusModel`], mode, seed) and call `run_layer` /
//! `run_network` / `run_batched` / `run_streaming`. One network walk
//! serves every mode; everything layer-kind-specific (conv, pool, FC)
//! lives behind the [`ops::LayerOp`] trait, so the walk, the shard
//! pool, the batched fan-out, the layer pipeline and the [`bus`]
//! contention model are all kind-agnostic. The 0.2 free-function API
//! (and its 0.3 `#[deprecated]` shims) is gone; `tools/
//! check-deprecated.sh` keeps it from coming back.
//!
//! Since 0.5.0 execution is **compile-once**: the engine holds a
//! shape-keyed [`PlanCache`] of compiled layers (layout plan + task
//! programs + tile-analytic profile) and each core owns a [`Scratch`]
//! staging arena, threaded into the executors through [`ExecCtx`] —
//! steady-state batched/streaming frames perform zero codegen and
//! near-zero allocation (see `codegen::compiled`).

pub mod bus;
pub mod engine;
pub mod executor;
pub mod faults;
pub mod metrics;
pub mod ops;

pub use crate::codegen::compiled::{CacheStats, PlanCache, Scratch};
pub use bus::BusModel;
pub use engine::{
    run_multi_streaming, BatchedResult, CorePool, Engine, EngineConfig, PoolMode, ShardPolicy,
    StageCores, TenantRun,
};
pub use executor::{ExecCtx, ExecMode, ExecOptions, NetLayer};
pub use faults::{FaultKind, FaultPlan, FaultReport};
pub use metrics::{LayerResult, MultiTenantResult, NetworkResult, PipelineResult};
pub use ops::LayerOp;
