//! The [`LayerOp`] trait: ONE surface for everything the coordinator
//! needs to know about a layer kind — shapes, MACs, cost-model
//! footprints, synthetic tensor draws, single-core execution, intra-
//! layer sharding, and shard merging. [`ConvLayer`], [`PoolLayer`] and
//! [`FcLayer`] implement it; [`NetLayer::op`] is the single dispatch
//! point. No other code matches on the layer kind, so adding a layer
//! kind (depthwise, residual add, normalization, …) means one new impl
//! here — engine, bus, metrics and report code pick it up unchanged.
//!
//! The Multi-Mode Inference Engine of Ardakani et al. (arXiv:1712.03994)
//! treats conv and FC as two modes of one datapath; this module is the
//! coordinator-level analogue. The FC mode rides the Fig. 2 conv
//! dataflow via [`FcLayer::as_conv`] (input features = depth slices,
//! output neurons = oc tiles), so its shards are *neuron tiles* and its
//! cost is dominated by the weight stream (every weight is used exactly
//! once per frame — heavily DMA-bound).

use crate::codegen::layout;
use crate::codegen::stage;
use crate::core::Cpu;
use crate::model::{ConvLayer, FcLayer, NetLayer, PoolLayer};
use crate::util::XorShift;

use super::bus::BusModel;
use super::engine::ShardPolicy;
use super::executor::{conv_layer, fc_layer, pool_layer, ExecCtx, ExecError, ExecMode, ExecOptions};
use super::metrics::LayerResult;

/// SFU pool tile: 16 channels per vector.
pub(crate) const POOL_GRAIN: usize = 16;

/// One layer kind's behavior behind the coordinator's generic walks.
///
/// `run_solo` executes the whole layer on one core; `shard` splits it
/// into [`Shard`]s for a pool of cores (each shard re-runs `run_solo`
/// on its sub-layer); `merge` scatters shard outputs back and prices
/// the makespan. `draw` defines the layer's slot in the deterministic
/// synthetic-weight stream; `tensor_footprints` and `layer_cost` feed
/// the first-order cost model behind `ShardPolicy::Auto` and the
/// pipeline-stage DP.
pub trait LayerOp {
    /// Layer name (model tables carry static names).
    fn name(&self) -> &'static str;

    /// Kind label for reports: `"conv"`, `"pool"`, `"fc"`, …
    fn kind(&self) -> &'static str;

    /// Input tensor elements (unpadded, as the network walk stages it).
    fn in_elems(&self) -> usize;

    /// Output tensor elements — the layer's contribution to the
    /// activation chain (`out_shape` flattened).
    fn out_elems(&self) -> usize;

    /// Useful MACs of the layer's arithmetic.
    fn macs(&self) -> u64;

    /// `(input, weight, output)` element counts for the first-order
    /// cost model (input counted *padded* where the dataflow stages it
    /// padded). Only relative magnitudes matter.
    fn tensor_footprints(&self) -> (usize, usize, usize);

    /// `(weight, bias)` element counts of the drawable parameter
    /// tensors; `(0, 0)` for weightless layers.
    fn param_elems(&self) -> (usize, usize);

    /// This layer's draw from the synthetic weight stream: weights then
    /// biases, in the crate-wide ranges. THE single definition of the
    /// draw order — every walk consumes the stream through this method,
    /// so tensors are bit-identical across execution modes by
    /// construction. `None` for weightless layers (no stream advance).
    fn draw(&self, rng: &mut XorShift) -> Option<(Vec<i16>, Vec<i32>)> {
        let (w, b) = self.param_elems();
        if w == 0 {
            return None;
        }
        Some((rng.i16_vec(w, -128, 128), rng.i32_vec(b, -1000, 1000)))
    }

    /// Execute the whole layer on one core. `w`/`b` are empty slices
    /// for weightless layers. `ctx` carries the engine's compile-once
    /// plan cache and the core's scratch arena — implementations
    /// derive plans/programs through it instead of compiling per call.
    fn run_solo(
        &self,
        cpu: &mut Cpu,
        x: &[i16],
        w: &[i16],
        b: &[i32],
        opts: ExecOptions,
        ctx: &mut ExecCtx<'_>,
    ) -> Result<LayerResult, ExecError>;

    /// Split the layer into shards for (at most) `want` cores under
    /// `policy`. Shard outputs must tile the output tensor exactly and
    /// reproduce the single-core arithmetic bit-for-bit.
    fn shard(&self, x: &[i16], policy: ShardPolicy, want: usize) -> Vec<Shard>;

    /// Predicted single-core cost: MACs at a calibrated **~2/3
    /// utilization** guess for compute vs tensor footprints over the
    /// bus width for DMA. The two terms combine with the executor's
    /// overlap `max` when the layer's DM plan rotates (the
    /// double-buffered stream hides under compute) and with an honest
    /// `+` when it cannot ([`LayerOp::dma_serialized`]), mirroring the
    /// executor's fill/steady vs serialized pricing. This one
    /// first-order estimate feeds *three* consumers — the `Auto` shard
    /// policy, the legacy one-core-per-stage pipeline DP, and (through
    /// [`LayerOp::layer_cost_on`]) the partition-DP that assigns whole
    /// core *groups* to stages — so they all rank layers consistently.
    /// Only the relative ranking matters.
    fn layer_cost(&self) -> u64 {
        self.layer_cost_on(1)
    }

    /// Predicted per-core cost of this layer sharded across `cores`
    /// cores — the partition-DP's cost surface, derived from the SAME
    /// ~2/3-utilization estimate as [`LayerOp::layer_cost`] (with
    /// `cores == 1` the two are identical by construction). Compute
    /// divides evenly across the group; of the DMA footprint the
    /// filter and output streams divide (each core touches only its
    /// shard's slice under every policy) while the input stream is
    /// conservatively charged in full per core (the oc-tile/neuron-
    /// tile regime — row-band shards would divide it, so this
    /// under-promises, never over-promises, group speedup on
    /// input-heavy layers). Serialized layers add their DMA term
    /// instead of overlapping it; both terms are individually monotone
    /// non-increasing in `cores`, so their sum and their max both are
    /// — the partition-DP's makespan stays monotone in the core budget
    /// in either regime.
    fn layer_cost_on(&self, cores: usize) -> u64 {
        let k = cores.max(1) as u64;
        let (i, w, o) = self.tensor_footprints();
        let comp = (self.macs() * 3 / (2 * crate::PEAK_MACS_PER_CYCLE)).div_ceil(k);
        let bytes = 2 * (i as u64 + (w as u64 + o as u64).div_ceil(k));
        let dma = bytes / crate::mem::EXT_BYTES_PER_CYCLE as u64;
        if self.dma_serialized() { (comp + dma).max(1) } else { comp.max(dma).max(1) }
    }

    /// Does this layer's DMA stream fail to overlap its compute? `true`
    /// when the layer's DM plan cannot hold a rotation shadow (the
    /// second filter-block + input-band staging slot), so the executor
    /// prices its stream serially (`compute + dma`) rather than with
    /// the double-buffered overlap `max`. Kinds with a plan consult it;
    /// the default covers weightless streaming kinds.
    fn dma_serialized(&self) -> bool {
        false
    }

    /// `(bytes, dma requests)` of this layer's per-frame parameter
    /// stream that a pipeline stage's *repeating* schedule can keep
    /// resident in DM across frames — `(0, 0)` when the parameters
    /// don't fit next to the working set (or the layer streams none).
    /// Streaming timing drops both the payload bytes and the elided
    /// requests' DRAM latency from steady-state frames; the fill pass
    /// (first frame through a stage) always pays the full stream. The
    /// engine only credits residency to layers that own their stage
    /// alone: every DM map packs from the same base addresses, so any
    /// co-resident layer's staging would overwrite the tiles each
    /// frame.
    fn resident_param_stream(&self) -> (u64, u64) {
        (0, 0)
    }

    /// Merge executed shard results into the layer's [`LayerResult`]:
    /// accumulate metrics (fault retry/recovery counters included),
    /// cross-check the shards' output checksums when a detecting fault
    /// plan is active, scatter outputs through the placement runs, and
    /// price per-core busy time under the bus model. The shared default
    /// serves every kind.
    fn merge(
        &self,
        results: Vec<LayerResult>,
        placements: &[Vec<(usize, usize)>],
        core_of: &[usize],
        cores: usize,
        mode: ExecMode,
        bus: BusModel,
        faults: Option<&super::faults::FaultPlan>,
    ) -> Result<LayerResult, ExecError> {
        merge_shards(
            self.name(),
            self.out_elems(),
            results,
            placements,
            core_of,
            cores,
            mode,
            bus,
            faults,
        )
    }
}

impl NetLayer {
    /// THE layer-kind dispatch point. All per-kind behavior hangs off
    /// the returned [`LayerOp`]; nothing outside this method and the
    /// trait impls matches on the variant.
    pub fn op(&self) -> &dyn LayerOp {
        match self {
            NetLayer::Conv(l) => l,
            NetLayer::Pool(l) => l,
            NetLayer::Fc(l) => l,
        }
    }

    /// The wrapped layer's name.
    pub fn name(&self) -> &'static str {
        self.op().name()
    }

    /// Kind label for reports (`conv` / `pool` / `fc`).
    pub fn kind(&self) -> &'static str {
        self.op().kind()
    }
}

/// A shard's view of the layer input.
pub enum ShardInput {
    /// Borrow `[lo, hi)` of the caller's tensor (contiguous slices —
    /// oc-tile group slices and pool slabs — stay zero-copy).
    Range(usize, usize),
    /// Shard-private gathered tensor (row bands are strided in the full
    /// tensor, so they are materialized per shard).
    Owned(Vec<i16>),
}

impl ShardInput {
    pub fn resolve<'a>(&'a self, x: &'a [i16]) -> &'a [i16] {
        match self {
            ShardInput::Range(lo, hi) => &x[*lo..*hi],
            ShardInput::Owned(v) => v,
        }
    }
}

/// One unit of sharded work: a sub-layer plus the tensor ranges it
/// reads and the output runs it produces. Kind-agnostic — the engine
/// runs `sub.op().run_solo(...)` on the resolved slices.
pub struct Shard {
    /// The sub-layer this shard executes (same kind machinery as the
    /// full layer, or a lowered kind — FC shards are 1×1 conv tiles).
    pub sub: NetLayer,
    /// The shard's input view.
    pub input: ShardInput,
    /// Half-open weight element range in the full weight tensor.
    pub w: (usize, usize),
    /// Half-open bias element range in the full bias tensor.
    pub b: (usize, usize),
    /// `(dst offset, len)` runs in the full output tensor; the shard's
    /// output is consumed sequentially across the runs.
    pub placement: Vec<(usize, usize)>,
}

// ---------------------------------------------------------------------------
// shared shard/cost machinery
// ---------------------------------------------------------------------------

/// Split `units` units into at most `want` balanced contiguous chunks,
/// front-loading the remainder: half-open `(u0, u1)` unit ranges. The
/// single partitioner behind every shard axis (oc tiles, row bands,
/// pool slabs, neuron tiles) — deterministic in its inputs.
fn balanced_chunks(units: usize, want: usize) -> Vec<(usize, usize)> {
    let k = want.max(1).min(units.max(1));
    let (base, extra) = (units / k, units % k);
    let mut chunks = Vec::with_capacity(k);
    let mut u0 = 0usize;
    for ci in 0..k {
        let n = base + usize::from(ci < extra);
        if n > 0 {
            chunks.push((u0, u0 + n));
            u0 += n;
        }
    }
    chunks
}

/// Tile-aligned contiguous oc ranges within each group:
/// `(group, oc0, oc1)`. Deterministic in (layer, want).
fn octile_specs(layer: &ConvLayer, want: usize) -> Vec<(usize, usize, usize)> {
    let g = layer.groups;
    let lg = layer.per_group();
    let ocg = lg.oc;
    // Tile-align chunks to the planner's oc grain so shards don't add
    // padding lanes the single-core schedule wouldn't have.
    let grain = layout::plan(&lg).map(|p| p.variant.ocs()).unwrap_or(16);
    let units = ocg.div_ceil(grain).max(1);
    let mut specs = Vec::new();
    for gi in 0..g {
        for (u0, u1) in balanced_chunks(units, want.div_ceil(g)) {
            let oc0 = (u0 * grain).min(ocg);
            let oc1 = (u1 * grain).min(ocg);
            if oc0 < oc1 {
                specs.push((gi, oc0, oc1));
            }
        }
    }
    specs
}

/// Balanced contiguous output-row bands `(r0, r1)` over `rows` rows.
fn rowband_specs(rows: usize, want: usize) -> Vec<(usize, usize)> {
    balanced_chunks(rows, want)
}

fn conv_shards_octile(layer: &ConvLayer, want: usize) -> Vec<Shard> {
    let lg = layer.per_group();
    let (icg, ocg) = (lg.ic, lg.oc);
    let ohw = layer.oh() * layer.ow();
    octile_specs(layer, want)
        .into_iter()
        .map(|(gi, oc0, oc1)| {
            let oc_abs = gi * ocg + oc0;
            Shard {
                sub: NetLayer::Conv(ConvLayer {
                    ic: icg,
                    oc: oc1 - oc0,
                    groups: 1,
                    ..layer.clone()
                }),
                input: ShardInput::Range(
                    gi * icg * layer.ih * layer.iw,
                    (gi + 1) * icg * layer.ih * layer.iw,
                ),
                w: (
                    oc_abs * icg * layer.fh * layer.fw,
                    (oc_abs + (oc1 - oc0)) * icg * layer.fh * layer.fw,
                ),
                b: (oc_abs, oc_abs + (oc1 - oc0)),
                placement: vec![(oc_abs * ohw, (oc1 - oc0) * ohw)],
            }
        })
        .collect()
}

/// Row-band conv shards: the sub-layer convolves a pre-padded row slice
/// (its own halo included) with `pad = 0`, which is arithmetically
/// identical to the full layer restricted to those output rows — so
/// outputs stay bit-exact and per-shard MACs tile the layer exactly.
fn conv_shards_rowband(layer: &ConvLayer, x: &[i16], want: usize) -> Vec<Shard> {
    let (oh, ow) = (layer.oh(), layer.ow());
    let (ihp, iwp) = (layer.ihp(), layer.iwp());
    let xp = stage::pad_input(layer, x);
    let w_all = layer.oc * (layer.ic / layer.groups) * layer.fh * layer.fw;
    rowband_specs(oh, want)
        .into_iter()
        .map(|(oh0, oh1)| {
            let rows = oh1 - oh0;
            let in_r0 = oh0 * layer.stride;
            let in_rows = (rows - 1) * layer.stride + layer.fh;
            let mut xin = vec![0i16; layer.ic * in_rows * iwp];
            for (c, dst) in xin.chunks_exact_mut(in_rows * iwp).enumerate() {
                let src = (c * ihp + in_r0) * iwp;
                dst.copy_from_slice(&xp[src..src + in_rows * iwp]);
            }
            Shard {
                sub: NetLayer::Conv(ConvLayer { ih: in_rows, iw: iwp, pad: 0, ..layer.clone() }),
                input: ShardInput::Owned(xin),
                w: (0, w_all),
                b: (0, layer.oc),
                placement: (0..layer.oc).map(|o| ((o * oh + oh0) * ow, rows * ow)).collect(),
            }
        })
        .collect()
}

fn pool_shards_slab(layer: &PoolLayer, want: usize) -> Vec<Shard> {
    let (ih, iw) = (layer.ih, layer.iw);
    let (oh, ow) = (layer.oh(), layer.ow());
    let units = layer.ic.div_ceil(POOL_GRAIN).max(1);
    let mut shards = Vec::new();
    for (u0, u1) in balanced_chunks(units, want) {
        let c0 = (u0 * POOL_GRAIN).min(layer.ic);
        let c1 = (u1 * POOL_GRAIN).min(layer.ic);
        if c0 < c1 {
            shards.push(Shard {
                sub: NetLayer::Pool(PoolLayer { ic: c1 - c0, ..layer.clone() }),
                input: ShardInput::Range(c0 * ih * iw, c1 * ih * iw),
                w: (0, 0),
                b: (0, 0),
                placement: vec![(c0 * oh * ow, (c1 - c0) * oh * ow)],
            });
        }
    }
    shards
}

fn pool_shards_rowband(layer: &PoolLayer, x: &[i16], want: usize) -> Vec<Shard> {
    let (oh, ow) = (layer.oh(), layer.ow());
    rowband_specs(oh, want)
        .into_iter()
        .map(|(oy0, oy1)| {
            let rows = oy1 - oy0;
            let in_r0 = oy0 * layer.stride;
            let in_rows = (rows - 1) * layer.stride + layer.size;
            let mut xin = vec![0i16; layer.ic * in_rows * layer.iw];
            for (c, dst) in xin.chunks_exact_mut(in_rows * layer.iw).enumerate() {
                let src = (c * layer.ih + in_r0) * layer.iw;
                dst.copy_from_slice(&x[src..src + in_rows * layer.iw]);
            }
            Shard {
                sub: NetLayer::Pool(PoolLayer { ih: in_rows, ..layer.clone() }),
                input: ShardInput::Owned(xin),
                w: (0, 0),
                b: (0, 0),
                placement: (0..layer.ic).map(|c| ((c * oh + oy0) * ow, rows * ow)).collect(),
            }
        })
        .collect()
}

/// First-order shard cost for the `Auto` policy and the default
/// [`LayerOp::layer_cost`]: compute from MACs at a calibrated ~2/3
/// utilization, DMA from tensor footprints over the bus width,
/// combined with the executor's overlap `max`. Only the relative
/// ranking matters.
pub(crate) fn conv_cost(macs: u64, in_elems: usize, w_elems: usize, out_elems: usize) -> u64 {
    let comp = macs * 3 / (2 * crate::PEAK_MACS_PER_CYCLE);
    let bytes = 2 * (in_elems + w_elems + out_elems) as u64;
    comp.max(bytes / crate::mem::EXT_BYTES_PER_CYCLE as u64)
}

/// Makespan of round-robining `costs` over `cores` (the real shard
/// assignment order).
fn predicted_makespan(costs: &[u64], cores: usize) -> u64 {
    let n = cores.max(1);
    let mut load = vec![0u64; n];
    for (i, c) in costs.iter().enumerate() {
        load[i % n] += c;
    }
    load.into_iter().max().unwrap_or(0)
}

pub(crate) fn resolve_conv_policy(
    policy: ShardPolicy,
    layer: &ConvLayer,
    cores: usize,
) -> ShardPolicy {
    if policy != ShardPolicy::Auto {
        return policy;
    }
    let lg = layer.per_group();
    let (oh, ow) = (layer.oh(), layer.ow());
    let w_per_oc = lg.ic * layer.fh * layer.fw;
    let oc_costs: Vec<u64> = octile_specs(layer, cores)
        .iter()
        .map(|&(_, oc0, oc1)| {
            let oc = oc1 - oc0;
            conv_cost(
                (oc * w_per_oc * oh * ow) as u64,
                lg.ic * layer.ihp() * layer.iwp(),
                oc * w_per_oc,
                oc * oh * ow,
            )
        })
        .collect();
    let rb_costs: Vec<u64> = rowband_specs(oh, cores)
        .iter()
        .map(|&(oh0, oh1)| {
            let rows = oh1 - oh0;
            let in_rows = (rows - 1) * layer.stride + layer.fh;
            conv_cost(
                (layer.oc * w_per_oc * rows * ow) as u64,
                layer.ic * in_rows * layer.iwp(),
                layer.oc * w_per_oc,
                layer.oc * rows * ow,
            )
        })
        .collect();
    if predicted_makespan(&rb_costs, cores) < predicted_makespan(&oc_costs, cores) {
        ShardPolicy::RowBand
    } else {
        ShardPolicy::OcTile
    }
}

fn resolve_pool_policy(policy: ShardPolicy, layer: &PoolLayer, cores: usize) -> ShardPolicy {
    match policy {
        // slabs cannot fill the pool when there are fewer 16-channel
        // units than cores; row bands always can in practice
        ShardPolicy::Auto => {
            if layer.ic.div_ceil(POOL_GRAIN) < cores {
                ShardPolicy::RowBand
            } else {
                ShardPolicy::OcTile
            }
        }
        p => p,
    }
}

/// The ONE shard-merge helper behind [`LayerOp::merge`]: accumulates
/// metrics, scatters shard outputs through their placement runs, and
/// prices per-core busy time under the bus model. The layer's latency
/// is the makespan of the slowest core.
///
/// With a detecting fault plan active, each shard's output checksum
/// (stamped at its priced verification, `faults::apply_layer_faults`)
/// is recomputed and cross-checked here — a mismatch means the data
/// changed between the shard's verified production and the merge
/// hand-off, which bounded per-core retry cannot repair, so it
/// surfaces as [`ExecError::Corrupted`]. Fault retry/recovery counters
/// sum like every other shard metric; the recovery cycles themselves
/// ride inside each shard's `cycles`, so they flow through the bus
/// segment decomposition (serialized on the owning core) and the
/// makespan without any special-casing.
#[allow(clippy::too_many_arguments)]
fn merge_shards(
    name: &'static str,
    out_len: usize,
    results: Vec<LayerResult>,
    placements: &[Vec<(usize, usize)>],
    core_of: &[usize],
    cores: usize,
    mode: ExecMode,
    bus: BusModel,
    faults: Option<&super::faults::FaultPlan>,
) -> Result<LayerResult, ExecError> {
    use super::bus::{core_busy, Segment};
    use super::metrics::add_stats;

    let check = faults.is_some_and(|p| p.detect);
    let mut res = LayerResult { name, ..Default::default() };
    // only FullCycle produces shard outputs worth merging
    let mut out = if mode == ExecMode::FullCycle { vec![0i16; out_len] } else { Vec::new() };
    let mut segs: Vec<Vec<Segment>> = (0..cores).map(|_| Vec::new()).collect();
    for (idx, r) in results.into_iter().enumerate() {
        if check && !r.out.is_empty() && super::faults::checksum_words(&r.out) != r.out_checksum {
            return Err(ExecError::Corrupted { layer: name.to_string() });
        }
        res.compute_cycles += r.compute_cycles;
        res.dma_cycles += r.dma_cycles;
        res.dma_fill_bytes += r.dma_fill_bytes;
        res.dma_fill_cycles += r.dma_fill_cycles;
        res.dma_serial_bytes += r.dma_serial_bytes;
        res.dma_serial_cycles += r.dma_serial_cycles;
        res.macs += r.macs;
        res.io_in += r.io_in;
        res.io_out += r.io_out;
        res.fault_retries += r.fault_retries;
        res.fault_recovery_cycles += r.fault_recovery_cycles;
        res.stats = add_stats(&res.stats, &r.stats);
        segs[core_of[idx]].push(Segment::of_layer(&r));
        if !r.out.is_empty() {
            let mut src = 0usize;
            for &(dst, len) in &placements[idx] {
                out[dst..dst + len].copy_from_slice(&r.out[src..src + len]);
                src += len;
            }
        }
    }
    let acct = core_busy(&segs, bus);
    res.cycles = acct.busy.iter().copied().max().unwrap_or(0);
    res.core_cycles = acct.busy;
    if mode == ExecMode::FullCycle {
        res.out = out;
    }
    if check {
        res.out_checksum = super::faults::checksum_words(&res.out);
    }
    Ok(res)
}

// ---------------------------------------------------------------------------
// the three layer kinds
// ---------------------------------------------------------------------------

impl LayerOp for ConvLayer {
    fn name(&self) -> &'static str {
        self.name
    }

    fn kind(&self) -> &'static str {
        "conv"
    }

    fn in_elems(&self) -> usize {
        self.ic * self.ih * self.iw
    }

    fn out_elems(&self) -> usize {
        self.oc * self.oh() * self.ow()
    }

    fn macs(&self) -> u64 {
        ConvLayer::macs(self)
    }

    fn tensor_footprints(&self) -> (usize, usize, usize) {
        let lg = self.per_group();
        (
            self.ic * self.ihp() * self.iwp(),
            self.oc * lg.ic * self.fh * self.fw,
            self.oc * self.oh() * self.ow(),
        )
    }

    fn param_elems(&self) -> (usize, usize) {
        (self.oc * (self.ic / self.groups) * self.fh * self.fw, self.oc)
    }

    fn run_solo(
        &self,
        cpu: &mut Cpu,
        x: &[i16],
        w: &[i16],
        b: &[i32],
        opts: ExecOptions,
        ctx: &mut ExecCtx<'_>,
    ) -> Result<LayerResult, ExecError> {
        conv_layer(cpu, self, x, w, b, opts, ctx)
    }

    fn shard(&self, x: &[i16], policy: ShardPolicy, want: usize) -> Vec<Shard> {
        match resolve_conv_policy(policy, self, want) {
            ShardPolicy::RowBand => conv_shards_rowband(self, x, want),
            _ => conv_shards_octile(self, want),
        }
    }

    /// A conv stream serializes when its per-group DM plan cannot hold
    /// the rotation shadow next to the working map (the executor then
    /// charges `compute + dma`). Unplannable layers keep the overlap
    /// estimate — they cannot execute at all, so the ranking is moot.
    fn dma_serialized(&self) -> bool {
        layout::plan(&self.per_group()).is_ok_and(|p| p.rot.is_none())
    }
}

impl LayerOp for PoolLayer {
    fn name(&self) -> &'static str {
        self.name
    }

    fn kind(&self) -> &'static str {
        "pool"
    }

    fn in_elems(&self) -> usize {
        self.ic * self.ih * self.iw
    }

    fn out_elems(&self) -> usize {
        self.ic * self.oh() * self.ow()
    }

    // pool layers carry no MACs; their cost is the SFU-hidden streaming
    // of the tensor through the bus
    fn macs(&self) -> u64 {
        0
    }

    fn tensor_footprints(&self) -> (usize, usize, usize) {
        (self.ic * self.ih * self.iw, 0, self.ic * self.oh() * self.ow())
    }

    fn param_elems(&self) -> (usize, usize) {
        (0, 0)
    }

    fn run_solo(
        &self,
        cpu: &mut Cpu,
        x: &[i16],
        _w: &[i16],
        _b: &[i32],
        opts: ExecOptions,
        ctx: &mut ExecCtx<'_>,
    ) -> Result<LayerResult, ExecError> {
        pool_layer(cpu, self, x, opts, ctx)
    }

    fn shard(&self, x: &[i16], policy: ShardPolicy, want: usize) -> Vec<Shard> {
        match resolve_pool_policy(policy, self, want) {
            ShardPolicy::RowBand => pool_shards_rowband(self, x, want),
            _ => pool_shards_slab(self, want),
        }
    }

    /// A pool stream serializes when DM cannot hold a second
    /// input-rows + output-row staging pair.
    fn dma_serialized(&self) -> bool {
        crate::codegen::pool::plan_pool(self).is_ok_and(|p| p.rot.is_none())
    }
}

impl LayerOp for FcLayer {
    fn name(&self) -> &'static str {
        self.name
    }

    fn kind(&self) -> &'static str {
        "fc"
    }

    fn in_elems(&self) -> usize {
        self.in_features
    }

    fn out_elems(&self) -> usize {
        self.out_features
    }

    fn macs(&self) -> u64 {
        FcLayer::macs(self)
    }

    /// Weights dominate: each of the `in·out` weights streams in once
    /// per frame, so FC layers are heavily DMA-bound and the pipeline
    /// stage DP isolates the FC tail onto its own core(s).
    fn tensor_footprints(&self) -> (usize, usize, usize) {
        (self.in_features, self.in_features * self.out_features, self.out_features)
    }

    fn param_elems(&self) -> (usize, usize) {
        (self.in_features * self.out_features, self.out_features)
    }

    fn run_solo(
        &self,
        cpu: &mut Cpu,
        x: &[i16],
        w: &[i16],
        b: &[i32],
        opts: ExecOptions,
        ctx: &mut ExecCtx<'_>,
    ) -> Result<LayerResult, ExecError> {
        fc_layer(cpu, self, x, w, b, opts, ctx)
    }

    /// FC weight residency for pipelined stages: when every
    /// (neuron-tile, slice) filter block of the 1×1 lowering fits in
    /// DM *next to* one task's full working map, a stage's repeating
    /// schedule keeps the whole weight set resident across frames and
    /// steady-state frames skip the re-stream. Returns the per-frame
    /// filter+bias stream bytes the executor would charge
    /// ([`crate::codegen::ConvPlan::filter_stream_bytes`] — the bytes
    /// saved) plus the elided stream's descriptor count (one per
    /// (tile, slice) block — their DRAM latency disappears with them),
    /// or `(0, 0)` when the tiles don't fit: fc6-scale layers never
    /// fit the 128 KB DM, small heads (e.g. a 256→10 classifier) do.
    fn resident_param_stream(&self) -> (u64, u64) {
        let Ok(p) = layout::plan(&self.as_conv()) else { return (0, 0) };
        // The per-frame stream the executor charges for filters+bias
        // (the 1×1 map has one band, so each block streams once). The
        // same number is each staged block's DM footprint — filter
        // vectors + the 2 FIFO slack vectors + the 32 B bias — so it
        // doubles as the residency fit check, conservatively on top of
        // the full one-task DM map (which already holds one block —
        // two when the plan carries a rotation shadow, hence the
        // rot-aware end).
        let bytes =
            p.n_tiles as u64 * (0..p.m).map(|mi| p.filter_stream_bytes(mi)).sum::<u64>();
        let end = p.rot.as_ref().map_or(p.dm.end, |r| r.end);
        if end as u64 + bytes > crate::mem::DM_BYTES as u64 {
            return (0, 0);
        }
        (bytes, (p.n_tiles * p.m) as u64)
    }

    /// FC streams serialize exactly when the 1×1 lowering's plan cannot
    /// rotate. fc6-scale filter blocks are sliced to fit DM, so even
    /// they double-buffer; the override exists so an FC that ever
    /// out-sizes the shadow prices honestly.
    fn dma_serialized(&self) -> bool {
        layout::plan(&self.as_conv()).is_ok_and(|p| p.rot.is_none())
    }

    /// Neuron tiles — the oc-tile machinery on the 1×1 lowering. Every
    /// policy resolves to neuron tiling: row bands are degenerate on a
    /// 1×1 output map (a single band = no parallelism), so `Auto` and
    /// an explicit `RowBand` both fall back to tiling the output
    /// neurons.
    fn shard(&self, _x: &[i16], _policy: ShardPolicy, want: usize) -> Vec<Shard> {
        conv_shards_octile(&self.as_conv(), want)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_partition(macs: u64, out_elems: usize, shards: &[Shard], what: &str) {
        let shard_macs: u64 = shards.iter().map(|s| s.sub.op().macs()).sum();
        assert_eq!(shard_macs, macs, "{what}: shard MACs must tile the layer");
        let mut marks = vec![false; out_elems];
        for s in shards {
            for &(dst, len) in &s.placement {
                for m in &mut marks[dst..dst + len] {
                    assert!(!*m, "{what}: overlapping shard output");
                    *m = true;
                }
            }
        }
        assert!(marks.iter().all(|&m| m), "{what}: uncovered outputs");
    }

    #[test]
    fn octile_shards_partition_the_layer() {
        for (l, want) in [
            (ConvLayer::new("d", 8, 16, 16, 64, 3, 3, 1, 1, 1), 4),
            (ConvLayer::new("g", 8, 13, 13, 32, 3, 3, 1, 1, 2), 4),
            (ConvLayer::new("tiny", 4, 10, 10, 16, 3, 3, 1, 1, 1), 8),
        ] {
            let shards = conv_shards_octile(&l, want);
            check_partition(l.macs(), LayerOp::out_elems(&l), &shards, l.name);
        }
    }

    #[test]
    fn rowband_shards_partition_the_layer() {
        for (l, want) in [
            (ConvLayer::new("d", 8, 16, 16, 64, 3, 3, 1, 1, 1), 4),
            (ConvLayer::new("g", 8, 13, 13, 32, 3, 3, 1, 1, 2), 4),
            (ConvLayer::new("s2", 3, 23, 23, 16, 5, 5, 2, 2, 1), 3),
            (ConvLayer::new("thin", 4, 6, 10, 16, 3, 3, 1, 1, 1), 8),
        ] {
            let x = vec![0i16; l.ic * l.ih * l.iw];
            let shards = conv_shards_rowband(&l, &x, want);
            check_partition(l.macs(), LayerOp::out_elems(&l), &shards, l.name);
            // every shard sees the full filter set and a row halo that
            // fits the padded input
            for s in &shards {
                assert_eq!(s.w.1 - s.w.0, l.oc * (l.ic / l.groups) * l.fh * l.fw);
                let NetLayer::Conv(sub) = &s.sub else { panic!("row-band sub must be conv") };
                assert!(sub.ih <= l.ihp());
                assert_eq!(sub.ow(), l.ow());
            }
        }
    }

    #[test]
    fn fc_shards_are_neuron_tiles_under_every_policy() {
        let fc = FcLayer::new("fct", 96, 72);
        let x = vec![0i16; 96];
        for policy in [ShardPolicy::OcTile, ShardPolicy::RowBand, ShardPolicy::Auto] {
            let shards = LayerOp::shard(&fc, &x, policy, 3);
            assert!(shards.len() > 1, "{policy:?}: FC must actually parallelize");
            check_partition(fc.macs(), fc.out_features, &shards, fc.name);
            // every shard is a contiguous neuron tile reading the full
            // input and its own weight rows
            let mut covered = 0usize;
            for s in &shards {
                let NetLayer::Conv(sub) = &s.sub else { panic!("FC sub must be the 1×1 conv") };
                assert_eq!((sub.ic, sub.fh, sub.fw, sub.ih, sub.iw), (96, 1, 1, 1, 1));
                assert_eq!(s.w.1 - s.w.0, sub.oc * 96, "weight rows match the neuron tile");
                covered += sub.oc;
            }
            assert_eq!(covered, fc.out_features);
        }
    }

    #[test]
    fn auto_policy_picks_rowband_for_shallow_input_layers() {
        // VGG conv1_1-like: 3 input channels, huge spatial extent — the
        // oc-tile policy replicates the whole input per core and goes
        // DMA-bound; row bands divide it
        let early = ConvLayer::new("c11", 3, 224, 224, 64, 3, 3, 1, 1, 1);
        assert_eq!(resolve_conv_policy(ShardPolicy::Auto, &early, 4), ShardPolicy::RowBand);
        // AlexNet conv1-like (3 channels in, 11x11 stride-4): the other
        // canonical few-output-channel input layer must also go row-band
        let alex1 = ConvLayer::new("aconv1", 3, 227, 227, 96, 11, 11, 4, 0, 1);
        assert_eq!(resolve_conv_policy(ShardPolicy::Auto, &alex1, 4), ShardPolicy::RowBand);
        // deep, spatially small layers keep the oc-tile policy
        let deep = ConvLayer::new("c53", 512, 14, 14, 512, 3, 3, 1, 1, 1);
        assert_eq!(resolve_conv_policy(ShardPolicy::Auto, &deep, 4), ShardPolicy::OcTile);
        // explicit policies pass through untouched
        assert_eq!(resolve_conv_policy(ShardPolicy::RowBand, &deep, 4), ShardPolicy::RowBand);
    }

    #[test]
    fn dispatch_and_kind_labels() {
        let layers = [
            NetLayer::Conv(ConvLayer::new("c", 4, 8, 8, 16, 3, 3, 1, 1, 1)),
            NetLayer::Pool(PoolLayer { name: "p", ic: 16, ih: 8, iw: 8, size: 2, stride: 2 }),
            NetLayer::Fc(FcLayer::new("f", 256, 10)),
        ];
        assert_eq!(layers.iter().map(|l| l.kind()).collect::<Vec<_>>(), ["conv", "pool", "fc"]);
        assert_eq!(layers.iter().map(|l| l.name()).collect::<Vec<_>>(), ["c", "p", "f"]);
        // shapes chain through the pool→fc flatten
        assert_eq!(layers[1].op().out_elems(), 16 * 4 * 4);
        assert_eq!(layers[2].op().in_elems(), 256);
        // weightless layers draw nothing; weighted layers draw w then b
        let mut rng = XorShift::new(1);
        assert!(layers[1].op().draw(&mut rng).is_none());
        let (w, b) = layers[2].op().draw(&mut rng).unwrap();
        assert_eq!((w.len(), b.len()), (2560, 10));
    }

    #[test]
    fn fc_weight_residency_gates_on_dm_fit() {
        // a small head's weight tiles fit in DM next to the working
        // set: residency saves exactly the per-frame filter+bias
        // stream (the executor's own formula) and its descriptors
        let small = FcLayer::new("head", 256, 10);
        let p = layout::plan(&small.as_conv()).unwrap();
        assert_eq!(p.m, 1, "a 256-feature head must not slice");
        let bytes: u64 =
            p.n_tiles as u64 * (0..p.m).map(|mi| p.filter_stream_bytes(mi)).sum::<u64>();
        assert!(bytes > 0);
        assert_eq!(
            LayerOp::resident_param_stream(&small),
            (bytes, (p.n_tiles * p.m) as u64)
        );
        // fc6-scale weights can never sit in the 128 KB DM
        let fc6 = FcLayer::new("fc6", 9216, 4096);
        assert_eq!(LayerOp::resident_param_stream(&fc6), (0, 0));
        // conv and pool layers keep the streaming default
        let conv = ConvLayer::new("c", 8, 16, 16, 16, 3, 3, 1, 1, 1);
        assert_eq!(LayerOp::resident_param_stream(&conv), (0, 0));
        let pool = PoolLayer { name: "p", ic: 16, ih: 8, iw: 8, size: 2, stride: 2 };
        assert_eq!(LayerOp::resident_param_stream(&pool), (0, 0));
    }

    #[test]
    fn serialized_streams_price_as_compute_plus_dma() {
        // the tall-filter/wide-row witness cannot hold a rotation
        // shadow in DM, so its cost estimate adds the stream instead
        // of hiding it under compute
        let tall = ConvLayer::new("tall", 1, 31, 350, 16, 31, 1, 1, 0, 1);
        assert!(LayerOp::dma_serialized(&tall), "witness must serialize");
        let (i, w, o) = LayerOp::tensor_footprints(&tall);
        let comp = LayerOp::macs(&tall) * 3 / (2 * crate::PEAK_MACS_PER_CYCLE);
        let dma = 2 * (i + w + o) as u64 / crate::mem::EXT_BYTES_PER_CYCLE as u64;
        assert_eq!(LayerOp::layer_cost(&tall), (comp + dma).max(1));
        assert!(LayerOp::layer_cost(&tall) > comp.max(dma), "sum must exceed the overlap max");
        // the serialized branch stays monotone non-increasing in cores
        // (the partition-DP's correctness precondition)
        let mut prev = u64::MAX;
        for k in 1..=6usize {
            let c = LayerOp::layer_cost_on(&tall, k);
            assert!(c <= prev, "{k} cores: cost {c} rose above {prev}");
            prev = c;
        }
        // a rotatable conv keeps the overlap max
        let conv = ConvLayer::new("c", 64, 56, 56, 64, 3, 3, 1, 1, 1);
        assert!(!LayerOp::dma_serialized(&conv));
        let (ci, cw, co) = LayerOp::tensor_footprints(&conv);
        assert_eq!(LayerOp::layer_cost(&conv), conv_cost(LayerOp::macs(&conv), ci, cw, co).max(1));
        // fc6's sliced filter blocks double-buffer, so the FC tail
        // keeps its DMA-bound overlap estimate
        assert!(!LayerOp::dma_serialized(&FcLayer::new("fc6", 9216, 4096)));
        // benchmark pools rotate too
        let pool = PoolLayer { name: "p", ic: 64, ih: 112, iw: 112, size: 2, stride: 2 };
        assert!(!LayerOp::dma_serialized(&pool));
    }

    #[test]
    fn fc_layer_cost_is_weight_dma_bound() {
        // fc6-scale: 9216·4096 weights stream once per frame — the DMA
        // term (2 B/elem over the bus width) must dominate the MACs-at-
        // 2/3-utilization compute term by a wide margin
        let fc = FcLayer::new("fc6", 9216, 4096);
        let (i, w, o) = LayerOp::tensor_footprints(&fc);
        let dma = 2 * (i + w + o) as u64 / crate::mem::EXT_BYTES_PER_CYCLE as u64;
        let comp = fc.macs() * 3 / (2 * crate::PEAK_MACS_PER_CYCLE);
        assert!(dma > 2 * comp, "fc6 must be DMA-bound: dma {dma} vs comp {comp}");
        assert_eq!(LayerOp::layer_cost(&fc), dma.max(comp).max(1));
        // and a same-MACs conv is NOT dominated by its weight stream
        let conv = ConvLayer::new("c", 64, 56, 56, 64, 3, 3, 1, 1, 1);
        let (ci, cw, co) = LayerOp::tensor_footprints(&conv);
        assert!(cw < ci + co, "conv weights must not dominate its footprints");
    }
}
