//! Multi-core, batched inference coordinator.
//!
//! The paper targets batch-1, single-core latency (Sec. III); serving
//! heavy traffic instead wants **throughput**: several ConvAix cores in
//! one chip, each with its private DM/PM/line buffer and external-bus
//! port (the partitioned-multi-array organization of Shen et al.,
//! FPGA'17 — resource partitioning beats one monolithic array).
//!
//! Two parallelism axes are offered, both deterministic:
//!
//! * **Layer sharding** ([`run_conv_layer_mc`] / [`run_pool_layer_mc`] /
//!   [`run_network_mc`]): one frame, each layer's output channels split
//!   into tile-aligned contiguous shards, one sub-layer per shard,
//!   round-robin over the core pool. Outputs and MAC counts are
//!   bit-identical to the single-core path — every output channel is
//!   produced by exactly one core running exactly the kernel the
//!   single-core executor would run for that tile range. Layer latency
//!   is the **makespan** (max per-core busy cycles).
//! * **Frame batching** ([`run_batched`]): independent frames fanned out
//!   over the cores, each core running whole networks back to back —
//!   the highest-throughput mode since it needs no intra-layer
//!   synchronization at all.
//!
//! Simulation itself runs on real host threads (`std::thread::scope`),
//! so wall-clock speeds up alongside the modeled cycles
//! (`benches/multicore.rs` sweeps it).
//!
//! Modeling assumption: cores are fully partitioned — no shared
//! external-bus contention between them (each shard still pays the full
//! analytic DMA model for its own traffic, see `executor::dma_cycles`).

use std::thread;

use crate::codegen::layout;
use crate::core::Cpu;
use crate::model::{ConvLayer, PoolLayer};

use super::executor::{
    run_conv_layer, run_network, run_pool_layer, ExecError, ExecMode, ExecOptions, NetLayer,
};
use super::metrics::{add_stats, LayerResult, NetworkResult};

/// A pool of independent ConvAix cores (one cycle simulator each).
pub struct CorePool {
    cpus: Vec<Cpu>,
}

impl CorePool {
    /// Build a pool of `cores` cores (min 1), each with its own
    /// external-memory model of `ext_capacity` bytes.
    pub fn new(cores: usize, ext_capacity: usize) -> Self {
        let cores = cores.max(1);
        Self { cpus: (0..cores).map(|_| Cpu::new(ext_capacity)).collect() }
    }

    pub fn cores(&self) -> usize {
        self.cpus.len()
    }

    /// Core 0 — the single-core fallback path.
    pub fn cpu0(&mut self) -> &mut Cpu {
        &mut self.cpus[0]
    }
}

/// One unit of sharded conv work: a dense sub-layer covering a
/// contiguous output-channel range (of one group, for grouped layers),
/// plus the element ranges it reads/writes in the full tensors.
struct ConvShard {
    sub: ConvLayer,
    x0: usize,
    x1: usize,
    w0: usize,
    w1: usize,
    b0: usize,
    b1: usize,
    out0: usize,
    out_len: usize,
}

/// Split `layer` into at most `want`-ish shards at output-channel tile
/// granularity. Grouped layers shard within each group (groups never
/// mix input slices). Deterministic: depends only on (layer, want).
fn conv_shards(layer: &ConvLayer, want: usize) -> Vec<ConvShard> {
    let g = layer.groups;
    let lg = layer.per_group();
    let (icg, ocg) = (lg.ic, lg.oc);
    let ohw = layer.oh() * layer.ow();
    // Tile-align chunks to the planner's oc grain so shards don't add
    // padding lanes the single-core schedule wouldn't have.
    let grain = layout::plan(&lg).map(|p| p.variant.ocs()).unwrap_or(16);
    let units = ocg.div_ceil(grain).max(1);
    let k = want.div_ceil(g).max(1).min(units);
    let (base, extra) = (units / k, units % k);

    let mut shards = Vec::with_capacity(g * k);
    for gi in 0..g {
        let mut u0 = 0usize;
        for ci in 0..k {
            let len_u = base + usize::from(ci < extra);
            let oc0 = (u0 * grain).min(ocg);
            let oc1 = ((u0 + len_u) * grain).min(ocg);
            u0 += len_u;
            if oc0 >= oc1 {
                continue;
            }
            let oc_abs = gi * ocg + oc0;
            let sub = ConvLayer { ic: icg, oc: oc1 - oc0, groups: 1, ..layer.clone() };
            shards.push(ConvShard {
                sub,
                x0: gi * icg * layer.ih * layer.iw,
                x1: (gi + 1) * icg * layer.ih * layer.iw,
                w0: oc_abs * icg * layer.fh * layer.fw,
                w1: (oc_abs + (oc1 - oc0)) * icg * layer.fh * layer.fw,
                b0: oc_abs,
                b1: oc_abs + (oc1 - oc0),
                out0: oc_abs * ohw,
                out_len: (oc1 - oc0) * ohw,
            });
        }
    }
    shards
}

/// One unit of sharded pool work: a contiguous 16-channel-aligned slab.
struct PoolShard {
    sub: PoolLayer,
    c0: usize,
    c1: usize,
}

fn pool_shards(layer: &PoolLayer, want: usize) -> Vec<PoolShard> {
    const GRAIN: usize = 16; // SFU pool tile: 16 channels per vector
    let units = layer.ic.div_ceil(GRAIN).max(1);
    let k = want.max(1).min(units);
    let (base, extra) = (units / k, units % k);
    let mut shards = Vec::with_capacity(k);
    let mut u0 = 0usize;
    for ci in 0..k {
        let len_u = base + usize::from(ci < extra);
        let c0 = (u0 * GRAIN).min(layer.ic);
        let c1 = ((u0 + len_u) * GRAIN).min(layer.ic);
        u0 += len_u;
        if c0 >= c1 {
            continue;
        }
        shards.push(PoolShard { sub: PoolLayer { ic: c1 - c0, ..layer.clone() }, c0, c1 });
    }
    shards
}

/// Run per-core worklists on the pool's cores (one host thread per
/// busy core) and return the shard results in shard-index order.
fn run_on_pool<W, R>(
    pool: &mut CorePool,
    assignments: Vec<Vec<(usize, W)>>,
    n_shards: usize,
    work: impl Fn(&mut Cpu, &W) -> Result<R, ExecError> + Sync,
) -> Result<Vec<R>, ExecError>
where
    W: Send,
    R: Send,
{
    let work = &work;
    let mut slots: Vec<Option<R>> = (0..n_shards).map(|_| None).collect();
    thread::scope(|s| -> Result<(), ExecError> {
        let mut handles = Vec::new();
        for (cpu, list) in pool.cpus.iter_mut().zip(assignments) {
            if list.is_empty() {
                continue;
            }
            handles.push(s.spawn(move || -> Result<Vec<(usize, R)>, ExecError> {
                let mut done = Vec::with_capacity(list.len());
                for (idx, w) in &list {
                    done.push((*idx, work(cpu, w)?));
                }
                Ok(done)
            }));
        }
        for h in handles {
            for (idx, r) in h.join().expect("core thread panicked")? {
                slots[idx] = Some(r);
            }
        }
        Ok(())
    })?;
    Ok(slots.into_iter().map(|r| r.expect("shard not executed")).collect())
}

/// Round-robin shard indices over `cores` cores. Returns per-core lists
/// of (shard index, shard).
fn round_robin<W>(shards: Vec<W>, cores: usize) -> Vec<Vec<(usize, W)>> {
    let mut lists: Vec<Vec<(usize, W)>> = (0..cores).map(|_| Vec::new()).collect();
    for (i, s) in shards.into_iter().enumerate() {
        lists[i % cores].push((i, s));
    }
    lists
}

/// Run a conv layer sharded across the pool. With `opts.cores <= 1`
/// (or a single-core pool) this is exactly [`run_conv_layer`].
pub fn run_conv_layer_mc(
    pool: &mut CorePool,
    layer: &ConvLayer,
    x: &[i16],
    w: &[i16],
    b: &[i32],
    opts: ExecOptions,
) -> Result<LayerResult, ExecError> {
    let n = opts.cores.min(pool.cores()).max(1);
    if n == 1 {
        return run_conv_layer(pool.cpu0(), layer, x, w, b, opts);
    }
    let inner = ExecOptions { cores: 1, batch: 1, ..opts };
    let shards = conv_shards(layer, n);
    let n_shards = shards.len();
    // shard descriptors for the merge, in shard-index order
    let descs: Vec<(usize, usize)> = shards.iter().map(|s| (s.out0, s.out_len)).collect();
    let core_of: Vec<usize> = (0..n_shards).map(|i| i % n).collect();
    let assignments = round_robin(shards, n);
    let results = run_on_pool(pool, assignments, n_shards, |cpu, sh: &ConvShard| {
        run_conv_layer(cpu, &sh.sub, &x[sh.x0..sh.x1], &w[sh.w0..sh.w1], &b[sh.b0..sh.b1], inner)
    })?;

    let ohw = layer.oh() * layer.ow();
    let mut res = LayerResult { name: layer.name.to_string(), ..Default::default() };
    // only FullCycle produces shard outputs worth merging
    let mut out = if opts.mode == ExecMode::FullCycle {
        vec![0i16; layer.oc * ohw]
    } else {
        Vec::new()
    };
    let mut core_cycles = vec![0u64; n];
    for (idx, r) in results.into_iter().enumerate() {
        let (out0, out_len) = descs[idx];
        res.compute_cycles += r.compute_cycles;
        res.dma_cycles += r.dma_cycles;
        res.macs += r.macs;
        res.io_in += r.io_in;
        res.io_out += r.io_out;
        res.stats = add_stats(&res.stats, &r.stats);
        core_cycles[core_of[idx]] += r.cycles;
        if !r.out.is_empty() {
            out[out0..out0 + out_len].copy_from_slice(&r.out);
        }
    }
    res.cycles = core_cycles.iter().copied().max().unwrap_or(0);
    res.core_cycles = core_cycles;
    if opts.mode == ExecMode::FullCycle {
        res.out = out;
    }
    Ok(res)
}

/// Run a pool layer sharded across the pool (16-channel slabs).
pub fn run_pool_layer_mc(
    pool: &mut CorePool,
    layer: &PoolLayer,
    x: &[i16],
    opts: ExecOptions,
) -> Result<LayerResult, ExecError> {
    let n = opts.cores.min(pool.cores()).max(1);
    if n == 1 {
        return run_pool_layer(pool.cpu0(), layer, x, opts);
    }
    let inner = ExecOptions { cores: 1, batch: 1, ..opts };
    let (ih, iw) = (layer.ih, layer.iw);
    let (oh, ow) = (layer.oh(), layer.ow());
    let shards = pool_shards(layer, n);
    let n_shards = shards.len();
    let descs: Vec<(usize, usize)> = shards.iter().map(|s| (s.c0, s.c1)).collect();
    let core_of: Vec<usize> = (0..n_shards).map(|i| i % n).collect();
    let assignments = round_robin(shards, n);
    let results = run_on_pool(pool, assignments, n_shards, |cpu, sh: &PoolShard| {
        run_pool_layer(cpu, &sh.sub, &x[sh.c0 * ih * iw..sh.c1 * ih * iw], inner)
    })?;

    let mut res = LayerResult { name: layer.name.to_string(), ..Default::default() };
    let mut out = if opts.mode == ExecMode::FullCycle {
        vec![0i16; layer.ic * oh * ow]
    } else {
        Vec::new()
    };
    let mut core_cycles = vec![0u64; n];
    for (idx, r) in results.into_iter().enumerate() {
        let (c0, c1) = descs[idx];
        res.compute_cycles += r.compute_cycles;
        res.dma_cycles += r.dma_cycles;
        res.io_in += r.io_in;
        res.io_out += r.io_out;
        res.stats = add_stats(&res.stats, &r.stats);
        core_cycles[core_of[idx]] += r.cycles;
        if !r.out.is_empty() {
            out[c0 * oh * ow..c1 * oh * ow].copy_from_slice(&r.out);
        }
    }
    res.cycles = core_cycles.iter().copied().max().unwrap_or(0);
    res.core_cycles = core_cycles;
    if opts.mode == ExecMode::FullCycle {
        res.out = out;
    }
    Ok(res)
}

/// Multi-core [`run_network`]: one frame, every layer sharded across
/// the pool, activations threaded exactly like the single-core path
/// (identical xorshift weight draws, so outputs are bit-identical).
pub fn run_network_mc(
    pool: &mut CorePool,
    name: &str,
    layers: &[NetLayer],
    input: &[i16],
    opts: ExecOptions,
    seed: u64,
) -> Result<NetworkResult, ExecError> {
    let mut rng = crate::util::XorShift::new(seed);
    let mut act = input.to_vec();
    let mut net = NetworkResult { name: name.into(), ..Default::default() };
    for layer in layers {
        match layer {
            NetLayer::Conv(l) => {
                let w = rng.i16_vec(l.oc * (l.ic / l.groups) * l.fh * l.fw, -128, 128);
                let b = rng.i32_vec(l.oc, -1000, 1000);
                let x = if act.len() == l.ic * l.ih * l.iw {
                    act.clone()
                } else {
                    vec![0i16; l.ic * l.ih * l.iw]
                };
                let r = run_conv_layer_mc(pool, l, &x, &w, &b, opts)?;
                if !r.out.is_empty() {
                    act = r.out.clone();
                }
                net.layers.push(r);
            }
            NetLayer::Pool(l) => {
                let x = if act.len() == l.ic * l.ih * l.iw {
                    act.clone()
                } else {
                    vec![0i16; l.ic * l.ih * l.iw]
                };
                let r = run_pool_layer_mc(pool, l, &x, opts)?;
                if !r.out.is_empty() {
                    act = r.out.clone();
                }
                net.layers.push(r);
            }
        }
    }
    Ok(net)
}

/// Result of a batched multi-core run.
#[derive(Debug, Clone, Default)]
pub struct BatchedResult {
    pub name: String,
    /// Per-frame network results, in input order.
    pub frames: Vec<NetworkResult>,
    /// Final activation per frame (empty vectors in analytic mode).
    pub outputs: Vec<Vec<i16>>,
    /// Busy cycles per core.
    pub core_cycles: Vec<u64>,
    /// Which core ran each frame (parallel to `frames`).
    pub frame_core: Vec<usize>,
}

impl BatchedResult {
    /// Batch latency: the slowest core's busy cycles.
    pub fn makespan_cycles(&self) -> u64 {
        self.core_cycles.iter().copied().max().unwrap_or(0)
    }

    /// What the batch would cost on one core.
    pub fn serial_cycles(&self) -> u64 {
        self.frames.iter().map(|f| f.cycles()).sum()
    }

    /// Cycle-level speedup of the fan-out over a single core.
    pub fn speedup(&self) -> f64 {
        let mk = self.makespan_cycles();
        if mk == 0 {
            return 1.0;
        }
        self.serial_cycles() as f64 / mk as f64
    }

    /// Frames per second at the modeled clock.
    pub fn throughput_fps(&self) -> f64 {
        let mk = self.makespan_cycles();
        if mk == 0 {
            return 0.0;
        }
        self.frames.len() as f64 / (mk as f64 / crate::CLOCK_HZ as f64)
    }

    /// Per-core busy fraction of the makespan.
    pub fn core_utilization(&self) -> Vec<f64> {
        let mk = self.makespan_cycles().max(1) as f64;
        self.core_cycles.iter().map(|&c| c as f64 / mk).collect()
    }

    /// Aggregate core activity over all frames (for the energy model).
    pub fn stats(&self) -> crate::core::CoreStats {
        let mut acc = crate::core::CoreStats::default();
        for f in &self.frames {
            acc = add_stats(&acc, &f.stats());
        }
        acc
    }
}

/// Batched inference: distribute `inputs` (one tensor per frame)
/// round-robin over the pool's cores; each core runs whole networks
/// back to back. Weights are the same deterministic per-layer xorshift
/// draws as [`run_network`], so every frame sees the same network and a
/// single-frame batch is bit-identical to `run_network`.
pub fn run_batched(
    pool: &mut CorePool,
    name: &str,
    layers: &[NetLayer],
    inputs: &[Vec<i16>],
    opts: ExecOptions,
    seed: u64,
) -> Result<BatchedResult, ExecError> {
    let n = opts.cores.min(pool.cores()).max(1);
    let inner = ExecOptions { cores: 1, batch: 1, ..opts };
    let frames: Vec<&Vec<i16>> = inputs.iter().collect();
    let n_frames = frames.len();
    let core_of: Vec<usize> = (0..n_frames).map(|i| i % n).collect();
    let assignments = round_robin(frames, n);
    let results = run_on_pool(pool, assignments, n_frames, |cpu, x: &&Vec<i16>| {
        run_network(cpu, name, layers, x.as_slice(), inner, seed)
    })?;

    let mut br = BatchedResult {
        name: name.into(),
        core_cycles: vec![0u64; n],
        frame_core: core_of,
        ..Default::default()
    };
    for (idx, f) in results.into_iter().enumerate() {
        br.core_cycles[br.frame_core[idx]] += f.cycles();
        br.outputs.push(f.layers.last().map(|l| l.out.clone()).unwrap_or_default());
        br.frames.push(f);
    }
    Ok(br)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift;

    fn tensors(l: &ConvLayer, seed: u64) -> (Vec<i16>, Vec<i16>, Vec<i32>) {
        let mut rng = XorShift::new(seed);
        (
            rng.i16_vec(l.ic * l.ih * l.iw, -2000, 2000),
            rng.i16_vec(l.oc * (l.ic / l.groups) * l.fh * l.fw, -256, 256),
            rng.i32_vec(l.oc, -1000, 1000),
        )
    }

    #[test]
    fn conv_shards_partition_the_layer() {
        for (l, want) in [
            (ConvLayer::new("d", 8, 16, 16, 64, 3, 3, 1, 1, 1), 4),
            (ConvLayer::new("g", 8, 13, 13, 32, 3, 3, 1, 1, 2), 4),
            (ConvLayer::new("tiny", 4, 10, 10, 16, 3, 3, 1, 1, 1), 8),
        ] {
            let shards = conv_shards(&l, want);
            let oc_sum: usize = shards.iter().map(|s| s.sub.oc).sum();
            assert_eq!(oc_sum, l.oc, "{}", l.name);
            let mac_sum: u64 = shards.iter().map(|s| s.sub.macs()).sum();
            assert_eq!(mac_sum, l.macs(), "{}", l.name);
            // output ranges tile [0, oc*ohw) without overlap
            let mut marks = vec![false; l.oc * l.oh() * l.ow()];
            for s in &shards {
                for m in &mut marks[s.out0..s.out0 + s.out_len] {
                    assert!(!*m, "overlapping shard output");
                    *m = true;
                }
            }
            assert!(marks.iter().all(|&m| m), "{}: uncovered outputs", l.name);
        }
    }

    #[test]
    fn sharded_conv_matches_single_core_bitexact() {
        let l = ConvLayer::new("mc", 8, 16, 16, 64, 3, 3, 1, 1, 1);
        let (x, w, b) = tensors(&l, 3);
        let mut solo = Cpu::new(1 << 22);
        let base = run_conv_layer(&mut solo, &l, &x, &w, &b, ExecOptions::default()).unwrap();
        for cores in [2usize, 4] {
            let mut pool = CorePool::new(cores, 1 << 22);
            let opts = ExecOptions { cores, ..Default::default() };
            let r = run_conv_layer_mc(&mut pool, &l, &x, &w, &b, opts).unwrap();
            assert_eq!(r.out, base.out, "{cores}-core output");
            assert_eq!(r.macs, base.macs, "{cores}-core macs");
            assert_eq!(r.core_cycles.len(), cores);
            assert!(r.cycles > 0);
            assert!(
                r.parallel_speedup() > 1.5,
                "{cores}-core speedup {}",
                r.parallel_speedup()
            );
        }
    }

    #[test]
    fn sharded_grouped_conv_matches() {
        let l = ConvLayer::new("mcg", 8, 13, 13, 32, 3, 3, 1, 1, 2);
        let (x, w, b) = tensors(&l, 5);
        let mut solo = Cpu::new(1 << 22);
        let base = run_conv_layer(&mut solo, &l, &x, &w, &b, ExecOptions::default()).unwrap();
        let mut pool = CorePool::new(4, 1 << 22);
        let opts = ExecOptions { cores: 4, ..Default::default() };
        let r = run_conv_layer_mc(&mut pool, &l, &x, &w, &b, opts).unwrap();
        assert_eq!(r.out, base.out);
        assert_eq!(r.macs, base.macs);
    }

    #[test]
    fn sharded_pool_layer_matches() {
        let l = PoolLayer { name: "mcp", ic: 48, ih: 13, iw: 13, size: 3, stride: 2 };
        let mut rng = XorShift::new(9);
        let x = rng.i16_vec(l.ic * l.ih * l.iw, -30000, 30000);
        let mut solo = Cpu::new(1 << 22);
        let base = run_pool_layer(&mut solo, &l, &x, ExecOptions::default()).unwrap();
        let mut pool = CorePool::new(3, 1 << 22);
        let opts = ExecOptions { cores: 3, ..Default::default() };
        let r = run_pool_layer_mc(&mut pool, &l, &x, opts).unwrap();
        assert_eq!(r.out, base.out);
    }

    #[test]
    fn batched_frames_match_serial_runs() {
        let layers = vec![
            NetLayer::Conv(ConvLayer::new("c1", 4, 12, 12, 16, 3, 3, 1, 1, 1)),
            NetLayer::Pool(PoolLayer { name: "p1", ic: 16, ih: 12, iw: 12, size: 2, stride: 2 }),
            NetLayer::Conv(ConvLayer::new("c2", 16, 6, 6, 16, 3, 3, 1, 1, 1)),
        ];
        let mut rng = XorShift::new(11);
        let inputs: Vec<Vec<i16>> =
            (0..3).map(|_| rng.i16_vec(4 * 12 * 12, -1000, 1000)).collect();
        let opts = ExecOptions { cores: 2, batch: 3, ..Default::default() };
        let mut pool = CorePool::new(2, 1 << 22);
        let br = run_batched(&mut pool, "mini", &layers, &inputs, opts, 42).unwrap();
        assert_eq!(br.frames.len(), 3);
        assert_eq!(br.outputs.len(), 3);
        assert_eq!(br.frame_core, vec![0, 1, 0], "round-robin frame placement");
        // every frame must equal its standalone single-core run
        for (i, input) in inputs.iter().enumerate() {
            let mut solo = Cpu::new(1 << 22);
            let f =
                run_network(&mut solo, "mini", &layers, input, ExecOptions::default(), 42).unwrap();
            assert_eq!(br.outputs[i], f.layers.last().unwrap().out, "frame {i}");
            assert_eq!(br.frames[i].macs(), f.macs(), "frame {i} macs");
        }
        assert!(br.speedup() > 1.0, "two cores must beat one on 3 frames");
    }
}
