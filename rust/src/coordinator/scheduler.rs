//! Deprecated 0.2 multi-core free-function API.
//!
//! The multi-core machinery lives in [`super::engine`] now: one network
//! walk, one shard-merge helper, pluggable [`ShardPolicy`] /
//! [`BusModel`](super::bus::BusModel). These shims keep the 0.2
//! signatures alive for one release with the seed semantics (oc-tile
//! sharding, partitioned bus) so downstream code and the multicore
//! determinism contract tests keep compiling unchanged. New code must
//! construct an [`Engine`](super::engine::Engine); the CI deny-list
//! (`tools/check-deprecated.sh`) enforces that outside this module.

use crate::model::{ConvLayer, PoolLayer};

use super::bus::BusModel;
use super::engine::{self, RunSpec, ShardPolicy};
use super::executor::{ExecError, ExecOptions, NetLayer};
use super::metrics::{LayerResult, NetworkResult};

pub use super::engine::{BatchedResult, CorePool};

/// The seed scheduler's fixed policies: oc-tile shards on a fully
/// partitioned bus.
fn seed_spec(opts: ExecOptions, seed: u64) -> RunSpec {
    RunSpec { opts, shard: ShardPolicy::OcTile, bus: BusModel::Partitioned, seed }
}

/// Deprecated 0.2 shim: conv layer sharded across the pool (oc-tile,
/// partitioned bus).
#[deprecated(
    since = "0.3.0",
    note = "build an engine: `EngineConfig::new().cores(n).build()`, then `engine.run_conv_layer(...)`"
)]
pub fn run_conv_layer_mc(
    pool: &mut CorePool,
    layer: &ConvLayer,
    x: &[i16],
    w: &[i16],
    b: &[i32],
    opts: ExecOptions,
) -> Result<LayerResult, ExecError> {
    engine::run_conv_sharded(pool, layer, x, w, b, seed_spec(opts, 0))
}

/// Deprecated 0.2 shim: pool layer sharded across the pool (16-channel
/// slabs, partitioned bus).
#[deprecated(
    since = "0.3.0",
    note = "build an engine: `EngineConfig::new().cores(n).build()`, then `engine.run_pool_layer(...)`"
)]
pub fn run_pool_layer_mc(
    pool: &mut CorePool,
    layer: &PoolLayer,
    x: &[i16],
    opts: ExecOptions,
) -> Result<LayerResult, ExecError> {
    engine::run_pool_sharded(pool, layer, x, seed_spec(opts, 0))
}

/// Deprecated 0.2 shim: multi-core network run (oc-tile, partitioned
/// bus). Delegates to the engine's single network walk, so xorshift
/// draws stay bit-identical to every other path.
#[deprecated(
    since = "0.3.0",
    note = "build an engine: `EngineConfig::new().cores(n).seed(seed).build()`, then `engine.run_network(...)`"
)]
pub fn run_network_mc(
    pool: &mut CorePool,
    name: &str,
    layers: &[NetLayer],
    input: &[i16],
    opts: ExecOptions,
    seed: u64,
) -> Result<NetworkResult, ExecError> {
    engine::run_network_on(pool, name, layers, input, seed_spec(opts, seed))
}

/// Deprecated 0.2 shim: batched frame fan-out over the pool
/// (partitioned bus).
#[deprecated(
    since = "0.3.0",
    note = "build an engine: `EngineConfig::new().cores(n).batch(b).seed(seed).build()`, then `engine.run_batched(...)`"
)]
pub fn run_batched(
    pool: &mut CorePool,
    name: &str,
    layers: &[NetLayer],
    inputs: &[Vec<i16>],
    opts: ExecOptions,
    seed: u64,
) -> Result<BatchedResult, ExecError> {
    engine::run_batched_on(pool, name, layers, inputs, seed_spec(opts, seed))
}
