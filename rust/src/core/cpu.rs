//! The bundle-accurate ConvAix core interpreter.

use crate::fixed::{self, RoundMode};
use crate::isa::*;
use crate::mem::dma::DmaDir;
use crate::mem::linebuf::LB_ROWS;
use crate::mem::pm::ProgramMem;
use crate::mem::MemInterface;

use super::regfile::{can_access_vrl, can_read_vr, can_write_vr, own_acc_base, RegFiles, Who};
use super::BRANCH_BUBBLES;
use crate::isa::analysis::timing;

/// Filter FIFO depth (defined by the shared timing model).
pub use crate::isa::analysis::timing::FIFO_DEPTH;

#[derive(Debug)]
pub enum SimError {
    Access { cycle: u64, pc: usize, what: String },
    Fault { cycle: u64, pc: usize, what: String },
    RanOff { pc: usize },
    Watchdog(u64),
    Pm(crate::mem::pm::PmError),
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::Access { cycle, pc, what } => {
                write!(f, "cycle {cycle}, bundle {pc}: access violation: {what}")
            }
            SimError::Fault { cycle, pc, what } => write!(f, "cycle {cycle}, bundle {pc}: {what}"),
            SimError::RanOff { pc } => {
                write!(f, "program ran past the last bundle without halt (pc={pc})")
            }
            SimError::Watchdog(n) => write!(f, "watchdog: exceeded {n} cycles"),
            SimError::Pm(e) => write!(f, "program memory: {e}"),
        }
    }
}

impl std::error::Error for SimError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SimError::Pm(e) => Some(e),
            _ => None,
        }
    }
}

impl From<crate::mem::pm::PmError> for SimError {
    fn from(e: crate::mem::pm::PmError) -> Self {
        SimError::Pm(e)
    }
}

/// Datapath configuration registers (written by `Csrwi`/`Csrw`).
#[derive(Debug, Clone)]
pub struct CsrFile {
    pub frac_shift: u8,
    pub round_mode: RoundMode,
    pub gate_bits: u8,
    pub lb_stride: u8,
}

impl Default for CsrFile {
    fn default() -> Self {
        Self { frac_shift: 0, round_mode: RoundMode::HalfUp, gate_bits: 16, lb_stride: 1 }
    }
}

/// Cycle and activity statistics — the inputs to the utilization metric
/// (Table II) and the activity-based power model (Fig. 3c). Equality
/// is field-wise: the energy-composition tests compare aggregated
/// counter sets directly. `Copy` (it is a flat block of counters) so
/// per-run snapshots and the analytic sample caches move it without
/// allocator or clone churn.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CoreStats {
    pub cycles: u64,
    pub bundles: u64,
    /// MAC lane-operations actually executed (64 per vmac/vmul op).
    pub mac_ops: u64,
    /// Bundles that issued at least one vector MAC.
    pub mac_bundles: u64,
    /// Vector MAC/MUL instructions.
    pub vmacs: u64,
    /// Requantize ops.
    pub qmovs: u64,
    /// Elementwise / move / broadcast vector ops.
    pub veops: u64,
    /// SFU ops (relu / poolmax) — slot 1.
    pub sfu_ops: u64,
    /// InitA / ClrA accumulator setup ops.
    pub acc_setup: u64,
    /// Scalar ALU ops (incl. Li).
    pub scalar_ops: u64,
    /// Branches / jumps / loop instructions executed.
    pub ctrl_ops: u64,
    /// Taken-branch bubbles.
    pub branch_stalls: u64,
    /// Scoreboard (RAW) stall cycles.
    pub hazard_stalls: u64,
    /// Stalls waiting for a line-buffer fill.
    pub lb_stalls: u64,
    /// Stalls in DmaWait.
    pub dma_wait_stalls: u64,
    /// Extra slot-0 occupancy for 512-bit LdA/StA.
    pub wide_ls_stalls: u64,
    /// Vector loads / stores (256-bit DM port-0 accesses).
    pub vloads: u64,
    pub vstores: u64,
    /// Accumulator loads/stores (512-bit).
    pub aloads: u64,
    pub astores: u64,
    /// Scalar loads/stores.
    pub sloads: u64,
    pub sstores: u64,
    /// Line-buffer fills started.
    pub lb_fills: u64,
    /// Line-buffer pixels consumed by MAC operands.
    pub lb_pixel_reads: u64,
    /// VR reads/writes by vector ops (energy accounting).
    pub vr_reads: u64,
    pub vr_writes: u64,
    /// VRl accumulate writes (4 entries per vmac).
    pub vrl_writes: u64,
    /// Effective gate bits histogram: ops executed at <=8 bits.
    pub mac_ops_gated8: u64,
}

impl CoreStats {
    /// MAC utilization rate as defined in Table II footnote e: ratio of
    /// ideal processing time (100 % MAC usage per cycle) to actual.
    pub fn utilization(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.mac_ops as f64 / (self.cycles as f64 * crate::PEAK_MACS_PER_CYCLE as f64)
    }
}

struct LoopFrame {
    start: usize,
    last: usize,
    remaining: u32,
}

/// The core simulator: owns register state, CSRs, the memory interface
/// and the decoded program.
pub struct Cpu {
    pub regs: RegFiles,
    pub csr: CsrFile,
    pub mem: MemInterface,
    pub stats: CoreStats,
    pc: usize,
    halted: bool,
    loops: Vec<LoopFrame>,
    /// Scoreboard: cycle at which each VR / VRl entry / scalar reg is
    /// ready for a consumer. Shared model with `isa::analysis::predict`
    /// (the static cycle analyzer) via `isa::analysis::timing`.
    sb: timing::Scoreboard,
    /// Filter FIFO of the operand fetch & prepare stage: (vector, cycle
    /// at which it is usable). Depth 8.
    filt_fifo: std::collections::VecDeque<([i16; LANES], u64)>,
    /// Watchdog limit.
    pub max_cycles: u64,
}

impl Cpu {
    pub fn new(ext_capacity: usize) -> Self {
        Self {
            regs: RegFiles::new(),
            csr: CsrFile::default(),
            mem: MemInterface::new(ext_capacity),
            stats: CoreStats::default(),
            pc: 0,
            halted: false,
            loops: Vec::with_capacity(4),
            sb: timing::Scoreboard::new(),
            filt_fifo: std::collections::VecDeque::with_capacity(FIFO_DEPTH),
            max_cycles: 10_000_000_000,
        }
    }

    fn err_access(&self, what: impl Into<String>) -> SimError {
        SimError::Access { cycle: self.stats.cycles, pc: self.pc, what: what.into() }
    }

    fn err_fault(&self, what: impl Into<String>) -> SimError {
        SimError::Fault { cycle: self.stats.cycles, pc: self.pc, what: what.into() }
    }

    /// Advance one cycle of wall-clock (memory system ticks too).
    /// Fast path: when no LB fill / DMA is in flight, the only per-cycle
    /// memory bookkeeping is clearing the port-0 bank reservation.
    #[inline(always)]
    fn advance_cycle(&mut self) {
        self.stats.cycles += 1;
        if self.mem.background_idle() {
            self.mem.dm.end_cycle();
        } else {
            self.mem.tick();
        }
    }

    /// Reset per-run state, keeping memory contents (the coordinator
    /// stages tensors between runs).
    pub fn reset_for_run(&mut self) {
        self.pc = 0;
        self.halted = false;
        self.loops.clear();
        self.sb.reset();
        self.filt_fifo.clear();
    }

    /// Run `program` to completion (Halt) and return per-run stats.
    /// Cumulative stats accumulate in `self.stats`.
    pub fn run(&mut self, pm: &ProgramMem) -> Result<CoreStats, SimError> {
        // plain copy snapshot: CoreStats is Copy, so the per-run diff
        // costs two stack copies, not a clone per task invocation
        let before = self.stats;
        self.reset_for_run();
        let prog = pm.program();
        while !self.halted {
            if self.stats.cycles > self.max_cycles {
                return Err(SimError::Watchdog(self.max_cycles));
            }
            if self.pc >= prog.bundles.len() {
                return Err(SimError::RanOff { pc: self.pc });
            }
            self.step(prog)?;
        }
        // drain background engines so end-of-task time is honest
        let drain = self.mem.drain();
        self.stats.cycles += drain;
        Ok(diff_stats(&before, &self.stats))
    }

    /// Execute the bundle at pc (with stalls), advance pc.
    fn step(&mut self, prog: &Program) -> Result<(), SimError> {
        // borrow, don't copy: the interpreter loop touches every bundle
        // once per dynamic instruction, and the per-slot ops below are
        // small `Copy` reads anyway
        let bundle = &prog.bundles[self.pc];

        // ---- hazard scan: how long must issue wait? --------------------
        let stall = self.issue_stall(bundle)?;
        for _ in 0..stall {
            self.stats.hazard_stalls += 1;
            self.advance_cycle();
        }

        // ---- line-buffer interlock ------------------------------------
        self.wait_lb_operands(bundle)?;

        // the cycle the bundle actually issues at (post-stall); all
        // scoreboard writes below are anchored here
        let issue_now = self.stats.cycles;

        // ---- execute the three vector slots ----------------------------
        let mut any_mac = false;
        let mut fifo_used = false;
        for s in 1..=VALU_SLOTS as u8 {
            let op = bundle.v[(s - 1) as usize];
            any_mac |= matches!(op, VecOp::Mac { .. } | VecOp::Mul { .. });
            fifo_used |= matches!(
                op,
                VecOp::Mac { b: BSrc::Fifo | BSrc::FifoLaneQuad { .. }, .. }
                    | VecOp::Mul { b: BSrc::Fifo | BSrc::FifoLaneQuad { .. }, .. }
            );
            self.exec_vec(s, op)?;
        }
        if any_mac {
            self.stats.mac_bundles += 1;
        }
        if fifo_used {
            // one pop per bundle — all slots consumed the same front entry
            self.filt_fifo.pop_front();
        }

        // ---- execute slot 0 (may redirect pc / block) ------------------
        let next_pc = self.exec_slot0(&bundle.slot0)?;

        // ---- scoreboard writes (shared rules with the analyzer) --------
        timing::retire_bundle(bundle, issue_now, &mut self.sb);

        self.stats.bundles += 1;
        self.advance_cycle();

        // ---- control flow ----------------------------------------------
        match next_pc {
            PcUpdate::Seq => {
                self.pc = self.loop_next(self.pc);
            }
            PcUpdate::Jump(t) => {
                self.pc = t;
                for _ in 0..BRANCH_BUBBLES {
                    self.stats.branch_stalls += 1;
                    self.advance_cycle();
                }
            }
            PcUpdate::Halt => self.halted = true,
        }
        Ok(())
    }

    /// Hardware-loop-aware sequential successor of `pc`.
    fn loop_next(&mut self, pc: usize) -> usize {
        if let Some(frame) = self.loops.last_mut() {
            if pc == frame.last {
                if frame.remaining > 0 {
                    frame.remaining -= 1;
                    return frame.start;
                }
                self.loops.pop();
            }
        }
        pc + 1
    }

    // ------------------------------------------------------------------
    // hazard scan
    // ------------------------------------------------------------------

    /// Cycles to wait before this bundle may issue (RAW on scoreboard).
    /// The rules live in `isa::analysis::timing::issue_ready`, shared
    /// with the static cycle analyzer.
    fn issue_stall(&self, b: &Bundle) -> Result<u64, SimError> {
        let now = self.stats.cycles;
        let front = self.filt_fifo.front().map(|&(_, rdy)| rdy);
        match timing::issue_ready(b, &self.sb, front, now) {
            Ok(ready) => Ok(ready.saturating_sub(now)),
            Err(timing::FifoEmpty) => Err(SimError::Fault {
                cycle: now,
                pc: self.pc,
                what: "vector MAC with empty filter FIFO".into(),
            }),
        }
    }

    /// Block until every LB operand of this bundle is readable.
    fn wait_lb_operands(&mut self, b: &Bundle) -> Result<(), SimError> {
        loop {
            let mut blocked = false;
            for op in b.v.iter() {
                let lb_ref = match *op {
                    VecOp::Mac { a: ASrc::Lb { row, off }, .. }
                    | VecOp::Mul { a: ASrc::Lb { row, off }, .. } => {
                        // variant A: slices read off + j*stride, j<=3
                        Some((row, off as usize + 3 * self.csr.lb_stride as usize))
                    }
                    VecOp::Mac { a: ASrc::LbVec { row, off }, .. }
                    | VecOp::Mul { a: ASrc::LbVec { row, off }, .. } => {
                        // variant B: lanes read off + l*stride, l<=15
                        Some((row, off as usize + 15 * self.csr.lb_stride as usize))
                    }
                    _ => None,
                };
                if let Some((row, max_idx)) = lb_ref {
                    let row = row as usize;
                    if row >= LB_ROWS {
                        return Err(self.err_fault(format!("LB row {row} out of range")));
                    }
                    if !self.mem.lb.can_read(row, max_idx) {
                        if self.mem.lb.filling() && self.mem.lb.fill_row() == Some(row) {
                            blocked = true;
                        } else {
                            return Err(self.err_fault(format!(
                                "LB read row {row} px<= {max_idx} but row not filled"
                            )));
                        }
                    }
                }
            }
            if !blocked {
                return Ok(());
            }
            self.stats.lb_stalls += 1;
            self.mem.lb.note_read_stall();
            self.advance_cycle();
        }
    }

    // ------------------------------------------------------------------
    // vector slots
    // ------------------------------------------------------------------

    /// Fetch the prepared A-operand for slice `j` of vALU `s`.
    #[inline]
    fn prep_a(&mut self, s: u8, a: ASrc, j: u8) -> Result<[i16; LANES], SimError> {
        match a {
            ASrc::Lb { row, off } => {
                let px = off as usize + j as usize * self.csr.lb_stride as usize;
                let v = self
                    .mem
                    .lb
                    .read_pixel(row as usize, px)
                    .map_err(|e| self.err_fault(e.to_string()))?;
                self.stats.lb_pixel_reads += 1;
                Ok([v; LANES])
            }
            ASrc::LbVec { row, off } => {
                let stride = self.csr.lb_stride as usize;
                let row = row as usize;
                let mut out = [0i16; LANES];
                for (l, o) in out.iter_mut().enumerate() {
                    *o = self
                        .mem
                        .lb
                        .read_pixel(row, off as usize + l * stride)
                        .map_err(|e| self.err_fault(e.to_string()))?;
                }
                // hardware reads the 16 pixels once and broadcasts them to
                // all 4 slices — count the energy-relevant reads once
                if j == 0 {
                    self.stats.lb_pixel_reads += LANES as u64;
                } else {
                    // correct the per-call accounting done by read_pixel
                    self.mem.lb.stats.pixel_reads -= LANES as u64;
                }
                Ok(out)
            }
            ASrc::VrBcast { vr, base, step } => {
                if !can_read_vr(Who::Valu(s), vr) {
                    return Err(self.err_access(format!("vALU{s} read v{}", vr.0)));
                }
                let lane = base as usize + j as usize * step as usize;
                if lane >= LANES {
                    return Err(self.err_fault(format!("bcast lane {lane} out of range")));
                }
                self.stats.vr_reads += 1;
                Ok([self.regs.vr[vr.0 as usize][lane]; LANES])
            }
            ASrc::VrQuad { vr } => {
                let e = VReg(vr.0 + j);
                if e.0 >= VReg::COUNT || !can_read_vr(Who::Valu(s), e) {
                    return Err(self.err_access(format!("vALU{s} read v{}", e.0)));
                }
                self.stats.vr_reads += 1;
                Ok(self.regs.vr[e.0 as usize])
            }
        }
    }

    #[inline]
    fn prep_b(&mut self, s: u8, b: BSrc, j: u8) -> Result<[i16; LANES], SimError> {
        match b {
            BSrc::Vr { vr } => {
                if !can_read_vr(Who::Valu(s), vr) {
                    return Err(self.err_access(format!("vALU{s} read v{}", vr.0)));
                }
                self.stats.vr_reads += 1;
                Ok(self.regs.vr[vr.0 as usize])
            }
            BSrc::VrLane { vr, lane } => {
                if !can_read_vr(Who::Valu(s), vr) {
                    return Err(self.err_access(format!("vALU{s} read v{}", vr.0)));
                }
                self.stats.vr_reads += 1;
                Ok([self.regs.vr[vr.0 as usize][lane as usize % LANES]; LANES])
            }
            BSrc::VrLaneQuad { vr, base } => {
                if !can_read_vr(Who::Valu(s), vr) {
                    return Err(self.err_access(format!("vALU{s} read v{}", vr.0)));
                }
                self.stats.vr_reads += 1;
                let lane = (base + j) as usize;
                if lane >= LANES {
                    return Err(self.err_fault(format!("lane-quad lane {lane} out of range")));
                }
                Ok([self.regs.vr[vr.0 as usize][lane]; LANES])
            }
            BSrc::Fifo => {
                let (v, _) = self
                    .filt_fifo
                    .front()
                    .ok_or_else(|| self.err_fault("filter FIFO empty".to_string()))?;
                Ok(*v)
            }
            BSrc::FifoLaneQuad { base } => {
                let (v, _) = self
                    .filt_fifo
                    .front()
                    .ok_or_else(|| self.err_fault("filter FIFO empty".to_string()))?;
                let lane = (base + j) as usize;
                if lane >= LANES {
                    return Err(self.err_fault(format!("fifo lane {lane} out of range")));
                }
                Ok([v[lane]; LANES])
            }
            BSrc::VrQuad { vr } => {
                let e = VReg(vr.0 + j);
                if e.0 >= VReg::COUNT || !can_read_vr(Who::Valu(s), e) {
                    return Err(self.err_access(format!("vALU{s} read v{}", e.0)));
                }
                self.stats.vr_reads += 1;
                Ok(self.regs.vr[e.0 as usize])
            }
        }
    }

    fn exec_vec(&mut self, s: u8, op: VecOp) -> Result<(), SimError> {
        match op {
            VecOp::Nop => {}
            VecOp::Mac { a, b } | VecOp::Mul { a, b } => {
                let overwrite = matches!(op, VecOp::Mul { .. });
                let gate_bits = self.csr.gate_bits;
                let base = own_acc_base(s) as usize;
                let stride = self.csr.lb_stride as usize;

                // Hot-path dispatch on the two codegen-emitted operand
                // patterns; everything else falls back to the generic
                // (fully checked) path. The LB interlock in
                // `wait_lb_operands` validated all pixel indices already.
                match (a, b) {
                    // variant A: per-slice LB pixel broadcast x filter
                    // vector from the FIFO
                    (ASrc::Lb { row, off }, BSrc::Fifo) => {
                        let (fv, _) = self
                            .filt_fifo
                            .front()
                            .ok_or_else(|| self.err_fault("filter FIFO empty".to_string()))?;
                        let bv: [i16; LANES] = if gate_bits >= 16 {
                            *fv
                        } else {
                            std::array::from_fn(|l| fixed::gate(fv[l], gate_bits))
                        };
                        let row = row as usize;
                        let off = off as usize;
                        for j in 0..SLICES {
                            let x =
                                fixed::gate(self.mem.lb.pixel(row, off + j * stride), gate_bits)
                                    as i32;
                            let acc = &mut self.regs.vrl[base + j];
                            if overwrite {
                                for lane in 0..LANES {
                                    acc[lane] = x.wrapping_mul(bv[lane] as i32);
                                }
                            } else {
                                for lane in 0..LANES {
                                    acc[lane] =
                                        acc[lane].wrapping_add(x.wrapping_mul(bv[lane] as i32));
                                }
                            }
                        }
                        self.mem.lb.note_pixel_reads(SLICES as u64);
                        self.stats.lb_pixel_reads += SLICES as u64;
                    }
                    // variant B: LB pixel vector (slice-invariant) x
                    // per-slice filter lane from the FIFO
                    (ASrc::LbVec { row, off }, BSrc::FifoLaneQuad { base: lb }) => {
                        let (fv, _) = self
                            .filt_fifo
                            .front()
                            .ok_or_else(|| self.err_fault("filter FIFO empty".to_string()))?;
                        let fv = *fv;
                        if lb as usize + SLICES > LANES {
                            return Err(self.err_fault("fifo lane out of range".to_string()));
                        }
                        let row = row as usize;
                        let off = off as usize;
                        let av: [i32; LANES] = std::array::from_fn(|l| {
                            fixed::gate(self.mem.lb.pixel(row, off + l * stride), gate_bits)
                                as i32
                        });
                        for j in 0..SLICES {
                            let w = fixed::gate(fv[lb as usize + j], gate_bits) as i32;
                            let acc = &mut self.regs.vrl[base + j];
                            if overwrite {
                                for lane in 0..LANES {
                                    acc[lane] = av[lane].wrapping_mul(w);
                                }
                            } else {
                                for lane in 0..LANES {
                                    acc[lane] = acc[lane].wrapping_add(av[lane].wrapping_mul(w));
                                }
                            }
                        }
                        self.mem.lb.note_pixel_reads(LANES as u64);
                        self.stats.lb_pixel_reads += LANES as u64;
                    }
                    // generic path (tests, hand-written kernels)
                    _ => {
                        for j in 0..SLICES as u8 {
                            let av = self.prep_a(s, a, j)?;
                            let bv = self.prep_b(s, b, j)?;
                            let acc = &mut self.regs.vrl[base + j as usize];
                            for lane in 0..LANES {
                                let x = fixed::gate(av[lane], gate_bits);
                                let w = fixed::gate(bv[lane], gate_bits);
                                let prev = if overwrite { 0 } else { acc[lane] };
                                acc[lane] = fixed::mac(prev, x, w);
                            }
                        }
                    }
                }
                self.stats.vmacs += 1;
                self.stats.mac_ops += (SLICES * LANES) as u64;
                if gate_bits <= 8 {
                    self.stats.mac_ops_gated8 += (SLICES * LANES) as u64;
                }
                self.stats.vrl_writes += SLICES as u64;
            }
            VecOp::ClrA { only } => {
                let base = own_acc_base(s);
                for j in 0..SLICES as u8 {
                    if only.is_none() || only == Some(j) {
                        self.regs.vrl[(base + j) as usize] = [0; LANES];
                    }
                }
                self.stats.acc_setup += 1;
            }
            VecOp::InitA { vr } => {
                if !can_read_vr(Who::Valu(s), vr) {
                    return Err(self.err_access(format!("vALU{s} read v{}", vr.0)));
                }
                let bias = self.regs.vr[vr.0 as usize];
                let shift = self.csr.frac_shift;
                let base = own_acc_base(s);
                for j in 0..SLICES as u8 {
                    let acc = &mut self.regs.vrl[(base + j) as usize];
                    for lane in 0..LANES {
                        acc[lane] = fixed::mac_init(bias[lane] as i32, shift);
                    }
                }
                self.stats.acc_setup += 1;
                self.stats.vr_reads += 1;
            }
            VecOp::InitALane { vr, base: lane_base } => {
                if !can_read_vr(Who::Valu(s), vr) {
                    return Err(self.err_access(format!("vALU{s} read v{}", vr.0)));
                }
                let bias = self.regs.vr[vr.0 as usize];
                let shift = self.csr.frac_shift;
                let base = own_acc_base(s);
                for j in 0..SLICES as u8 {
                    let lane = (lane_base + j) as usize;
                    if lane >= LANES {
                        return Err(self.err_fault(format!("vinital lane {lane} out of range")));
                    }
                    let v = fixed::mac_init(bias[lane] as i32, shift);
                    self.regs.vrl[(base + j) as usize] = [v; LANES];
                }
                self.stats.acc_setup += 1;
                self.stats.vr_reads += 1;
            }
            VecOp::QMov { vd, j, relu } => {
                if !can_write_vr(Who::Valu(s), vd) {
                    return Err(self.err_access(format!("vALU{s} write v{}", vd.0)));
                }
                let a = VAcc(own_acc_base(s) + j);
                if !can_access_vrl(Who::Valu(s), a) {
                    return Err(self.err_access(format!("vALU{s} acc a{}", a.0)));
                }
                let shift = self.csr.frac_shift;
                let mode = self.csr.round_mode;
                let acc = self.regs.vrl[a.0 as usize];
                let out: [i16; LANES] =
                    std::array::from_fn(|l| fixed::requantize(acc[l], shift, mode, relu));
                self.regs.vr[vd.0 as usize] = out;
                self.stats.qmovs += 1;
                self.stats.vr_writes += 1;
            }
            VecOp::EOp { f, vd, va, vb } => {
                if !can_read_vr(Who::Valu(s), va) || !can_read_vr(Who::Valu(s), vb) {
                    return Err(self.err_access(format!("vALU{s} eop read")));
                }
                if !can_write_vr(Who::Valu(s), vd) {
                    return Err(self.err_access(format!("vALU{s} write v{}", vd.0)));
                }
                let a = self.regs.vr[va.0 as usize];
                let b = self.regs.vr[vb.0 as usize];
                let out: [i16; LANES] = std::array::from_fn(|l| veop(f, a[l], b[l]));
                self.regs.vr[vd.0 as usize] = out;
                self.stats.veops += 1;
                self.stats.vr_reads += 2;
                self.stats.vr_writes += 1;
            }
            VecOp::EOpI { f, vd, va, imm } => {
                if !can_read_vr(Who::Valu(s), va) || !can_write_vr(Who::Valu(s), vd) {
                    return Err(self.err_access(format!("vALU{s} eopi")));
                }
                let a = self.regs.vr[va.0 as usize];
                let out: [i16; LANES] = std::array::from_fn(|l| veop(f, a[l], imm));
                self.regs.vr[vd.0 as usize] = out;
                self.stats.veops += 1;
                self.stats.vr_reads += 1;
                self.stats.vr_writes += 1;
            }
            VecOp::Mov { vd, vs } => {
                if !can_read_vr(Who::Valu(s), vs) || !can_write_vr(Who::Valu(s), vd) {
                    return Err(self.err_access(format!("vALU{s} mov")));
                }
                self.regs.vr[vd.0 as usize] = self.regs.vr[vs.0 as usize];
                self.stats.veops += 1;
                self.stats.vr_reads += 1;
                self.stats.vr_writes += 1;
            }
            VecOp::Bcst { vd, vs, lane } => {
                if !can_read_vr(Who::Valu(s), vs) || !can_write_vr(Who::Valu(s), vd) {
                    return Err(self.err_access(format!("vALU{s} bcst")));
                }
                let v = self.regs.vr[vs.0 as usize][lane as usize % LANES];
                self.regs.vr[vd.0 as usize] = [v; LANES];
                self.stats.veops += 1;
                self.stats.vr_reads += 1;
                self.stats.vr_writes += 1;
            }
            VecOp::Relu { .. } | VecOp::PoolMax { .. } if s != 1 => {
                return Err(self.err_access(format!("SFU op in slot {s} (slot 1 only)")));
            }
            VecOp::Relu { vd, vs } => {
                if !can_read_vr(Who::Valu(s), vs) || !can_write_vr(Who::Valu(s), vd) {
                    return Err(self.err_access("SFU relu regs".to_string()));
                }
                let a = self.regs.vr[vs.0 as usize];
                let out: [i16; LANES] = std::array::from_fn(|l| a[l].max(0));
                self.regs.vr[vd.0 as usize] = out;
                self.stats.sfu_ops += 1;
                self.stats.vr_reads += 1;
                self.stats.vr_writes += 1;
            }
            VecOp::PoolMax { vd, va, vb } => {
                if !can_read_vr(Who::Valu(s), va)
                    || !can_read_vr(Who::Valu(s), vb)
                    || !can_write_vr(Who::Valu(s), vd)
                {
                    return Err(self.err_access("SFU poolmax regs".to_string()));
                }
                let a = self.regs.vr[va.0 as usize];
                let b = self.regs.vr[vb.0 as usize];
                let out: [i16; LANES] = std::array::from_fn(|l| a[l].max(b[l]));
                self.regs.vr[vd.0 as usize] = out;
                self.stats.sfu_ops += 1;
                self.stats.vr_reads += 2;
                self.stats.vr_writes += 1;
            }
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // slot 0
    // ------------------------------------------------------------------

    fn addr_of(&mut self, a: &Addr) -> usize {
        let base = self.regs.r(a.base);
        let addr = base.wrapping_add(a.offset);
        if a.post_inc != 0 {
            self.regs.set_r(a.base, base.wrapping_add(a.post_inc));
        }
        addr as usize
    }

    fn exec_slot0(&mut self, op: &SlotOp) -> Result<PcUpdate, SimError> {
        let now = self.stats.cycles;
        Ok(match *op {
            SlotOp::Nop => PcUpdate::Seq,
            SlotOp::Halt => PcUpdate::Halt,
            SlotOp::Li { rd, imm } => {
                self.regs.set_r(rd, imm);
                self.stats.scalar_ops += 1;
                PcUpdate::Seq
            }
            SlotOp::Alu { f, w, rd, ra, rb } => {
                let v = alu(f, w, self.regs.r(ra), self.regs.r(rb));
                self.regs.set_r(rd, v);
                self.stats.scalar_ops += 1;
                PcUpdate::Seq
            }
            SlotOp::AluI { f, w, rd, ra, imm } => {
                let v = alu(f, w, self.regs.r(ra), imm);
                self.regs.set_r(rd, v);
                self.stats.scalar_ops += 1;
                PcUpdate::Seq
            }
            SlotOp::Br { c, ra, rb, target } => {
                self.stats.ctrl_ops += 1;
                let a = self.regs.r(ra);
                let b = self.regs.r(rb);
                let taken = match c {
                    Cond::Eq => a == b,
                    Cond::Ne => a != b,
                    Cond::Lt => a < b,
                    Cond::Ge => a >= b,
                };
                if taken {
                    PcUpdate::Jump(target as usize)
                } else {
                    PcUpdate::Seq
                }
            }
            SlotOp::Jmp { target } => {
                self.stats.ctrl_ops += 1;
                PcUpdate::Jump(target as usize)
            }
            SlotOp::Loop { n, body } => {
                self.stats.ctrl_ops += 1;
                let count = self.regs.r(n).max(0) as u32;
                self.push_loop(count, body)?
            }
            SlotOp::LoopI { n, body } => {
                self.stats.ctrl_ops += 1;
                self.push_loop(n, body)?
            }
            SlotOp::Csrwi { csr, imm } => {
                self.write_csr(csr, imm);
                self.stats.scalar_ops += 1;
                PcUpdate::Seq
            }
            SlotOp::Csrw { csr, rs } => {
                let v = self.regs.r(rs) as u32;
                self.write_csr(csr, v);
                self.stats.scalar_ops += 1;
                PcUpdate::Seq
            }
            SlotOp::LdS { rd, addr } => {
                let a = self.addr_of(&addr);
                let v = self
                    .mem
                    .dm
                    .read_i16_p0(a)
                    .map_err(|e| self.err_fault(e.to_string()))?;
                self.regs.set_r(rd, v as i32);
                self.stats.sloads += 1;
                PcUpdate::Seq
            }
            SlotOp::StS { rs, addr } => {
                let a = self.addr_of(&addr);
                let v = self.regs.r(rs) as i16;
                self.mem
                    .dm
                    .write_i16_p0(a, v)
                    .map_err(|e| self.err_fault(e.to_string()))?;
                self.stats.sstores += 1;
                PcUpdate::Seq
            }
            SlotOp::LdV { vd, addr } => {
                let a = self.addr_of(&addr);
                let v = self
                    .mem
                    .dm
                    .read_vec_p0(a)
                    .map_err(|e| self.err_fault(e.to_string()))?;
                self.regs.vr[vd.0 as usize] = v;
                self.stats.vloads += 1;
                PcUpdate::Seq
            }
            SlotOp::StV { vs, addr } => {
                let a = self.addr_of(&addr);
                let v = self.regs.vr[vs.0 as usize];
                self.mem
                    .dm
                    .write_vec_p0(a, &v)
                    .map_err(|e| self.err_fault(e.to_string()))?;
                self.stats.vstores += 1;
                PcUpdate::Seq
            }
            SlotOp::LdVF { addr } => {
                if self.filt_fifo.len() >= FIFO_DEPTH {
                    return Err(self.err_fault("filter FIFO overflow".to_string()));
                }
                let a = self.addr_of(&addr);
                let v = self
                    .mem
                    .dm
                    .read_vec_p0(a)
                    .map_err(|e| self.err_fault(e.to_string()))?;
                self.filt_fifo.push_back((v, timing::fifo_entry_ready(now)));
                self.stats.vloads += 1;
                PcUpdate::Seq
            }
            SlotOp::LdA { ad, addr } => {
                let a = self.addr_of(&addr);
                // 512 bits = 2 port-0 accesses = 1 extra cycle
                let lo = self
                    .mem
                    .dm
                    .read_vec_p0(a)
                    .map_err(|e| self.err_fault(e.to_string()))?;
                self.advance_cycle();
                self.stats.wide_ls_stalls += 1;
                let hi = self
                    .mem
                    .dm
                    .read_vec_p0(a + 32)
                    .map_err(|e| self.err_fault(e.to_string()))?;
                // interleave: lanes 0..16 i32 little-endian across the two
                // 256-bit words (lo = low halves, hi = high halves)
                let acc = &mut self.regs.vrl[ad.0 as usize];
                for l in 0..LANES {
                    acc[l] = (lo[l] as u16 as i32) | ((hi[l] as i32) << 16);
                }
                self.stats.aloads += 1;
                PcUpdate::Seq
            }
            SlotOp::StA { as_, addr } => {
                let a = self.addr_of(&addr);
                let acc = self.regs.vrl[as_.0 as usize];
                let mut lo = [0i16; LANES];
                let mut hi = [0i16; LANES];
                for l in 0..LANES {
                    lo[l] = acc[l] as i16;
                    hi[l] = (acc[l] >> 16) as i16;
                }
                self.mem
                    .dm
                    .write_vec_p0(a, &lo)
                    .map_err(|e| self.err_fault(e.to_string()))?;
                self.advance_cycle();
                self.stats.wide_ls_stalls += 1;
                self.mem
                    .dm
                    .write_vec_p0(a + 32, &hi)
                    .map_err(|e| self.err_fault(e.to_string()))?;
                self.stats.astores += 1;
                PcUpdate::Seq
            }
            SlotOp::DmaLoad { ch, ext, dm, len } => {
                let e = self.regs.r(ext) as usize;
                let d = self.regs.r(dm) as usize;
                let l = self.regs.r(len) as usize;
                self.mem
                    .start_dma(ch as usize, DmaDir::ExtToDm, e, d, l)
                    .map_err(|x| self.err_fault(x.to_string()))?;
                self.stats.ctrl_ops += 1;
                PcUpdate::Seq
            }
            SlotOp::DmaStore { ch, ext, dm, len } => {
                let e = self.regs.r(ext) as usize;
                let d = self.regs.r(dm) as usize;
                let l = self.regs.r(len) as usize;
                self.mem
                    .start_dma(ch as usize, DmaDir::DmToExt, e, d, l)
                    .map_err(|x| self.err_fault(x.to_string()))?;
                self.stats.ctrl_ops += 1;
                PcUpdate::Seq
            }
            SlotOp::DmaWait { ch } => {
                self.stats.ctrl_ops += 1;
                while self.mem.dma.busy(ch as usize) {
                    self.stats.dma_wait_stalls += 1;
                    self.advance_cycle();
                }
                PcUpdate::Seq
            }
            SlotOp::LbLoad { row, dm, off, win, nrows, rstride } => {
                // a second LbLoad while one is in flight interlocks
                while self.mem.lb.filling() {
                    self.stats.lb_stalls += 1;
                    self.advance_cycle();
                }
                let a = self.regs.r(dm) as usize + off as usize;
                self.mem
                    .start_lb_fill_2d(row as usize, a, win as usize, nrows as usize, rstride as usize)
                    .map_err(|e| self.err_fault(e.to_string()))?;
                self.stats.lb_fills += 1;
                PcUpdate::Seq
            }
        })
    }

    fn push_loop(&mut self, n: u32, body: u16) -> Result<PcUpdate, SimError> {
        if body == 0 {
            return Err(self.err_fault("loop with empty body"));
        }
        if self.loops.len() >= 2 {
            return Err(self.err_fault("hardware loop nesting > 2"));
        }
        if n == 0 {
            // skip the body entirely
            return Ok(PcUpdate::Jump(self.pc + 1 + body as usize));
        }
        self.loops.push(LoopFrame {
            start: self.pc + 1,
            last: self.pc + body as usize,
            remaining: n - 1,
        });
        Ok(PcUpdate::Seq)
    }

    fn write_csr(&mut self, csr: Csr, v: u32) {
        match csr {
            Csr::FracShift => self.csr.frac_shift = (v & 31) as u8,
            Csr::RoundMode => self.csr.round_mode = RoundMode::from_bits(v),
            Csr::GateBits => self.csr.gate_bits = (v.clamp(1, 16)) as u8,
            Csr::LbStride => self.csr.lb_stride = (v.max(1) & 0xF) as u8,
        }
    }
}

enum PcUpdate {
    Seq,
    Jump(usize),
    Halt,
}

#[inline]
pub(crate) fn alu(f: AluFn, w: Width, a: i32, b: i32) -> i32 {
    let v = match f {
        AluFn::Add => a.wrapping_add(b),
        AluFn::Sub => a.wrapping_sub(b),
        AluFn::Mul => a.wrapping_mul(b),
        AluFn::And => a & b,
        AluFn::Or => a | b,
        AluFn::Xor => a ^ b,
        AluFn::Shl => a.wrapping_shl(b as u32 & 31),
        AluFn::Shr => a.wrapping_shr(b as u32 & 31),
        AluFn::Min => a.min(b),
        AluFn::Max => a.max(b),
    };
    match w {
        Width::W32 => v,
        Width::W16 => v as i16 as i32,
    }
}

#[inline]
fn veop(f: VFn, a: i16, b: i16) -> i16 {
    match f {
        VFn::Add => a.wrapping_add(b),
        VFn::Sub => a.wrapping_sub(b),
        VFn::Mul => a.wrapping_mul(b),
        VFn::Max => a.max(b),
        VFn::Min => a.min(b),
        VFn::Shl => a.wrapping_shl(b as u32 & 15),
        VFn::Shr => a.wrapping_shr(b as u32 & 15),
    }
}

/// Per-run stats = after - before (component-wise).
fn diff_stats(before: &CoreStats, after: &CoreStats) -> CoreStats {
    macro_rules! d {
        ($($f:ident),* $(,)?) => {
            CoreStats { $($f: after.$f - before.$f),* }
        };
    }
    d!(
        cycles, bundles, mac_ops, mac_bundles, vmacs, qmovs, veops, sfu_ops,
        acc_setup, scalar_ops, ctrl_ops, branch_stalls, hazard_stalls,
        lb_stalls, dma_wait_stalls, wide_ls_stalls, vloads, vstores, aloads,
        astores, sloads, sstores, lb_fills, lb_pixel_reads, vr_reads,
        vr_writes, vrl_writes, mac_ops_gated8,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::MAC_TO_QMOV_LATENCY;
    use crate::isa::asm::assemble;
    use crate::mem::pm::ProgramMem;

    fn run_asm(src: &str) -> (Cpu, CoreStats) {
        let p = assemble(src).unwrap();
        let pm = ProgramMem::load(&p).unwrap();
        let mut cpu = Cpu::new(1 << 20);
        let stats = cpu.run(&pm).unwrap();
        (cpu, stats)
    }

    #[test]
    fn scalar_arithmetic() {
        let (cpu, _) = run_asm(
            "li r1, 7\n\
             li r2, 5\n\
             add r3, r1, r2\n\
             mul r4, r3, r2\n\
             sub.16 r5, r1, r2\n\
             halt",
        );
        assert_eq!(cpu.regs.r(SReg(3)), 12);
        assert_eq!(cpu.regs.r(SReg(4)), 60);
        assert_eq!(cpu.regs.r(SReg(5)), 2);
    }

    #[test]
    fn width16_wraps() {
        let (cpu, _) = run_asm(
            "li r1, 30000\n\
             li r2, 10000\n\
             add.16 r3, r1, r2\n\
             add r4, r1, r2\n\
             halt",
        );
        assert_eq!(cpu.regs.r(SReg(3)), (40000i32 as i16) as i32); // wrapped
        assert_eq!(cpu.regs.r(SReg(4)), 40000);
    }

    #[test]
    fn branch_loop_counts() {
        let (cpu, stats) = run_asm(
            "li r1, 0\n\
             li r2, 10\n\
             li r3, 1\n\
             loop: add r1, r1, r3\n\
             bne r1, r2, loop\n\
             halt",
        );
        assert_eq!(cpu.regs.r(SReg(1)), 10);
        // 9 taken branches × 2 bubbles
        assert_eq!(stats.branch_stalls, 18);
    }

    #[test]
    fn hardware_loop_zero_overhead() {
        let (cpu, stats) = run_asm(
            "li r1, 0\n\
             li r3, 1\n\
             loopi 10, 1\n\
             add r1, r1, r3\n\
             halt",
        );
        assert_eq!(cpu.regs.r(SReg(1)), 10);
        assert_eq!(stats.branch_stalls, 0);
        // 3 setup + 10 body + halt = 14 bundles... cycles == bundles (+drain 0)
        assert_eq!(stats.bundles, 14);
        assert_eq!(stats.cycles, 14);
    }

    #[test]
    fn loop_count_zero_skips_body() {
        let (cpu, _) = run_asm(
            "li r1, 5\n\
             li r4, 0\n\
             loop r4, 1\n\
             li r1, 99\n\
             halt",
        );
        assert_eq!(cpu.regs.r(SReg(1)), 5);
    }

    #[test]
    fn nested_hw_loops() {
        let (cpu, _) = run_asm(
            "li r1, 0\n\
             li r3, 1\n\
             loopi 4, 3\n\
             loopi 5, 1\n\
             add r1, r1, r3\n\
             nop\n\
             halt",
        );
        assert_eq!(cpu.regs.r(SReg(1)), 20);
    }

    #[test]
    fn dm_vector_roundtrip_and_load_use_stall() {
        let (cpu, stats) = run_asm(
            "li r1, 256\n\
             li r2, 512\n\
             ldv v4, [r1] | vnop | vnop | vnop\n\
             stv v4, [r2]\n\
             halt",
        );
        // store must wait LOAD_USE cycles after the load
        assert!(stats.hazard_stalls >= 1, "stalls={}", stats.hazard_stalls);
        let _ = cpu;
    }

    #[test]
    fn vmac_from_vr_bcast_accumulates() {
        // v0 = filter (from DM), A operand: broadcast lane of v1
        let mut p = Program::default();
        p.bundles.push(Bundle::s0(SlotOp::Li { rd: SReg(1), imm: 0 }));
        p.bundles.push(Bundle::s0(SlotOp::LdV { vd: VReg(0), addr: Addr::base(SReg(1)) }));
        p.bundles.push(Bundle::s0(SlotOp::LdV { vd: VReg(4), addr: Addr::offs(SReg(1), 32) }));
        // clear accumulators, then 3 MACs: acc[j] += v4[0+j] * v0
        p.bundles.push(Bundle {
            slot0: SlotOp::Nop,
            v: [VecOp::ClrA { only: None }, VecOp::Nop, VecOp::Nop],
        });
        let mac = VecOp::Mac {
            a: ASrc::VrBcast { vr: VReg(4), base: 0, step: 1 },
            b: BSrc::Vr { vr: VReg(0) },
        };
        for _ in 0..3 {
            p.bundles.push(Bundle { slot0: SlotOp::Nop, v: [mac, VecOp::Nop, VecOp::Nop] });
        }
        p.bundles.push(Bundle::s0(SlotOp::Halt));
        let pm = ProgramMem::load(&p).unwrap();
        let mut cpu = Cpu::new(1 << 16);
        // filter = 1..16, input pixels v4 = [2,3,4,...]
        let filt: Vec<i16> = (1..=16).collect();
        let pix: Vec<i16> = (2..18).collect();
        cpu.mem.dm.poke_i16_slice(0, &filt);
        cpu.mem.dm.poke_i16_slice(32, &pix);
        let stats = cpu.run(&pm).unwrap();
        // acc slice j, lane l = 3 * pix[j] * filt[l]
        for j in 0..4 {
            for l in 0..16 {
                assert_eq!(
                    cpu.regs.vrl[j][l],
                    3 * (pix[j] as i32) * (filt[l] as i32),
                    "j={j} l={l}"
                );
            }
        }
        assert_eq!(stats.mac_ops, 3 * 64);
        assert_eq!(stats.vmacs, 3);
    }

    #[test]
    fn qmov_requantizes_and_relu() {
        let mut p = Program::default();
        p.bundles.push(Bundle::s0(SlotOp::Csrwi { csr: Csr::FracShift, imm: 2 }));
        p.bundles.push(Bundle::s0(SlotOp::Li { rd: SReg(1), imm: 0 }));
        p.bundles.push(Bundle::s0(SlotOp::LdV { vd: VReg(0), addr: Addr::base(SReg(1)) }));
        p.bundles.push(Bundle::s0(SlotOp::LdV { vd: VReg(4), addr: Addr::offs(SReg(1), 32) }));
        // acc = a*b (Mul overwrites), then requant with relu into v5
        p.bundles.push(Bundle {
            slot0: SlotOp::Nop,
            v: [
                VecOp::Mul {
                    a: ASrc::VrBcast { vr: VReg(4), base: 0, step: 0 },
                    b: BSrc::Vr { vr: VReg(0) },
                },
                VecOp::Nop,
                VecOp::Nop,
            ],
        });
        p.bundles.push(Bundle {
            slot0: SlotOp::Nop,
            v: [VecOp::QMov { vd: VReg(5), j: 0, relu: true }, VecOp::Nop, VecOp::Nop],
        });
        p.bundles.push(Bundle::s0(SlotOp::Halt));
        let pm = ProgramMem::load(&p).unwrap();
        let mut cpu = Cpu::new(1 << 16);
        let filt: Vec<i16> = (0..16).map(|i| (i as i16 - 8) * 3).collect();
        cpu.mem.dm.poke_i16_slice(0, &filt);
        cpu.mem.dm.poke_i16_slice(32, &[10; 16]);
        let stats = cpu.run(&pm).unwrap();
        for l in 0..16 {
            let acc = 10 * filt[l] as i32;
            let expect = fixed::requantize(acc, 2, RoundMode::HalfUp, true);
            assert_eq!(cpu.regs.vr[5][l], expect, "lane {l}");
        }
        // QMov right after MAC: must stall ~MAC_TO_QMOV cycles
        assert!(stats.hazard_stalls >= MAC_TO_QMOV_LATENCY - 1);
    }

    #[test]
    fn region_violation_detected() {
        // vALU 1 writing VR region 2 must fault
        let mut p = Program::default();
        p.bundles.push(Bundle {
            slot0: SlotOp::Nop,
            v: [VecOp::Mov { vd: VReg(8), vs: VReg(0) }, VecOp::Nop, VecOp::Nop],
        });
        p.bundles.push(Bundle::s0(SlotOp::Halt));
        let pm = ProgramMem::load(&p).unwrap();
        let mut cpu = Cpu::new(1 << 16);
        assert!(matches!(cpu.run(&pm), Err(SimError::Access { .. })));
    }

    #[test]
    fn sfu_only_in_slot1() {
        let mut p = Program::default();
        p.bundles.push(Bundle {
            slot0: SlotOp::Nop,
            v: [VecOp::Nop, VecOp::Relu { vd: VReg(8), vs: VReg(8) }, VecOp::Nop],
        });
        p.bundles.push(Bundle::s0(SlotOp::Halt));
        let pm = ProgramMem::load(&p).unwrap();
        let mut cpu = Cpu::new(1 << 16);
        assert!(matches!(cpu.run(&pm), Err(SimError::Access { .. })));
    }

    #[test]
    fn lb_fill_and_mac_interlock() {
        let mut p = Program::default();
        p.bundles.push(Bundle::s0(SlotOp::Csrwi { csr: Csr::LbStride, imm: 1 }));
        p.bundles.push(Bundle::s0(SlotOp::Li { rd: SReg(1), imm: 0 }));
        p.bundles.push(Bundle::s0(SlotOp::LdV { vd: VReg(0), addr: Addr::base(SReg(1)) }));
        p.bundles.push(Bundle::s0(SlotOp::LbLoad { row: 0, dm: SReg(1), off: 0, win: 32, nrows: 1, rstride: 0 }));
        p.bundles.push(Bundle {
            slot0: SlotOp::Nop,
            v: [
                VecOp::Mul { a: ASrc::Lb { row: 0, off: 0 }, b: BSrc::Vr { vr: VReg(0) } },
                VecOp::Nop,
                VecOp::Nop,
            ],
        });
        p.bundles.push(Bundle::s0(SlotOp::Halt));
        let pm = ProgramMem::load(&p).unwrap();
        let mut cpu = Cpu::new(1 << 16);
        let data: Vec<i16> = (0..32).map(|i| i + 1).collect();
        cpu.mem.dm.poke_i16_slice(0, &data);
        let stats = cpu.run(&pm).unwrap();
        // 32-pixel fill takes 2 port-1 cycles; the MAC issued right after
        // must have stalled at least once
        assert!(stats.lb_stalls >= 1, "lb_stalls={}", stats.lb_stalls);
        // slice j reads pixel j (stride 1 from off 0), times filter lane l
        for j in 0..4 {
            for l in 0..16 {
                assert_eq!(cpu.regs.vrl[j][l], (j as i32 + 1) * data[l] as i32);
            }
        }
    }

    #[test]
    fn lda_sta_roundtrip_512bit() {
        let mut p = Program::default();
        p.bundles.push(Bundle::s0(SlotOp::Li { rd: SReg(1), imm: 0 }));
        p.bundles.push(Bundle::s0(SlotOp::Li { rd: SReg(2), imm: 256 }));
        p.bundles.push(Bundle::s0(SlotOp::LdA { ad: VAcc(0), addr: Addr::base(SReg(1)) }));
        p.bundles.push(Bundle::s0(SlotOp::StA { as_: VAcc(0), addr: Addr::base(SReg(2)) }));
        p.bundles.push(Bundle::s0(SlotOp::Halt));
        let pm = ProgramMem::load(&p).unwrap();
        let mut cpu = Cpu::new(1 << 16);
        // stage an i32 accumulator image: lo halves then hi halves
        let vals: Vec<i32> = (0..16).map(|i| (i - 8) * 100_000).collect();
        for (l, v) in vals.iter().enumerate() {
            cpu.mem.dm.poke_i16(2 * l, *v as i16);
            cpu.mem.dm.poke_i16(32 + 2 * l, (*v >> 16) as i16);
        }
        let stats = cpu.run(&pm).unwrap();
        assert_eq!(cpu.regs.vrl[0].to_vec(), vals);
        // copied back out
        for (l, v) in vals.iter().enumerate() {
            let lo = cpu.mem.dm.peek_i16(256 + 2 * l) as u16 as i32;
            let hi = cpu.mem.dm.peek_i16(256 + 32 + 2 * l) as i32;
            assert_eq!(lo | (hi << 16), *v);
        }
        assert_eq!(stats.aloads, 1);
        assert_eq!(stats.astores, 1);
        assert_eq!(stats.wide_ls_stalls, 2);
    }

    #[test]
    fn dma_wait_blocks() {
        let (cpu, stats) = run_asm(
            "li r1, 0\n\
             li r2, 1024\n\
             li r3, 512\n\
             dmald 0, r1, r2, r3\n\
             dmawait 0\n\
             halt",
        );
        assert!(stats.dma_wait_stalls > 0);
        assert_eq!(cpu.mem.ext.stats.bytes_read, 512);
    }

    #[test]
    fn utilization_metric() {
        // a pure vmac loop should approach utilization 1
        let mut src = String::from(
            "li r1, 0\nldv v0, [r1]\nlbld 0, r1, 16\ncsrwi lb_stride, 1\nnop\nnop\n",
        );
        for _ in 0..50 {
            src.push_str("nop | vmac lb:0, v0 | vmac lb:0, v0 | vmac lb:0, v0\n");
        }
        src.push_str("halt\n");
        let (_, stats) = run_asm(&src);
        let u = stats.utilization();
        assert!(u > 0.8, "utilization {u}");
    }

    #[test]
    fn run_off_end_detected() {
        let mut p = Program::default();
        p.bundles.push(Bundle::NOP);
        let pm = ProgramMem::load(&p).unwrap();
        let mut cpu = Cpu::new(1 << 16);
        assert!(matches!(cpu.run(&pm), Err(SimError::RanOff { .. })));
    }

    #[test]
    fn gating_affects_mac_numerics_and_stats() {
        let mut p = Program::default();
        p.bundles.push(Bundle::s0(SlotOp::Csrwi { csr: Csr::GateBits, imm: 8 }));
        p.bundles.push(Bundle::s0(SlotOp::Li { rd: SReg(1), imm: 0 }));
        p.bundles.push(Bundle::s0(SlotOp::LdV { vd: VReg(0), addr: Addr::base(SReg(1)) }));
        p.bundles.push(Bundle::s0(SlotOp::LdV { vd: VReg(4), addr: Addr::offs(SReg(1), 32) }));
        p.bundles.push(Bundle {
            slot0: SlotOp::Nop,
            v: [
                VecOp::Mul {
                    a: ASrc::VrBcast { vr: VReg(4), base: 0, step: 0 },
                    b: BSrc::Vr { vr: VReg(0) },
                },
                VecOp::Nop,
                VecOp::Nop,
            ],
        });
        p.bundles.push(Bundle::s0(SlotOp::Halt));
        let pm = ProgramMem::load(&p).unwrap();
        let mut cpu = Cpu::new(1 << 16);
        cpu.mem.dm.poke_i16_slice(0, &[0x0123; 16]);
        cpu.mem.dm.poke_i16_slice(32, &[0x0456; 16]);
        let stats = cpu.run(&pm).unwrap();
        let expect = (fixed::gate(0x0456, 8) as i32) * (fixed::gate(0x0123, 8) as i32);
        assert_eq!(cpu.regs.vrl[0][0], expect);
        assert_eq!(stats.mac_ops_gated8, 64);
    }
}
