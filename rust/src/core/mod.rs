//! The ConvAix core: an 8-stage (IF, ID, E1..E6) 4-slot VLIW pipeline.
//!
//! The simulator is *bundle-accurate with a hazard scoreboard*: one
//! bundle issues per cycle; stalls are charged where the hardware would
//! interlock —
//!
//! * vector-load → vALU use: 2 cycles (DM access completes in E4, the
//!   vALU reads operands in E2),
//! * MAC → requantize of the same accumulator: 4 cycles (the MAC result
//!   retires in E6),
//! * requantize → store of the same VR entry: 3 cycles,
//! * taken branches: 2 bubbles (resolved in E1),
//! * accumulator spills (`LdA`/`StA`, 512 b = two 256-b accesses):
//!   one extra slot-0 occupancy cycle,
//! * line-buffer reads of an in-flight fill and `DmaWait` block until
//!   the background engine delivers.
//!
//! Back-to-back MACs to the same accumulator do **not** stall (dedicated
//! accumulate forwarding path — the standard design for MAC datapaths,
//! and the only way the paper's 192 MAC/cycle steady state is possible).
//!
//! Register-file **sub-region port constraints** (Section IV) are
//! enforced: vALU in slot *s* may read VR regions {0, s}, write VR
//! region s, and owns VRl region s-1 exclusively; slot 0 accesses
//! everything. Violations are simulation errors — the code generator is
//! tested never to produce them.

pub mod cpu;
pub mod regfile;

pub use cpu::{CoreStats, Cpu, SimError};
pub use regfile::RegFiles;

// The latency constants live in `isa::analysis::timing` — the single
// source of truth shared between this simulator and the static cycle
// analyzer — and are re-exported here for the existing callers.
pub use crate::isa::analysis::timing::{
    BRANCH_BUBBLES, LOAD_USE_LATENCY, MAC_TO_QMOV_LATENCY, QMOV_TO_READ_LATENCY,
};
