//! Register files R / VR / VRl with the paper's sub-region semantics.
//!
//! Storage is plain arrays; *permission* checking lives here so both the
//! simulator and the codegen tests can query it. Sizes (Table I: 3648
//! bytes of registers):
//!
//! * `R`   : 32 × 32 b scalar (the paper's 16-bit R file + the 32-bit
//!           addressing registers, modeled as one 32-bit file) = 128 B
//! * `VR`  : 16 × 256 b = 512 B
//! * `VRl` : 12 × 512 b = 768 B
//!
//! (The remaining bytes of the paper's figure are pipeline registers,
//! accounted in `energy::area`.)

use crate::isa::{SReg, VAcc, VReg, LANES};

/// Which issue slot is touching the register file (permission checks).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Who {
    /// Slot 0 (load/store + data movement): full access.
    Slot0,
    /// vALU in issue slot 1..=3.
    Valu(u8),
}

/// vALU `s` may read VR sub-regions {0, s}.
pub fn can_read_vr(who: Who, vr: VReg) -> bool {
    match who {
        Who::Slot0 => true,
        Who::Valu(s) => {
            let r = vr.region();
            r == 0 || r == s
        }
    }
}

/// vALU `s` may write only VR sub-region s.
pub fn can_write_vr(who: Who, vr: VReg) -> bool {
    match who {
        Who::Slot0 => true,
        Who::Valu(s) => vr.region() == s,
    }
}

/// vALU `s` owns VRl sub-region s-1.
pub fn can_access_vrl(who: Who, a: VAcc) -> bool {
    match who {
        Who::Slot0 => true,
        Who::Valu(s) => a.region() == s - 1,
    }
}

/// The accumulator entries owned by vALU slot `s` (1..=3).
pub fn own_acc_base(s: u8) -> u8 {
    (s - 1) * 4
}

#[derive(Clone)]
pub struct RegFiles {
    pub r: [i32; 32],
    pub vr: [[i16; LANES]; 16],
    pub vrl: [[i32; LANES]; 12],
}

impl Default for RegFiles {
    fn default() -> Self {
        Self::new()
    }
}

impl RegFiles {
    pub fn new() -> Self {
        Self { r: [0; 32], vr: [[0; LANES]; 16], vrl: [[0; LANES]; 12] }
    }

    #[inline]
    pub fn r(&self, reg: SReg) -> i32 {
        self.r[reg.0 as usize]
    }

    #[inline]
    pub fn set_r(&mut self, reg: SReg, v: i32) {
        self.r[reg.0 as usize] = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slot0_sees_everything() {
        for i in 0..16 {
            assert!(can_read_vr(Who::Slot0, VReg(i)));
            assert!(can_write_vr(Who::Slot0, VReg(i)));
        }
        for i in 0..12 {
            assert!(can_access_vrl(Who::Slot0, VAcc(i)));
        }
    }

    #[test]
    fn valu_reads_shared_and_private() {
        // vALU 2: regions {0, 2} readable
        assert!(can_read_vr(Who::Valu(2), VReg(0)));
        assert!(can_read_vr(Who::Valu(2), VReg(3)));
        assert!(can_read_vr(Who::Valu(2), VReg(8)));
        assert!(can_read_vr(Who::Valu(2), VReg(11)));
        assert!(!can_read_vr(Who::Valu(2), VReg(4))); // region 1
        assert!(!can_read_vr(Who::Valu(2), VReg(12))); // region 3
    }

    #[test]
    fn valu_writes_only_private() {
        assert!(can_write_vr(Who::Valu(1), VReg(4)));
        assert!(!can_write_vr(Who::Valu(1), VReg(0)));
        assert!(!can_write_vr(Who::Valu(1), VReg(8)));
    }

    #[test]
    fn vrl_ownership() {
        assert!(can_access_vrl(Who::Valu(1), VAcc(0)));
        assert!(can_access_vrl(Who::Valu(1), VAcc(3)));
        assert!(!can_access_vrl(Who::Valu(1), VAcc(4)));
        assert!(can_access_vrl(Who::Valu(3), VAcc(8)));
        assert!(!can_access_vrl(Who::Valu(3), VAcc(7)));
        assert_eq!(own_acc_base(1), 0);
        assert_eq!(own_acc_base(2), 4);
        assert_eq!(own_acc_base(3), 8);
    }

    #[test]
    fn register_bytes_match_table1_storage() {
        // VR 512 B + VRl 768 B + R 128 B = 1408 B of architectural
        // registers; Table I's 3648 B adds pipeline registers (see
        // energy::area for the split).
        let arch = 16 * 32 + 12 * 64 + 32 * 4;
        assert_eq!(arch, 1408);
    }
}
