//! Logic-area model: Table I and the Fig. 3b breakdown.
//!
//! The only published split is "vALUs 56 %"; the remaining categories
//! are modeled from standard-cell intuition (register files and the
//! 4-slot VLIW decode are the next-largest blocks) and sum to the
//! published 1293 kGE. The model is parametric in the vector geometry so
//! the ablation bench can sweep lanes/slices/slots.

/// Total logic gate count (Table I), kGE.
pub const LOGIC_KGE_TOTAL: f64 = 1293.0;

/// On-chip SRAM (Table I): 128 KB data + 16 KB instruction.
pub const SRAM_KBYTES: usize = 144;

/// Register + pipeline-register bytes (Table I).
pub const REGISTER_BYTES: usize = 3648;
/// Architectural registers: R (128 B) + VR (512 B) + VRl (768 B).
pub const ARCH_REGISTER_BYTES: usize = 1408;

/// SRAM macro share of total chip area (Section V).
pub const SRAM_AREA_FRACTION: f64 = 0.63;

#[derive(Debug, Clone)]
pub struct AreaItem {
    pub name: &'static str,
    pub kge: f64,
}

/// Fig. 3b: logic-area breakdown (w/o SRAM macros). Fractions sum to 1;
/// the vALU share is the published 56 %.
pub fn area_breakdown() -> Vec<AreaItem> {
    let f = [
        ("vector ALUs (3 × 4 × 16 MAC)", 0.56),
        ("register files VR/VRl/R", 0.11),
        ("VLIW decode + scalar ALU + control", 0.12),
        ("memory interface + DMA", 0.08),
        ("line buffer", 0.05),
        ("SFU (activation / pooling)", 0.04),
        ("PM fetch", 0.04),
    ];
    f.iter()
        .map(|(name, frac)| AreaItem { name, kge: frac * LOGIC_KGE_TOTAL })
        .collect()
}

/// Parametric logic area for a hypothetical geometry (ablation): vALU
/// area scales with total MAC lanes; register files with storage bits;
/// the rest is fixed overhead.
pub fn logic_kge(slots: usize, slices: usize, lanes: usize) -> f64 {
    let base_lanes = 3.0 * 4.0 * 16.0;
    let l = (slots * slices * lanes) as f64;
    let valu = 0.56 * LOGIC_KGE_TOTAL * l / base_lanes;
    let rf = 0.11 * LOGIC_KGE_TOTAL * l / base_lanes; // VR/VRl scale with lanes
    let fixed = (1.0 - 0.56 - 0.11) * LOGIC_KGE_TOTAL;
    valu + rf + fixed
}

/// Peak GOP/s for a geometry at `mhz` (2 OPs per MAC).
pub fn peak_gops(slots: usize, slices: usize, lanes: usize, mhz: f64) -> f64 {
    2.0 * (slots * slices * lanes) as f64 * mhz * 1e6 / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_sums_to_total() {
        let total: f64 = area_breakdown().iter().map(|i| i.kge).sum();
        assert!((total - LOGIC_KGE_TOTAL).abs() < 1e-6);
    }

    #[test]
    fn valu_share_is_published_56_percent() {
        let b = area_breakdown();
        assert!((b[0].kge / LOGIC_KGE_TOTAL - 0.56).abs() < 1e-9);
    }

    #[test]
    fn parametric_matches_reference_geometry() {
        assert!((logic_kge(3, 4, 16) - LOGIC_KGE_TOTAL).abs() < 1e-6);
        // halving the lanes removes ~33.5% of logic
        let half = logic_kge(3, 4, 8);
        assert!(half < LOGIC_KGE_TOTAL * 0.7);
    }

    #[test]
    fn peak_gops_table1() {
        // Table I: 153.6 GOP/s at 400 MHz
        assert!((peak_gops(3, 4, 16, 400.0) - 153.6).abs() < 1e-9);
        assert!((peak_gops(3, 4, 16, 400.0) - crate::PEAK_GOPS).abs() < 1e-9);
    }

    #[test]
    fn register_accounting() {
        assert_eq!(ARCH_REGISTER_BYTES, 128 + 512 + 768);
        assert!(REGISTER_BYTES > ARCH_REGISTER_BYTES); // + pipeline registers
    }
}
