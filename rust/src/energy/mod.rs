//! Area and power models (Table I, Fig. 3b, Fig. 3c, Table II columns).
//!
//! The paper's numbers come from a post-P&R TSMC 28 nm netlist with
//! simulated switching activity — unavailable here. Substitution
//! (DESIGN.md §2): calibrated analytical models driven by the cycle
//! simulator's activity counters. Calibration anchors:
//!
//! * Table I: 1293 kGE logic, 144 KB SRAM, 3648 B registers;
//! * Fig. 3b: vALUs = 56 % of logic area;
//! * Fig. 3c: vALUs ≈ 44 %, DM+RF+LB ≈ 44.1 % of power (AlexNet conv3,
//!   8-bit gated);
//! * Table II: 228.8 mW (AlexNet) and 223.9 mW (VGG-16) total power.
//!
//! The fitted per-event energies (`power::consts`) reproduce all anchors
//! simultaneously to <1 % (see `tests` and EXPERIMENTS.md) and sit in
//! the literature range for 28 nm (MAC ≈ 1.6/3.3 pJ at 8/16 bit, SRAM
//! ≈ 0.1 pJ/bit/access).

pub mod area;
pub mod power;
pub mod scale;

pub use area::{area_breakdown, AreaItem, LOGIC_KGE_TOTAL};
pub use power::{network_power, PowerBreakdown};
pub use scale::{scale_energy_eff, scale_power};
