//! Activity-based power model (Fig. 3c, Table II power rows).
//!
//! `E = Σᵢ activityᵢ · eᵢ`, activities from the cycle simulator's
//! counters, per-event energies fitted to the paper's anchors (module
//! docs in `energy`). Categories follow Fig. 3c:
//!
//! * **vALU** — MAC datapaths incl. pipeline registers & operand mux
//!   (the paper notes these are included in its 44 %),
//! * **memory** — DM SRAM + register files + line buffer (44.1 %),
//! * **control** — instruction fetch/decode, scalar ALU, DMA engine.

use crate::core::CoreStats;

/// Fitted per-event energies (pJ), 28 nm @ 1 V. See module docs.
pub mod consts {
    /// One 16-bit MAC lane-op (multiplier + adder + pipe/mux share).
    pub const E_MAC16: f64 = 3.3;
    /// One precision-gated (≤8 bit effective) MAC lane-op — the gating
    /// keeps multiplier LSB toggling quiet (Moons et al. [9]).
    pub const E_MAC8: f64 = 1.585;
    /// 512-bit VRl accumulator-entry write.
    pub const E_VRL_WRITE: f64 = 20.4;
    /// 256-bit VR register file access.
    pub const E_VR_ACCESS: f64 = 4.0;
    /// 256-bit DM SRAM bank access (port 0 or 1).
    pub const E_DM_ACCESS: f64 = 25.0;
    /// Line-buffer pixel read (16 bit, combinational port).
    pub const E_LB_PIXEL: f64 = 0.505;
    /// One LbLoad fill (≈4 port-1 accesses to the LB SRAM side).
    pub const E_LB_FILL: f64 = 100.0;
    /// One VLIW bundle: PM fetch (256 bit) + 4-slot decode + issue +
    /// scalar ALU activity.
    pub const E_BUNDLE: f64 = 68.0;
    /// Requantize op (shift+round+saturate, 16 lanes).
    pub const E_QMOV: f64 = 6.0;
    /// SFU op (ReLU / pool-max, 16 lanes).
    pub const E_SFU: f64 = 4.0;
}

#[derive(Debug, Clone, Default)]
pub struct PowerBreakdown {
    pub valu_mw: f64,
    pub mem_mw: f64,
    pub ctrl_mw: f64,
}

impl PowerBreakdown {
    pub fn total_mw(&self) -> f64 {
        self.valu_mw + self.mem_mw + self.ctrl_mw
    }
    pub fn fractions(&self) -> (f64, f64, f64) {
        let t = self.total_mw();
        (self.valu_mw / t, self.mem_mw / t, self.ctrl_mw / t)
    }
}

/// Power over an execution window of `seconds`, from activity counters.
pub fn network_power(stats: &CoreStats, seconds: f64) -> PowerBreakdown {
    use consts::*;
    let mac16 = (stats.mac_ops - stats.mac_ops_gated8) as f64;
    let mac8 = stats.mac_ops_gated8 as f64;
    let e_valu = mac16 * E_MAC16 + mac8 * E_MAC8 + stats.qmovs as f64 * E_QMOV
        + stats.sfu_ops as f64 * E_SFU;
    let dm_accesses = (stats.vloads
        + stats.vstores
        + stats.sloads
        + stats.sstores
        + 2 * (stats.aloads + stats.astores)) as f64;
    let e_mem = stats.vrl_writes as f64 * E_VRL_WRITE
        + (stats.vr_reads + stats.vr_writes) as f64 * E_VR_ACCESS
        + dm_accesses * E_DM_ACCESS
        + stats.lb_pixel_reads as f64 * E_LB_PIXEL
        + stats.lb_fills as f64 * E_LB_FILL;
    let e_ctrl = stats.bundles as f64 * E_BUNDLE;
    // pJ -> mW: 1e-12 J / s * 1e3
    let to_mw = 1e-9 / seconds;
    PowerBreakdown {
        valu_mw: e_valu * to_mw,
        mem_mw: e_mem * to_mw,
        ctrl_mw: e_ctrl * to_mw,
    }
}

/// Energy efficiency in GOP/s/W given useful MACs over `seconds`.
pub fn energy_eff_gops_per_w(macs: u64, seconds: f64, power_mw: f64) -> f64 {
    let gops = 2.0 * macs as f64 / seconds / 1e9;
    gops / (power_mw / 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synthetic_stats(macs: u64, gated: bool) -> CoreStats {
        CoreStats {
            mac_ops: macs,
            mac_ops_gated8: if gated { macs } else { 0 },
            vmacs: macs / 64,
            vrl_writes: macs / 16,
            vloads: macs / 192,
            lb_pixel_reads: macs / 4,
            lb_fills: macs / 2000,
            bundles: macs / 150,
            ..Default::default()
        }
    }

    #[test]
    fn gating_saves_mac_power() {
        let s16 = synthetic_stats(1_000_000_000, false);
        let s8 = synthetic_stats(1_000_000_000, true);
        let p16 = network_power(&s16, 0.01);
        let p8 = network_power(&s8, 0.01);
        assert!(p8.valu_mw < p16.valu_mw * 0.6);
        assert_eq!(p8.mem_mw, p16.mem_mw);
    }

    #[test]
    fn power_scales_inverse_with_time() {
        let s = synthetic_stats(1_000_000_000, true);
        let fast = network_power(&s, 0.005);
        let slow = network_power(&s, 0.010);
        assert!((fast.total_mw() / slow.total_mw() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn energy_eff_formula() {
        // 1 GMAC in 10 ms at 200 mW -> 200 GOP/s / 0.2 W = 1000 GOP/s/W
        let eff = energy_eff_gops_per_w(1_000_000_000, 0.01, 200.0);
        assert!((eff - 1000.0).abs() < 1e-6);
    }

    #[test]
    fn fractions_sum_to_one() {
        let s = synthetic_stats(5_000_000, true);
        let p = network_power(&s, 0.001);
        let (a, b, c) = p.fractions();
        assert!((a + b + c - 1.0).abs() < 1e-9);
    }
}
