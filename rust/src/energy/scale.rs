//! Technology scaling (Table II footnote f):
//!
//! `P_scaled = P_old · (L_new / L_old) · (V_DD,new / V_DD,old)²`
//!
//! used by the paper to compare designs fabricated in 65/40/28 nm at a
//! uniform 28 nm / 1 V operating point.

/// Scale a power figure from (l_old nm, v_old V) to (l_new, v_new).
pub fn scale_power(p_old: f64, l_old: f64, v_old: f64, l_new: f64, v_new: f64) -> f64 {
    p_old * (l_new / l_old) * (v_new / v_old).powi(2)
}

/// Scale an energy-efficiency figure (GOP/s/W) — inverse of power
/// scaling at constant throughput.
pub fn scale_energy_eff(eff_old: f64, l_old: f64, v_old: f64, l_new: f64, v_new: f64) -> f64 {
    eff_old * (l_old / l_new) * (v_old / v_new).powi(2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eyeriss_scaling_matches_paper() {
        // Eyeriss AlexNet: 187 GOP/s/W @ 65 nm / 1 V -> 434 @ 28 nm / 1 V
        let scaled = scale_energy_eff(187.0, 65.0, 1.0, 28.0, 1.0);
        assert!((scaled - 434.1).abs() < 1.0, "{scaled}");
        // VGG: 104 -> 242
        let vgg = scale_energy_eff(104.0, 65.0, 1.0, 28.0, 1.0);
        assert!((vgg - 241.4).abs() < 1.0, "{vgg}");
    }

    #[test]
    fn envision_scaling_matches_paper() {
        // Envision: 815 GOP/s/W @ 40 nm, ~0.905 V -> ≈955 @ 28 nm / 1 V
        let scaled = scale_energy_eff(815.0, 40.0, 0.905, 28.0, 1.0);
        assert!((scaled - 955.0).abs() < 10.0, "{scaled}");
    }

    #[test]
    fn identity_scaling() {
        assert_eq!(scale_power(100.0, 28.0, 1.0, 28.0, 1.0), 100.0);
        assert_eq!(scale_energy_eff(100.0, 28.0, 1.0, 28.0, 1.0), 100.0);
    }

    #[test]
    fn power_and_eff_are_inverse() {
        let p = scale_power(100.0, 65.0, 1.2, 28.0, 1.0);
        let e = scale_energy_eff(50.0, 65.0, 1.2, 28.0, 1.0);
        // P shrinks, eff grows by the same ratio
        assert!(((100.0 / p) - (e / 50.0)).abs() < 1e-9);
    }
}
