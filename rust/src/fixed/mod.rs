//! Q-format 16-bit fixed-point arithmetic — the ConvAix datapath contract.
//!
//! These semantics are **bit-exact** with `python/compile/kernels/quant.py`
//! (and therefore with the AOT HLO artifacts the golden tests compare
//! against):
//!
//! * activations/weights: `i16`
//! * MAC accumulation: **wrapping** `i32` (the VRl accumulator register is
//!   32 bits per lane; hardware wraps, so does the model)
//! * requantization: arithmetic shift right by the runtime-configured
//!   fractional shift with a configurable rounding mode (the AOT artifacts
//!   use `HalfUp`, the ASIP default), then saturation to `i16`
//! * optional fused ReLU (the slot-1 SFU)
//! * precision gating: zeroing of operand LSBs (energy technique of
//!   Moons et al. [9]); numerics *and* the energy model respond to it.

/// Rounding mode of the vALU requantization stage (runtime configurable
/// on the ASIP via a control/status register).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RoundMode {
    /// Add 2^(s-1) then arithmetic shift (ties round towards +inf).
    /// This is the mode baked into the AOT golden artifacts.
    #[default]
    HalfUp,
    /// Plain arithmetic shift (truncate towards -inf).
    Truncate,
    /// Round half to even (unbiased; costs an extra adder stage on HW).
    HalfEven,
}

impl RoundMode {
    /// Parse a CSR encoding (see `isa::csr`).
    pub fn from_bits(b: u32) -> RoundMode {
        match b & 0b11 {
            0 => RoundMode::HalfUp,
            1 => RoundMode::Truncate,
            _ => RoundMode::HalfEven,
        }
    }
    pub fn to_bits(self) -> u32 {
        match self {
            RoundMode::HalfUp => 0,
            RoundMode::Truncate => 1,
            RoundMode::HalfEven => 2,
        }
    }
}

/// One 16×16→32-bit multiply-accumulate with wrapping i32 accumulation.
#[inline(always)]
pub fn mac(acc: i32, a: i16, w: i16) -> i32 {
    acc.wrapping_add((a as i32).wrapping_mul(w as i32))
}

/// Shift-and-round an i32 accumulator right by `shift` bits.
#[inline(always)]
pub fn round_shift(acc: i32, shift: u8, mode: RoundMode) -> i32 {
    if shift == 0 {
        return acc;
    }
    let s = shift as u32;
    match mode {
        RoundMode::HalfUp => acc.wrapping_add(1 << (s - 1)) >> s,
        RoundMode::Truncate => acc >> s,
        RoundMode::HalfEven => {
            let floor = acc >> s;
            let rem = acc & ((1 << s) - 1);
            let half = 1 << (s - 1);
            if rem > half || (rem == half && (floor & 1) == 1) {
                floor.wrapping_add(1)
            } else {
                floor
            }
        }
    }
}

/// Saturate an i32 to the i16 range.
#[inline(always)]
pub fn sat16(v: i32) -> i16 {
    v.clamp(i16::MIN as i32, i16::MAX as i32) as i16
}

/// Full requantization: shift+round, saturate, optional ReLU.
/// Bit-exact with `quant.requantize` in python.
#[inline(always)]
pub fn requantize(acc: i32, shift: u8, mode: RoundMode, relu: bool) -> i16 {
    let mut v = sat16(round_shift(acc, shift, mode));
    if relu && v < 0 {
        v = 0;
    }
    v
}

/// Accumulator initial value for a bias at output scale:
/// after the final shift the bias lands at unit weight.
#[inline(always)]
pub fn mac_init(bias: i32, shift: u8) -> i32 {
    if shift == 0 {
        bias
    } else {
        bias.wrapping_shl(shift as u32)
    }
}

/// Precision-gate an operand to `bits` effective bits by zeroing LSBs.
/// `bits >= 16` is a no-op. Bit-exact with `quant.gate_precision`.
#[inline(always)]
pub fn gate(v: i16, bits: u8) -> i16 {
    if bits >= 16 {
        v
    } else {
        let mask = (-1i16) << (16 - bits as i32);
        v & mask
    }
}

/// Convert an f32 in [-1,1)·2^(15-frac) to Q-format i16 (test helper).
pub fn to_q(v: f32, frac: u8) -> i16 {
    let scaled = v * (1i32 << frac) as f32;
    sat16(scaled.round() as i32)
}

/// Convert a Q-format i16 back to f32 (test helper).
pub fn from_q(v: i16, frac: u8) -> f32 {
    v as f32 / (1i32 << frac) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mac_wraps_like_hardware() {
        // accumulate close to i32::MAX and beyond: must wrap, not saturate
        let mut acc = i32::MAX - 10;
        acc = mac(acc, 100, 100); // +10000 wraps
        assert!(acc < 0, "expected wraparound, got {acc}");
    }

    #[test]
    fn round_half_up_ties() {
        assert_eq!(round_shift(3, 1, RoundMode::HalfUp), 2);
        assert_eq!(round_shift(1, 1, RoundMode::HalfUp), 1);
        assert_eq!(round_shift(-1, 1, RoundMode::HalfUp), 0);
        assert_eq!(round_shift(-3, 1, RoundMode::HalfUp), -1);
        assert_eq!(round_shift(2, 1, RoundMode::HalfUp), 1);
    }

    #[test]
    fn round_truncate() {
        assert_eq!(round_shift(3, 1, RoundMode::Truncate), 1);
        assert_eq!(round_shift(-1, 1, RoundMode::Truncate), -1);
        assert_eq!(round_shift(-4, 2, RoundMode::Truncate), -1);
    }

    #[test]
    fn round_half_even() {
        // 1.5 -> 2, 2.5 -> 2, -1.5 -> -2 (to even)
        assert_eq!(round_shift(3, 1, RoundMode::HalfEven), 2);
        assert_eq!(round_shift(5, 1, RoundMode::HalfEven), 2);
        assert_eq!(round_shift(-3, 1, RoundMode::HalfEven), -2);
        assert_eq!(round_shift(7, 1, RoundMode::HalfEven), 4);
    }

    #[test]
    fn requantize_saturates() {
        assert_eq!(requantize(40_000, 0, RoundMode::HalfUp, false), 32767);
        assert_eq!(requantize(-40_000, 0, RoundMode::HalfUp, false), -32768);
    }

    #[test]
    fn requantize_relu() {
        assert_eq!(requantize(-5, 0, RoundMode::HalfUp, true), 0);
        assert_eq!(requantize(5, 0, RoundMode::HalfUp, true), 5);
    }

    #[test]
    fn requantize_wrapping_round_addend() {
        // matches python test_requantize_wrapping_round_addend
        assert_eq!(requantize(i32::MAX, 8, RoundMode::HalfUp, false), -32768);
    }

    #[test]
    fn gating_masks_lsbs() {
        assert_eq!(gate(0x1234, 8), 0x1200);
        assert_eq!(gate(0x1234, 16), 0x1234);
        assert_eq!(gate(0x1234, 4), 0x1000);
        assert_eq!(gate(-1, 8), -256);
    }

    #[test]
    fn q_roundtrip() {
        let v = to_q(0.5, 8);
        assert_eq!(v, 128);
        assert!((from_q(v, 8) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn mac_init_shifts_bias() {
        assert_eq!(mac_init(3, 8), 3 << 8);
        assert_eq!(mac_init(-3, 0), -3);
    }
}
