//! DM bank geometry and the port-1 conflict rule — the single source of
//! truth shared by the simulator ([`crate::mem::dm::DataMem`] delegates
//! here) and the static analyzers ([`super::predict`] prices bank
//! conflicts through the same functions via [`super::timing`], and
//! [`super::memory`] annotates each enumerated access with its bank
//! set). Moved out of `mem/dm.rs` — not copied — so the analyzer cannot
//! drift from the machine (the PR 7 scoreboard discipline).
//!
//! Geometry (Section III of the paper): 128 KB DM in 16 dual-ported
//! 8 KB banks; port 0 serves the pipeline, port 1 serves DMA and the
//! line-buffer fill. A port-1 access that lands in the bank port 0
//! already touched in the same cycle retries next cycle and counts a
//! `bank_conflict`.

use crate::mem::{DM_BANKS, DM_BANK_BYTES};

/// Bank index of a DM byte address.
#[inline]
#[must_use]
pub fn bank_of(addr: usize) -> usize {
    (addr / DM_BANK_BYTES) % DM_BANKS
}

/// The port-1 retry rule: does a port-1 access at `p1_addr` collide with
/// the bank port 0 touched this cycle (`p0_bank`, `None` when the
/// pipeline made no DM access)? Block accesses never straddle a bank:
/// ports are 32 B wide and banks 8 KB, so the start address decides.
#[inline]
#[must_use]
pub fn p1_conflicts(p0_bank: Option<usize>, p1_addr: usize) -> bool {
    p0_bank == Some(bank_of(p1_addr))
}

/// Bitmask of the banks a byte range `[addr, addr + len)` touches
/// (bit *i* set ⇔ bank *i* touched). Used by the memory pass to report
/// each access's bank set; `len == 0` touches nothing.
#[must_use]
pub fn bank_set(addr: usize, len: usize) -> u16 {
    if len == 0 {
        return 0;
    }
    let first = addr / DM_BANK_BYTES;
    let last = (addr + len - 1) / DM_BANK_BYTES;
    if last - first + 1 >= DM_BANKS {
        return u16::MAX; // wraps the whole interleave
    }
    let mut mask = 0u16;
    for b in first..=last {
        mask |= 1 << (b % DM_BANKS);
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::DM_BYTES;

    #[test]
    fn bank_mapping() {
        assert_eq!(bank_of(0), 0);
        assert_eq!(bank_of(DM_BANK_BYTES - 1), 0);
        assert_eq!(bank_of(DM_BANK_BYTES), 1);
        assert_eq!(bank_of(DM_BYTES - 1), DM_BANKS - 1);
    }

    #[test]
    fn conflict_rule() {
        assert!(p1_conflicts(Some(0), 100));
        assert!(!p1_conflicts(Some(1), 100));
        assert!(!p1_conflicts(None, 100));
        assert!(p1_conflicts(Some(1), DM_BANK_BYTES + 4));
    }

    #[test]
    fn bank_sets() {
        assert_eq!(bank_set(0, 0), 0);
        assert_eq!(bank_set(0, 32), 1);
        assert_eq!(bank_set(DM_BANK_BYTES - 2, 4), 0b11);
        assert_eq!(bank_set(0, DM_BYTES), u16::MAX);
        // spans exactly the last and first bank of the interleave
        let m = bank_set(DM_BYTES - 2, 4);
        assert_eq!(m, (1 << (DM_BANKS - 1)) | 1);
    }
}
