//! Pass 2 — def-before-use dataflow.
//!
//! A forward *must-defined* analysis over the CFG: a register / CSR
//! counts as defined at a program point only if it is written on every
//! path from entry to that point (join = intersection). The entry state
//! is the task ABI (host-written scalar registers) plus the host-owned
//! `RoundMode`/`GateBits` CSRs; `FracShift` and `LbStride` must be
//! written by the program itself before any dependent op.
//!
//! Within a bundle, uses and defs follow the interpreter's execution
//! order: vector slots 1..=3 first (each slot's reads before its
//! writes), then slot 0 — so a `StV` legitimately sees a same-bundle
//! `QMov`'s definition, exactly like the hardware write path.
//!
//! Out-of-range register indices are skipped here (pass 3 reports them
//! as `RegionViolation`); intersection over a finite bitset lattice
//! guarantees the fixpoint terminates.

use crate::core::regfile::own_acc_base;
use crate::isa::{ASrc, BSrc, Bundle, Csr, Program, SReg, SlotOp, VReg, VecOp, SLICES};

use super::{finding, AbiSpec, Cfg, Finding, FindingKind};

const CSR_FRAC: u8 = 1 << 0;
const CSR_ROUND: u8 = 1 << 1;
const CSR_GATE: u8 = 1 << 2;
const CSR_STRIDE: u8 = 1 << 3;

fn csr_bit(c: Csr) -> u8 {
    match c {
        Csr::FracShift => CSR_FRAC,
        Csr::RoundMode => CSR_ROUND,
        Csr::GateBits => CSR_GATE,
        Csr::LbStride => CSR_STRIDE,
    }
}

fn csr_name(c: Csr) -> &'static str {
    match c {
        Csr::FracShift => "FracShift",
        Csr::RoundMode => "RoundMode",
        Csr::GateBits => "GateBits",
        Csr::LbStride => "LbStride",
    }
}

/// Must-defined bitsets at a program point.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Defs {
    r: u32,
    vr: u16,
    vrl: u16,
    csr: u8,
}

impl Defs {
    fn inter(a: Defs, b: Defs) -> Defs {
        Defs { r: a.r & b.r, vr: a.vr & b.vr, vrl: a.vrl & b.vrl, csr: a.csr & b.csr }
    }
}

fn use_r(d: &Defs, r: SReg, miss: &mut dyn FnMut(String)) {
    if r.0 < 32 && d.r & (1u32 << r.0) == 0 {
        miss(format!("r{} read before any definition", r.0));
    }
}

fn def_r(d: &mut Defs, r: SReg) {
    if r.0 < 32 {
        d.r |= 1 << r.0;
    }
}

fn use_vr(d: &Defs, v: VReg, miss: &mut dyn FnMut(String)) {
    if v.0 < 16 && d.vr & (1u16 << v.0) == 0 {
        miss(format!("v{} read before any definition", v.0));
    }
}

fn def_vr(d: &mut Defs, v: VReg) {
    if v.0 < 16 {
        d.vr |= 1 << v.0;
    }
}

fn use_acc(d: &Defs, a: u16, miss: &mut dyn FnMut(String)) {
    if a < 12 && d.vrl & (1u16 << a) == 0 {
        miss(format!("accumulator a{a} read before any definition"));
    }
}

fn def_acc(d: &mut Defs, a: u16) {
    if a < 12 {
        d.vrl |= 1 << a;
    }
}

fn use_csr(d: &Defs, c: Csr, miss: &mut dyn FnMut(String)) {
    if d.csr & csr_bit(c) == 0 {
        miss(format!("CSR {} read before written", csr_name(c)));
    }
}

/// One bundle's transfer function; `miss` receives a message per
/// use-before-def. The same function drives both the fixpoint (no-op
/// sink) and the reporting sweep, so they cannot disagree.
fn step(b: &Bundle, d: &mut Defs, miss: &mut dyn FnMut(String)) {
    for (i, op) in b.v.iter().enumerate() {
        let s = i as u8 + 1;
        let base = own_acc_base(s) as u16;
        match *op {
            VecOp::Nop => {}
            VecOp::Mac { a, b } | VecOp::Mul { a, b } => {
                let accumulates = matches!(op, VecOp::Mac { .. });
                match a {
                    // both LB source shapes apply the per-slice/lane stride
                    ASrc::Lb { .. } | ASrc::LbVec { .. } => use_csr(d, Csr::LbStride, miss),
                    ASrc::VrBcast { vr, .. } => use_vr(d, vr, miss),
                    ASrc::VrQuad { vr } => {
                        for k in 0..SLICES as u8 {
                            use_vr(d, VReg(vr.0.wrapping_add(k)), miss);
                        }
                    }
                }
                match b {
                    BSrc::Vr { vr } | BSrc::VrLane { vr, .. } | BSrc::VrLaneQuad { vr, .. } => {
                        use_vr(d, vr, miss)
                    }
                    BSrc::VrQuad { vr } => {
                        for k in 0..SLICES as u8 {
                            use_vr(d, VReg(vr.0.wrapping_add(k)), miss);
                        }
                    }
                    // FIFO occupancy is pass 3's job
                    BSrc::Fifo | BSrc::FifoLaneQuad { .. } => {}
                }
                use_csr(d, Csr::GateBits, miss);
                if accumulates {
                    for j in 0..SLICES as u16 {
                        use_acc(d, base + j, miss);
                    }
                }
                for j in 0..SLICES as u16 {
                    def_acc(d, base + j);
                }
            }
            VecOp::ClrA { only } => {
                for j in 0..SLICES as u8 {
                    if only.is_none() || only == Some(j) {
                        def_acc(d, base + j as u16);
                    }
                }
            }
            VecOp::InitA { vr } | VecOp::InitALane { vr, .. } => {
                use_vr(d, vr, miss);
                use_csr(d, Csr::FracShift, miss);
                for j in 0..SLICES as u16 {
                    def_acc(d, base + j);
                }
            }
            VecOp::QMov { vd, j, .. } => {
                use_acc(d, base + j as u16, miss);
                use_csr(d, Csr::FracShift, miss);
                use_csr(d, Csr::RoundMode, miss);
                def_vr(d, vd);
            }
            VecOp::EOp { vd, va, vb, .. } => {
                use_vr(d, va, miss);
                use_vr(d, vb, miss);
                def_vr(d, vd);
            }
            VecOp::EOpI { vd, va, .. } => {
                use_vr(d, va, miss);
                def_vr(d, vd);
            }
            VecOp::Mov { vd, vs } | VecOp::Bcst { vd, vs, .. } | VecOp::Relu { vd, vs } => {
                use_vr(d, vs, miss);
                def_vr(d, vd);
            }
            VecOp::PoolMax { vd, va, vb } => {
                use_vr(d, va, miss);
                use_vr(d, vb, miss);
                def_vr(d, vd);
            }
        }
    }
    match b.slot0 {
        SlotOp::Nop | SlotOp::Halt | SlotOp::Jmp { .. } | SlotOp::LoopI { .. } => {}
        SlotOp::DmaWait { .. } => {}
        SlotOp::Li { rd, .. } => def_r(d, rd),
        SlotOp::Alu { rd, ra, rb, .. } => {
            use_r(d, ra, miss);
            use_r(d, rb, miss);
            def_r(d, rd);
        }
        SlotOp::AluI { rd, ra, .. } => {
            use_r(d, ra, miss);
            def_r(d, rd);
        }
        SlotOp::Br { ra, rb, .. } => {
            use_r(d, ra, miss);
            use_r(d, rb, miss);
        }
        SlotOp::Loop { n, .. } => use_r(d, n, miss),
        SlotOp::Csrwi { csr, .. } => d.csr |= csr_bit(csr),
        SlotOp::Csrw { csr, rs } => {
            use_r(d, rs, miss);
            d.csr |= csr_bit(csr);
        }
        SlotOp::LdS { rd, addr } => {
            use_r(d, addr.base, miss);
            def_r(d, rd);
        }
        SlotOp::StS { rs, addr } => {
            use_r(d, rs, miss);
            use_r(d, addr.base, miss);
        }
        SlotOp::LdV { vd, addr } => {
            use_r(d, addr.base, miss);
            def_vr(d, vd);
        }
        SlotOp::StV { vs, addr } => {
            use_vr(d, vs, miss);
            use_r(d, addr.base, miss);
        }
        SlotOp::LdVF { addr } => use_r(d, addr.base, miss),
        SlotOp::LdA { ad, addr } => {
            use_r(d, addr.base, miss);
            def_acc(d, ad.0 as u16);
        }
        SlotOp::StA { as_, addr } => {
            use_acc(d, as_.0 as u16, miss);
            use_r(d, addr.base, miss);
        }
        SlotOp::DmaLoad { ext, dm, len, .. } | SlotOp::DmaStore { ext, dm, len, .. } => {
            use_r(d, ext, miss);
            use_r(d, dm, miss);
            use_r(d, len, miss);
        }
        SlotOp::LbLoad { dm, .. } => use_r(d, dm, miss),
    }
}

pub(crate) fn check(prog: &Program, cfg: &Cfg, abi: &AbiSpec, out: &mut Vec<Finding>) {
    let len = prog.bundles.len();
    if len == 0 {
        return;
    }
    let mut entry = Defs { r: 0, vr: 0, vrl: 0, csr: CSR_ROUND | CSR_GATE };
    for &r in &abi.defined_sregs {
        if r < 32 {
            entry.r |= 1 << r;
        }
    }
    let mut instate: Vec<Option<Defs>> = vec![None; len];
    instate[0] = Some(entry);
    let mut work = vec![0usize];
    while let Some(pc) = work.pop() {
        let mut d = instate[pc].unwrap();
        step(&prog.bundles[pc], &mut d, &mut |_| {});
        for &succ in &cfg.succs[pc] {
            if succ >= len {
                continue;
            }
            let merged = match instate[succ] {
                None => d,
                Some(old) => Defs::inter(old, d),
            };
            if instate[succ] != Some(merged) {
                instate[succ] = Some(merged);
                work.push(succ);
            }
        }
    }
    // report sweep over reachable bundles only
    for pc in 0..len {
        let Some(mut d) = instate[pc] else { continue };
        let mut msgs: Vec<String> = Vec::new();
        step(&prog.bundles[pc], &mut d, &mut |m| msgs.push(m));
        msgs.dedup();
        for m in msgs {
            out.push(finding(prog, FindingKind::UseBeforeDef, pc, m));
        }
    }
}
