//! Pass 5 — the symbolic memory-access verifier.
//!
//! Walks a decoded [`Program`] under a concrete [`AbiEnv`] (the same
//! constant-lattice walk [`super::predict`] uses, minus all timing) and
//! enumerates **every** memory access the program performs: DM port-0
//! pipeline accesses, DM port-1 line-buffer fills and DMA ranges, and
//! external-memory DMA endpoints — each as an [`Access`] carrying
//! (address, length, port, read/write, bank set). On that stream it
//! checks, per [`MemSpec`]:
//!
//! * **bounds** ([`FindingKind::MemBounds`]) — every DM access lies
//!   inside DM and inside a region that permits its direction. The
//!   region map is the plan's `DmMap` with the planner's slack included
//!   (the 64 B filter over-read, the staged-input prefetch band), so the
//!   checker proves the slack sufficient instead of trusting it.
//! * **aliasing** ([`FindingKind::MemOverlap`]) — the declared regions
//!   are pairwise disjoint and end within DM, machine-checked per
//!   compiled plan instead of asserted by construction in `layout.rs`.
//! * **hazards** ([`FindingKind::DmaRace`]) — between a DMA start and
//!   its `DmaWait`, no compute access may touch a `DmaLoad`'s
//!   destination byte range and no compute *write* may touch a
//!   `DmaStore`'s source byte range. This refines `resource.rs`'s
//!   channel-level protocol lint to exact byte ranges.
//!
//! Because the walk is driven by a concrete ABI environment, the caller
//! parameterizes it by the *actual* per-row register file
//! (`r2 = dm.input + oh_local·S·row_bytes`, see
//! `codegen::compiled::CompiledConv::abi_env_for_row`) — not just row 0.
//! Accesses whose base register is statically unknown (e.g. derived
//! from loaded data) are skipped and counted in [`Trace::unknown`];
//! unknown *control flow* aborts with [`MemError::Unsupported`] exactly
//! like the cycle analyzer, since a walk that cannot follow the path
//! cannot claim to have enumerated its accesses.

use crate::isa::{Program, SReg, SlotOp};
use crate::mem::DM_BYTES;

use super::banks::bank_set;
use super::predict::AbiEnv;
use super::{finding, Finding, FindingKind, Report};

/// Which physical port an access uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Port {
    /// Pipeline (slot-0 load/store) accesses.
    P0,
    /// Background accesses: line-buffer fill and DMA.
    P1,
}

/// Which address space an access touches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Space {
    Dm,
    Ext,
}

/// What issued the access (for reporting; bounds rules key off
/// `space`/`write`/`kind == Dma`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessKind {
    /// `LdS`/`StS` (2 B), `LdV`/`StV`/`LdVF` (32 B), `LdA`/`StA` (64 B).
    Pipeline,
    /// One source-row read of an `LbLoad` 2-D window fill.
    LbFill,
    /// A whole DMA transfer range (recorded once at start).
    Dma,
}

/// One enumerated memory access.
#[derive(Debug, Clone, Copy)]
pub struct Access {
    /// Bundle that issued it (for DMA, the `DmaLoad`/`DmaStore` bundle).
    pub pc: usize,
    pub space: Space,
    pub addr: usize,
    pub len: usize,
    pub write: bool,
    pub port: Port,
    pub kind: AccessKind,
    /// DM banks the range touches (bit *i* ⇔ bank *i*; 0 for ext).
    pub banks: u16,
}

impl Access {
    fn overlaps(&self, lo: usize, hi: usize) -> bool {
        self.space == Space::Dm && self.addr < hi && lo < self.addr + self.len
    }
}

/// An in-flight DMA transfer (from start until its `DmaWait`), used for
/// the byte-range hazard check.
#[derive(Debug, Clone, Copy)]
struct Transfer {
    pc: usize,
    ch: u8,
    /// DM byte range `[lo, hi)`.
    lo: usize,
    hi: usize,
    /// `DmaLoad` (ext → DM, DM range is the destination) vs `DmaStore`.
    load: bool,
    /// Index into the access stream where the transfer started.
    start: usize,
    /// Index where `DmaWait` closed it (`usize::MAX` = still open at halt).
    end: usize,
}

/// The full enumeration of a program's accesses under one ABI env.
#[derive(Debug, Default)]
pub struct Trace {
    pub accesses: Vec<Access>,
    /// Accesses skipped because their base register was unknown.
    pub unknown: usize,
    transfers: Vec<Transfer>,
}

/// One named `DmMap` region with its permitted access directions.
#[derive(Debug, Clone)]
pub struct Region {
    pub name: &'static str,
    /// Byte range `[start, end)`.
    pub start: usize,
    pub end: usize,
    pub read: bool,
    pub write: bool,
}

impl Region {
    pub fn new(name: &'static str, start: usize, end: usize, read: bool, write: bool) -> Self {
        Self { name, start, end, read, write }
    }
}

/// The memory contract a program is checked against. With no regions,
/// only the DM extent and DMA hazards are checked (hand-written / test
/// programs); plan-derived specs come from
/// `codegen::conv::mem_spec` / `codegen::pool::mem_spec`.
#[derive(Debug, Clone, Default)]
pub struct MemSpec {
    pub regions: Vec<Region>,
    pub dm_bytes: usize,
}

impl MemSpec {
    /// No region constraints — DM extent and DMA hazards only.
    pub fn open() -> Self {
        Self { regions: vec![], dm_bytes: DM_BYTES }
    }

    pub fn with_regions(regions: Vec<Region>) -> Self {
        Self { regions, dm_bytes: DM_BYTES }
    }

    /// The aliasing check: regions pairwise disjoint, each within DM.
    /// Returns one message per violation (empty = disjoint).
    pub fn region_violations(&self) -> Vec<String> {
        let mut out = Vec::new();
        for r in &self.regions {
            if r.start > r.end {
                out.push(format!("region {} is inverted ({:#x}..{:#x})", r.name, r.start, r.end));
            }
            if r.end > self.dm_bytes {
                out.push(format!(
                    "region {} ends at {:#x}, past DM ({:#x} bytes)",
                    r.name, r.end, self.dm_bytes
                ));
            }
        }
        for (i, a) in self.regions.iter().enumerate() {
            for b in &self.regions[i + 1..] {
                if a.start < b.end && b.start < a.end {
                    out.push(format!(
                        "regions {} ({:#x}..{:#x}) and {} ({:#x}..{:#x}) overlap",
                        a.name, a.start, a.end, b.name, b.start, b.end
                    ));
                }
            }
        }
        out
    }
}

/// Why a walk could not complete.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MemError {
    /// Control flow (or a DMA operand, which the hazard check must
    /// know) depends on a statically unknown register.
    Unsupported { pc: usize, what: String },
    /// Walk exceeded the step/access budget (runaway loop).
    Watchdog,
    /// Ran past the last bundle (the structural pass reports this too).
    RanOff { pc: usize },
}

impl std::fmt::Display for MemError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MemError::Unsupported { pc, what } => {
                write!(f, "bundle {pc}: unsupported for memory analysis: {what}")
            }
            MemError::Watchdog => write!(f, "watchdog: memory walk exceeded its step budget"),
            MemError::RanOff { pc } => write!(f, "ran past the last bundle (pc={pc})"),
        }
    }
}

impl std::error::Error for MemError {}

const MAX_STEPS: u64 = 50_000_000;
const MAX_ACCESSES: usize = 4_000_000;

/// Enumerate every access of `prog` under `env` (program order).
pub fn trace(prog: &Program, env: &AbiEnv) -> Result<Trace, MemError> {
    let mut w = Walker::new(env);
    let mut steps = 0u64;
    while !w.halted {
        steps += 1;
        if steps > MAX_STEPS || w.tr.accesses.len() > MAX_ACCESSES {
            return Err(MemError::Watchdog);
        }
        if w.pc >= prog.bundles.len() {
            return Err(MemError::RanOff { pc: w.pc });
        }
        w.step(prog)?;
    }
    Ok(w.tr)
}

/// Run the full pass: enumerate accesses, then check region aliasing,
/// per-access bounds and DMA–compute hazards. Findings are deduplicated
/// per (kind, bundle) — loop iterations repeat the same access sites.
pub fn check(prog: &Program, env: &AbiEnv, spec: &MemSpec) -> Result<Report, MemError> {
    let tr = trace(prog, env)?;
    let mut out: Vec<Finding> = Vec::new();
    let mut seen = std::collections::BTreeSet::new();
    let mut push = |out: &mut Vec<Finding>, kind: FindingKind, pc: usize, detail: String| {
        if seen.insert((kind, pc)) {
            out.push(finding(prog, kind, pc, detail));
        }
    };

    for v in spec.region_violations() {
        push(&mut out, FindingKind::MemOverlap, 0, v);
    }

    for a in &tr.accesses {
        if a.space != Space::Dm {
            continue;
        }
        if a.addr + a.len > spec.dm_bytes {
            push(
                &mut out,
                FindingKind::MemBounds,
                a.pc,
                format!("access {:#x}+{} runs past DM ({:#x} bytes)", a.addr, a.len, spec.dm_bytes),
            );
            continue;
        }
        // DMA ranges target host-staging territory, not the task's
        // region map; they are covered by the extent check above and
        // the hazard check below.
        if a.kind == AccessKind::Dma || spec.regions.is_empty() {
            continue;
        }
        match spec.regions.iter().find(|r| a.addr >= r.start && a.addr + a.len <= r.end) {
            None => push(
                &mut out,
                FindingKind::MemBounds,
                a.pc,
                format!(
                    "{} {:#x}+{} outside every declared region",
                    if a.write { "write" } else { "read" },
                    a.addr,
                    a.len
                ),
            ),
            Some(r) => {
                let ok = if a.write { r.write } else { r.read };
                if !ok {
                    push(
                        &mut out,
                        FindingKind::MemBounds,
                        a.pc,
                        format!(
                            "region {} is not {} ({:#x}+{})",
                            r.name,
                            if a.write { "writable" } else { "readable" },
                            a.addr,
                            a.len
                        ),
                    );
                }
            }
        }
    }

    for t in &tr.transfers {
        let end = t.end.min(tr.accesses.len());
        for a in &tr.accesses[t.start..end] {
            if a.kind == AccessKind::Dma && a.pc == t.pc {
                continue; // the transfer's own range records
            }
            let races = if t.load {
                // ext -> DM: nothing may touch the landing zone
                a.overlaps(t.lo, t.hi)
            } else {
                // DM -> ext: writes would corrupt the outgoing data
                a.write && a.overlaps(t.lo, t.hi)
            };
            if races {
                push(
                    &mut out,
                    FindingKind::DmaRace,
                    a.pc,
                    format!(
                        "{} {:#x}+{} intersects DMA ch{} {} range {:#x}..{:#x} (started at bundle {})",
                        if a.write { "write" } else { "read" },
                        a.addr,
                        a.len,
                        t.ch,
                        if t.load { "destination" } else { "source" },
                        t.lo,
                        t.hi,
                        t.pc
                    ),
                );
            }
        }
    }

    out.sort_by(|a, b| (a.pc, a.kind).cmp(&(b.pc, b.kind)));
    Ok(Report { findings: out })
}

struct LoopFrame {
    start: usize,
    last: usize,
    remaining: u32,
}

enum PcUpdate {
    Seq,
    Jump(usize),
    Halt,
}

/// The untimed symbolic machine: the same constant lattice as
/// `predict::Walker`, recording accesses instead of pricing them. Kept
/// separate because this walker must *accept* DMA programs (the hazard
/// check exists for them) while the cycle analyzer rejects them.
struct Walker {
    regs: [Option<i32>; 32],
    loops: Vec<LoopFrame>,
    pc: usize,
    halted: bool,
    tr: Trace,
    /// Open transfer per DMA channel (index into `tr.transfers`).
    open: [Option<usize>; 2],
}

impl Walker {
    fn new(env: &AbiEnv) -> Self {
        let mut regs = [None; 32];
        for &(r, v) in &env.regs {
            if (r.0 as usize) < 32 {
                regs[r.0 as usize] = Some(v);
            }
        }
        Self { regs, loops: Vec::with_capacity(4), pc: 0, halted: false, tr: Trace::default(), open: [None; 2] }
    }

    fn unsupported(&self, what: impl Into<String>) -> MemError {
        MemError::Unsupported { pc: self.pc, what: what.into() }
    }

    fn known(&self, r: SReg, why: &str) -> Result<i32, MemError> {
        self.regs[r.0 as usize]
            .ok_or_else(|| self.unsupported(format!("{why} depends on unknown r{}", r.0)))
    }

    /// `addr_of` over the constant lattice (applies post-increment).
    /// Unknown base → `None` (the access is skipped, counted).
    fn addr_of(&mut self, a: &crate::isa::Addr) -> Option<usize> {
        let base = self.regs[a.base.0 as usize];
        if a.post_inc != 0 {
            self.regs[a.base.0 as usize] = base.map(|b| b.wrapping_add(a.post_inc));
        }
        match base {
            Some(b) => Some(b.wrapping_add(a.offset) as usize),
            None => {
                self.tr.unknown += 1;
                None
            }
        }
    }

    fn record(&mut self, space: Space, addr: usize, len: usize, write: bool, port: Port, kind: AccessKind) {
        let banks = if space == Space::Dm { bank_set(addr, len) } else { 0 };
        self.tr.accesses.push(Access { pc: self.pc, space, addr, len, write, port, kind, banks });
    }

    fn p0(&mut self, addr: &crate::isa::Addr, len: usize, write: bool) {
        if let Some(a) = self.addr_of(addr) {
            self.record(Space::Dm, a, len, write, Port::P0, AccessKind::Pipeline);
        }
    }

    fn step(&mut self, prog: &Program) -> Result<(), MemError> {
        let bundle = &prog.bundles[self.pc];
        // vector slots never touch memory (LB reads come from the fill,
        // which LbLoad records); only slot 0 matters here
        let next_pc = self.exec_slot0(&bundle.slot0)?;
        match next_pc {
            PcUpdate::Seq => self.pc = self.loop_next(self.pc),
            PcUpdate::Jump(t) => self.pc = t,
            PcUpdate::Halt => self.halted = true,
        }
        Ok(())
    }

    fn loop_next(&mut self, pc: usize) -> usize {
        if let Some(frame) = self.loops.last_mut() {
            if pc == frame.last {
                if frame.remaining > 0 {
                    frame.remaining -= 1;
                    return frame.start;
                }
                self.loops.pop();
            }
        }
        pc + 1
    }

    fn exec_slot0(&mut self, op: &SlotOp) -> Result<PcUpdate, MemError> {
        Ok(match *op {
            SlotOp::Nop | SlotOp::Csrwi { .. } => PcUpdate::Seq,
            SlotOp::Halt => PcUpdate::Halt,
            SlotOp::Li { rd, imm } => {
                self.regs[rd.0 as usize] = Some(imm);
                PcUpdate::Seq
            }
            SlotOp::Alu { f, w, rd, ra, rb } => {
                let v = match (self.regs[ra.0 as usize], self.regs[rb.0 as usize]) {
                    (Some(a), Some(b)) => Some(crate::core::cpu::alu(f, w, a, b)),
                    _ => None,
                };
                self.regs[rd.0 as usize] = v;
                PcUpdate::Seq
            }
            SlotOp::AluI { f, w, rd, ra, imm } => {
                self.regs[rd.0 as usize] =
                    self.regs[ra.0 as usize].map(|a| crate::core::cpu::alu(f, w, a, imm));
                PcUpdate::Seq
            }
            SlotOp::Br { c, ra, rb, target } => {
                let a = self.known(ra, "branch")?;
                let b = self.known(rb, "branch")?;
                let taken = match c {
                    crate::isa::Cond::Eq => a == b,
                    crate::isa::Cond::Ne => a != b,
                    crate::isa::Cond::Lt => a < b,
                    crate::isa::Cond::Ge => a >= b,
                };
                if taken {
                    PcUpdate::Jump(target as usize)
                } else {
                    PcUpdate::Seq
                }
            }
            SlotOp::Jmp { target } => PcUpdate::Jump(target as usize),
            SlotOp::Loop { n, body } => {
                let count = self.known(n, "loop count")?.max(0) as u32;
                self.push_loop(count, body)?
            }
            SlotOp::LoopI { n, body } => self.push_loop(n, body)?,
            SlotOp::Csrw { csr: _, rs: _ } => PcUpdate::Seq,
            SlotOp::LdS { rd, addr } => {
                self.p0(&addr, 2, false);
                // a loaded value is data, not a static constant
                self.regs[rd.0 as usize] = None;
                PcUpdate::Seq
            }
            SlotOp::StS { rs: _, addr } => {
                self.p0(&addr, 2, true);
                PcUpdate::Seq
            }
            SlotOp::LdV { vd: _, addr } | SlotOp::LdVF { addr } => {
                self.p0(&addr, 32, false);
                PcUpdate::Seq
            }
            SlotOp::StV { vs: _, addr } => {
                self.p0(&addr, 32, true);
                PcUpdate::Seq
            }
            SlotOp::LdA { ad: _, addr } => {
                self.p0(&addr, 64, false);
                PcUpdate::Seq
            }
            SlotOp::StA { as_: _, addr } => {
                self.p0(&addr, 64, true);
                PcUpdate::Seq
            }
            SlotOp::DmaLoad { ch, ext, dm, len } | SlotOp::DmaStore { ch, ext, dm, len } => {
                let load = matches!(op, SlotOp::DmaLoad { .. });
                // the hazard check is meaningless with an unknown range,
                // so DMA operands must be statically known
                let e = self.known(ext, "DMA ext address")?.max(0) as usize;
                let d = self.known(dm, "DMA dm address")?.max(0) as usize;
                let n = self.known(len, "DMA length")?.max(0) as usize;
                let start = self.tr.accesses.len();
                self.record(Space::Dm, d, n, load, Port::P1, AccessKind::Dma);
                self.record(Space::Ext, e, n, !load, Port::P1, AccessKind::Dma);
                if n > 0 {
                    let idx = self.tr.transfers.len();
                    self.tr.transfers.push(Transfer {
                        pc: self.pc,
                        ch,
                        lo: d,
                        hi: d + n,
                        load,
                        start,
                        end: usize::MAX,
                    });
                    // a restart without DmaWait is resource.rs's lint;
                    // track the newest transfer per channel here
                    self.open[(ch & 1) as usize] = Some(idx);
                }
                PcUpdate::Seq
            }
            SlotOp::DmaWait { ch } => {
                if let Some(idx) = self.open[(ch & 1) as usize].take() {
                    self.tr.transfers[idx].end = self.tr.accesses.len();
                }
                PcUpdate::Seq
            }
            SlotOp::LbLoad { row: _, dm, off, win, nrows, rstride } => {
                if let Some(base) = self.regs[dm.0 as usize] {
                    let base = base.wrapping_add(off as i32) as usize;
                    for r in 0..nrows as usize {
                        let a = base + r * rstride as usize;
                        self.record(Space::Dm, a, win as usize * 2, false, Port::P1, AccessKind::LbFill);
                    }
                } else {
                    self.tr.unknown += 1;
                }
                PcUpdate::Seq
            }
        })
    }

    fn push_loop(&mut self, n: u32, body: u16) -> Result<PcUpdate, MemError> {
        if body == 0 {
            return Err(self.unsupported("loop with empty body"));
        }
        if self.loops.len() >= 2 {
            return Err(self.unsupported("hardware loop nesting > 2"));
        }
        if n == 0 {
            return Ok(PcUpdate::Jump(self.pc + 1 + body as usize));
        }
        self.loops.push(LoopFrame {
            start: self.pc + 1,
            last: self.pc + body as usize,
            remaining: n - 1,
        });
        Ok(PcUpdate::Seq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::asm::assemble;

    fn run(src: &str, env: &AbiEnv, spec: &MemSpec) -> Report {
        let p = assemble(src).unwrap();
        check(&p, env, spec).unwrap()
    }

    #[test]
    fn accesses_are_enumerated_with_banks() {
        let p = assemble(
            "li r1, 8192\n\
             ldv v0, [r1]\n\
             stv v0, [r1+32]\n\
             halt",
        )
        .unwrap();
        let tr = trace(&p, &AbiEnv::default()).unwrap();
        assert_eq!(tr.accesses.len(), 2);
        assert_eq!(tr.accesses[0].banks, 1 << 1);
        assert!(!tr.accesses[0].write);
        assert!(tr.accesses[1].write);
        assert_eq!(tr.unknown, 0);
    }

    #[test]
    fn bounds_respected_inside_region() {
        let spec = MemSpec::with_regions(vec![
            Region::new("in", 0, 1024, true, false),
            Region::new("out", 1024, 2048, false, true),
        ]);
        let r = run(
            "li r1, 0\n\
             li r2, 1024\n\
             ldv v0, [r1]\n\
             stv v0, [r2]\n\
             halt",
            &AbiEnv::default(),
            &spec,
        );
        assert!(r.is_clean(), "{r}");
    }

    #[test]
    fn read_outside_regions_is_flagged() {
        let spec = MemSpec::with_regions(vec![Region::new("in", 0, 64, true, false)]);
        let r = run("li r1, 64\nldv v0, [r1]\nhalt", &AbiEnv::default(), &spec);
        assert!(r.has(FindingKind::MemBounds), "{r}");
    }

    #[test]
    fn write_to_read_only_region_is_flagged() {
        let spec = MemSpec::with_regions(vec![Region::new("in", 0, 1024, true, false)]);
        let r = run("li r1, 0\nli r2, 1\nsts r2, [r1]\nhalt", &AbiEnv::default(), &spec);
        assert!(r.has(FindingKind::MemBounds), "{r}");
    }

    #[test]
    fn overlapping_regions_are_flagged() {
        let spec = MemSpec::with_regions(vec![
            Region::new("a", 0, 128, true, false),
            Region::new("b", 96, 256, true, true),
        ]);
        let r = run("halt", &AbiEnv::default(), &spec);
        assert!(r.has(FindingKind::MemOverlap), "{r}");
    }

    #[test]
    fn region_past_dm_end_is_flagged() {
        let spec = MemSpec::with_regions(vec![Region::new("a", 0, DM_BYTES + 1, true, true)]);
        let r = run("halt", &AbiEnv::default(), &spec);
        assert!(r.has(FindingKind::MemOverlap), "{r}");
    }

    #[test]
    fn access_past_dm_is_flagged_without_regions() {
        let a = DM_BYTES as i32 - 8;
        let r = run(&format!("li r1, {a}\nldv v0, [r1]\nhalt"), &AbiEnv::default(), &MemSpec::open());
        assert!(r.has(FindingKind::MemBounds), "{r}");
    }

    #[test]
    fn compute_read_into_dma_destination_races() {
        let r = run(
            "li r1, 0\n\
             li r2, 4096\n\
             li r3, 512\n\
             dmald 0, r1, r2, r3\n\
             ldv v0, [r2+64]\n\
             dmawait 0\n\
             halt",
            &AbiEnv::default(),
            &MemSpec::open(),
        );
        assert!(r.has(FindingKind::DmaRace), "{r}");
    }

    #[test]
    fn access_after_dmawait_is_fine() {
        let r = run(
            "li r1, 0\n\
             li r2, 4096\n\
             li r3, 512\n\
             dmald 0, r1, r2, r3\n\
             dmawait 0\n\
             ldv v0, [r2+64]\n\
             halt",
            &AbiEnv::default(),
            &MemSpec::open(),
        );
        assert!(r.is_clean(), "{r}");
    }

    #[test]
    fn disjoint_access_during_dma_is_fine() {
        let r = run(
            "li r1, 0\n\
             li r2, 4096\n\
             li r3, 512\n\
             li r4, 16384\n\
             dmald 0, r1, r2, r3\n\
             ldv v0, [r4]\n\
             dmawait 0\n\
             halt",
            &AbiEnv::default(),
            &MemSpec::open(),
        );
        assert!(r.is_clean(), "{r}");
    }

    #[test]
    fn write_into_dma_store_source_races() {
        let r = run(
            "li r1, 0\n\
             li r2, 4096\n\
             li r3, 512\n\
             dmast 0, r1, r2, r3\n\
             sts r3, [r2]\n\
             dmawait 0\n\
             halt",
            &AbiEnv::default(),
            &MemSpec::open(),
        );
        assert!(r.has(FindingKind::DmaRace), "{r}");
    }

    #[test]
    fn read_of_dma_store_source_is_fine() {
        let r = run(
            "li r1, 0\n\
             li r2, 4096\n\
             li r3, 512\n\
             dmast 0, r1, r2, r3\n\
             ldv v0, [r2]\n\
             dmawait 0\n\
             halt",
            &AbiEnv::default(),
            &MemSpec::open(),
        );
        assert!(r.is_clean(), "{r}");
    }

    #[test]
    fn lb_fill_rows_are_recorded_per_row() {
        let p = assemble(
            "li r1, 256\n\
             lbld 0, r1, 16\n\
             halt",
        )
        .unwrap();
        let tr = trace(&p, &AbiEnv::default()).unwrap();
        let fills: Vec<_> =
            tr.accesses.iter().filter(|a| a.kind == AccessKind::LbFill).collect();
        assert_eq!(fills.len(), 1);
        assert_eq!(fills[0].addr, 256);
        assert_eq!(fills[0].len, 32);
        assert_eq!(fills[0].port, Port::P1);
    }

    #[test]
    fn unknown_address_is_skipped_not_flagged() {
        let r = assemble(
            "li r1, 0\n\
             lds r2, [r1]\n\
             ldv v0, [r2]\n\
             halt",
        )
        .unwrap();
        let tr = trace(&r, &AbiEnv::default()).unwrap();
        assert_eq!(tr.unknown, 1);
        let rep = check(&r, &AbiEnv::default(), &MemSpec::open()).unwrap();
        assert!(rep.is_clean());
    }

    #[test]
    fn unknown_branch_is_unsupported() {
        let p = assemble(
            "lds r1, [r2]\n\
             li r3, 0\n\
             bne r1, r3, 0\n\
             halt",
        )
        .unwrap();
        let err = check(&p, &AbiEnv::new(&[(2, 0)]), &MemSpec::open()).unwrap_err();
        assert!(matches!(err, MemError::Unsupported { .. }), "{err}");
    }
}
