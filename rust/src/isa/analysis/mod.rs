//! Static analysis over decoded VLIW [`Program`]s.
//!
//! Five passes, run on every program the plan cache compiles (in debug
//! builds and under `cargo test` always; opt-in for release via
//! `ANALYZE=1` or the CLI's `--verify-programs`) and on demand through
//! the `lint` CLI subcommand:
//!
//! 1. [`structural`] — CFG/loop well-formedness: branch and jump targets
//!    in range, hardware-loop bodies in bounds with at most one level of
//!    nesting, no branch in or out of a loop body, a reachable `Halt` on
//!    every path, encoded image within the 16 KB PM.
//! 2. [`dataflow`] — forward must-defined analysis over scalar regs,
//!    vector regs, accumulators and CSRs: every read is preceded by a
//!    definition on *all* paths (the task ABI seeds the entry state).
//! 3. [`resource`] — protocol lints: filter-FIFO balance (no
//!    pop-when-empty, no push-when-full, empty at halt, equal depth at
//!    joins), DMA channel protocol (no restart without `DmaWait`, no
//!    port-0 access overlapping an in-flight transfer), SFU placement,
//!    register sub-region/port rules, and `LbLoad` extents vs LB reads.
//! 4. [`predict`] — the static cycle analyzer: an exact symbolic replay
//!    of the scoreboard/memory timing model (shared with the simulator
//!    via [`timing`], bank-conflict rules included via [`banks`])
//!    yielding per-program cycle counts without simulation.
//! 5. [`memory`] — the symbolic memory-access verifier: enumerates every
//!    DM/LB/ext access (address, length, port, read/write, bank set)
//!    under a concrete ABI environment and checks region **bounds**,
//!    `DmMap` **aliasing** (pairwise-disjoint regions inside DM) and
//!    byte-range **DMA–compute hazards** per channel.
//!
//! Passes 1–3 and 5 are *verification* ([`verify`] → [`Report`], pass 5
//! via [`memory::check`] since it needs the plan's region map); pass 4
//! is *measurement* and assumes a clean report.

// clippy::pedantic is BLOCKING for this module tree (see ci.yml): the
// verifier polices everyone else's programs, so it holds itself to the
// strictest lint tier. These inner allows cover the children too
// (predict.rs, memory.rs, banks.rs, timing.rs, ...) and are the
// recorded debt; tools/check-deprecated.sh rejects any allow here that
// lacks its `// lint-debt:` marker.
#![allow(clippy::cast_possible_truncation)] // lint-debt: u64/usize/i32 cycle+address casts pervade the walkers; each site is bounded by DM/PM sizes
#![allow(clippy::cast_possible_wrap)] // lint-debt: DM addresses round-trip through i32 ABI registers by ISA design (< 2^17, never wraps)
#![allow(clippy::cast_sign_loss)] // lint-debt: the same ABI round-trip back to usize; negative values are rejected before the cast
#![allow(clippy::missing_errors_doc)] // lint-debt: error enums are self-describing; per-fn `# Errors` sections owed
#![allow(clippy::missing_panics_doc)] // lint-debt: panics are internal-invariant asserts, not caller contracts
#![allow(clippy::must_use_candidate)] // lint-debt: annotate the pure accessors module-wide in one dedicated sweep
#![allow(clippy::module_name_repetitions)] // lint-debt: MemSpec/MemError et al. read better fully qualified at call sites
#![allow(clippy::doc_markdown)] // lint-debt: prose names ISA items (DmaWait, LbLoad) bare in places; backtick sweep owed
#![allow(clippy::too_many_lines)] // lint-debt: the slot-0 walkers are long matches mirroring the interpreter; splitting hurts diffability
#![allow(clippy::match_same_arms)] // lint-debt: semantically distinct ISA cases kept as separate arms even when bodies coincide
#![allow(clippy::similar_names)] // lint-debt: operand idiom (ra/rb, va/vb) mirrors the ISA mnemonics

pub mod banks;
pub mod memory;
pub mod predict;
pub mod timing;

mod dataflow;
mod resource;
mod structural;

use std::fmt;

use crate::isa::{disasm, Program, SlotOp};

/// What a finding is about — the stable, testable classification.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum FindingKind {
    // structural
    BranchTargetOutOfRange,
    LoopBodyOutOfRange,
    LoopNesting,
    BranchCrossesLoop,
    NoHaltPath,
    RunsOffEnd,
    PmOverflow,
    // dataflow
    UseBeforeDef,
    // resource / protocol
    FifoUnderflow,
    FifoOverflow,
    FifoImbalance,
    FifoResidual,
    DmaRestart,
    DmaOverlap,
    SfuSlot,
    LbExtent,
    RegionViolation,
    // memory (pass 5)
    MemBounds,
    MemOverlap,
    DmaRace,
}

impl FindingKind {
    /// The analysis pass that emits this kind — the stable `pass` label
    /// of machine-readable (`lint --json`) output.
    #[must_use]
    pub fn pass(self) -> &'static str {
        use FindingKind as K;
        match self {
            K::BranchTargetOutOfRange
            | K::LoopBodyOutOfRange
            | K::LoopNesting
            | K::BranchCrossesLoop
            | K::NoHaltPath
            | K::RunsOffEnd
            | K::PmOverflow => "structural",
            K::UseBeforeDef => "dataflow",
            K::FifoUnderflow
            | K::FifoOverflow
            | K::FifoImbalance
            | K::FifoResidual
            | K::DmaRestart
            | K::DmaOverlap
            | K::SfuSlot
            | K::LbExtent
            | K::RegionViolation => "resource",
            K::MemBounds | K::MemOverlap | K::DmaRace => "memory",
        }
    }
}

impl fmt::Display for FindingKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            FindingKind::BranchTargetOutOfRange => "branch-target-out-of-range",
            FindingKind::LoopBodyOutOfRange => "loop-body-out-of-range",
            FindingKind::LoopNesting => "loop-nesting",
            FindingKind::BranchCrossesLoop => "branch-crosses-loop",
            FindingKind::NoHaltPath => "no-halt-path",
            FindingKind::RunsOffEnd => "runs-off-end",
            FindingKind::PmOverflow => "pm-overflow",
            FindingKind::UseBeforeDef => "use-before-def",
            FindingKind::FifoUnderflow => "fifo-underflow",
            FindingKind::FifoOverflow => "fifo-overflow",
            FindingKind::FifoImbalance => "fifo-imbalance",
            FindingKind::FifoResidual => "fifo-residual",
            FindingKind::DmaRestart => "dma-restart",
            FindingKind::DmaOverlap => "dma-overlap",
            FindingKind::SfuSlot => "sfu-slot",
            FindingKind::LbExtent => "lb-extent",
            FindingKind::RegionViolation => "region-violation",
            FindingKind::MemBounds => "mem-bounds",
            FindingKind::MemOverlap => "mem-overlap",
            FindingKind::DmaRace => "dma-race",
        };
        f.write_str(s)
    }
}

/// One verifier finding, anchored at a bundle with its disassembly.
#[derive(Debug, Clone)]
pub struct Finding {
    pub kind: FindingKind,
    pub pc: usize,
    pub detail: String,
    pub disasm: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] bundle {}: {}\n    {:5}: {}", self.kind, self.pc, self.detail, self.pc, self.disasm)
    }
}

/// The verifier's verdict on one program.
#[derive(Debug, Clone, Default)]
pub struct Report {
    pub findings: Vec<Finding>,
}

impl Report {
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    pub fn has(&self, kind: FindingKind) -> bool {
        self.findings.iter().any(|f| f.kind == kind)
    }
}

impl fmt::Display for Report {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_clean() {
            return f.write_str("clean");
        }
        for (i, fd) in self.findings.iter().enumerate() {
            if i > 0 {
                writeln!(f)?;
            }
            write!(f, "{fd}")?;
        }
        Ok(())
    }
}

/// The host/task calling convention a program is verified against:
/// which scalar registers the executor initializes before `Cpu::run`.
/// `RoundMode` and `GateBits` CSRs are host-owned (the executor writes
/// gate bits; rounding has a reset default the numerics tests pin), so
/// they count as pre-defined; `FracShift` and `LbStride` must be written
/// by the program before any dependent op.
#[derive(Debug, Clone)]
pub struct AbiSpec {
    pub name: &'static str,
    pub defined_sregs: Vec<u8>,
}

impl AbiSpec {
    /// No host-initialized registers (hand-written / test programs).
    pub fn bare() -> Self {
        Self { name: "bare", defined_sregs: vec![] }
    }

    /// The conv/FC task ABI (`executor::run_dense`): r2 = input row
    /// base, r4 = output base, r5 = psum base, r6 = filter base.
    pub fn conv() -> Self {
        Self { name: "conv", defined_sregs: vec![2, 4, 5, 6] }
    }

    /// The pool task ABI (`executor::run_pool`): r2 = input row base,
    /// r4 = output base.
    pub fn pool() -> Self {
        Self { name: "pool", defined_sregs: vec![2, 4] }
    }
}

/// Run passes 1–3 and collect every finding, sorted by bundle index.
pub fn verify(prog: &Program, abi: &AbiSpec) -> Report {
    let mut out = Vec::new();
    let cfg = Cfg::build(prog);
    structural::check(prog, &cfg, &mut out);
    dataflow::check(prog, &cfg, abi, &mut out);
    resource::check(prog, &cfg, &mut out);
    out.sort_by(|a, b| (a.pc, a.kind).cmp(&(b.pc, b.kind)));
    Report { findings: out }
}

/// Whether the plan cache verifies programs on insert: always in debug
/// builds (hence under `cargo test`), opt-in via `ANALYZE=1` (which the
/// CLI's `--verify-programs` flag sets) in release.
pub fn enabled() -> bool {
    cfg!(debug_assertions) || std::env::var_os("ANALYZE").is_some_and(|v| v != "0")
}

pub(crate) fn finding(prog: &Program, kind: FindingKind, pc: usize, detail: String) -> Finding {
    let disasm = prog.bundles.get(pc).map(disasm::bundle).unwrap_or_default();
    Finding { kind, pc, detail, disasm }
}

/// Control-flow graph shared by the verifier passes.
///
/// Successors may include `len` (= "runs off the end"); callers filter.
/// Hardware-loop back edges are modeled as an edge from a region's last
/// bundle to its first. When several regions share a `last` bundle only
/// the innermost back edge is real hardware behavior (`loop_next` checks
/// the top frame only); the CFG keeps all of them, which is conservative
/// for reachability and only ever *weakens* the must-analyses.
pub(crate) struct Cfg {
    /// Successor bundle indices per pc.
    pub succs: Vec<Vec<usize>>,
    /// Hardware-loop body regions: (loop-instruction pc, first, last).
    pub regions: Vec<(usize, usize, usize)>,
}

impl Cfg {
    pub fn build(prog: &Program) -> Cfg {
        let mut regions = Vec::new();
        for (pc, b) in prog.bundles.iter().enumerate() {
            if let SlotOp::Loop { body, .. } | SlotOp::LoopI { body, .. } = b.slot0 {
                if body > 0 {
                    regions.push((pc, pc + 1, pc + body as usize));
                }
            }
        }
        let fall = |pc: usize| -> Vec<usize> {
            let mut v: Vec<usize> = regions
                .iter()
                .filter(|&&(_, _, last)| last == pc)
                .map(|&(_, start, _)| start)
                .collect();
            v.push(pc + 1);
            v
        };
        let succs = prog
            .bundles
            .iter()
            .enumerate()
            .map(|(pc, b)| match b.slot0 {
                SlotOp::Halt => vec![],
                SlotOp::Jmp { target } => vec![target as usize],
                SlotOp::Br { target, .. } => {
                    let mut v = fall(pc);
                    v.push(target as usize);
                    v
                }
                // a loop instruction never takes its own enclosing
                // back edge (push_loop returns Seq with the *new* frame
                // innermost), so plain successors suffice
                SlotOp::Loop { body, .. } if body > 0 => vec![pc + 1, pc + 1 + body as usize],
                SlotOp::LoopI { n, body } if body > 0 => {
                    if n == 0 {
                        vec![pc + 1 + body as usize]
                    } else {
                        vec![pc + 1]
                    }
                }
                _ => fall(pc),
            })
            .collect();
        Cfg { succs, regions }
    }
}
