//! Pass 4 — the static cycle analyzer.
//!
//! Walks a decoded `Program` symbolically and produces the *exact*
//! cycle/stall counts the interpreter would report, without touching
//! tensor data. The walk reuses the interpreter's own building blocks so
//! the two cannot drift:
//!
//! * issue stalls and write latencies come from [`super::timing`] — the
//!   same functions `core::cpu` calls per dynamic bundle;
//! * line-buffer fill pacing, DM bank conflicts and end-of-task drain
//!   come from a real (zero-data) [`MemInterface`] driven at the real
//!   addresses, because fill duration depends on which banks port 0
//!   touches each cycle.
//!
//! Data values never matter for timing (pinned by the simulator test
//! `analytic_samples_are_data_independent`); *addresses* do, so the
//! walker keeps a constant lattice over the scalar register file seeded
//! from the task [`AbiEnv`]. Anything address- or control-relevant that
//! is not statically known (a branch on a loaded value, a DMA transfer,
//! an unknown `LbStride`) aborts with [`PredictError::Unsupported`] —
//! the caller's documented exclusion list. All codegen-emitted task
//! programs are fully supported.
//!
//! The walk assumes a program that passes [`super::verify`]; on broken
//! programs it may report a fault or panic just like the simulator.

use std::collections::VecDeque;
use std::fmt;

use crate::isa::{ASrc, BSrc, Bundle, Csr, Program, SReg, SlotOp, VecOp};
use crate::mem::linebuf::LB_ROWS;
use crate::mem::MemInterface;

use super::timing::{self, Scoreboard, BRANCH_BUBBLES, FIFO_DEPTH};

/// Scalar registers the host writes before `Cpu::run` — the task-ABI
/// environment the prediction is made for. Unlisted registers are
/// treated as *unknown*, so a program depending on them for addresses or
/// control flow is rejected as `Unsupported` rather than silently
/// assuming the reset value.
#[derive(Debug, Clone, Default)]
pub struct AbiEnv {
    pub regs: Vec<(SReg, i32)>,
}

impl AbiEnv {
    pub fn new(regs: &[(u8, i32)]) -> Self {
        Self { regs: regs.iter().map(|&(r, v)| (SReg(r), v)).collect() }
    }
}

/// The analyzer's cycle prediction — the timing-relevant subset of
/// `CoreStats`, asserted equal field-for-field against simulation.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StaticTiming {
    pub cycles: u64,
    pub bundles: u64,
    pub hazard_stalls: u64,
    pub lb_stalls: u64,
    pub branch_stalls: u64,
    pub dma_wait_stalls: u64,
    pub wide_ls_stalls: u64,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PredictError {
    /// The program uses a construct the symbolic walk cannot bound.
    Unsupported { pc: usize, what: String },
    /// The walk hit a machine fault (the verifier should have caught
    /// it first; kept as an error so `lint` can report it).
    Fault { pc: usize, what: String },
    Watchdog(u64),
    RanOff { pc: usize },
}

impl fmt::Display for PredictError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PredictError::Unsupported { pc, what } => {
                write!(f, "bundle {pc}: unsupported for static prediction: {what}")
            }
            PredictError::Fault { pc, what } => write!(f, "bundle {pc}: fault: {what}"),
            PredictError::Watchdog(n) => write!(f, "watchdog: exceeded {n} cycles"),
            PredictError::RanOff { pc } => write!(f, "ran past the last bundle (pc={pc})"),
        }
    }
}

impl std::error::Error for PredictError {}

/// Predict the exact per-run timing of `prog` under the given ABI
/// environment (fresh CSRs, empty FIFO/loop stack — the state
/// `Cpu::reset_for_run` establishes).
pub fn predict(prog: &Program, env: &AbiEnv) -> Result<StaticTiming, PredictError> {
    let mut w = Walker::new(env);
    while !w.halted {
        if w.t.cycles > w.max_cycles {
            return Err(PredictError::Watchdog(w.max_cycles));
        }
        if w.pc >= prog.bundles.len() {
            return Err(PredictError::RanOff { pc: w.pc });
        }
        w.step(prog)?;
    }
    w.t.cycles += w.mem.drain();
    Ok(w.t)
}

struct LoopFrame {
    start: usize,
    last: usize,
    remaining: u32,
}

enum PcUpdate {
    Seq,
    Jump(usize),
    Halt,
}

/// The symbolic machine: real memory-system timing state, constant
/// lattice for scalars, no vector/accumulator data at all.
struct Walker {
    regs: [Option<i32>; 32],
    lb_stride: u8,
    mem: MemInterface,
    sb: Scoreboard,
    /// Filter FIFO: ready cycles only (contents are irrelevant).
    fifo: VecDeque<u64>,
    loops: Vec<LoopFrame>,
    pc: usize,
    halted: bool,
    t: StaticTiming,
    max_cycles: u64,
}

impl Walker {
    fn new(env: &AbiEnv) -> Self {
        let mut regs = [None; 32];
        for &(r, v) in &env.regs {
            if (r.0 as usize) < 32 {
                regs[r.0 as usize] = Some(v);
            }
        }
        Self {
            regs,
            lb_stride: 1, // CsrFile::default()
            mem: MemInterface::new(0),
            sb: Scoreboard::new(),
            fifo: VecDeque::with_capacity(FIFO_DEPTH),
            loops: Vec::with_capacity(4),
            pc: 0,
            halted: false,
            t: StaticTiming::default(),
            max_cycles: 10_000_000_000,
        }
    }

    fn unsupported(&self, what: impl Into<String>) -> PredictError {
        PredictError::Unsupported { pc: self.pc, what: what.into() }
    }

    fn fault(&self, what: impl Into<String>) -> PredictError {
        PredictError::Fault { pc: self.pc, what: what.into() }
    }

    fn known(&self, r: SReg, why: &str) -> Result<i32, PredictError> {
        self.regs[r.0 as usize]
            .ok_or_else(|| self.unsupported(format!("{why} depends on unknown r{}", r.0)))
    }

    /// Mirror of `Cpu::advance_cycle`.
    fn advance_cycle(&mut self) {
        self.t.cycles += 1;
        if self.mem.background_idle() {
            self.mem.dm.end_cycle();
        } else {
            self.mem.tick();
        }
    }

    /// Mirror of `Cpu::step`, with data replaced by the constant lattice.
    fn step(&mut self, prog: &Program) -> Result<(), PredictError> {
        let bundle = &prog.bundles[self.pc];

        let ready = timing::issue_ready(bundle, &self.sb, self.fifo.front().copied(), self.t.cycles)
            .map_err(|timing::FifoEmpty| self.fault("vector MAC with empty filter FIFO"))?;
        let stall = ready.saturating_sub(self.t.cycles);
        for _ in 0..stall {
            self.t.hazard_stalls += 1;
            self.advance_cycle();
        }

        self.wait_lb_operands(bundle)?;
        let issue_now = self.t.cycles;

        // vector slots: only the FIFO pop is timing-relevant (scoreboard
        // writes are applied by retire_bundle below)
        let fifo_used = bundle.v.iter().any(|op| {
            matches!(
                op,
                VecOp::Mac { b: BSrc::Fifo | BSrc::FifoLaneQuad { .. }, .. }
                    | VecOp::Mul { b: BSrc::Fifo | BSrc::FifoLaneQuad { .. }, .. }
            )
        });
        if fifo_used {
            self.fifo.pop_front();
        }

        let next_pc = self.exec_slot0(&bundle.slot0)?;
        timing::retire_bundle(bundle, issue_now, &mut self.sb);

        self.t.bundles += 1;
        self.advance_cycle();

        match next_pc {
            PcUpdate::Seq => self.pc = self.loop_next(self.pc),
            PcUpdate::Jump(t) => {
                self.pc = t;
                for _ in 0..BRANCH_BUBBLES {
                    self.t.branch_stalls += 1;
                    self.advance_cycle();
                }
            }
            PcUpdate::Halt => self.halted = true,
        }
        Ok(())
    }

    fn loop_next(&mut self, pc: usize) -> usize {
        if let Some(frame) = self.loops.last_mut() {
            if pc == frame.last {
                if frame.remaining > 0 {
                    frame.remaining -= 1;
                    return frame.start;
                }
                self.loops.pop();
            }
        }
        pc + 1
    }

    /// Mirror of `Cpu::wait_lb_operands` (the LB fill-progress interlock).
    fn wait_lb_operands(&mut self, b: &Bundle) -> Result<(), PredictError> {
        loop {
            let mut blocked = false;
            for op in b.v.iter() {
                let lb_ref = match *op {
                    VecOp::Mac { a: ASrc::Lb { row, off }, .. }
                    | VecOp::Mul { a: ASrc::Lb { row, off }, .. } => {
                        Some((row, off as usize + 3 * self.lb_stride as usize))
                    }
                    VecOp::Mac { a: ASrc::LbVec { row, off }, .. }
                    | VecOp::Mul { a: ASrc::LbVec { row, off }, .. } => {
                        Some((row, off as usize + 15 * self.lb_stride as usize))
                    }
                    _ => None,
                };
                if let Some((row, max_idx)) = lb_ref {
                    let row = row as usize;
                    if row >= LB_ROWS {
                        return Err(self.fault(format!("LB row {row} out of range")));
                    }
                    if !self.mem.lb.can_read(row, max_idx) {
                        if self.mem.lb.filling() && self.mem.lb.fill_row() == Some(row) {
                            blocked = true;
                        } else {
                            return Err(self.fault(format!(
                                "LB read row {row} px<= {max_idx} but row not filled"
                            )));
                        }
                    }
                }
            }
            if !blocked {
                return Ok(());
            }
            self.t.lb_stalls += 1;
            self.mem.lb.note_read_stall();
            self.advance_cycle();
        }
    }

    /// `addr_of` over the constant lattice (applies post-increment).
    fn addr_of(&mut self, a: &crate::isa::Addr) -> Result<usize, PredictError> {
        let base = self.known(a.base, "memory address")?;
        let addr = base.wrapping_add(a.offset);
        if a.post_inc != 0 {
            self.regs[a.base.0 as usize] = Some(base.wrapping_add(a.post_inc));
        }
        Ok(addr as usize)
    }

    fn exec_slot0(&mut self, op: &SlotOp) -> Result<PcUpdate, PredictError> {
        let now = self.t.cycles;
        Ok(match *op {
            SlotOp::Nop => PcUpdate::Seq,
            SlotOp::Halt => PcUpdate::Halt,
            SlotOp::Li { rd, imm } => {
                self.regs[rd.0 as usize] = Some(imm);
                PcUpdate::Seq
            }
            SlotOp::Alu { f, w, rd, ra, rb } => {
                let v = match (self.regs[ra.0 as usize], self.regs[rb.0 as usize]) {
                    (Some(a), Some(b)) => Some(crate::core::cpu::alu(f, w, a, b)),
                    _ => None,
                };
                self.regs[rd.0 as usize] = v;
                PcUpdate::Seq
            }
            SlotOp::AluI { f, w, rd, ra, imm } => {
                self.regs[rd.0 as usize] =
                    self.regs[ra.0 as usize].map(|a| crate::core::cpu::alu(f, w, a, imm));
                PcUpdate::Seq
            }
            SlotOp::Br { c, ra, rb, target } => {
                let a = self.known(ra, "branch")?;
                let b = self.known(rb, "branch")?;
                let taken = match c {
                    crate::isa::Cond::Eq => a == b,
                    crate::isa::Cond::Ne => a != b,
                    crate::isa::Cond::Lt => a < b,
                    crate::isa::Cond::Ge => a >= b,
                };
                if taken {
                    PcUpdate::Jump(target as usize)
                } else {
                    PcUpdate::Seq
                }
            }
            SlotOp::Jmp { target } => PcUpdate::Jump(target as usize),
            SlotOp::Loop { n, body } => {
                let count = self.known(n, "loop count")?.max(0) as u32;
                self.push_loop(count, body)?
            }
            SlotOp::LoopI { n, body } => self.push_loop(n, body)?,
            SlotOp::Csrwi { csr, imm } => {
                if csr == Csr::LbStride {
                    self.lb_stride = (imm.max(1) & 0xF) as u8;
                }
                // FracShift / RoundMode / GateBits never affect timing
                PcUpdate::Seq
            }
            SlotOp::Csrw { csr, rs } => {
                if csr == Csr::LbStride {
                    let v = self.known(rs, "LbStride CSR write")? as u32;
                    self.lb_stride = (v.max(1) & 0xF) as u8;
                }
                PcUpdate::Seq
            }
            SlotOp::LdS { rd, addr } => {
                let a = self.addr_of(&addr)?;
                self.mem.dm.read_i16_p0(a).map_err(|e| self.fault(e.to_string()))?;
                // a loaded value is data, not a static constant
                self.regs[rd.0 as usize] = None;
                PcUpdate::Seq
            }
            SlotOp::StS { rs: _, addr } => {
                let a = self.addr_of(&addr)?;
                self.mem.dm.write_i16_p0(a, 0).map_err(|e| self.fault(e.to_string()))?;
                PcUpdate::Seq
            }
            SlotOp::LdV { vd: _, addr } => {
                let a = self.addr_of(&addr)?;
                self.mem.dm.read_vec_p0(a).map_err(|e| self.fault(e.to_string()))?;
                PcUpdate::Seq
            }
            SlotOp::StV { vs: _, addr } => {
                let a = self.addr_of(&addr)?;
                self.mem.dm.write_vec_p0(a, &[0; 16]).map_err(|e| self.fault(e.to_string()))?;
                PcUpdate::Seq
            }
            SlotOp::LdVF { addr } => {
                if self.fifo.len() >= FIFO_DEPTH {
                    return Err(self.fault("filter FIFO overflow"));
                }
                let a = self.addr_of(&addr)?;
                self.mem.dm.read_vec_p0(a).map_err(|e| self.fault(e.to_string()))?;
                self.fifo.push_back(timing::fifo_entry_ready(now));
                PcUpdate::Seq
            }
            SlotOp::LdA { ad: _, addr } => {
                let a = self.addr_of(&addr)?;
                self.mem.dm.read_vec_p0(a).map_err(|e| self.fault(e.to_string()))?;
                self.advance_cycle();
                self.t.wide_ls_stalls += 1;
                self.mem.dm.read_vec_p0(a + 32).map_err(|e| self.fault(e.to_string()))?;
                PcUpdate::Seq
            }
            SlotOp::StA { as_: _, addr } => {
                let a = self.addr_of(&addr)?;
                self.mem.dm.write_vec_p0(a, &[0; 16]).map_err(|e| self.fault(e.to_string()))?;
                self.advance_cycle();
                self.t.wide_ls_stalls += 1;
                self.mem
                    .dm
                    .write_vec_p0(a + 32, &[0; 16])
                    .map_err(|e| self.fault(e.to_string()))?;
                PcUpdate::Seq
            }
            SlotOp::DmaLoad { .. } | SlotOp::DmaStore { .. } => {
                // DMA pacing depends on external-memory latency credits and
                // per-cycle port-1 arbitration against future LB fills;
                // modeling it symbolically is future work. No generated
                // task program issues DMA (the host stages DM directly).
                return Err(self.unsupported("DMA transfer"));
            }
            SlotOp::DmaWait { ch } => {
                while self.mem.dma.busy(ch as usize) {
                    self.t.dma_wait_stalls += 1;
                    self.advance_cycle();
                }
                PcUpdate::Seq
            }
            SlotOp::LbLoad { row, dm, off, win, nrows, rstride } => {
                while self.mem.lb.filling() {
                    self.t.lb_stalls += 1;
                    self.advance_cycle();
                }
                let a = self.known(dm, "LB fill address")? as usize + off as usize;
                self.mem
                    .start_lb_fill_2d(row as usize, a, win as usize, nrows as usize, rstride as usize)
                    .map_err(|e| self.fault(e.to_string()))?;
                PcUpdate::Seq
            }
        })
    }

    fn push_loop(&mut self, n: u32, body: u16) -> Result<PcUpdate, PredictError> {
        if body == 0 {
            return Err(self.fault("loop with empty body"));
        }
        if self.loops.len() >= 2 {
            return Err(self.fault("hardware loop nesting > 2"));
        }
        if n == 0 {
            return Ok(PcUpdate::Jump(self.pc + 1 + body as usize));
        }
        self.loops.push(LoopFrame {
            start: self.pc + 1,
            last: self.pc + body as usize,
            remaining: n - 1,
        });
        Ok(PcUpdate::Seq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::cpu::Cpu;
    use crate::isa::asm::assemble;
    use crate::mem::pm::ProgramMem;

    /// Run both the interpreter and the analyzer on the same program and
    /// assert all timing fields agree.
    fn assert_agrees(src: &str, env: &AbiEnv) -> StaticTiming {
        let p = assemble(src).unwrap();
        let pm = ProgramMem::load(&p).unwrap();
        let st = predict(pm.program(), env).unwrap();
        let mut cpu = Cpu::new(1 << 16);
        for &(r, v) in &env.regs {
            cpu.regs.set_r(r, v);
        }
        let stats = cpu.run(&pm).unwrap();
        assert_eq!(st.cycles, stats.cycles, "cycles");
        assert_eq!(st.bundles, stats.bundles, "bundles");
        assert_eq!(st.hazard_stalls, stats.hazard_stalls, "hazard_stalls");
        assert_eq!(st.lb_stalls, stats.lb_stalls, "lb_stalls");
        assert_eq!(st.branch_stalls, stats.branch_stalls, "branch_stalls");
        assert_eq!(st.dma_wait_stalls, stats.dma_wait_stalls, "dma_wait_stalls");
        assert_eq!(st.wide_ls_stalls, stats.wide_ls_stalls, "wide_ls_stalls");
        st
    }

    #[test]
    fn straight_line_and_hardware_loop_agree() {
        let st = assert_agrees(
            "li r1, 0\n\
             li r3, 1\n\
             loopi 10, 1\n\
             add r1, r1, r3\n\
             halt",
            &AbiEnv::default(),
        );
        assert_eq!(st.cycles, 14);
        assert_eq!(st.bundles, 14);
    }

    #[test]
    fn branch_loop_agrees() {
        let st = assert_agrees(
            "li r1, 0\n\
             li r2, 10\n\
             li r3, 1\n\
             loop: add r1, r1, r3\n\
             bne r1, r2, loop\n\
             halt",
            &AbiEnv::default(),
        );
        assert_eq!(st.branch_stalls, 18);
    }

    #[test]
    fn lb_fill_interlock_and_mac_agree() {
        assert_agrees(
            "li r1, 0\n\
             ldv v0, [r1]\n\
             csrwi lb_stride, 1\n\
             lbld 0, r1, 16\n\
             nop | vmac lb:0, v0 | vnop | vnop\n\
             halt",
            &AbiEnv::default(),
        );
    }

    #[test]
    fn load_use_and_wide_ls_agree() {
        let st = assert_agrees(
            "li r1, 256\n\
             li r2, 512\n\
             ldv v4, [r1] | vnop | vnop | vnop\n\
             stv v4, [r2]\n\
             lda a0, [r1]\n\
             sta a0, [r2]\n\
             halt",
            &AbiEnv::default(),
        );
        assert_eq!(st.wide_ls_stalls, 2);
        assert!(st.hazard_stalls >= 1);
    }

    #[test]
    fn abi_register_addresses_work() {
        // address base comes from the environment, not the program
        assert_agrees(
            "ldv v0, [r2]\n\
             stv v0, [r4]\n\
             halt",
            &AbiEnv::new(&[(2, 64), (4, 1024)]),
        );
    }

    #[test]
    fn unknown_branch_operand_is_unsupported() {
        let p = assemble(
            "lds r1, [r2]\n\
             li r3, 0\n\
             bne r1, r3, 0\n\
             halt",
        )
        .unwrap();
        let err = predict(&p, &AbiEnv::new(&[(2, 0)])).unwrap_err();
        assert!(matches!(err, PredictError::Unsupported { .. }), "{err}");
    }

    #[test]
    fn dma_is_unsupported() {
        let p = assemble(
            "li r1, 0\n\
             li r2, 1024\n\
             li r3, 512\n\
             dmald 0, r1, r2, r3\n\
             dmawait 0\n\
             halt",
        )
        .unwrap();
        let err = predict(&p, &AbiEnv::default()).unwrap_err();
        assert!(matches!(err, PredictError::Unsupported { .. }), "{err}");
    }
}
