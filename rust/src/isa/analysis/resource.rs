//! Pass 3 — resource / protocol lints.
//!
//! Two kinds of checks live here:
//!
//! * **Stateless per-bundle rules** — SFU placement (`Relu`/`PoolMax`
//!   only in vALU slot 1), VR sub-region read/write permissions, lane
//!   and register index ranges. These mirror the simulator's `Access`
//!   errors one-for-one but are reported statically, per bundle.
//! * **A path-sensitive abstract interpretation** — a small forward
//!   fixpoint tracking filter-FIFO depth, DMA channel state (busy +
//!   known DM byte range), constant-propagated scalar registers, the
//!   last `LbLoad` extent per LB row and the `LbStride` CSR. It reports
//!   FIFO underflow/overflow/imbalance/residual, DMA restarts without
//!   `DmaWait`, known port-0 accesses overlapping an in-flight DMA's DM
//!   range, and LB reads past the filled extent.
//!
//! The abstract domain is deliberately modest: unknown values degrade
//! to ⊤ (`None`) and suppress the address-dependent checks rather than
//! false-positive. FIFO depth, by contrast, must be *equal* on every
//! path into a join — generated programs keep it balanced and a
//! mismatch is almost always a pop/push bug — so a disagreeing join is
//! itself a finding (`FifoImbalance`).

use std::collections::BTreeSet;

use crate::core::regfile::{can_read_vr, can_write_vr, Who};
use crate::isa::{ASrc, BSrc, Csr, Program, SlotOp, VReg, VecOp, LANES, SLICES};
use crate::mem::linebuf::{LB_ROWS, LB_ROW_PIXELS};

use super::timing::FIFO_DEPTH;
use super::{finding, Cfg, Finding, FindingKind};

const DMA_CHANNELS: usize = 2;

/// Abstract machine state at a program point.
#[derive(Debug, Clone, PartialEq, Eq)]
struct RState {
    /// Filter-FIFO occupancy (exact — a join mismatch is a finding).
    fifo: u8,
    /// Per DMA channel: a transfer is in flight.
    busy: [bool; DMA_CHANNELS],
    /// Per DMA channel: known DM byte range [start, end) of the
    /// in-flight transfer, if the registers were constant.
    range: [Option<(i64, i64)>; DMA_CHANNELS],
    /// Constant-propagated scalar registers (None = unknown).
    regs: [Option<i32>; 32],
    /// Pixels filled into each LB row by the latest `LbLoad` (0 = never
    /// filled on this path).
    lb_ext: [u16; LB_ROWS],
    /// `LbStride` CSR if statically known.
    stride: Option<u8>,
}

impl RState {
    fn entry() -> Self {
        RState {
            fifo: 0,
            busy: [false; DMA_CHANNELS],
            range: [None; DMA_CHANNELS],
            regs: [None; 32],
            lb_ext: [0; LB_ROWS],
            stride: Some(1), // CSR reset value
        }
    }

    /// Join for the must-analysis parts; returns true if the FIFO depth
    /// disagreed (reported by the caller as `FifoImbalance`).
    fn join(&mut self, o: &RState) -> bool {
        let imbalance = self.fifo != o.fifo;
        self.fifo = self.fifo.min(o.fifo);
        for c in 0..DMA_CHANNELS {
            self.busy[c] |= o.busy[c];
            if self.range[c] != o.range[c] {
                self.range[c] = None;
            }
        }
        for r in 0..32 {
            if self.regs[r] != o.regs[r] {
                self.regs[r] = None;
            }
        }
        for row in 0..LB_ROWS {
            self.lb_ext[row] = self.lb_ext[row].min(o.lb_ext[row]);
        }
        if self.stride != o.stride {
            self.stride = None;
        }
        imbalance
    }
}

/// Byte footprint of a slot-0 port-0 access (for DMA overlap checks).
fn access_bytes(op: &SlotOp) -> Option<u64> {
    match op {
        SlotOp::LdS { .. } | SlotOp::StS { .. } => Some(2),
        SlotOp::LdV { .. } | SlotOp::StV { .. } | SlotOp::LdVF { .. } => Some(32),
        SlotOp::LdA { .. } | SlotOp::StA { .. } => Some(64),
        _ => None,
    }
}

/// Transfer + checks for one bundle. The same function drives both the
/// fixpoint (no-op sink) and the reporting sweep, so state and findings
/// cannot disagree. Order mirrors the interpreter: vector slots first
/// (all three read the same FIFO front entry — one pop per bundle),
/// then slot 0.
fn step(st: &mut RState, prog: &Program, pc: usize, sink: &mut dyn FnMut(FindingKind, String)) {
    let b = &prog.bundles[pc];

    // ---- vector slots: FIFO pop + LB extent ----------------------------
    let mut pops = false;
    for op in &b.v {
        let (a, fifo_b) = match *op {
            VecOp::Mac { a, b } | VecOp::Mul { a, b } => {
                (Some(a), matches!(b, BSrc::Fifo | BSrc::FifoLaneQuad { .. }))
            }
            _ => (None, false),
        };
        pops |= fifo_b;
        // LB read extent vs the latest fill on this path
        if let Some(ASrc::Lb { row, off } | ASrc::LbVec { row, off }) = a {
            if row as usize >= LB_ROWS {
                sink(FindingKind::LbExtent, format!("LB read row {row} out of range"));
                continue;
            }
            let span = match a {
                Some(ASrc::Lb { .. }) => (SLICES - 1) as u16,
                _ => (LANES - 1) as u16,
            };
            if let Some(stride) = st.stride {
                let max_idx = off + span * stride as u16;
                let ext = st.lb_ext[row as usize];
                if max_idx >= ext {
                    sink(
                        FindingKind::LbExtent,
                        format!(
                            "LB read row {row} up to pixel {max_idx} but only {ext} filled on some path"
                        ),
                    );
                }
            }
        }
    }
    if pops {
        if st.fifo == 0 {
            sink(
                FindingKind::FifoUnderflow,
                "FIFO-sourced vector MAC with filter FIFO empty on some path".into(),
            );
        }
        st.fifo = st.fifo.saturating_sub(1);
    }

    // ---- slot 0 --------------------------------------------------------
    // known port-0 address (before post-increment) for DMA overlap checks
    if let Some(bytes) = access_bytes(&b.slot0) {
        let addr = match b.slot0 {
            SlotOp::LdS { addr, .. }
            | SlotOp::StS { addr, .. }
            | SlotOp::LdV { addr, .. }
            | SlotOp::StV { addr, .. }
            | SlotOp::LdVF { addr }
            | SlotOp::LdA { addr, .. }
            | SlotOp::StA { addr, .. } => Some(addr),
            _ => None,
        };
        if let Some(addr) = addr {
            if addr.base.0 < 32 {
                if let Some(base) = st.regs[addr.base.0 as usize] {
                    let lo = base as i64 + addr.offset as i64;
                    let hi = lo + bytes as i64;
                    for c in 0..DMA_CHANNELS {
                        if let (true, Some((dlo, dhi))) = (st.busy[c], st.range[c]) {
                            if lo < dhi && dlo < hi {
                                sink(
                                    FindingKind::DmaOverlap,
                                    format!(
                                        "port-0 access [{lo}, {hi}) overlaps in-flight DMA ch{c} DM range [{dlo}, {dhi})"
                                    ),
                                );
                            }
                        }
                    }
                }
                // post-increment updates the (known) base register
                if addr.post_inc != 0 {
                    st.regs[addr.base.0 as usize] =
                        st.regs[addr.base.0 as usize].map(|v| v.wrapping_add(addr.post_inc));
                }
            }
        }
    }

    let known = |st: &RState, r: u8| -> Option<i32> {
        if r < 32 {
            st.regs[r as usize]
        } else {
            None
        }
    };
    match b.slot0 {
        SlotOp::Li { rd, imm } => {
            if rd.0 < 32 {
                st.regs[rd.0 as usize] = Some(imm);
            }
        }
        SlotOp::Alu { f, w, rd, ra, rb } => {
            let v = match (known(st, ra.0), known(st, rb.0)) {
                (Some(a), Some(b)) => Some(crate::core::cpu::alu(f, w, a, b)),
                _ => None,
            };
            if rd.0 < 32 {
                st.regs[rd.0 as usize] = v;
            }
        }
        SlotOp::AluI { f, w, rd, ra, imm } => {
            let v = known(st, ra.0).map(|a| crate::core::cpu::alu(f, w, a, imm as i32));
            if rd.0 < 32 {
                st.regs[rd.0 as usize] = v;
            }
        }
        SlotOp::Csrwi { csr: Csr::LbStride, imm } => {
            st.stride = Some((imm.max(1) & 0xF) as u8);
        }
        SlotOp::Csrw { csr: Csr::LbStride, rs } => {
            st.stride = known(st, rs.0).map(|v| ((v as u32).max(1) & 0xF) as u8);
        }
        SlotOp::Csrwi { .. } | SlotOp::Csrw { .. } => {}
        SlotOp::LdS { rd, .. } => {
            // loaded value is data, not const-propagated
            if rd.0 < 32 {
                st.regs[rd.0 as usize] = None;
            }
        }
        SlotOp::LdVF { .. } => {
            if st.fifo as usize >= FIFO_DEPTH {
                sink(
                    FindingKind::FifoOverflow,
                    format!("LdVF with filter FIFO already at depth {FIFO_DEPTH} on some path"),
                );
            } else {
                st.fifo += 1;
            }
        }
        SlotOp::DmaLoad { ch, ext: _, dm, len } | SlotOp::DmaStore { ch, ext: _, dm, len } => {
            let c = ch as usize;
            if c >= DMA_CHANNELS {
                sink(FindingKind::RegionViolation, format!("DMA channel {ch} out of range"));
            } else {
                if st.busy[c] {
                    sink(
                        FindingKind::DmaRestart,
                        format!("DMA ch{c} restarted without DmaWait on some path"),
                    );
                }
                st.busy[c] = true;
                st.range[c] = match (known(st, dm.0), known(st, len.0)) {
                    // zero-length transfers complete immediately
                    (_, Some(0)) => {
                        st.busy[c] = false;
                        None
                    }
                    (Some(d), Some(l)) => Some((d as i64, d as i64 + l as i64)),
                    _ => None,
                };
            }
        }
        SlotOp::DmaWait { ch } => {
            let c = ch as usize;
            if c < DMA_CHANNELS {
                st.busy[c] = false;
                st.range[c] = None;
            }
        }
        SlotOp::LbLoad { row, win, nrows, .. } => {
            let len = win as u32 * nrows as u32;
            if row as usize >= LB_ROWS {
                sink(FindingKind::LbExtent, format!("LbLoad row {row} out of range"));
            } else if win == 0 || nrows == 0 || len as usize > LB_ROW_PIXELS {
                sink(
                    FindingKind::LbExtent,
                    format!("LbLoad fill of {len} px (win {win} x nrows {nrows}) is invalid (machine fault)"),
                );
            } else {
                st.lb_ext[row as usize] = len as u16;
            }
        }
        SlotOp::Halt => {
            if st.fifo != 0 {
                sink(
                    FindingKind::FifoResidual,
                    format!("halt with {} residual filter-FIFO entries", st.fifo),
                );
            }
        }
        _ => {}
    }
}

/// Stateless per-bundle legality: SFU placement and register
/// sub-region/index rules, mirroring the interpreter's `Access` errors.
fn scan_static(prog: &Program, out: &mut Vec<Finding>) {
    for (pc, b) in prog.bundles.iter().enumerate() {
        let mut bad = |kind: FindingKind, detail: String| {
            out.push(finding(prog, kind, pc, detail));
        };
        for (i, op) in b.v.iter().enumerate() {
            let s = i as u8 + 1;
            let who = Who::Valu(s);
            let rd_ok = |vr: VReg| vr.0 < 16 && can_read_vr(who, vr);
            let wr_ok = |vr: VReg| vr.0 < 16 && can_write_vr(who, vr);
            match *op {
                VecOp::Relu { .. } | VecOp::PoolMax { .. } if s != 1 => {
                    bad(FindingKind::SfuSlot, format!("SFU op in slot {s} (slot 1 only)"));
                }
                _ => {}
            }
            match *op {
                VecOp::Nop | VecOp::ClrA { .. } => {}
                VecOp::Mac { a, b } | VecOp::Mul { a, b } => {
                    match a {
                        ASrc::Lb { .. } | ASrc::LbVec { .. } => {} // row range in pass 3's LB check
                        ASrc::VrBcast { vr, base, step } => {
                            if !rd_ok(vr) {
                                bad(
                                    FindingKind::RegionViolation,
                                    format!("vALU{s} cannot read v{}", vr.0),
                                );
                            }
                            let max_lane = base as usize + (SLICES - 1) * step as usize;
                            if max_lane >= LANES {
                                bad(
                                    FindingKind::RegionViolation,
                                    format!("bcast lane {max_lane} out of range (machine fault)"),
                                );
                            }
                        }
                        ASrc::VrQuad { vr } => {
                            for k in 0..SLICES as u8 {
                                let e = VReg(vr.0.wrapping_add(k));
                                if !rd_ok(e) {
                                    bad(
                                        FindingKind::RegionViolation,
                                        format!("vALU{s} cannot read v{} (quad)", e.0),
                                    );
                                }
                            }
                        }
                    }
                    match b {
                        BSrc::Fifo => {}
                        // VrLane wraps its lane in hardware — no lane check
                        BSrc::Vr { vr } | BSrc::VrLane { vr, .. } => {
                            if !rd_ok(vr) {
                                bad(
                                    FindingKind::RegionViolation,
                                    format!("vALU{s} cannot read v{}", vr.0),
                                );
                            }
                        }
                        BSrc::VrLaneQuad { vr, base } => {
                            if !rd_ok(vr) {
                                bad(
                                    FindingKind::RegionViolation,
                                    format!("vALU{s} cannot read v{}", vr.0),
                                );
                            }
                            if base as usize + SLICES > LANES {
                                bad(
                                    FindingKind::RegionViolation,
                                    format!("lane-quad base {base} out of range (machine fault)"),
                                );
                            }
                        }
                        BSrc::FifoLaneQuad { base } => {
                            if base as usize + SLICES > LANES {
                                bad(
                                    FindingKind::RegionViolation,
                                    format!("fifo lane base {base} out of range (machine fault)"),
                                );
                            }
                        }
                        BSrc::VrQuad { vr } => {
                            for k in 0..SLICES as u8 {
                                let e = VReg(vr.0.wrapping_add(k));
                                if !rd_ok(e) {
                                    bad(
                                        FindingKind::RegionViolation,
                                        format!("vALU{s} cannot read v{} (quad)", e.0),
                                    );
                                }
                            }
                        }
                    }
                }
                VecOp::InitA { vr } => {
                    if !rd_ok(vr) {
                        bad(FindingKind::RegionViolation, format!("vALU{s} cannot read v{}", vr.0));
                    }
                }
                VecOp::InitALane { vr, base } => {
                    if !rd_ok(vr) {
                        bad(FindingKind::RegionViolation, format!("vALU{s} cannot read v{}", vr.0));
                    }
                    if base as usize + SLICES > LANES {
                        bad(
                            FindingKind::RegionViolation,
                            format!("init lane base {base} out of range (machine fault)"),
                        );
                    }
                }
                VecOp::QMov { vd, j, .. } => {
                    if !wr_ok(vd) {
                        bad(FindingKind::RegionViolation, format!("vALU{s} cannot write v{}", vd.0));
                    }
                    if j as usize >= SLICES {
                        bad(
                            FindingKind::RegionViolation,
                            format!("qmov accumulator index {j} outside own region"),
                        );
                    }
                }
                VecOp::EOp { vd, va, vb, .. } => {
                    for v in [va, vb] {
                        if !rd_ok(v) {
                            bad(FindingKind::RegionViolation, format!("vALU{s} cannot read v{}", v.0));
                        }
                    }
                    if !wr_ok(vd) {
                        bad(FindingKind::RegionViolation, format!("vALU{s} cannot write v{}", vd.0));
                    }
                }
                VecOp::EOpI { vd, va, .. } => {
                    if !rd_ok(va) {
                        bad(FindingKind::RegionViolation, format!("vALU{s} cannot read v{}", va.0));
                    }
                    if !wr_ok(vd) {
                        bad(FindingKind::RegionViolation, format!("vALU{s} cannot write v{}", vd.0));
                    }
                }
                // Bcst's source lane wraps in hardware — no lane check
                VecOp::Mov { vd, vs } | VecOp::Bcst { vd, vs, .. } | VecOp::Relu { vd, vs } => {
                    if !rd_ok(vs) {
                        bad(FindingKind::RegionViolation, format!("vALU{s} cannot read v{}", vs.0));
                    }
                    if !wr_ok(vd) {
                        bad(FindingKind::RegionViolation, format!("vALU{s} cannot write v{}", vd.0));
                    }
                }
                VecOp::PoolMax { vd, va, vb } => {
                    for v in [va, vb] {
                        if !rd_ok(v) {
                            bad(FindingKind::RegionViolation, format!("vALU{s} cannot read v{}", v.0));
                        }
                    }
                    if !wr_ok(vd) {
                        bad(FindingKind::RegionViolation, format!("vALU{s} cannot write v{}", vd.0));
                    }
                }
            }
        }
        // slot 0: index ranges the simulator would hit as panics or
        // faults. Operands are collected first and reported after the
        // match, so `out` is only borrowed in one place.
        let mut sregs: Vec<(u8, &str)> = Vec::new();
        let mut extra: Vec<String> = Vec::new();
        match b.slot0 {
            SlotOp::Nop | SlotOp::Halt | SlotOp::Jmp { .. } | SlotOp::LoopI { .. } => {}
            SlotOp::DmaWait { .. } => {}
            SlotOp::Li { rd, .. } => sregs.push((rd.0, "li dest")),
            SlotOp::Alu { rd, ra, rb, .. } => {
                sregs.extend([(rd.0, "alu dest"), (ra.0, "alu src"), (rb.0, "alu src")]);
            }
            SlotOp::AluI { rd, ra, .. } => {
                sregs.extend([(rd.0, "alui dest"), (ra.0, "alui src")]);
            }
            SlotOp::Br { ra, rb, .. } => {
                sregs.extend([(ra.0, "branch src"), (rb.0, "branch src")]);
            }
            SlotOp::Loop { n, .. } => sregs.push((n.0, "loop count")),
            SlotOp::Csrwi { .. } => {}
            SlotOp::Csrw { rs, .. } => sregs.push((rs.0, "csr src")),
            SlotOp::LdS { rd, addr } => {
                sregs.extend([(rd.0, "lds dest"), (addr.base.0, "address base")]);
            }
            SlotOp::StS { rs, addr } => {
                sregs.extend([(rs.0, "sts src"), (addr.base.0, "address base")]);
            }
            SlotOp::LdV { vd, addr } => {
                sregs.push((addr.base.0, "address base"));
                if vd.0 >= 16 {
                    extra.push(format!("vector register v{} out of range", vd.0));
                }
            }
            SlotOp::StV { vs, addr } => {
                sregs.push((addr.base.0, "address base"));
                if vs.0 >= 16 {
                    extra.push(format!("vector register v{} out of range", vs.0));
                }
            }
            SlotOp::LdVF { addr } => sregs.push((addr.base.0, "address base")),
            SlotOp::LdA { ad, addr } => {
                sregs.push((addr.base.0, "address base"));
                if ad.0 >= 12 {
                    extra.push(format!("accumulator a{} out of range", ad.0));
                }
            }
            SlotOp::StA { as_, addr } => {
                sregs.push((addr.base.0, "address base"));
                if as_.0 >= 12 {
                    extra.push(format!("accumulator a{} out of range", as_.0));
                }
            }
            SlotOp::DmaLoad { ext, dm, len, .. } | SlotOp::DmaStore { ext, dm, len, .. } => {
                sregs.extend([(ext.0, "dma ext"), (dm.0, "dma dm"), (len.0, "dma len")]);
            }
            SlotOp::LbLoad { dm, .. } => sregs.push((dm.0, "lbload dm")),
        }
        for (r, what) in sregs {
            if r >= 32 {
                bad(
                    FindingKind::RegionViolation,
                    format!("scalar register r{r} out of range ({what})"),
                );
            }
        }
        for m in extra {
            bad(FindingKind::RegionViolation, m);
        }
    }
}

pub(crate) fn check(prog: &Program, cfg: &Cfg, out: &mut Vec<Finding>) {
    scan_static(prog, out);

    let len = prog.bundles.len();
    if len == 0 {
        return;
    }
    let mut instate: Vec<Option<RState>> = vec![None; len];
    instate[0] = Some(RState::entry());
    let mut imbalance_joins: BTreeSet<usize> = BTreeSet::new();
    let mut work = vec![0usize];
    while let Some(pc) = work.pop() {
        let mut st = instate[pc].clone().unwrap();
        step(&mut st, prog, pc, &mut |_, _| {});
        for &succ in &cfg.succs[pc] {
            if succ >= len {
                continue;
            }
            let changed = match &mut instate[succ] {
                slot @ None => {
                    *slot = Some(st.clone());
                    true
                }
                Some(old) => {
                    let before = old.clone();
                    if old.join(&st) {
                        imbalance_joins.insert(succ);
                    }
                    *old != before
                }
            };
            if changed {
                work.push(succ);
            }
        }
    }
    for pc in imbalance_joins {
        out.push(finding(
            prog,
            FindingKind::FifoImbalance,
            pc,
            "filter-FIFO depth differs between paths joining here".into(),
        ));
    }
    // report sweep over reachable bundles, deduplicating identical
    // messages per bundle
    for pc in 0..len {
        let Some(mut st) = instate[pc].clone() else { continue };
        let mut msgs: Vec<(FindingKind, String)> = Vec::new();
        step(&mut st, prog, pc, &mut |k, m| msgs.push((k, m)));
        msgs.dedup();
        for (k, m) in msgs {
            out.push(finding(prog, k, pc, m));
        }
    }
}
