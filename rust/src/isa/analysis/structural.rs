//! Pass 1 — structural CFG/loop verification.
//!
//! Everything here is a *static* property of the bundle list: branch
//! and jump targets in range, hardware-loop bodies in bounds with at
//! most one level of nesting (the machine faults on depth > 2), no
//! branch crossing a loop-body boundary (the loop stack would desync
//! from the pc), a reachable `Halt`, no reachable fall-through past the
//! last bundle, and an encoded image that fits the 16 KB PM.

use std::collections::BTreeSet;

use crate::isa::{Program, SlotOp};
use crate::mem::PM_BYTES;

use super::{finding, Cfg, Finding, FindingKind};

pub(crate) fn check(prog: &Program, cfg: &Cfg, out: &mut Vec<Finding>) {
    let len = prog.bundles.len();
    if len == 0 {
        out.push(finding(prog, FindingKind::NoHaltPath, 0, "empty program".into()));
        return;
    }
    if prog.encoded_size() > PM_BYTES {
        out.push(finding(
            prog,
            FindingKind::PmOverflow,
            0,
            format!("encoded size {} B exceeds PM capacity {PM_BYTES} B", prog.encoded_size()),
        ));
    }

    for (pc, b) in prog.bundles.iter().enumerate() {
        match b.slot0 {
            SlotOp::Br { target, .. } | SlotOp::Jmp { target } => {
                if target as usize >= len {
                    out.push(finding(
                        prog,
                        FindingKind::BranchTargetOutOfRange,
                        pc,
                        format!("target {target} >= program length {len}"),
                    ));
                }
            }
            SlotOp::Loop { body, .. } | SlotOp::LoopI { body, .. } => {
                if body == 0 {
                    out.push(finding(
                        prog,
                        FindingKind::LoopBodyOutOfRange,
                        pc,
                        "hardware loop with empty body (machine fault)".into(),
                    ));
                } else if pc + body as usize >= len {
                    out.push(finding(
                        prog,
                        FindingKind::LoopBodyOutOfRange,
                        pc,
                        format!(
                            "loop body [{}..={}] extends past program length {len}",
                            pc + 1,
                            pc + body as usize
                        ),
                    ));
                }
            }
            _ => {}
        }
    }

    // nesting depth (the machine faults at depth > 2) and proper
    // containment of nested bodies
    for (i, &(lp, _, last)) in cfg.regions.iter().enumerate() {
        let mut depth = 1;
        for (j, &(_, s2, l2)) in cfg.regions.iter().enumerate() {
            if i == j {
                continue;
            }
            if s2 <= lp && lp <= l2 {
                depth += 1;
                if last > l2 {
                    out.push(finding(
                        prog,
                        FindingKind::LoopNesting,
                        lp,
                        format!("inner loop body ends at {last}, past enclosing body end {l2}"),
                    ));
                }
            }
        }
        if depth > 2 {
            out.push(finding(
                prog,
                FindingKind::LoopNesting,
                lp,
                format!("hardware loop nesting depth {depth} > 2 (machine fault)"),
            ));
        }
    }

    // branches in or out of a hardware-loop body desync the loop stack
    for (pc, b) in prog.bundles.iter().enumerate() {
        let target = match b.slot0 {
            SlotOp::Br { target, .. } | SlotOp::Jmp { target } => target as usize,
            _ => continue,
        };
        if target >= len {
            continue; // already reported above
        }
        for &(_, start, last) in &cfg.regions {
            let src_in = (start..=last).contains(&pc);
            let tgt_in = (start..=last).contains(&target);
            if src_in != tgt_in {
                out.push(finding(
                    prog,
                    FindingKind::BranchCrossesLoop,
                    pc,
                    format!("branch to {target} crosses hardware-loop body [{start}..={last}]"),
                ));
            }
        }
    }

    // reachability: a Halt must be reachable, and no reachable edge may
    // fall through past the last bundle
    let mut seen = vec![false; len];
    let mut stack = vec![0usize];
    seen[0] = true;
    let mut any_halt = false;
    let mut ran_off = BTreeSet::new();
    while let Some(pc) = stack.pop() {
        if matches!(prog.bundles[pc].slot0, SlotOp::Halt) {
            any_halt = true;
        }
        for &succ in &cfg.succs[pc] {
            if succ >= len {
                // an out-of-range branch *target* is already reported;
                // this catches sequential / loop-skip fall-through
                let is_br_target = matches!(
                    prog.bundles[pc].slot0,
                    SlotOp::Br { target, .. } | SlotOp::Jmp { target } if target as usize == succ
                );
                if !is_br_target {
                    ran_off.insert(pc);
                }
            } else if !seen[succ] {
                seen[succ] = true;
                stack.push(succ);
            }
        }
    }
    for pc in ran_off {
        out.push(finding(
            prog,
            FindingKind::RunsOffEnd,
            pc,
            "control can fall through past the last bundle (no halt)".into(),
        ));
    }
    if !any_halt {
        out.push(finding(prog, FindingKind::NoHaltPath, 0, "no reachable Halt".into()));
    }
}
