//! Shared issue/retire timing rules — the single source of truth for the
//! core's scoreboard (RAW hazard) model.
//!
//! Both the cycle simulator (`core::cpu`) and the static cycle analyzer
//! (`analysis::predict`) call these functions, so the stall model cannot
//! drift between the two: the simulator consults `issue_ready` /
//! `retire_bundle` per dynamic bundle, and the analyzer calls the very
//! same functions while walking a program symbolically. The equality
//! tests in `codegen::compiled` assert the resulting cycle counts match
//! bit-for-bit.
//!
//! The model: every register-file entry has a "ready" cycle. A bundle
//! issues at the max of `now` and the ready cycles of everything it
//! reads (`issue_ready`); once it executes, its writes set new ready
//! cycles (`retire_bundle`) using the latency constants below. Filter
//! FIFO entries carry their own ready cycle (`fifo_entry_ready`),
//! checked against the *front* entry only — pops are in order.

use crate::core::regfile::own_acc_base;
use crate::isa::{ASrc, BSrc, Bundle, SlotOp, VecOp, SLICES};

/// DM bank geometry + the port-1 conflict rule live in [`super::banks`]
/// and are re-exported here so every shared timing rule — scoreboard
/// *and* memory — is reachable from one module. `mem::dm::DataMem`
/// delegates to the same functions (moved, not copied).
pub use super::banks::{bank_of, bank_set, p1_conflicts};

/// DM load to dependent use (scalar, vector and filter-FIFO loads).
pub const LOAD_USE_LATENCY: u64 = 2;
/// Vector MAC to requantize (`QMov`) read of the same accumulator.
pub const MAC_TO_QMOV_LATENCY: u64 = 4;
/// Requantize (`QMov`) to dependent read of the destination VR entry.
pub const QMOV_TO_READ_LATENCY: u64 = 3;
/// Pipeline bubbles after a taken branch / jump.
pub const BRANCH_BUBBLES: u64 = 2;
/// Filter FIFO depth (operand fetch & prepare stage).
pub const FIFO_DEPTH: usize = 8;

/// Ready-cycle scoreboard for the three register files.
#[derive(Debug, Clone)]
pub struct Scoreboard {
    /// Cycle at which each VR entry is ready for a consumer.
    pub vr: [u64; 16],
    /// Cycle at which each VRl (accumulator) entry is ready.
    pub vrl: [u64; 12],
    /// Cycle at which each scalar register is ready.
    pub r: [u64; 32],
}

impl Scoreboard {
    pub fn new() -> Self {
        Self { vr: [0; 16], vrl: [0; 12], r: [0; 32] }
    }

    pub fn reset(&mut self) {
        self.vr = [0; 16];
        self.vrl = [0; 12];
        self.r = [0; 32];
    }
}

impl Default for Scoreboard {
    fn default() -> Self {
        Self::new()
    }
}

/// A FIFO-sourced vector MAC found the filter FIFO empty — a machine
/// fault (the simulator reports it as `SimError::Fault`, the verifier as
/// `FindingKind::FifoUnderflow`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FifoEmpty;

/// Earliest cycle `>= now` at which every operand read by `b` is ready.
///
/// `fifo_front_ready` is the ready cycle of the filter-FIFO front entry
/// (None = FIFO empty). Only *reads* contribute; `Csrw`, `Loop`,
/// `DmaLoad`/`DmaStore` operands are control-path reads that do not go
/// through the scoreboard (they are never load destinations in practice).
///
/// Register indices out of range panic, exactly like the simulator's
/// scoreboard arrays — run `analysis::verify` first for untrusted
/// programs.
pub fn issue_ready(
    b: &Bundle,
    sb: &Scoreboard,
    fifo_front_ready: Option<u64>,
    now: u64,
) -> Result<u64, FifoEmpty> {
    let mut ready = now;
    let need_vr = |idx: u8, ready: &mut u64| {
        *ready = (*ready).max(sb.vr[idx as usize]);
    };
    for (i, op) in b.v.iter().enumerate() {
        let s = i as u8 + 1;
        match *op {
            VecOp::Mac { a, b } | VecOp::Mul { a, b } => {
                match a {
                    ASrc::VrBcast { vr, .. } => need_vr(vr.0, &mut ready),
                    ASrc::VrQuad { vr } => {
                        for k in 0..SLICES as u8 {
                            need_vr(vr.0 + k, &mut ready);
                        }
                    }
                    ASrc::Lb { .. } | ASrc::LbVec { .. } => {}
                }
                match b {
                    BSrc::Vr { vr } | BSrc::VrLane { vr, .. } | BSrc::VrLaneQuad { vr, .. } => {
                        need_vr(vr.0, &mut ready)
                    }
                    BSrc::VrQuad { vr } => {
                        for k in 0..SLICES as u8 {
                            need_vr(vr.0 + k, &mut ready);
                        }
                    }
                    BSrc::Fifo | BSrc::FifoLaneQuad { .. } => match fifo_front_ready {
                        Some(rdy) => ready = ready.max(rdy),
                        None => return Err(FifoEmpty),
                    },
                }
            }
            VecOp::QMov { j, .. } => {
                let a = own_acc_base(s) + j;
                ready = ready.max(sb.vrl[a as usize]);
            }
            VecOp::EOp { va, vb, .. } => {
                need_vr(va.0, &mut ready);
                need_vr(vb.0, &mut ready);
            }
            VecOp::EOpI { va, .. } => need_vr(va.0, &mut ready),
            VecOp::Mov { vs, .. } | VecOp::Relu { vs, .. } | VecOp::Bcst { vs, .. } => {
                need_vr(vs.0, &mut ready)
            }
            VecOp::PoolMax { va, vb, .. } => {
                need_vr(va.0, &mut ready);
                need_vr(vb.0, &mut ready);
            }
            VecOp::InitA { vr } | VecOp::InitALane { vr, .. } => need_vr(vr.0, &mut ready),
            VecOp::ClrA { .. } | VecOp::Nop => {}
        }
    }
    match b.slot0 {
        SlotOp::StV { vs, addr } => {
            ready = ready.max(sb.vr[vs.0 as usize]).max(sb.r[addr.base.0 as usize]);
        }
        SlotOp::StA { as_, addr } => {
            ready = ready.max(sb.vrl[as_.0 as usize]).max(sb.r[addr.base.0 as usize]);
        }
        SlotOp::Alu { ra, rb, .. } => {
            ready = ready.max(sb.r[ra.0 as usize]).max(sb.r[rb.0 as usize]);
        }
        SlotOp::AluI { ra, .. } => ready = ready.max(sb.r[ra.0 as usize]),
        SlotOp::Br { ra, rb, .. } => {
            ready = ready.max(sb.r[ra.0 as usize]).max(sb.r[rb.0 as usize]);
        }
        SlotOp::LdS { addr, .. }
        | SlotOp::StS { addr, .. }
        | SlotOp::LdV { addr, .. }
        | SlotOp::LdVF { addr }
        | SlotOp::LdA { addr, .. } => {
            ready = ready.max(sb.r[addr.base.0 as usize]);
        }
        _ => {}
    }
    Ok(ready)
}

/// Apply the scoreboard *writes* of a bundle that issued (post-stall) at
/// cycle `now`. Write order is vector slots 1..=3 then slot 0, matching
/// the interpreter's execution order. Note `LdA`/`StA` advance the clock
/// mid-op for their second port-0 access; their latency is nonetheless
/// anchored at the issue cycle (`now`), exactly as the simulator does.
pub fn retire_bundle(b: &Bundle, now: u64, sb: &mut Scoreboard) {
    for (i, op) in b.v.iter().enumerate() {
        let s = i as u8 + 1;
        let base = own_acc_base(s) as usize;
        match *op {
            VecOp::Mac { .. } | VecOp::Mul { .. } => {
                let ready = now + MAC_TO_QMOV_LATENCY;
                for j in 0..SLICES {
                    sb.vrl[base + j] = ready;
                }
            }
            VecOp::ClrA { only } => {
                for j in 0..SLICES as u8 {
                    if only.is_none() || only == Some(j) {
                        sb.vrl[base + j as usize] = now;
                    }
                }
            }
            VecOp::InitA { .. } | VecOp::InitALane { .. } => {
                for j in 0..SLICES {
                    sb.vrl[base + j] = now;
                }
            }
            VecOp::QMov { vd, .. } => sb.vr[vd.0 as usize] = now + QMOV_TO_READ_LATENCY,
            VecOp::EOp { vd, .. }
            | VecOp::EOpI { vd, .. }
            | VecOp::Mov { vd, .. }
            | VecOp::Bcst { vd, .. }
            | VecOp::Relu { vd, .. }
            | VecOp::PoolMax { vd, .. } => sb.vr[vd.0 as usize] = now + 1,
            VecOp::Nop => {}
        }
    }
    match b.slot0 {
        SlotOp::LdS { rd, .. } => sb.r[rd.0 as usize] = now + LOAD_USE_LATENCY,
        SlotOp::LdV { vd, .. } => sb.vr[vd.0 as usize] = now + LOAD_USE_LATENCY,
        SlotOp::LdA { ad, .. } => sb.vrl[ad.0 as usize] = now + LOAD_USE_LATENCY + 1,
        _ => {}
    }
}

/// Ready cycle of a filter-FIFO entry pushed by an `LdVF` issued at
/// cycle `now` (same load-use latency as `LdV`).
pub fn fifo_entry_ready(now: u64) -> u64 {
    now + LOAD_USE_LATENCY
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Addr, SReg, VReg};

    #[test]
    fn ldv_then_use_pays_load_use_latency() {
        let mut sb = Scoreboard::new();
        let ld = Bundle::s0(SlotOp::LdV { vd: VReg(4), addr: Addr::base(SReg(1)) });
        retire_bundle(&ld, 10, &mut sb);
        let st = Bundle::s0(SlotOp::StV { vs: VReg(4), addr: Addr::base(SReg(2)) });
        let ready = issue_ready(&st, &sb, None, 11).unwrap();
        assert_eq!(ready, 10 + LOAD_USE_LATENCY);
    }

    #[test]
    fn mac_to_qmov_pays_full_latency() {
        let mut sb = Scoreboard::new();
        let mac = Bundle {
            slot0: SlotOp::Nop,
            v: [
                VecOp::Mac {
                    a: ASrc::VrBcast { vr: VReg(4), base: 0, step: 0 },
                    b: BSrc::Vr { vr: VReg(0) },
                },
                VecOp::Nop,
                VecOp::Nop,
            ],
        };
        retire_bundle(&mac, 5, &mut sb);
        let q = Bundle {
            slot0: SlotOp::Nop,
            v: [VecOp::QMov { vd: VReg(5), j: 0, relu: false }, VecOp::Nop, VecOp::Nop],
        };
        assert_eq!(issue_ready(&q, &sb, None, 6).unwrap(), 5 + MAC_TO_QMOV_LATENCY);
        // a different slot's accumulators are untouched
        let q2 = Bundle {
            slot0: SlotOp::Nop,
            v: [VecOp::Nop, VecOp::QMov { vd: VReg(9), j: 0, relu: false }, VecOp::Nop],
        };
        assert_eq!(issue_ready(&q2, &sb, None, 6).unwrap(), 6);
    }

    #[test]
    fn fifo_sourced_mac_waits_on_front_entry() {
        let sb = Scoreboard::new();
        let mac = Bundle {
            slot0: SlotOp::Nop,
            v: [
                VecOp::Mac { a: ASrc::Lb { row: 0, off: 0 }, b: BSrc::Fifo },
                VecOp::Nop,
                VecOp::Nop,
            ],
        };
        assert_eq!(issue_ready(&mac, &sb, None, 0), Err(FifoEmpty));
        assert_eq!(issue_ready(&mac, &sb, Some(fifo_entry_ready(3)), 4).unwrap(), 5);
        assert_eq!(issue_ready(&mac, &sb, Some(2), 9).unwrap(), 9);
    }
}
