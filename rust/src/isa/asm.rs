//! Two-pass assembler for the `.cvx` VLIW assembly syntax.
//!
//! One line = one bundle; slots separated by `|` (slot 0 first, then the
//! three vector slots; missing trailing slots assemble to nops). Labels
//! are `name:` on their own line or prefixed; branch targets may be
//! `@123` (absolute bundle index) or a label name. `;` starts a comment.
//!
//! ```text
//! start:
//!   csrwi frac_shift, 8
//!   li r1, 0
//! loop:
//!   ldv v0, [r1]!32 | vmac lb:0, v0 | vmac lb:4, v0 | vmac lb:8, v0
//!   addi r2, r2, -1
//!   bne r2, r0, loop
//!   halt
//! ```

use super::*;
use std::collections::HashMap;

#[derive(Debug)]
pub struct AsmError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for AsmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "asm error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for AsmError {}

fn err<T>(line: usize, msg: impl Into<String>) -> Result<T, AsmError> {
    Err(AsmError { line, msg: msg.into() })
}

pub fn assemble(src: &str) -> Result<Program, AsmError> {
    // pass 1: strip comments, collect labels and bundle lines
    let mut labels: HashMap<String, u32> = HashMap::new();
    let mut lines: Vec<(usize, String)> = Vec::new();
    let mut idx = 0u32;
    for (ln, raw) in src.lines().enumerate() {
        let ln = ln + 1;
        let mut line = raw.split(';').next().unwrap_or("").trim().to_string();
        // leading labels (possibly several)
        while let Some(pos) = line.find(':') {
            let (head, tail) = line.split_at(pos);
            let name = head.trim();
            if name.is_empty()
                || !name
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '.')
                || name.contains(' ')
            {
                break; // not a label (e.g. `lb:0` operand) — leave line alone
            }
            if labels.insert(name.to_string(), idx).is_some() {
                return err(ln, format!("duplicate label `{name}`"));
            }
            line = tail[1..].trim().to_string();
        }
        if line.is_empty() {
            continue;
        }
        lines.push((ln, line));
        idx += 1;
    }

    // pass 2: parse bundles
    let mut bundles = Vec::with_capacity(lines.len());
    for (ln, line) in &lines {
        bundles.push(parse_bundle(*ln, line, &labels)?);
    }
    Ok(Program { bundles })
}

fn parse_bundle(
    ln: usize,
    line: &str,
    labels: &HashMap<String, u32>,
) -> Result<Bundle, AsmError> {
    let parts: Vec<&str> = line.split('|').map(str::trim).collect();
    if parts.len() > 4 {
        return err(ln, "more than 4 slots in bundle");
    }
    let slot0 = parse_slot0(ln, parts[0], labels)?;
    let mut v = [VecOp::Nop; VALU_SLOTS];
    for (i, p) in parts.iter().skip(1).enumerate() {
        v[i] = parse_vec(ln, p)?;
    }
    Ok(Bundle { slot0, v })
}

struct Toks<'a> {
    ln: usize,
    op: &'a str,
    args: Vec<&'a str>,
}

fn tokenize(ln: usize, s: &str) -> Result<Toks<'_>, AsmError> {
    let s = s.trim();
    let (op, rest) = match s.find(char::is_whitespace) {
        Some(i) => (&s[..i], s[i..].trim()),
        None => (s, ""),
    };
    if op.is_empty() {
        return err(ln, "empty slot");
    }
    let args = if rest.is_empty() {
        vec![]
    } else {
        rest.split(',').map(str::trim).collect()
    };
    Ok(Toks { ln, op, args })
}

impl<'a> Toks<'a> {
    fn n(&self, want: usize) -> Result<(), AsmError> {
        if self.args.len() != want {
            return err(
                self.ln,
                format!("`{}` wants {} args, got {}", self.op, want, self.args.len()),
            );
        }
        Ok(())
    }
    fn arg(&self, i: usize) -> &'a str {
        self.args[i]
    }
}

fn parse_sreg(ln: usize, s: &str) -> Result<SReg, AsmError> {
    let n: u8 = s
        .strip_prefix('r')
        .and_then(|x| x.parse().ok())
        .ok_or(AsmError { line: ln, msg: format!("bad scalar reg `{s}`") })?;
    if n >= SReg::COUNT {
        return err(ln, format!("scalar reg out of range `{s}`"));
    }
    Ok(SReg(n))
}

fn parse_vreg(ln: usize, s: &str) -> Result<VReg, AsmError> {
    let n: u8 = s
        .strip_prefix('v')
        .and_then(|x| x.parse().ok())
        .ok_or(AsmError { line: ln, msg: format!("bad vector reg `{s}`") })?;
    if n >= VReg::COUNT {
        return err(ln, format!("vector reg out of range `{s}`"));
    }
    Ok(VReg(n))
}

fn parse_vacc(ln: usize, s: &str) -> Result<VAcc, AsmError> {
    let n: u8 = s
        .strip_prefix('a')
        .and_then(|x| x.parse().ok())
        .ok_or(AsmError { line: ln, msg: format!("bad acc reg `{s}`") })?;
    if n >= VAcc::COUNT {
        return err(ln, format!("acc reg out of range `{s}`"));
    }
    Ok(VAcc(n))
}

fn parse_int<T: TryFrom<i64>>(ln: usize, s: &str) -> Result<T, AsmError> {
    let v: i64 = if let Some(hex) = s.strip_prefix("0x") {
        i64::from_str_radix(hex, 16).map_err(|_| AsmError { line: ln, msg: format!("bad int `{s}`") })?
    } else {
        s.parse().map_err(|_| AsmError { line: ln, msg: format!("bad int `{s}`") })?
    };
    T::try_from(v).map_err(|_| AsmError { line: ln, msg: format!("int out of range `{s}`") })
}

/// `[rN]`, `[rN+off]`, optionally followed by `!inc`.
fn parse_addr(ln: usize, s: &str) -> Result<Addr, AsmError> {
    let (mem, inc) = match s.find('!') {
        Some(i) => (&s[..i], Some(&s[i + 1..])),
        None => (s, None),
    };
    let inner = mem
        .strip_prefix('[')
        .and_then(|x| x.strip_suffix(']'))
        .ok_or(AsmError { line: ln, msg: format!("bad address `{s}`") })?;
    let (base_s, off) = match inner.find(['+', '-']) {
        Some(i) if i > 0 => {
            let off: i32 = parse_int(ln, inner[i..].trim_start_matches('+'))?;
            (&inner[..i], off)
        }
        _ => (inner, 0),
    };
    let base = parse_sreg(ln, base_s.trim())?;
    let post_inc = match inc {
        Some(x) => parse_int(ln, x)?,
        None => 0,
    };
    Ok(Addr { base, offset: off, post_inc })
}

fn parse_target(ln: usize, s: &str, labels: &HashMap<String, u32>) -> Result<u32, AsmError> {
    if let Some(abs) = s.strip_prefix('@') {
        return parse_int(ln, abs);
    }
    labels
        .get(s)
        .copied()
        .ok_or(AsmError { line: ln, msg: format!("unknown label `{s}`") })
}

fn parse_csr(ln: usize, s: &str) -> Result<Csr, AsmError> {
    Ok(match s {
        "frac_shift" => Csr::FracShift,
        "round_mode" => Csr::RoundMode,
        "gate_bits" => Csr::GateBits,
        "lb_stride" => Csr::LbStride,
        _ => return err(ln, format!("unknown csr `{s}`")),
    })
}

fn alu_of(name: &str) -> Option<AluFn> {
    Some(match name {
        "add" => AluFn::Add,
        "sub" => AluFn::Sub,
        "mul" => AluFn::Mul,
        "and" => AluFn::And,
        "or" => AluFn::Or,
        "xor" => AluFn::Xor,
        "shl" => AluFn::Shl,
        "shr" => AluFn::Shr,
        "min" => AluFn::Min,
        "max" => AluFn::Max,
        _ => return None,
    })
}

fn parse_slot0(
    ln: usize,
    s: &str,
    labels: &HashMap<String, u32>,
) -> Result<SlotOp, AsmError> {
    let t = tokenize(ln, s)?;
    // alu ops: name[i][.16]
    let (base, w) = match t.op.strip_suffix(".16") {
        Some(b) => (b, Width::W16),
        None => (t.op, Width::W32),
    };
    if let Some(f) = alu_of(base) {
        t.n(3)?;
        return Ok(SlotOp::Alu {
            f,
            w,
            rd: parse_sreg(ln, t.arg(0))?,
            ra: parse_sreg(ln, t.arg(1))?,
            rb: parse_sreg(ln, t.arg(2))?,
        });
    }
    if let Some(f) = base.strip_suffix('i').and_then(alu_of) {
        t.n(3)?;
        return Ok(SlotOp::AluI {
            f,
            w,
            rd: parse_sreg(ln, t.arg(0))?,
            ra: parse_sreg(ln, t.arg(1))?,
            imm: parse_int(ln, t.arg(2))?,
        });
    }
    Ok(match t.op {
        "nop" => SlotOp::Nop,
        "halt" => SlotOp::Halt,
        "li" => {
            t.n(2)?;
            SlotOp::Li { rd: parse_sreg(ln, t.arg(0))?, imm: parse_int(ln, t.arg(1))? }
        }
        "beq" | "bne" | "blt" | "bge" => {
            t.n(3)?;
            let c = match t.op {
                "beq" => Cond::Eq,
                "bne" => Cond::Ne,
                "blt" => Cond::Lt,
                _ => Cond::Ge,
            };
            SlotOp::Br {
                c,
                ra: parse_sreg(ln, t.arg(0))?,
                rb: parse_sreg(ln, t.arg(1))?,
                target: parse_target(ln, t.arg(2), labels)?,
            }
        }
        "jmp" => {
            t.n(1)?;
            SlotOp::Jmp { target: parse_target(ln, t.arg(0), labels)? }
        }
        "loop" => {
            t.n(2)?;
            SlotOp::Loop { n: parse_sreg(ln, t.arg(0))?, body: parse_int(ln, t.arg(1))? }
        }
        "loopi" => {
            t.n(2)?;
            SlotOp::LoopI { n: parse_int(ln, t.arg(0))?, body: parse_int(ln, t.arg(1))? }
        }
        "csrwi" => {
            t.n(2)?;
            SlotOp::Csrwi { csr: parse_csr(ln, t.arg(0))?, imm: parse_int(ln, t.arg(1))? }
        }
        "csrw" => {
            t.n(2)?;
            SlotOp::Csrw { csr: parse_csr(ln, t.arg(0))?, rs: parse_sreg(ln, t.arg(1))? }
        }
        "lds" => {
            t.n(2)?;
            SlotOp::LdS { rd: parse_sreg(ln, t.arg(0))?, addr: parse_addr(ln, t.arg(1))? }
        }
        "sts" => {
            t.n(2)?;
            SlotOp::StS { rs: parse_sreg(ln, t.arg(0))?, addr: parse_addr(ln, t.arg(1))? }
        }
        "ldv" => {
            t.n(2)?;
            SlotOp::LdV { vd: parse_vreg(ln, t.arg(0))?, addr: parse_addr(ln, t.arg(1))? }
        }
        "stv" => {
            t.n(2)?;
            SlotOp::StV { vs: parse_vreg(ln, t.arg(0))?, addr: parse_addr(ln, t.arg(1))? }
        }
        "lda" => {
            t.n(2)?;
            SlotOp::LdA { ad: parse_vacc(ln, t.arg(0))?, addr: parse_addr(ln, t.arg(1))? }
        }
        "sta" => {
            t.n(2)?;
            SlotOp::StA { as_: parse_vacc(ln, t.arg(0))?, addr: parse_addr(ln, t.arg(1))? }
        }
        "dmald" | "dmast" => {
            t.n(4)?;
            let ch = parse_int(ln, t.arg(0))?;
            let ext = parse_sreg(ln, t.arg(1))?;
            let dm = parse_sreg(ln, t.arg(2))?;
            let len = parse_sreg(ln, t.arg(3))?;
            if t.op == "dmald" {
                SlotOp::DmaLoad { ch, ext, dm, len }
            } else {
                SlotOp::DmaStore { ch, ext, dm, len }
            }
        }
        "dmawait" => {
            t.n(1)?;
            SlotOp::DmaWait { ch: parse_int(ln, t.arg(0))? }
        }
        "lbld" => {
            // lbld row, rN, win            (1 row, off 0)
            // lbld row, rN, off, win, nrows, rstride
            if t.args.len() == 3 {
                SlotOp::LbLoad {
                    row: parse_int(ln, t.arg(0))?,
                    dm: parse_sreg(ln, t.arg(1))?,
                    off: 0,
                    win: parse_int(ln, t.arg(2))?,
                    nrows: 1,
                    rstride: 0,
                }
            } else {
                t.n(6)?;
                SlotOp::LbLoad {
                    row: parse_int(ln, t.arg(0))?,
                    dm: parse_sreg(ln, t.arg(1))?,
                    off: parse_int(ln, t.arg(2))?,
                    win: parse_int(ln, t.arg(3))?,
                    nrows: parse_int(ln, t.arg(4))?,
                    rstride: parse_int(ln, t.arg(5))?,
                }
            }
        }
        "ldvf" => {
            t.n(1)?;
            SlotOp::LdVF { addr: parse_addr(ln, t.arg(0))? }
        }
        other => return err(ln, format!("unknown slot-0 op `{other}`")),
    })
}

/// `lb:N` / `lbR:N` / `lbvR:N` | `vN~base+step` | `qN`
fn parse_asrc(ln: usize, s: &str) -> Result<ASrc, AsmError> {
    if let Some(rest) = s.strip_prefix("lbv") {
        if let Some(colon) = rest.find(':') {
            let row = if colon == 0 { 0 } else { parse_int(ln, &rest[..colon])? };
            return Ok(ASrc::LbVec { row, off: parse_int(ln, &rest[colon + 1..])? });
        }
    }
    if let Some(rest) = s.strip_prefix("lb") {
        if let Some(colon) = rest.find(':') {
            let row = if colon == 0 { 0 } else { parse_int(ln, &rest[..colon])? };
            return Ok(ASrc::Lb { row, off: parse_int(ln, &rest[colon + 1..])? });
        }
    }
    if let Some(q) = s.strip_prefix('q') {
        return Ok(ASrc::VrQuad { vr: VReg(parse_int::<i64>(ln, q)? as u8) });
    }
    if let Some(tilde) = s.find('~') {
        let vr = parse_vreg(ln, &s[..tilde])?;
        let rest = &s[tilde + 1..];
        let plus = rest
            .find('+')
            .ok_or(AsmError { line: ln, msg: format!("bad bcast src `{s}`") })?;
        return Ok(ASrc::VrBcast {
            vr,
            base: parse_int(ln, &rest[..plus])?,
            step: parse_int(ln, &rest[plus + 1..])?,
        });
    }
    err(ln, format!("bad vector A-source `{s}`"))
}

/// `vN` | `vN.lane` | `vN.base+` | `qN` | `ff` | `ff.base+`
fn parse_bsrc(ln: usize, s: &str) -> Result<BSrc, AsmError> {
    if s == "ff" {
        return Ok(BSrc::Fifo);
    }
    if let Some(rest) = s.strip_prefix("ff.") {
        if let Some(base) = rest.strip_suffix('+') {
            return Ok(BSrc::FifoLaneQuad { base: parse_int(ln, base)? });
        }
        return err(ln, format!("bad fifo source `{s}`"));
    }
    if let Some(q) = s.strip_prefix('q') {
        return Ok(BSrc::VrQuad { vr: VReg(parse_int::<i64>(ln, q)? as u8) });
    }
    if let Some(dot) = s.find('.') {
        if let Some(base) = s[dot + 1..].strip_suffix('+') {
            return Ok(BSrc::VrLaneQuad {
                vr: parse_vreg(ln, &s[..dot])?,
                base: parse_int(ln, base)?,
            });
        }
        return Ok(BSrc::VrLane {
            vr: parse_vreg(ln, &s[..dot])?,
            lane: parse_int(ln, &s[dot + 1..])?,
        });
    }
    Ok(BSrc::Vr { vr: parse_vreg(ln, s)? })
}

fn vfn_of(name: &str) -> Option<VFn> {
    Some(match name {
        "vadd" => VFn::Add,
        "vsub" => VFn::Sub,
        "vmul16" => VFn::Mul,
        "vmax" => VFn::Max,
        "vmin" => VFn::Min,
        "vshl" => VFn::Shl,
        "vshr" => VFn::Shr,
        _ => return None,
    })
}

fn parse_vec(ln: usize, s: &str) -> Result<VecOp, AsmError> {
    let t = tokenize(ln, s)?;
    if let Some(f) = vfn_of(t.op) {
        t.n(3)?;
        return Ok(VecOp::EOp {
            f,
            vd: parse_vreg(ln, t.arg(0))?,
            va: parse_vreg(ln, t.arg(1))?,
            vb: parse_vreg(ln, t.arg(2))?,
        });
    }
    if let Some(f) = t.op.strip_suffix('i').and_then(vfn_of) {
        t.n(3)?;
        return Ok(VecOp::EOpI {
            f,
            vd: parse_vreg(ln, t.arg(0))?,
            va: parse_vreg(ln, t.arg(1))?,
            imm: parse_int(ln, t.arg(2))?,
        });
    }
    Ok(match t.op {
        "vnop" => VecOp::Nop,
        "vmac" | "vmul" => {
            t.n(2)?;
            let a = parse_asrc(ln, t.arg(0))?;
            let b = parse_bsrc(ln, t.arg(1))?;
            if t.op == "vmac" {
                VecOp::Mac { a, b }
            } else {
                VecOp::Mul { a, b }
            }
        }
        "vclra" => {
            if t.args.is_empty() {
                VecOp::ClrA { only: None }
            } else {
                VecOp::ClrA { only: Some(parse_int(ln, t.arg(0))?) }
            }
        }
        "vinita" => {
            t.n(1)?;
            VecOp::InitA { vr: parse_vreg(ln, t.arg(0))? }
        }
        "vinital" => {
            t.n(1)?;
            let src = t.arg(0);
            let dot = src
                .find('.')
                .ok_or(AsmError { line: ln, msg: format!("vinital wants vN.base+, got `{src}`") })?;
            let base = src[dot + 1..]
                .strip_suffix('+')
                .ok_or(AsmError { line: ln, msg: format!("vinital wants vN.base+, got `{src}`") })?;
            VecOp::InitALane { vr: parse_vreg(ln, &src[..dot])?, base: parse_int(ln, base)? }
        }
        "vqmov" | "vqmov.relu" => {
            t.n(2)?;
            VecOp::QMov {
                vd: parse_vreg(ln, t.arg(0))?,
                j: parse_int(ln, t.arg(1))?,
                relu: t.op.ends_with(".relu"),
            }
        }
        "vmov" => {
            t.n(2)?;
            VecOp::Mov { vd: parse_vreg(ln, t.arg(0))?, vs: parse_vreg(ln, t.arg(1))? }
        }
        "vbcst" => {
            t.n(2)?;
            let dst = parse_vreg(ln, t.arg(0))?;
            let src = t.arg(1);
            let dot = src
                .find('.')
                .ok_or(AsmError { line: ln, msg: format!("vbcst wants vN.lane, got `{src}`") })?;
            VecOp::Bcst {
                vd: dst,
                vs: parse_vreg(ln, &src[..dot])?,
                lane: parse_int(ln, &src[dot + 1..])?,
            }
        }
        "vrelu" => {
            t.n(2)?;
            VecOp::Relu { vd: parse_vreg(ln, t.arg(0))?, vs: parse_vreg(ln, t.arg(1))? }
        }
        "vpoolmax" => {
            t.n(3)?;
            VecOp::PoolMax {
                vd: parse_vreg(ln, t.arg(0))?,
                va: parse_vreg(ln, t.arg(1))?,
                vb: parse_vreg(ln, t.arg(2))?,
            }
        }
        other => return err(ln, format!("unknown vector op `{other}`")),
    })
}

#[cfg(test)]
mod tests {
    use super::super::disasm;
    use super::*;

    #[test]
    fn assembles_basic_program() {
        let p = assemble(
            "start:\n\
             csrwi frac_shift, 8\n\
             li r1, 1024\n\
             loop: ldv v0, [r1]!32 | vmac lb:0, v0 | vmac lb:4, v0 | vmac lb:8, v0\n\
             addi r2, r2, -1\n\
             bne r2, r0, loop\n\
             halt",
        )
        .unwrap();
        assert_eq!(p.len(), 6);
        assert_eq!(p.bundles[2].mac_count(), 192);
        match p.bundles[4].slot0 {
            SlotOp::Br { target, .. } => assert_eq!(target, 2),
            ref o => panic!("expected branch, got {o:?}"),
        }
    }

    #[test]
    fn label_vs_lb_operand_not_confused() {
        let p = assemble("nop | vmac lb:3, v1").unwrap();
        assert_eq!(
            p.bundles[0].v[0],
            VecOp::Mac { a: ASrc::Lb { row: 0, off: 3 }, b: BSrc::Vr { vr: VReg(1) } }
        );
    }

    #[test]
    fn comments_and_blanks_skipped() {
        let p = assemble("; header\n\n  halt ; done\n").unwrap();
        assert_eq!(p.len(), 1);
        assert_eq!(p.bundles[0].slot0, SlotOp::Halt);
    }

    #[test]
    fn duplicate_label_rejected() {
        assert!(assemble("a:\nnop\na:\nhalt").is_err());
    }

    #[test]
    fn unknown_label_rejected() {
        assert!(assemble("jmp nowhere").is_err());
    }

    #[test]
    fn reg_range_checked() {
        assert!(assemble("li r32, 0").is_err());
        assert!(assemble("nop | vmov v16, v0").is_err());
    }

    #[test]
    fn addr_modes() {
        let p = assemble("ldv v1, [r2+64]!32\nstv v1, [r3-16]").unwrap();
        assert_eq!(
            p.bundles[0].slot0,
            SlotOp::LdV { vd: VReg(1), addr: Addr { base: SReg(2), offset: 64, post_inc: 32 } }
        );
        assert_eq!(
            p.bundles[1].slot0,
            SlotOp::StV { vs: VReg(1), addr: Addr { base: SReg(3), offset: -16, post_inc: 0 } }
        );
    }

    #[test]
    fn roundtrip_disasm_asm() {
        use crate::util::proptest::prop;
        // programs without branches (targets print as @n which reparse fine)
        prop("disasm->asm roundtrip", 40, |g| {
            let mut p = Program::default();
            let n = g.usize_in(1, 20);
            for _ in 0..n {
                let s0 = match g.int(0, 4) {
                    0 => SlotOp::Nop,
                    1 => SlotOp::Li { rd: SReg(g.usize_in(0, 31) as u8), imm: g.int(-1000, 1000) as i32 },
                    2 => SlotOp::LdV {
                        vd: VReg(g.usize_in(0, 15) as u8),
                        addr: Addr {
                            base: SReg(g.usize_in(0, 31) as u8),
                            offset: g.int(-512, 512) as i32,
                            post_inc: g.int(-16, 16) as i32 * 2,
                        },
                    },
                    3 => SlotOp::Csrwi { csr: Csr::FracShift, imm: g.int(0, 15) as u32 },
                    _ => SlotOp::LoopI { n: g.int(1, 100) as u32, body: g.int(1, 10) as u16 },
                };
                let vop = match g.int(0, 3) {
                    0 => VecOp::Nop,
                    1 => VecOp::Mac {
                        a: ASrc::Lb { row: g.int(0, 3) as u8, off: g.int(0, 255) as u16 },
                        b: BSrc::Vr { vr: VReg(g.usize_in(0, 15) as u8) },
                    },
                    2 => VecOp::QMov { vd: VReg(g.usize_in(0, 15) as u8), j: g.int(0, 3) as u8, relu: g.bool() },
                    _ => VecOp::EOpI { f: VFn::Shr, vd: VReg(1), va: VReg(2), imm: g.int(-5, 15) as i16 },
                };
                p.bundles.push(Bundle { slot0: s0, v: [vop, VecOp::Nop, vop] });
            }
            let text = disasm::program(&p);
            let back = assemble(&text).unwrap_or_else(|e| panic!("{e}\n{text}"));
            assert_eq!(p.bundles, back.bundles, "text:\n{text}");
        });
    }
}
