//! Disassembler: `Bundle` → the `.cvx` assembly syntax accepted by
//! [`super::asm`]. `asm(disasm(p)) == p` is property-tested.

use super::*;

fn alu_name(f: AluFn) -> &'static str {
    match f {
        AluFn::Add => "add",
        AluFn::Sub => "sub",
        AluFn::Mul => "mul",
        AluFn::And => "and",
        AluFn::Or => "or",
        AluFn::Xor => "xor",
        AluFn::Shl => "shl",
        AluFn::Shr => "shr",
        AluFn::Min => "min",
        AluFn::Max => "max",
    }
}

fn cond_name(c: Cond) -> &'static str {
    match c {
        Cond::Eq => "beq",
        Cond::Ne => "bne",
        Cond::Lt => "blt",
        Cond::Ge => "bge",
    }
}

fn csr_name(c: Csr) -> &'static str {
    match c {
        Csr::FracShift => "frac_shift",
        Csr::RoundMode => "round_mode",
        Csr::GateBits => "gate_bits",
        Csr::LbStride => "lb_stride",
    }
}

fn vfn_name(f: VFn) -> &'static str {
    match f {
        VFn::Add => "vadd",
        VFn::Sub => "vsub",
        VFn::Mul => "vmul16",
        VFn::Max => "vmax",
        VFn::Min => "vmin",
        VFn::Shl => "vshl",
        VFn::Shr => "vshr",
    }
}

fn addr_str(a: &Addr) -> String {
    let mut s = format!("[r{}", a.base.0);
    if a.offset != 0 {
        s.push_str(&format!("+{}", a.offset));
    }
    s.push(']');
    if a.post_inc != 0 {
        s.push_str(&format!("!{}", a.post_inc));
    }
    s
}

pub fn slot0(op: &SlotOp) -> String {
    match *op {
        SlotOp::Nop => "nop".into(),
        SlotOp::Li { rd, imm } => format!("li r{}, {}", rd.0, imm),
        SlotOp::Alu { f, w, rd, ra, rb } => format!(
            "{}{} r{}, r{}, r{}",
            alu_name(f),
            if w == Width::W16 { ".16" } else { "" },
            rd.0,
            ra.0,
            rb.0
        ),
        SlotOp::AluI { f, w, rd, ra, imm } => format!(
            "{}i{} r{}, r{}, {}",
            alu_name(f),
            if w == Width::W16 { ".16" } else { "" },
            rd.0,
            ra.0,
            imm
        ),
        SlotOp::Br { c, ra, rb, target } => {
            format!("{} r{}, r{}, @{}", cond_name(c), ra.0, rb.0, target)
        }
        SlotOp::Jmp { target } => format!("jmp @{target}"),
        SlotOp::Loop { n, body } => format!("loop r{}, {}", n.0, body),
        SlotOp::LoopI { n, body } => format!("loopi {n}, {body}"),
        SlotOp::Halt => "halt".into(),
        SlotOp::Csrwi { csr, imm } => format!("csrwi {}, {}", csr_name(csr), imm),
        SlotOp::Csrw { csr, rs } => format!("csrw {}, r{}", csr_name(csr), rs.0),
        SlotOp::LdS { rd, addr } => format!("lds r{}, {}", rd.0, addr_str(&addr)),
        SlotOp::StS { rs, addr } => format!("sts r{}, {}", rs.0, addr_str(&addr)),
        SlotOp::LdV { vd, addr } => format!("ldv v{}, {}", vd.0, addr_str(&addr)),
        SlotOp::StV { vs, addr } => format!("stv v{}, {}", vs.0, addr_str(&addr)),
        SlotOp::LdA { ad, addr } => format!("lda a{}, {}", ad.0, addr_str(&addr)),
        SlotOp::StA { as_, addr } => format!("sta a{}, {}", as_.0, addr_str(&addr)),
        SlotOp::DmaLoad { ch, ext, dm, len } => {
            format!("dmald {}, r{}, r{}, r{}", ch, ext.0, dm.0, len.0)
        }
        SlotOp::DmaStore { ch, ext, dm, len } => {
            format!("dmast {}, r{}, r{}, r{}", ch, ext.0, dm.0, len.0)
        }
        SlotOp::DmaWait { ch } => format!("dmawait {ch}"),
        SlotOp::LbLoad { row, dm, off, win, nrows, rstride } => {
            format!("lbld {}, r{}, {}, {}, {}, {}", row, dm.0, off, win, nrows, rstride)
        }
        SlotOp::LdVF { addr } => format!("ldvf {}", addr_str(&addr)),
    }
}

fn asrc(a: &ASrc) -> String {
    match *a {
        ASrc::Lb { row, off } => format!("lb{row}:{off}"),
        ASrc::LbVec { row, off } => format!("lbv{row}:{off}"),
        ASrc::VrBcast { vr, base, step } => format!("v{}~{}+{}", vr.0, base, step),
        ASrc::VrQuad { vr } => format!("q{}", vr.0),
    }
}

fn bsrc(b: &BSrc) -> String {
    match *b {
        BSrc::Vr { vr } => format!("v{}", vr.0),
        BSrc::VrLane { vr, lane } => format!("v{}.{}", vr.0, lane),
        BSrc::VrQuad { vr } => format!("q{}", vr.0),
        BSrc::VrLaneQuad { vr, base } => format!("v{}.{}+", vr.0, base),
        BSrc::Fifo => "ff".into(),
        BSrc::FifoLaneQuad { base } => format!("ff.{base}+"),
    }
}

pub fn vec(op: &VecOp) -> String {
    match *op {
        VecOp::Nop => "vnop".into(),
        VecOp::Mac { a, b } => format!("vmac {}, {}", asrc(&a), bsrc(&b)),
        VecOp::Mul { a, b } => format!("vmul {}, {}", asrc(&a), bsrc(&b)),
        VecOp::ClrA { only: None } => "vclra".into(),
        VecOp::ClrA { only: Some(j) } => format!("vclra {j}"),
        VecOp::InitA { vr } => format!("vinita v{}", vr.0),
        VecOp::InitALane { vr, base } => format!("vinital v{}.{}+", vr.0, base),
        VecOp::QMov { vd, j, relu } => {
            format!("vqmov{} v{}, {}", if relu { ".relu" } else { "" }, vd.0, j)
        }
        VecOp::EOp { f, vd, va, vb } => {
            format!("{} v{}, v{}, v{}", vfn_name(f), vd.0, va.0, vb.0)
        }
        VecOp::EOpI { f, vd, va, imm } => {
            format!("{}i v{}, v{}, {}", vfn_name(f), vd.0, va.0, imm)
        }
        VecOp::Mov { vd, vs } => format!("vmov v{}, v{}", vd.0, vs.0),
        VecOp::Bcst { vd, vs, lane } => format!("vbcst v{}, v{}.{}", vd.0, vs.0, lane),
        VecOp::Relu { vd, vs } => format!("vrelu v{}, v{}", vd.0, vs.0),
        VecOp::PoolMax { vd, va, vb } => format!("vpoolmax v{}, v{}, v{}", vd.0, va.0, vb.0),
    }
}

/// Disassemble one bundle: four slots joined by ` | `.
pub fn bundle(b: &Bundle) -> String {
    format!(
        "{} | {} | {} | {}",
        slot0(&b.slot0),
        vec(&b.v[0]),
        vec(&b.v[1]),
        vec(&b.v[2])
    )
}

/// Disassemble a whole program with bundle indices as `@n` comments.
pub fn program(p: &Program) -> String {
    let mut out = String::new();
    for b in &p.bundles {
        out.push_str(&bundle(b));
        out.push('\n');
    }
    out
}
