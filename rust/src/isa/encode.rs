//! Binary instruction encoding.
//!
//! Each slot op encodes to one 64-bit word; a bundle is 4 words = 32 bytes.
//! The 16 KB program memory therefore holds 512 bundles — a real capacity
//! limit the code generator must respect (`codegen` returns an error when
//! a kernel does not fit, and the layout planner then shrinks tile sizes).
//!
//! The encoding is dense enough to be honest about PM pressure but favors
//! decode simplicity over minimal width (the paper does not publish its
//! encoding). Layout (LSB-first):
//!
//! ```text
//! [7:0]   opcode
//! [15:8]  field a
//! [23:16] field b
//! [31:24] field c
//! [63:32] imm32
//! ```

use super::*;

pub const WORD_BYTES: usize = 8;
pub const BUNDLE_BYTES: usize = 4 * WORD_BYTES;

// --- slot-0 opcodes -------------------------------------------------------
const OP_NOP: u8 = 0x00;
const OP_LI: u8 = 0x01;
const OP_ALU: u8 = 0x02;
const OP_ALUI: u8 = 0x03;
const OP_BR: u8 = 0x04;
const OP_JMP: u8 = 0x05;
const OP_LOOP: u8 = 0x06;
const OP_LOOPI: u8 = 0x07;
const OP_HALT: u8 = 0x08;
const OP_CSRWI: u8 = 0x09;
const OP_CSRW: u8 = 0x0A;
const OP_LDS: u8 = 0x0B;
const OP_STS: u8 = 0x0C;
const OP_LDV: u8 = 0x0D;
const OP_STV: u8 = 0x0E;
const OP_LDA: u8 = 0x0F;
const OP_STA: u8 = 0x10;
const OP_DMAL: u8 = 0x11;
const OP_DMAS: u8 = 0x12;
const OP_DMAW: u8 = 0x13;
const OP_LBLD: u8 = 0x14;
const OP_LDVF: u8 = 0x16;

// --- vector opcodes -------------------------------------------------------
const OP_VNOP: u8 = 0x80;
const OP_VMAC: u8 = 0x81;
const OP_VMUL: u8 = 0x82;
const OP_VCLRA: u8 = 0x83;
const OP_VINITA: u8 = 0x84;
const OP_VQMOV: u8 = 0x85;
const OP_VEOP: u8 = 0x86;
const OP_VEOPI: u8 = 0x87;
const OP_VMOV: u8 = 0x88;
const OP_VBCST: u8 = 0x89;
const OP_VRELU: u8 = 0x8A;
const OP_VPOOLMAX: u8 = 0x8B;
const OP_VINITAL: u8 = 0x8C;

#[derive(Debug, PartialEq)]
pub enum EncodeError {
    BadOpcode(u8, usize),
    Range(&'static str),
    Truncated(usize),
}

impl std::fmt::Display for EncodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EncodeError::BadOpcode(op, word) => write!(f, "bad opcode {op:#x} at word {word}"),
            EncodeError::Range(field) => write!(f, "field out of range: {field}"),
            EncodeError::Truncated(bytes) => {
                write!(f, "truncated program: {bytes} bytes is not a multiple of {BUNDLE_BYTES}")
            }
        }
    }
}

impl std::error::Error for EncodeError {}

#[inline]
fn pack(op: u8, a: u8, b: u8, c: u8, imm: u32) -> u64 {
    op as u64 | (a as u64) << 8 | (b as u64) << 16 | (c as u64) << 24 | (imm as u64) << 32
}

#[inline]
fn un(w: u64) -> (u8, u8, u8, u8, u32) {
    (
        w as u8,
        (w >> 8) as u8,
        (w >> 16) as u8,
        (w >> 24) as u8,
        (w >> 32) as u32,
    )
}

fn alu_bits(f: AluFn) -> u8 {
    match f {
        AluFn::Add => 0,
        AluFn::Sub => 1,
        AluFn::Mul => 2,
        AluFn::And => 3,
        AluFn::Or => 4,
        AluFn::Xor => 5,
        AluFn::Shl => 6,
        AluFn::Shr => 7,
        AluFn::Min => 8,
        AluFn::Max => 9,
    }
}

fn alu_from(b: u8) -> Option<AluFn> {
    Some(match b {
        0 => AluFn::Add,
        1 => AluFn::Sub,
        2 => AluFn::Mul,
        3 => AluFn::And,
        4 => AluFn::Or,
        5 => AluFn::Xor,
        6 => AluFn::Shl,
        7 => AluFn::Shr,
        8 => AluFn::Min,
        9 => AluFn::Max,
        _ => return None,
    })
}

fn cond_bits(c: Cond) -> u8 {
    match c {
        Cond::Eq => 0,
        Cond::Ne => 1,
        Cond::Lt => 2,
        Cond::Ge => 3,
    }
}

fn cond_from(b: u8) -> Option<Cond> {
    Some(match b {
        0 => Cond::Eq,
        1 => Cond::Ne,
        2 => Cond::Lt,
        3 => Cond::Ge,
        _ => return None,
    })
}

fn csr_bits(c: Csr) -> u8 {
    match c {
        Csr::FracShift => 0,
        Csr::RoundMode => 1,
        Csr::GateBits => 2,
        Csr::LbStride => 3,
    }
}

fn csr_from(b: u8) -> Option<Csr> {
    Some(match b {
        0 => Csr::FracShift,
        1 => Csr::RoundMode,
        2 => Csr::GateBits,
        3 => Csr::LbStride,
        _ => return None,
    })
}

/// Addr packs: a=base, imm = offset (low 24, sign-extended) | post_inc
/// (high 8, as multiples of 2 bytes, signed). Offsets are byte values.
fn addr_pack(a: Addr) -> Result<(u8, u32), EncodeError> {
    let off = a.offset;
    if !(-(1 << 23)..(1 << 23)).contains(&off) {
        return Err(EncodeError::Range("addr offset"));
    }
    let pi = a.post_inc / 2;
    if a.post_inc % 2 != 0 || !(-128..128).contains(&pi) {
        return Err(EncodeError::Range("post-increment"));
    }
    let imm = (off as u32 & 0x00FF_FFFF) | ((pi as i8 as u8 as u32) << 24);
    Ok((a.base.0, imm))
}

fn addr_unpack(base: u8, imm: u32) -> Addr {
    let off = ((imm & 0x00FF_FFFF) as i32) << 8 >> 8; // sign-extend 24 bits
    let pi = ((imm >> 24) as u8 as i8 as i32) * 2;
    Addr { base: SReg(base), offset: off, post_inc: pi }
}

pub fn encode_slot0(op: &SlotOp) -> Result<u64, EncodeError> {
    Ok(match *op {
        SlotOp::Nop => pack(OP_NOP, 0, 0, 0, 0),
        SlotOp::Li { rd, imm } => pack(OP_LI, rd.0, 0, 0, imm as u32),
        SlotOp::Alu { f, w, rd, ra, rb } => pack(
            OP_ALU,
            rd.0,
            ra.0,
            rb.0,
            alu_bits(f) as u32 | ((w == Width::W16) as u32) << 8,
        ),
        SlotOp::AluI { f, w, rd, ra, imm } => {
            if !(-(1 << 15)..(1 << 15)).contains(&imm) {
                return Err(EncodeError::Range("alui imm"));
            }
            pack(
                OP_ALUI,
                rd.0,
                ra.0,
                alu_bits(f) | ((w == Width::W16) as u8) << 7,
                (imm as u16) as u32,
            )
        }
        SlotOp::Br { c, ra, rb, target } => pack(OP_BR, cond_bits(c), ra.0, rb.0, target),
        SlotOp::Jmp { target } => pack(OP_JMP, 0, 0, 0, target),
        SlotOp::Loop { n, body } => pack(OP_LOOP, n.0, 0, 0, body as u32),
        SlotOp::LoopI { n, body } => {
            if n >= 1 << 16 {
                return Err(EncodeError::Range("loopi count"));
            }
            pack(OP_LOOPI, 0, 0, 0, n << 16 | body as u32)
        }
        SlotOp::Halt => pack(OP_HALT, 0, 0, 0, 0),
        SlotOp::Csrwi { csr, imm } => pack(OP_CSRWI, csr_bits(csr), 0, 0, imm),
        SlotOp::Csrw { csr, rs } => pack(OP_CSRW, csr_bits(csr), rs.0, 0, 0),
        SlotOp::LdS { rd, addr } => {
            let (b, imm) = addr_pack(addr)?;
            pack(OP_LDS, rd.0, b, 0, imm)
        }
        SlotOp::StS { rs, addr } => {
            let (b, imm) = addr_pack(addr)?;
            pack(OP_STS, rs.0, b, 0, imm)
        }
        SlotOp::LdV { vd, addr } => {
            let (b, imm) = addr_pack(addr)?;
            pack(OP_LDV, vd.0, b, 0, imm)
        }
        SlotOp::StV { vs, addr } => {
            let (b, imm) = addr_pack(addr)?;
            pack(OP_STV, vs.0, b, 0, imm)
        }
        SlotOp::LdA { ad, addr } => {
            let (b, imm) = addr_pack(addr)?;
            pack(OP_LDA, ad.0, b, 0, imm)
        }
        SlotOp::StA { as_, addr } => {
            let (b, imm) = addr_pack(addr)?;
            pack(OP_STA, as_.0, b, 0, imm)
        }
        SlotOp::DmaLoad { ch, ext, dm, len } => pack(OP_DMAL, ch, ext.0, dm.0, len.0 as u32),
        SlotOp::DmaStore { ch, ext, dm, len } => pack(OP_DMAS, ch, ext.0, dm.0, len.0 as u32),
        SlotOp::DmaWait { ch } => pack(OP_DMAW, ch, 0, 0, 0),
        SlotOp::LbLoad { row, dm, off, win, nrows, rstride } => {
            if row > 3 || nrows > 15 || win > 64 {
                return Err(EncodeError::Range("lbld fields"));
            }
            // a = row(2) | nrows(4)<<2 ; c = win ; imm = off | rstride<<16
            pack(
                OP_LBLD,
                row | nrows << 2,
                dm.0,
                win,
                off as u32 | (rstride as u32) << 16,
            )
        }
        SlotOp::LdVF { addr } => {
            let (b, imm) = addr_pack(addr)?;
            pack(OP_LDVF, 0, b, 0, imm)
        }
    })
}

fn asrc_pack(a: ASrc) -> (u8, u16) {
    match a {
        // off in [9:0], row in [11:10]
        ASrc::Lb { row, off } => (0, (off & 0x3FF) | (row as u16 & 0x3) << 10),
        ASrc::VrBcast { vr, base, step } => (1, vr.0 as u16 | (base as u16) << 4 | (step as u16) << 9),
        ASrc::VrQuad { vr } => (2, vr.0 as u16),
        ASrc::LbVec { row, off } => (3, (off & 0x3FF) | (row as u16 & 0x3) << 10),
    }
}

fn asrc_unpack(tag: u8, v: u16) -> Option<ASrc> {
    Some(match tag {
        0 => ASrc::Lb { row: ((v >> 10) & 0x3) as u8, off: v & 0x3FF },
        1 => ASrc::VrBcast {
            vr: VReg((v & 0xF) as u8),
            base: ((v >> 4) & 0x1F) as u8,
            step: ((v >> 9) & 0x7F) as u8,
        },
        2 => ASrc::VrQuad { vr: VReg((v & 0xF) as u8) },
        3 => ASrc::LbVec { row: ((v >> 10) & 0x3) as u8, off: v & 0x3FF },
        _ => return None,
    })
}

fn bsrc_pack(b: BSrc) -> (u8, u16) {
    match b {
        BSrc::Vr { vr } => (0, vr.0 as u16),
        BSrc::VrLane { vr, lane } => (1, vr.0 as u16 | (lane as u16) << 4),
        BSrc::VrQuad { vr } => (2, vr.0 as u16),
        BSrc::VrLaneQuad { vr, base } => (3, vr.0 as u16 | (base as u16) << 4),
        BSrc::Fifo => (4, 0),
        BSrc::FifoLaneQuad { base } => (5, base as u16),
    }
}

fn bsrc_unpack(tag: u8, v: u16) -> Option<BSrc> {
    Some(match tag {
        0 => BSrc::Vr { vr: VReg((v & 0xF) as u8) },
        1 => BSrc::VrLane { vr: VReg((v & 0xF) as u8), lane: ((v >> 4) & 0xF) as u8 },
        2 => BSrc::VrQuad { vr: VReg((v & 0xF) as u8) },
        3 => BSrc::VrLaneQuad { vr: VReg((v & 0xF) as u8), base: ((v >> 4) & 0xF) as u8 },
        4 => BSrc::Fifo,
        5 => BSrc::FifoLaneQuad { base: (v & 0xF) as u8 },
        _ => return None,
    })
}

fn vfn_bits(f: VFn) -> u8 {
    match f {
        VFn::Add => 0,
        VFn::Sub => 1,
        VFn::Mul => 2,
        VFn::Max => 3,
        VFn::Min => 4,
        VFn::Shl => 5,
        VFn::Shr => 6,
    }
}

fn vfn_from(b: u8) -> Option<VFn> {
    Some(match b {
        0 => VFn::Add,
        1 => VFn::Sub,
        2 => VFn::Mul,
        3 => VFn::Max,
        4 => VFn::Min,
        5 => VFn::Shl,
        6 => VFn::Shr,
        _ => return None,
    })
}

pub fn encode_vec(op: &VecOp) -> Result<u64, EncodeError> {
    Ok(match *op {
        VecOp::Nop => pack(OP_VNOP, 0, 0, 0, 0),
        VecOp::Mac { a, b } => {
            let (at, av) = asrc_pack(a);
            let (bt, bv) = bsrc_pack(b);
            pack(OP_VMAC, at, bt, 0, av as u32 | (bv as u32) << 16)
        }
        VecOp::Mul { a, b } => {
            let (at, av) = asrc_pack(a);
            let (bt, bv) = bsrc_pack(b);
            pack(OP_VMUL, at, bt, 0, av as u32 | (bv as u32) << 16)
        }
        VecOp::ClrA { only } => pack(OP_VCLRA, only.map_or(0xFF, |j| j), 0, 0, 0),
        VecOp::InitA { vr } => pack(OP_VINITA, vr.0, 0, 0, 0),
        VecOp::InitALane { vr, base } => pack(OP_VINITAL, vr.0, base, 0, 0),
        VecOp::QMov { vd, j, relu } => pack(OP_VQMOV, vd.0, j, relu as u8, 0),
        VecOp::EOp { f, vd, va, vb } => pack(OP_VEOP, vd.0, va.0, vb.0, vfn_bits(f) as u32),
        VecOp::EOpI { f, vd, va, imm } => {
            pack(OP_VEOPI, vd.0, va.0, vfn_bits(f), (imm as u16) as u32)
        }
        VecOp::Mov { vd, vs } => pack(OP_VMOV, vd.0, vs.0, 0, 0),
        VecOp::Bcst { vd, vs, lane } => pack(OP_VBCST, vd.0, vs.0, lane, 0),
        VecOp::Relu { vd, vs } => pack(OP_VRELU, vd.0, vs.0, 0, 0),
        VecOp::PoolMax { vd, va, vb } => pack(OP_VPOOLMAX, vd.0, va.0, vb.0, 0),
    })
}

pub fn decode_slot0(w: u64, idx: usize) -> Result<SlotOp, EncodeError> {
    let (op, a, b, c, imm) = un(w);
    let bad = || EncodeError::BadOpcode(op, idx);
    Ok(match op {
        OP_NOP => SlotOp::Nop,
        OP_LI => SlotOp::Li { rd: SReg(a), imm: imm as i32 },
        OP_ALU => SlotOp::Alu {
            f: alu_from((imm & 0xFF) as u8).ok_or_else(bad)?,
            w: if imm >> 8 & 1 == 1 { Width::W16 } else { Width::W32 },
            rd: SReg(a),
            ra: SReg(b),
            rb: SReg(c),
        },
        OP_ALUI => SlotOp::AluI {
            f: alu_from(c & 0x7F).ok_or_else(bad)?,
            w: if c >> 7 == 1 { Width::W16 } else { Width::W32 },
            rd: SReg(a),
            ra: SReg(b),
            imm: imm as u16 as i16 as i32,
        },
        OP_BR => SlotOp::Br {
            c: cond_from(a).ok_or_else(bad)?,
            ra: SReg(b),
            rb: SReg(c),
            target: imm,
        },
        OP_JMP => SlotOp::Jmp { target: imm },
        OP_LOOP => SlotOp::Loop { n: SReg(a), body: imm as u16 },
        OP_LOOPI => SlotOp::LoopI { n: imm >> 16, body: (imm & 0xFFFF) as u16 },
        OP_HALT => SlotOp::Halt,
        OP_CSRWI => SlotOp::Csrwi { csr: csr_from(a).ok_or_else(bad)?, imm },
        OP_CSRW => SlotOp::Csrw { csr: csr_from(a).ok_or_else(bad)?, rs: SReg(b) },
        OP_LDS => SlotOp::LdS { rd: SReg(a), addr: addr_unpack(b, imm) },
        OP_STS => SlotOp::StS { rs: SReg(a), addr: addr_unpack(b, imm) },
        OP_LDV => SlotOp::LdV { vd: VReg(a), addr: addr_unpack(b, imm) },
        OP_STV => SlotOp::StV { vs: VReg(a), addr: addr_unpack(b, imm) },
        OP_LDA => SlotOp::LdA { ad: VAcc(a), addr: addr_unpack(b, imm) },
        OP_STA => SlotOp::StA { as_: VAcc(a), addr: addr_unpack(b, imm) },
        OP_DMAL => SlotOp::DmaLoad { ch: a, ext: SReg(b), dm: SReg(c), len: SReg(imm as u8) },
        OP_DMAS => SlotOp::DmaStore { ch: a, ext: SReg(b), dm: SReg(c), len: SReg(imm as u8) },
        OP_DMAW => SlotOp::DmaWait { ch: a },
        OP_LBLD => SlotOp::LbLoad {
            row: a & 0x3,
            nrows: a >> 2,
            dm: SReg(b),
            win: c,
            off: imm as u16,
            rstride: (imm >> 16) as u16,
        },
        OP_LDVF => SlotOp::LdVF { addr: addr_unpack(b, imm) },
        _ => return Err(bad()),
    })
}

pub fn decode_vec(w: u64, idx: usize) -> Result<VecOp, EncodeError> {
    let (op, a, b, c, imm) = un(w);
    let bad = || EncodeError::BadOpcode(op, idx);
    Ok(match op {
        OP_VNOP => VecOp::Nop,
        OP_VMAC | OP_VMUL => {
            let asrc = asrc_unpack(a, (imm & 0xFFFF) as u16).ok_or_else(bad)?;
            let bsrc = bsrc_unpack(b, (imm >> 16) as u16).ok_or_else(bad)?;
            if op == OP_VMAC {
                VecOp::Mac { a: asrc, b: bsrc }
            } else {
                VecOp::Mul { a: asrc, b: bsrc }
            }
        }
        OP_VCLRA => VecOp::ClrA { only: if a == 0xFF { None } else { Some(a) } },
        OP_VINITA => VecOp::InitA { vr: VReg(a) },
        OP_VINITAL => VecOp::InitALane { vr: VReg(a), base: b },
        OP_VQMOV => VecOp::QMov { vd: VReg(a), j: b, relu: c != 0 },
        OP_VEOP => VecOp::EOp {
            f: vfn_from(imm as u8).ok_or_else(bad)?,
            vd: VReg(a),
            va: VReg(b),
            vb: VReg(c),
        },
        OP_VEOPI => VecOp::EOpI {
            f: vfn_from(c).ok_or_else(bad)?,
            vd: VReg(a),
            va: VReg(b),
            imm: imm as u16 as i16,
        },
        OP_VMOV => VecOp::Mov { vd: VReg(a), vs: VReg(b) },
        OP_VBCST => VecOp::Bcst { vd: VReg(a), vs: VReg(b), lane: c },
        OP_VRELU => VecOp::Relu { vd: VReg(a), vs: VReg(b) },
        OP_VPOOLMAX => VecOp::PoolMax { vd: VReg(a), va: VReg(b), vb: VReg(c) },
        _ => return Err(bad()),
    })
}

/// Encode a whole program to bytes (little-endian words).
pub fn encode_program(p: &Program) -> Result<Vec<u8>, EncodeError> {
    let mut out = Vec::with_capacity(p.bundles.len() * BUNDLE_BYTES);
    for b in &p.bundles {
        out.extend_from_slice(&encode_slot0(&b.slot0)?.to_le_bytes());
        for v in &b.v {
            out.extend_from_slice(&encode_vec(v)?.to_le_bytes());
        }
    }
    Ok(out)
}

/// Decode a program from bytes.
pub fn decode_program(bytes: &[u8]) -> Result<Program, EncodeError> {
    if bytes.len() % BUNDLE_BYTES != 0 {
        return Err(EncodeError::Truncated(bytes.len()));
    }
    let mut bundles = Vec::with_capacity(bytes.len() / BUNDLE_BYTES);
    for (i, chunk) in bytes.chunks_exact(BUNDLE_BYTES).enumerate() {
        let w = |k: usize| u64::from_le_bytes(chunk[k * 8..(k + 1) * 8].try_into().unwrap());
        bundles.push(Bundle {
            slot0: decode_slot0(w(0), i)?,
            v: [
                decode_vec(w(1), i)?,
                decode_vec(w(2), i)?,
                decode_vec(w(3), i)?,
            ],
        });
    }
    Ok(Program { bundles })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{prop, Gen};

    fn arb_slot0(g: &mut Gen) -> SlotOp {
        match g.int(0, 13) {
            0 => SlotOp::Nop,
            1 => SlotOp::Li { rd: SReg(g.usize_in(0, 31) as u8), imm: g.int(i32::MIN as i64, i32::MAX as i64) as i32 },
            2 => SlotOp::Alu {
                f: *g.pick(&[AluFn::Add, AluFn::Sub, AluFn::Mul, AluFn::Shr, AluFn::Max]),
                w: if g.bool() { Width::W16 } else { Width::W32 },
                rd: SReg(g.usize_in(0, 31) as u8),
                ra: SReg(g.usize_in(0, 31) as u8),
                rb: SReg(g.usize_in(0, 31) as u8),
            },
            3 => SlotOp::AluI {
                f: *g.pick(&[AluFn::Add, AluFn::And, AluFn::Shl]),
                w: Width::W32,
                rd: SReg(g.usize_in(0, 31) as u8),
                ra: SReg(g.usize_in(0, 31) as u8),
                imm: g.int(-32768, 32767) as i32,
            },
            4 => SlotOp::Br {
                c: *g.pick(&[Cond::Eq, Cond::Ne, Cond::Lt, Cond::Ge]),
                ra: SReg(g.usize_in(0, 31) as u8),
                rb: SReg(g.usize_in(0, 31) as u8),
                target: g.int(0, 511) as u32,
            },
            5 => SlotOp::LdV {
                vd: VReg(g.usize_in(0, 15) as u8),
                addr: Addr {
                    base: SReg(g.usize_in(0, 31) as u8),
                    offset: g.int(-100000, 100000) as i32,
                    post_inc: g.int(-60, 60) as i32 * 2,
                },
            },
            6 => SlotOp::StA {
                as_: VAcc(g.usize_in(0, 11) as u8),
                addr: Addr::offs(SReg(1), g.int(0, 4000) as i32),
            },
            7 => SlotOp::LoopI { n: g.int(1, 65535) as u32, body: g.int(1, 400) as u16 },
            8 => SlotOp::Csrwi { csr: *g.pick(&[Csr::FracShift, Csr::RoundMode, Csr::GateBits, Csr::LbStride]), imm: g.int(0, 31) as u32 },
            9 => SlotOp::DmaLoad { ch: g.int(0, 1) as u8, ext: SReg(1), dm: SReg(2), len: SReg(3) },
            10 => SlotOp::LbLoad {
                row: g.int(0, 3) as u8,
                dm: SReg(g.usize_in(0, 31) as u8),
                off: g.int(0, 60000) as u16,
                win: g.int(1, 60) as u8,
                nrows: g.int(1, 11) as u8,
                rstride: g.int(2, 1000) as u16,
            },
            13 => SlotOp::LdVF {
                addr: Addr {
                    base: SReg(g.usize_in(0, 31) as u8),
                    offset: g.int(-4096, 4096) as i32,
                    post_inc: g.int(-16, 16) as i32 * 2,
                },
            },
            11 => SlotOp::Halt,
            _ => SlotOp::DmaWait { ch: g.int(0, 1) as u8 },
        }
    }

    fn arb_vec(g: &mut Gen) -> VecOp {
        match g.int(0, 8) {
            0 => VecOp::Nop,
            1 => VecOp::Mac {
                a: ASrc::Lb { row: g.int(0, 3) as u8, off: g.int(0, 1023) as u16 },
                b: BSrc::Vr { vr: VReg(g.usize_in(0, 15) as u8) },
            },
            2 => VecOp::Mac {
                a: match g.int(0, 2) {
                    0 => ASrc::VrBcast { vr: VReg(g.usize_in(0, 15) as u8), base: g.int(0, 15) as u8, step: g.int(0, 7) as u8 },
                    1 => ASrc::LbVec { row: g.int(0, 3) as u8, off: g.int(0, 1023) as u16 },
                    _ => ASrc::Lb { row: g.int(0, 3) as u8, off: g.int(0, 1023) as u16 },
                },
                b: match g.int(0, 3) {
                    0 => BSrc::VrLane { vr: VReg(g.usize_in(0, 15) as u8), lane: g.int(0, 15) as u8 },
                    1 => BSrc::Fifo,
                    2 => BSrc::FifoLaneQuad { base: g.int(0, 12) as u8 },
                    _ => BSrc::VrLaneQuad { vr: VReg(g.usize_in(0, 15) as u8), base: g.int(0, 12) as u8 },
                },
            },
            3 => VecOp::QMov { vd: VReg(g.usize_in(0, 15) as u8), j: g.int(0, 3) as u8, relu: g.bool() },
            4 => VecOp::EOp {
                f: *g.pick(&[VFn::Add, VFn::Sub, VFn::Max, VFn::Shr]),
                vd: VReg(g.usize_in(0, 15) as u8),
                va: VReg(g.usize_in(0, 15) as u8),
                vb: VReg(g.usize_in(0, 15) as u8),
            },
            5 => VecOp::InitA { vr: VReg(g.usize_in(0, 15) as u8) },
            6 => VecOp::ClrA { only: if g.bool() { None } else { Some(g.int(0, 3) as u8) } },
            7 => VecOp::Mul {
                a: ASrc::VrQuad { vr: VReg(g.usize_in(0, 12) as u8) },
                b: BSrc::VrQuad { vr: VReg(g.usize_in(0, 12) as u8) },
            },
            _ => VecOp::PoolMax {
                vd: VReg(g.usize_in(0, 15) as u8),
                va: VReg(g.usize_in(0, 15) as u8),
                vb: VReg(g.usize_in(0, 15) as u8),
            },
        }
    }

    #[test]
    fn roundtrip_slot0_property() {
        prop("slot0 encode/decode roundtrip", 300, |g| {
            let op = arb_slot0(g);
            let w = encode_slot0(&op).unwrap();
            let back = decode_slot0(w, 0).unwrap();
            assert_eq!(op, back, "word {w:#018x}");
        });
    }

    #[test]
    fn roundtrip_vec_property() {
        prop("vec encode/decode roundtrip", 300, |g| {
            let op = arb_vec(g);
            let w = encode_vec(&op).unwrap();
            let back = decode_vec(w, 0).unwrap();
            assert_eq!(op, back, "word {w:#018x}");
        });
    }

    #[test]
    fn roundtrip_program() {
        prop("program roundtrip", 30, |g| {
            let n = g.usize_in(1, 40);
            let mut p = Program::default();
            for _ in 0..n {
                p.bundles.push(Bundle {
                    slot0: arb_slot0(g),
                    v: [arb_vec(g), arb_vec(g), arb_vec(g)],
                });
            }
            let bytes = encode_program(&p).unwrap();
            assert_eq!(bytes.len(), p.encoded_size());
            let back = decode_program(&bytes).unwrap();
            assert_eq!(p.bundles, back.bundles);
        });
    }

    #[test]
    fn rejects_bad_opcode() {
        assert!(decode_slot0(0xFFu64, 0).is_err());
        assert!(decode_vec(0x70u64, 0).is_err());
    }

    #[test]
    fn rejects_truncated() {
        assert_eq!(
            decode_program(&[0u8; 7]),
            Err(EncodeError::Truncated(7))
        );
    }

    #[test]
    fn addr_negative_offset_roundtrip() {
        let a = Addr { base: SReg(3), offset: -4096, post_inc: -32 };
        let op = SlotOp::LdV { vd: VReg(2), addr: a };
        let back = decode_slot0(encode_slot0(&op).unwrap(), 0).unwrap();
        assert_eq!(op, back);
    }

    #[test]
    fn odd_post_inc_rejected() {
        let op = SlotOp::LdV { vd: VReg(0), addr: Addr { base: SReg(0), offset: 0, post_inc: 3 } };
        assert!(encode_slot0(&op).is_err());
    }

    #[test]
    fn pm_capacity_is_512_bundles() {
        assert_eq!(16 * 1024 / BUNDLE_BYTES, 512);
    }

    // ---- exhaustive round-trip: every variant, re-encode identical -----
    //
    // The generators above sample the common ops; these cover every
    // `SlotOp`/`VecOp` variant and every `ASrc`/`BSrc` addressing mode
    // at the encoding's full legal field ranges, and additionally check
    // that re-encoding the decoded op reproduces the exact word — i.e.
    // the encoding has no don't-care bits that decode forgets.

    const ALL_ALU: [AluFn; 10] = [
        AluFn::Add,
        AluFn::Sub,
        AluFn::Mul,
        AluFn::And,
        AluFn::Or,
        AluFn::Xor,
        AluFn::Shl,
        AluFn::Shr,
        AluFn::Min,
        AluFn::Max,
    ];
    const ALL_VFN: [VFn; 7] =
        [VFn::Add, VFn::Sub, VFn::Mul, VFn::Max, VFn::Min, VFn::Shl, VFn::Shr];
    const ALL_COND: [Cond; 4] = [Cond::Eq, Cond::Ne, Cond::Lt, Cond::Ge];
    const ALL_CSR: [Csr; 4] = [Csr::FracShift, Csr::RoundMode, Csr::GateBits, Csr::LbStride];

    /// Full legal `Addr` range: 24-bit signed offset, even post-inc
    /// whose half fits i8.
    fn arb_addr(g: &mut Gen) -> Addr {
        Addr {
            base: SReg(g.usize_in(0, 31) as u8),
            offset: g.int(-(1 << 23), (1 << 23) - 1) as i32,
            post_inc: g.int(-128, 127) as i32 * 2,
        }
    }

    fn arb_sreg(g: &mut Gen) -> SReg {
        SReg(g.usize_in(0, 31) as u8)
    }

    fn arb_vreg(g: &mut Gen) -> VReg {
        VReg(g.usize_in(0, 15) as u8)
    }

    fn arb_slot0_exhaustive(g: &mut Gen) -> SlotOp {
        match g.int(0, 21) {
            0 => SlotOp::Nop,
            1 => SlotOp::Li {
                rd: arb_sreg(g),
                imm: g.int(i32::MIN as i64, i32::MAX as i64) as i32,
            },
            2 => SlotOp::Alu {
                f: *g.pick(&ALL_ALU),
                w: if g.bool() { Width::W16 } else { Width::W32 },
                rd: arb_sreg(g),
                ra: arb_sreg(g),
                rb: arb_sreg(g),
            },
            3 => SlotOp::AluI {
                f: *g.pick(&ALL_ALU),
                w: if g.bool() { Width::W16 } else { Width::W32 },
                rd: arb_sreg(g),
                ra: arb_sreg(g),
                imm: g.int(-32768, 32767) as i32,
            },
            4 => SlotOp::Br {
                c: *g.pick(&ALL_COND),
                ra: arb_sreg(g),
                rb: arb_sreg(g),
                target: g.int(0, u32::MAX as i64) as u32,
            },
            5 => SlotOp::Jmp { target: g.int(0, u32::MAX as i64) as u32 },
            6 => SlotOp::Loop { n: arb_sreg(g), body: g.int(0, 65535) as u16 },
            7 => SlotOp::LoopI { n: g.int(0, 65535) as u32, body: g.int(0, 65535) as u16 },
            8 => SlotOp::Halt,
            9 => SlotOp::Csrwi {
                csr: *g.pick(&ALL_CSR),
                imm: g.int(0, u32::MAX as i64) as u32,
            },
            10 => SlotOp::Csrw { csr: *g.pick(&ALL_CSR), rs: arb_sreg(g) },
            11 => SlotOp::LdS { rd: arb_sreg(g), addr: arb_addr(g) },
            12 => SlotOp::StS { rs: arb_sreg(g), addr: arb_addr(g) },
            13 => SlotOp::LdV { vd: arb_vreg(g), addr: arb_addr(g) },
            14 => SlotOp::StV { vs: arb_vreg(g), addr: arb_addr(g) },
            15 => SlotOp::LdA { ad: VAcc(g.usize_in(0, 11) as u8), addr: arb_addr(g) },
            16 => SlotOp::StA { as_: VAcc(g.usize_in(0, 11) as u8), addr: arb_addr(g) },
            17 => SlotOp::DmaLoad {
                ch: g.int(0, 255) as u8,
                ext: arb_sreg(g),
                dm: arb_sreg(g),
                len: arb_sreg(g),
            },
            18 => SlotOp::DmaStore {
                ch: g.int(0, 255) as u8,
                ext: arb_sreg(g),
                dm: arb_sreg(g),
                len: arb_sreg(g),
            },
            19 => SlotOp::DmaWait { ch: g.int(0, 255) as u8 },
            20 => SlotOp::LbLoad {
                row: g.int(0, 3) as u8,
                dm: arb_sreg(g),
                off: g.int(0, 65535) as u16,
                win: g.int(0, 64) as u8,
                nrows: g.int(0, 15) as u8,
                rstride: g.int(0, 65535) as u16,
            },
            _ => SlotOp::LdVF { addr: arb_addr(g) },
        }
    }

    fn arb_asrc(g: &mut Gen) -> ASrc {
        match g.int(0, 3) {
            0 => ASrc::Lb { row: g.int(0, 3) as u8, off: g.int(0, 1023) as u16 },
            1 => ASrc::VrBcast {
                vr: arb_vreg(g),
                base: g.int(0, 31) as u8,
                step: g.int(0, 127) as u8,
            },
            2 => ASrc::VrQuad { vr: arb_vreg(g) },
            _ => ASrc::LbVec { row: g.int(0, 3) as u8, off: g.int(0, 1023) as u16 },
        }
    }

    fn arb_bsrc(g: &mut Gen) -> BSrc {
        match g.int(0, 5) {
            0 => BSrc::Vr { vr: arb_vreg(g) },
            1 => BSrc::VrLane { vr: arb_vreg(g), lane: g.int(0, 15) as u8 },
            2 => BSrc::VrQuad { vr: arb_vreg(g) },
            3 => BSrc::VrLaneQuad { vr: arb_vreg(g), base: g.int(0, 15) as u8 },
            4 => BSrc::Fifo,
            _ => BSrc::FifoLaneQuad { base: g.int(0, 15) as u8 },
        }
    }

    fn arb_vec_exhaustive(g: &mut Gen) -> VecOp {
        match g.int(0, 12) {
            0 => VecOp::Nop,
            1 => VecOp::Mac { a: arb_asrc(g), b: arb_bsrc(g) },
            2 => VecOp::Mul { a: arb_asrc(g), b: arb_bsrc(g) },
            // 0xFF is the encoding's None sentinel — Some(0xFF) is not
            // representable, everything below it is
            3 => VecOp::ClrA {
                only: if g.bool() { None } else { Some(g.int(0, 254) as u8) },
            },
            4 => VecOp::InitA { vr: arb_vreg(g) },
            5 => VecOp::InitALane { vr: arb_vreg(g), base: g.int(0, 255) as u8 },
            6 => VecOp::QMov { vd: arb_vreg(g), j: g.int(0, 255) as u8, relu: g.bool() },
            7 => VecOp::EOp {
                f: *g.pick(&ALL_VFN),
                vd: arb_vreg(g),
                va: arb_vreg(g),
                vb: arb_vreg(g),
            },
            8 => VecOp::EOpI {
                f: *g.pick(&ALL_VFN),
                vd: arb_vreg(g),
                va: arb_vreg(g),
                imm: g.int(-32768, 32767) as i16,
            },
            9 => VecOp::Mov { vd: arb_vreg(g), vs: arb_vreg(g) },
            10 => VecOp::Bcst { vd: arb_vreg(g), vs: arb_vreg(g), lane: g.int(0, 255) as u8 },
            11 => VecOp::Relu { vd: arb_vreg(g), vs: arb_vreg(g) },
            _ => VecOp::PoolMax { vd: arb_vreg(g), va: arb_vreg(g), vb: arb_vreg(g) },
        }
    }

    #[test]
    fn exhaustive_slot0_roundtrip_and_reencode() {
        prop("slot0 exhaustive roundtrip + reencode", 2000, |g| {
            let op = arb_slot0_exhaustive(g);
            let w = encode_slot0(&op).unwrap();
            let back = decode_slot0(w, 0).unwrap();
            assert_eq!(op, back, "decode mismatch for word {w:#018x}");
            assert_eq!(
                encode_slot0(&back).unwrap(),
                w,
                "re-encode of {back:?} not byte-identical"
            );
        });
    }

    #[test]
    fn exhaustive_vec_roundtrip_and_reencode() {
        prop("vec exhaustive roundtrip + reencode", 2000, |g| {
            let op = arb_vec_exhaustive(g);
            let w = encode_vec(&op).unwrap();
            let back = decode_vec(w, 0).unwrap();
            assert_eq!(op, back, "decode mismatch for word {w:#018x}");
            assert_eq!(
                encode_vec(&back).unwrap(),
                w,
                "re-encode of {back:?} not byte-identical"
            );
        });
    }

    #[test]
    fn exhaustive_program_bytes_reencode_identical() {
        prop("program bytes stable under decode/encode", 40, |g| {
            let n = g.usize_in(1, 64);
            let mut p = Program::default();
            for _ in 0..n {
                p.bundles.push(Bundle {
                    slot0: arb_slot0_exhaustive(g),
                    v: [arb_vec_exhaustive(g), arb_vec_exhaustive(g), arb_vec_exhaustive(g)],
                });
            }
            let bytes = encode_program(&p).unwrap();
            let back = decode_program(&bytes).unwrap();
            assert_eq!(p.bundles, back.bundles);
            assert_eq!(
                encode_program(&back).unwrap(),
                bytes,
                "program bytes must be a decode/encode fixpoint"
            );
        });
    }

    #[test]
    fn clra_some_ff_is_unrepresentable_by_design() {
        // `only: Some(0xFF)` collides with the None sentinel; the
        // encoder maps it to None rather than erroring (no generated
        // program clears a single accumulator index 255 — there are 12).
        let w = encode_vec(&VecOp::ClrA { only: Some(0xFF) }).unwrap();
        assert_eq!(decode_vec(w, 0).unwrap(), VecOp::ClrA { only: None });
    }
}
