//! The ConvAix instruction set (Section IV of the paper).
//!
//! A VLIW bundle has **4 heterogeneous issue slots**:
//!
//! * **slot 0** — control flow, scalar ALU (16-bit, with a 32-bit
//!   addressing datapath), loads/stores between DM and the register
//!   files, DMA and line-buffer control, CSR writes.
//! * **slots 1–3** — one vector ALU each: 4 slices × 16 lanes of 16-bit
//!   MACs accumulating into the slot's private VRl sub-region. Slot 1
//!   additionally hosts the SFU (activation / max-pooling) operating on
//!   single 16-lane vectors.
//!
//! Register files (with the paper's sub-region port constraints):
//!
//! * `R`   — 32 scalar registers (32-bit storage; 16-bit ops wrap).
//! * `VR`  — 16 × 256 b (16 lanes × i16), sliced into VR0..VR3 of 4
//!   entries each. **VR0 is readable by every vALU** (shared operands,
//!   e.g. filter vectors); VR`s` is private to vALU `s`; slot 0 accesses
//!   everything (data movement, load/store).
//! * `VRl` — 12 × 512 b (16 lanes × i32), sliced into VRl0..VRl2; vALU
//!   `s` owns VRl`s-1` (its 4 slice accumulators); slot 0 may spill/fill
//!   any entry.
//!
//! The *line buffer* is an architecturally visible row register: vector
//! MAC operands can be sourced directly from it with a per-instruction
//! pixel offset; the LB applies the configured stride per slice. This is
//! how "possibly strided inputs" reach the vALUs with zero slot-0 cost
//! (the paper's Section IV).

pub mod analysis;
pub mod asm;
pub mod disasm;
pub mod encode;

use std::fmt;

/// Scalar register index (R0..R31). R0 is *not* hardwired to zero.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SReg(pub u8);

/// Vector register index (VR 0..15). Sub-region = index / 4.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct VReg(pub u8);

/// Wide accumulator register index (VRl 0..11). Sub-region = index / 4.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct VAcc(pub u8);

impl SReg {
    pub const COUNT: u8 = 32;
}
impl VReg {
    pub const COUNT: u8 = 16;
    /// Sub-region VR0..VR3 this entry belongs to.
    pub fn region(self) -> u8 {
        self.0 / 4
    }
}
impl VAcc {
    pub const COUNT: u8 = 12;
    pub fn region(self) -> u8 {
        self.0 / 4
    }
}

/// Vector lane count per slice (and per VR entry).
pub const LANES: usize = 16;
/// Slices per vector ALU.
pub const SLICES: usize = 4;
/// Number of vector ALU issue slots (slots 1..=3).
pub const VALU_SLOTS: usize = 3;

/// Control/status registers (runtime-configurable datapath settings,
/// Section IV: "the rounding-scheme as well as the fractional-shift of
/// the vector-ALUs can be configured at runtime").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Csr {
    /// Fractional shift applied by `VQMov` requantization (0..=31).
    FracShift,
    /// Rounding mode (see `fixed::RoundMode::from_bits`).
    RoundMode,
    /// Precision gating: effective operand bits (1..=16).
    GateBits,
    /// Line-buffer stride (input pixels per output-pixel step).
    LbStride,
}

/// Scalar ALU operation width: the paper's slot-0 ALU is 16-bit with an
/// additional 32-bit datapath for large-memory addressing.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Width {
    #[default]
    W32,
    W16,
}

/// Scalar binary ALU function.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AluFn {
    Add,
    Sub,
    Mul,
    And,
    Or,
    Xor,
    Shl,
    Shr, // arithmetic
    Min,
    Max,
}

/// Branch condition (compares two scalar registers).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cond {
    Eq,
    Ne,
    Lt,
    Ge,
}

/// DM addressing for vector/scalar load-store: byte address
/// `R[base] + offset`, with optional post-increment of `R[base]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Addr {
    pub base: SReg,
    pub offset: i32,
    /// Post-increment added to R[base] after the access (0 = none).
    pub post_inc: i32,
}

impl Addr {
    pub fn base(base: SReg) -> Self {
        Self { base, offset: 0, post_inc: 0 }
    }
    pub fn offs(base: SReg, offset: i32) -> Self {
        Self { base, offset, post_inc: 0 }
    }
    pub fn post(base: SReg, post_inc: i32) -> Self {
        Self { base, offset: 0, post_inc }
    }
}

/// Slot-0 operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlotOp {
    Nop,
    /// rd <- imm (the assembler splits large immediates if ever needed;
    /// the simulator models it as a 1-slot op).
    Li { rd: SReg, imm: i32 },
    /// rd <- alu(ra, rb)
    Alu { f: AluFn, w: Width, rd: SReg, ra: SReg, rb: SReg },
    /// rd <- alu(ra, imm)
    AluI { f: AluFn, w: Width, rd: SReg, ra: SReg, imm: i32 },
    /// Conditional branch to absolute bundle index.
    Br { c: Cond, ra: SReg, rb: SReg, target: u32 },
    /// Unconditional jump.
    Jmp { target: u32 },
    /// Zero-overhead hardware loop: repeat the following `body` bundles
    /// `n` times (n from register). One level of nesting is allowed.
    Loop { n: SReg, body: u16 },
    /// Immediate-count hardware loop.
    LoopI { n: u32, body: u16 },
    Halt,
    /// CSR write from immediate.
    Csrwi { csr: Csr, imm: u32 },
    /// CSR write from register.
    Csrw { csr: Csr, rs: SReg },
    /// Scalar load/store (16-bit element, sign-extended).
    LdS { rd: SReg, addr: Addr },
    StS { rs: SReg, addr: Addr },
    /// Vector load/store: one 256-bit DM access (port 0).
    LdV { vd: VReg, addr: Addr },
    StV { vs: VReg, addr: Addr },
    /// Vector load into the **filter FIFO** (depth 8) of the operand
    /// fetch & prepare stage. Vector MACs with a `BSrc::Fifo*` operand
    /// consume entries in order (one pop per bundle, shared by all three
    /// vALU slots — they all see the same filter vector, which is what
    /// both lane mappings need). Decouples filter prefetch from loop
    /// structure so hardware-loop bodies stay static.
    LdVF { addr: Addr },
    /// Accumulator spill/fill: 512 bits = 2 port-0 accesses (occupies
    /// slot 0 for 2 cycles — used when PSums spill per Fig. 2).
    LdA { ad: VAcc, addr: Addr },
    StA { as_: VAcc, addr: Addr },
    /// DMA: start a background transfer on channel `ch` (0/1).
    /// Direction Ext->DM (`DmaLoad`) or DM->Ext (`DmaStore`).
    /// Addresses/length in bytes from scalar registers.
    DmaLoad { ch: u8, ext: SReg, dm: SReg, len: SReg },
    DmaStore { ch: u8, ext: SReg, dm: SReg, len: SReg },
    /// Block until DMA channel `ch` is idle.
    DmaWait { ch: u8 },
    /// Line buffer 2-D window fill: load `nrows` row windows of `win`
    /// pixels each into slot `row` (concatenated), reading row r from DM
    /// byte address `R[dm] + off + r*rstride`. Runs in the background on
    /// DM port 1; a vector op reading that slot before completion
    /// interlocks. `nrows > 1` is the application-specific trick that
    /// lets one slot-0 instruction stage a full FH×window input patch
    /// per input channel — Section IV's "simultaneous loads of new IFMap
    /// row-chunks while providing (possibly strided) inputs".
    LbLoad { row: u8, dm: SReg, off: u16, win: u8, nrows: u8, rstride: u16 },
}

/// Source A of a vector MAC/MUL — what the operand fetch & prepare stage
/// feeds each of the 4 slices.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ASrc {
    /// Line buffer row slot `row`, strided select: slice `j` receives
    /// pixel `off + j*CSR.LbStride` of that row, broadcast to its 16
    /// lanes. (`off` bakes in `fx + slot_pixel_base*stride` — static.)
    /// Lane-mapping **variant A**: lanes = output channels.
    Lb { row: u8, off: u16 },
    /// Line buffer vector read: every slice receives the same 16-lane
    /// vector of pixels `off + l*CSR.LbStride` (l = lane index).
    /// Lane-mapping **variant B**: lanes = output pixels.
    LbVec { row: u8, off: u16 },
    /// One VR entry; slice `j` receives lane `base + j*step` broadcast
    /// to its 16 lanes (the runtime-pattern permute of the paper).
    VrBcast { vr: VReg, base: u8, step: u8 },
    /// Four consecutive VR entries `vr..vr+4`, one per slice, elementwise
    /// (lane-mapping variant B).
    VrQuad { vr: VReg },
}

/// Source B of a vector MAC/MUL.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BSrc {
    /// One VR entry broadcast (as a whole 16-lane vector) to all slices —
    /// the filter vector of lane-mapping variant A.
    Vr { vr: VReg },
    /// Single lane of a VR entry broadcast to all lanes of all slices
    /// (a shared weight scalar).
    VrLane { vr: VReg, lane: u8 },
    /// Slice `j` receives lane `base + j` of one VR entry, broadcast to
    /// its 16 lanes (per-slice weight scalars of lane-mapping variant B:
    /// 4 output channels from one filter vector).
    VrLaneQuad { vr: VReg, base: u8 },
    /// Four consecutive VR entries, one per slice, elementwise.
    VrQuad { vr: VReg },
    /// Front of the filter FIFO as a whole 16-lane vector, broadcast to
    /// all slices (variant A: the 16-OCh filter vector).
    Fifo,
    /// Slice `j` receives lane `base + j` of the filter FIFO front
    /// (variant B: 4 output-channel weights from one filter vector).
    FifoLaneQuad { base: u8 },
}

/// Elementwise vector ALU function (single VR entry, 16 lanes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VFn {
    Add,
    Sub,
    Mul, // low 16 bits
    Max,
    Min,
    Shl,
    Shr,
}

/// Vector-slot operation (slots 1..=3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VecOp {
    Nop,
    /// 4-slice MAC: `VRl[own region][j] += prepare_A(j) * prepare_B(j)`
    /// for j in 0..4 — 64 MACs. Precision gating per CSR.GateBits.
    Mac { a: ASrc, b: BSrc },
    /// Like `Mac` but overwrites the accumulators (acc = a*b).
    Mul { a: ASrc, b: BSrc },
    /// Clear the slot's 4 accumulator entries (j-th if `only`=Some(j)).
    ClrA { only: Option<u8> },
    /// Initialize the slot's 4 accumulators with bias vector `vr`
    /// (each lane sign-extended and shifted left by CSR.FracShift —
    /// `fixed::mac_init`).
    InitA { vr: VReg },
    /// Variant-B bias init: accumulator `j` gets lane `base + j` of `vr`
    /// broadcast to all its lanes, shifted left by CSR.FracShift (one
    /// bias value per output channel; lanes are pixels).
    InitALane { vr: VReg, base: u8 },
    /// Requantize one own-region accumulator entry to a VR entry:
    /// `vd = requant(VRl[own][j])` per CSR (shift, rounding), optional
    /// fused ReLU (SFU path).
    QMov { vd: VReg, j: u8, relu: bool },
    /// Elementwise vector op on 16 lanes: `vd = f(va, vb)`.
    EOp { f: VFn, vd: VReg, va: VReg, vb: VReg },
    /// Elementwise with scalar immediate: `vd = f(va, imm)`.
    EOpI { f: VFn, vd: VReg, va: VReg, imm: i16 },
    /// Move vd <- vs.
    Mov { vd: VReg, vs: VReg },
    /// Broadcast lane `lane` of vs to all lanes of vd.
    Bcst { vd: VReg, vs: VReg, lane: u8 },
    /// SFU (slot 1 only): ReLU on a single vector.
    Relu { vd: VReg, vs: VReg },
    /// SFU (slot 1 only): lane-wise max of two vectors (max-pool step).
    PoolMax { vd: VReg, va: VReg, vb: VReg },
}

/// One VLIW instruction bundle: slot 0 + three vector slots.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Bundle {
    pub slot0: SlotOp,
    pub v: [VecOp; VALU_SLOTS],
}

impl Bundle {
    pub const NOP: Bundle = Bundle {
        slot0: SlotOp::Nop,
        v: [VecOp::Nop, VecOp::Nop, VecOp::Nop],
    };

    pub fn s0(op: SlotOp) -> Bundle {
        Bundle { slot0: op, ..Bundle::NOP }
    }

    pub fn is_nop(&self) -> bool {
        *self == Bundle::NOP
    }

    /// Number of MAC operations this bundle performs at full precision.
    pub fn mac_count(&self) -> u64 {
        self.v
            .iter()
            .map(|op| match op {
                VecOp::Mac { .. } | VecOp::Mul { .. } => (SLICES * LANES) as u64,
                _ => 0,
            })
            .sum()
    }
}

/// A complete program: decoded bundles (what the simulator executes) —
/// the encoded form (see [`encode`]) is what must fit the 16 KB PM.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Program {
    pub bundles: Vec<Bundle>,
}

impl Program {
    pub fn len(&self) -> usize {
        self.bundles.len()
    }
    pub fn is_empty(&self) -> bool {
        self.bundles.is_empty()
    }
    /// Encoded size in bytes (for the PM capacity check).
    pub fn encoded_size(&self) -> usize {
        self.bundles.len() * encode::BUNDLE_BYTES
    }
}

impl fmt::Display for Program {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, b) in self.bundles.iter().enumerate() {
            writeln!(f, "{i:5}: {}", disasm::bundle(b))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regions() {
        assert_eq!(VReg(0).region(), 0);
        assert_eq!(VReg(5).region(), 1);
        assert_eq!(VReg(15).region(), 3);
        assert_eq!(VAcc(11).region(), 2);
    }

    #[test]
    fn bundle_mac_count() {
        let b = Bundle {
            slot0: SlotOp::Nop,
            v: [
                VecOp::Mac { a: ASrc::Lb { row: 0, off: 0 }, b: BSrc::Vr { vr: VReg(0) } },
                VecOp::Mac { a: ASrc::Lb { row: 0, off: 4 }, b: BSrc::Vr { vr: VReg(0) } },
                VecOp::Nop,
            ],
        };
        assert_eq!(b.mac_count(), 128);
        assert_eq!(Bundle::NOP.mac_count(), 0);
    }

    #[test]
    fn peak_bundle_is_192_macs() {
        let m = VecOp::Mac { a: ASrc::Lb { row: 0, off: 0 }, b: BSrc::Vr { vr: VReg(0) } };
        let b = Bundle { slot0: SlotOp::Nop, v: [m, m, m] };
        assert_eq!(b.mac_count(), crate::PEAK_MACS_PER_CYCLE);
    }
}
