//! # ConvAix-rs
//!
//! A cycle-level reproduction of **“An Application-Specific VLIW Processor
//! with Vector Instruction Set for CNN Acceleration”** (Bytyn, Leupers,
//! Ascheid — ISCAS 2019): the *ConvAix* ASIP.
//!
//! The crate contains everything needed to regenerate the paper's
//! evaluation without python at runtime:
//!
//! * [`isa`] — the ConvAix VLIW instruction set (4 issue slots, vector
//!   MAC ops, line-buffer/DMA control), an assembler and a disassembler.
//! * [`core`] — the 8-stage pipeline cycle simulator: scalar ALU, three
//!   4-slice × 16-lane vector ALUs, SFU, register files with sub-region
//!   port constraints, hazard interlocks.
//! * [`mem`] — 16-bank dual-ported data memory, program memory, DMA
//!   engine, IFMap line buffer, external DRAM model.
//! * [`fixed`] — the Q-format 16-bit arithmetic contract shared (bit
//!   exactly) with the JAX/Pallas golden model.
//! * [`codegen`] — the "compiler": generates VLIW kernels for conv /
//!   pooling / FC layers using the Fig. 2 dataflow (depth slicing,
//!   row-wise processing, DMA double buffering), plus the compile-once
//!   layer cache ([`codegen::compiled`]): shape-keyed plans/programs/
//!   analytic profiles and the per-core staging arenas.
//! * [`model`] — AlexNet / VGG-16 workload tables: the paper's conv
//!   stacks and the full end-to-end nets (pools interleaved, fc6/fc7/
//!   fc8 tails with the implicit conv→FC flatten).
//! * [`coordinator`] — the execution [`Engine`](coordinator::Engine):
//!   single- and multi-core layer scheduling (oc-tile / row-band shard
//!   policies, FC neuron tiles, partitioned / shared external bus),
//!   batched frame fan-out, layer-pipelined streaming, and metrics
//!   (utilization, GOP/s, off-chip I/O) — the numbers of Table II.
//!   Layer kinds plug in through the
//!   [`LayerOp`](coordinator::ops::LayerOp) trait.
//! * [`energy`] — calibrated area (Table I, Fig. 3b) and activity-based
//!   power (Fig. 3c, Table II) models, technology scaling.
//! * [`baselines`] — analytical Eyeriss / Envision models for the
//!   comparison columns of Table II.
//! * [`runtime`] — PJRT loader for the AOT-compiled JAX/Pallas artifacts
//!   (HLO text) used as the bit-exact golden model.
//!
//! See `DESIGN.md` for the experiment index and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod baselines;
pub mod cli;
pub mod codegen;
pub mod coordinator;
pub mod core;
pub mod energy;
pub mod fixed;
pub mod isa;
pub mod mem;
pub mod model;
pub mod runtime;
pub mod util;

/// Peak MACs per cycle: 3 vector slots × 4 slices × 16 lanes (Table I).
pub const PEAK_MACS_PER_CYCLE: u64 = 192;
/// Target clock frequency in Hz (Table I).
pub const CLOCK_HZ: u64 = 400_000_000;
/// Peak throughput in GOP/s (1 MAC = 2 OP), Table I: 153.6 GOP/s.
pub const PEAK_GOPS: f64 = (2 * PEAK_MACS_PER_CYCLE * CLOCK_HZ) as f64 / 1e9;
