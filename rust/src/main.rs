//! `convaix` — CLI entrypoint. See `convaix help`.

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    match convaix::cli::main_with(&argv) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
