//! On-chip data memory: 128 KB in 16 dual-ported 8 KB banks.
//!
//! Storage is passive; *timing* is modeled by the access-recording layer:
//! every physical access notes its cycle, port and bank so the memory
//! interface can detect port-1 collisions (DMA / line-buffer fill hitting
//! the bank the pipeline is using in the same cycle) and charge stalls.
//! Counters feed the activity-based energy model (Fig. 3c).

use crate::isa::analysis::banks;

use super::{DM_BYTES, DM_PORT_BYTES};

#[derive(Debug)]
pub enum DmError {
    OutOfRange { addr: usize, len: usize },
    Misaligned { addr: usize, align: usize },
}

impl std::fmt::Display for DmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DmError::OutOfRange { addr, len } => {
                write!(f, "DM access out of range: addr {addr:#x} len {len} (DM is {DM_BYTES} bytes)")
            }
            DmError::Misaligned { addr, align } => {
                write!(f, "DM access misaligned: addr {addr:#x} requires {align}-byte alignment")
            }
        }
    }
}

impl std::error::Error for DmError {}

/// Activity counters (inputs to `energy::power`).
#[derive(Debug, Default, Clone)]
pub struct DmStats {
    /// 256-bit accesses on port 0 (pipeline loads).
    pub p0_reads: u64,
    /// 256-bit accesses on port 0 (pipeline stores).
    pub p0_writes: u64,
    /// 256-bit accesses on port 1 (DMA + line-buffer fill).
    pub p1_reads: u64,
    pub p1_writes: u64,
    /// Port-1 retries due to same-bank collision with port 0.
    pub bank_conflicts: u64,
}

pub struct DataMem {
    bytes: Vec<u8>,
    pub stats: DmStats,
    /// Bank touched by port 0 in the current cycle (set by the pipeline,
    /// cleared by `end_cycle`); port 1 must avoid it.
    p0_bank: Option<usize>,
}

impl Default for DataMem {
    fn default() -> Self {
        Self::new()
    }
}

impl DataMem {
    pub fn new() -> Self {
        Self { bytes: vec![0; DM_BYTES], stats: DmStats::default(), p0_bank: None }
    }

    /// Bank index of an address. The geometry and conflict rule live in
    /// `isa::analysis::banks` (shared with the static analyzers — moved
    /// there, not copied); this forwards for the simulator's callers.
    #[inline]
    pub fn bank_of(addr: usize) -> usize {
        banks::bank_of(addr)
    }

    fn check(&self, addr: usize, len: usize, align: usize) -> Result<(), DmError> {
        if addr % align != 0 {
            return Err(DmError::Misaligned { addr, align });
        }
        if addr + len > DM_BYTES {
            return Err(DmError::OutOfRange { addr, len });
        }
        Ok(())
    }

    // --- port 0 (pipeline) ------------------------------------------------

    /// 16-bit scalar load (port 0).
    pub fn read_i16_p0(&mut self, addr: usize) -> Result<i16, DmError> {
        self.check(addr, 2, 2)?;
        self.stats.p0_reads += 1;
        self.p0_bank = Some(Self::bank_of(addr));
        Ok(i16::from_le_bytes([self.bytes[addr], self.bytes[addr + 1]]))
    }

    pub fn write_i16_p0(&mut self, addr: usize, v: i16) -> Result<(), DmError> {
        self.check(addr, 2, 2)?;
        self.stats.p0_writes += 1;
        self.p0_bank = Some(Self::bank_of(addr));
        self.bytes[addr..addr + 2].copy_from_slice(&v.to_le_bytes());
        Ok(())
    }

    /// 256-bit vector load (port 0): 16 lanes of i16.
    pub fn read_vec_p0(&mut self, addr: usize) -> Result<[i16; 16], DmError> {
        self.check(addr, DM_PORT_BYTES, 2)?;
        self.stats.p0_reads += 1;
        self.p0_bank = Some(Self::bank_of(addr));
        Ok(self.peek_vec(addr))
    }

    pub fn write_vec_p0(&mut self, addr: usize, v: &[i16; 16]) -> Result<(), DmError> {
        self.check(addr, DM_PORT_BYTES, 2)?;
        self.stats.p0_writes += 1;
        self.p0_bank = Some(Self::bank_of(addr));
        for (i, x) in v.iter().enumerate() {
            self.bytes[addr + 2 * i..addr + 2 * i + 2].copy_from_slice(&x.to_le_bytes());
        }
        Ok(())
    }

    // --- port 1 (DMA / line buffer) ----------------------------------------

    /// Try a 32-byte port-1 read this cycle. Returns None (and counts a
    /// conflict) if port 0 already touched the same bank.
    pub fn try_read_block_p1(&mut self, addr: usize, len: usize) -> Result<Option<Vec<u8>>, DmError> {
        let len = len.min(DM_PORT_BYTES);
        self.check(addr, len, 1)?;
        if banks::p1_conflicts(self.p0_bank, addr) {
            self.stats.bank_conflicts += 1;
            return Ok(None);
        }
        self.stats.p1_reads += 1;
        Ok(Some(self.bytes[addr..addr + len].to_vec()))
    }

    pub fn try_write_block_p1(&mut self, addr: usize, data: &[u8]) -> Result<bool, DmError> {
        let len = data.len().min(DM_PORT_BYTES);
        self.check(addr, len, 1)?;
        if banks::p1_conflicts(self.p0_bank, addr) {
            self.stats.bank_conflicts += 1;
            return Ok(false);
        }
        self.stats.p1_writes += 1;
        self.bytes[addr..addr + len].copy_from_slice(&data[..len]);
        Ok(true)
    }

    /// End-of-cycle: clear the port-0 bank reservation.
    pub fn end_cycle(&mut self) {
        self.p0_bank = None;
    }

    // --- untimed debug/setup access (no stats, used by the loader) ---------

    pub fn peek_vec(&self, addr: usize) -> [i16; 16] {
        let mut out = [0i16; 16];
        for (i, o) in out.iter_mut().enumerate() {
            *o = i16::from_le_bytes([self.bytes[addr + 2 * i], self.bytes[addr + 2 * i + 1]]);
        }
        out
    }

    pub fn peek_i16(&self, addr: usize) -> i16 {
        i16::from_le_bytes([self.bytes[addr], self.bytes[addr + 1]])
    }

    pub fn poke_i16(&mut self, addr: usize, v: i16) {
        self.bytes[addr..addr + 2].copy_from_slice(&v.to_le_bytes());
    }

    pub fn poke_i16_slice(&mut self, addr: usize, vs: &[i16]) {
        for (i, v) in vs.iter().enumerate() {
            self.poke_i16(addr + 2 * i, *v);
        }
    }

    pub fn peek_i16_slice(&self, addr: usize, n: usize) -> Vec<i16> {
        (0..n).map(|i| self.peek_i16(addr + 2 * i)).collect()
    }

    pub fn peek_i32(&self, addr: usize) -> i32 {
        i32::from_le_bytes([
            self.bytes[addr],
            self.bytes[addr + 1],
            self.bytes[addr + 2],
            self.bytes[addr + 3],
        ])
    }

    pub fn poke_i32(&mut self, addr: usize, v: i32) {
        self.bytes[addr..addr + 4].copy_from_slice(&v.to_le_bytes());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bank_mapping() {
        assert_eq!(DataMem::bank_of(0), 0);
        assert_eq!(DataMem::bank_of(8191), 0);
        assert_eq!(DataMem::bank_of(8192), 1);
        assert_eq!(DataMem::bank_of(DM_BYTES - 1), 15);
    }

    #[test]
    fn rw_roundtrip() {
        let mut dm = DataMem::new();
        dm.write_i16_p0(100, -1234).unwrap();
        assert_eq!(dm.read_i16_p0(100).unwrap(), -1234);
        let v: [i16; 16] = std::array::from_fn(|i| i as i16 * 3 - 7);
        dm.write_vec_p0(256, &v).unwrap();
        assert_eq!(dm.read_vec_p0(256).unwrap(), v);
    }

    #[test]
    fn oob_rejected() {
        let mut dm = DataMem::new();
        assert!(dm.read_i16_p0(DM_BYTES).is_err());
        assert!(dm.read_vec_p0(DM_BYTES - 8).is_err());
        assert!(dm.write_i16_p0(1, 0).is_err()); // misaligned
    }

    #[test]
    fn port1_conflict_detected() {
        let mut dm = DataMem::new();
        // port 0 touches bank 0 this cycle
        dm.read_i16_p0(0).unwrap();
        // port 1 same bank -> rejected
        assert!(dm.try_read_block_p1(100, 32).unwrap().is_none());
        assert_eq!(dm.stats.bank_conflicts, 1);
        // port 1 other bank -> ok
        assert!(dm.try_read_block_p1(8192, 32).unwrap().is_some());
        // next cycle: free again
        dm.end_cycle();
        assert!(dm.try_read_block_p1(64, 32).unwrap().is_some());
    }

    #[test]
    fn stats_counted() {
        let mut dm = DataMem::new();
        dm.read_vec_p0(0).unwrap();
        dm.write_vec_p0(32, &[0; 16]).unwrap();
        dm.end_cycle();
        dm.try_write_block_p1(64, &[1u8; 32]).unwrap();
        assert_eq!(dm.stats.p0_reads, 1);
        assert_eq!(dm.stats.p0_writes, 1);
        assert_eq!(dm.stats.p1_writes, 1);
    }

    #[test]
    fn peek_poke_no_stats() {
        let mut dm = DataMem::new();
        dm.poke_i16_slice(10, &[1, 2, 3]);
        assert_eq!(dm.peek_i16_slice(10, 3), vec![1, 2, 3]);
        assert_eq!(dm.stats.p0_reads + dm.stats.p0_writes, 0);
        dm.poke_i32(100, -77777);
        assert_eq!(dm.peek_i32(100), -77777);
    }
}
