//! Two-channel descriptor DMA engine (Section IV: "a simple direct
//! memory access engine included in the memory interface").
//!
//! Each channel executes one transfer at a time, Ext→DM or DM→Ext, in
//! 32-byte bursts on DM port 1, throttled by the external-memory
//! bandwidth credit (EXT_BYTES_PER_CYCLE per core cycle, shared between
//! the channels) plus a fixed per-descriptor latency. Transfers overlap
//! compute; `DmaWait` in slot 0 blocks the pipeline until a channel
//! drains — the Fig. 2 double-buffering synchronization point.

use super::dm::DataMem;
use super::ext::ExtMem;
use super::DM_PORT_BYTES;

pub const DMA_CHANNELS: usize = 2;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DmaDir {
    ExtToDm,
    DmToExt,
}

#[derive(Debug, Clone)]
struct Xfer {
    dir: DmaDir,
    ext_addr: usize,
    dm_addr: usize,
    remaining: usize,
    latency_left: u64,
}

#[derive(Debug, Default, Clone)]
pub struct DmaStats {
    pub transfers: u64,
    pub bytes_moved: u64,
    /// Cycles where a burst was ready but DM port 1 was lost to
    /// arbitration or a bank conflict.
    pub port_stalls: u64,
    /// Cycles spent in fixed DRAM latency.
    pub latency_cycles: u64,
}

#[derive(Debug)]
pub enum DmaError {
    Busy(usize),
    BadChannel(usize),
}

impl std::fmt::Display for DmaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DmaError::Busy(ch) => write!(f, "DMA start on busy channel {ch}"),
            DmaError::BadChannel(ch) => write!(f, "DMA bad channel {ch}"),
        }
    }
}

impl std::error::Error for DmaError {}

pub struct DmaEngine {
    ch: [Option<Xfer>; DMA_CHANNELS],
    /// Accumulated external-bandwidth credit in bytes.
    credit: f64,
    /// Round-robin pointer for fair channel service.
    rr: usize,
    pub stats: DmaStats,
}

impl Default for DmaEngine {
    fn default() -> Self {
        Self::new()
    }
}

impl DmaEngine {
    pub fn new() -> Self {
        Self { ch: [None, None], credit: 0.0, rr: 0, stats: DmaStats::default() }
    }

    pub fn start(
        &mut self,
        ch: usize,
        dir: DmaDir,
        ext_addr: usize,
        dm_addr: usize,
        len: usize,
        latency: u64,
    ) -> Result<(), DmaError> {
        if ch >= DMA_CHANNELS {
            return Err(DmaError::BadChannel(ch));
        }
        if self.ch[ch].is_some() {
            return Err(DmaError::Busy(ch));
        }
        if len == 0 {
            return Ok(());
        }
        self.stats.transfers += 1;
        self.ch[ch] = Some(Xfer { dir, ext_addr, dm_addr, remaining: len, latency_left: latency });
        Ok(())
    }

    pub fn busy(&self, ch: usize) -> bool {
        ch < DMA_CHANNELS && self.ch[ch].is_some()
    }

    pub fn any_busy(&self) -> bool {
        self.ch.iter().any(Option::is_some)
    }

    /// One core cycle of DMA progress. `port1_free` tells whether DM
    /// port 1 is available this cycle (the memory interface arbitrates
    /// between DMA and line-buffer fill). Returns true if the port was
    /// consumed.
    pub fn tick(&mut self, dm: &mut DataMem, ext: &mut ExtMem, port1_free: bool) -> bool {
        self.credit += ext.bytes_per_cycle as f64;
        // cap the credit so idle periods don't bank unbounded bandwidth
        self.credit = self.credit.min(4.0 * DM_PORT_BYTES as f64);

        // tick down latencies
        for x in self.ch.iter_mut().flatten() {
            if x.latency_left > 0 {
                x.latency_left -= 1;
                self.stats.latency_cycles += 1;
            }
        }

        // pick a ready channel round-robin
        for k in 0..DMA_CHANNELS {
            let i = (self.rr + k) % DMA_CHANNELS;
            let ready = matches!(&self.ch[i], Some(x) if x.latency_left == 0);
            if !ready {
                continue;
            }
            let burst = {
                let x = self.ch[i].as_ref().unwrap();
                x.remaining.min(DM_PORT_BYTES)
            };
            if (self.credit as usize) < burst {
                return false; // external bus is the bottleneck this cycle
            }
            if !port1_free {
                self.stats.port_stalls += 1;
                return false;
            }
            let x = self.ch[i].as_mut().unwrap();
            let moved = match x.dir {
                DmaDir::ExtToDm => {
                    let data = ext.read(x.ext_addr, burst).to_vec();
                    match dm.try_write_block_p1(x.dm_addr, &data) {
                        Ok(true) => burst,
                        Ok(false) => {
                            // bank conflict with the pipeline: retry next cycle
                            ext.stats.bytes_read -= burst as u64; // un-count
                            self.stats.port_stalls += 1;
                            return false;
                        }
                        Err(e) => panic!("DMA DM write error: {e}"),
                    }
                }
                DmaDir::DmToExt => match dm.try_read_block_p1(x.dm_addr, burst) {
                    Ok(Some(data)) => {
                        ext.write(x.ext_addr, &data);
                        burst
                    }
                    Ok(None) => {
                        self.stats.port_stalls += 1;
                        return false;
                    }
                    Err(e) => panic!("DMA DM read error: {e}"),
                },
            };
            x.ext_addr += moved;
            x.dm_addr += moved;
            x.remaining -= moved;
            self.credit -= moved as f64;
            self.stats.bytes_moved += moved as u64;
            if x.remaining == 0 {
                self.ch[i] = None;
            }
            self.rr = (i + 1) % DMA_CHANNELS;
            return true;
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_to_idle(dma: &mut DmaEngine, dm: &mut DataMem, ext: &mut ExtMem) -> u64 {
        let mut cycles = 0;
        while dma.any_busy() {
            dma.tick(dm, ext, true);
            dm.end_cycle();
            cycles += 1;
            assert!(cycles < 1_000_000, "DMA hang");
        }
        cycles
    }

    #[test]
    fn ext_to_dm_roundtrip() {
        let mut dm = DataMem::new();
        let mut ext = ExtMem::new(1 << 16);
        let mut dma = DmaEngine::new();
        let data: Vec<i16> = (0..100).map(|i| i as i16 * 7 - 300).collect();
        ext.poke_i16_slice(0x100, &data);
        dma.start(0, DmaDir::ExtToDm, 0x100, 0x40, 200, 10).unwrap();
        run_to_idle(&mut dma, &mut dm, &mut ext);
        assert_eq!(dm.peek_i16_slice(0x40, 100), data);
        assert_eq!(dma.stats.bytes_moved, 200);
    }

    #[test]
    fn dm_to_ext_roundtrip() {
        let mut dm = DataMem::new();
        let mut ext = ExtMem::new(1 << 16);
        let mut dma = DmaEngine::new();
        dm.poke_i16_slice(0x80, &[5, -6, 7, -8]);
        dma.start(1, DmaDir::DmToExt, 0x200, 0x80, 8, 0).unwrap();
        run_to_idle(&mut dma, &mut dm, &mut ext);
        assert_eq!(ext.peek_i16_slice(0x200, 4), vec![5, -6, 7, -8]);
    }

    #[test]
    fn bandwidth_throttles() {
        // 1024 bytes at 8 B/cycle must take >= 128 cycles
        let mut dm = DataMem::new();
        let mut ext = ExtMem::new(1 << 16);
        let mut dma = DmaEngine::new();
        dma.start(0, DmaDir::ExtToDm, 0, 0, 1024, 0).unwrap();
        let cycles = run_to_idle(&mut dma, &mut dm, &mut ext);
        assert!(cycles >= 1024 / ext.bytes_per_cycle as u64, "cycles={cycles}");
    }

    #[test]
    fn latency_delays_start() {
        let mut dm = DataMem::new();
        let mut ext = ExtMem::new(1 << 16);
        let mut dma = DmaEngine::new();
        dma.start(0, DmaDir::ExtToDm, 0, 0, 32, 50).unwrap();
        let cycles = run_to_idle(&mut dma, &mut dm, &mut ext);
        assert!(cycles >= 50, "latency not applied: {cycles}");
    }

    #[test]
    fn busy_channel_rejected() {
        let mut dma = DmaEngine::new();
        dma.start(0, DmaDir::ExtToDm, 0, 0, 64, 0).unwrap();
        assert!(dma.start(0, DmaDir::ExtToDm, 0, 0, 64, 0).is_err());
        assert!(dma.start(2, DmaDir::ExtToDm, 0, 0, 64, 0).is_err());
    }

    #[test]
    fn two_channels_share_bandwidth() {
        let mut dm = DataMem::new();
        let mut ext = ExtMem::new(1 << 16);
        let mut dma = DmaEngine::new();
        dma.start(0, DmaDir::ExtToDm, 0, 0x000, 512, 0).unwrap();
        dma.start(1, DmaDir::ExtToDm, 0x400, 0x800, 512, 0).unwrap();
        let cycles = run_to_idle(&mut dma, &mut dm, &mut ext);
        // both transfers share the 8 B/cy bus: >= 1024/8
        assert!(cycles >= 128, "cycles={cycles}");
        assert_eq!(dma.stats.bytes_moved, 1024);
    }

    #[test]
    fn port_denied_stalls_but_completes() {
        let mut dm = DataMem::new();
        let mut ext = ExtMem::new(1 << 16);
        ext.bytes_per_cycle = 64; // ample credit so the port is the limiter
        let mut dma = DmaEngine::new();
        dma.start(0, DmaDir::ExtToDm, 0, 0, 64, 0).unwrap();
        let mut cycles = 0;
        while dma.any_busy() {
            // deny the port on even cycles
            dma.tick(&mut dm, &mut ext, cycles % 2 == 1);
            dm.end_cycle();
            cycles += 1;
            assert!(cycles < 10_000);
        }
        assert!(dma.stats.port_stalls > 0);
    }
}
