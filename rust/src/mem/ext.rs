//! External DRAM model.
//!
//! Passive byte storage plus the cost model the DMA engine consults:
//! a fixed per-request latency and a sustained bandwidth in bytes per
//! core cycle. Total bytes moved are counted here — this is exactly the
//! "Off-Chip I/O [MByte]" row of Table II (counted at the DMA boundary,
//! uncompressed, as footnote *d* of the paper states for ConvAix).

use super::{EXT_BYTES_PER_CYCLE, EXT_LATENCY_CYCLES};

#[derive(Debug, Default, Clone)]
pub struct ExtStats {
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub requests: u64,
}

impl ExtStats {
    pub fn total_bytes(&self) -> u64 {
        self.bytes_read + self.bytes_written
    }
    pub fn total_mbytes(&self) -> f64 {
        self.total_bytes() as f64 / 1e6
    }
}

pub struct ExtMem {
    bytes: Vec<u8>,
    pub stats: ExtStats,
    pub bytes_per_cycle: usize,
    pub latency_cycles: u64,
}

impl ExtMem {
    pub fn new(capacity: usize) -> Self {
        Self {
            bytes: vec![0; capacity],
            stats: ExtStats::default(),
            bytes_per_cycle: EXT_BYTES_PER_CYCLE,
            latency_cycles: EXT_LATENCY_CYCLES,
        }
    }

    pub fn capacity(&self) -> usize {
        self.bytes.len()
    }

    /// Timed read of up to `len` bytes (counted). The DMA engine calls
    /// this in bandwidth-sized chunks.
    pub fn read(&mut self, addr: usize, len: usize) -> &[u8] {
        assert!(addr + len <= self.bytes.len(), "ext read OOB {addr:#x}+{len}");
        self.stats.bytes_read += len as u64;
        &self.bytes[addr..addr + len]
    }

    pub fn write(&mut self, addr: usize, data: &[u8]) {
        assert!(addr + data.len() <= self.bytes.len(), "ext write OOB");
        self.stats.bytes_written += data.len() as u64;
        self.bytes[addr..addr + data.len()].copy_from_slice(data);
    }

    pub fn note_request(&mut self) {
        self.stats.requests += 1;
    }

    // untimed setup/inspection (tensor staging by the coordinator)
    pub fn poke(&mut self, addr: usize, data: &[u8]) {
        self.bytes[addr..addr + data.len()].copy_from_slice(data);
    }

    pub fn poke_i16_slice(&mut self, addr: usize, vs: &[i16]) {
        for (i, v) in vs.iter().enumerate() {
            self.bytes[addr + 2 * i..addr + 2 * i + 2].copy_from_slice(&v.to_le_bytes());
        }
    }

    pub fn peek(&self, addr: usize, len: usize) -> &[u8] {
        &self.bytes[addr..addr + len]
    }

    pub fn peek_i16_slice(&self, addr: usize, n: usize) -> Vec<i16> {
        (0..n)
            .map(|i| i16::from_le_bytes([self.bytes[addr + 2 * i], self.bytes[addr + 2 * i + 1]]))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_io_bytes() {
        let mut e = ExtMem::new(1 << 16);
        e.poke(0, &[1, 2, 3, 4]);
        let _ = e.read(0, 4).to_vec();
        e.write(100, &[9; 10]);
        assert_eq!(e.stats.bytes_read, 4);
        assert_eq!(e.stats.bytes_written, 10);
        assert_eq!(e.stats.total_bytes(), 14);
    }

    #[test]
    fn poke_peek_untimed() {
        let mut e = ExtMem::new(1024);
        e.poke_i16_slice(10, &[-5, 6, 7]);
        assert_eq!(e.peek_i16_slice(10, 3), vec![-5, 6, 7]);
        assert_eq!(e.stats.total_bytes(), 0);
    }

    #[test]
    #[should_panic]
    fn oob_panics() {
        let mut e = ExtMem::new(16);
        e.write(10, &[0; 10]);
    }
}
