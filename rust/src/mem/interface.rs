//! The custom memory interface (Section IV): owns DM, external memory,
//! the DMA engine and the line buffer, and arbitrates **DM port 1**
//! between the line-buffer fill path and the DMA each cycle (the
//! pipeline owns port 0 unconditionally).
//!
//! Arbitration policy: the line buffer wins (it feeds the vALUs on the
//! critical path; the DMA tolerates latency by design), DMA otherwise.

use super::dma::{DmaDir, DmaEngine, DmaError};
use super::dm::DataMem;
use super::ext::ExtMem;
use super::linebuf::{LbError, LineBuffer};

pub struct MemInterface {
    pub dm: DataMem,
    pub ext: ExtMem,
    pub dma: DmaEngine,
    pub lb: LineBuffer,
}

impl MemInterface {
    pub fn new(ext_capacity: usize) -> Self {
        Self {
            dm: DataMem::new(),
            ext: ExtMem::new(ext_capacity),
            dma: DmaEngine::new(),
            lb: LineBuffer::new(),
        }
    }

    /// True when no background engine needs `tick` work this cycle —
    /// the simulator's fast path (the vast majority of cycles).
    #[inline(always)]
    pub fn background_idle(&self) -> bool {
        !self.lb.filling() && !self.dma.any_busy()
    }

    /// One core cycle of background activity (call once per cycle, after
    /// the pipeline's port-0 access has been performed).
    pub fn tick(&mut self) {
        // line-buffer fill has priority on port 1
        let mut port1_used = false;
        if let Some((addr, len)) = self.lb.fill_request() {
            match self.dm.try_read_block_p1(addr, len) {
                Ok(Some(bytes)) => {
                    self.lb.accept_fill_data(&bytes);
                    port1_used = true;
                }
                Ok(None) => {
                    // bank conflict with port 0: retry next cycle
                    port1_used = true; // the attempt occupied the port
                }
                Err(e) => panic!("LB fill DM error: {e}"),
            }
        }
        self.dma.tick(&mut self.dm, &mut self.ext, !port1_used);
        self.dm.end_cycle();
    }

    pub fn start_dma(
        &mut self,
        ch: usize,
        dir: DmaDir,
        ext_addr: usize,
        dm_addr: usize,
        len: usize,
    ) -> Result<(), DmaError> {
        self.ext.note_request();
        let latency = self.ext.latency_cycles;
        self.dma.start(ch, dir, ext_addr, dm_addr, len, latency)
    }

    pub fn start_lb_fill(&mut self, row: usize, dm_addr: usize, len_px: usize) -> Result<(), LbError> {
        self.lb.start_fill(row, dm_addr, len_px)
    }

    pub fn start_lb_fill_2d(
        &mut self,
        row: usize,
        dm_addr: usize,
        win_px: usize,
        nrows: usize,
        rstride: usize,
    ) -> Result<(), LbError> {
        self.lb.start_fill_2d(row, dm_addr, win_px, nrows, rstride)
    }

    /// Drain all background engines (test helper / end-of-task barrier).
    /// Returns the number of cycles it took.
    pub fn drain(&mut self) -> u64 {
        let mut cycles = 0;
        while self.dma.any_busy() || self.lb.filling() {
            self.tick();
            cycles += 1;
            assert!(cycles < 100_000_000, "memory system hang");
        }
        cycles
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lb_beats_dma_on_port1() {
        let mut m = MemInterface::new(1 << 16);
        m.dm.poke_i16_slice(0, &[1; 64]);
        m.start_lb_fill(0, 0, 64).unwrap();
        m.start_dma(0, DmaDir::DmToExt, 0x100, 0x800, 256).unwrap();
        // while the LB fill is in flight, DMA should record port stalls
        // only when it actually had credit+data ready; just check both
        // finish and the LB is valid.
        let cycles = m.drain();
        assert!(cycles > 0);
        assert!(m.lb.can_read(0, 63));
        assert_eq!(m.ext.stats.bytes_written, 256);
    }

    #[test]
    fn dma_roundtrip_through_interface() {
        let mut m = MemInterface::new(1 << 16);
        let data: Vec<i16> = (0..128).map(|i| (i * 13 % 777) as i16).collect();
        m.ext.poke_i16_slice(0x1000, &data);
        m.start_dma(0, DmaDir::ExtToDm, 0x1000, 0x200, 256).unwrap();
        m.drain();
        assert_eq!(m.dm.peek_i16_slice(0x200, 128), data);
        // off-chip read I/O counted
        assert_eq!(m.ext.stats.bytes_read, 256);
    }

    #[test]
    fn drain_idle_is_zero_cycles() {
        let mut m = MemInterface::new(1024);
        assert_eq!(m.drain(), 0);
    }
}
