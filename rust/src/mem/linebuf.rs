//! The application-specific line buffer (Section IV).
//!
//! Holds 4 row slots of up to 64 pixels each. A `LbLoad` fills one slot
//! from DM in the background over port 1 (16 pixels = 32 bytes per
//! access); vector MAC operands read *completed* slots combinationally
//! with a per-instruction pixel offset and the CSR-configured stride —
//! this is how strided convolutions execute "with minimal cycle
//! overhead" and why filter loads get slot 0 to themselves.
//!
//! Reading a slot whose fill is still in flight interlocks the pipeline
//! (counted in `LbStats::read_stalls`).

/// Row slots (double buffering needs 2; 4 allows deeper prefetch).
pub const LB_ROWS: usize = 4;
/// Pixels per row slot. 64 covers the widest window the codegen emits:
/// 11 slices·stride-4 + FW-11 = 55 pixels (AlexNet conv1).
pub const LB_ROW_PIXELS: usize = 64;

#[derive(Debug, Default, Clone)]
pub struct LbStats {
    /// Completed row fills.
    pub fills: u64,
    /// Port-1 accesses used for fills.
    pub fill_accesses: u64,
    /// Pipeline stall cycles waiting on an in-flight fill.
    pub read_stalls: u64,
    /// Pixel reads served to the vector ALUs.
    pub pixel_reads: u64,
}

#[derive(Debug, Clone)]
struct Fill {
    row: usize,
    dm_addr: usize,
    /// pixels per source row window
    win_px: usize,
    /// source rows (windows are concatenated in the slot)
    nrows: usize,
    /// source row stride in bytes
    rstride: usize,
    done_px: usize,
}

pub struct LineBuffer {
    rows: [[i16; LB_ROW_PIXELS]; LB_ROWS],
    valid: [usize; LB_ROWS], // pixels valid per row
    fill: Option<Fill>,
    pub stats: LbStats,
}

impl Default for LineBuffer {
    fn default() -> Self {
        Self::new()
    }
}

#[derive(Debug)]
pub enum LbError {
    TooLong { len: usize },
    BadRow { row: usize },
    ReadPastEnd { row: usize, px: usize, valid: usize },
    Busy,
}

impl std::fmt::Display for LbError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LbError::TooLong { len } => {
                write!(f, "line-buffer fill of {len} pixels exceeds row capacity {LB_ROW_PIXELS}")
            }
            LbError::BadRow { row } => write!(f, "line-buffer row {row} out of range"),
            LbError::ReadPastEnd { row, px, valid } => {
                write!(f, "line-buffer read past valid data: row {row} pixel {px} (valid {valid})")
            }
            LbError::Busy => write!(f, "line-buffer fill started while a fill is in flight"),
        }
    }
}

impl std::error::Error for LbError {}

impl LineBuffer {
    pub fn new() -> Self {
        Self {
            rows: [[0; LB_ROW_PIXELS]; LB_ROWS],
            valid: [0; LB_ROWS],
            fill: None,
            stats: LbStats::default(),
        }
    }

    /// Begin filling `row` with `len` pixels from DM byte address `dm_addr`
    /// (1-D convenience wrapper over `start_fill_2d`).
    pub fn start_fill(&mut self, row: usize, dm_addr: usize, len: usize) -> Result<(), LbError> {
        self.start_fill_2d(row, dm_addr, len, 1, 0)
    }

    /// Begin a 2-D window fill: `nrows` windows of `win_px` pixels, read
    /// from `dm_addr + r*rstride`, concatenated in the slot. The previous
    /// contents of the row slot become invalid immediately.
    pub fn start_fill_2d(
        &mut self,
        row: usize,
        dm_addr: usize,
        win_px: usize,
        nrows: usize,
        rstride: usize,
    ) -> Result<(), LbError> {
        if row >= LB_ROWS {
            return Err(LbError::BadRow { row });
        }
        let len = win_px * nrows;
        if len > LB_ROW_PIXELS || win_px == 0 || nrows == 0 {
            return Err(LbError::TooLong { len });
        }
        if self.fill.is_some() {
            // hardware has a single fill engine; the assembler/codegen must
            // space LbLoads — modeled as an error surfaced to the program.
            return Err(LbError::Busy);
        }
        self.valid[row] = 0;
        self.fill = Some(Fill { row, dm_addr, win_px, nrows, rstride, done_px: 0 });
        Ok(())
    }

    /// True if a fill is in flight (the interface calls `tick_fill`).
    pub fn filling(&self) -> bool {
        self.fill.is_some()
    }

    /// Row targeted by the in-flight fill, if any.
    pub fn fill_row(&self) -> Option<usize> {
        self.fill.as_ref().map(|f| f.row)
    }

    /// Advance the fill by one port-1 access (up to 16 pixels, never
    /// crossing a source-row boundary). The caller (memory interface)
    /// has already won arbitration for port 1. Returns the DM address +
    /// length to read; the caller passes the bytes back via
    /// `accept_fill_data`.
    pub fn fill_request(&self) -> Option<(usize, usize)> {
        self.fill.as_ref().map(|f| {
            let src_row = f.done_px / f.win_px;
            let within = f.done_px % f.win_px;
            let px = (f.win_px - within).min(16);
            (f.dm_addr + src_row * f.rstride + 2 * within, 2 * px)
        })
    }

    pub fn accept_fill_data(&mut self, bytes: &[u8]) {
        let f = self.fill.as_mut().expect("no fill in flight");
        let px = bytes.len() / 2;
        for i in 0..px {
            self.rows[f.row][f.done_px + i] =
                i16::from_le_bytes([bytes[2 * i], bytes[2 * i + 1]]);
        }
        f.done_px += px;
        self.stats.fill_accesses += 1;
        if f.done_px >= f.win_px * f.nrows {
            self.valid[f.row] = f.done_px;
            self.stats.fills += 1;
            self.fill = None;
        }
    }

    /// Whether a vector op may read `px` pixels starting at `off` from
    /// `row` this cycle (fill complete and in range).
    pub fn can_read(&self, row: usize, max_px_index: usize) -> bool {
        row < LB_ROWS && max_px_index < self.valid[row]
    }

    /// Unchecked-fast pixel read for the simulator's hot path. Callers
    /// must have validated availability via `can_read` (the pipeline's
    /// LB interlock does); debug builds still bound-check.
    #[inline(always)]
    pub fn pixel(&self, row: usize, px: usize) -> i16 {
        debug_assert!(self.can_read(row, px), "LB fast read of invalid pixel");
        self.rows[row][px]
    }

    /// Bulk stats update for fast-path reads.
    #[inline(always)]
    pub fn note_pixel_reads(&mut self, n: u64) {
        self.stats.pixel_reads += n;
    }

    /// Read one pixel (combinational path to the vALU operand-prepare).
    pub fn read_pixel(&mut self, row: usize, px: usize) -> Result<i16, LbError> {
        if row >= LB_ROWS {
            return Err(LbError::BadRow { row });
        }
        if px >= self.valid[row] {
            return Err(LbError::ReadPastEnd { row, px, valid: self.valid[row] });
        }
        self.stats.pixel_reads += 1;
        Ok(self.rows[row][px])
    }

    pub fn note_read_stall(&mut self) {
        self.stats.read_stalls += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill_row(lb: &mut LineBuffer, row: usize, data: &[i16]) {
        lb.start_fill(row, 0, data.len()).unwrap();
        let mut fed = 0;
        while let Some((_addr, len)) = lb.fill_request() {
            let px = len / 2;
            let mut bytes = Vec::new();
            for v in &data[fed..fed + px] {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
            lb.accept_fill_data(&bytes);
            fed += px;
        }
    }

    #[test]
    fn fill_and_read() {
        let mut lb = LineBuffer::new();
        let data: Vec<i16> = (0..40).map(|i| i * 3 - 20).collect();
        fill_row(&mut lb, 1, &data);
        assert!(lb.can_read(1, 39));
        assert!(!lb.can_read(1, 40));
        for (i, v) in data.iter().enumerate() {
            assert_eq!(lb.read_pixel(1, i).unwrap(), *v);
        }
        assert_eq!(lb.stats.fills, 1);
        assert_eq!(lb.stats.fill_accesses, 3); // 16+16+8 pixels
    }

    #[test]
    fn read_during_fill_rejected() {
        let mut lb = LineBuffer::new();
        lb.start_fill(0, 0, 32).unwrap();
        assert!(!lb.can_read(0, 0));
        assert!(lb.read_pixel(0, 0).is_err());
    }

    #[test]
    fn double_fill_rejected() {
        let mut lb = LineBuffer::new();
        lb.start_fill(0, 0, 16).unwrap();
        assert!(matches!(lb.start_fill(1, 0, 16), Err(LbError::Busy)));
    }

    #[test]
    fn other_rows_stay_valid_during_fill() {
        let mut lb = LineBuffer::new();
        fill_row(&mut lb, 0, &[7; 20]);
        lb.start_fill(1, 0, 20).unwrap();
        assert!(lb.can_read(0, 19)); // row 0 untouched
        assert_eq!(lb.read_pixel(0, 5).unwrap(), 7);
    }

    #[test]
    fn capacity_checked() {
        let mut lb = LineBuffer::new();
        assert!(lb.start_fill(0, 0, LB_ROW_PIXELS + 1).is_err());
        assert!(lb.start_fill(4, 0, 8).is_err());
    }
}
