//! The ConvAix memory system (Section IV):
//!
//! * [`dm`] — 128 KB on-chip data memory, 16 dual-ported banks of 8 KB.
//!   Port 0 serves the pipeline's load/store unit (slot 0), port 1 is
//!   shared by the DMA engine and the line-buffer fill path (arbitrated
//!   in [`interface`]); same-bank collisions between the ports stall the
//!   background requester.
//! * [`pm`] — 16 KB program memory (512 encoded bundles).
//! * [`ext`] — external DRAM model: passive storage plus a bandwidth /
//!   latency cost model; counts the off-chip I/O bytes of Table II.
//! * [`dma`] — 2-channel descriptor DMA engine overlapping compute.
//! * [`linebuf`] — the application-specific IFMap row cache feeding the
//!   vector ALUs with (possibly strided) pixels at zero slot-0 cost.
//! * [`interface`] — the custom memory interface arbitrating port 1.

pub mod dma;
pub mod dm;
pub mod ext;
pub mod interface;
pub mod linebuf;
pub mod pm;

pub use dm::DataMem;
pub use dma::{DmaDir, DmaEngine};
pub use ext::ExtMem;
pub use interface::MemInterface;
pub use linebuf::LineBuffer;
pub use pm::ProgramMem;

/// Data-memory capacity: 128 KByte (Table I).
pub const DM_BYTES: usize = 128 * 1024;
/// Number of DM banks (Section IV: 16 banks of 8 KByte).
pub const DM_BANKS: usize = 16;
/// Bytes per DM bank.
pub const DM_BANK_BYTES: usize = DM_BYTES / DM_BANKS;
/// DM port width: one 256-bit vector per access.
pub const DM_PORT_BYTES: usize = 32;
/// Program-memory capacity: 16 KByte (Table I).
pub const PM_BYTES: usize = 16 * 1024;
/// External-memory bandwidth available to the DMA, bytes per core cycle.
/// 8 B/cy @ 400 MHz = 3.2 GB/s — a single-channel LPDDR3/4 class
/// interface, consistent with the paper's embedded target.
pub const EXT_BYTES_PER_CYCLE: usize = 8;
/// Fixed DRAM request latency in cycles (row activation + controller).
pub const EXT_LATENCY_CYCLES: u64 = 40;
/// Checksum throughput relative to the DMA stream: the fold unit
/// digests `CHECKSUM_BEATS_PER_CYCLE × EXT_BYTES_PER_CYCLE` bytes per
/// cycle (it rides the existing 64-bit datapath, 8 beats deep), so
/// verifying a transfer costs ~1/8th of streaming it.
pub const CHECKSUM_BEATS_PER_CYCLE: usize = 8;
/// Line-buffer capacity in pixels (i16). 2 KB — enough for a full
/// VGG/AlexNet row chunk including filter overlap.
pub const LB_PIXELS: usize = 1024;
