//! Program memory: 16 KB (Table I), i.e. 512 encoded 32-byte bundles.
//!
//! The simulator executes decoded bundles for speed, but every program is
//! loaded through its encoded image so the capacity limit is real: the
//! code generator must tile kernels to fit (and is tested for it).

use crate::isa::{encode, Program};
use super::PM_BYTES;

#[derive(Debug)]
pub enum PmError {
    TooLarge { size: usize },
    Encode(encode::EncodeError),
}

impl std::fmt::Display for PmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PmError::TooLarge { size } => {
                write!(f, "program of {size} bytes exceeds the {PM_BYTES}-byte program memory")
            }
            PmError::Encode(e) => write!(f, "encode: {e}"),
        }
    }
}

impl std::error::Error for PmError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            PmError::Encode(e) => Some(e),
            _ => None,
        }
    }
}

impl From<encode::EncodeError> for PmError {
    fn from(e: encode::EncodeError) -> Self {
        PmError::Encode(e)
    }
}

pub struct ProgramMem {
    image: Vec<u8>,
    program: Program,
}

impl ProgramMem {
    /// Load a program: encodes it (checking field ranges), verifies it
    /// fits, and keeps both the image and the decoded form.
    pub fn load(program: &Program) -> Result<Self, PmError> {
        let image = encode::encode_program(program)?;
        if image.len() > PM_BYTES {
            return Err(PmError::TooLarge { size: image.len() });
        }
        // round-trip through the image: what executes is what fits in PM
        let decoded = encode::decode_program(&image)?;
        Ok(Self { image, program: decoded })
    }

    pub fn program(&self) -> &Program {
        &self.program
    }

    pub fn image_bytes(&self) -> usize {
        self.image.len()
    }

    pub fn bundle_count(&self) -> usize {
        self.program.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Bundle, SlotOp};

    #[test]
    fn loads_and_roundtrips() {
        let mut p = Program::default();
        p.bundles.push(Bundle::s0(SlotOp::Halt));
        let pm = ProgramMem::load(&p).unwrap();
        assert_eq!(pm.bundle_count(), 1);
        assert_eq!(pm.image_bytes(), 32);
        assert_eq!(pm.program().bundles[0].slot0, SlotOp::Halt);
    }

    #[test]
    fn capacity_enforced() {
        let mut p = Program::default();
        for _ in 0..513 {
            p.bundles.push(Bundle::NOP);
        }
        assert!(matches!(ProgramMem::load(&p), Err(PmError::TooLarge { .. })));
    }

    #[test]
    fn exactly_512_fits() {
        let mut p = Program::default();
        for _ in 0..512 {
            p.bundles.push(Bundle::NOP);
        }
        assert!(ProgramMem::load(&p).is_ok());
    }
}
