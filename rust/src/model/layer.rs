//! Layer descriptors (batch-1, NCHW without N — the paper targets
//! latency-sensitive single-frame inference, Section III).

/// A 2-D convolutional layer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConvLayer {
    pub name: &'static str,
    pub ic: usize,
    pub ih: usize,
    pub iw: usize,
    pub oc: usize,
    pub fh: usize,
    pub fw: usize,
    pub stride: usize,
    pub pad: usize,
    /// Grouped convolution (AlexNet conv2/4/5 use 2 groups).
    pub groups: usize,
    /// Fractional shift of the requantization stage for this layer.
    pub frac_shift: u8,
    /// Fused ReLU.
    pub relu: bool,
}

impl ConvLayer {
    pub const fn new(
        name: &'static str,
        ic: usize,
        ih: usize,
        iw: usize,
        oc: usize,
        fh: usize,
        fw: usize,
        stride: usize,
        pad: usize,
        groups: usize,
    ) -> Self {
        Self { name, ic, ih, iw, oc, fh, fw, stride, pad, groups, frac_shift: 8, relu: true }
    }

    pub fn oh(&self) -> usize {
        (self.ih + 2 * self.pad - self.fh) / self.stride + 1
    }

    pub fn ow(&self) -> usize {
        (self.iw + 2 * self.pad - self.fw) / self.stride + 1
    }

    /// Padded input height/width (inputs are staged pre-padded).
    pub fn ihp(&self) -> usize {
        self.ih + 2 * self.pad
    }

    pub fn iwp(&self) -> usize {
        self.iw + 2 * self.pad
    }

    /// MAC count, grouped-convolution aware.
    pub fn macs(&self) -> u64 {
        (self.oc * (self.ic / self.groups) * self.fh * self.fw * self.oh() * self.ow()) as u64
    }

    /// 2·MACs, the paper's OP counting convention.
    pub fn ops(&self) -> u64 {
        2 * self.macs()
    }

    /// Weight parameter count.
    pub fn weights(&self) -> u64 {
        (self.oc * (self.ic / self.groups) * self.fh * self.fw) as u64
    }

    /// A single group viewed as a standalone dense conv (the executor
    /// runs grouped layers one group at a time).
    pub fn per_group(&self) -> ConvLayer {
        ConvLayer {
            ic: self.ic / self.groups,
            oc: self.oc / self.groups,
            groups: 1,
            ..self.clone()
        }
    }
}

/// A max-pooling layer (executed on the slot-1 SFU).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PoolLayer {
    pub name: &'static str,
    pub ic: usize,
    pub ih: usize,
    pub iw: usize,
    pub size: usize,
    pub stride: usize,
}

impl PoolLayer {
    pub fn oh(&self) -> usize {
        (self.ih - self.size) / self.stride + 1
    }
    pub fn ow(&self) -> usize {
        (self.iw - self.size) / self.stride + 1
    }
}

/// A fully connected layer: `y = act(W·x + b)`, matrix–vector over the
/// vector lanes. Executed through the Fig. 2 dataflow as a 1×1
/// convolution over a 1×1 map ([`FcLayer::as_conv`]): input features
/// become input channels (streamed as depth slices through the filter
/// FIFO), output neurons become output-channel tiles, so the oc-tile
/// machinery shards FC layers as *neuron tiles*. The conv→FC boundary
/// is an implicit flatten: NCHW-contiguous activations reinterpret as
/// the feature vector with no data movement.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FcLayer {
    pub name: &'static str,
    pub in_features: usize,
    pub out_features: usize,
    /// Fractional shift of the requantization stage for this layer.
    pub frac_shift: u8,
    /// Fused ReLU (off for logits layers like fc8).
    pub relu: bool,
}

impl FcLayer {
    pub const fn new(name: &'static str, in_features: usize, out_features: usize) -> Self {
        Self { name, in_features, out_features, frac_shift: 8, relu: true }
    }

    /// MAC count: one multiply per weight.
    pub fn macs(&self) -> u64 {
        (self.in_features * self.out_features) as u64
    }

    /// 2·MACs, the paper's OP counting convention.
    pub fn ops(&self) -> u64 {
        2 * self.macs()
    }

    /// Weight parameter count — FC cost is dominated by streaming these
    /// (each weight is used exactly once per frame).
    pub fn weights(&self) -> u64 {
        self.macs()
    }

    /// The layer lowered onto the conv dataflow: a 1×1 convolution over
    /// a 1×1 input map with `ic = in_features`, `oc = out_features`.
    /// Weight layout `(out, in)` equals the conv's `(oc, ic, 1, 1)`, so
    /// the lowering is bit-exact by construction.
    pub fn as_conv(&self) -> ConvLayer {
        ConvLayer {
            name: self.name,
            ic: self.in_features,
            ih: 1,
            iw: 1,
            oc: self.out_features,
            fh: 1,
            fw: 1,
            stride: 1,
            pad: 0,
            groups: 1,
            frac_shift: self.frac_shift,
            relu: self.relu,
        }
    }
}

/// A network layer: the unit of the coordinator's network walks. The
/// per-kind behavior (shapes, weight draws, execution, sharding, cost)
/// lives behind the [`LayerOp`](crate::coordinator::ops::LayerOp)
/// trait — `NetLayer::op()` is the single dispatch point; code outside
/// the trait impls must not match on the layer kind.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum NetLayer {
    Conv(ConvLayer),
    Pool(PoolLayer),
    Fc(FcLayer),
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_geometry() {
        let l = ConvLayer::new("t", 3, 227, 227, 96, 11, 11, 4, 0, 1);
        assert_eq!(l.oh(), 55);
        assert_eq!(l.ow(), 55);
        assert_eq!(l.macs(), 105_415_200);
    }

    #[test]
    fn grouped_macs() {
        let l = ConvLayer::new("t", 96, 27, 27, 256, 5, 5, 1, 2, 2);
        assert_eq!(l.macs(), 223_948_800);
        let g = l.per_group();
        assert_eq!(g.ic, 48);
        assert_eq!(g.oc, 128);
        assert_eq!(g.macs() * 2, l.macs());
    }

    #[test]
    fn pool_geometry() {
        let p = PoolLayer { name: "p", ic: 96, ih: 55, iw: 55, size: 3, stride: 2 };
        assert_eq!(p.oh(), 27);
        assert_eq!(p.ow(), 27);
    }

    #[test]
    fn fc_as_conv_is_the_exact_lowering() {
        let fc = FcLayer::new("fc6", 9216, 4096);
        assert_eq!(fc.macs(), 37_748_736);
        assert_eq!(fc.weights(), fc.macs());
        let c = fc.as_conv();
        assert_eq!((c.ic, c.oc), (9216, 4096));
        assert_eq!((c.ih, c.iw, c.fh, c.fw, c.stride, c.pad, c.groups), (1, 1, 1, 1, 1, 0, 1));
        assert_eq!((c.oh(), c.ow()), (1, 1));
        assert_eq!(c.macs(), fc.macs());
        assert_eq!(c.weights(), fc.weights());
        // logits layers carry relu=false through the lowering
        let mut fc8 = FcLayer::new("fc8", 4096, 1000);
        fc8.relu = false;
        assert!(!fc8.as_conv().relu);
    }
}
