//! CNN workload descriptions: AlexNet and VGG-16 (the paper's benchmarks),
//! mirrored bit-for-bit against `python/compile/model.py`, plus the FC
//! tails that turn the conv stacks into end-to-end networks.

pub mod layer;
pub mod nets;

pub use layer::{ConvLayer, FcLayer, NetLayer, PoolLayer};
pub use nets::{
    alexnet_conv, alexnet_fc, alexnet_full, alexnet_pools, conv_stack, vgg16_conv, vgg16_fc,
    vgg16_full, vgg16_pools,
};
