//! CNN workload descriptions: AlexNet and VGG-16 (the paper's benchmarks),
//! mirrored bit-for-bit against `python/compile/model.py`.

pub mod layer;
pub mod nets;

pub use layer::{ConvLayer, PoolLayer};
pub use nets::{alexnet_conv, alexnet_pools, vgg16_conv, vgg16_pools};
