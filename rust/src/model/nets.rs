//! AlexNet [1] and VGG-16 [14] workloads — the benchmark networks of
//! Table II. Shapes mirror `python/compile/model.py` and the original
//! papers; MAC totals are pinned by tests to the literature values
//! (0.666 GMAC AlexNet conv, 15.35 GMAC VGG-16 conv).
//!
//! The paper evaluates the conv stacks only ([`alexnet_conv`] /
//! [`vgg16_conv`]); serving wants whole nets, so [`alexnet_full`] /
//! [`vgg16_full`] interleave the pools and append the fc6/fc7/fc8
//! tails (fc8 is the logits layer — no ReLU). The conv→FC boundary is
//! an implicit flatten: the NCHW activation reinterprets as the
//! feature vector in place.

use super::layer::{ConvLayer, FcLayer, NetLayer, PoolLayer};

pub fn alexnet_conv() -> Vec<ConvLayer> {
    vec![
        ConvLayer::new("conv1", 3, 227, 227, 96, 11, 11, 4, 0, 1),
        ConvLayer::new("conv2", 96, 27, 27, 256, 5, 5, 1, 2, 2),
        ConvLayer::new("conv3", 256, 13, 13, 384, 3, 3, 1, 1, 1),
        ConvLayer::new("conv4", 384, 13, 13, 384, 3, 3, 1, 1, 2),
        ConvLayer::new("conv5", 384, 13, 13, 256, 3, 3, 1, 1, 2),
    ]
}

pub fn alexnet_pools() -> Vec<PoolLayer> {
    vec![
        PoolLayer { name: "pool1", ic: 96, ih: 55, iw: 55, size: 3, stride: 2 },
        PoolLayer { name: "pool2", ic: 256, ih: 27, iw: 27, size: 3, stride: 2 },
        PoolLayer { name: "pool5", ic: 256, ih: 13, iw: 13, size: 3, stride: 2 },
    ]
}

pub fn vgg16_conv() -> Vec<ConvLayer> {
    vec![
        ConvLayer::new("conv1_1", 3, 224, 224, 64, 3, 3, 1, 1, 1),
        ConvLayer::new("conv1_2", 64, 224, 224, 64, 3, 3, 1, 1, 1),
        ConvLayer::new("conv2_1", 64, 112, 112, 128, 3, 3, 1, 1, 1),
        ConvLayer::new("conv2_2", 128, 112, 112, 128, 3, 3, 1, 1, 1),
        ConvLayer::new("conv3_1", 128, 56, 56, 256, 3, 3, 1, 1, 1),
        ConvLayer::new("conv3_2", 256, 56, 56, 256, 3, 3, 1, 1, 1),
        ConvLayer::new("conv3_3", 256, 56, 56, 256, 3, 3, 1, 1, 1),
        ConvLayer::new("conv4_1", 256, 28, 28, 512, 3, 3, 1, 1, 1),
        ConvLayer::new("conv4_2", 512, 28, 28, 512, 3, 3, 1, 1, 1),
        ConvLayer::new("conv4_3", 512, 28, 28, 512, 3, 3, 1, 1, 1),
        ConvLayer::new("conv5_1", 512, 14, 14, 512, 3, 3, 1, 1, 1),
        ConvLayer::new("conv5_2", 512, 14, 14, 512, 3, 3, 1, 1, 1),
        ConvLayer::new("conv5_3", 512, 14, 14, 512, 3, 3, 1, 1, 1),
    ]
}

pub fn vgg16_pools() -> Vec<PoolLayer> {
    vec![
        PoolLayer { name: "pool1", ic: 64, ih: 224, iw: 224, size: 2, stride: 2 },
        PoolLayer { name: "pool2", ic: 128, ih: 112, iw: 112, size: 2, stride: 2 },
        PoolLayer { name: "pool3", ic: 256, ih: 56, iw: 56, size: 2, stride: 2 },
        PoolLayer { name: "pool4", ic: 512, ih: 28, iw: 28, size: 2, stride: 2 },
        PoolLayer { name: "pool5", ic: 512, ih: 14, iw: 14, size: 2, stride: 2 },
    ]
}

/// A conv stack as a layer list (the paper's conv-only evaluation
/// shape). The one place the `ConvLayer`→`NetLayer` mapping lives —
/// reports, benches and examples all go through here.
pub fn conv_stack(layers: Vec<ConvLayer>) -> Vec<NetLayer> {
    layers.into_iter().map(NetLayer::Conv).collect()
}

/// AlexNet FC tail: fc6/fc7/fc8. fc6 consumes pool5's 256·6·6 map
/// flattened to 9216 features; fc8 emits the 1000 logits without ReLU.
pub fn alexnet_fc() -> Vec<FcLayer> {
    let mut fc8 = FcLayer::new("fc8", 4096, 1000);
    fc8.relu = false;
    vec![FcLayer::new("fc6", 256 * 6 * 6, 4096), FcLayer::new("fc7", 4096, 4096), fc8]
}

/// VGG-16 FC tail: fc6 consumes pool5's 512·7·7 map (25088 features).
pub fn vgg16_fc() -> Vec<FcLayer> {
    let mut fc8 = FcLayer::new("fc8", 4096, 1000);
    fc8.relu = false;
    vec![FcLayer::new("fc6", 512 * 7 * 7, 4096), FcLayer::new("fc7", 4096, 4096), fc8]
}

/// Full AlexNet: convs and pools interleaved in execution order, FC
/// tail appended. Activation shapes chain end to end (pinned by test).
pub fn alexnet_full() -> Vec<NetLayer> {
    let c = alexnet_conv();
    let p = alexnet_pools();
    let mut net: Vec<NetLayer> = vec![
        NetLayer::Conv(c[0].clone()),
        NetLayer::Pool(p[0].clone()),
        NetLayer::Conv(c[1].clone()),
        NetLayer::Pool(p[1].clone()),
        NetLayer::Conv(c[2].clone()),
        NetLayer::Conv(c[3].clone()),
        NetLayer::Conv(c[4].clone()),
        NetLayer::Pool(p[2].clone()),
    ];
    net.extend(alexnet_fc().into_iter().map(NetLayer::Fc));
    net
}

/// Full VGG-16: the 13-conv stack with its 5 pools interleaved, FC
/// tail appended.
pub fn vgg16_full() -> Vec<NetLayer> {
    let c = vgg16_conv();
    let p = vgg16_pools();
    // pool after conv indices 1, 3, 6, 9, 12 (the 2/2/3/3/3 blocks)
    let block_ends = [1usize, 3, 6, 9, 12];
    let mut net = Vec::new();
    let mut pi = 0usize;
    for (i, l) in c.into_iter().enumerate() {
        net.push(NetLayer::Conv(l));
        if pi < block_ends.len() && i == block_ends[pi] {
            net.push(NetLayer::Pool(p[pi].clone()));
            pi += 1;
        }
    }
    net.extend(vgg16_fc().into_iter().map(NetLayer::Fc));
    net
}

/// Conv-stack MACs for AlexNet (matches the literature; pinned by test).
pub const ALEXNET_CONV_MACS: u64 = 665_784_864;
/// Conv-stack MACs for VGG-16.
pub const VGG16_CONV_MACS: u64 = 15_346_630_656;
/// FC-tail MACs for AlexNet (9216·4096 + 4096·4096 + 4096·1000).
pub const ALEXNET_FC_MACS: u64 = 58_621_952;
/// FC-tail MACs for VGG-16 (25088·4096 + 4096·4096 + 4096·1000).
pub const VGG16_FC_MACS: u64 = 123_633_664;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alexnet_totals() {
        let total: u64 = alexnet_conv().iter().map(|l| l.macs()).sum();
        assert_eq!(total, ALEXNET_CONV_MACS);
    }

    #[test]
    fn vgg_totals() {
        let total: u64 = vgg16_conv().iter().map(|l| l.macs()).sum();
        assert_eq!(total, VGG16_CONV_MACS);
    }

    #[test]
    fn chains_consistent() {
        let a = alexnet_conv();
        assert_eq!(a[0].oh(), 55);
        // pool1 55->27 feeds conv2
        assert_eq!((55 - 3) / 2 + 1, a[1].ih);
        for w in vgg16_conv().windows(2) {
            assert_eq!(w[1].ic, w[0].oc);
            assert!(w[1].ih == w[0].oh() || w[1].ih == w[0].oh() / 2);
        }
    }

    #[test]
    fn fc_totals() {
        let a: u64 = alexnet_fc().iter().map(|l| l.macs()).sum();
        assert_eq!(a, ALEXNET_FC_MACS);
        let v: u64 = vgg16_fc().iter().map(|l| l.macs()).sum();
        assert_eq!(v, VGG16_FC_MACS);
        // logits layers carry no ReLU
        assert!(!alexnet_fc().last().unwrap().relu);
        assert!(!vgg16_fc().last().unwrap().relu);
    }

    #[test]
    fn full_nets_chain_end_to_end() {
        // activation element counts must chain through every boundary,
        // including the implicit conv→FC flatten (checked through the
        // same LayerOp surface the network walk uses)
        for (net, layers, conv_macs, fc_macs) in [
            ("alexnet", alexnet_full(), ALEXNET_CONV_MACS, ALEXNET_FC_MACS),
            ("vgg16", vgg16_full(), VGG16_CONV_MACS, VGG16_FC_MACS),
        ] {
            for w in layers.windows(2) {
                assert_eq!(
                    w[1].op().in_elems(),
                    w[0].op().out_elems(),
                    "{net}: {} -> {} boundary",
                    w[0].name(),
                    w[1].name()
                );
            }
            let total: u64 = layers.iter().map(|l| l.op().macs()).sum();
            assert_eq!(total, conv_macs + fc_macs, "{net} total MACs");
            assert_eq!(layers.last().unwrap().op().out_elems(), 1000, "{net} logits");
        }
        // the flatten boundaries consume exactly the pool5 maps
        assert_eq!(alexnet_fc()[0].in_features, 256 * 6 * 6);
        assert_eq!(vgg16_fc()[0].in_features, 512 * 7 * 7);
        assert_eq!(alexnet_full().len(), 11);
        assert_eq!(vgg16_full().len(), 21);
    }

    #[test]
    fn ideal_time_matches_paper_arithmetic() {
        // MACs / 192 per cycle / 400 MHz = ideal time; paper: AlexNet
        // 12.60 ms at util 0.69 -> ideal 8.69 ms; VGG 263 ms at 0.76 ->
        // ideal 200 ms.
        let ideal_alex = ALEXNET_CONV_MACS as f64 / 192.0 / 400e6 * 1e3;
        assert!((ideal_alex - 8.67).abs() < 0.1, "{ideal_alex}");
        let ideal_vgg = VGG16_CONV_MACS as f64 / 192.0 / 400e6 * 1e3;
        assert!((ideal_vgg - 199.8).abs() < 1.0, "{ideal_vgg}");
    }
}
