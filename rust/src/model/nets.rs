//! AlexNet [1] and VGG-16 [14] convolutional stacks — the benchmark
//! workloads of Table II. Shapes mirror `python/compile/model.py` and the
//! original papers; MAC totals are pinned by tests to the literature
//! values (0.666 GMAC AlexNet conv, 15.35 GMAC VGG-16 conv).

use super::layer::{ConvLayer, PoolLayer};

pub fn alexnet_conv() -> Vec<ConvLayer> {
    vec![
        ConvLayer::new("conv1", 3, 227, 227, 96, 11, 11, 4, 0, 1),
        ConvLayer::new("conv2", 96, 27, 27, 256, 5, 5, 1, 2, 2),
        ConvLayer::new("conv3", 256, 13, 13, 384, 3, 3, 1, 1, 1),
        ConvLayer::new("conv4", 384, 13, 13, 384, 3, 3, 1, 1, 2),
        ConvLayer::new("conv5", 384, 13, 13, 256, 3, 3, 1, 1, 2),
    ]
}

pub fn alexnet_pools() -> Vec<PoolLayer> {
    vec![
        PoolLayer { name: "pool1", ic: 96, ih: 55, iw: 55, size: 3, stride: 2 },
        PoolLayer { name: "pool2", ic: 256, ih: 27, iw: 27, size: 3, stride: 2 },
        PoolLayer { name: "pool5", ic: 256, ih: 13, iw: 13, size: 3, stride: 2 },
    ]
}

pub fn vgg16_conv() -> Vec<ConvLayer> {
    vec![
        ConvLayer::new("conv1_1", 3, 224, 224, 64, 3, 3, 1, 1, 1),
        ConvLayer::new("conv1_2", 64, 224, 224, 64, 3, 3, 1, 1, 1),
        ConvLayer::new("conv2_1", 64, 112, 112, 128, 3, 3, 1, 1, 1),
        ConvLayer::new("conv2_2", 128, 112, 112, 128, 3, 3, 1, 1, 1),
        ConvLayer::new("conv3_1", 128, 56, 56, 256, 3, 3, 1, 1, 1),
        ConvLayer::new("conv3_2", 256, 56, 56, 256, 3, 3, 1, 1, 1),
        ConvLayer::new("conv3_3", 256, 56, 56, 256, 3, 3, 1, 1, 1),
        ConvLayer::new("conv4_1", 256, 28, 28, 512, 3, 3, 1, 1, 1),
        ConvLayer::new("conv4_2", 512, 28, 28, 512, 3, 3, 1, 1, 1),
        ConvLayer::new("conv4_3", 512, 28, 28, 512, 3, 3, 1, 1, 1),
        ConvLayer::new("conv5_1", 512, 14, 14, 512, 3, 3, 1, 1, 1),
        ConvLayer::new("conv5_2", 512, 14, 14, 512, 3, 3, 1, 1, 1),
        ConvLayer::new("conv5_3", 512, 14, 14, 512, 3, 3, 1, 1, 1),
    ]
}

pub fn vgg16_pools() -> Vec<PoolLayer> {
    vec![
        PoolLayer { name: "pool1", ic: 64, ih: 224, iw: 224, size: 2, stride: 2 },
        PoolLayer { name: "pool2", ic: 128, ih: 112, iw: 112, size: 2, stride: 2 },
        PoolLayer { name: "pool3", ic: 256, ih: 56, iw: 56, size: 2, stride: 2 },
        PoolLayer { name: "pool4", ic: 512, ih: 28, iw: 28, size: 2, stride: 2 },
        PoolLayer { name: "pool5", ic: 512, ih: 14, iw: 14, size: 2, stride: 2 },
    ]
}

/// Conv-stack MACs for AlexNet (matches the literature; pinned by test).
pub const ALEXNET_CONV_MACS: u64 = 665_784_864;
/// Conv-stack MACs for VGG-16.
pub const VGG16_CONV_MACS: u64 = 15_346_630_656;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alexnet_totals() {
        let total: u64 = alexnet_conv().iter().map(|l| l.macs()).sum();
        assert_eq!(total, ALEXNET_CONV_MACS);
    }

    #[test]
    fn vgg_totals() {
        let total: u64 = vgg16_conv().iter().map(|l| l.macs()).sum();
        assert_eq!(total, VGG16_CONV_MACS);
    }

    #[test]
    fn chains_consistent() {
        let a = alexnet_conv();
        assert_eq!(a[0].oh(), 55);
        // pool1 55->27 feeds conv2
        assert_eq!((55 - 3) / 2 + 1, a[1].ih);
        for w in vgg16_conv().windows(2) {
            assert_eq!(w[1].ic, w[0].oc);
            assert!(w[1].ih == w[0].oh() || w[1].ih == w[0].oh() / 2);
        }
    }

    #[test]
    fn ideal_time_matches_paper_arithmetic() {
        // MACs / 192 per cycle / 400 MHz = ideal time; paper: AlexNet
        // 12.60 ms at util 0.69 -> ideal 8.69 ms; VGG 263 ms at 0.76 ->
        // ideal 200 ms.
        let ideal_alex = ALEXNET_CONV_MACS as f64 / 192.0 / 400e6 * 1e3;
        assert!((ideal_alex - 8.67).abs() < 0.1, "{ideal_alex}");
        let ideal_vgg = VGG16_CONV_MACS as f64 / 192.0 / 400e6 * 1e3;
        assert!((ideal_vgg - 199.8).abs() < 1.0, "{ideal_vgg}");
    }
}
