//! Golden verification: cycle simulator ⇄ JAX/Pallas (via PJRT),
//! **bit-exact**, three-way (simulator / PJRT artifact / host reference).

use anyhow::Result;

use crate::codegen::refconv;
use crate::coordinator::EngineConfig;
use crate::fixed::RoundMode;
use crate::model::{ConvLayer, PoolLayer};
use crate::util::XorShift;

use super::pjrt::{ArtifactConv, ArtifactPool, Manifest, PjrtRunner};

#[derive(Debug)]
pub struct GoldenReport {
    pub name: String,
    pub elements: usize,
    pub sim_vs_pjrt_mismatches: usize,
    pub sim_vs_host_mismatches: usize,
    pub sim_cycles: u64,
    pub sim_util: f64,
}

impl GoldenReport {
    pub fn ok(&self) -> bool {
        self.sim_vs_pjrt_mismatches == 0 && self.sim_vs_host_mismatches == 0
    }
}

fn conv_layer_of(art: &ArtifactConv) -> ConvLayer {
    ConvLayer {
        name: "golden",
        ic: art.ic,
        ih: art.ih,
        iw: art.iw,
        oc: art.oc,
        fh: art.fh,
        fw: art.fw,
        stride: art.stride,
        pad: art.pad,
        groups: 1,
        frac_shift: art.frac_shift,
        relu: art.relu,
    }
}

/// Run one conv artifact through (a) the PJRT golden model, (b) the
/// cycle simulator, (c) the host reference, on identical synthetic
/// tensors, and compare bit-exactly.
pub fn golden_conv_check(
    runner: &PjrtRunner,
    manifest: &Manifest,
    art: &ArtifactConv,
    seed: u64,
) -> Result<GoldenReport> {
    let layer = conv_layer_of(art);
    let mut rng = XorShift::new(seed);
    let x = rng.i16_vec(art.ic * art.ih * art.iw, -2000, 2000);
    let w = rng.i16_vec(art.oc * art.ic * art.fh * art.fw, -256, 256);
    let b = rng.i32_vec(art.oc, -2000, 2000);

    let golden = runner.run_conv(manifest, art, &x, &w, &b)?;
    let host = refconv::conv2d(&x, &w, &b, &layer, RoundMode::HalfUp, 16);

    let mut engine = EngineConfig::new().build();
    let sim = engine
        .run_conv_layer(&layer, &x, &w, &b)
        .map_err(|e| anyhow::anyhow!("sim: {e}"))?;

    let mism = |a: &[i16], b: &[i16]| a.iter().zip(b).filter(|(x, y)| x != y).count();
    Ok(GoldenReport {
        name: art.name.clone(),
        elements: golden.len(),
        sim_vs_pjrt_mismatches: mism(&sim.out, &golden),
        sim_vs_host_mismatches: mism(&sim.out, &host),
        sim_cycles: sim.cycles,
        sim_util: sim.utilization(),
    })
}

/// Same for a pool artifact (SFU path).
pub fn golden_pool_check(
    runner: &PjrtRunner,
    manifest: &Manifest,
    art: &ArtifactPool,
    seed: u64,
) -> Result<GoldenReport> {
    let layer = PoolLayer {
        name: "golden",
        ic: art.ic,
        ih: art.ih,
        iw: art.iw,
        size: art.size,
        stride: art.stride,
    };
    let mut rng = XorShift::new(seed);
    let x = rng.i16_vec(art.ic * art.ih * art.iw, -30000, 30000);

    let golden = runner.run_pool(manifest, art, &x)?;
    let host = refconv::maxpool2d(&x, art.ic, art.ih, art.iw, art.size, art.stride);

    let mut engine = EngineConfig::new().ext_capacity(1 << 22).build();
    let sim = engine
        .run_pool_layer(&layer, &x)
        .map_err(|e| anyhow::anyhow!("sim: {e}"))?;

    let mism = |a: &[i16], b: &[i16]| a.iter().zip(b).filter(|(x, y)| x != y).count();
    Ok(GoldenReport {
        name: art.name.clone(),
        elements: golden.len(),
        sim_vs_pjrt_mismatches: mism(&sim.out, &golden),
        sim_vs_host_mismatches: mism(&sim.out, &host),
        sim_cycles: sim.cycles,
        sim_util: sim.utilization(),
    })
}
