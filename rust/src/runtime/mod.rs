//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! This is the bridge to the L1/L2 python layers (build-time only):
//! `make artifacts` lowers the Pallas conv/pool kernels to **HLO text**
//! (`artifacts/*.hlo.txt` + `manifest.json`); this module compiles them
//! on the PJRT CPU client (`xla` crate) and runs them as the **golden
//! model** — the cycle simulator's outputs must match **bit-exactly**
//! (both sides implement the same Q-format contract, `fixed` /
//! `kernels/quant.py`).
//!
//! HLO *text* is the interchange format: jax ≥ 0.5 emits HloModuleProto
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids (see /opt/xla-example/README.md).

pub mod golden;
pub mod pjrt;

pub use golden::{golden_conv_check, golden_pool_check, GoldenReport};
pub use pjrt::{ArtifactConv, ArtifactPool, Manifest, PjrtRunner};
