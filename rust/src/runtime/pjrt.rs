//! PJRT CPU client wrapper + artifact manifest parsing.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// A conv artifact entry from `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct ArtifactConv {
    pub name: String,
    pub ic: usize,
    pub ih: usize,
    pub iw: usize,
    pub oc: usize,
    pub fh: usize,
    pub fw: usize,
    pub stride: usize,
    pub pad: usize,
    pub frac_shift: u8,
    pub relu: bool,
    pub oh: usize,
    pub ow: usize,
}

/// A pool artifact entry.
#[derive(Debug, Clone)]
pub struct ArtifactPool {
    pub name: String,
    pub ic: usize,
    pub ih: usize,
    pub iw: usize,
    pub size: usize,
    pub stride: usize,
    pub oh: usize,
    pub ow: usize,
}

#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub convs: Vec<ArtifactConv>,
    pub pools: Vec<ArtifactPool>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Parse `<dir>/manifest.json` (written by `python -m compile.aot`).
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("{}/manifest.json (run `make artifacts`)", dir.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;
        let mut m = Manifest { dir, ..Default::default() };
        for c in j.get("convs").and_then(Json::as_arr).unwrap_or(&[]) {
            m.convs.push(ArtifactConv {
                name: c.s("name").to_string(),
                ic: c.u("ic"),
                ih: c.u("ih"),
                iw: c.u("iw"),
                oc: c.u("oc"),
                fh: c.u("fh"),
                fw: c.u("fw"),
                stride: c.u("stride"),
                pad: c.u("pad"),
                frac_shift: c.u("frac_shift") as u8,
                relu: c.u("relu") != 0,
                oh: c.u("oh"),
                ow: c.u("ow"),
            });
        }
        for p in j.get("pools").and_then(Json::as_arr).unwrap_or(&[]) {
            m.pools.push(ArtifactPool {
                name: p.s("name").to_string(),
                ic: p.u("ic"),
                ih: p.u("ih"),
                iw: p.u("iw"),
                size: p.u("size"),
                stride: p.u("stride"),
                oh: p.u("oh"),
                ow: p.u("ow"),
            });
        }
        Ok(m)
    }

    pub fn conv(&self, name: &str) -> Option<&ArtifactConv> {
        self.convs.iter().find(|c| c.name == name)
    }

    pub fn hlo_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }
}

/// PJRT CPU client + compiled-executable cache.
///
/// The real backend needs the external `xla` crate, which is not part of
/// the offline vendor set; it is kept behind the `xla-backend` feature.
/// The default build compiles a stub whose constructor returns a
/// descriptive error, so the golden CLI/tests degrade gracefully
/// (`tests/golden_integration.rs` already skips when `artifacts/` is
/// absent).
#[cfg(feature = "xla-backend")]
pub struct PjrtRunner {
    client: xla::PjRtClient,
}

#[cfg(not(feature = "xla-backend"))]
pub struct PjrtRunner {
    _private: (),
}

#[cfg(not(feature = "xla-backend"))]
impl PjrtRunner {
    pub fn new() -> Result<Self> {
        anyhow::bail!(
            "PJRT golden backend unavailable: convaix was built without the \
             `xla-backend` feature (the `xla` crate is not in the offline vendor set)"
        )
    }

    pub fn run_conv(
        &self,
        _manifest: &Manifest,
        _art: &ArtifactConv,
        _x: &[i16],
        _w: &[i16],
        _b: &[i32],
    ) -> Result<Vec<i16>> {
        anyhow::bail!("PJRT golden backend unavailable (built without `xla-backend`)")
    }

    pub fn run_pool(&self, _manifest: &Manifest, _art: &ArtifactPool, _x: &[i16]) -> Result<Vec<i16>> {
        anyhow::bail!("PJRT golden backend unavailable (built without `xla-backend`)")
    }
}

#[cfg(feature = "xla-backend")]
impl PjrtRunner {
    pub fn new() -> Result<Self> {
        Ok(Self { client: xla::PjRtClient::cpu()? })
    }

    /// i16 literal (the crate's `vec1` covers only 32/64-bit natives;
    /// 16-bit tensors go through the untyped-bytes constructor).
    fn literal_i16(data: &[i16], dims: &[usize]) -> Result<xla::Literal> {
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::S16,
            dims,
            &bytes,
        )?)
    }

    fn compile(&self, hlo_path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().context("non-utf8 path")?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }

    /// Execute a conv artifact: x (ic·ih·iw i16), w (oc·ic·fh·fw i16),
    /// b (oc i32) -> (oc·oh·ow i16).
    pub fn run_conv(
        &self,
        manifest: &Manifest,
        art: &ArtifactConv,
        x: &[i16],
        w: &[i16],
        b: &[i32],
    ) -> Result<Vec<i16>> {
        assert_eq!(x.len(), art.ic * art.ih * art.iw);
        assert_eq!(w.len(), art.oc * art.ic * art.fh * art.fw);
        assert_eq!(b.len(), art.oc);
        let exe = self.compile(&manifest.hlo_path(&art.name))?;
        let xl = Self::literal_i16(x, &[art.ic, art.ih, art.iw])?;
        let wl = Self::literal_i16(w, &[art.oc, art.ic, art.fh, art.fw])?;
        let bl = xla::Literal::vec1(b);
        let result = exe.execute::<xla::Literal>(&[xl, wl, bl])?[0][0].to_literal_sync()?;
        // lowered with return_tuple=True -> 1-tuple
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<i16>()?)
    }

    /// Execute a pool artifact: x (ic·ih·iw i16) -> (ic·oh·ow i16).
    pub fn run_pool(
        &self,
        manifest: &Manifest,
        art: &ArtifactPool,
        x: &[i16],
    ) -> Result<Vec<i16>> {
        assert_eq!(x.len(), art.ic * art.ih * art.iw);
        let exe = self.compile(&manifest.hlo_path(&art.name))?;
        let xl = Self::literal_i16(x, &[art.ic, art.ih, art.iw])?;
        let result = exe.execute::<xla::Literal>(&[xl])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<i16>()?)
    }
}
