//! Micro-benchmark measurer (criterion is not in the offline vendor set).
//!
//! `cargo bench` targets use `harness = false` and call [`Bench::run`] /
//! the table regenerators directly. Reports min/median/mean over N
//! timed iterations after warmup, criterion-style.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub min_ns: u128,
    pub median_ns: u128,
    pub mean_ns: u128,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<40} iters={:<4} min={:>12} median={:>12} mean={:>12}",
            self.name,
            self.iters,
            fmt_ns(self.min_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns)
        );
    }
}

pub fn fmt_ns(ns: u128) -> String {
    if ns >= 1_000_000_000 {
        format!("{:.3} s", ns as f64 / 1e9)
    } else if ns >= 1_000_000 {
        format!("{:.3} ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.3} µs", ns as f64 / 1e3)
    } else {
        format!("{ns} ns")
    }
}

pub struct Bench {
    pub warmup: usize,
    pub iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Self { warmup: 2, iters: 10 }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self { warmup: 1, iters: 5 }
    }

    /// Time `f`, which must return something observable to prevent DCE.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples: Vec<u128> = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_nanos());
        }
        samples.sort_unstable();
        let res = BenchResult {
            name: name.to_string(),
            iters: self.iters,
            min_ns: samples[0],
            median_ns: samples[samples.len() / 2],
            mean_ns: samples.iter().sum::<u128>() / samples.len() as u128,
        };
        res.report();
        res
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bench { warmup: 1, iters: 3 };
        let r = b.run("noop-ish", || {
            let mut s = 0u64;
            for i in 0..1000u64 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert!(r.min_ns > 0);
        assert!(r.min_ns <= r.median_ns);
        assert!(r.median_ns <= r.mean_ns * 2);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500).contains("ns"));
        assert!(fmt_ns(5_000).contains("µs"));
        assert!(fmt_ns(5_000_000).contains("ms"));
        assert!(fmt_ns(5_000_000_000).contains(" s"));
    }
}
