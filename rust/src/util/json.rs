//! Minimal JSON reader/writer (serde is not in the offline vendor set).
//!
//! Supports exactly the subset used by `artifacts/manifest.json` and the
//! metric dumps: objects, arrays, strings (no escapes beyond \" \\ \n \t),
//! integers, floats, booleans, null.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Convenience: `obj.u("field")` with a clear panic for malformed
    /// manifests (build-time artifacts, not user input).
    pub fn u(&self, key: &str) -> usize {
        self.get(key)
            .and_then(Json::as_usize)
            .unwrap_or_else(|| panic!("manifest field `{key}` missing/not a number"))
    }

    pub fn s(&self, key: &str) -> &str {
        self.get(key)
            .and_then(Json::as_str)
            .unwrap_or_else(|| panic!("manifest field `{key}` missing/not a string"))
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected `{}` at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'/') => s.push('/'),
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(c) => {
                    // raw UTF-8 passthrough
                    let start = self.i;
                    let len = utf8_len(c);
                    self.i += len;
                    s.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?);
                }
                None => return Err("unterminated string".into()),
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-' {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let s = r#"{"convs": [{"name": "c", "ic": 8, "relu": 1}], "pools": []}"#;
        let j = Json::parse(s).unwrap();
        let convs = j.get("convs").unwrap().as_arr().unwrap();
        assert_eq!(convs.len(), 1);
        assert_eq!(convs[0].s("name"), "c");
        assert_eq!(convs[0].u("ic"), 8);
    }

    #[test]
    fn roundtrip() {
        let s = r#"{"a":[1,2.5,-3],"b":"x\"y","c":true,"d":null}"#;
        let j = Json::parse(s).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\"}").is_err());
    }

    #[test]
    fn nested() {
        let j = Json::parse(r#"[[[1]],{"k":{"l":[true,false]}}]"#).unwrap();
        assert!(j.as_arr().unwrap().len() == 2);
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-12").unwrap().as_i64(), Some(-12));
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(Json::parse("2.5").unwrap().as_f64(), Some(2.5));
    }
}
