//! Small self-contained utilities (the offline vendor set has no serde /
//! criterion / proptest — these are the hand-rolled substitutes).

pub mod bench;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod table;

pub use rng::XorShift;
