//! Miniature property-testing harness (the offline vendor set has no
//! `proptest`/`quickcheck`). Deterministic seeds, fixed case counts, and
//! a shrink-on-failure pass that retries with "smaller" integer inputs.
//!
//! Usage (`no_run`: doctest executables miss the xla rpath in this image):
//! ```no_run
//! use convaix::util::proptest::prop;
//! prop("addition commutes", 100, |g| {
//!     let a = g.int(-1000, 1000);
//!     let b = g.int(-1000, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::rng::XorShift;

/// Value generator handed to property closures. Records the draws so a
/// failing case can be reported and (coarsely) shrunk.
pub struct Gen {
    rng: XorShift,
    pub draws: Vec<i64>,
    /// When replaying a shrink candidate this holds the forced values.
    forced: Option<Vec<i64>>,
    cursor: usize,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Self { rng: XorShift::new(seed), draws: Vec::new(), forced: None, cursor: 0 }
    }

    fn replay(values: Vec<i64>) -> Self {
        Self {
            rng: XorShift::new(0),
            draws: Vec::new(),
            forced: Some(values),
            cursor: 0,
        }
    }

    /// Draw an integer in [lo, hi] (inclusive).
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        let v = if let Some(forced) = &self.forced {
            let raw = forced.get(self.cursor).copied().unwrap_or(lo);
            self.cursor += 1;
            raw.clamp(lo, hi)
        } else {
            lo + (self.rng.next_u64() % (hi - lo + 1) as u64) as i64
        };
        self.draws.push(v);
        v
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.int(lo as i64, hi as i64) as usize
    }

    pub fn i16_in(&mut self, lo: i16, hi: i16) -> i16 {
        self.int(lo as i64, hi as i64) as i16
    }

    pub fn bool(&mut self) -> bool {
        self.int(0, 1) == 1
    }

    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize_in(0, items.len() - 1)]
    }

    pub fn vec_i16(&mut self, n: usize, lo: i16, hi: i16) -> Vec<i16> {
        (0..n).map(|_| self.i16_in(lo, hi)).collect()
    }
}

/// Run `cases` random cases of `f`; on panic, attempt a simple shrink
/// (halving each recorded draw towards zero) and re-panic with the
/// minimal found counterexample draws.
pub fn prop<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(name: &str, cases: u64, f: F) {
    for case in 0..cases {
        let seed = 0xC0DE_0000 + case;
        let mut g = Gen::new(seed);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut g)));
        if let Err(err) = r {
            // shrink: repeatedly halve draws while still failing
            let mut best = g.draws.clone();
            let mut improved = true;
            while improved {
                improved = false;
                for i in 0..best.len() {
                    if best[i] == 0 {
                        continue;
                    }
                    let mut cand = best.clone();
                    cand[i] /= 2;
                    let mut rg = Gen::replay(cand.clone());
                    let failed = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        f(&mut rg)
                    }))
                    .is_err();
                    if failed {
                        best = cand;
                        improved = true;
                    }
                }
            }
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "panic".into());
            panic!(
                "property `{name}` failed (case {case}, seed {seed:#x}): {msg}\n  shrunk draws: {best:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        prop("add commutes", 50, |g| {
            let a = g.int(-100, 100);
            let b = g.int(-100, 100);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn failing_property_is_reported_and_shrunk() {
        let r = std::panic::catch_unwind(|| {
            prop("always small", 50, |g| {
                let v = g.int(0, 1000);
                assert!(v < 500, "v too big: {v}");
            });
        });
        let msg = format!("{:?}", r.unwrap_err().downcast_ref::<String>());
        assert!(msg.contains("always small"));
    }

    #[test]
    fn gen_bounds_inclusive() {
        let mut g = Gen::new(1);
        for _ in 0..1000 {
            let v = g.int(-3, 3);
            assert!((-3..=3).contains(&v));
        }
    }

    #[test]
    fn pick_and_bool() {
        let mut g = Gen::new(2);
        let items = [1, 2, 3];
        for _ in 0..50 {
            assert!(items.contains(g.pick(&items)));
            let _ = g.bool();
        }
    }
}
