//! Deterministic xorshift64* PRNG for synthetic workload data.
//!
//! The paper's metrics are data-independent (dense fixed-point datapath),
//! but golden comparisons need *identical* tensors on the rust and PJRT
//! sides — a tiny, fully specified generator guarantees that.

/// xorshift64* — 64-bit state, passes BigCrush for our purposes.
#[derive(Debug, Clone)]
pub struct XorShift {
    state: u64,
}

impl XorShift {
    pub fn new(seed: u64) -> Self {
        // avoid the all-zero fixed point
        Self { state: seed.wrapping_mul(0x9E3779B97F4A7C15) | 1 }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [lo, hi) (hi > lo).
    #[inline]
    pub fn range_i32(&mut self, lo: i32, hi: i32) -> i32 {
        debug_assert!(hi > lo);
        let span = (hi - lo) as u64;
        lo + (self.next_u64() % span) as i32
    }

    /// Random i16 in [lo, hi).
    #[inline]
    pub fn i16_in(&mut self, lo: i16, hi: i16) -> i16 {
        self.range_i32(lo as i32, hi as i32) as i16
    }

    /// Vector of random i16 in [lo, hi).
    pub fn i16_vec(&mut self, n: usize, lo: i16, hi: i16) -> Vec<i16> {
        (0..n).map(|_| self.i16_in(lo, hi)).collect()
    }

    /// Vector of random i32 in [lo, hi).
    pub fn i32_vec(&mut self, n: usize, lo: i32, hi: i32) -> Vec<i32> {
        (0..n).map(|_| self.range_i32(lo, hi)).collect()
    }

    /// Uniform f64 in [0,1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = XorShift::new(42);
        let mut b = XorShift::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = XorShift::new(1);
        let mut b = XorShift::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn range_bounds() {
        let mut r = XorShift::new(7);
        for _ in 0..1000 {
            let v = r.range_i32(-100, 100);
            assert!((-100..100).contains(&v));
        }
    }

    #[test]
    fn i16_vec_len_and_bounds() {
        let mut r = XorShift::new(3);
        let v = r.i16_vec(257, -50, 50);
        assert_eq!(v.len(), 257);
        assert!(v.iter().all(|&x| (-50..50).contains(&x)));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = XorShift::new(9);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }
}
